"""Fault-tolerant checkpointing: atomic sharded save/restore, keep-N GC,
and elastic remesh on restore.

Layout (one directory per step):
    <dir>/step_000042/
        manifest.json      tree structure, shapes, dtypes, step, extras
        arr_00000.npy ...  one file per leaf (written per-host on a cluster)
    <dir>/step_000042.done  commit marker (atomicity: tmpdir + rename +
                            marker — a crash mid-write leaves no valid step)

Restore paths:
* ``restore(dir)``           — latest committed step, host arrays.
* ``restore(dir, shardings=...)`` — device_put each leaf with the given
  sharding pytree: this is the **elastic remesh** path (restore a checkpoint
  taken on one mesh onto a different mesh/pod count — shardings come from
  the new mesh's rules).
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _treedef_to_str(treedef) -> str:
    return str(treedef)


def save(dir_: str, step: int, tree, *, extras: dict | None = None,
         keep: int = 3) -> str:
    """Atomically write a checkpoint; prune to the newest ``keep`` steps."""
    os.makedirs(dir_, exist_ok=True)
    name = f"step_{step:09d}"
    final = os.path.join(dir_, name)
    leaves, treedef = _flatten(tree)
    tmp = tempfile.mkdtemp(dir=dir_, prefix=".tmp_" + name)
    try:
        manifest = {
            "step": step,
            "n_leaves": len(leaves),
            "extras": extras or {},
            "leaves": [],
        }
        for i, leaf in enumerate(leaves):
            arr = np.asarray(jax.device_get(leaf))
            np.save(os.path.join(tmp, f"arr_{i:05d}.npy"), arr)
            manifest["leaves"].append(
                {"shape": list(arr.shape), "dtype": str(arr.dtype)})
        # Store the pytree structure via example (keys) serialization.
        paths = [jax.tree_util.keystr(p)
                 for p, _ in jax.tree_util.tree_flatten_with_path(tree)[0]]
        manifest["paths"] = paths
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        # Commit marker written only after rename completes.
        with open(final + ".done", "w") as f:
            f.write(str(step))
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _gc(dir_, keep)
    return final


def _gc(dir_: str, keep: int):
    steps = committed_steps(dir_)
    for s in steps[:-keep] if keep else []:
        name = os.path.join(dir_, f"step_{s:09d}")
        shutil.rmtree(name, ignore_errors=True)
        try:
            os.remove(name + ".done")
        except OSError:
            pass


def committed_steps(dir_: str) -> list[int]:
    if not os.path.isdir(dir_):
        return []
    out = []
    for f in os.listdir(dir_):
        if f.endswith(".done") and f.startswith("step_"):
            out.append(int(f[len("step_"):-len(".done")]))
    return sorted(out)


def latest_step(dir_: str) -> int | None:
    steps = committed_steps(dir_)
    return steps[-1] if steps else None


def restore(dir_: str, like, *, step: int | None = None, shardings=None):
    """Restore into the structure of ``like`` (a pytree or eval_shape tree).

    ``shardings``: optional pytree of ``jax.sharding.Sharding`` matching
    ``like`` — each leaf is device_put with its sharding (elastic remesh).
    Returns (tree, step, extras).
    """
    step = latest_step(dir_) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no committed checkpoint in {dir_}")
    path = os.path.join(dir_, f"step_{step:09d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    leaves_like, treedef = _flatten(like)
    if len(leaves_like) != manifest["n_leaves"]:
        raise ValueError(
            f"checkpoint has {manifest['n_leaves']} leaves; "
            f"restore target has {len(leaves_like)}")
    leaves = []
    for i, spec in enumerate(manifest["leaves"]):
        arr = np.load(os.path.join(path, f"arr_{i:05d}.npy"))
        if list(arr.shape) != list(leaves_like[i].shape):
            raise ValueError(
                f"leaf {i}: checkpoint shape {arr.shape} != target "
                f"{leaves_like[i].shape}")
        leaves.append(arr)
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.tree.map(
            lambda a, s: jax.device_put(a, s), tree, shardings)
    return tree, step, manifest.get("extras", {})
