"""Small bounded LRU cache for compiled artifacts.

The repo keeps several module-wide caches of expensive compiled objects —
jitted scorers (``api.get_scorer``), the device pipeline's produce→graph
stages (``optimize.DevicePipeline``), and the design service's evaluators
(``serve.design``).  As one-shot experiment runners these could stay
unbounded dicts; a long-lived serving process cannot leak compiled
executables, so they are all backed by this LRU with an eviction counter
(surfaced through ``api.scorer_cache_stats`` / ``SweepStats`` /
``serve.design.DesignStats``).

Keys that must survive while in active use (e.g. an evaluator whose run
generators are still live) can be *pinned*: pinned entries are skipped
when choosing an eviction victim, and the cache is allowed to exceed its
capacity transiently while everything is pinned.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Callable


class LRUCache:
    """Least-recently-used mapping with a capacity, pins and an eviction
    counter.  ``get``/``__getitem__``/``__setitem__`` refresh recency."""

    def __init__(self, capacity: int, on_evict: Callable | None = None):
        if capacity < 1:
            raise ValueError(f"LRU capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self.evictions = 0
        self._on_evict = on_evict
        self._data: OrderedDict = OrderedDict()
        self._pins: dict = {}           # key -> pin count

    # -- mapping ----------------------------------------------------------
    def __contains__(self, key) -> bool:
        return key in self._data

    def __len__(self) -> int:
        return len(self._data)

    def __iter__(self):
        return iter(self._data)

    def __getitem__(self, key):
        self._data.move_to_end(key)
        return self._data[key]

    def get(self, key, default=None):
        if key not in self._data:
            return default
        return self[key]

    def __setitem__(self, key, value) -> None:
        self._data[key] = value
        self._data.move_to_end(key)
        self._shrink()

    def pop(self, key, *default):
        self._pins.pop(key, None)
        return self._data.pop(key, *default)

    def clear(self) -> None:
        self._data.clear()
        self._pins.clear()

    # -- pinning ----------------------------------------------------------
    def pin(self, key) -> None:
        """Protect ``key`` from eviction until :meth:`unpin` (refcounted)."""
        if key not in self._data:
            raise KeyError(key)
        self._pins[key] = self._pins.get(key, 0) + 1

    def unpin(self, key) -> None:
        n = self._pins.get(key, 0) - 1
        if n <= 0:
            self._pins.pop(key, None)
            self._shrink()
        else:
            self._pins[key] = n

    def pinned(self, key) -> bool:
        return self._pins.get(key, 0) > 0

    # -- capacity ---------------------------------------------------------
    def set_capacity(self, capacity: int) -> None:
        if capacity < 1:
            raise ValueError(f"LRU capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self._shrink()

    def _shrink(self) -> None:
        while len(self._data) > self.capacity:
            victim = next((k for k in self._data if not self.pinned(k)),
                          None)
            if victim is None:          # everything pinned: overflow for now
                return
            value = self._data.pop(victim)
            self.evictions += 1
            if self._on_evict is not None:
                self._on_evict(victim, value)
