"""Layered traffic-evaluation package.

* :mod:`repro.netsim.workload` — traces / synthetic traffic compiled
  into fixed-shape demand tensors (runtime operands of the scorer).
* :mod:`repro.netsim.model` — batched jitted ECMP + queueing rate model
  over stacked ScoreGraphs; feeds the ``trace-lat`` objective term.
* :mod:`repro.netsim.sim` — the event-driven wormhole-lite simulator
  (host-side calibration oracle; re-exported at ``repro.core.netsim``
  for compatibility).
"""
from .model import (Q_CAP, TRACE_METRIC_KEYS, make_trace_model,
                    trace_metrics_one, unpack_demand)
from .sim import (ROUTER_PIPELINE, ChipletNet, NetSim, Packet, SimResult,
                  latency_throughput_curve, synthetic_packets)
from .workload import Workload, demand_dim

__all__ = [
    "Q_CAP", "TRACE_METRIC_KEYS", "make_trace_model", "trace_metrics_one",
    "unpack_demand", "ROUTER_PIPELINE", "ChipletNet", "NetSim", "Packet",
    "SimResult", "latency_throughput_curve", "synthetic_packets",
    "Workload", "demand_dim",
]
