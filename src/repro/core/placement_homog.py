"""Homogeneous placement representation (paper §V-A, Fig. 5).

A placement is an R x C grid; each cell holds a compute-, memory- or
IO-chiplet or is empty.  All chiplets are 3mm x 3mm.  Chiplets with a single
PHY (memory/IO in the *baseline* chiplet configuration) can be rotated so the
PHY faces N/E/S/W; chiplets with four PHYs cannot (isomorphic placements).

The solution object is a pair of int8 numpy arrays ``(types, rot)`` of shape
[R, C]; ``types`` holds -1 for empty or the chiplet kind, ``rot`` in {0..3}
encodes the facing direction of single-PHY chiplets (0=S, 1=E, 2=N, 3=W —
matching ``Chiplet.rotated``).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from .chiplets import COMPUTE, IO, MEMORY, ArchSpec
from .proxies import Layout
from .topology import (DIR_DELTA as _DIR_DELTA, OPP_DIR as _OPP,
                       ROT_DIR as _ROT_DIR, PlacedPhys, ScoreGraph,
                       _UnionFind, build_score_graph)


Sol = tuple[np.ndarray, np.ndarray]  # (types [R,C], rot [R,C])


def sol_key(sol: Sol) -> bytes:
    return sol[0].tobytes() + sol[1].tobytes()


def hex_mask(side: int) -> np.ndarray:
    """Allowed-cell mask for a centered-hexagonal arrangement of ``side`` s:
    2s-1 rows of widths s, s+1, ..., 2s-1, ..., s+1, s (3s^2 - 3s + 1 cells,
    s=7 -> 127) centered on a (2s-1) x (2s-1) grid — the HexaMesh layout
    expressed on the square-grid representation."""
    n = 2 * side - 1
    mask = np.zeros((n, n), dtype=bool)
    for r in range(n):
        width = n - abs(r - (side - 1))
        lo = (n - width) // 2
        mask[r, lo:lo + width] = True
    return mask


@dataclass
class HomogRep:
    """Placement representation + operators for homogeneous chiplet shapes."""

    arch: ArchSpec
    R: int
    C: int
    mutation_mode: str = "neighbor-one"   # any-both | any-one | neighbor-both | neighbor-one
    allowed: np.ndarray | None = None     # [R, C] bool cell mask (None = all)

    def __post_init__(self):
        n = len(self.arch.chiplets)
        if self.allowed is not None:
            self.allowed = np.asarray(self.allowed, dtype=bool)
            if self.allowed.shape != (self.R, self.C):
                raise ValueError("allowed mask shape != (R, C)")
            if self.allowed.all():
                self.allowed = None       # degenerate mask == no mask
        n_cells = (self.R * self.C if self.allowed is None
                   else int(self.allowed.sum()))
        if n_cells < n:
            raise ValueError("grid too small for chiplet count")
        self._kind_instances = {
            k: [i for i, ch in enumerate(self.arch.chiplets) if ch.kind == k]
            for k in (COMPUTE, MEMORY, IO)
        }
        self._phy_base = np.zeros(n + 1, dtype=np.int64)
        for i, ch in enumerate(self.arch.chiplets):
            self._phy_base[i + 1] = self._phy_base[i] + ch.n_phys()
        self._rotatable = {
            k: self.arch.chiplets[self._kind_instances[k][0]].n_phys() == 1
            for k in (COMPUTE, MEMORY, IO) if self._kind_instances[k]
        }

    # -- static properties ---------------------------------------------------
    @property
    def layout(self) -> Layout:
        return Layout(Vp=int(self._phy_base[-1]), kinds=self.arch.kinds())

    @property
    def e_max(self) -> int:
        return 2 * (self.R * (self.C - 1) + (self.R - 1) * self.C)

    @property
    def area(self) -> float:
        # §V-A get_area: chiplet_size * n_cells (identical for all
        # placements); masked cells are not part of the package.
        sz = self.arch.chiplets[0].w * self.arch.chiplets[0].h
        n_cells = (self.R * self.C if self.allowed is None
                   else int(self.allowed.sum()))
        return float(sz * n_cells)

    # -- helpers ---------------------------------------------------------
    def _cell_allowed(self, r: int, c: int) -> bool:
        return self.allowed is None or bool(self.allowed[r, c])

    def _occupied_dirs(self, types: np.ndarray, r: int, c: int) -> list[int]:
        """Rotations whose PHY faces an occupied neighbor cell."""
        out = []
        for rot, d in enumerate(_ROT_DIR):
            dr, dc = _DIR_DELTA[d]
            rr, cc = r + dr, c + dc
            if 0 <= rr < self.R and 0 <= cc < self.C and types[rr, cc] >= 0:
                out.append(rot)
        return out

    def _inside_dirs(self, r: int, c: int) -> list[int]:
        out = []
        for rot, d in enumerate(_ROT_DIR):
            dr, dc = _DIR_DELTA[d]
            rr, cc = r + dr, c + dc
            if 0 <= rr < self.R and 0 <= cc < self.C \
                    and self._cell_allowed(rr, cc):
                out.append(rot)
        return out

    def _roll_rotation(self, types: np.ndarray, r: int, c: int,
                       rng: np.random.Generator) -> int:
        """Pick a rotation: PHY must face another chiplet, not the outside."""
        cands = self._occupied_dirs(types, r, c) or self._inside_dirs(r, c) \
            or [0, 1, 2, 3]
        return int(rng.choice(cands))

    def _fix_rotations(self, types: np.ndarray, rot: np.ndarray,
                       rng: np.random.Generator) -> None:
        """Re-roll rotations of single-PHY chiplets in-place."""
        for r in range(self.R):
            for c in range(self.C):
                k = types[r, c]
                if k >= 0 and self._rotatable.get(int(k), False):
                    rot[r, c] = self._roll_rotation(types, r, c, rng)
                else:
                    rot[r, c] = 0

    # -- the four representation functions (§IV) --------------------------
    def random(self, rng: np.random.Generator) -> Sol:
        cells = self.R * self.C
        flat = np.full(cells, -1, dtype=np.int8)
        kinds = [k for k, ids in self._kind_instances.items()
                 for _ in ids]
        cand = (np.arange(cells) if self.allowed is None
                else np.flatnonzero(self.allowed.reshape(-1)))
        pos = rng.choice(cand, size=len(kinds), replace=False)
        flat[pos] = np.array(kinds, dtype=np.int8)
        types = flat.reshape(self.R, self.C)
        rot = np.zeros_like(types)
        self._fix_rotations(types, rot, rng)
        return types, rot

    def mutate(self, sol: Sol, rng: np.random.Generator) -> Sol:
        types = sol[0].copy()
        rot = sol[1].copy()
        neighbor = self.mutation_mode.startswith("neighbor")
        both = self.mutation_mode.endswith("both")
        do_swap = True
        do_rot = both or not any(self._rotatable.values())
        if not both and any(self._rotatable.values()):
            do_swap = bool(rng.integers(2))
            do_rot = not do_swap
        if do_swap:
            self._swap(types, rot, rng, neighbor)
        if do_rot and any(self._rotatable.values()):
            self._rotate_one(types, rot, rng)
        return types, rot

    def _swap(self, types, rot, rng, neighbor: bool) -> None:
        """Swap two cells of *different* types (empty counts as a type)."""
        for _ in range(200):
            r1 = int(rng.integers(self.R))
            c1 = int(rng.integers(self.C))
            if neighbor:
                d = _ROT_DIR[int(rng.integers(4))]
                dr, dc = _DIR_DELTA[d]
                r2, c2 = r1 + dr, c1 + dc
                if not (0 <= r2 < self.R and 0 <= c2 < self.C):
                    continue
            else:
                r2 = int(rng.integers(self.R))
                c2 = int(rng.integers(self.C))
            if not (self._cell_allowed(r1, c1)
                    and self._cell_allowed(r2, c2)):
                continue
            if types[r1, c1] == types[r2, c2]:
                continue
            if types[r1, c1] < 0 and types[r2, c2] < 0:
                continue
            types[r1, c1], types[r2, c2] = types[r2, c2], types[r1, c1]
            rot[r1, c1], rot[r2, c2] = rot[r2, c2], rot[r1, c1]
            for (r, c) in ((r1, c1), (r2, c2)):
                k = types[r, c]
                if k >= 0 and self._rotatable.get(int(k), False):
                    rot[r, c] = self._roll_rotation(types, r, c, rng)
                else:
                    rot[r, c] = 0
            return

    def _rotate_one(self, types, rot, rng) -> None:
        cand = [(r, c) for r in range(self.R) for c in range(self.C)
                if types[r, c] >= 0
                and self._rotatable.get(int(types[r, c]), False)]
        if not cand:
            return
        r, c = cand[int(rng.integers(len(cand)))]
        rot[r, c] = self._roll_rotation(types, r, c, rng)

    def merge(self, a: Sol, b: Sol, rng: np.random.Generator) -> Sol:
        """§V-A merge: keep matching types/rotations, randomize the rest."""
        ta, ra_ = a
        tb, rb_ = b
        types = np.full_like(ta, -2)            # -2 = unresolved
        match = ta == tb
        types[match] = ta[match]
        # Count how many chiplets of each kind were carried over.
        remaining = {k: len(ids) for k, ids in self._kind_instances.items()}
        for k in remaining:
            remaining[k] -= int((types == k).sum())
        # Fill unresolved cells with leftover chiplets + empties.
        unresolved = np.argwhere(types == -2)
        fill = []
        for k, n in remaining.items():
            fill += [k] * n
        fill += [-1] * (len(unresolved) - len(fill))
        fill = np.array(fill, dtype=np.int8)
        rng.shuffle(fill)
        for (r, c), v in zip(unresolved, fill):
            types[r, c] = v
        rot = np.zeros_like(types)
        rot_match = match & (ra_ == rb_)
        rot[rot_match] = ra_[rot_match]
        # Re-roll rotations that were not carried over (or face emptiness).
        for r in range(self.R):
            for c in range(self.C):
                k = types[r, c]
                if k >= 0 and self._rotatable.get(int(k), False):
                    if not rot_match[r, c]:
                        rot[r, c] = self._roll_rotation(types, r, c, rng)
                else:
                    rot[r, c] = 0
        return types, rot

    # -- geometry / network ---------------------------------------------
    def _assign_instances(self, types: np.ndarray) -> np.ndarray:
        """Row-major scan assigns concrete chiplet instance ids to cells."""
        inst = np.full((self.R, self.C), -1, dtype=np.int64)
        counters = {k: 0 for k in self._kind_instances}
        for r in range(self.R):
            for c in range(self.C):
                k = int(types[r, c])
                if k < 0:
                    continue
                inst[r, c] = self._kind_instances[k][counters[k]]
                counters[k] += 1
        return inst

    def _phy_of(self, inst: int, types, rot, r: int, c: int,
                direction: str) -> int:
        """Global PHY index of chiplet ``inst`` facing ``direction`` or -1."""
        ch = self.arch.chiplets[inst]
        if ch.n_phys() == 4:
            # base phys order is n, e, s, w (see homogeneous_chiplet)
            local = "nesw".index(direction)
            return int(self._phy_base[inst]) + local
        if _ROT_DIR[int(rot[r, c])] == direction:
            return int(self._phy_base[inst])
        return -1

    def links_of(self, sol: Sol) -> tuple[list[tuple[int, int]], np.ndarray]:
        """§V-A get_network: connect opposing PHYs of adjacent chiplets."""
        types, rot = sol
        inst = self._assign_instances(types)
        links: list[tuple[int, int]] = []
        for r in range(self.R):
            for c in range(self.C):
                if types[r, c] < 0:
                    continue
                for d in ("n", "e"):       # scan each adjacency once
                    dr, dc = _DIR_DELTA[d]
                    rr, cc = r + dr, c + dc
                    if not (0 <= rr < self.R and 0 <= cc < self.C):
                        continue
                    if types[rr, cc] < 0:
                        continue
                    p = self._phy_of(int(inst[r, c]), types, rot, r, c, d)
                    q = self._phy_of(int(inst[rr, cc]), types, rot, rr, cc,
                                     _OPP[d])
                    if p >= 0 and q >= 0:
                        links.append((p, q))
        return links, inst

    def is_connected(self, sol: Sol) -> bool:
        types, _ = sol
        links, inst = self.links_of(sol)
        n = len(self.arch.chiplets)
        uf = _UnionFind(n)
        owner = self._owner_of_phys(inst)
        for p, q in links:
            uf.union(int(owner[p]), int(owner[q]))
        cells = inst[inst >= 0]
        roots = {uf.find(int(i)) for i in cells}
        return len(roots) == 1

    def _owner_of_phys(self, inst: np.ndarray) -> np.ndarray:
        Vp = int(self._phy_base[-1])
        owner = np.zeros(Vp, dtype=np.int32)
        for i, ch in enumerate(self.arch.chiplets):
            owner[self._phy_base[i]:self._phy_base[i + 1]] = i
        return owner

    def geometry(self, sol: Sol) -> PlacedPhys:
        types, rot = sol
        inst = self._assign_instances(types)
        Vp = int(self._phy_base[-1])
        pos = np.zeros((Vp, 2), dtype=np.float32)
        sz = self.arch.chiplets[0].w
        for r in range(self.R):
            for c in range(self.C):
                i = int(inst[r, c])
                if i < 0:
                    continue
                ch = self.arch.chiplets[i].rotated(int(rot[r, c])
                                                   if self.arch.chiplets[i]
                                                   .n_phys() == 1 else 0)
                ox, oy = c * sz, r * sz
                for li, (x, y) in enumerate(ch.phys):
                    pos[self._phy_base[i] + li] = (ox + x, oy + y)
        owner = self._owner_of_phys(inst)
        relay = np.array([ch.relay for ch in self.arch.chiplets])
        kinds = np.array(self.arch.kinds(), dtype=np.int8)
        return PlacedPhys(pos=pos, owner=owner, relay=relay, kinds=kinds,
                          area=self.area)

    def score_graph(self, sol: Sol) -> ScoreGraph:
        links, _ = self.links_of(sol)
        geo = self.geometry(sol)
        return build_score_graph(self.arch, geo, links, self.e_max,
                                 self.is_connected(sol))

    def batch_ops(self) -> "HomogBatch":
        """Cached vectorized (device-resident) operators for this grid."""
        if not hasattr(self, "_batch_ops"):
            self._batch_ops = HomogBatch(self)
        return self._batch_ops


# ---------------------------------------------------------------------------
# Device-resident batched operators.
#
# The host operators above generate/mutate/merge one placement at a time with
# a ``np.random.Generator``; at HexaMesh scale the per-individual Python loop
# (plus the retry-until-connected loop around it) dominates wall time.
# ``HomogBatch`` mirrors the same decision points as pure JAX array ops over
# stacked [B, R, C] ``(types, rot)`` arrays keyed by a PRNG key, so a whole
# GA generation / SA chain-block is produced in one fused device call (see
# ``optimize.DevicePipeline``).  Equivalence with the host operators is
# *distributional* — every random choice is uniform over the same candidate
# set — not bit-for-bit (different RNG streams); tested in
# tests/test_batched_pipeline.py.
# ---------------------------------------------------------------------------

_KINDS = (COMPUTE, MEMORY, IO)
_SWAP_TRIES = 128     # host caps at 200 sequential tries; pre-drawn here


class HomogBatch:
    """Vectorized ``random/mutate/merge`` over stacked homogeneous grids."""

    def __init__(self, rep: HomogRep):
        self.rep = rep
        self.R, self.C = rep.R, rep.C
        self.cells = rep.R * rep.C
        allowed = (np.ones((self.R, self.C), bool) if rep.allowed is None
                   else rep.allowed)
        self._masked = rep.allowed is not None
        self._allowed_flat = jnp.asarray(allowed.reshape(-1))
        self._allowed_idx = jnp.asarray(np.flatnonzero(allowed.reshape(-1)))
        n_allowed = int(allowed.sum())
        fill = [k for k, ids in rep._kind_instances.items() for _ in ids]
        fill += [-1] * (n_allowed - len(fill))
        self._kinds_fill = jnp.asarray(np.array(fill, dtype=np.int8))
        self._counts = np.array(
            [len(rep._kind_instances.get(k, ())) for k in _KINDS], np.int32)
        rotatable = np.array([bool(rep._rotatable.get(k, False))
                              for k in _KINDS])
        self._rotatable_kind = jnp.asarray(rotatable)
        self._any_rotatable = bool(rotatable.any())
        inside = np.zeros((self.R, self.C, 4), bool)
        for rot_i, d in enumerate(_ROT_DIR):
            dr, dc = _DIR_DELTA[d]
            for r in range(self.R):
                for c in range(self.C):
                    rr, cc = r + dr, c + dc
                    inside[r, c, rot_i] = (0 <= rr < self.R
                                           and 0 <= cc < self.C
                                           and allowed[rr, cc])
        self._inside = jnp.asarray(inside)
        self._dr = jnp.asarray(
            np.array([_DIR_DELTA[d][0] for d in _ROT_DIR], np.int32))
        self._dc = jnp.asarray(
            np.array([_DIR_DELTA[d][1] for d in _ROT_DIR], np.int32))

    # -- rotation re-roll (vectorized ``_fix_rotations``) -------------------
    def _neighbor_occ(self, occ: jnp.ndarray) -> jnp.ndarray:
        """[..., R, C] occupancy -> [..., R, C, 4] per-rotation neighbor
        occupancy in ``_ROT_DIR`` order (out-of-grid counts unoccupied)."""
        pad = [(0, 0)] * (occ.ndim - 2) + [(1, 1), (1, 1)]
        po = jnp.pad(occ, pad, constant_values=False)
        R, C = self.R, self.C
        sl = lambda dr, dc: po[..., 1 + dr:1 + dr + R, 1 + dc:1 + dc + C]
        return jnp.stack(
            [sl(*_DIR_DELTA[d]) for d in _ROT_DIR], axis=-1)

    def _rotatable_cells(self, types: jnp.ndarray) -> jnp.ndarray:
        occ = types >= 0
        kind = jnp.clip(types, 0, 2).astype(jnp.int32)
        return occ & self._rotatable_kind[kind]

    def _roll_rot_batch(self, key, types, rot, update) -> jnp.ndarray:
        """Re-roll rotations under ``update``: rotatable cells get a uniform
        pick from occupied-facing (else in-grid, else all) directions, all
        other updated cells get 0; cells outside ``update`` keep ``rot``."""
        occ = types >= 0
        nb = self._neighbor_occ(occ)
        cand = jnp.where(nb.any(-1, keepdims=True), nb,
                         jnp.where(self._inside.any(-1, keepdims=True),
                                   self._inside, True))
        g = jax.random.gumbel(key, occ.shape + (4,))
        new = jnp.argmax(jnp.where(cand, g, -jnp.inf), axis=-1)
        new = new.astype(rot.dtype)
        rotatable = self._rotatable_cells(types)
        return jnp.where(update & rotatable, new,
                         jnp.where(update, 0, rot)).astype(jnp.int8)

    # -- the four representation functions, batched -------------------------
    def random_batch(self, key, n: int) -> tuple[jnp.ndarray, jnp.ndarray]:
        """n independent uniform placements: a random permutation of the
        chiplet-kind multiset over the allowed cells, rotations re-rolled."""
        k1, k2 = jax.random.split(key)
        keys = jax.random.split(k1, n)
        perm = jax.vmap(
            lambda k: jax.random.permutation(k, self._kinds_fill))(keys)
        if self._masked:
            flat = jnp.full((n, self.cells), -1, dtype=perm.dtype)
            flat = flat.at[:, self._allowed_idx].set(perm)
        else:
            flat = perm
        types = flat.reshape(n, self.R, self.C)
        rot = jnp.zeros_like(types)
        rot = self._roll_rot_batch(k2, types, rot,
                                   jnp.ones(types.shape, bool))
        return types, rot

    def _onehot_cells(self, idx: jnp.ndarray, flag: jnp.ndarray
                      ) -> jnp.ndarray:
        return (jnp.arange(self.cells)[None, :] == idx[:, None]) \
            & flag[:, None]

    def mutate_batch(self, key, types, rot
                     ) -> tuple[jnp.ndarray, jnp.ndarray]:
        """Batched ``mutate``: per placement either a (neighbor-)swap of two
        differing cells or a re-roll of one rotatable chiplet (or both,
        per ``mutation_mode``), with the host's first-valid-try semantics."""
        B = types.shape[0]
        neighbor = self.rep.mutation_mode.startswith("neighbor")
        both = self.rep.mutation_mode.endswith("both")
        kcoin, kr1, kc1, kd, kr2, kc2, kpick, kfix = jax.random.split(key, 8)
        if both or not self._any_rotatable:
            do_swap = jnp.ones(B, bool)
        else:
            do_swap = jax.random.bernoulli(kcoin, 0.5, (B,))
        if not self._any_rotatable:
            do_rot = jnp.zeros(B, bool)
        elif both:
            do_rot = jnp.ones(B, bool)
        else:
            do_rot = ~do_swap
        # Pre-drawn swap tries; the first valid one is the host's accepted
        # draw (identical first-success distribution).
        T = _SWAP_TRIES
        r1 = jax.random.randint(kr1, (B, T), 0, self.R)
        c1 = jax.random.randint(kc1, (B, T), 0, self.C)
        if neighbor:
            d = jax.random.randint(kd, (B, T), 0, 4)
            r2 = r1 + self._dr[d]
            c2 = c1 + self._dc[d]
        else:
            r2 = jax.random.randint(kr2, (B, T), 0, self.R)
            c2 = jax.random.randint(kc2, (B, T), 0, self.C)
        inb = (r2 >= 0) & (r2 < self.R) & (c2 >= 0) & (c2 < self.C)
        i1 = r1 * self.C + c1
        i2 = jnp.clip(r2, 0, self.R - 1) * self.C + jnp.clip(c2, 0,
                                                             self.C - 1)
        tflat = types.reshape(B, self.cells)
        rflat = rot.reshape(B, self.cells)
        t1 = jnp.take_along_axis(tflat, i1, axis=1)
        t2 = jnp.take_along_axis(tflat, i2, axis=1)
        valid = inb & (t1 != t2) & ~((t1 < 0) & (t2 < 0))
        if self._masked:
            valid &= self._allowed_flat[i1] & self._allowed_flat[i2]
        first = jnp.argmax(valid, axis=1)
        sel = lambda a: jnp.take_along_axis(a, first[:, None], axis=1)[:, 0]
        do_it = do_swap & valid.any(axis=1)
        s1 = jnp.where(do_it, sel(i1), 0)
        s2 = jnp.where(do_it, sel(i2), 0)      # s1 == s2 == 0 -> no-op swap
        b = jnp.arange(B)
        v1t, v2t = tflat[b, s1], tflat[b, s2]
        tflat = tflat.at[b, s1].set(v2t).at[b, s2].set(v1t)
        v1r, v2r = rflat[b, s1], rflat[b, s2]
        rflat = rflat.at[b, s1].set(v2r).at[b, s2].set(v1r)
        update = self._onehot_cells(s1, do_it) | self._onehot_cells(s2, do_it)
        if self._any_rotatable:
            rc = self._rotatable_cells(tflat)
            g = jax.random.gumbel(kpick, (B, self.cells))
            pick = jnp.argmax(jnp.where(rc, g, -jnp.inf), axis=1)
            update |= self._onehot_cells(pick, do_rot & rc.any(axis=1))
        types2 = tflat.reshape(B, self.R, self.C)
        rot2 = rflat.reshape(B, self.R, self.C)
        rot2 = self._roll_rot_batch(kfix, types2, rot2,
                                    update.reshape(B, self.R, self.C))
        return types2, rot2

    def merge_batch(self, key, ta, ra, tb, rb
                    ) -> tuple[jnp.ndarray, jnp.ndarray]:
        """Batched §V-A merge: keep agreeing cells, distribute the leftover
        chiplets uniformly over the disagreeing cells (random-rank fill ==
        host's shuffled fill), carry rotations only where both agree."""
        B = ta.shape[0]
        k1, k2 = jax.random.split(key)
        match = ta == tb
        taf = ta.reshape(B, self.cells)
        mf = match.reshape(B, self.cells)
        carried = jnp.where(mf, taf, -2)
        rem = [self._counts[k] - (carried == k).sum(axis=1) for k in range(3)]
        prio = jax.random.uniform(k1, (B, self.cells))
        prio = jnp.where(carried == -2, prio, 2.0)   # resolved cells: last
        rank = jnp.argsort(jnp.argsort(prio, axis=1), axis=1)
        c0 = rem[0][:, None]
        c1 = c0 + rem[1][:, None]
        c2 = c1 + rem[2][:, None]
        fill = jnp.where(rank < c0, COMPUTE,
                         jnp.where(rank < c1, MEMORY,
                                   jnp.where(rank < c2, IO, -1)))
        types = jnp.where(mf, taf, fill.astype(ta.dtype))
        types = types.reshape(B, self.R, self.C)
        rot_match = match & (ra == rb)
        rot0 = jnp.where(rot_match, ra, 0).astype(ra.dtype)
        update = ~(rot_match & self._rotatable_cells(types))
        rot = self._roll_rot_batch(k2, types, rot0, update)
        return types, rot
