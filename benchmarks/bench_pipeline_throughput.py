"""Device-resident pipeline throughput: host-loop vs batched path.

PlaceIT's runtime is dominated by placement evaluation (paper Table V); PR 2
moved the *production* side — generate / mutate / merge, link inference and
ScoreGraph assembly — onto the device as fused batched calls
(``optimize.DevicePipeline``).  This bench measures placements per second on
three homogeneous grids for:

* **prep** (the pipeline stage this PR moved on-device): producing a
  scorable ScoreGraph batch from parents / randomness.  Host = per-child
  Python ``merge -> mutate -> score_graph`` (includes the union-find
  connectivity pass); device = one fused ``merge_batch -> mutate_batch ->
  build`` call (connectivity rides the scorer's FW pass, so the device
  number excludes it — see the emitted note).
* **e2e** (prep + proxy scoring with the shared jitted scorer): a full GA
  generation including retry-until-connected (host) / mask-and-resample
  (device).  On CPU both paths are Floyd-Warshall-bound, so this ratio
  mostly tracks the scorer; the prep ratio is the one the refactor targets.

PR 3 extends the same measurement to the heterogeneous path (hetero32):
host per-child corner placement + Kruskal MST vs the batched pipeline
(device operators, vectorized host corner placement, batched Borůvka link
inference + ScoreGraph assembly on device).

PR 4 adds the **objective ranking** section: once a candidate batch is
scored, picking the best placements used to require pulling all nine
metric arrays to the host and running the numpy cost formula + argsort
per call; the objective layer compiles the cost terms into the jitted
scorer, so cost + top-k selection happen on device
(``Evaluator.topk`` / ``proxies.make_ranker``).  The bench isolates that
stage (host metric conversion + ``total_cost`` + argsort vs the jitted
cost+top-k over device-resident metrics) and also reports the fused
end-to-end ranking call.

PR 7 adds the **large_v** section: per-generation seconds and FW-kernel
comparison (pure-XLA reference vs VMEM-resident Pallas vs blocked-tile
Pallas) on 100+-chiplet archs (homog100 / hex127 / homog256), where the
VMEM-resident kernel's ~3*V^2*4B working set stops fitting and
``ops.fw_impl_tiled`` auto-dispatches to the blocked-tile kernel.  It
also fills the e2e gap: every grid (8x8 and 12x12 included) now emits
``e2e_per_s`` numbers with per-grid batch budgets.

PR 9 adds the **arch3d** section: prep throughput for the 3D /
hierarchical families (``repro.arch3d``) — host per-child Python
(merge + mutate + record-walk graph assembly + union-find) vs one fused
device call through the same pluggable ``DevicePipeline._stages``, with
the tier-value vector (TSV / backbone latency multipliers) as a runtime
jit operand.  Target: >= 3x device over host.

Results go to stdout as BENCH lines and to
``artifacts/bench/pipeline_throughput.json``; ``benchmarks.run`` copies
that to ``BENCH_pipeline_throughput.json`` at the repo root so the perf
trajectory is versioned.
"""
from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

import functools

import jax.numpy as jnp

from repro.core.chiplets import homogeneous_arch, paper_arch
from repro.core.cost import total_cost
from repro.core.objective import compile_objective, norms_vec
from repro.core.optimize import DevicePipeline, Evaluator
from repro.core.placement_hetero import HeteroRep
from repro.core.placement_homog import HomogRep
from repro.core.topology import stack_graphs

from .common import budget, emit, out_dir

# grid name -> (R, C, (n_compute, n_memory, n_io)).  Fully occupied, like
# the paper's grids (homog32 packs 40 chiplets onto 8x5): sparse grids make
# connected placements vanishingly rare under the baseline single-PHY
# memory/IO chiplets.
GRIDS = {
    "6x6": (6, 6, (28, 4, 4)),
    "8x8": (8, 8, (52, 6, 6)),
    "12x12": (12, 12, (128, 8, 8)),
}

# Per-grid e2e budgets (quick, full): e2e includes the FW scorer, whose
# cost grows O(V^3) — larger grids need smaller batches to keep the bench
# bounded.  Every grid gets an e2e number in both modes (PR 7: 8x8/12x12
# previously emitted prep-only artifacts).
E2E_N = {"6x6": (16, 64), "8x8": (8, 32), "12x12": (4, 8)}

# 100+-chiplet archs for the large-V section (quick mode runs the first
# only; full mode all).  V here is the scorer's working matrix side
# (Vp + 2*n virtual rows): homog100 -> 552, hex127 -> 702, homog256 ->
# 1440 — the last pads past ops.FW_TILED_AUTO_V, so auto-dispatch takes
# the blocked-tile kernel and the VMEM-resident kernel could not run
# compiled on a 16 MB-VMEM TPU at all.
LARGE_ARCHS = ("homog100", "hex127", "homog256")


def _host_prep_rate(rep, parents, n: int) -> float:
    """Host-loop GA-generation prep: merge + mutate + score_graph each."""
    rng = np.random.default_rng(1)
    best = np.inf
    for _ in range(3):           # best-of-3: single passes are noisy
        idx = rng.integers(len(parents), size=(n, 2))
        t0 = time.perf_counter()
        for a, b in idx:
            child = rep.merge(parents[a], parents[b], rng)
            if rng.random() < 0.5:
                child = rep.mutate(child, rng)
            rep.score_graph(child)
        best = min(best, time.perf_counter() - t0)
    return n / best


def _device_prep_rate(rep, parents, n: int) -> float:
    """One fused merge_batch -> mutate_batch -> build call for n children.
    Reps with runtime weight tiers (``repro.arch3d``) take the tier
    vector as a trailing stage operand."""
    _, _, _gen, _mut, _child, _ = DevicePipeline._stages(rep)
    tiers = getattr(rep, "tier_values", None)
    extra = () if tiers is None else (jnp.asarray(tiers),)
    rng = np.random.default_rng(1)
    idx = rng.integers(len(parents), size=(n, 2))
    ta = np.stack([parents[a][0] for a, _ in idx])
    ra = np.stack([parents[a][1] for a, _ in idx])
    tb = np.stack([parents[b][0] for _, b in idx])
    rb = np.stack([parents[b][1] for _, b in idx])
    key = jax.random.PRNGKey(0)
    jax.block_until_ready(                                    # warm the jit
        _child(key, ta, ra, tb, rb, 0.5, *extra))
    best = np.inf
    for i in range(1, 4):        # best-of-3: single calls are noisy
        t0 = time.perf_counter()
        jax.block_until_ready(
            _child(jax.random.PRNGKey(i), ta, ra, tb, rb, 0.5, *extra))
        best = min(best, time.perf_counter() - t0)
    return n / best


def _e2e_rates(rep, arch, n: int, chunk: int, norm_samples: int = 8
               ) -> tuple[float, float]:
    """Full GA generation incl. scoring + validity: host retry loop vs
    device mask-and-resample.  Returns (host_per_s, device_per_s)."""
    ev = Evaluator(rep, arch, rng=np.random.default_rng(0),
                   norm_samples=norm_samples, chunk=chunk)
    rng = np.random.default_rng(2)
    parents, _ = ev.generate_valid(rep.random, rng, max(4, n // 4))

    def op(r):
        a = parents[int(r.integers(len(parents)))]
        b = parents[int(r.integers(len(parents)))]
        child = rep.merge(a, b, r)
        if r.random() < 0.5:
            child = rep.mutate(child, r)
        return child

    ev.costs([rep.score_graph(parents[0])] * min(n, chunk))   # warm the jit
    t0 = time.perf_counter()
    sols, graphs = ev.generate_valid(op, rng, n)
    ev.costs(graphs)
    host = n / (time.perf_counter() - t0)

    pipe = ev.pipeline()
    idx = rng.integers(len(parents), size=(n, 2))
    pa_t = np.stack([parents[a][0] for a, _ in idx])
    pa_r = np.stack([parents[a][1] for a, _ in idx])
    pb_t = np.stack([parents[b][0] for _, b in idx])
    pb_r = np.stack([parents[b][1] for _, b in idx])
    pipe.sample_children(rng, pa_t, pa_r, pb_t, pb_r, 0.5)    # warm the jit
    t0 = time.perf_counter()
    _, _, m = pipe.sample_children(rng, pa_t, pa_r, pb_t, pb_r, 0.5)
    ev.costs_from(m)
    dev = n / (time.perf_counter() - t0)
    return host, dev


def _hetero_prep_rates(arch_name: str, n: int) -> tuple[float, float]:
    """GA-generation production on a heterogeneous arch: host per-child
    Python (merge + mutate + corner placement + Kruskal MST + ScoreGraph)
    vs the batched path (fused device operators, vectorized host corner
    placement, batched Borůvka link inference + assembly on device).
    Returns (host_per_s, device_per_s)."""
    arch = paper_arch(arch_name, "baseline")
    rep = HeteroRep(arch)
    rng = np.random.default_rng(0)
    parents = [rep.random(rng) for _ in range(16)]

    best = np.inf
    for _ in range(3):
        idx = rng.integers(len(parents), size=(n, 2))
        t0 = time.perf_counter()
        for a, b in idx:
            child = rep.merge(parents[a], parents[b], rng)
            if rng.random() < 0.5:
                child = rep.mutate(child, rng)
            rep.score_graph(child)
        best = min(best, time.perf_counter() - t0)
    host = n / best

    _, _, _gen, _mut, _child, _ = DevicePipeline._stages(rep)
    idx = rng.integers(len(parents), size=(n, 2))
    oa = np.stack([parents[a][0] for a, _ in idx])
    ra = np.stack([parents[a][1] for a, _ in idx])
    ob = np.stack([parents[b][0] for _, b in idx])
    rb = np.stack([parents[b][1] for _, b in idx])
    jax.block_until_ready(
        _child(jax.random.PRNGKey(0), oa, ra, ob, rb, 0.5)[2]["W"])
    best = np.inf
    for i in range(1, 4):
        t0 = time.perf_counter()
        jax.block_until_ready(
            _child(jax.random.PRNGKey(i), oa, ra, ob, rb, 0.5)[2]["W"])
        best = min(best, time.perf_counter() - t0)
    return host, n / best


def _ranking_rates(arch_name: str, n: int, k: int = 4
                   ) -> tuple[float, float, float]:
    """Cost evaluation + best-placement selection over scored batches.

    * **host stage**: the pre-objective hot path, once per optimizer
      round — numpy float64 ``total_cost`` over the scorer's metrics +
      argsort, take k.  (On the CPU backend ``np.asarray`` of a device
      array is zero-copy, so this isolates formula + sort.)
    * **device stage**: what the objective layer fuses into the scorer —
      jitted vmapped cost + ``top_k`` on the device-resident metrics.
    * **fused e2e**: ``Evaluator.topk`` — score + cost + top-k in one
      call (FW-bound on CPU; the stage ratio is the refactor's target).

    Each measurement ranks ``inner`` independent batches so the timed
    quantum is well above scheduler noise; best-of-5 measurements.
    Returns (host_stage_per_s, device_stage_per_s, fused_per_s).
    """
    arch = paper_arch(arch_name, "baseline")
    from repro.core.api import make_rep
    rep = make_rep(arch, arch_name)
    ev = Evaluator(rep, arch, rng=np.random.default_rng(0), norm_samples=8,
                   chunk=16)
    rng = np.random.default_rng(1)
    _, graphs = ev.generate_valid(rep.random, rng, n)
    batch = stack_graphs(graphs)
    inner = 16
    base = {k2: jnp.asarray(v)
            for k2, v in ev.scorer(batch, ev.norm_vec).items()}
    sets = [jax.block_until_ready({k2: v + 0 for k2, v in base.items()})
            for _ in range(inner)]

    def host_stage():
        out = None
        for dm in sets:
            m = {k2: np.asarray(v) for k2, v in dm.items() if k2 != "cost"}
            costs = np.asarray(total_cost(m, arch, ev.norm))
            out = np.argsort(costs)[:k]
        return out

    cobj = compile_objective(ev.objective)
    row = jnp.asarray(norms_vec(ev.norm))

    @functools.partial(jax.jit, static_argnames=("kk",))
    def dev_one(m, kk):
        # Default-objective terms are metrics-only; no graph arrays needed.
        sample = {k2: v for k2, v in m.items() if k2 != "cost"}
        costs = jax.vmap(lambda s: cobj.cost_one(s, row))(sample)
        return jax.lax.top_k(-costs, kk)[1]

    def dev_stage():
        outs = [dev_one(dm, k) for dm in sets]
        jax.block_until_ready(outs)
        return np.asarray(outs[-1])

    def best_of(fn, reps=5, warm=2):
        for _ in range(warm):
            fn()
        best = np.inf
        for _ in range(reps):
            t0 = time.perf_counter()
            fn()
            best = min(best, time.perf_counter() - t0)
        return best

    total = n * inner
    host_best = best_of(host_stage)
    dev_best = best_of(dev_stage)
    fused_best = best_of(lambda: ev.topk(batch, k=k), reps=3, warm=1)
    return total / host_best, total / dev_best, n / fused_best


def _large_v_section(arch_name: str, gen_n: int, norm_samples: int,
                     time_vmem: bool) -> dict:
    """Per-generation throughput + FW-kernel comparison at 100+-chiplet V.

    * **generation**: one device GA generation (fused sample_children +
      scoring via ``costs_from``) with the "fw-ref" production backend —
      the per-generation seconds the tiled kernel exists to bound.
    * **kernels**: steady-state FW timings on a real placement's W — the
      pure-XLA reference, the VMEM-resident Pallas kernel (skipped when
      its working set cannot fit VMEM, or when ``time_vmem`` is False),
      and the blocked-tile kernel — plus the static VMEM-feasibility
      numbers driving ``ops.fw_impl_tiled``'s auto-dispatch.
    """
    from repro.core.api import make_evaluator, make_rep
    from repro.core.chiplets import resolve_arch
    from repro.kernels.minplus import fw_counts_pallas, fw_counts_tiled_pallas
    from repro.kernels.ops import FW_TILED_AUTO_V
    from repro.kernels import ref

    arch = resolve_arch(arch_name, "baseline")
    rep = make_rep(arch, arch_name)
    ev = make_evaluator(rep, arch, rng=np.random.default_rng(0),
                        norm_samples=norm_samples, chunk=4, backend="fw-ref")
    pipe = ev.pipeline()
    rng = np.random.default_rng(1)
    parents, _ = ev.generate_valid(rep.random, rng, 4)
    idx = rng.integers(len(parents), size=(gen_n, 2))
    pa_t = np.stack([parents[a][0] for a, _ in idx])
    pa_r = np.stack([parents[a][1] for a, _ in idx])
    pb_t = np.stack([parents[b][0] for _, b in idx])
    pb_r = np.stack([parents[b][1] for _, b in idx])

    def generation():
        _, _, m = pipe.sample_children(rng, pa_t, pa_r, pb_t, pb_r, 0.5)
        return ev.costs_from(m)

    generation()                                  # warm the jits
    t0 = time.perf_counter()
    generation()
    gen_s = time.perf_counter() - t0

    W = jnp.asarray(rep.score_graph(parents[0]).W)
    V = int(W.shape[-1])
    Vp128 = max(128, -(-V // 128) * 128)
    vmem_mb = 3 * Vp128 * Vp128 * 4 / 2**20       # W, D, N resident
    fits_vmem = vmem_mb <= 16.0
    out = dict(V=V, padded_V=Vp128, n_chiplets=len(arch.chiplets),
               gen_n=gen_n, seconds_per_generation=gen_s,
               gen_placements_per_s=gen_n / gen_s,
               vmem_required_mb=round(vmem_mb, 1), fits_vmem=fits_vmem,
               auto_dispatch=("vmem" if Vp128 <= FW_TILED_AUTO_V
                              else "tiled"))

    def _time(fn):
        f = jax.jit(fn)
        jax.block_until_ready(f(W)[0])            # compile + warm
        best = np.inf
        for _ in range(2):
            t0 = time.perf_counter()
            jax.block_until_ready(f(W)[0])
            best = min(best, time.perf_counter() - t0)
        return best

    out["fw_ref_s"] = _time(ref.fw_counts_ref)
    out["fw_tiled_s"] = _time(fw_counts_tiled_pallas)
    if fits_vmem and time_vmem:
        out["fw_vmem_s"] = _time(fw_counts_pallas)
    return out


def run(quick: bool = True) -> dict:
    n = budget(quick, 48, 256)
    e2e_norm = budget(quick, 2, 8)
    results: dict = {"n_prep": n}
    for name, (R, C, (nc, nm, ni)) in GRIDS.items():
        arch = homogeneous_arch(nc, nm, ni, "baseline")
        rep = HomogRep(arch, R=R, C=C)
        rng = np.random.default_rng(0)
        parents = [rep.random(rng) for _ in range(16)]
        host = _host_prep_rate(rep, parents, n)
        dev = _device_prep_rate(rep, parents, n)
        results[name] = dict(host_prep_per_s=host, device_prep_per_s=dev,
                             prep_speedup=dev / host)
        emit(f"pipeline_{name}_host_prep_per_s", round(host, 1),
             "per-child python merge+mutate+graph+union-find")
        emit(f"pipeline_{name}_device_prep_per_s", round(dev, 1),
             "one fused device call; connectivity rides the scorer FW")
        emit(f"pipeline_{name}_prep_speedup", round(dev / host, 1),
             f"{dev / host:.1f}x device over host loop")
        e2e_n = budget(quick, *E2E_N[name])
        h2, d2 = _e2e_rates(rep, arch, e2e_n, budget(quick, 8, 16),
                            norm_samples=e2e_norm)
        results[name].update(host_e2e_per_s=h2, device_e2e_per_s=d2,
                             e2e_speedup=d2 / h2, n_e2e=e2e_n)
        emit(f"pipeline_{name}_e2e_speedup", round(d2 / h2, 2),
             "incl. shared FW scorer (FW-bound on CPU; prep ratio is "
             "the refactor's target)")
    # heterogeneous path (PR 3): batched Borůvka link inference vs the
    # per-child host Kruskal+union-find loop
    hn = budget(quick, 32, 128)
    hh, hd = _hetero_prep_rates("hetero32", hn)
    results["hetero32"] = dict(host_prep_per_s=hh, device_prep_per_s=hd,
                               prep_speedup=hd / hh, n_prep=hn)
    emit("pipeline_hetero32_host_prep_per_s", round(hh, 1),
         "per-child python merge+mutate+corner-place+kruskal+graph")
    emit("pipeline_hetero32_device_prep_per_s", round(hd, 1),
         "fused batched ops + vectorized corner place + Boruvka on device")
    emit("pipeline_hetero32_prep_speedup", round(hd / hh, 1),
         f"{hd / hh:.1f}x batched over host loop (target >= 3x)")
    # 3D / hierarchical families (PR 9): stacked grids + gateway
    # backbones through the same pluggable stages.  gw3d64 uses the
    # relay-capable "placeit" config (see arch3d.families).
    from repro.arch3d import make_rep3d
    from repro.core.chiplets import resolve_arch
    a3n = budget(quick, 32, 128)
    arch3d = {}
    for arch_name, config in (("stack3d32", "baseline"),
                              ("gw3d64", "placeit")):
        arch = resolve_arch(arch_name, config)
        rep3 = make_rep3d(arch, arch_name)
        rng = np.random.default_rng(0)
        parents = [rep3.random(rng) for _ in range(16)]
        h3 = _host_prep_rate(rep3, parents, a3n)
        d3 = _device_prep_rate(rep3, parents, a3n)
        arch3d[arch_name] = dict(host_prep_per_s=h3, device_prep_per_s=d3,
                                 prep_speedup=d3 / h3, n_prep=a3n,
                                 config=config)
        emit(f"pipeline_{arch_name}_host_prep_per_s", round(h3, 1),
             "per-child python merge+mutate+record-walk graph+union-find")
        emit(f"pipeline_{arch_name}_device_prep_per_s", round(d3, 1),
             "fused device call; tier values are a runtime jit operand")
        emit(f"pipeline_{arch_name}_prep_speedup", round(d3 / h3, 1),
             f"{d3 / h3:.1f}x device over host loop (target >= 3x)")
    results["arch3d"] = arch3d
    # objective ranking (PR 4): cost evaluation + best-placement selection
    # over a scored candidate batch — host numpy formula + argsort vs the
    # in-scorer compiled objective + device top-k
    rn = budget(quick, 512, 2048)
    rh, rd, rf = _ranking_rates("homog32", rn)
    results["objective_ranking"] = dict(
        n_rank=rn, host_stage_per_s=rh, device_stage_per_s=rd,
        fused_e2e_per_s=rf, stage_speedup=rd / rh)
    emit("objective_ranking_host_stage_per_s", round(rh, 1),
         "metrics->host + numpy total_cost + argsort, per scored batch")
    emit("objective_ranking_device_stage_per_s", round(rd, 1),
         "jitted vmapped objective cost + top_k on device metrics")
    emit("objective_ranking_fused_e2e_per_s", round(rf, 1),
         "Evaluator.topk: score+cost+top-k one call (FW-bound on CPU)")
    emit("objective_ranking_stage_speedup", round(rd / rh, 1),
         f"{rd / rh:.1f}x device cost+top-k over host formula+argsort "
         "(target >= 2x)")
    # large-V section (PR 7): per-generation throughput + FW-kernel
    # comparison in the 100+-chiplet (HexaMesh) regime, where the
    # blocked-tile FW replaces the VMEM-resident kernel
    large_gen_n = {"homog100": (8, 32), "hex127": (8, 16),
                   "homog256": (4, 8)}
    large = {}
    for arch_name in LARGE_ARCHS[:1] if quick else LARGE_ARCHS:
        # per-arch budgets: homog256's V=1440 FW dominates; small n still
        # yields stable per-generation seconds (one fused call either way)
        gen_n = budget(quick, *large_gen_n[arch_name])
        norm = min(e2e_norm, 2) if arch_name == "homog256" else e2e_norm
        sec = _large_v_section(arch_name, gen_n, norm,
                               time_vmem=not quick or arch_name == "homog100")
        large[arch_name] = sec
        emit(f"large_v_{arch_name}_s_per_generation",
             round(sec["seconds_per_generation"], 2),
             f"device generation of {sec['gen_n']} at V={sec['V']} "
             "(fw-ref backend)")
        emit(f"large_v_{arch_name}_fw_tiled_s",
             round(sec["fw_tiled_s"], 3),
             f"blocked-tile FW+counts, one [V,V] at padded V="
             f"{sec['padded_V']}")
        emit(f"large_v_{arch_name}_vmem_required_mb",
             sec["vmem_required_mb"],
             f"VMEM-resident kernel needs this; fits_vmem="
             f"{sec['fits_vmem']}, auto-dispatch={sec['auto_dispatch']}")
    results["large_v"] = large
    # headline: the acceptance metric — GA-generation production on 8x8
    emit("pipeline_8x8_ga_generation_speedup",
         round(results["8x8"]["prep_speedup"], 1),
         "device-resident generate->graph vs host loop (target >= 5x)")
    with open(os.path.join(out_dir(), "pipeline_throughput.json"), "w") as f:
        json.dump(results, f, indent=1, default=float)
    return results


def main(quick: bool = True):
    run(quick)


if __name__ == "__main__":
    main(quick=os.environ.get("BENCH_FULL", "") != "1")
