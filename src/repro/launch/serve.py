"""End-to-end serving driver: batched requests through the slot engine.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --smoke \
      --requests 8 --max-tokens 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..configs import get_config
from ..models.model import build_model
from ..serve.engine import EngineConfig, Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-tokens", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=256)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, EngineConfig(
        n_slots=args.slots, cache_len=args.cache_len, eos=-1))

    rng = np.random.default_rng(0)
    reqs = []
    for i in range(args.requests):
        plen = int(rng.integers(4, 24))
        reqs.append(Request(i, rng.integers(
            3, cfg.vocab, size=plen).astype(np.int32),
            max_tokens=args.max_tokens))
        eng.submit(reqs[-1])
    t0 = time.monotonic()
    ticks = eng.run()
    dt = time.monotonic() - t0
    n_tok = sum(len(r.out_tokens) for r in reqs)
    print(f"[serve] {len(reqs)} requests, {n_tok} tokens in {ticks} ticks, "
          f"{dt:.1f}s -> {n_tok/max(dt,1e-9):.1f} tok/s "
          f"(all done: {all(r.done for r in reqs)})")


if __name__ == "__main__":
    main()
