"""Placement-design service: continuous-batching over stacked sweeps.

Many tenants submit :class:`~repro.core.api.DesignRequest`\\ s (an
``ExperimentConfig``, optionally expanded over a
:class:`~repro.core.pareto.ParetoGridSpec`).  The engine turns every
(expanded config x algorithm x repetition) into one preemptible
step-generator unit (``api.stackable_steps``), and each tick:

1. expires timed-out requests and admits queued ones into free capacity,
2. groups every live unit's pending scoring request by compiled scorer
   (same layout / chunk / backend / objective *structure* — the
   ``get_scorer`` LRU key), concatenates each group into **one** batched
   scorer call with per-row normalizer/weight vectors
   (:func:`repro.core.optimize.score_stacked` — the same core
   ``run_sweep`` stacks with), optionally population-sharded across
   devices (:func:`repro.sharding.population.shard_scorer`),
3. resumes the generators and streams one ``"progress"`` update per
   request (best-so-far cost), a ``"front"`` update whenever finished
   units extend the request's incremental Pareto front
   (:class:`repro.core.pareto.IncrementalFront`), and a terminal
   ``"done"`` / ``"cancelled"`` / ``"timeout"`` / ``"error"`` update.

Unlike the lockstep ``drive_stacked`` (all runs start together), tenants
join and leave the stacked batch at arbitrary generations — continuous
batching, exactly the ``serve.engine`` slot loop with "decode one token"
replaced by "score one stacked generation".

Results are bit-for-bit what ``run_sweep(fold_repetitions=False)``
produces for the same configs (same evaluator-cache keys, same norm
sharing, same per-(seed, repetition, algorithm) RNG streams), so
batching/sharding never changes a tenant's answer — pinned by
``tests/test_design_service.py``.  Evaluators live in a bounded LRU
(compiled scorers have their own in ``api.get_scorer``); entries backing
live runs are pinned so eviction can never invalidate an active request.
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field

import numpy as np

from ..core.api import (DesignRequest, DesignResponse, DesignUpdate,
                        RunRecord, algo_seed, make_evaluator, make_rep,
                        stackable_steps)
from ..core.cache import LRUCache
from ..core.chiplets import resolve_arch
from ..core.optimize import _request_parts, score_stacked
from ..core.pareto import (IncrementalFront, archive_candidates,
                           candidates_from_records)
from ..core.registries import OPTIMIZERS


@dataclass
class DesignStats:
    """Engine counters (``SweepStats``-style; cumulative over the engine's
    lifetime).  ``score_calls`` counts scorer dispatches — with >= 2
    compatible tenants in flight it is strictly smaller than the sum of
    the tenants' sequential dispatches (pinned by tests)."""

    submitted: int = 0
    admitted: int = 0
    completed: int = 0
    cancelled: int = 0
    timeouts: int = 0
    errors: int = 0
    ticks: int = 0
    score_calls: int = 0       # batched scorer dispatches
    stacked_rounds: int = 0    # dispatches that covered >= 2 units
    rows_scored: int = 0       # total placements scored
    evaluators_built: int = 0
    evaluator_evictions: int = 0
    shard_devices: int = 1


@dataclass
class _Unit:
    """One (expanded config, algorithm, repetition) run of a request."""

    req_id: str
    label: str                 # grid-point label ("base" for plain runs)
    cfg_i: int                 # expanded-config index within the request
    cfg: object                # the expanded ExperimentConfig
    objective: object          # its scalarization
    algo: str
    rep_i: int
    ev: object
    ev_key: tuple
    gen: object = None         # step generator (None once closed/sync)
    parts: tuple | None = None  # pending scoring request (_request_parts)
    result: object = None      # OptResult on completion
    record: RunRecord | None = None
    done: bool = False
    seconds: float = 0.0
    n_generated: int = 0
    best: float = float("inf")


@dataclass
class _ReqState:
    req: DesignRequest
    status: str = "queued"     # queued|active|done|cancelled|timeout|error
    units: list = field(default_factory=list)
    records: list = field(default_factory=list)
    updates: list = field(default_factory=list)
    front: IncrementalFront | None = None
    deadline: float | None = None
    t_submit: float = 0.0
    generation: int = 0        # scoring rounds this request took part in
    error: str | None = None
    _archive_seen: set = field(default_factory=set)

    @property
    def best(self) -> float | None:
        costs = [u.best for u in self.units if np.isfinite(u.best)]
        return min(costs) if costs else None


class DesignEngine:
    """The placement-design request engine (see module docstring).

    ``max_active`` bounds concurrently-running requests (queued requests
    wait); ``evaluator_cache`` bounds the evaluator LRU; ``shard`` routes
    every stacked scoring call through the population-axis ``shard_map``
    wrapper (bit-for-bit identical on one device).
    """

    def __init__(self, *, max_active: int = 8, evaluator_cache: int = 16,
                 shard: bool = False):
        self.stats = DesignStats()
        self.max_active = int(max_active)
        self.shard = bool(shard)
        self._mesh = None
        self._shard_fns: dict[int, object] = {}  # id(scorer) -> wrapper
        if shard:
            from repro.sharding.population import (n_pop_devices,
                                                   population_mesh)
            self._mesh = population_mesh()
            self.stats.shard_devices = n_pop_devices(self._mesh)

        def _on_evict(key, ev):
            self.stats.evaluator_evictions += 1

        self._evs: LRUCache = LRUCache(evaluator_cache, on_evict=_on_evict)
        self._norms: dict[tuple, object] = {}    # nkey -> normalizer draw
        self._queue: list[str] = []
        self._reqs: dict[str, _ReqState] = {}
        self._n = 0

    # -- request lifecycle -------------------------------------------------
    def submit(self, req: DesignRequest | dict) -> str:
        """Enqueue a request; returns its id (assigned when empty)."""
        if not isinstance(req, DesignRequest):
            req = DesignRequest.from_dict(req)
        if not req.request_id:
            self._n += 1
            req = dataclasses.replace(req, request_id=f"req-{self._n}")
        rid = req.request_id
        if rid in self._reqs:
            raise ValueError(f"duplicate request_id {rid!r}")
        st = _ReqState(req, t_submit=time.monotonic())
        if req.timeout_s is not None:
            st.deadline = st.t_submit + float(req.timeout_s)
        self._reqs[rid] = st
        self._queue.append(rid)
        self.stats.submitted += 1
        return rid

    def cancel(self, request_id: str) -> bool:
        """Cancel a queued or active request (False if already terminal)."""
        st = self._reqs[request_id]
        if st.status not in ("queued", "active"):
            return False
        self._finish(st, "cancelled")
        self.stats.cancelled += 1
        return True

    def status(self, request_id: str) -> str:
        return self._reqs[request_id].status

    def updates(self, request_id: str) -> list[DesignUpdate]:
        """All updates streamed so far (terminal one included at the end)."""
        return list(self._reqs[request_id].updates)

    def result(self, request_id: str) -> DesignResponse | None:
        """Terminal :class:`DesignResponse`, or None while still running."""
        st = self._reqs[request_id]
        if st.status in ("queued", "active"):
            return None
        return DesignResponse(
            request_id=request_id, status=st.status,
            records=list(st.records),
            front=None if st.front is None else st.front.front(),
            updates=list(st.updates),
            seconds=time.monotonic() - st.t_submit, error=st.error)

    # -- evaluator cache ---------------------------------------------------
    def _evaluator(self, cfg, salt):
        """run_sweep's evaluator sharing, LRU-bounded: one evaluator per
        (structure key x objective x schedule), one normalizer draw per
        structure key.  Configs with an archive get a per-request ``salt``
        so tenants never share (and so cross-pollute) archives; the norm
        draw is seed-deterministic, so re-building after an eviction
        returns identical evaluators."""
        arch = resolve_arch(cfg.arch, cfg.config)
        nkey = (cfg.arch, cfg.config, cfg.seed, cfg.norm_samples, cfg.chunk,
                cfg.backend, cfg.mutation_mode, cfg.objective.normalizer)
        key = nkey + (cfg.objective, cfg.schedule, cfg.archive_k,
                      cfg.workload, salt)
        if key not in self._evs:
            rep = make_rep(arch, cfg.arch, cfg.mutation_mode)
            ev = make_evaluator(
                rep, arch, rng=np.random.default_rng(cfg.seed),
                norm_samples=cfg.norm_samples, chunk=cfg.chunk,
                backend=cfg.backend, objective=cfg.objective,
                schedule=cfg.schedule, norm=self._norms.get(nkey),
                archive_k=cfg.archive_k, workload=cfg.workload)
            self._evs[key] = ev
            self._norms.setdefault(nkey, ev.norm)
            self.stats.evaluators_built += 1
        return key, self._evs[key]

    def _score_fn(self, scorer):
        if not self.shard:
            return None
        sid = id(scorer)
        if sid not in self._shard_fns:
            from repro.sharding.population import shard_scorer
            self._shard_fns[sid] = shard_scorer(scorer, self._mesh)
        return self._shard_fns[sid]

    # -- admission ---------------------------------------------------------
    def _expanded(self, req: DesignRequest):
        cfg = req.config
        if req.pareto_grid is None:
            return [("base", cfg.objective, cfg)]
        return [(label, obj, dataclasses.replace(cfg, objective=obj))
                for label, obj in req.pareto_grid.points(cfg.objective)]

    def _admit(self, st: _ReqState) -> None:
        st.status = "active"
        self.stats.admitted += 1
        req = st.req
        if req.pareto_grid is not None or req.config.archive_k > 0:
            st.front = IncrementalFront(req.config)
        for cfg_i, (label, obj, cfg) in enumerate(self._expanded(req)):
            salt = req.request_id if cfg.archive_k > 0 else None
            ev_key, ev = self._evaluator(cfg, salt)
            for algo in cfg.algorithms:
                entry = OPTIMIZERS.get(algo)
                params = cfg.resolved_params(algo)
                steps = stackable_steps(algo)
                for rep_i in range(cfg.repetitions):
                    u = _Unit(req.request_id, label, cfg_i, cfg, obj, algo,
                              rep_i, ev, ev_key)
                    st.units.append(u)
                    rng = np.random.default_rng(
                        algo_seed(cfg.seed, rep_i, algo))
                    if steps is None or cfg.budget.seconds is not None:
                        # Not preemptible (unregistered stepper, or a
                        # wall-clock budget that interleaving would eat):
                        # run to completion at admission.
                        ta, g0 = time.monotonic(), ev.n_generated
                        c0 = ev.n_score_calls
                        u.result = entry.fn(ev, rng, cfg.budget, params)
                        u.seconds = time.monotonic() - ta
                        u.n_generated = ev.n_generated - g0
                        u.best = float(u.result.best_cost)
                        u.done = True
                        self.stats.score_calls += ev.n_score_calls - c0
                        self.stats.rows_scored += u.result.n_evaluated
                        self._record(st, u)
                        st.updates.append(DesignUpdate(
                            req.request_id, "progress",
                            tick=self.stats.ticks,
                            generation=st.generation, best_cost=st.best))
                    else:
                        self._evs.pin(ev_key)
                        u.gen = steps(ev, rng, cfg.budget, params)
                        self._resume(u)        # prime to the first request
                        if u.done:             # degenerate: no scoring round
                            self._record(st, u)
        if all(u.done for u in st.units):
            self._finish(st, "done")

    # -- unit stepping -----------------------------------------------------
    def _resume(self, u: _Unit, send=None) -> None:
        g0, ta = u.ev.n_generated, time.monotonic()
        try:
            r = next(u.gen) if send is None else u.gen.send(send)
            u.parts = _request_parts(r)
        except StopIteration as e:
            u.result, u.done, u.parts = e.value, True, None
            u.best = float(u.result.best_cost)
            self._release(u)
        u.seconds += time.monotonic() - ta
        u.n_generated += u.ev.n_generated - g0

    def _release(self, u: _Unit) -> None:
        if u.gen is not None:
            u.gen.close()
            u.gen = None
            self._evs.unpin(u.ev_key)

    def _record(self, st: _ReqState, u: _Unit) -> None:
        u.record = RunRecord(
            u.cfg.arch, u.cfg.config, u.algo, u.rep_i, u.result, u.seconds,
            degenerate_norms=u.ev.degenerate_norms)
        # Completion order varies with budgets; the response's records stay
        # in canonical unit order (config-major), like run_sweep's.
        st.records = [x.record for x in st.units if x.record is not None]
        if st.front is not None:
            cands = candidates_from_records(
                [(u.label, u.cfg_i, u.objective, u.record)])
            snap = u.result.archive
            if snap is not None:
                # The archive is per-evaluator (shared by the request's
                # repetitions/algorithms on one expanded config); dedup
                # snapshots by content so rows are added once.
                h = np.asarray(snap["costs"]).tobytes()
                if h not in st._archive_seen:
                    st._archive_seen.add(h)
                    cands += archive_candidates(
                        u.label, u.cfg_i, u.objective, snap,
                        normalizers=u.result.normalizers)
            st.front.add(cands)

    def _finish(self, st: _ReqState, status: str) -> None:
        for u in st.units:
            self._release(u)
        if st.status == "queued":
            self._queue.remove(st.req.request_id)
        st.status = status
        if status == "done":
            self.stats.completed += 1
            if st.front is not None:
                st.updates.append(DesignUpdate(
                    st.req.request_id, "front", tick=self.stats.ticks,
                    generation=st.generation, best_cost=st.best,
                    front=st.front.front()))
        st.updates.append(DesignUpdate(
            st.req.request_id, status, tick=self.stats.ticks,
            generation=st.generation, best_cost=st.best, error=st.error))

    # -- the tick loop -----------------------------------------------------
    def _active(self) -> list[_ReqState]:
        return [s for s in self._reqs.values() if s.status == "active"]

    def step(self) -> bool:
        """One engine tick; False when nothing is queued or running."""
        if not self._queue and not self._active():
            return False
        self.stats.ticks += 1
        now = time.monotonic()

        # 1. Expire (queued requests included: timeout_s=0 never runs).
        for st in list(self._reqs.values()):
            if st.status in ("queued", "active") and \
                    st.deadline is not None and now >= st.deadline:
                self._finish(st, "timeout")
                self.stats.timeouts += 1

        # 2. Admit into free capacity, FIFO.
        while self._queue and len(self._active()) < self.max_active:
            st = self._reqs[self._queue.pop(0)]
            try:
                self._admit(st)
            except Exception as e:            # bad config: fail the request
                st.error = f"{type(e).__name__}: {e}"
                self._finish(st, "error")
                self.stats.errors += 1

        # 3. One stacked scoring round per compiled scorer.
        live = [u for st in self._active() for u in st.units
                if u.parts is not None]
        groups: dict[int, list[_Unit]] = {}
        for u in live:
            groups.setdefault(id(u.ev.scorer), []).append(u)
        touched: dict[str, bool] = {}
        for us in groups.values():
            entries = [(u.parts, u.ev) for u in us]
            sizes = [p[2] for p, _ in entries]
            score_fn = self._score_fn(us[0].ev.scorer)
            try:
                per_entry, t_score = score_stacked(entries,
                                                   score_fn=score_fn)
            except Exception as e:
                for u in us:
                    st = self._reqs[u.req_id]
                    if st.status == "active":
                        st.error = f"{type(e).__name__}: {e}"
                        self._finish(st, "error")
                        self.stats.errors += 1
                continue
            self.stats.score_calls += 1
            self.stats.rows_scored += sum(sizes)
            if len(us) > 1:
                self.stats.stacked_rounds += 1
            total = max(sum(sizes), 1)
            for u, sz, (costs, mi) in zip(us, sizes, per_entry):
                u.seconds += t_score * (sz / total)
                u.parts = None
                c = np.asarray(costs)
                if c.size:
                    u.best = min(u.best, float(np.min(c)))
                self._resume(u, (costs, mi))
                touched[u.req_id] = True
                if u.done:
                    self._record(self._reqs[u.req_id], u)

        # 4. Stream progress; finalize requests whose units all finished.
        for rid in touched:
            st = self._reqs[rid]
            if st.status != "active":
                continue
            st.generation += 1
            st.updates.append(DesignUpdate(
                rid, "progress", tick=self.stats.ticks,
                generation=st.generation, best_cost=st.best))
            if all(u.done for u in st.units):
                self._finish(st, "done")
        return True

    def run(self, max_ticks: int = 100_000) -> int:
        """Drive ticks until every request is terminal; returns #ticks."""
        ticks = 0
        while ticks < max_ticks and self.step():
            ticks += 1
        return ticks
