"""Kernel-layer microbenchmarks (CPU: jnp reference path wall-times +
Pallas interpret-mode correctness cross-checks; real perf is a TPU matter —
the dry-run roofline carries those numbers).

Measures the PlaceIT scoring hot spot (batched FW) and the LM hot ops.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref

from .common import budget, emit


def timeit(f, *args, reps=3):
    f(*args)[0].block_until_ready() if isinstance(f(*args), tuple) else \
        jax.block_until_ready(f(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(f(*args))
    return (time.perf_counter() - t0) / reps * 1e6  # us


def run(quick: bool = True):
    rng = np.random.default_rng(0)
    # --- batched FW (PlaceIT scorer hot spot) --------------------------
    for B, V in [(4, 128), (16, 128)] + ([] if quick else [(64, 256)]):
        W = np.full((B, V, V), 1e9, np.float32)
        for b in range(B):
            np.fill_diagonal(W[b], 0)
            for _ in range(V * 3):
                i, j = rng.integers(V, size=2)
                W[b, i, j] = W[b, j, i] = min(W[b, i, j], 1.0)
        f = jax.jit(ref.fw_counts_ref)
        us = timeit(lambda w: f(w)[0], jnp.array(W))
        emit(f"kernel_fw_counts_B{B}_V{V}_us", round(us, 1),
             f"{B / (us / 1e6):.0f} graphs/s")

    # --- flash attention ref path ---------------------------------------
    B, S, H, Hkv, d = 2, budget(quick, 512, 2048), 8, 2, 64
    q = jnp.array(rng.standard_normal((B, S, H, d)), jnp.float32)
    k = jnp.array(rng.standard_normal((B, S, Hkv, d)), jnp.float32)
    v = jnp.array(rng.standard_normal((B, S, Hkv, d)), jnp.float32)
    f = jax.jit(lambda q, k, v: ref.attention_ref(q, k, v, causal=True))
    us = timeit(f, q, k, v)
    emit(f"kernel_attention_ref_S{S}_us", round(us, 1),
         f"{2 * B * H * S * S * d * 2 / (us / 1e6) / 1e9:.1f} GFLOP/s")

    # --- selective scan ref ----------------------------------------------
    Bt, S2, Di, N = 2, budget(quick, 256, 1024), 256, 16
    x = jnp.array(rng.standard_normal((Bt, S2, Di)), jnp.float32)
    dt = jnp.array(0.1 + rng.random((Bt, S2, Di)), jnp.float32)
    A = jnp.array(-rng.random((Di, N)), jnp.float32)
    Bm = jnp.array(rng.standard_normal((Bt, S2, N)), jnp.float32)
    Cm = jnp.array(rng.standard_normal((Bt, S2, N)), jnp.float32)
    Dm = jnp.array(rng.standard_normal(Di), jnp.float32)
    f = jax.jit(lambda *a: ref.selective_scan_ref(*a)[0])
    us = timeit(f, x, dt, A, Bm, Cm, Dm)
    emit(f"kernel_selective_scan_S{S2}_us", round(us, 1))

    # --- interpret-mode cross-check (tiny, correctness-on-CPU story) ----
    D1, N1 = ops.fw_counts(jnp.array(
        np.minimum(rng.random((1, 32, 32)).astype(np.float32) * 8, 1e9)
        + np.where(np.eye(32), -1e9, 0)).clip(0, 1e9), impl="pallas")
    emit("kernel_fw_pallas_interpret_ok", bool(np.isfinite(
        np.array(D1)).all()))


def main(quick: bool = True):
    run(quick)


if __name__ == "__main__":
    main()
