"""Named 3D / hierarchical arch families and their representation factory.

Each family pairs a chiplet-count entry in ``core.chiplets.ARCH3D`` with
grid dimensions and a structural spec (stack vs gateway hierarchy, an
optional registered augmentation).  ``api.make_rep`` dispatches here for
any family name in ``ARCH3D``, so ``run_sweep`` / Pareto grids / the
design service open the 3D scenario space with an arch-name change only.

Tier semantics (``W_INTRA < W_BACKBONE < W_VERTICAL``): planar mesh links
are the paper's D2D cost, backbone / express links pay
``backbone_factor`` on the link latency, vertical TSVs pay
``tsv_slowdown`` — both runtime operands (see
``arch3d.topology.default_tier_values``).
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.chiplets import ArchSpec

from .placement import Homog3DRep


@dataclass(frozen=True)
class Family3DSpec:
    """Structural spec of one 3D family (everything but chiplet counts)."""

    dims: tuple[int, int, int]                # (R, C, Z)
    kind: str = "stack"                       # stack | gateway
    cluster: tuple[int, int] | None = None
    augment: str = "none"                     # none | torus | express | ...
    augment_params: dict = field(default_factory=dict)
    tsv_slowdown: float = 4.0
    backbone_factor: float = 2.0


# Family registry.  Chiplet counts (core.chiplets.ARCH3D) fill the grids
# exactly — 32 chiplets on 2 layers of 4x4, 64 on 4 layers — keeping the
# paper's ~6:1:1 compute:memory:io shape, so the flat-vs-stacked
# comparison (examples/topo3d_sweep.py) holds the chiplet set fixed and
# varies only the arrangement.
FAMILIES3D: dict[str, Family3DSpec] = {
    "stack3d32": Family3DSpec(dims=(4, 4, 2)),
    "stack3d64": Family3DSpec(dims=(4, 4, 4)),
    # Gateway hierarchies want the relay-capable "placeit" chiplet config:
    # under "baseline" a non-relay 1-PHY chiplet landing on a gateway cell
    # cuts its whole cluster off, so connected random placements are rare
    # (~2-3%) and generate_valid burns its retry budget.
    "gw3d64": Family3DSpec(dims=(4, 4, 4), kind="gateway", cluster=(2, 2)),
    "torus3d32": Family3DSpec(dims=(4, 4, 2), augment="torus"),
    "express3d32": Family3DSpec(dims=(4, 4, 2), augment="express"),
}


def make_rep3d(arch: ArchSpec, arch_name: str,
               mutation_mode: str = "neighbor-one") -> Homog3DRep:
    """Representation for a named 3D family (``FAMILIES3D`` keys)."""
    try:
        spec = FAMILIES3D[arch_name]
    except KeyError:
        raise ValueError(
            f"unknown 3D arch family {arch_name!r}; known: "
            f"{', '.join(sorted(FAMILIES3D))}") from None
    R, C, Z = spec.dims
    return Homog3DRep(arch, R=R, C=C, Z=Z, mutation_mode=mutation_mode,
                      kind=spec.kind, cluster=spec.cluster,
                      augment=spec.augment,
                      augment_params=dict(spec.augment_params),
                      tsv_slowdown=spec.tsv_slowdown,
                      backbone_factor=spec.backbone_factor)
