"""Fault-tolerant training loop: checkpoint/restart, straggler watermarks,
preemption-safe saves.

This is the single-controller driver a deployment wraps per-host.  Fault
tolerance story (1000+ node posture, DESIGN.md §6):

* restart     — the loop opens with ``ckpt.restore`` (latest committed
                step); the data cursor rides in checkpoint extras, so a
                restart replays nothing and skips nothing.
* atomicity   — saves go through tmpdir+rename+marker; a kill mid-save
                cannot corrupt the latest good step.
* stragglers  — per-step wall time feeds an EWMA watermark; steps slower
                than ``straggler_factor``× the watermark are counted and
                surfaced in metrics (on a real cluster this hook triggers
                hot-spare swap / rescheduling; on one host it is telemetry).
* preemption  — SIGTERM flips a flag; the loop checkpoints and exits
                cleanly at the next step boundary.
* elasticity  — restore accepts a different mesh: ``state_shardings`` are
                computed from the *current* mesh and applied on device_put
                (see ckpt.restore / tests/test_ckpt.py::test_elastic_remesh).
"""
from __future__ import annotations

import signal
import time
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import numpy as np

from ..ckpt import checkpoint as ckpt


@dataclass
class LoopConfig:
    total_steps: int
    ckpt_dir: str
    ckpt_every: int = 100
    keep: int = 3
    log_every: int = 10
    straggler_factor: float = 2.0
    ewma: float = 0.9


@dataclass
class LoopState:
    step: int = 0
    watermark_s: float = 0.0
    n_stragglers: int = 0
    preempted: bool = False
    history: list = field(default_factory=list)


def run(loop_cfg: LoopConfig, *, state, train_step: Callable, stream,
        state_shardings=None, log: Callable = print) -> tuple[Any, LoopState]:
    """Run (or resume) training.  Returns (final_state, loop_state)."""
    ls = LoopState()

    # ---- restart path ----------------------------------------------------
    last = ckpt.latest_step(loop_cfg.ckpt_dir)
    if last is not None:
        like = jax.tree.map(lambda x: x, state)
        state, step, extras = ckpt.restore(
            loop_cfg.ckpt_dir, like, shardings=state_shardings)
        ls.step = step
        if "cursor" in extras and hasattr(stream, "from_cursor"):
            stream.step = int(extras["cursor"].get("step", step))
        log(f"[loop] resumed from step {step}")

    # ---- preemption hook ---------------------------------------------------
    def _on_sigterm(signum, frame):
        ls.preempted = True
    try:
        prev_handler = signal.signal(signal.SIGTERM, _on_sigterm)
    except ValueError:              # non-main thread (tests)
        prev_handler = None

    jitted = train_step if hasattr(train_step, "lower") else jax.jit(train_step)

    def save(step):
        ckpt.save(loop_cfg.ckpt_dir, step, state,
                  extras={"cursor": stream.cursor()
                          if hasattr(stream, "cursor") else {}},
                  keep=loop_cfg.keep)

    try:
        while ls.step < loop_cfg.total_steps:
            batch = stream.batch_at(ls.step) if hasattr(stream, "batch_at") \
                else next(stream)
            if hasattr(stream, "step"):
                stream.step = ls.step + 1
            t0 = time.monotonic()
            state, metrics = jitted(state, batch)
            jax.block_until_ready(jax.tree.leaves(metrics)[0])
            dt = time.monotonic() - t0
            ls.step += 1
            # ---- straggler watermark ----------------------------------
            if ls.watermark_s == 0.0:
                ls.watermark_s = dt
            slow = dt > loop_cfg.straggler_factor * ls.watermark_s
            if slow:
                ls.n_stragglers += 1
            ls.watermark_s = (loop_cfg.ewma * ls.watermark_s
                              + (1 - loop_cfg.ewma) * dt)
            if ls.step % loop_cfg.log_every == 0 or slow:
                loss = float(np.asarray(metrics.get("loss", np.nan)))
                ls.history.append((ls.step, loss, dt))
                log(f"[loop] step {ls.step} loss {loss:.4f} "
                    f"dt {dt*1e3:.0f}ms wm {ls.watermark_s*1e3:.0f}ms"
                    + (" STRAGGLER" if slow else ""))
            if ls.step % loop_cfg.ckpt_every == 0 \
                    or ls.step == loop_cfg.total_steps or ls.preempted:
                save(ls.step)
            if ls.preempted:
                log(f"[loop] preempted; checkpointed at step {ls.step}")
                break
    finally:
        if prev_handler is not None:
            signal.signal(signal.SIGTERM, prev_handler)
    return state, ls
