"""Model configuration for the LM substrate (all 10 assigned architectures).

One frozen dataclass covers every family: dense / MoE / SSM (mamba-1) /
hybrid (griffin) / encoder-decoder / VLM- and audio-stub decoders.  The
assigned-architecture configs in ``repro.configs`` instantiate these with the
exact published hyper-parameters; smoke tests use ``reduced()`` copies.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass


@dataclass(frozen=True)
class LMConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0               # 0 -> d_model // n_heads

    # attention flavor
    qk_norm: bool = False           # qwen3: RMSNorm on q and k per head
    qkv_bias: bool = False          # qwen2.5: bias on qkv projections
    softcap: float | None = None    # grok: tanh logit soft-capping
    rope_theta: float = 10000.0
    norm_eps: float = 1.0e-6
    tie_embeddings: bool = False

    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01

    # SSM (mamba-1)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    dt_rank: int = 0                # 0 -> ceil(d_model / 16)

    # hybrid (griffin / recurrentgemma): pattern of temporal-mixing blocks,
    # repeated; 'r' = RG-LRU recurrent block, 'a' = local-attention block.
    pattern: str = ""               # e.g. "rra"
    window: int = 0                 # local-attention window (0 = none)
    d_rnn: int = 0                  # RG-LRU width (0 -> d_model)
    conv_width: int = 4

    # encoder-decoder (seamless)
    n_enc_layers: int = 0

    # modality frontend STUB: precomputed embeddings prepended to the token
    # stream ('patch' for VLM anyres tiles, 'audio' for speech frames).
    frontend: str = ""              # "" | "patch" | "audio"
    n_frontend_tokens: int = 0

    # numerics / lowering
    dtype: str = "bfloat16"
    pad_heads_to: int = 0           # zero-pad q heads for clean TP sharding
    vocab_pad_to: int = 2048        # pad vocab for clean TP sharding
    remat: bool = True
    scan_layers: bool = True        # False -> unrolled (exact cost analysis)
    q_chunk: int = 0                # 0 -> unchunked attention
    attn_impl: str = "auto"         # kernels.ops impl selector

    # ---------------------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def n_heads_p(self) -> int:
        """Padded query-head count (sharding-friendly; zero-padded heads are
        function-exact: zero wq columns → uniform attention → zero wo rows).
        Must stay a multiple of n_kv_heads (GQA grouping)."""
        if self.pad_heads_to and self.pad_heads_to > self.n_heads:
            assert self.pad_heads_to % max(self.n_kv_heads, 1) == 0
            return self.pad_heads_to
        return self.n_heads

    @property
    def vocab_padded(self) -> int:
        p = self.vocab_pad_to
        return -(-self.vocab // p) * p if p else self.vocab

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def dt_rank_(self) -> int:
        return self.dt_rank or -(-self.d_model // 16)

    @property
    def d_rnn_(self) -> int:
        return self.d_rnn or self.d_model

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def bounded_state(self) -> bool:
        """True if decode state does not grow with context (SSM / hybrid
        with windowed attention) — the long_500k eligibility criterion."""
        return self.family == "ssm" or (self.family == "hybrid"
                                        and self.window > 0)

    def layer_plan(self) -> list[tuple[str, int]]:
        """Homogeneous groups of layers to scan over: [(kind, count)].

        dense/moe/ssm: one group.  hybrid: superblocks of len(pattern)
        layers plus an explicit tail so arbitrary depths keep the exact
        published layer order (e.g. recurrentgemma-9b: 38 = 12*(r,r,a)+2r).
        """
        if self.family == "hybrid":
            p = len(self.pattern)
            n_super, tail = divmod(self.n_layers, p)
            plan = [("super", n_super)] if n_super else []
            for ch in self.pattern[:tail]:
                plan.append(("rec" if ch == "r" else "lattn", 1))
            return plan
        kind = {"dense": "attn", "moe": "moe", "ssm": "mamba",
                "encdec": "attn"}[self.family]
        return [(kind, self.n_layers)]

    def reduced(self, **over) -> "LMConfig":
        """Smoke-test copy: same family/flavors, tiny dimensions."""
        small = dict(
            n_layers=min(self.n_layers, 4 if self.family != "hybrid"
                         else max(len(self.pattern) + 1, 4)),
            d_model=128,
            n_heads=min(self.n_heads, 4),
            n_kv_heads=min(self.n_kv_heads, 2),
            head_dim=32,
            d_ff=256,
            vocab=512,
            vocab_pad_to=128,
            n_experts=min(self.n_experts, 8) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            ssm_state=min(self.ssm_state, 8) if self.ssm_state else 0,
            window=min(self.window, 32) if self.window else 0,
            d_rnn=128 if self.d_rnn_ and self.family == "hybrid" else 0,
            n_enc_layers=min(self.n_enc_layers, 2),
            n_frontend_tokens=min(self.n_frontend_tokens, 16),
            dt_rank=8 if self.family == "ssm" else 0,
            dtype="float32",
            scan_layers=True,
            q_chunk=0,
            name=self.name + "-smoke",
        )
        small.update(over)
        return dataclasses.replace(self, **small)
