"""Optimization algorithms (paper §II-B) over placement representations.

Best Random (BR), Genetic Algorithm (GA) and Simulated Annealing (SA), all
driven through the four representation functions random_placement / mutate /
merge / get_cost (§IV).  Invalid placements (unconnected chiplets) cause the
generating operation to be repeated, exactly as in §V-A / §VI-A.

Beyond-paper adaptation (DESIGN.md §3): cost evaluation is *batched* — a GA
generation or a block of SA chains is scored in a single vmapped JAX call —
which is what makes the method TPU-friendly.  The faithful sequential
semantics are preserved: BR/GA evaluate the same individuals they would
sequentially; "SA x K chains" runs K independent faithful chains.

Two execution styles coexist:

* **Host-loop** (``best_random`` / ``genetic_algorithm`` /
  ``simulated_annealing``): individuals are generated/mutated/merged one at
  a time in host Python with retry-until-connected, then scored in batches.
  All three are written as *step generators* (``best_random_steps`` /
  ``genetic_algorithm_steps`` / ``simulated_annealing_steps``) that yield
  scoring requests and receive ``(costs, metrics)`` — ``_drive`` runs one
  generator against one Evaluator, :func:`drive_stacked` runs several in
  lockstep with their scoring requests stacked into single vmapped calls
  (the ``run_sweep`` cross-config fast path).
* **Device-resident** (``best_random_batched`` / ``genetic_algorithm_batched``
  / ``simulated_annealing_batched``): whole generations / chain-blocks are
  produced by :class:`DevicePipeline` as fused generate→graph→score batched
  calls over stacked arrays — fully on device for homogeneous grids, with a
  vectorized host corner-placement stage for heterogeneous archs — and
  invalid individuals are masked-and-resampled in batch instead of retried
  one by one.  These too are step generators (``*_batched_steps``) whose
  requests are pre-stacked device batch dicts, so they stack across
  configs in :func:`drive_stacked` exactly like the host loops.

Cost evaluation is in-scorer: the Evaluator's jitted scorer carries the
compiled :class:`repro.core.objective.Objective`, emits a per-placement
``cost`` next to the metrics (normalizers *and objective weights* enter
as runtime vectors), and ``Evaluator.topk`` ranks a candidate batch on
device in the same call — there is no host-numpy cost loop on the hot
path.  With a :class:`repro.core.objective.Schedule` attached to the
Evaluator, every step generator tags its scoring requests with the
ramped weight vector at the run's current progress (constraint
hardening) and re-ranks its final pool under the final weights — all
without retracing, since only the objective's term structure is
trace-time.
"""
from __future__ import annotations

import functools
import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from .cache import LRUCache
from .cost import CostNormalizers
from .objective import (NORM_DIM, TRACE_TERMS, Objective, compile_schedule,
                        norms_vec, objective_cost_host, weights_vec)
from .placement_hetero import HeteroRep
from .placement_homog import HomogRep
from .proxies import make_ranker, make_scorer
from .topology import (HeteroGraphBatch, HomogGraphBatch, ScoreGraph,
                       stack_graphs)


@dataclass
class OptResult:
    best_sol: object
    best_cost: float
    best_metrics: dict
    # (wall_seconds, n_evaluated, best_cost_so_far) samples
    history: list = field(default_factory=list)
    n_generated: int = 0          # placements generated incl. retries
    n_evaluated: int = 0          # placements actually scored
    normalizers: CostNormalizers | None = None
    # Snapshot of the evaluator's population archive at run end (see
    # PopArchive.snapshot; None when the evaluator has no archive).  The
    # archive is per-evaluator, so records sharing an evaluator carry
    # increasingly complete snapshots — the last one is the full archive.
    archive: dict | None = None


# ---------------------------------------------------------------------------
# Device-resident population archive (ROADMAP: thicker Pareto fronts at no
# extra search cost).
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("k",))
def _archive_merge(sc, sa, sb, costs, a, b, k: int):
    c = jnp.concatenate([sc, costs])
    A = jnp.concatenate([sa, a])
    B = jnp.concatenate([sb, b])
    order = jnp.argsort(c)                     # stable: keeps first-seen
    cs = c[order]
    dup = jnp.concatenate(
        [jnp.zeros((1,), bool), cs[1:] == cs[:-1]])
    cs = jnp.where(dup, jnp.inf, cs)           # equal-cost rows collapse
    keep = jnp.argsort(cs)[:k]
    sel = order[keep]
    return cs[keep], A[sel], B[sel]


class PopArchive:
    """Fixed-size top-K archive of evaluated (cost, placement) rows.

    Every scored batch that passes through :meth:`add` is masked (invalid
    rows -> +inf) and compacted against the current archive in one jitted
    device call (concatenate + stable sort + equal-cost dedup + take-K),
    so the archive rides along with the search at no extra scoring cost.
    Pareto fronts built from a sweep then re-score these K placements next
    to the per-run winners (``pareto.run_pareto_sweep``), thickening the
    front beyond one point per run.

    The scalar ``cost`` is only the archive's *selection pressure* — rows
    are re-scored under the front's base objective before entering a
    front, so mixing costs from different schedule-ramp stages merely
    biases which K placements are retained, never the front itself.
    Equal-cost rows are collapsed to the first seen (elites re-scored
    every generation must not fill the archive with copies); distinct
    placements with bit-equal costs are deliberately dropped too.
    """

    def __init__(self, k: int):
        if k < 1:
            raise ValueError(f"archive size must be >= 1, got {k}")
        self.k = int(k)
        self.n_added = 0
        self._state = None

    def add(self, costs, a, b, valid=None) -> None:
        """Fold a scored batch into the archive.  ``a``/``b`` are the
        stacked placement arrays ([B, ...]; the host Sol tuple's two
        members), ``costs`` the matching [B] cost vector, ``valid`` an
        optional [B] bool mask (e.g. batched-pipeline connectivity)."""
        costs = jnp.asarray(np.asarray(costs), jnp.float32)
        if valid is not None:
            costs = jnp.where(jnp.asarray(np.asarray(valid), bool),
                              costs, jnp.inf)
        a, b = jnp.asarray(a), jnp.asarray(b)
        if self._state is None:
            self._state = (
                jnp.full((self.k,), jnp.inf, jnp.float32),
                jnp.zeros((self.k,) + a.shape[1:], a.dtype),
                jnp.zeros((self.k,) + b.shape[1:], b.dtype))
        sc, sa, sb = self._state
        self._state = _archive_merge(sc, sa, sb, costs, a, b, self.k)
        self.n_added += int(costs.shape[0])

    def snapshot(self) -> dict | None:
        """Host copy of the filled rows: ``{"costs", "a", "b"}`` arrays
        (ascending cost), or None when nothing was archived."""
        if self._state is None:
            return None
        c, a, b = (np.asarray(x) for x in self._state)
        m = np.isfinite(c)
        if not m.any():
            return None
        return {"costs": c[m], "a": a[m], "b": b[m]}


class Evaluator:
    """rep + scorer + objective + cost normalizers -> batched get_cost().

    ``objective`` defaults to the arch's (deprecated) ``w_*`` weights via
    :meth:`Objective.from_arch`.  When a pre-built ``scorer`` is passed it
    must have been compiled with the *same term structure*
    (``Objective.structure_key``; ``api.get_scorer`` keys its cache
    accordingly) — the objective's weights are always passed at call time
    as the runtime weight vector, so objectives differing only in weights
    share one compiled scorer.

    ``schedule`` (an ``objective.Schedule``) attaches constraint-hardening
    weight ramps: step generators ask :meth:`sched_weights` for the weight
    vector at their current progress and tag their scoring requests with
    it.  ``norm`` re-uses an existing ``CostNormalizers`` draw (sweeps
    share draws across objectives whose normalizer inputs are identical)
    instead of re-drawing ``norm_samples`` placements.
    """

    def __init__(self, rep, arch, *, rng: np.random.Generator,
                 norm_samples: int = 500, chunk: int = 16, fw_impl=None,
                 scorer=None, objective: Objective | None = None,
                 schedule=None, norm: CostNormalizers | None = None,
                 archive_k: int = 0, workload=None):
        self.rep = rep
        self.arch = arch
        self.objective = (objective if objective is not None
                          else Objective.from_arch(arch))
        self._weights_vec = weights_vec(self.objective)
        # Traffic workload (repro.netsim.workload.Workload) backing a
        # `trace-lat` objective term.  Its packed vector rides along with
        # every scoring request as the runtime `_demand` operand, so
        # workloads never retrace and stacked cross-workload scoring
        # carries per-row demand.
        self.workload = workload
        needs_demand = any(t.name in TRACE_TERMS
                           for t in self.objective.terms)
        if needs_demand and workload is None:
            raise ValueError(
                "objective has a trace term (trace-lat/trace-thr) but no "
                "workload; pass Evaluator(..., "
                "workload=netsim.Workload(...))")
        self._demand_vec = None
        if needs_demand:
            if workload.n != rep.layout.N:
                raise ValueError(
                    f"workload covers {workload.n} chiplets but the arch "
                    f"has {rep.layout.N}")
            self._demand_vec = np.asarray(workload.vec(), np.float32)
        self.schedule = (compile_schedule(schedule, self.objective)
                         if schedule is not None else None)
        if scorer is not None:
            # Pre-built (usually cached) jitted scorer — see api.get_scorer.
            self.scorer = scorer
        else:
            kw = {"chunk": chunk, "objective": self.objective}
            if fw_impl is not None:
                kw["fw_impl"] = fw_impl
            self.scorer = make_scorer(rep.layout, **kw)
        self.n_generated = 0
        self.n_score_calls = 0
        self._pipeline: "DevicePipeline | None" = None
        self._ranker = None
        # The archive only collects search batches, never the norm-sample
        # draw below (those costs are computed against all-ones norms).
        self.archive: PopArchive | None = None
        if norm is not None:
            self.norm = norm
            self._norm_vec = norms_vec(self.norm)
        else:
            # Norm-sample draws are scored before normalizers exist; the
            # device cost of those calls is computed against all-ones norms
            # and never consumed.
            self._norm_vec = np.ones(NORM_DIM, np.float32)
            sols, graphs = self.generate_valid(
                lambda r: self.rep.random(r), rng, norm_samples)
            metrics = self.score(graphs)
            self.norm = CostNormalizers.from_samples(
                metrics, policy=self.objective.normalizer)
            self._norm_vec = norms_vec(self.norm)
        if archive_k:
            self.archive = PopArchive(archive_k)

    @property
    def norm_vec(self) -> np.ndarray:
        """Normalizers as the scorer's runtime [NORM_DIM] vector."""
        return self._norm_vec

    @property
    def demand_vec(self) -> np.ndarray | None:
        """The workload's packed demand operand (``None`` unless the
        objective carries a trace term — trace-lat / trace-thr)."""
        return self._demand_vec

    def _with_demand(self, batch: dict) -> dict:
        """Attach the workload's `_demand` rows to a scoring batch (no-op
        without a trace-term workload, or when rows — e.g. per-row stacked
        demand — are already present)."""
        if self._demand_vec is None or "_demand" in batch:
            return batch
        P = int(batch["W"].shape[0])
        batch = dict(batch)
        batch["_demand"] = np.broadcast_to(
            self._demand_vec, (P, self._demand_vec.shape[0]))
        return batch

    @property
    def weights_vec(self) -> np.ndarray:
        """Objective weights as the scorer's runtime weight vector."""
        return self._weights_vec

    def sched_weights(self, progress: float) -> np.ndarray | None:
        """The schedule's weight vector at ``progress`` in [0, 1] (``None``
        when no schedule is attached — requests then use the static
        objective weights)."""
        if self.schedule is None:
            return None
        return self.schedule.weights_at(progress)

    @property
    def degenerate_norms(self) -> tuple:
        """Traffic types whose normalizer fell back to 1.0 (see
        ``CostNormalizers.degenerate``)."""
        return self.norm.degenerate

    # -- generation with the paper's retry-until-connected semantics -------
    def generate_valid(self, op, rng: np.random.Generator, n: int,
                       max_tries: int = 500):
        sols, graphs = [], []
        while len(sols) < n:
            for _ in range(max_tries):
                s = op(rng)
                self.n_generated += 1
                g = self.rep.score_graph(s)
                if g.connected:
                    sols.append(s)
                    graphs.append(g)
                    break
            else:  # pragma: no cover - pathological architecture
                raise RuntimeError("could not generate a connected placement")
        return sols, graphs

    def score(self, graphs: list[ScoreGraph]) -> dict:
        return self.score_batch(stack_graphs(graphs))

    def score_batch(self, batch: dict, norms=None, weights=None,
                    fn=None) -> dict:
        """Score pre-stacked (host or device) ScoreGraph arrays.  ``norms``
        / ``weights`` override the evaluator's normalizer / objective
        weight vectors (e.g. per-row vectors in stacked cross-run scoring,
        or a schedule's ramped weights).  ``fn`` substitutes the scorer
        call itself — e.g. a population-sharded wrapper from
        :func:`repro.sharding.population.shard_scorer` — while keeping
        the evaluator's dispatch accounting."""
        self.n_score_calls += 1
        batch = self._with_demand(batch)
        out = (fn or self.scorer)(
            batch,
            self._norm_vec if norms is None else norms,
            self._weights_vec if weights is None else weights)
        return {k: np.asarray(v) for k, v in out.items()}

    def archive_add(self, sols, costs, valid=None) -> None:
        """Fold scored host solutions into the population archive (no-op
        without one); sols are the representation's ``(a, b)`` tuples."""
        if self.archive is None or not len(sols):
            return
        self.archive.add(costs, np.stack([s[0] for s in sols]),
                         np.stack([s[1] for s in sols]), valid=valid)

    def costs_from(self, metrics: dict) -> np.ndarray:
        """Per-placement cost — the scorer's in-jit ``cost`` when present
        (always, for objective-compiled scorers); the float64 host
        evaluation of the objective otherwise (metrics-only terms)."""
        if "cost" in metrics:
            return np.array(metrics["cost"])   # writable copy, not a view
        return objective_cost_host(metrics, self.objective, self.norm)

    def costs(self, graphs: list[ScoreGraph]) -> tuple[np.ndarray, dict]:
        metrics = self.score(graphs)
        return self.costs_from(metrics), metrics

    def topk(self, graphs_or_batch, k: int = 1
             ) -> tuple[np.ndarray, np.ndarray]:
        """In-scorer ranking: score + select the ``k`` cheapest placements
        on device in one fused jitted call.  Accepts a list[ScoreGraph] or
        a stacked batch dict; returns ``(costs [k], indices [k])`` in
        ascending-cost order.  A batch's own ``connected`` flags (the
        hetero Borůvka-component rule, stricter than the scorer's FW
        reachability) and ``overflow`` flags demote the affected rows to
        infinite cost instead of being silently dropped."""
        self.n_score_calls += 1
        if self._ranker is None:
            self._ranker = make_ranker(self.scorer)
        batch, gconn, _, wrow = _request_parts(graphs_or_batch)
        batch = self._with_demand(batch)
        ovf = batch.pop("overflow", None)
        valid = None if gconn is None else np.asarray(gconn)
        if ovf is not None and np.asarray(ovf).any():
            ok = ~np.asarray(ovf)
            valid = ok if valid is None else valid & ok
        c, i = self._ranker(batch, self._norm_vec, k=k, valid=valid,
                            weights=self._weights_vec
                            if wrow is None else wrow)
        return np.asarray(c), np.asarray(i)

    def pipeline(self) -> "DevicePipeline":
        """Cached device-resident generate→graph→score pipeline."""
        if self._pipeline is None:
            self._pipeline = DevicePipeline(self)
        return self._pipeline


def _metrics_row(metrics: dict, i: int) -> dict:
    return {k: float(v[i]) for k, v in metrics.items()}


# ---------------------------------------------------------------------------
# Step-generator execution.  Optimizers yield *scoring requests* — either a
# list of host ScoreGraphs or a pre-stacked (device) batch dict, optionally
# carrying its own ``connected`` flags (the hetero Borůvka-component rule,
# which overrides the scorer's FW reachability) and/or a per-request
# ``weights`` vector (a schedule's ramped objective weights at the run's
# current progress) — and receive ``(costs, metrics)`` back.  _drive runs
# one generator against one Evaluator (the classic entry points below);
# drive_stacked (bottom of this module) runs many in lockstep with their
# requests concatenated into single scorer calls.
# ---------------------------------------------------------------------------

def _request_parts(req):
    """Normalize a scoring request to
    ``(batch, conn_override, size, weights_override)``."""
    wrow = None
    if isinstance(req, tuple):            # weight-tagged host graph list
        req, wrow = req
    if isinstance(req, dict):
        batch = dict(req)
        gconn = batch.pop("connected", None)
        wrow = batch.pop("weights", wrow)
        return batch, gconn, int(batch["W"].shape[0]), wrow
    return stack_graphs(req), None, len(req), wrow


def _tag(req, weights):
    """Attach a schedule's weight vector to a scoring request (no-op when
    ``weights`` is None — the evaluator's static weights then apply)."""
    if weights is None:
        return req
    if isinstance(req, dict):
        return dict(req, weights=weights)
    return (req, weights)


def _sched_progress(done, total, t0: float,
                    budget_s: float | None) -> float:
    """A run's progress fraction for schedule ramps: completed units over
    the unit budget when one is set, elapsed wall fraction otherwise."""
    if total:
        return min(1.0, done / total)
    if budget_s:
        return min(1.0, (time.monotonic() - t0) / budget_s)
    return 0.0


def _score_request(ev: Evaluator, req) -> tuple[np.ndarray, dict]:
    batch, gconn, _, wrow = _request_parts(req)
    metrics = ev.score_batch(batch, weights=wrow)
    if gconn is not None:
        metrics["connected"] = np.asarray(gconn)
    return ev.costs_from(metrics), metrics


def _drive(gen, ev: Evaluator) -> OptResult:
    try:
        req = next(gen)
        while True:
            req = gen.send(_score_request(ev, req))
    except StopIteration as e:
        return e.value


# ---------------------------------------------------------------------------
# Best Random (§II-B1).
# ---------------------------------------------------------------------------

def best_random_steps(ev: Evaluator, rng: np.random.Generator, *,
                      time_budget_s: float | None = None,
                      max_evals: int | None = None,
                      batch: int = 32):
    """Generator form of :func:`best_random` (yields graphs to score).

    With a schedule attached to the evaluator, each batch is scored under
    the ramped weights at the run's progress, and the per-batch winners
    are re-ranked under the *final* (progress 1.0) weights at the end —
    costs from different ramp stages are not comparable, so ``best_*``
    always refers to the final weighting.
    """
    res = OptResult(None, np.inf, {})
    t0 = time.monotonic()
    pool_sols, pool_graphs = [], []
    while True:
        if time_budget_s is not None and time.monotonic() - t0 > time_budget_s:
            break
        if max_evals is not None and res.n_evaluated >= max_evals:
            break
        sols, graphs = ev.generate_valid(ev.rep.random, rng, batch)
        w = ev.sched_weights(_sched_progress(res.n_evaluated, max_evals,
                                             t0, time_budget_s))
        costs, metrics = yield _tag(graphs, w)
        ev.archive_add(sols, costs)
        res.n_evaluated += len(sols)
        i = int(np.argmin(costs))
        if ev.schedule is not None:
            pool_sols.append(sols[i])
            pool_graphs.append(graphs[i])
        if costs[i] < res.best_cost:
            res.best_cost = float(costs[i])
            res.best_sol = sols[i]
            res.best_metrics = _metrics_row(metrics, i)
        res.history.append((time.monotonic() - t0, res.n_evaluated,
                            res.best_cost))
    if ev.schedule is not None and pool_sols:
        costs, metrics = yield _tag(pool_graphs, ev.sched_weights(1.0))
        i = int(np.argmin(costs))
        res.best_cost = float(costs[i])
        res.best_sol = pool_sols[i]
        res.best_metrics = _metrics_row(metrics, i)
        res.history.append((time.monotonic() - t0, res.n_evaluated,
                            res.best_cost))
    res.n_generated = ev.n_generated
    res.normalizers = ev.norm
    if ev.archive is not None:
        res.archive = ev.archive.snapshot()
    return res


def best_random(ev: Evaluator, rng: np.random.Generator, *,
                time_budget_s: float | None = None,
                max_evals: int | None = None,
                batch: int = 32) -> OptResult:
    return _drive(best_random_steps(ev, rng, time_budget_s=time_budget_s,
                                    max_evals=max_evals, batch=batch), ev)


# ---------------------------------------------------------------------------
# Genetic Algorithm (§II-B2; parameters Table III/IV).
# ---------------------------------------------------------------------------

def genetic_algorithm_steps(ev: Evaluator, rng: np.random.Generator, *,
                            population: int, elitism: int, tournament: int,
                            p_mutation: float = 0.5,
                            time_budget_s: float | None = None,
                            max_generations: int | None = None):
    """Generator form of :func:`genetic_algorithm` (yields graphs).

    With a schedule, each generation is scored under the ramped weights at
    ``gen / max_generations`` (selection pressure hardens over the run)
    and the final population is re-ranked under the final weights for
    ``best_*``.
    """
    res = OptResult(None, np.inf, {})
    t0 = time.monotonic()
    sols, graphs = ev.generate_valid(ev.rep.random, rng, population)
    gen = 0
    while True:
        w = ev.sched_weights(_sched_progress(gen, max_generations, t0,
                                             time_budget_s))
        costs, metrics = yield _tag(graphs, w)
        ev.archive_add(sols, costs)
        res.n_evaluated += len(sols)
        order = np.argsort(costs)
        if costs[order[0]] < res.best_cost:
            res.best_cost = float(costs[order[0]])
            res.best_sol = sols[order[0]]
            res.best_metrics = _metrics_row(metrics, int(order[0]))
        res.history.append((time.monotonic() - t0, res.n_evaluated,
                            res.best_cost))
        gen += 1
        if time_budget_s is not None and time.monotonic() - t0 > time_budget_s:
            break
        if max_generations is not None and gen >= max_generations:
            break

        def tournament_pick() -> int:
            idx = rng.choice(len(sols), size=min(tournament, len(sols)),
                             replace=False)
            return int(idx[np.argmin(costs[idx])])

        elite_idx = order[:elitism]
        new_sols = [sols[i] for i in elite_idx]
        new_graphs = [graphs[i] for i in elite_idx]
        while len(new_sols) < population:
            pa, pb = sols[tournament_pick()], sols[tournament_pick()]

            def op(r, pa=pa, pb=pb):
                child = ev.rep.merge(pa, pb, r)
                if r.random() < p_mutation:
                    child = ev.rep.mutate(child, r)
                return child

            cs, cg = ev.generate_valid(op, rng, 1)
            new_sols += cs
            new_graphs += cg
        sols, graphs = new_sols, new_graphs
    if ev.schedule is not None:
        costs, metrics = yield _tag(graphs, ev.sched_weights(1.0))
        i = int(np.argmin(costs))
        res.best_cost = float(costs[i])
        res.best_sol = sols[i]
        res.best_metrics = _metrics_row(metrics, i)
        res.history.append((time.monotonic() - t0, res.n_evaluated,
                            res.best_cost))
    res.n_generated = ev.n_generated
    res.normalizers = ev.norm
    if ev.archive is not None:
        res.archive = ev.archive.snapshot()
    return res


def genetic_algorithm(ev: Evaluator, rng: np.random.Generator, *,
                      population: int, elitism: int, tournament: int,
                      p_mutation: float = 0.5,
                      time_budget_s: float | None = None,
                      max_generations: int | None = None) -> OptResult:
    return _drive(genetic_algorithm_steps(
        ev, rng, population=population, elitism=elitism,
        tournament=tournament, p_mutation=p_mutation,
        time_budget_s=time_budget_s, max_generations=max_generations), ev)


# ---------------------------------------------------------------------------
# Simulated Annealing (§II-B3; adaptive cooling, DESIGN.md §3).
#
# Cooling: after each block of L iterations at temperature T,
#     T <- alpha * T / (1 + beta * T / sigma_block)
# with sigma_block the std-dev of costs seen in the block (Aarts & van
# Laarhoven).  Table III/IV's (T0, L, alpha=1, beta) plug in directly.
# ``chains`` > 1 runs that many independent chains, evaluated as one batch
# per step (beyond-paper batching; chains never interact).
#
# The Metropolis acceptance and the adaptive cooling step are shared with
# the device-resident variant below — sa-batched applies exactly this rule
# to identically distributed proposals.
# ---------------------------------------------------------------------------

def _sa_accept(rng: np.random.Generator, delta: np.ndarray,
               temps: np.ndarray) -> np.ndarray:
    return (delta < 0) | (rng.random(len(delta))
                          < np.exp(-np.maximum(delta, 0)
                                   / np.maximum(temps, 1e-9)))


def _sa_cool(temps: np.ndarray, block_costs: list[np.ndarray],
             alpha: float, beta: float) -> np.ndarray:
    sigma = np.maximum(np.stack(block_costs).std(axis=0), 1e-6)
    return alpha * temps / (1.0 + beta * temps / sigma)


def simulated_annealing_steps(ev: Evaluator, rng: np.random.Generator, *,
                              t0_temp: float, block_len: int,
                              alpha: float = 1.0, beta: float = 5.0,
                              chains: int = 1,
                              time_budget_s: float | None = None,
                              max_iters: int | None = None):
    """Generator form of :func:`simulated_annealing` (yields graphs).

    With a schedule, proposals are accepted under the ramped weights at
    ``it / max_iters`` (chains traverse infeasible regions early, harden
    late) and the final chain states are re-ranked under the final
    weights for ``best_*``.
    """
    res = OptResult(None, np.inf, {})
    tstart = time.monotonic()
    sols, graphs = ev.generate_valid(ev.rep.random, rng, chains)
    costs, metrics = yield _tag(graphs, ev.sched_weights(0.0))
    ev.archive_add(sols, costs)
    res.n_evaluated += chains
    temps = np.full(chains, float(t0_temp))
    block_costs: list[np.ndarray] = []
    i = int(np.argmin(costs))
    res.best_cost = float(costs[i])
    res.best_sol = sols[i]
    res.best_metrics = _metrics_row(metrics, i)
    it = 0
    while True:
        if time_budget_s is not None and \
                time.monotonic() - tstart > time_budget_s:
            break
        if max_iters is not None and it >= max_iters:
            break
        nb_sols, nb_graphs = [], []
        for c in range(chains):
            s, g = ev.generate_valid(
                lambda r, c=c: ev.rep.mutate(sols[c], r), rng, 1)
            nb_sols += s
            nb_graphs += g
        w = ev.sched_weights(_sched_progress(it, max_iters, tstart,
                                             time_budget_s))
        if w is None:
            nb_costs, nb_metrics = yield nb_graphs
        else:
            # Ramped weights shift the incumbents' costs too: score the
            # proposals and the current chain states in one request so the
            # Metropolis delta compares both under the *current* weights.
            all_costs, nb_metrics = yield _tag(nb_graphs + graphs, w)
            nb_costs = all_costs[:chains]
            costs = all_costs[chains:]
            nb_metrics = {k: v[:chains] for k, v in nb_metrics.items()}
        ev.archive_add(nb_sols, nb_costs)
        res.n_evaluated += chains
        accept = _sa_accept(rng, nb_costs - costs, temps)
        for c in range(chains):
            if accept[c]:
                sols[c], graphs[c], costs[c] = \
                    nb_sols[c], nb_graphs[c], nb_costs[c]
        block_costs.append(nb_costs.copy())
        i = int(np.argmin(nb_costs))
        if nb_costs[i] < res.best_cost:
            res.best_cost = float(nb_costs[i])
            res.best_sol = nb_sols[i]
            res.best_metrics = _metrics_row(nb_metrics, i)
        it += 1
        if it % block_len == 0:
            temps = _sa_cool(temps, block_costs, alpha, beta)
            block_costs = []
        res.history.append((time.monotonic() - tstart, res.n_evaluated,
                            res.best_cost))
    if ev.schedule is not None:
        fcosts, fmetrics = yield _tag(graphs, ev.sched_weights(1.0))
        i = int(np.argmin(fcosts))
        res.best_cost = float(fcosts[i])
        res.best_sol = sols[i]
        res.best_metrics = _metrics_row(fmetrics, i)
        res.history.append((time.monotonic() - tstart, res.n_evaluated,
                            res.best_cost))
    res.n_generated = ev.n_generated
    res.normalizers = ev.norm
    if ev.archive is not None:
        res.archive = ev.archive.snapshot()
    return res


def simulated_annealing(ev: Evaluator, rng: np.random.Generator, *,
                        t0_temp: float, block_len: int,
                        alpha: float = 1.0, beta: float = 5.0,
                        chains: int = 1,
                        time_budget_s: float | None = None,
                        max_iters: int | None = None) -> OptResult:
    return _drive(simulated_annealing_steps(
        ev, rng, t0_temp=t0_temp, block_len=block_len, alpha=alpha,
        beta=beta, chains=chains, time_budget_s=time_budget_s,
        max_iters=max_iters), ev)


# ---------------------------------------------------------------------------
# Device-resident pipeline: fused generate→graph→score over stacked arrays.
# ---------------------------------------------------------------------------

class DevicePipeline:
    """Batched produce→graph→score path for both placement families.

    Couples the vectorized representation operators
    (:class:`placement_homog.HomogBatch` / :class:`placement_hetero.
    HeteroBatch`), the batched ScoreGraph assembly
    (:class:`topology.HomogGraphBatch` with masked selection over the static
    grid adjacency, or :class:`topology.HeteroGraphBatch` with the batched
    Borůvka MST + augmentation over padded candidate edges) and the
    Evaluator's cached jitted scorer.  Each ``sample_*`` call produces a
    whole batch; invalid individuals are masked and resampled in batch
    (valid slots are kept) — the device equivalent of the paper's
    retry-until-connected loop.

    Homogeneous grids run generate→graph fully on device.  The
    heterogeneous corner placement is inherently sequential per individual
    and stays host-side, but vectorized across the population
    (``HeteroBatch.geometry_batch``); operators and link inference run on
    device.  Connectivity masking uses the scorer's FW-derived
    ``connected`` for grids and the Borůvka-component flag (identical to
    the fixed host union-find rule) for hetero archs.

    The jitted produce→graph stages only depend on the arch statics
    (grid dims, mutation mode), so — like the jitted scorer behind
    ``api.get_scorer`` — they are cached module-wide per arch and shared by
    every Evaluator over the same arch instead of re-traced per run.  The
    cache is a bounded LRU (long-lived services must not leak compiled
    stages); live pipelines hold their own stage references, so eviction
    only drops the shared cache entry.
    """

    _STAGE_CACHE: LRUCache = LRUCache(32)

    @classmethod
    def clear_stage_cache(cls) -> None:
        """Drop cached jitted stages + their static W matrices (mirrors
        ``api.clear_scorer_cache`` for the produce→graph side)."""
        cls._STAGE_CACHE.clear()

    @classmethod
    def _stages(cls, rep):
        if isinstance(rep, HomogRep):
            # The allowed-cell mask shapes every stage (generation,
            # mutation, area); two reps differing only in mask must not
            # share compiled stages.
            mask_key = (None if rep.allowed is None
                        else rep.allowed.tobytes())
            key = ("homog", rep.arch, rep.R, rep.C, rep.mutation_mode,
                   mask_key)
        elif isinstance(rep, HeteroRep):
            key = ("hetero", rep.arch, rep.mutation_mode)
        elif hasattr(rep, "device_stage_key") and hasattr(rep, "graph_batch"):
            # Pluggable grid-like reps (e.g. repro.arch3d.Homog3DRep):
            # the rep names its own cache key — tier latency values are
            # runtime operands and must NOT appear in it.
            key = rep.device_stage_key()
        else:
            raise TypeError(
                "device-resident batched optimizers require a HomogRep, "
                "HeteroRep, or a rep exposing device_stage_key()/"
                f"graph_batch()/batch_ops(), got {type(rep)!r}")
        if key in cls._STAGE_CACHE:
            return cls._STAGE_CACHE[key]
        ops = rep.batch_ops()
        if not isinstance(rep, (HomogRep, HeteroRep)):
            gb = rep.graph_batch()

            # Stage closures take the tier latency vector as a trailing
            # operand (DevicePipeline.__init__ binds the rep's current
            # values), so reps differing only in tsv/backbone factors
            # share these compiled stages — zero retraces.
            @functools.partial(jax.jit, static_argnames=("n",))
            def _gen(key, n, tiers):
                t, r = ops.random_batch(key, n)
                return t, r, gb.build(t, r, tiers)

            @jax.jit
            def _mut(key, t, r, tiers):
                nt, nr = ops.mutate_batch(key, t, r)
                return nt, nr, gb.build(nt, nr, tiers)

            @jax.jit
            def _child(key, pat, par, pbt, pbr, p_mut, tiers):
                k1, k2, k3 = jax.random.split(key, 3)
                t, r = ops.merge_batch(k1, pat, par, pbt, pbr)
                mt, mr = ops.mutate_batch(k2, t, r)
                m = jax.random.bernoulli(
                    k3, p_mut, (t.shape[0],)).reshape(
                    (-1,) + (1,) * (t.ndim - 1))
                t = jnp.where(m, mt, t)
                r = jnp.where(m, mr, r)
                return t, r, gb.build(t, r, tiers)

            _rebuild = jax.jit(gb.build)
        elif isinstance(rep, HomogRep):
            gb = HomogGraphBatch(rep.arch, rep.R, rep.C, area=rep.area)

            @functools.partial(jax.jit, static_argnames=("n",))
            def _gen(key, n):
                t, r = ops.random_batch(key, n)
                return t, r, gb.build(t, r)

            @jax.jit
            def _mut(key, t, r):
                nt, nr = ops.mutate_batch(key, t, r)
                return nt, nr, gb.build(nt, nr)

            @jax.jit
            def _child(key, pat, par, pbt, pbr, p_mut):
                k1, k2, k3 = jax.random.split(key, 3)
                t, r = ops.merge_batch(k1, pat, par, pbt, pbr)
                mt, mr = ops.mutate_batch(k2, t, r)
                m = jax.random.bernoulli(
                    k3, p_mut, (t.shape[0],))[:, None, None]
                t = jnp.where(m, mt, t)
                r = jnp.where(m, mr, r)
                return t, r, gb.build(t, r)

            _rebuild = jax.jit(gb.build)
        else:
            gb = HeteroGraphBatch(rep.arch)
            _rand_op = jax.jit(ops.random_batch, static_argnums=1)
            _mut_op = jax.jit(ops.mutate_batch)

            @jax.jit
            def _child_op(key, oa, ra, ob, rb, p_mut):
                k1, k2, k3 = jax.random.split(key, 3)
                o, r = ops.merge_batch(k1, oa, ra, ob, rb)
                mo, mr = ops.mutate_batch(k2, o, r)
                m = jax.random.bernoulli(k3, p_mut, (o.shape[0],))[:, None]
                return jnp.where(m, mo, o), jnp.where(m, mr, r)

            _build = jax.jit(gb.build)

            def _graph(o, r):
                # Host-side stage: corner placement is sequential per
                # individual; vectorized across the population.
                on, rn = np.asarray(o), np.asarray(r)
                ppos, area = ops.geometry_batch(on, rn)
                batch = dict(_build(jnp.asarray(ppos), jnp.asarray(area)))
                ovf = np.asarray(batch.pop("overflow"))
                if ovf.any():  # pragma: no cover - needs > Ecap candidates
                    # Candidate set exceeded the device working set: take
                    # the exact host path for the affected rows.
                    batch = {k: np.array(v) for k, v in batch.items()}
                    for b in np.nonzero(ovf)[0]:
                        g = rep.score_graph((on[b], rn[b]))
                        batch["W"][b] = g.W
                        batch["edges"][b] = g.edges
                        batch["edge_mask"][b] = g.edge_mask
                        batch["edge_len"][b] = g.edge_len
                        batch["area"][b] = g.area
                        batch["connected"][b] = g.connected
                return batch

            def _gen(key, n):
                o, r = _rand_op(key, n)
                return o, r, _graph(o, r)

            def _mut(key, o, r):
                no, nr = _mut_op(key, o, r)
                return no, nr, _graph(no, nr)

            def _child(key, oa, ra, ob, rb, p_mut):
                o, r = _child_op(key, oa, ra, ob, rb, p_mut)
                return o, r, _graph(o, r)

            _rebuild = _graph

        cls._STAGE_CACHE[key] = (ops, gb, _gen, _mut, _child, _rebuild)
        return cls._STAGE_CACHE[key]

    def __init__(self, ev: Evaluator):
        self.ev = ev
        (self.ops, self.graphs, self._gen, self._mut,
         self._child, self._rebuild) = self._stages(ev.rep)
        tiers = getattr(ev.rep, "tier_values", None)
        if tiers is not None:
            # Bind this rep's tier latency vector as the stages' trailing
            # runtime operand (shared compiled stages across tier values).
            tv = jnp.asarray(np.asarray(tiers, np.float32))
            _gen, _mut, _child, _reb = (self._gen, self._mut, self._child,
                                        self._rebuild)
            self._gen = lambda key, n: _gen(key, n, tv)
            self._mut = lambda key, t, r: _mut(key, t, r, tv)
            self._child = lambda key, pat, par, pbt, pbr, p: _child(
                key, pat, par, pbt, pbr, p, tv)
            self._rebuild = lambda t, r: _reb(t, r, tv)

    def rebuild(self, t, r) -> dict:
        """Graph batch for existing solutions (no RNG): re-scoring a
        population under different (e.g. schedule-final) weights."""
        return dict(self._rebuild(t, r))

    def _key(self, rng: np.random.Generator):
        return jax.random.PRNGKey(int(rng.integers(2 ** 31 - 1)))

    def _until_connected_steps(self, rng, make, n, max_rounds: int = 500,
                               weights=None):
        """Generator: run ``make`` until every slot holds a connected
        placement, yielding each produced batch as a scoring request and
        receiving ``(costs, metrics)`` back.

        ``make(key, idx)`` produces one candidate per entry of ``idx``
        (slot indices; repeats allowed).  The first round fills every
        slot; later rounds only produce candidates for the still-invalid
        slots — padded to a power of two so the retrace set of the jitted
        stages/scorer stays bounded — and each slot takes its first
        connected candidate (per-slot rejection sampling, the same
        conditional distribution as the host retry loop).

        A graph stage may put its own ``connected`` into the batch dict
        (the hetero path's Borůvka-component flag, which matches the host
        union-find rule exactly); the request scorer
        (:func:`_score_request` or :func:`drive_stacked`) then lets it
        override the scorer's FW-reachability output.

        ``weights`` (a schedule's runtime weight vector) tags every
        yielded request, so ramped costs apply to resample rounds too.

        Returns ``(t, r, metrics, costs)`` for the filled slots.
        """
        t, r, batch = make(self._key(rng), np.arange(n))
        costs, metrics = yield _tag(batch, weights)
        costs = np.array(costs)
        metrics = {k: np.array(v) for k, v in metrics.items()}
        self.ev.n_generated += n
        conn = metrics["connected"].astype(bool)
        if self.ev.archive is not None:
            self.ev.archive.add(costs, t, r, valid=conn)
        for _ in range(max_rounds):
            bad = np.nonzero(~conn)[0]
            if not len(bad):
                return t, r, metrics, costs
            size = 1 << (len(bad) - 1).bit_length()
            size = min(max(size, min(8, n)), n)
            idx = bad[np.arange(size) % len(bad)]
            t2, r2, batch2 = make(self._key(rng), idx)
            c2, m2 = yield _tag(batch2, weights)
            self.ev.n_generated += size
            conn2 = np.asarray(m2["connected"]).astype(bool)
            if self.ev.archive is not None:
                self.ev.archive.add(np.asarray(c2), t2, r2, valid=conn2)
            slots, rows = [], []
            for i in range(size):
                s = int(idx[i])
                if conn2[i] and not conn[s]:
                    conn[s] = True
                    slots.append(s)
                    rows.append(i)
            if slots:
                sl, rw = np.array(slots), np.array(rows)
                t = t.at[jnp.asarray(sl)].set(t2[jnp.asarray(rw)])
                r = r.at[jnp.asarray(sl)].set(r2[jnp.asarray(rw)])
                for k, v in metrics.items():
                    v[sl] = np.asarray(m2[k])[rw]
                costs[sl] = np.asarray(c2)[rw]
        raise RuntimeError(  # pragma: no cover - pathological architecture
            "could not batch-generate connected placements")

    # -- generator forms (used by the *_batched_steps optimizers) -----------
    def sample_random_steps(self, rng, n: int, weights=None):
        return self._until_connected_steps(
            rng, lambda k, idx: self._gen(k, len(idx)), n, weights=weights)

    def sample_mutants_steps(self, rng, t, r, weights=None):
        def make(k, idx):
            i = jnp.asarray(idx)
            return self._mut(k, t[i], r[i])
        return self._until_connected_steps(rng, make, t.shape[0],
                                           weights=weights)

    def sample_children_steps(self, rng, pat, par, pbt, pbr,
                              p_mutation: float, weights=None):
        def make(k, idx):
            i = jnp.asarray(idx)
            return self._child(k, pat[i], par[i], pbt[i], pbr[i],
                               p_mutation)
        return self._until_connected_steps(rng, make, pat.shape[0],
                                           weights=weights)

    # -- direct batched counterparts of the representation operators --------
    def _run(self, gen):
        try:
            req = next(gen)
            while True:
                req = gen.send(_score_request(self.ev, req))
        except StopIteration as e:
            t, r, metrics, _ = e.value
            return t, r, metrics

    def sample_random(self, rng, n: int):
        return self._run(self.sample_random_steps(rng, n))

    def sample_mutants(self, rng, t, r):
        return self._run(self.sample_mutants_steps(rng, t, r))

    def sample_children(self, rng, pat, par, pbt, pbr, p_mutation: float):
        return self._run(self.sample_children_steps(rng, pat, par, pbt, pbr,
                                                    p_mutation))


def _sol_at(t, r, i: int):
    """Device batch row -> host Sol (matches the host operators' dtypes)."""
    return (np.asarray(t[i]), np.asarray(r[i]))


def best_random_batched_steps(ev: Evaluator, rng: np.random.Generator, *,
                              time_budget_s: float | None = None,
                              max_evals: int | None = None,
                              batch: int = 32):
    """BR over the device pipeline: one fused request per batch.  Under a
    schedule, batches score with ramped weights and the per-batch winners
    are re-ranked under the final weights (see ``best_random_steps``)."""
    pipe = ev.pipeline()
    res = OptResult(None, np.inf, {})
    t0 = time.monotonic()
    pool_t, pool_r = [], []
    while True:
        if time_budget_s is not None and time.monotonic() - t0 > time_budget_s:
            break
        if max_evals is not None and res.n_evaluated >= max_evals:
            break
        w = ev.sched_weights(_sched_progress(res.n_evaluated, max_evals,
                                             t0, time_budget_s))
        t, r, metrics, costs = yield from pipe.sample_random_steps(
            rng, batch, weights=w)
        res.n_evaluated += batch
        i = int(np.argmin(costs))
        if ev.schedule is not None:
            pool_t.append(t[i])
            pool_r.append(r[i])
        if costs[i] < res.best_cost:
            res.best_cost = float(costs[i])
            res.best_sol = _sol_at(t, r, i)
            res.best_metrics = _metrics_row(metrics, i)
        res.history.append((time.monotonic() - t0, res.n_evaluated,
                            res.best_cost))
    if ev.schedule is not None and pool_t:
        pt, pr = jnp.stack(pool_t), jnp.stack(pool_r)
        costs, metrics = yield _tag(pipe.rebuild(pt, pr),
                                    ev.sched_weights(1.0))
        i = int(np.argmin(costs))
        res.best_cost = float(costs[i])
        res.best_sol = _sol_at(pt, pr, i)
        res.best_metrics = _metrics_row(metrics, i)
        res.history.append((time.monotonic() - t0, res.n_evaluated,
                            res.best_cost))
    res.n_generated = ev.n_generated
    res.normalizers = ev.norm
    if ev.archive is not None:
        res.archive = ev.archive.snapshot()
    return res


def best_random_batched(ev: Evaluator, rng: np.random.Generator, *,
                        time_budget_s: float | None = None,
                        max_evals: int | None = None,
                        batch: int = 32) -> OptResult:
    """BR over the device pipeline: one fused call per batch."""
    return _drive(best_random_batched_steps(
        ev, rng, time_budget_s=time_budget_s, max_evals=max_evals,
        batch=batch), ev)


def genetic_algorithm_batched_steps(ev: Evaluator,
                                    rng: np.random.Generator, *,
                                    population: int, elitism: int,
                                    tournament: int,
                                    p_mutation: float = 0.5,
                                    time_budget_s: float | None = None,
                                    max_generations: int | None = None):
    """Generator form of :func:`genetic_algorithm_batched`.  Under a
    schedule, children score with the ramped weights at their generation,
    the retained population is re-scored under the current weights each
    generation (the host GA re-yields its whole population per
    generation, so elite costs never go stale against the ramp), and the
    final population is re-ranked under the final weights."""
    pipe = ev.pipeline()
    res = OptResult(None, np.inf, {})
    t0 = time.monotonic()
    t, r, metrics, costs = yield from pipe.sample_random_steps(
        rng, population, weights=ev.sched_weights(0.0))
    res.n_evaluated += population
    gen = 0
    while True:
        if ev.schedule is not None and gen > 0:
            # Unify the mixed-progress costs (elites were scored under an
            # earlier, weaker ramp stage) so selection pressure hardens
            # for the whole population, not just the fresh children.
            w_now = ev.sched_weights(_sched_progress(
                gen, max_generations, t0, time_budget_s))
            costs, metrics = yield _tag(pipe.rebuild(t, r), w_now)
        order = np.argsort(costs)
        if costs[order[0]] < res.best_cost:
            i = int(order[0])
            res.best_cost = float(costs[i])
            res.best_sol = _sol_at(t, r, i)
            res.best_metrics = _metrics_row(metrics, i)
        res.history.append((time.monotonic() - t0, res.n_evaluated,
                            res.best_cost))
        gen += 1
        if time_budget_s is not None and time.monotonic() - t0 > time_budget_s:
            break
        if max_generations is not None and gen >= max_generations:
            break

        def tournament_pick() -> int:
            idx = rng.choice(population, size=min(tournament, population),
                             replace=False)
            return int(idx[np.argmin(costs[idx])])

        n_child = population - elitism
        pa = np.array([tournament_pick() for _ in range(n_child)])
        pb = np.array([tournament_pick() for _ in range(n_child)])
        w = ev.sched_weights(_sched_progress(gen, max_generations, t0,
                                             time_budget_s))
        ct, cr, cm, ccosts = yield from pipe.sample_children_steps(
            rng, t[jnp.asarray(pa)], r[jnp.asarray(pa)],
            t[jnp.asarray(pb)], r[jnp.asarray(pb)], p_mutation, weights=w)
        res.n_evaluated += n_child
        elite = order[:elitism]
        t = jnp.concatenate([t[jnp.asarray(elite)], ct])
        r = jnp.concatenate([r[jnp.asarray(elite)], cr])
        metrics = {k: np.concatenate([v[elite], cm[k]])
                   for k, v in metrics.items()}
        costs = np.concatenate([costs[elite], ccosts])
    if ev.schedule is not None:
        costs, metrics = yield _tag(pipe.rebuild(t, r),
                                    ev.sched_weights(1.0))
        i = int(np.argmin(costs))
        res.best_cost = float(costs[i])
        res.best_sol = _sol_at(t, r, i)
        res.best_metrics = _metrics_row(metrics, i)
        res.history.append((time.monotonic() - t0, res.n_evaluated,
                            res.best_cost))
    res.n_generated = ev.n_generated
    res.normalizers = ev.norm
    if ev.archive is not None:
        res.archive = ev.archive.snapshot()
    return res


def genetic_algorithm_batched(ev: Evaluator, rng: np.random.Generator, *,
                              population: int, elitism: int, tournament: int,
                              p_mutation: float = 0.5,
                              time_budget_s: float | None = None,
                              max_generations: int | None = None
                              ) -> OptResult:
    """GA whose whole generation (merge + mutate + graph + score) is one
    fused device call; selection stays host-side on the cost vector.
    Individuals are scored once, at creation (the host loop re-scores the
    full population every generation), so ``n_evaluated`` counts scored
    placements: ``population + generations * (population - elitism)``."""
    return _drive(genetic_algorithm_batched_steps(
        ev, rng, population=population, elitism=elitism,
        tournament=tournament, p_mutation=p_mutation,
        time_budget_s=time_budget_s, max_generations=max_generations), ev)


def simulated_annealing_batched_steps(ev: Evaluator,
                                      rng: np.random.Generator, *,
                                      t0_temp: float, block_len: int,
                                      alpha: float = 1.0, beta: float = 5.0,
                                      chains: int = 1,
                                      time_budget_s: float | None = None,
                                      max_iters: int | None = None):
    """Generator form of :func:`simulated_annealing_batched`.  Under a
    schedule, proposals (and, for exact Metropolis deltas, the re-scored
    incumbents) use the ramped weights at the current iteration; the final
    chain states are re-ranked under the final weights."""
    pipe = ev.pipeline()
    res = OptResult(None, np.inf, {})
    tstart = time.monotonic()
    t, r, metrics, costs = yield from pipe.sample_random_steps(
        rng, chains, weights=ev.sched_weights(0.0))
    res.n_evaluated += chains
    temps = np.full(chains, float(t0_temp))
    block_costs: list[np.ndarray] = []
    i = int(np.argmin(costs))
    res.best_cost = float(costs[i])
    res.best_sol = _sol_at(t, r, i)
    res.best_metrics = _metrics_row(metrics, i)
    it = 0
    while True:
        if time_budget_s is not None and \
                time.monotonic() - tstart > time_budget_s:
            break
        if max_iters is not None and it >= max_iters:
            break
        w = ev.sched_weights(_sched_progress(it, max_iters, tstart,
                                             time_budget_s))
        nt, nr, nm, ncosts = yield from pipe.sample_mutants_steps(
            rng, t, r, weights=w)
        if w is not None:
            # Incumbent costs are stale under ramped weights: re-score the
            # chain states so the Metropolis delta is exact at progress t.
            costs, _ = yield _tag(pipe.rebuild(t, r), w)
        res.n_evaluated += chains
        accept = _sa_accept(rng, ncosts - costs, temps)
        acc = jnp.asarray(accept).reshape((-1,) + (1,) * (t.ndim - 1))
        t = jnp.where(acc, nt, t)
        r = jnp.where(acc, nr, r)
        costs = np.where(accept, ncosts, costs)
        block_costs.append(ncosts.copy())
        i = int(np.argmin(ncosts))
        if ncosts[i] < res.best_cost:
            res.best_cost = float(ncosts[i])
            res.best_sol = _sol_at(nt, nr, i)
            res.best_metrics = _metrics_row(nm, i)
        it += 1
        if it % block_len == 0:
            temps = _sa_cool(temps, block_costs, alpha, beta)
            block_costs = []
        res.history.append((time.monotonic() - tstart, res.n_evaluated,
                            res.best_cost))
    if ev.schedule is not None:
        fcosts, fmetrics = yield _tag(pipe.rebuild(t, r),
                                      ev.sched_weights(1.0))
        i = int(np.argmin(fcosts))
        res.best_cost = float(fcosts[i])
        res.best_sol = _sol_at(t, r, i)
        res.best_metrics = _metrics_row(fmetrics, i)
        res.history.append((time.monotonic() - tstart, res.n_evaluated,
                            res.best_cost))
    res.n_generated = ev.n_generated
    res.normalizers = ev.norm
    if ev.archive is not None:
        res.archive = ev.archive.snapshot()
    return res


def simulated_annealing_batched(ev: Evaluator, rng: np.random.Generator, *,
                                t0_temp: float, block_len: int,
                                alpha: float = 1.0, beta: float = 5.0,
                                chains: int = 1,
                                time_budget_s: float | None = None,
                                max_iters: int | None = None) -> OptResult:
    """SA whose chain-step (mutate all chains + graph + score) is one fused
    device call; Metropolis acceptance and adaptive cooling are host-side
    (identical to the host loop's rule on identically distributed
    proposals)."""
    return _drive(simulated_annealing_batched_steps(
        ev, rng, t0_temp=t0_temp, block_len=block_len, alpha=alpha,
        beta=beta, chains=chains, time_budget_s=time_budget_s,
        max_iters=max_iters), ev)


# ---------------------------------------------------------------------------
# Stacked execution of step generators (run_sweep cross-config batching).
# ---------------------------------------------------------------------------

def score_stacked(entries: list, *, score_fn=None
                  ) -> tuple[list, float]:
    """One stacked scoring round: concatenate several runs' pending
    scoring requests into a single batched scorer call with per-row
    normalizer and weight vectors, and split the results back.

    ``entries`` is a list of ``(parts, evaluator)`` pairs where ``parts``
    is the :func:`_request_parts` normalization of one scoring request;
    all evaluators must share one compiled scorer (same layout / chunk /
    backend / objective structure).  ``score_fn`` substitutes the scorer
    call — e.g. a population-sharded wrapper from
    :func:`repro.sharding.population.shard_scorer` — for the whole
    stacked batch.  Returns ``(per_entry, t_score)`` with ``per_entry[i]
    = (costs, metrics)`` for entry ``i`` (per-request ``connected``
    overrides restored, costs via each run's own evaluator).

    This is the preemptible core both :func:`drive_stacked` (whole sweeps
    run to completion) and the design service's tick loop
    (``repro.serve.design`` — requests interleaved at arbitrary
    generations) are built on.
    """
    sizes = [p[2] for p, _ in entries]
    keys = sorted(entries[0][0][0])
    for j, (p, _) in enumerate(entries[1:], start=1):
        if sorted(p[0]) != keys:    # fail loudly on heterogeneous requests
            raise ValueError(
                f"stacked scoring requests disagree on batch keys: entry "
                f"0 has {keys}, entry {j} has {sorted(p[0])}")
    cat = {k: jnp.concatenate([jnp.asarray(p[0][k]) for p, _ in entries])
           for k in keys}
    # Per-row workload demand: entries whose evaluator carries a trace-lat
    # workload contribute their own demand rows, so requests over
    # *different* workloads stack into one dispatch of the same compiled
    # scorer.  Mixing demand-bearing and demand-free entries would feed
    # one term structure two different batch layouts — fail loudly.
    dvecs = [ev.demand_vec for _, ev in entries]
    if any(d is not None for d in dvecs):
        if any(d is None for d in dvecs):
            raise ValueError(
                "stacked scoring requests disagree on workloads: some "
                "evaluators carry a 'trace-lat' workload and some do not")
        cat["_demand"] = np.concatenate(
            [np.broadcast_to(d, (sz, d.shape[0]))
             for d, sz in zip(dvecs, sizes)])
    norms = np.concatenate(
        [np.broadcast_to(ev.norm_vec, (sz, NORM_DIM))
         for (p, ev), sz in zip(entries, sizes)])
    weights = np.concatenate(
        [np.broadcast_to(np.asarray(
            ev.weights_vec if p[3] is None else p[3], np.float32),
            (sz, ev.weights_vec.shape[0]))
         for (p, ev), sz in zip(entries, sizes)])
    ts = time.monotonic()
    metrics = entries[0][1].score_batch(cat, norms=norms, weights=weights,
                                        fn=score_fn)
    t_score = time.monotonic() - ts
    out = []
    off = 0
    for (p, ev), sz in zip(entries, sizes):
        mi = {k: v[off:off + sz] for k, v in metrics.items()}
        if p[1] is not None:                   # per-request conn override
            mi["connected"] = np.asarray(p[1])
        off += sz
        out.append((ev.costs_from(mi), mi))
    return out, t_score


def drive_stacked(items: list, *, score_fn=None
                  ) -> tuple[list, list[int], list[float]]:
    """Run several step-generators in lockstep, stacking each round's
    scoring requests into one batched scorer call.

    ``items`` is a list of ``(generator, evaluator)`` pairs whose
    evaluators share one jitted scorer (same layout/chunk/backend and
    objective *structure* — objectives differing only in weights share).
    Each round collects the pending scoring requests of every live
    generator — host graph lists and device batch dicts mix freely —
    scores their concatenation once with *per-row normalizer and weight
    vectors* (each row carries its own run's norms and objective weights
    — a Pareto grid point's scalarization, or a schedule's ramped weights
    from the request tag — so the in-scorer ``cost`` is exact for every
    run), splits the metrics back (restoring per-request ``connected``
    overrides), and resumes the generators.  Results are bit-for-bit
    identical to driving each generator alone (the scorer is vmapped
    elementwise), with ~k fewer dispatches.  ``score_fn`` routes every
    stacked call through a substitute scorer (see :func:`score_stacked`),
    e.g. the population-axis ``shard_map`` wrapper.

    Returns ``(results, n_generated, seconds)`` aligned with ``items`` —
    ``n_generated[i]`` is the number of placements generated by run ``i``
    (attributed exactly even though evaluators may be shared, because only
    one generator runs between two of its scoring requests), and
    ``seconds[i]`` is run ``i``'s attributed wall time: its own generator
    resumes plus each stacked scoring call split proportionally to its
    share of that call's batch — so per-record evals/s stays meaningful.
    """
    n = len(items)
    results: list = [None] * n
    gen_counts = [0] * n
    secs = [0.0] * n
    reqs: dict[int, tuple] = {}

    def _resume(i, send=None):
        gen, ev = items[i]
        g0 = ev.n_generated
        ta = time.monotonic()
        try:
            req = next(gen) if send is None else gen.send(send)
            reqs[i] = _request_parts(req)
        except StopIteration as e:
            results[i] = e.value
        secs[i] += time.monotonic() - ta
        gen_counts[i] += ev.n_generated - g0

    for i in range(n):
        _resume(i)
    while reqs:
        order = sorted(reqs)
        parts = {i: reqs[i] for i in order}
        reqs = {}
        sizes = [parts[i][2] for i in order]
        per_entry, t_score = score_stacked(
            [(parts[i], items[i][1]) for i in order], score_fn=score_fn)
        total = max(sum(sizes), 1)
        for i, sz, (ci, mi) in zip(order, sizes, per_entry):
            secs[i] += t_score * (sz / total)
            _resume(i, (ci, mi))
    return results, gen_counts, secs


ALGORITHMS = {
    "br": best_random,
    "ga": genetic_algorithm,
    "sa": simulated_annealing,
    "br-batched": best_random_batched,
    "ga-batched": genetic_algorithm_batched,
    "sa-batched": simulated_annealing_batched,
}
