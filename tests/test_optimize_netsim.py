"""Optimizers (§II-B) + packet simulator + traces + bridge."""
import numpy as np
import pytest

from repro.core.api import (Budget, ExperimentConfig, baseline_cost,
                            best_by_algorithm, run_experiment, summarize)
from repro.core.baseline import MeshBaseline
from repro.core.bridge import (TrafficSignature, codesign,
                               weights_from_signature)
from repro.core.chiplets import paper_arch
from repro.core.netsim import ChipletNet, NetSim, Packet, synthetic_packets
from repro.core.optimize import (Evaluator, best_random, genetic_algorithm,
                                 simulated_annealing)
from repro.core.placement_homog import HomogRep
from repro.core.traces import TraceRegion, generate_trace, trace_stats


@pytest.fixture(scope="module")
def ev():
    arch = paper_arch("homog32", "baseline")
    rep = HomogRep(arch, R=8, C=5)
    return Evaluator(rep, arch, rng=np.random.default_rng(0),
                     norm_samples=12, chunk=4), arch


def test_br_ga_sa_improve_over_single_random(ev):
    ev_, arch = ev
    rng = np.random.default_rng(1)
    sols, graphs = ev_.generate_valid(ev_.rep.random, rng, 1)
    c0, _ = ev_.costs(graphs)
    br = best_random(ev_, np.random.default_rng(2), max_evals=24, batch=8)
    ga = genetic_algorithm(ev_, np.random.default_rng(3), population=8,
                           elitism=2, tournament=3, max_generations=3)
    sa = simulated_annealing(ev_, np.random.default_rng(4), t0_temp=40.0,
                             block_len=10, chains=4, max_iters=8)
    for res in (br, ga, sa):
        assert res.best_cost <= float(c0[0]) * 1.05
        assert np.isfinite(res.best_cost)
        assert res.best_sol is not None
    # GA keeps population-many evaluations per generation
    assert ga.n_evaluated >= 24


def test_runner_and_baseline():
    cfg = ExperimentConfig("homog32", "baseline", algorithms=("br",),
                           repetitions=1, budget=Budget(evals=12),
                           norm_samples=8)
    recs = run_experiment(cfg)
    rows = summarize(recs)
    assert rows and rows[0]["n_evaluated"] >= 12
    bc, bm = baseline_cost(cfg)
    assert np.isfinite(bc)
    best = best_by_algorithm(recs)
    assert "br" in best


# ---------------------------------------------------------------------------
# netsim
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def net():
    arch = paper_arch("homog32", "baseline")
    mb = MeshBaseline(arch)
    g, geo, links = mb.build()
    return ChipletNet.from_links(arch, geo, links), arch


def test_single_packet_latency_analytic(net):
    n, arch = net
    sim = NetSim(n, arch)
    # one packet, no contention: latency = hops*(d2d+pipeline) + relays
    src, dst = 8, 9
    path = n.path(src, dst)
    hops = len(path) - 1
    res = sim.run([Packet(0, src, dst, flits=9, cycle=0)])
    expect = hops * sim.hop_lat + (hops - 1) * sim.relay_lat + 9 - 1
    assert res.avg_latency == pytest.approx(expect)


def test_congestion_raises_latency(net):
    n, arch = net
    sim = NetSim(n, arch)
    rng = np.random.default_rng(0)
    lo = sim.run(synthetic_packets(n, "c2m", 0.002, 3000, rng))
    rng = np.random.default_rng(0)
    hi = sim.run(synthetic_packets(n, "c2m", 0.2, 3000, rng))
    assert hi.avg_latency > lo.avg_latency


def test_dependencies_enforced(net):
    n, arch = net
    sim = NetSim(n, arch)
    pkts = [Packet(0, 0, 5, 1, cycle=100),
            Packet(1, 5, 0, 9, cycle=0, deps=(0,))]
    res = sim.run(pkts, mode="authentic")
    inj0, fin0 = res.times[0]
    inj1, _ = res.times[1]
    assert inj0 == 100
    assert inj1 >= fin0


def test_idealized_faster_injection(net):
    n, arch = net
    pkts_a = generate_trace(n, (TraceRegion(400, 50_000),), seed=2)
    sim = NetSim(n, arch)
    res_a = sim.run(pkts_a, mode="authentic")
    pkts_i = generate_trace(n, (TraceRegion(400, 50_000),), seed=2)
    res_i = sim.run(pkts_i, mode="idealized")
    assert res_i.makespan <= res_a.makespan


def test_trace_mix_matches_paper(net):
    n, arch = net
    pkts = generate_trace(n, (TraceRegion(4000, 40_000),), seed=0)
    st = trace_stats(pkts, n)
    # §V-B measured mix: C2C 0-5%, C2M(+M2C) 80-95%, M2I(+I2M) 3-16%
    assert st["c2c"] <= 0.05
    assert 0.70 <= st["c2m"] + st["m2c"] <= 0.97
    assert 0.02 <= st["m2i"] + st["i2m"] <= 0.20


# ---------------------------------------------------------------------------
# bridge
# ---------------------------------------------------------------------------

def test_weights_from_signature_shapes():
    sig = TrafficSignature("x", "train_4k", "train", t_comp=1.0, t_mem=3.0,
                           t_coll=1.0, io_share=0.1)
    w = weights_from_signature(sig)
    assert len(w["w_lat"]) == 4 and len(w["w_thr"]) == 4
    # memory-heavy workload: c2m throughput weight dominates
    assert w["w_thr"][1] == max(w["w_thr"])
    total = sum(w["w_lat"]) + sum(w["w_thr"]) + w["w_area"]
    assert total == pytest.approx(10.0, rel=0.05)


def test_codesign_beats_baseline_smoke():
    sig = TrafficSignature("tiny", "decode_32k", "decode", t_comp=0.1,
                           t_mem=2.0, t_coll=0.5, io_share=0.1)
    out = codesign(sig, max_evals=40, norm_samples=12)
    assert np.isfinite(out["placeit_cost"])
    assert out["placeit_cost"] <= out["baseline_cost"] * 1.2
    assert out["package"]["n_memory"] >= 2
