"""RG-LRU diagonal linear-recurrence Pallas kernel (recurrentgemma).

h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * x_t   (element-wise over channels).

Same VMEM-resident-state pattern as the selective scan, but the state is a
single (bd,) lane vector, making this purely bandwidth-bound: one HBM pass
over a, x and h.  Channel-blocked grid; sequence walked inside the kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from . import _compat


def _rglru_kernel(a_ref, x_ref, h0_ref, y_ref, hf_ref, h_scr, *, S: int):
    # Blocks: a/x/y (1, bd, S); h0/hf (1, bd); scratch (1, bd) fp32.
    h_scr[...] = h0_ref[...].astype(jnp.float32)

    def step(t, _):
        at = a_ref[0, :, t].astype(jnp.float32)
        xt = x_ref[0, :, t].astype(jnp.float32)
        bt = jnp.sqrt(jnp.maximum(1.0 - at * at, 0.0)) * xt
        h = at * h_scr[0] + bt
        h_scr[0] = h
        y_ref[0, :, t] = h.astype(y_ref.dtype)
        return 0

    jax.lax.fori_loop(0, S, step, 0)
    hf_ref[...] = h_scr[...].astype(hf_ref.dtype)


def rglru_scan_pallas(x: jnp.ndarray, a: jnp.ndarray,
                      h0: jnp.ndarray | None = None, *,
                      bd: int = 256, interpret: bool = True
                      ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x, a: [B, S, D] -> (h [B, S, D], h_final [B, D]).

    Matches ``ref.rglru_ref``.
    """
    B, S, Di = x.shape
    if h0 is None:
        h0 = jnp.zeros((B, Di), dtype=jnp.float32)
    bd_ = min(bd, Di)
    Dp = -(-Di // bd_) * bd_
    xt = jnp.swapaxes(x, 1, 2)                      # (B, D, S)
    at = jnp.swapaxes(a, 1, 2)
    if Dp != Di:
        xt = jnp.pad(xt, ((0, 0), (0, Dp - Di), (0, 0)))
        at = jnp.pad(at, ((0, 0), (0, Dp - Di), (0, 0)))
        h0 = jnp.pad(h0, ((0, 0), (0, Dp - Di)))
    kern = functools.partial(_rglru_kernel, S=S)
    y, hf = pl.pallas_call(
        kern,
        grid=(B, Dp // bd_),
        in_specs=[
            pl.BlockSpec((1, bd_, S), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, bd_, S), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, bd_), lambda b, i: (b, i)),
        ],
        out_specs=[pl.BlockSpec((1, bd_, S), lambda b, i: (b, i, 0)),
                   pl.BlockSpec((1, bd_), lambda b, i: (b, i))],
        out_shape=[jax.ShapeDtypeStruct((B, Dp, S), x.dtype),
                   jax.ShapeDtypeStruct((B, Dp), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((1, bd_), jnp.float32)],
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(at, xt, h0)
    return jnp.swapaxes(y, 1, 2)[:, :, :Di], hf[:, :Di]
