"""3D homogeneous placement representation (stacked grids).

A placement is an R x C x Z grid of cells; each cell holds a compute-,
memory- or IO-chiplet or is empty.  The solution object is a pair of int8
numpy arrays ``(types, rot)`` of shape [R, C, Z] — the 2D representation
(``core.placement_homog.HomogRep``) with one more axis.  Rotation stays
*in-plane*: a 1-PHY chiplet's PHY faces N/E/S/W within its layer
(vertical TSV attachment ignores rotation, see ``arch3d.topology``).

``Homog3DRep`` hosts the four representation functions (random / mutate /
merge / score) with python-loop semantics mirroring ``HomogRep``;
``Homog3DBatch`` is the device-resident batched mirror (distribution-
equivalent, not bit-for-bit — different RNG streams), and the
``device_stage_key`` / ``graph_batch`` / ``tier_values`` trio plugs the
rep into ``optimize.DevicePipeline`` without the core ever importing this
package.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.chiplets import COMPUTE, IO, MEMORY, ArchSpec
from repro.core.proxies import Layout
from repro.core.topology import (DIR_DELTA as _DIR_DELTA,
                                 ROT_DIR as _ROT_DIR, ScoreGraph)

from .topology import (Grid3DGraphBatch, default_tier_values, family_records,
                       score_graph3d_host)

Sol3D = tuple[np.ndarray, np.ndarray]   # (types [R,C,Z], rot [R,C,Z])

_KINDS = (COMPUTE, MEMORY, IO)
_SWAP_TRIES = 128    # host caps at 200 sequential tries; pre-drawn here
# Neighbor-mutation directions: the four in-plane grid directions plus
# up/down the stack, as (dr, dc, dz).
_DIRS3 = tuple([(_DIR_DELTA[d][0], _DIR_DELTA[d][1], 0) for d in _ROT_DIR]
               + [(0, 0, 1), (0, 0, -1)])


def sol_key3d(sol: Sol3D) -> bytes:
    return sol[0].tobytes() + sol[1].tobytes()


@dataclass
class Homog3DRep:
    """Placement representation + operators for stacked homogeneous grids.

    ``kind`` / ``cluster`` / ``augment`` select the arch family's static
    adjacency structure (see ``arch3d.topology.family_records``);
    ``tsv_slowdown`` / ``backbone_factor`` only scale the runtime tier
    latency vector (:attr:`tier_values`) — they are *excluded* from
    :meth:`device_stage_key`, so sweeping them shares compiled stages.
    """

    arch: ArchSpec
    R: int
    C: int
    Z: int
    mutation_mode: str = "neighbor-one"
    kind: str = "stack"                       # stack | gateway
    cluster: tuple[int, int] | None = None
    augment: str = "none"                     # none | torus | express | ...
    augment_params: dict = field(default_factory=dict)
    tsv_slowdown: float = 4.0
    backbone_factor: float = 2.0

    def __post_init__(self):
        n = len(self.arch.chiplets)
        if self.R * self.C * self.Z < n:
            raise ValueError("grid too small for chiplet count")
        self._kind_instances = {
            k: [i for i, ch in enumerate(self.arch.chiplets) if ch.kind == k]
            for k in _KINDS
        }
        self._phy_base = np.zeros(n + 1, dtype=np.int64)
        for i, ch in enumerate(self.arch.chiplets):
            self._phy_base[i + 1] = self._phy_base[i] + ch.n_phys()
        self._rotatable = {
            k: self.arch.chiplets[self._kind_instances[k][0]].n_phys() == 1
            for k in _KINDS if self._kind_instances[k]
        }
        self.records = tuple(family_records(
            self.arch, self.R, self.C, self.Z, kind=self.kind,
            cluster=self.cluster, augment=self.augment,
            augment_params=self.augment_params))
        # Per-cell rotation candidates, derived from the *family's* records
        # (not bare grid adjacency): ``_rot_other[cell][rot]`` lists the
        # cells a 1-PHY chiplet rotated to ``rot`` could link to.  Gateway
        # families exclude cross-cluster sides; torus/express wraps count —
        # without this, 1-PHY chiplets roll toward record-free sides and
        # connected gateway placements become vanishingly rare.
        cells = self.R * self.C * self.Z
        rot_other: list[list[list[int]]] = [
            [[] for _ in range(4)] for _ in range(cells)]
        for a in self.records:
            if a.rot1 >= 0:
                rot_other[a.cell1][a.rot1].append(a.cell2)
            if a.rot2 >= 0:
                rot_other[a.cell2][a.rot2].append(a.cell1)
        self._rot_other = rot_other

    # -- static properties -------------------------------------------------
    @property
    def layout(self) -> Layout:
        return Layout(Vp=int(self._phy_base[-1]), kinds=self.arch.kinds())

    @property
    def e_max(self) -> int:
        return 2 * len(self.records)

    @property
    def area(self) -> float:
        # The package footprint is one layer; stacking does not grow it.
        sz = self.arch.chiplets[0].w * self.arch.chiplets[0].h
        return float(sz * self.R * self.C)

    @property
    def tier_values(self) -> np.ndarray:
        """Runtime ``[W_INTRA, W_BACKBONE, W_VERTICAL]`` latency vector."""
        return default_tier_values(self.arch,
                                   tsv_slowdown=self.tsv_slowdown,
                                   backbone_factor=self.backbone_factor)

    @property
    def scorer_shape_key(self) -> tuple:
        """Splits ``api.get_scorer``'s cache between same-layout families
        with different edge-slot counts (stack3d32 vs torus3d32): stacked
        cross-run scoring groups by scorer identity, and unlike edge
        shapes cannot concatenate into one batch."""
        return ("arch3d-edges", 2 * len(self.records))

    # -- DevicePipeline plug-in surface -------------------------------------
    def device_stage_key(self) -> tuple:
        """Stage-cache key: everything that shapes the compiled stages.
        Tier latencies (tsv/backbone factors) are runtime operands and
        deliberately absent."""
        return ("arch3d", self.arch, self.R, self.C, self.Z,
                self.mutation_mode, self.kind, self.cluster, self.augment,
                tuple(sorted(self.augment_params.items())))

    def graph_batch(self) -> Grid3DGraphBatch:
        return Grid3DGraphBatch(self.arch, self.R, self.C, self.Z,
                                list(self.records))

    def batch_ops(self) -> "Homog3DBatch":
        if not hasattr(self, "_batch_ops"):
            self._batch_ops = Homog3DBatch(self)
        return self._batch_ops

    # -- helpers -------------------------------------------------------------
    def _roll_rotation(self, types, r, c, z, rng) -> int:
        """Uniform rotation over the cell's record-backed candidates:
        rotations whose link partner is occupied, else rotations with any
        record, else all four (mirrors the 2D occupied -> inside -> all
        cascade, generalized to the family's adjacency)."""
        tflat = types.reshape(-1)
        cands_cell = self._rot_other[(r * self.C + c) * self.Z + z]
        occ = [rot for rot in range(4)
               if any(tflat[o] >= 0 for o in cands_cell[rot])]
        anyr = [rot for rot in range(4) if cands_cell[rot]]
        return int(rng.choice(occ or anyr or [0, 1, 2, 3]))

    def _fix_rotations(self, types, rot, rng) -> None:
        for r in range(self.R):
            for c in range(self.C):
                for z in range(self.Z):
                    k = types[r, c, z]
                    if k >= 0 and self._rotatable.get(int(k), False):
                        rot[r, c, z] = self._roll_rotation(types, r, c, z,
                                                           rng)
                    else:
                        rot[r, c, z] = 0

    # -- the four representation functions -----------------------------------
    def random(self, rng: np.random.Generator) -> Sol3D:
        cells = self.R * self.C * self.Z
        flat = np.full(cells, -1, dtype=np.int8)
        kinds = [k for k, ids in self._kind_instances.items() for _ in ids]
        pos = rng.choice(np.arange(cells), size=len(kinds), replace=False)
        flat[pos] = np.array(kinds, dtype=np.int8)
        types = flat.reshape(self.R, self.C, self.Z)
        rot = np.zeros_like(types)
        self._fix_rotations(types, rot, rng)
        return types, rot

    def mutate(self, sol: Sol3D, rng: np.random.Generator) -> Sol3D:
        types = sol[0].copy()
        rot = sol[1].copy()
        neighbor = self.mutation_mode.startswith("neighbor")
        both = self.mutation_mode.endswith("both")
        do_swap = True
        do_rot = both or not any(self._rotatable.values())
        if not both and any(self._rotatable.values()):
            do_swap = bool(rng.integers(2))
            do_rot = not do_swap
        if do_swap:
            self._swap(types, rot, rng, neighbor)
        if do_rot and any(self._rotatable.values()):
            self._rotate_one(types, rot, rng)
        return types, rot

    def _swap(self, types, rot, rng, neighbor: bool) -> None:
        for _ in range(200):
            r1 = int(rng.integers(self.R))
            c1 = int(rng.integers(self.C))
            z1 = int(rng.integers(self.Z))
            if neighbor:
                dr, dc, dz = _DIRS3[int(rng.integers(6))]
                r2, c2, z2 = r1 + dr, c1 + dc, z1 + dz
                if not (0 <= r2 < self.R and 0 <= c2 < self.C
                        and 0 <= z2 < self.Z):
                    continue
            else:
                r2 = int(rng.integers(self.R))
                c2 = int(rng.integers(self.C))
                z2 = int(rng.integers(self.Z))
            a, b = (r1, c1, z1), (r2, c2, z2)
            if types[a] == types[b]:
                continue
            if types[a] < 0 and types[b] < 0:
                continue
            types[a], types[b] = types[b], types[a]
            rot[a], rot[b] = rot[b], rot[a]
            for (r, c, z) in (a, b):
                k = types[r, c, z]
                if k >= 0 and self._rotatable.get(int(k), False):
                    rot[r, c, z] = self._roll_rotation(types, r, c, z, rng)
                else:
                    rot[r, c, z] = 0
            return

    def _rotate_one(self, types, rot, rng) -> None:
        cand = [(r, c, z) for r in range(self.R) for c in range(self.C)
                for z in range(self.Z)
                if types[r, c, z] >= 0
                and self._rotatable.get(int(types[r, c, z]), False)]
        if not cand:
            return
        r, c, z = cand[int(rng.integers(len(cand)))]
        rot[r, c, z] = self._roll_rotation(types, r, c, z, rng)

    def merge(self, a: Sol3D, b: Sol3D, rng: np.random.Generator) -> Sol3D:
        ta, ra_ = a
        tb, rb_ = b
        types = np.full_like(ta, -2)            # -2 = unresolved
        match = ta == tb
        types[match] = ta[match]
        remaining = {k: len(ids) for k, ids in self._kind_instances.items()}
        for k in remaining:
            remaining[k] -= int((types == k).sum())
        unresolved = np.argwhere(types == -2)
        fill = []
        for k, n in remaining.items():
            fill += [k] * n
        fill += [-1] * (len(unresolved) - len(fill))
        fill = np.array(fill, dtype=np.int8)
        rng.shuffle(fill)
        for (r, c, z), v in zip(unresolved, fill):
            types[r, c, z] = v
        rot = np.zeros_like(types)
        rot_match = match & (ra_ == rb_)
        rot[rot_match] = ra_[rot_match]
        for r in range(self.R):
            for c in range(self.C):
                for z in range(self.Z):
                    k = types[r, c, z]
                    if k >= 0 and self._rotatable.get(int(k), False):
                        if not rot_match[r, c, z]:
                            rot[r, c, z] = self._roll_rotation(
                                types, r, c, z, rng)
                    else:
                        rot[r, c, z] = 0
        return types, rot

    # -- scoring --------------------------------------------------------------
    def score_graph(self, sol: Sol3D) -> ScoreGraph:
        return score_graph3d_host(self.arch, self.records, sol[0], sol[1],
                                  self.tier_values, self.area)

    def is_connected(self, sol: Sol3D) -> bool:
        return bool(self.score_graph(sol).connected)


# ---------------------------------------------------------------------------
# Device-resident batched operators (the [B, R, C, Z] mirror of HomogBatch).
# ---------------------------------------------------------------------------


class Homog3DBatch:
    """Vectorized ``random/mutate/merge`` over stacked 3D grids."""

    def __init__(self, rep: Homog3DRep):
        self.rep = rep
        self.R, self.C, self.Z = rep.R, rep.C, rep.Z
        self.cells = rep.R * rep.C * rep.Z
        fill = [k for k, ids in rep._kind_instances.items() for _ in ids]
        fill += [-1] * (self.cells - len(fill))
        self._kinds_fill = jnp.asarray(np.array(fill, dtype=np.int8))
        self._counts = np.array(
            [len(rep._kind_instances.get(k, ())) for k in _KINDS], np.int32)
        rotatable = np.array([bool(rep._rotatable.get(k, False))
                              for k in _KINDS])
        self._rotatable_kind = jnp.asarray(rotatable)
        self._any_rotatable = bool(rotatable.any())
        # Record-backed rotation candidates, padded to a rectangular
        # gather table: ``_rot_other_idx[cell, rot]`` lists link-partner
        # cells (sentinel ``cells`` = an always-unoccupied pad slot).
        M = max(1, max(len(s) for cell in rep._rot_other for s in cell))
        other = np.full((self.cells, 4, M), self.cells, np.int32)
        any_rec = np.zeros((self.cells, 4), bool)
        for cell, per_rot in enumerate(rep._rot_other):
            for rot_i, partners in enumerate(per_rot):
                other[cell, rot_i, :len(partners)] = partners
                any_rec[cell, rot_i] = bool(partners)
        self._rot_other_idx = jnp.asarray(other)
        self._rot_any = jnp.asarray(any_rec)
        self._dr6 = jnp.asarray(np.array([d[0] for d in _DIRS3], np.int32))
        self._dc6 = jnp.asarray(np.array([d[1] for d in _DIRS3], np.int32))
        self._dz6 = jnp.asarray(np.array([d[2] for d in _DIRS3], np.int32))

    # -- rotation re-roll (vectorized ``_fix_rotations``) --------------------
    def _rotatable_cells(self, types: jnp.ndarray) -> jnp.ndarray:
        occ = types >= 0
        kind = jnp.clip(types, 0, 2).astype(jnp.int32)
        return occ & self._rotatable_kind[kind]

    def _roll_rot_batch(self, key, types, rot, update) -> jnp.ndarray:
        """Gumbel-argmax uniform roll over each cell's record-backed
        candidate rotations (same cascade as the host
        ``_roll_rotation``: partner-occupied -> any-record -> all 4)."""
        shape = types.shape
        lead = shape[:-3]
        occ = (types >= 0).reshape(lead + (self.cells,))
        occ_pad = jnp.concatenate(
            [occ, jnp.zeros(lead + (1,), bool)], axis=-1)
        cand_occ = occ_pad[..., self._rot_other_idx].any(-1)
        rot_any = jnp.broadcast_to(self._rot_any, cand_occ.shape)
        cand = jnp.where(cand_occ.any(-1, keepdims=True), cand_occ,
                         jnp.where(rot_any.any(-1, keepdims=True),
                                   rot_any, True))
        g = jax.random.gumbel(key, cand.shape)
        new = jnp.argmax(jnp.where(cand, g, -jnp.inf), axis=-1)
        new = new.astype(rot.dtype).reshape(shape)
        rotatable = self._rotatable_cells(types)
        return jnp.where(update & rotatable, new,
                         jnp.where(update, 0, rot)).astype(jnp.int8)

    # -- the representation functions, batched -------------------------------
    def random_batch(self, key, n: int) -> tuple[jnp.ndarray, jnp.ndarray]:
        k1, k2 = jax.random.split(key)
        keys = jax.random.split(k1, n)
        perm = jax.vmap(
            lambda k: jax.random.permutation(k, self._kinds_fill))(keys)
        types = perm.reshape(n, self.R, self.C, self.Z)
        rot = jnp.zeros_like(types)
        rot = self._roll_rot_batch(k2, types, rot,
                                   jnp.ones(types.shape, bool))
        return types, rot

    def _onehot_cells(self, idx: jnp.ndarray, flag: jnp.ndarray
                      ) -> jnp.ndarray:
        return (jnp.arange(self.cells)[None, :] == idx[:, None]) \
            & flag[:, None]

    def mutate_batch(self, key, types, rot
                     ) -> tuple[jnp.ndarray, jnp.ndarray]:
        B = types.shape[0]
        neighbor = self.rep.mutation_mode.startswith("neighbor")
        both = self.rep.mutation_mode.endswith("both")
        (kcoin, kr1, kc1, kz1, kd, kr2, kc2, kz2,
         kpick, kfix) = jax.random.split(key, 10)
        if both or not self._any_rotatable:
            do_swap = jnp.ones(B, bool)
        else:
            do_swap = jax.random.bernoulli(kcoin, 0.5, (B,))
        if not self._any_rotatable:
            do_rot = jnp.zeros(B, bool)
        elif both:
            do_rot = jnp.ones(B, bool)
        else:
            do_rot = ~do_swap
        # Pre-drawn swap tries; the first valid one is the host's accepted
        # draw (identical first-success distribution).
        T = _SWAP_TRIES
        r1 = jax.random.randint(kr1, (B, T), 0, self.R)
        c1 = jax.random.randint(kc1, (B, T), 0, self.C)
        z1 = jax.random.randint(kz1, (B, T), 0, self.Z)
        if neighbor:
            d = jax.random.randint(kd, (B, T), 0, 6)
            r2 = r1 + self._dr6[d]
            c2 = c1 + self._dc6[d]
            z2 = z1 + self._dz6[d]
        else:
            r2 = jax.random.randint(kr2, (B, T), 0, self.R)
            c2 = jax.random.randint(kc2, (B, T), 0, self.C)
            z2 = jax.random.randint(kz2, (B, T), 0, self.Z)
        inb = ((r2 >= 0) & (r2 < self.R) & (c2 >= 0) & (c2 < self.C)
               & (z2 >= 0) & (z2 < self.Z))
        i1 = (r1 * self.C + c1) * self.Z + z1
        i2 = (jnp.clip(r2, 0, self.R - 1) * self.C
              + jnp.clip(c2, 0, self.C - 1)) * self.Z \
            + jnp.clip(z2, 0, self.Z - 1)
        tflat = types.reshape(B, self.cells)
        rflat = rot.reshape(B, self.cells)
        t1 = jnp.take_along_axis(tflat, i1, axis=1)
        t2 = jnp.take_along_axis(tflat, i2, axis=1)
        valid = inb & (t1 != t2) & ~((t1 < 0) & (t2 < 0))
        first = jnp.argmax(valid, axis=1)
        sel = lambda a: jnp.take_along_axis(a, first[:, None], axis=1)[:, 0]
        do_it = do_swap & valid.any(axis=1)
        s1 = jnp.where(do_it, sel(i1), 0)
        s2 = jnp.where(do_it, sel(i2), 0)    # s1 == s2 == 0 -> no-op swap
        b = jnp.arange(B)
        v1t, v2t = tflat[b, s1], tflat[b, s2]
        tflat = tflat.at[b, s1].set(v2t).at[b, s2].set(v1t)
        v1r, v2r = rflat[b, s1], rflat[b, s2]
        rflat = rflat.at[b, s1].set(v2r).at[b, s2].set(v1r)
        update = self._onehot_cells(s1, do_it) | self._onehot_cells(s2, do_it)
        if self._any_rotatable:
            rc = self._rotatable_cells(tflat)
            g = jax.random.gumbel(kpick, (B, self.cells))
            pick = jnp.argmax(jnp.where(rc, g, -jnp.inf), axis=1)
            update |= self._onehot_cells(pick, do_rot & rc.any(axis=1))
        shape = (B, self.R, self.C, self.Z)
        types2 = tflat.reshape(shape)
        rot2 = rflat.reshape(shape)
        rot2 = self._roll_rot_batch(kfix, types2, rot2, update.reshape(shape))
        return types2, rot2

    def merge_batch(self, key, ta, ra, tb, rb
                    ) -> tuple[jnp.ndarray, jnp.ndarray]:
        """Batched merge: keep agreeing cells, distribute the leftover
        chiplets uniformly over the disagreeing cells (random-rank fill ==
        host's shuffled fill), carry rotations only where both agree."""
        B = ta.shape[0]
        k1, k2 = jax.random.split(key)
        match = ta == tb
        taf = ta.reshape(B, self.cells)
        mf = match.reshape(B, self.cells)
        carried = jnp.where(mf, taf, -2)
        rem = [self._counts[k] - (carried == k).sum(axis=1) for k in range(3)]
        prio = jax.random.uniform(k1, (B, self.cells))
        prio = jnp.where(carried == -2, prio, 2.0)   # resolved cells: last
        rank = jnp.argsort(jnp.argsort(prio, axis=1), axis=1)
        c0 = rem[0][:, None]
        c1 = c0 + rem[1][:, None]
        c2 = c1 + rem[2][:, None]
        fill = jnp.where(rank < c0, COMPUTE,
                         jnp.where(rank < c1, MEMORY,
                                   jnp.where(rank < c2, IO, -1)))
        types = jnp.where(mf, taf, fill.astype(ta.dtype))
        types = types.reshape(B, self.R, self.C, self.Z)
        rot_match = match & (ra == rb)
        rot0 = jnp.where(rot_match, ra, 0).astype(ra.dtype)
        update = ~(rot_match & self._rotatable_cells(types))
        rot = self._roll_rot_batch(k2, types, rot0, update)
        return types, rot
