"""The user-defined cost function (paper §IV-B).

cost(placement) = sum_t w_lat[t] * lat_t / E[lat_t]
               + sum_t w_thr[t] * (1/thr_t) / E[1/thr_t]
               + w_area * area / E[area]

where the expectations are *normalizers*: means of each raw component over
``norm_samples`` random placements (Table II, "Norm. Samples").  Throughput
enters inverted so that every term is "lower is better".
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from .chiplets import TRAFFIC_TYPES, ArchSpec

_EPS = 1.0e-6


@dataclass
class CostNormalizers:
    lat: dict = field(default_factory=dict)     # type -> mean latency
    inv_thr: dict = field(default_factory=dict)  # type -> mean 1/throughput
    area: float = 1.0

    @staticmethod
    def from_samples(metrics: dict) -> "CostNormalizers":
        n = CostNormalizers()
        for t in TRAFFIC_TYPES:
            lat = np.asarray(metrics[f"lat_{t}"], dtype=np.float64)
            thr = np.asarray(metrics[f"thr_{t}"], dtype=np.float64)
            ok = lat < 1.0e8
            n.lat[t] = float(lat[ok].mean()) if ok.any() else 1.0
            n.inv_thr[t] = float((1.0 / np.maximum(thr[ok], _EPS)).mean()) \
                if ok.any() else 1.0
        n.area = float(np.asarray(metrics["area"], dtype=np.float64).mean())
        return n


def cost_components(metrics: dict, arch: ArchSpec,
                    norm: CostNormalizers) -> dict:
    """Normalized, weighted components (9 of them, Fig. 4)."""
    comp = {}
    for i, t in enumerate(TRAFFIC_TYPES):
        lat = np.asarray(metrics[f"lat_{t}"], dtype=np.float64)
        thr = np.asarray(metrics[f"thr_{t}"], dtype=np.float64)
        comp[f"lat_{t}"] = arch.w_lat[i] * lat / max(norm.lat[t], _EPS)
        comp[f"thr_{t}"] = (arch.w_thr[i]
                            * (1.0 / np.maximum(thr, _EPS))
                            / max(norm.inv_thr[t], _EPS))
    comp["area"] = (arch.w_area
                    * np.asarray(metrics["area"], dtype=np.float64)
                    / max(norm.area, _EPS))
    return comp


def total_cost(metrics: dict, arch: ArchSpec, norm: CostNormalizers
               ) -> np.ndarray:
    comp = cost_components(metrics, arch, norm)
    return sum(comp.values())
