"""Compatibility shim: the event-driven simulator moved to
``repro.netsim.sim`` (with the device rate model and workload compiler
beside it in ``repro.netsim``).  Existing ``repro.core.netsim`` imports
keep working through this re-export."""
from repro.netsim.sim import (ROUTER_PIPELINE, ChipletNet, NetSim, Packet,
                              SimResult, latency_throughput_curve,
                              synthetic_packets)

__all__ = [
    "ROUTER_PIPELINE", "ChipletNet", "NetSim", "Packet", "SimResult",
    "latency_throughput_curve", "synthetic_packets",
]
