"""Placement representation invariants (§V-A homog, §VI-A hetero).

Property-based (hypothesis): chiplet-count conservation under mutate/merge,
legal rotations only, corner placement produces no overlaps, isomorphism
constraints (order by type, rotation classes).
"""
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core.chiplets import COMPUTE, IO, MEMORY, paper_arch
from repro.core.placement_hetero import HeteroRep, corner_place
from repro.core.placement_homog import HomogRep


def counts_of(types):
    return {k: int((types == k).sum()) for k in (COMPUTE, MEMORY, IO)}


@pytest.fixture(scope="module")
def homog():
    return HomogRep(paper_arch("homog32", "baseline"), R=8, C=5)


@pytest.fixture(scope="module")
def hetero():
    return HeteroRep(paper_arch("hetero32", "baseline"))


# ---------------------------------------------------------------------------
# homogeneous
# ---------------------------------------------------------------------------

@given(st.integers(0, 10_000))
@settings(max_examples=25, deadline=None)
def test_homog_random_valid(seed):
    rep = HomogRep(paper_arch("homog32", "baseline"), R=8, C=5)
    rng = np.random.default_rng(seed)
    types, rot = rep.random(rng)
    assert counts_of(types) == {COMPUTE: 32, MEMORY: 4, IO: 4}
    # compute chiplets (4 PHYs) never rotated
    assert (rot[types == COMPUTE] == 0).all()


@given(st.integers(0, 10_000),
       st.sampled_from(["any-both", "any-one", "neighbor-both",
                        "neighbor-one"]))
@settings(max_examples=30, deadline=None)
def test_homog_mutate_preserves_counts(seed, mode):
    rep = HomogRep(paper_arch("homog32", "baseline"), R=8, C=5,
                   mutation_mode=mode)
    rng = np.random.default_rng(seed)
    sol = rep.random(rng)
    mut = rep.mutate(sol, rng)
    assert counts_of(mut[0]) == counts_of(sol[0])
    assert (mut[1][mut[0] == COMPUTE] == 0).all()


@given(st.integers(0, 10_000))
@settings(max_examples=25, deadline=None)
def test_homog_merge_carries_matches(seed):
    rep = HomogRep(paper_arch("homog32", "baseline"), R=8, C=5)
    rng = np.random.default_rng(seed)
    a, b = rep.random(rng), rep.random(rng)
    m = rep.merge(a, b, rng)
    assert counts_of(m[0]) == counts_of(a[0])
    match = a[0] == b[0]
    assert (m[0][match] == a[0][match]).all()     # agreements carried over


def test_homog_network_links_opposing_phys(homog, rng):
    sol = homog.random(rng)
    links, inst = homog.links_of(sol)
    geo = homog.geometry(sol)
    for p, q in links:
        # linked PHYs belong to adjacent chiplets; distance == one pitch gap
        a, b = geo.owner[p], geo.owner[q]
        assert a != b
        d = np.linalg.norm(geo.pos[p] - geo.pos[q])
        assert d <= 3.0 + 1e-6


# ---------------------------------------------------------------------------
# heterogeneous
# ---------------------------------------------------------------------------

@given(st.lists(st.tuples(st.sampled_from([2.0, 3.0, 4.0, 5.0]),
                          st.sampled_from([2.0, 3.0, 4.0, 5.0])),
                min_size=1, max_size=12))
@settings(max_examples=60, deadline=None)
def test_corner_place_no_overlap(dims):
    pos = corner_place(dims)
    n = len(dims)
    for i in range(n):
        for j in range(i + 1, n):
            xi, yi = pos[i]
            xj, yj = pos[j]
            wi, hi = dims[i]
            wj, hj = dims[j]
            overlap = (xi < xj + wj - 1e-9 and xj < xi + wi - 1e-9 and
                       yi < yj + hj - 1e-9 and yj < yi + hi - 1e-9)
            assert not overlap, f"rect {i} overlaps {j}"


def test_corner_place_step4_geometric():
    """Fig. 7 step-4 regression (hand-computed): the push direction comes
    from where the blocking rect lies, not from an alternation heuristic.

    dims = [(2,2), (2,4), (4,2)]:
    * rect0 -> (0,0); rect1 -> (2,0) (smallest enclosing square, side 4).
    * rect2 (4x2) from anchor (0,0): overlaps rect0, whose bottom edge is
      at the anchor's level -> overlap to the *right* -> push up to (0,2);
      there it overlaps rect1 (bottom edge y=0, again at/below level) ->
      push up to (0,4), which is free.  Key (side 6, x+y 4) beats every
      other anchor (the (2,4) anchor also reaches side 6 but x+y 6), so
      rect2 lands at (0,4).  The old alternation seeded from the anchor
      position moved right first and misplaced rect2 at (2,4).
    """
    pos = corner_place([(2.0, 2.0), (2.0, 4.0), (4.0, 2.0)])
    assert np.array_equal(pos, np.array([[0.0, 0.0], [2.0, 0.0], [0.0, 4.0]]))


def test_corner_place_batch_matches_scalar(hetero):
    from repro.core.placement_hetero import corner_place_batch

    rng = np.random.default_rng(7)
    sols = [hetero.random(rng) for _ in range(6)]
    ops = hetero.batch_ops()
    dims = ops._dims_table[np.stack([s[0] for s in sols]).astype(np.int64),
                           np.stack([s[1] for s in sols]).astype(np.int64)]
    batch = corner_place_batch(dims)
    for b, s in enumerate(sols):
        chips = [hetero._proto[int(k)].rotated(int(r))
                 for k, r in zip(s[0], s[1])]
        assert np.array_equal(batch[b], corner_place([(c.w, c.h)
                                                      for c in chips]))


@given(st.integers(0, 10_000))
@settings(max_examples=25, deadline=None)
def test_hetero_random_valid(seed):
    rep = HeteroRep(paper_arch("hetero32", "baseline"))
    rng = np.random.default_rng(seed)
    order, rots = rep.random(rng)
    assert counts_of(order) == {COMPUTE: 32, MEMORY: 4, IO: 4}
    for k, r in zip(order, rots):
        assert r in rep._allowed_rot[int(k)]


@given(st.integers(0, 10_000))
@settings(max_examples=20, deadline=None)
def test_hetero_mutate_merge_invariants(seed):
    rep = HeteroRep(paper_arch("hetero32", "baseline"))
    rng = np.random.default_rng(seed)
    a, b = rep.random(rng), rep.random(rng)
    m = rep.mutate(a, rng)
    assert counts_of(m[0]) == counts_of(a[0])
    g = rep.merge(a, b, rng)
    assert counts_of(g[0]) == counts_of(a[0])
    match = a[0] == b[0]
    assert (g[0][match] == a[0][match]).all()
    for k, r in zip(g[0], g[1]):
        assert r in rep._allowed_rot[int(k)]


def test_hetero_geometry_no_phy_outside(hetero, rng):
    sol = hetero.random(rng)
    pos, chips, inst = hetero.place(sol)
    geo = hetero.geometry(sol)
    # every PHY sits on its chiplet's bounding box
    for p in range(geo.pos.shape[0]):
        c = int(geo.owner[p])
        k = int(np.nonzero(inst == c)[0][0])
        x0, y0 = pos[k]
        ch = chips[k]
        x, y = geo.pos[p]
        assert x0 - 1e-5 <= x <= x0 + ch.w + 1e-5
        assert y0 - 1e-5 <= y <= y0 + ch.h + 1e-5
