"""Regression tests for the cycle-level ICI simulator (``netsim``).

``netsim`` was only exercised indirectly (through the trace benchmarks);
these pin its two public workloads directly:

* ``synthetic_packets`` — per-traffic-class rate accounting: sources and
  destinations drawn from the right chiplet kinds, no self-pairs,
  Bernoulli injection count tracking ``rate * n_cycles`` per source,
  rate clipping at 1 packet/cycle, seeded determinism.
* ``latency_throughput_curve`` — zero-load latency matching the routed
  hop latency, saturation monotonicity (average latency does not
  collapse as the injection rate grows, and diverges well past the
  bottleneck-link saturation point).
"""
import numpy as np
import pytest

from repro.core.baseline import MeshBaseline
from repro.core.chiplets import COMPUTE, IO, MEMORY, paper_arch
from repro.core.netsim import (ROUTER_PIPELINE, ChipletNet, NetSim,
                               latency_throughput_curve, synthetic_packets)

KIND_OF = {"c": COMPUTE, "m": MEMORY, "i": IO}


@pytest.fixture(scope="module")
def net():
    arch = paper_arch("homog32", "baseline")
    _, geo, links = MeshBaseline(arch).build()
    return arch, ChipletNet.from_links(arch, geo, links)


# ---------------------------------------------------------------------------
# synthetic_packets: per-class rate accounting.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("traffic", ["c2c", "c2m", "c2i", "m2i"])
def test_synthetic_packets_class_accounting(net, traffic):
    arch, cn = net
    ks, kd = KIND_OF[traffic[0]], KIND_OF[traffic[2]]
    n_src = int((cn.kinds == ks).sum())
    rate, n_cycles = 0.05, 4000
    pkts = synthetic_packets(cn, traffic, rate, n_cycles,
                             np.random.default_rng(7))
    assert pkts, "no packets generated"
    for p in pkts:
        assert cn.kinds[p.src] == ks
        assert cn.kinds[p.dst] == kd
        assert p.src != p.dst
        assert 0 <= p.cycle < n_cycles
        assert p.flits == 9                      # default data packet
    # Bernoulli(n_cycles, rate) per source: mean n_src*rate*n_cycles, and
    # a 5-sigma band on the total (self-pair drops only shave c2c a bit).
    mean = n_src * rate * n_cycles
    sigma = np.sqrt(n_src * n_cycles * rate * (1 - rate))
    slack = mean / max((cn.kinds == kd).sum(), 1)   # dropped self pairs
    assert mean - 5 * sigma - slack <= len(pkts) <= mean + 5 * sigma


def test_synthetic_packets_rate_clips_at_one(net):
    _, cn = net
    n_cycles = 50
    pkts = synthetic_packets(cn, "m2i", 3.0, n_cycles,
                             np.random.default_rng(0))
    n_src = int((cn.kinds == MEMORY).sum())
    # rate is clipped to 1 packet/cycle/source
    assert len(pkts) <= n_src * n_cycles


def test_synthetic_packets_deterministic_under_seed(net):
    _, cn = net
    a = synthetic_packets(cn, "c2m", 0.1, 500, np.random.default_rng(3))
    b = synthetic_packets(cn, "c2m", 0.1, 500, np.random.default_rng(3))
    assert [(p.src, p.dst, p.cycle) for p in a] \
        == [(p.src, p.dst, p.cycle) for p in b]


# ---------------------------------------------------------------------------
# latency_throughput_curve: zero-load latency + saturation monotonicity.
# ---------------------------------------------------------------------------

def test_zero_load_latency_matches_routed_hops(net):
    arch, cn = net
    sim = NetSim(cn, arch)
    # a single packet: latency = hops * (d2d + pipeline) + relays * L_R
    # + serialization (flits - 1), with no contention
    srcs = np.nonzero(cn.kinds == COMPUTE)[0]
    dsts = np.nonzero(cn.kinds == MEMORY)[0]
    s, d = int(srcs[0]), int(dsts[-1])
    from repro.core.netsim import Packet
    res = sim.run([Packet(0, s, d, 9, 0)])
    path = cn.path(s, d)
    hops = len(path) - 1
    want = hops * (arch.latency.d2d_cost() + ROUTER_PIPELINE) \
        + (hops - 1) * arch.latency.l_relay + 9 - 1
    assert res.n_done == 1
    assert res.avg_latency == pytest.approx(want)


def test_latency_throughput_curve_saturates_monotonically(net):
    arch, cn = net
    rates = [0.005, 0.02, 0.1, 0.4]
    curve = latency_throughput_curve(cn, arch, "c2m", rates,
                                     n_cycles=1500, seed=1)
    assert [r for r, _ in curve] == rates
    lats = np.array([lat for _, lat in curve])
    assert np.isfinite(lats).all()
    # low-load latency sits near the zero-load point; saturation blows up
    assert lats[0] > 0
    # monotone non-decreasing within a small tolerance for queue noise
    assert (np.diff(lats) > -0.05 * lats[:-1]).all()
    # far past saturation the average latency must clearly diverge
    assert lats[-1] > 2.0 * lats[0]


def test_curve_per_class_rates_are_independent(net):
    """Each traffic class saturates against its own bottleneck: the curve
    for a sparse class (m2i, 4 sources) stays much flatter at the same
    per-source rate than the dense c2m class (32 sources)."""
    arch, cn = net
    r = [0.25]
    (_, lat_c2m), = latency_throughput_curve(cn, arch, "c2m", r,
                                             n_cycles=1200, seed=2)
    (_, lat_m2i), = latency_throughput_curve(cn, arch, "m2i", r,
                                             n_cycles=1200, seed=2)
    assert np.isfinite(lat_c2m) and np.isfinite(lat_m2i)
    assert lat_c2m > lat_m2i
