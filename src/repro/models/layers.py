"""Shared layer primitives: norms, RoPE, GQA attention, SwiGLU MLP.

Parameters are plain nested dicts of jnp arrays — the whole framework is
pure-function JAX (init / apply), which keeps pjit sharding rules a simple
path-pattern table (``repro.sharding.rules``).

Activation sharding: blocks call ``shard(x, axes...)`` which applies
``with_sharding_constraint`` when a mesh context is installed (see
``repro.sharding.partition.activation_shardings``) and is a no-op otherwise,
so the same model code runs single-device and under pjit.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..kernels import ops
from ..sharding.partition import shard
from .config import LMConfig


def _dt(cfg: LMConfig):
    return jnp.dtype(cfg.dtype)


def dense_init(key, d_in: int, d_out: int, dtype, scale: float | None = None):
    scale = (d_in ** -0.5) if scale is None else scale
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale
            ).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rms_norm(x, w, eps: float):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * (1.0 + w.astype(jnp.float32))
            ).astype(x.dtype)


def rms_norm_init(d: int):
    # Stored as an offset from 1.0 (gemma convention) — zero init.
    return jnp.zeros((d,), jnp.float32)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x: jnp.ndarray, pos: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: [B, S, H, d]; pos: [B, S] int32 absolute positions."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = pos[..., None].astype(jnp.float32) * freq          # [B, S, half]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# GQA attention block (pre-norm residual)
# ---------------------------------------------------------------------------

def attn_init(key, cfg: LMConfig) -> dict:
    ks = jax.random.split(key, 5)
    D, H, Hkv, hd = cfg.d_model, cfg.n_heads_p, cfg.n_kv_heads, cfg.hd
    dt = _dt(cfg)
    wq = dense_init(ks[0], D, H * hd, dt)
    wo = dense_init(ks[3], H * hd, D, dt)
    if H > cfg.n_heads:          # zero the padded heads (function-exact)
        real = cfg.n_heads * hd
        wq = wq.at[:, real:].set(0)
        wo = wo.at[real:, :].set(0)
    p = {
        "norm": rms_norm_init(D),
        "wq": wq,
        "wk": dense_init(ks[1], D, Hkv * hd, dt),
        "wv": dense_init(ks[2], D, Hkv * hd, dt),
        "wo": wo,
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dt)
        p["bk"] = jnp.zeros((Hkv * hd,), dt)
        p["bv"] = jnp.zeros((Hkv * hd,), dt)
    if cfg.qk_norm:
        p["q_norm"] = rms_norm_init(hd)
        p["k_norm"] = rms_norm_init(hd)
    return p


def _qkv(p, x, cfg: LMConfig, pos):
    B, S, D = x.shape
    H, Hkv, hd = cfg.n_heads_p, cfg.n_kv_heads, cfg.hd
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, Hkv, hd)
    v = v.reshape(B, S, Hkv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = rope(q, pos, cfg.rope_theta)
    k = rope(k, pos, cfg.rope_theta)
    q = shard(q, "act_heads")
    k = shard(k, "act_kv")
    v = shard(v, "act_kv")
    return q, k, v


def _sdpa_train(q, k, v, cfg: LMConfig, *, window: int | None,
                causal: bool = True):
    """Full-sequence attention; optionally q-chunked via ``lax.scan`` so
    only one chunk's logits block is ever live (flash-style peak memory in
    XLA; the while-aware cost analysis multiplies the body by the trip
    count).  Each chunk attends the full K/V with a position mask."""
    B, S, H, hd = q.shape
    qc = cfg.q_chunk
    if not qc or S <= qc:
        return ops.flash_attention(q, k, v, causal=causal, window=window,
                                   softcap=cfg.softcap, impl=cfg.attn_impl)
    pad = (-S) % qc                      # ragged tail (e.g. VLM patch prefix)
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nq = (S + pad) // qc
    qs = jnp.moveaxis(q.reshape(B, nq, qc, H, hd), 1, 0)       # [nq,B,qc,H,hd]
    offs = jnp.arange(nq, dtype=jnp.int32) * qc

    def body(_, xs):
        qi, off = xs
        o = ops.flash_attention(qi, k, v, causal=causal, window=window,
                                softcap=cfg.softcap, pos_offset=off,
                                impl=cfg.attn_impl)
        return 0, o

    _, outs = jax.lax.scan(body, 0, (qs, offs))
    out = jnp.moveaxis(outs, 0, 1).reshape(B, S + pad, H, hd)
    return out[:, :S] if pad else out


def attn_train(p, x, cfg: LMConfig, pos, *, window: int | None = None,
               causal: bool = True):
    B, S, D = x.shape
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    q, k, v = _qkv(p, h, cfg, pos)
    o = _sdpa_train(q, k, v, cfg, window=window, causal=causal)
    o = o.reshape(B, S, cfg.n_heads_p * cfg.hd) @ p["wo"]
    return x + shard(o, "act")


def attn_prefill(p, x, cfg: LMConfig, pos, *, window: int | None = None,
                 cache_len: int):
    """Like train, but also returns the (padded) KV cache for decode."""
    B, S, D = x.shape
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    q, k, v = _qkv(p, h, cfg, pos)
    o = _sdpa_train(q, k, v, cfg, window=window)
    o = o.reshape(B, S, cfg.n_heads_p * cfg.hd) @ p["wo"]
    kc = jnp.zeros((B, cache_len, cfg.n_kv_heads, cfg.hd), k.dtype)
    vc = jnp.zeros_like(kc)
    if window is None and S > cache_len:
        raise ValueError(
            f"prefill length {S} exceeds cache_len {cache_len} "
            "(only windowed layers may ring-wrap)")
    ins = min(S, cache_len)
    # Windowed layers keep a ring cache of the last `window` positions.
    if window is not None and cache_len == window and S > window:
        ks, vs = k[:, -window:], v[:, -window:]
        # ring order: position p stored at slot p % window
        slots = (jnp.arange(S - window, S)) % window
        kc = kc.at[:, slots].set(ks)
        vc = vc.at[:, slots].set(vs)
    else:
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k[:, :ins], 0, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v[:, :ins], 0, axis=1)
    cache = {"k": shard(kc, "cache"), "v": shard(vc, "cache")}
    return x + shard(o, "act"), cache


def attn_decode(p, x, cache, cfg: LMConfig, length, *,
                window: int | None = None):
    """x: [B, 1, D]; cache k/v: [B, Sc, Hkv, hd]; length: [B] tokens so far.

    The new token sits at absolute position `length`; ring-indexed when the
    cache is window-sized.
    """
    B, _, D = x.shape
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    q, k, v = _qkv(p, h, cfg, length[:, None])
    Sc = cache["k"].shape[1]
    slot = length % Sc if (window is not None and Sc == window) else length
    # Masked (one-hot) update instead of scatter: stays collective-free when
    # the cache is sequence-sharded over the model axis (DESIGN.md §6).
    onehot = (jnp.arange(Sc)[None] == slot[:, None])[..., None, None]
    kc = jnp.where(onehot, k[:, 0][:, None], cache["k"])
    vc = jnp.where(onehot, v[:, 0][:, None], cache["v"])
    ring = window is not None and Sc == window
    o = ops.decode_attention(
        q[:, 0], kc, vc,
        lengths=jnp.minimum(length + 1, Sc) if ring else length + 1,
        window=None if ring else window,
        softcap=cfg.softcap, impl=cfg.attn_impl)
    o = o.reshape(B, 1, cfg.n_heads_p * cfg.hd) @ p["wo"]
    return x + o, {"k": kc, "v": vc}


def attn_cache_init(cfg: LMConfig, B: int, cache_len: int, window=None):
    Sc = min(cache_len, window) if window else cache_len
    shape = (B, Sc, cfg.n_kv_heads, cfg.hd)
    return {"k": jnp.zeros(shape, _dt(cfg)), "v": jnp.zeros(shape, _dt(cfg))}


# ---------------------------------------------------------------------------
# Cross-attention (encoder-decoder); encoder output is the static memory.
# ---------------------------------------------------------------------------

def xattn_init(key, cfg: LMConfig) -> dict:
    ks = jax.random.split(key, 4)
    D, H, Hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    dt = _dt(cfg)
    return {
        "norm": rms_norm_init(D),
        "wq": dense_init(ks[0], D, H * hd, dt),
        "wk": dense_init(ks[1], D, Hkv * hd, dt),
        "wv": dense_init(ks[2], D, Hkv * hd, dt),
        "wo": dense_init(ks[3], H * hd, D, dt),
    }


def xattn(p, x, memory, cfg: LMConfig):
    """x: [B, S, D] decoder states; memory: [B, Sm, D] encoder output."""
    B, S, D = x.shape
    Sm = memory.shape[1]
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    q = (h @ p["wq"]).reshape(B, S, H, hd)
    k = (memory @ p["wk"]).reshape(B, Sm, Hkv, hd)
    v = (memory @ p["wv"]).reshape(B, Sm, Hkv, hd)
    o = _sdpa_train(q, k, v, cfg, window=None, causal=False)
    o = o.reshape(B, S, H * hd) @ p["wo"]
    return x + o


def xattn_kv(p, memory, cfg: LMConfig):
    """Precompute cross-attention K/V once per prefill (decode fast path)."""
    B, Sm, _ = memory.shape
    k = (memory @ p["wk"]).reshape(B, Sm, cfg.n_kv_heads, cfg.hd)
    v = (memory @ p["wv"]).reshape(B, Sm, cfg.n_kv_heads, cfg.hd)
    return {"k": k, "v": v}


def xattn_decode(p, x, kv, cfg: LMConfig, mem_len):
    B, _, D = x.shape
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    q = (h @ p["wq"]).reshape(B, cfg.n_heads, cfg.hd)
    o = ops.decode_attention(q, kv["k"], kv["v"], lengths=mem_len,
                             impl=cfg.attn_impl)
    o = o.reshape(B, 1, cfg.n_heads * cfg.hd) @ p["wo"]
    return x + o


# ---------------------------------------------------------------------------
# SwiGLU MLP block (pre-norm residual)
# ---------------------------------------------------------------------------

def mlp_init(key, cfg: LMConfig, d_ff: int | None = None) -> dict:
    ks = jax.random.split(key, 3)
    D, F = cfg.d_model, d_ff or cfg.d_ff
    dt = _dt(cfg)
    return {
        "norm": rms_norm_init(D),
        "w1": dense_init(ks[0], D, F, dt),        # gate
        "w3": dense_init(ks[1], D, F, dt),        # up
        "w2": dense_init(ks[2], F, D, dt),        # down
    }


def mlp(p, x, cfg: LMConfig):
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    a = shard(h @ p["w1"], "act_ff")
    b = shard(h @ p["w3"], "act_ff")
    o = (jax.nn.silu(a) * b) @ p["w2"]
    return x + shard(o, "act")
