"""Assigned architecture config: moonshot-v1-16b-a3b (see registry for source).

Exposes CONFIG (exact published hyper-parameters) and SMOKE (reduced copy
for CPU smoke tests).  Select with ``--arch moonshot-v1-16b-a3b``.
"""
from .registry import get_config

CONFIG = get_config("moonshot-v1-16b-a3b")
SMOKE = CONFIG.reduced()
