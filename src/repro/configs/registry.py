"""Assigned-architecture registry: exact configs, shapes, input specs.

Every architecture from the assignment is a selectable config
(``--arch <id>``); each is paired with the four input shapes.  Shape
eligibility (per instructions + DESIGN.md §5):

* ``long_500k`` needs sub-quadratic attention — only the bounded-state
  archs (falcon-mamba-7b, recurrentgemma-9b) run it; pure full-attention
  archs skip it (noted in DESIGN.md §Arch-applicability).
* all archs are decoder-bearing — no decode-shape skips.

``input_specs(arch, shape, ...)`` returns ShapeDtypeStruct stand-ins for
every model input (no allocation) — the dry-run lowers against these.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from ..models.config import LMConfig

# ---------------------------------------------------------------------------
# Shapes (assignment): (seq_len, global_batch, kind)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# The 10 assigned architectures — exact published configs.
# ---------------------------------------------------------------------------

ARCHS: dict[str, LMConfig] = {
    # [hybrid] RG-LRU + local attn 1:2 (griffin pattern r,r,a) —
    # [arXiv:2402.19427]
    "recurrentgemma-9b": LMConfig(
        name="recurrentgemma-9b", family="hybrid", n_layers=38,
        d_model=4096, n_heads=16, n_kv_heads=1, d_ff=12288, vocab=256_000,
        head_dim=256, pattern="rra", window=2048, d_rnn=4096,
        tie_embeddings=True),
    # [dense] llama-arch small — [hf:HuggingFaceTB/SmolLM-360M]
    "smollm-360m": LMConfig(
        name="smollm-360m", family="dense", n_layers=32, d_model=960,
        n_heads=15, n_kv_heads=5, d_ff=2560, vocab=49_152,
        tie_embeddings=True),
    # [dense] qk_norm, GQA — [hf:Qwen/Qwen3-1.7B]
    "qwen3-1.7b": LMConfig(
        name="qwen3-1.7b", family="dense", n_layers=28, d_model=2048,
        n_heads=16, n_kv_heads=8, d_ff=6144, vocab=151_936, head_dim=128,
        qk_norm=True, rope_theta=1_000_000.0),
    # [dense] GQA, QKV bias — [hf:Qwen/Qwen2.5-3B]
    "qwen2.5-3b": LMConfig(
        name="qwen2.5-3b", family="dense", n_layers=36, d_model=2048,
        n_heads=16, n_kv_heads=2, d_ff=11_008, vocab=151_936,
        qkv_bias=True, rope_theta=1_000_000.0),
    # [dense] llama2-arch small — [arXiv:2401.02385]
    "tinyllama-1.1b": LMConfig(
        name="tinyllama-1.1b", family="dense", n_layers=22, d_model=2048,
        n_heads=32, n_kv_heads=4, d_ff=5632, vocab=32_000),
    # [ssm] mamba-1, attn-free — [arXiv:2410.05355]
    "falcon-mamba-7b": LMConfig(
        name="falcon-mamba-7b", family="ssm", n_layers=64, d_model=4096,
        n_heads=0, n_kv_heads=0, d_ff=0, vocab=65_024, ssm_state=16,
        ssm_conv=4, ssm_expand=2),
    # [moe] 8 experts top-2 — [hf:xai-org/grok-1]
    "grok-1-314b": LMConfig(
        name="grok-1-314b", family="moe", n_layers=64, d_model=6144,
        n_heads=48, n_kv_heads=8, d_ff=32_768, vocab=131_072, head_dim=128,
        n_experts=8, top_k=2, softcap=30.0),
    # [moe] kimi/moonlight 64e top-6 — [hf:moonshotai/Moonlight-16B-A3B]
    "moonshot-v1-16b-a3b": LMConfig(
        name="moonshot-v1-16b-a3b", family="moe", n_layers=48, d_model=2048,
        n_heads=16, n_kv_heads=16, d_ff=1408, vocab=163_840,
        n_experts=64, top_k=6),
    # [audio] enc-dec, multimodal (frontend STUB) — [arXiv:2308.11596]
    "seamless-m4t-medium": LMConfig(
        name="seamless-m4t-medium", family="encdec", n_layers=12,
        n_enc_layers=12, d_model=1024, n_heads=16, n_kv_heads=16, d_ff=4096,
        vocab=256_206, frontend="audio"),
    # [vlm] anyres tiling (frontend STUB) — [hf:llava-next-34b]
    "llava-next-34b": LMConfig(
        name="llava-next-34b", family="vlm", n_layers=60, d_model=7168,
        n_heads=56, n_kv_heads=8, d_ff=20_480, vocab=64_000, head_dim=128,
        frontend="patch", n_frontend_tokens=576),
}

# VLM family reuses the dense decoder plan.
ARCHS["llava-next-34b"] = dataclasses.replace(
    ARCHS["llava-next-34b"], family="dense", frontend="patch")
_VLM_IDS = {"llava-next-34b"}


def get_config(arch: str) -> LMConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; have {sorted(ARCHS)}")
    return ARCHS[arch]


def eligible_shapes(arch: str) -> list[str]:
    cfg = get_config(arch)
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.bounded_state:
        out.append("long_500k")
    return out


def all_cells() -> list[tuple[str, str]]:
    return [(a, s) for a in ARCHS for s in eligible_shapes(a)]


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins; no device allocation).
# ---------------------------------------------------------------------------

def input_specs(arch: str, shape: str, *, batch_override: int | None = None
                ) -> dict:
    cfg = get_config(arch)
    sh = SHAPES[shape]
    B = batch_override or sh.global_batch
    S = sh.seq_len
    i32 = jnp.int32
    dt = jnp.dtype(cfg.dtype)
    D = cfg.d_model

    def tok(*shape):
        return jax.ShapeDtypeStruct(shape, i32)

    if sh.kind == "train":
        spec = {"tokens": tok(B, S), "labels": tok(B, S)}
        if cfg.frontend == "patch":
            spec["patch_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.n_frontend_tokens, D), dt)
        if cfg.family == "encdec":
            spec["src_embeds"] = jax.ShapeDtypeStruct((B, S, D), dt)
        return spec
    if sh.kind == "prefill":
        spec = {"tokens": tok(B, S)}
        if cfg.frontend == "patch":
            spec["patch_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.n_frontend_tokens, D), dt)
        if cfg.family == "encdec":
            spec["src_embeds"] = jax.ShapeDtypeStruct((B, S, D), dt)
        return spec
    # decode: one new token against a cache of S
    spec = {"tokens": tok(B, 1), "lengths": jax.ShapeDtypeStruct((B,), i32)}
    if cfg.family == "encdec":
        spec["mem_len"] = jax.ShapeDtypeStruct((B,), i32)
    return spec


def cache_specs(arch: str, shape: str, *, batch_override: int | None = None):
    """ShapeDtypeStructs for the decode cache (dry-run stand-ins)."""
    from ..models.model import init_cache

    cfg = get_config(arch)
    sh = SHAPES[shape]
    B = batch_override or sh.global_batch
    mem_len = sh.seq_len if cfg.family == "encdec" else 0
    return jax.eval_shape(
        lambda: init_cache(cfg, B, sh.seq_len, mem_len=mem_len))
