"""Mixture-of-Experts block: top-k routing with capacity-sort dispatch.

TPU-native dispatch (DESIGN.md §3): instead of the (T, E, C) one-hot
dispatch einsum (O(T·E·C) memory) we sort token-assignments by expert *within
each batch row* and gather each expert's first-C tokens, giving dense
(B, E, C, D) buffers of the same order as the activations themselves
(C = S·k/E·cf).  Per-row dispatch keeps every index operation local to the
batch shard — no cross-data-shard collectives are induced by the sort.

Sharding: experts over `model` when divisible (moonshot 64e/16), otherwise
the expert FFN is tensor-parallel on d_ff (grok 8e: all experts resident,
each sharded 16-way).  Router aux loss (load-balancing, Switch-style) is
returned for the train loss.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..sharding.partition import shard
from .config import LMConfig
from .layers import dense_init, rms_norm, rms_norm_init


def moe_init(key, cfg: LMConfig) -> dict:
    ks = jax.random.split(key, 4)
    D, F, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    dt = jnp.dtype(cfg.dtype)
    return {
        "norm": rms_norm_init(D),
        "router": dense_init(ks[0], D, E, jnp.float32),
        "we1": (jax.random.normal(ks[1], (E, D, F), jnp.float32)
                * (D ** -0.5)).astype(dt),
        "we3": (jax.random.normal(ks[2], (E, D, F), jnp.float32)
                * (D ** -0.5)).astype(dt),
        "we2": (jax.random.normal(ks[3], (E, F, D), jnp.float32)
                * (F ** -0.5)).astype(dt),
    }


def _capacity(cfg: LMConfig, S: int) -> int:
    if S == 1:
        # decode: top-k experts are distinct, so one slot per expert is
        # dropless and keeps the dispatch einsum minimal (memory-bound path)
        return 1
    c = int(S * cfg.top_k * cfg.capacity_factor / cfg.n_experts) + 1
    return max(8, -(-c // 8) * 8)


def moe_mlp(p, x, cfg: LMConfig) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x: [B, S, D] -> (y [B, S, D], aux_loss scalar)."""
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    C = _capacity(cfg, S)
    h = rms_norm(x, p["norm"], cfg.norm_eps)

    logits = (h.astype(jnp.float32) @ p["router"])          # [B, S, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, eidx = jax.lax.top_k(probs, K)                # [B, S, K]
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # Load-balancing aux loss (Switch): E * sum_e f_e * p_e.
    me = probs.mean(axis=(0, 1))                             # [E]
    ce = jnp.zeros((E,), jnp.float32).at[eidx.reshape(-1)].add(
        1.0 / (B * S * K))
    aux = E * jnp.sum(me * ce)

    # ---- per-row capacity-sort dispatch --------------------------------
    flat_e = eidx.reshape(B, S * K)                          # assignments
    sort_idx = jnp.argsort(flat_e, axis=-1)                  # [B, S*K]
    sorted_e = jnp.take_along_axis(flat_e, sort_idx, axis=-1)
    # counts/starts per expert per row
    counts = jax.vmap(lambda r: jnp.bincount(r, length=E))(flat_e)  # [B, E]
    starts = jnp.cumsum(counts, axis=-1) - counts            # [B, E]
    slot = starts[:, :, None] + jnp.arange(C)[None, None]    # [B, E, C]
    valid = jnp.arange(C)[None, None] < counts[:, :, None]   # [B, E, C]
    slot_c = jnp.minimum(slot, S * K - 1)
    assign = jnp.take_along_axis(                            # idx into S*K
        sort_idx, slot_c.reshape(B, E * C), axis=-1).reshape(B, E, C)
    tok = assign // K                                        # token position
    gsel = jnp.take_along_axis(
        gate_vals.reshape(B, S * K), assign.reshape(B, E * C),
        axis=-1).reshape(B, E, C)
    gsel = jnp.where(valid, gsel, 0.0)

    # gather -> [B, E, C, D]
    xe = jnp.take_along_axis(h[:, None], tok[..., None], axis=2)
    xe = shard(xe, "moe_disp")
    a = jnp.einsum("becd,edf->becf", xe, p["we1"])
    b = jnp.einsum("becd,edf->becf", xe, p["we3"])
    hh = shard(jax.nn.silu(a) * b, "moe_ff")
    ye = jnp.einsum("becf,efd->becd", hh, p["we2"])
    ye = ye * gsel[..., None].astype(ye.dtype)

    # combine: scatter-add back over token positions (vmapped over rows so
    # the scatter stays batch-local under pjit)
    def combine_row(ye_row, tok_row):
        return jnp.zeros((S, D), ye.dtype).at[tok_row.reshape(-1)].add(
            ye_row.reshape(E * C, D), mode="drop")

    y = jax.vmap(combine_row)(ye, tok)
    return x + shard(y.astype(x.dtype), "act"), aux
