import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_XLA_EXTRA", "") + " --xla_force_host_platform_device_count=" + os.environ.get("REPRO_DRYRUN_DEVICES", "512")).strip()  # noqa: E501 -- MUST precede any jax import
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST run before any jax import — jax locks the device
count at first init.  512 placeholder host devices back the production
meshes: (16, 16) single-pod and (2, 16, 16) multi-pod.

Per cell this driver:
  1. builds the model + step function (train_step / prefill / decode),
  2. attaches in/out shardings from ``repro.sharding.rules``,
  3. ``jit(...).lower(**input_specs).compile()`` — ShapeDtypeStructs only,
     nothing is allocated,
  4. records memory_analysis (fits-in-HBM proof), cost_analysis (FLOPs /
     bytes) and the HLO collective schedule (ops, bytes, axes) to a JSON
     artifact in ``artifacts/dryrun/`` (resumable: existing cells skip).

Usage:
  python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k --mesh multi
  python -m repro.launch.dryrun --all [--mesh both] [--force]
"""
import argparse
import dataclasses
import functools
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import SHAPES, all_cells, get_config, input_specs
from ..models.model import build_model, init_cache, init_params
from ..sharding import rules
from ..sharding.partition import MeshInfo, use_sharding
from ..train.optimizer import OptConfig, adamw_init
from .mesh import make_production_mesh

ARTIFACT_DIR = os.path.join("artifacts", "dryrun")

# Per-(arch, shape) execution overrides for the production lowering:
# microbatch count (activation memory) and q-chunk (attention logits), plus
# head padding for TP-unfriendly head counts (llava 56 -> 64; zero-padded,
# function-exact).
OVERRIDES: dict[str, dict] = {
    "grok-1-314b": dict(microbatches={"train_4k": 16}, opt_int8=True,
                        accum_dtype="bfloat16",
                        q_chunk={"train_4k": 2048, "prefill_32k": 2048}),
    "llava-next-34b": dict(pad_heads_to=64,
                           microbatches={"train_4k": 16},
                           q_chunk={"train_4k": 512, "prefill_32k": 512}),
    "recurrentgemma-9b": dict(microbatches={"train_4k": 8},
                              q_chunk={"prefill_32k": 2048}),
    "falcon-mamba-7b": dict(microbatches={"train_4k": 8}),
    "moonshot-v1-16b-a3b": dict(microbatches={"train_4k": 8},
                                q_chunk={"prefill_32k": 2048}),
    "qwen2.5-3b": dict(microbatches={"train_4k": 4},
                       q_chunk={"train_4k": 2048, "prefill_32k": 2048}),
    "qwen3-1.7b": dict(microbatches={"train_4k": 2},
                       q_chunk={"train_4k": 2048, "prefill_32k": 2048}),
    "tinyllama-1.1b": dict(microbatches={"train_4k": 2},
                           q_chunk={"train_4k": 2048,
                                    "prefill_32k": 2048}),
    "smollm-360m": dict(microbatches={},   # §Perf A4: grads reduce once
                        # §Perf A3: seq-sharded attention makes q-chunking
                        # redundant at train (logits already 16x smaller);
                        # chunk-reshape regathers were the last wire driver
                        q_chunk={"prefill_32k": 512},
                        # §Perf A2: 360M params -> replicate weights, run
                        # the whole mesh as 256-way data/sequence parallel
                        replicate_params=True, seq_parallel=True),
    "seamless-m4t-medium": dict(microbatches={"train_4k": 4},
                                q_chunk={"train_4k": 2048,
                                         "prefill_32k": 2048}),
}


def prod_config(arch: str, shape: str, *, scan_layers: bool = False):
    """The exact arch config with production lowering knobs applied."""
    cfg = get_config(arch)
    ov = OVERRIDES.get(arch, {})
    rep: dict = dict(dtype="bfloat16", scan_layers=scan_layers,
                     attn_impl="ref", remat=True)
    if "pad_heads_to" in ov:
        rep["pad_heads_to"] = ov["pad_heads_to"]
    qc = ov.get("q_chunk", {}).get(shape)
    if qc:
        rep["q_chunk"] = qc
    return dataclasses.replace(cfg, **rep), ov.get(
        "microbatches", {}).get(shape, 1)


def mesh_info_for(mesh, global_batch: int) -> MeshInfo:
    """Batch-aware axis roles: B == 1 cells move the data axes into TP."""
    names = mesh.axis_names
    dp = tuple(a for a in names if a in ("pod", "data"))
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    # Multi-pod policy: FSDP stays intra-pod (weight gathers on ICI only);
    # the pod axis carries plain DP (one cross-DCI grad reduce per step).
    fsdp = tuple(a for a in dp if a != "pod") or None
    if global_batch == 1:
        return MeshInfo(mesh=mesh, dp=(), tp=tuple(names))
    if global_batch % dp_size != 0:
        # shed pod axis from dp if that fixes divisibility
        dp2 = tuple(a for a in dp if a != "pod")
        dp_size2 = 1
        for a in dp2:
            dp_size2 *= mesh.shape[a]
        if global_batch % dp_size2 == 0:
            return MeshInfo(mesh=mesh, dp=dp2, tp="model", fsdp_over=dp2)
        raise ValueError(f"batch {global_batch} unshardable on {names}")
    return MeshInfo(mesh=mesh, dp=dp, tp="model", fsdp_over=fsdp)


# ---------------------------------------------------------------------------
# Step builders (lower-only; no allocation).
# ---------------------------------------------------------------------------

SERVING_TP_ONLY_LIMIT = 3e9   # per-chip param bytes under TP-only sharding


def _serving_param_specs(cfg, param_shapes, mi, fsdp_specs):
    """Inference param sharding: TP-only when the per-chip footprint
    allows (kills the per-step FSDP weight all-gathers — §Perf iteration
    B1); FSDP otherwise (grok-314B).  REPRO_SERVING_FSDP=1 forces the
    FSDP baseline for before/after measurements."""
    if os.environ.get("REPRO_SERVING_FSDP") == "1":
        return fsdp_specs
    per_chip = sum(
        x.size * jnp.dtype(x.dtype).itemsize
        for x in jax.tree.leaves(param_shapes)) / max(mi.tp_size, 1)
    if per_chip > SERVING_TP_ONLY_LIMIT:
        return fsdp_specs
    mi_tp = MeshInfo(mesh=mi.mesh, dp=(), tp=mi.tp)
    return rules.param_pspecs(cfg, param_shapes, mi_tp)


def build_cell(arch: str, shape: str, mesh, *, scan_layers=False):
    from ..train.step import build_train_step

    cfg, microbatches = prod_config(arch, shape, scan_layers=scan_layers)
    sh = SHAPES[shape]
    mi = mesh_info_for(mesh, sh.global_batch)
    # §Perf C4: the global microbatch must not drop below the dp shard
    # count, or GSPMD pads every chip to a whole row (silent 2x flops).
    microbatches = max(1, min(microbatches,
                              sh.global_batch // max(mi.dp_size, 1)))
    specs = input_specs(arch, shape)
    model = build_model(cfg)
    cache_len = sh.seq_len
    ov = OVERRIDES.get(arch, {})
    ctx = rules.make_ctx(cfg, mi, cache_len=cache_len,
                         seq_shard_attn=(sh.kind != "decode"))
    if ov.get("seq_parallel") and sh.kind != "decode":
        dp_ax = tuple(mi.dp) or None
        ctx.act_specs["act"] = P(dp_ax, mi.tp, None)
        ctx.act_specs["act_heads"] = P(dp_ax, mi.tp, None, None)
        ctx.act_specs["act_ff"] = P(dp_ax, mi.tp, None)
        ctx.act_specs["logits"] = P(dp_ax, mi.tp, None)

    param_shapes = jax.eval_shape(
        functools.partial(init_params, cfg), jax.random.PRNGKey(0))
    if ov.get("replicate_params"):
        p_specs = jax.tree.map(lambda _: P(), param_shapes)
    else:
        p_specs = rules.param_pspecs(cfg, param_shapes, mi)
    b_specs = rules.batch_pspecs(specs, mi)
    named = lambda tree: jax.tree.map(lambda s: NamedSharding(mesh, s), tree)

    if sh.kind == "train":
        opt_cfg = OptConfig(
            state_int8=OVERRIDES.get(arch, {}).get("opt_int8", False))
        state_shapes = {
            "params": param_shapes,
            "opt": jax.eval_shape(
                functools.partial(adamw_init, opt_cfg), param_shapes),
        }
        # Hierarchical ZeRO (§Perf C3): optimizer state shards over
        # (pod, data) — it is never gathered, so the extra pod dimension
        # costs one cross-DCI grad reduce-scatter + param all-gather per
        # step instead of doubling resident state.
        mi_opt = dataclasses.replace(mi, fsdp_over=tuple(mi.dp))
        o_specs = rules.param_pspecs(cfg, state_shapes["opt"], mi_opt)
        # opt m/v mirror params; scalar step replicated
        o_specs["step"] = P()
        state_specs = {"params": p_specs, "opt": o_specs}
        step = build_train_step(
            model, opt_cfg, microbatches=microbatches,
            accum_dtype=OVERRIDES.get(arch, {}).get("accum_dtype",
                                                    "float32"))

        def fn(state, batch):
            with use_sharding(ctx):
                return step(state, batch)

        jfn = jax.jit(fn,
                      in_shardings=(named(state_specs), named(b_specs)),
                      out_shardings=(named(state_specs), None),
                      donate_argnums=(0,))
        args = (state_shapes, specs)
    elif sh.kind == "prefill":
        p_specs = _serving_param_specs(cfg, param_shapes, mi, p_specs)

        def fn(params, batch):
            with use_sharding(ctx):
                return model.prefill(params, batch, cache_len)

        cache_shapes = jax.eval_shape(
            lambda: init_cache(cfg, sh.global_batch, cache_len,
                               mem_len=sh.seq_len
                               if cfg.family == "encdec" else 0))
        c_specs = rules.cache_pspecs(cfg, cache_shapes, mi,
                                     cache_len=cache_len)
        jfn = jax.jit(fn,
                      in_shardings=(named(p_specs), named(b_specs)),
                      out_shardings=(None, named(c_specs)))
        args = (param_shapes, specs)
    else:  # decode
        p_specs = _serving_param_specs(cfg, param_shapes, mi, p_specs)
        mem_len = sh.seq_len if cfg.family == "encdec" else 0
        cache_shapes = jax.eval_shape(
            lambda: init_cache(cfg, sh.global_batch, cache_len,
                               mem_len=mem_len))
        c_specs = rules.cache_pspecs(cfg, cache_shapes, mi,
                                     cache_len=cache_len)

        def fn(params, batch, caches):
            with use_sharding(ctx):
                return model.decode_step(params, batch, caches)

        jfn = jax.jit(fn,
                      in_shardings=(named(p_specs), named(b_specs),
                                    named(c_specs)),
                      out_shardings=(None, named(c_specs)),
                      donate_argnums=(2,))
        args = (param_shapes, specs, cache_shapes)
    return jfn, args, cfg, mi, microbatches


# ---------------------------------------------------------------------------
# Analyses
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1}

_COLL_RE = re.compile(
    r"(\w[\w\d.\-]*)\s*=\s*(\([^)]*\)|\S+?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([\d,]+)\}")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str, n_chips: int) -> dict:
    """Per-op-kind wire-bytes-per-chip (ring algorithm estimates).

    result-shape bytes R, group size g:
      all-gather:        R is gathered (full) -> wire/chip = R*(g-1)/g
      all-reduce:        R == operand         -> wire/chip = 2R*(g-1)/g
      reduce-scatter:    R is the shard       -> wire/chip = R*(g-1)
      all-to-all:        R == operand         -> wire/chip = R*(g-1)/g
      collective-permute:R == operand         -> wire/chip = R
    """
    out: dict[str, dict] = {}
    per_chip_total = 0.0
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        _, type_str, kind, _ = m.groups()
        R = _shape_bytes(type_str)
        g = n_chips
        mg = _GROUPS_RE.search(line)
        if mg:
            g = len(mg.group(1).split(","))
        else:
            mg2 = _GROUPS_V2_RE.search(line)
            if mg2:
                g = int(mg2.group(2))
        g = max(g, 1)
        if kind == "all-gather":
            wire = R * (g - 1) / g
        elif kind == "all-reduce":
            wire = 2 * R * (g - 1) / g
        elif kind == "reduce-scatter":
            wire = R * (g - 1)
        elif kind == "all-to-all":
            wire = R * (g - 1) / g
        else:
            wire = R
        d = out.setdefault(kind, {"count": 0, "wire_bytes_per_chip": 0.0})
        d["count"] += 1
        d["wire_bytes_per_chip"] += wire
        per_chip_total += wire
    return {"ops": out, "wire_bytes_per_chip": per_chip_total}


def analyze(compiled, n_chips: int) -> dict:
    from .hlo_cost import analyze_hlo, xla_cost_analysis

    ca = xla_cost_analysis(compiled)
    ma = compiled.memory_analysis()
    mem = {}
    for f in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        v = getattr(ma, f, None)
        if v is not None:
            mem[f] = int(v)
    txt = compiled.as_text()
    wa = analyze_hlo(txt, n_chips)        # while-aware exact accounting
    return {
        "flops_total": float(wa["flops"]),
        "bytes_accessed_total": float(wa["bytes"]),
        "convert_bytes_total": float(wa.get("convert_bytes", 0.0)),
        "xla_flops_body_once": float(ca.get("flops", -1)),
        "xla_bytes_body_once": float(ca.get("bytes accessed", -1)),
        "memory_analysis": mem,
        "collectives": {"ops": wa["collectives"],
                        "wire_bytes_per_chip": wa["wire_bytes_per_chip"],
                        "cross_pod_bytes_per_chip":
                            wa.get("cross_pod_bytes_per_chip", 0.0)},
        "n_collective_lines": sum(d["count"]
                                  for d in wa["collectives"].values()),
        "top_collectives": [
            {"path": p[-60:], "kind": k, "wire_bytes": round(w, 1),
             "shape": sh}
            for (p, k, w, sh) in wa["schedule"][:12]],
    }


def _mesh_for(mesh_kind: str):
    """Production mesh, or a reduced test mesh via REPRO_TEST_MESH=RxC."""
    tm = os.environ.get("REPRO_TEST_MESH")
    if tm:
        dims = tuple(int(x) for x in tm.split("x"))
        axes = (("pod", "data", "model") if len(dims) == 3
                else ("data", "model"))
        return jax.make_mesh(dims, axes)
    return make_production_mesh(multi_pod=(mesh_kind == "multi"))


def run_cell(arch: str, shape: str, mesh_kind: str, *, out_dir=ARTIFACT_DIR,
             force=False, scan_layers=True) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    tag = f"{arch}__{shape}__{mesh_kind}"
    path = os.path.join(out_dir, tag + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    mesh = _mesh_for(mesh_kind)
    n_chips = mesh.size
    t0 = time.time()
    rec = {"arch": arch, "shape": shape, "mesh": mesh_kind,
           "n_chips": n_chips, "ok": False}
    try:
        jfn, args, cfg, mi, mb = build_cell(arch, shape, mesh,
                                            scan_layers=scan_layers)
        lowered = jfn.lower(*args)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
        rec.update(analyze(compiled, n_chips))
        rec.update(ok=True, lower_s=round(t1 - t0, 1),
                   compile_s=round(t2 - t1, 1), microbatches=mb,
                   dp=list(mi.dp), tp=list(mi.tp) if isinstance(mi.tp, tuple)
                   else [mi.tp], scan_layers=scan_layers)
        n_params = sum(x.size for x in jax.tree.leaves(jax.eval_shape(
            functools.partial(init_params, cfg), jax.random.PRNGKey(0))))
        rec["n_params"] = int(n_params)
    except Exception as e:  # noqa: BLE001 — record the failure, keep going
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--unrolled", action="store_true",
                    help="unroll layers (slow compile; cross-checks the "
                         "while-aware cost analysis)")
    ap.add_argument("--out", default=ARTIFACT_DIR)
    args = ap.parse_args()
    meshes = {"single": ["single"], "multi": ["multi"],
              "both": ["single", "multi"]}[args.mesh]
    cells = all_cells() if args.all else [(args.arch, args.shape)]
    for arch, shape in cells:
        for mk in meshes:
            rec = run_cell(arch, shape, mk, out_dir=args.out,
                           force=args.force,
                           scan_layers=not args.unrolled)
            status = "OK " if rec.get("ok") else "FAIL"
            mem = rec.get("memory_analysis", {})
            per_dev = (mem.get("argument_size_in_bytes", 0)
                       + mem.get("temp_size_in_bytes", 0)) / 1e9
            print(f"[{status}] {arch:22s} {shape:12s} {mk:6s} "
                  f"flops={rec.get('flops_total', 0):.3e} "
                  f"mem/dev={per_dev:.2f}GB "
                  f"coll={rec.get('n_collective_lines', '-')}"
                  + ("" if rec.get("ok")
                     else "  " + rec.get("error", "")[:120]),
                  flush=True)


if __name__ == "__main__":
    main()
