"""End-to-end training driver.

Selects an assigned architecture (``--arch``), builds the sharded train
step on the available mesh (host devices on CPU; the production mesh shapes
on a real cluster), and runs the fault-tolerant loop on the synthetic
pipeline.  ``--smoke`` uses the reduced config (CPU-sized).

Example (the (b) deliverable's ~100M-model run):
  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m --smoke \
      --steps 300 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import functools

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import get_config
from ..data.pipeline import DataConfig, TokenStream
from ..models.model import build_model
from ..sharding import rules
from ..sharding.partition import MeshInfo, use_sharding
from ..train.loop import LoopConfig, run
from ..train.optimizer import OptConfig
from ..train.step import build_train_step, init_state
from .mesh import make_host_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--compress-int8", action="store_true")
    ap.add_argument("--model-par", type=int, default=1)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    model = build_model(cfg)
    opt_cfg = OptConfig(lr=args.lr, total_steps=args.steps,
                        warmup_steps=max(args.steps // 20, 5),
                        compress_int8=args.compress_int8)

    mesh = make_host_mesh(args.model_par)
    mi = MeshInfo(mesh=mesh, dp=("data",), tp="model")
    ctx = rules.make_ctx(cfg, mi)
    state = init_state(model, opt_cfg, jax.random.PRNGKey(0))
    p_specs = rules.param_pspecs(cfg, state["params"], mi)
    o_specs = rules.param_pspecs(cfg, state["opt"], mi)
    o_specs["step"] = P()
    named = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t)
    st_specs = named({"params": p_specs, "opt": o_specs})
    state = jax.device_put(state, st_specs)

    raw_step = build_train_step(model, opt_cfg,
                                microbatches=args.microbatches)

    def fn(state, batch):
        with use_sharding(ctx):
            return raw_step(state, batch)

    jitted = jax.jit(fn, in_shardings=(st_specs, None),
                     out_shardings=(st_specs, None), donate_argnums=(0,))

    stream = TokenStream(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                    global_batch=args.batch))
    loop_cfg = LoopConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                          ckpt_every=args.ckpt_every, log_every=10)
    state, ls = run(loop_cfg, state=state, train_step=jitted, stream=stream,
                    state_shardings=st_specs)
    if ls.history:
        print(f"[train] done: step {ls.step}, "
              f"loss {ls.history[0][1]:.3f} -> {ls.history[-1][1]:.3f}, "
              f"stragglers {ls.n_stragglers}")


if __name__ == "__main__":
    main()
