"""Paper Table V: placements evaluated per time budget.

The paper's CPU implementation evaluates one placement at a time (87.0k/17.3k
homog, 8.5k/1.2k hetero per 3600 s).  Our TPU-native adaptation scores a
whole batch per call (vmapped Floyd-Warshall).  This bench measures
evaluations/second single vs batched — the beyond-paper speedup claimed in
DESIGN.md §3 — plus the area deltas of §VII-E, plus the sweep-level win:
``run_sweep`` shares one jitted scorer across configs (no recompilation).
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.core.api import (Budget, ExperimentConfig, GAParams,
                            clear_scorer_cache, make_evaluator, make_rep,
                            run_sweep, scorer_cache_stats)
from repro.core.baseline import MeshBaseline
from repro.core.chiplets import paper_arch
from repro.core.registries import OPTIMIZERS

from .common import budget, emit, out_dir


def eval_rate(arch_name: str, chunk: int, n: int, quick: bool) -> float:
    """chunk == 1 measures the paper-style per-placement loop (one scoring
    call per placement, python dispatch included); chunk > 1 measures the
    TPU-native batched evaluation (one vmapped call per chunk)."""
    arch = paper_arch(arch_name, "baseline")
    rep = make_rep(arch, arch_name)
    rng = np.random.default_rng(0)
    ev = make_evaluator(rep, arch, rng=rng, norm_samples=max(chunk, 8),
                        chunk=chunk)
    sols, graphs = ev.generate_valid(rep.random, rng, n)
    ev.costs(graphs[:chunk])          # warm the jit cache
    t0 = time.perf_counter()
    if chunk == 1:
        for g in graphs:
            ev.costs([g])
    else:
        ev.costs(graphs)
    dt = time.perf_counter() - t0
    return n / dt


def run(quick: bool = True) -> dict:
    results = {}
    n = budget(quick, 48, 512)
    for name in ("homog32", "hetero32"):
        r1 = eval_rate(name, chunk=1, n=n, quick=quick)
        rb = eval_rate(name, chunk=budget(quick, 16, 64), n=n, quick=quick)
        results[name] = dict(scalar_per_s=r1, batched_per_s=rb,
                             ratio=rb / r1)
        # paper Table V: 87.0k (homog32) / 8.5k (hetero32) BR placements
        # per 3600 s = 24.2 / 2.4 evals/s on a Xeon X7550.
        paper = {"homog32": 24.2, "hetero32": 2.4}[name]
        emit(f"table5_{name}_evals_per_s_scalar", round(r1, 1),
             f"paper={paper}/s ({r1 / paper:.1f}x)")
        emit(f"table5_{name}_evals_per_s_batched", round(rb, 1),
             "CPU note: batching loses L2 locality on 1 core; the batched "
             "win is a TPU/VMEM property (Pallas FW kernel)")

    # -- sweep-level amortization: one jitted scorer across configs --------
    clear_scorer_cache()
    sweep_cfgs = [
        ExperimentConfig("homog32", algorithms=("sa",),
                         repetitions=budget(quick, 2, 4),
                         budget=Budget(evals=budget(quick, 16, 200)),
                         norm_samples=8, seed=s)
        for s in range(budget(quick, 2, 4))]
    t0 = time.perf_counter()
    sres = run_sweep(sweep_cfgs)
    sweep_s = time.perf_counter() - t0
    emit("table5_sweep_scorers_built", sres.stats.scorers_built,
         f"{len(sweep_cfgs)} configs share 1 jitted scorer "
         f"({sres.stats.n_evaluated} evals in {sweep_s:.1f}s, "
         f"{sres.stats.n_evaluated / max(sweep_s, 1e-9):.1f}/s)")
    results["sweep"] = dict(configs=len(sweep_cfgs),
                            scorers_built=sres.stats.scorers_built,
                            n_evaluated=sres.stats.n_evaluated,
                            seconds=sweep_s,
                            cache=scorer_cache_stats())

    # §VII-E area comparison (heterogeneous only; homogeneous is constant)
    arch = paper_arch("hetero32", "baseline")
    rep = make_rep(arch, "hetero32")
    rng = np.random.default_rng(1)
    ev = make_evaluator(rep, arch, rng=rng,
                        norm_samples=budget(quick, 24, 500))
    ga = OPTIMIZERS.get("ga")
    pop = budget(quick, 16, 30)
    res = ga.fn(ev, rng, Budget(evals=pop * budget(quick, 6, 40)),
                GAParams(population=pop, elitism=4, tournament=4))
    base_area = float(MeshBaseline(arch).build()[0].area)
    opt_area = float(res.best_metrics["area"])
    delta = (opt_area - base_area) / base_area
    results["area"] = dict(baseline=base_area, ga=opt_area, delta=delta)
    emit("areaE_hetero32_ga_vs_baseline", round(delta, 4),
         f"ga={opt_area:.0f}mm2 base={base_area:.0f}mm2 "
         f"(paper: GA -8.1%)")
    with open(os.path.join(out_dir(), "table5_area.json"), "w") as f:
        json.dump(results, f, indent=1, default=float)
    return results


def main(quick: bool = True):
    run(quick)


if __name__ == "__main__":
    main(quick=os.environ.get("BENCH_FULL", "") != "1")
