"""Benchmark orchestrator: one module per paper table/figure + beyond-paper.

  PYTHONPATH=src python -m benchmarks.run [--full] [--only NAME]

Emits ``BENCH,name,value,derived`` CSV lines and JSON artifacts under
artifacts/bench/; each module's artifact is additionally *merged* into
``BENCH_<name>.json`` at the repo root so the perf trajectory is versioned
alongside the code (artifacts/ is transient).  Merging is section-wise
(recursive on dict values): a run that only exercises a subset of a
module's sections — quick mode skips expensive ones — updates those keys
and preserves the rest, instead of churning the whole versioned file.
Quick mode targets CI budgets; --full approaches the paper's budgets.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import shutil
import time
import traceback

MODULES = [
    ("fig6_fig12_optimizers", "paper Figs. 6/12: BR/GA/SA vs baseline"),
    ("fig14_15_synthetic", "paper Figs. 14/15: synthetic traffic"),
    ("fig16_18_traces", "paper Figs. 16-18: trace speedups"),
    ("table5_rate", "paper Table V: placements/s + §VII-E area"),
    ("pipeline_throughput", "beyond-paper: device-resident pipeline vs "
                            "host loop (PR 2)"),
    ("pareto_frontier", "beyond-paper: device Pareto fronts + stacked "
                        "scalarization grids (PR 5)"),
    ("design_service", "beyond-paper: continuous-batching design engine "
                       "vs sequential runs (PR 6)"),
    ("netsim_device", "beyond-paper: device netsim rate model vs host "
                      "sim + trace-guided search (PR 8)"),
    ("kernels", "kernel micro-benches"),
    ("bridge_roofline", "beyond-paper: bridge co-design + roofline"),
]


ARTIFACT_DIR = os.path.join("artifacts", "bench")


def _snapshot() -> dict[str, float]:
    return {p: os.path.getmtime(p)
            for p in glob.glob(os.path.join(ARTIFACT_DIR, "*.json"))}


def _merge(old, new):
    """Section-wise merge: new keys win, dict values merge recursively,
    keys only present in ``old`` survive (partial runs must not drop the
    sections they skipped)."""
    out = dict(old)
    for k, v in new.items():
        if isinstance(v, dict) and isinstance(out.get(k), dict):
            out[k] = _merge(out[k], v)
        else:
            out[k] = v
    return out


def promote_artifacts(before: dict[str, float]) -> list[str]:
    """Merge artifacts written/updated since ``before`` into the repo-root
    ``BENCH_<stem>.json`` (the versioned perf trajectory).  Non-dict or
    unreadable JSON falls back to a plain copy."""
    promoted = []
    for p in glob.glob(os.path.join(ARTIFACT_DIR, "*.json")):
        if p in before and os.path.getmtime(p) <= before[p]:
            continue
        stem = os.path.splitext(os.path.basename(p))[0]
        dst = f"BENCH_{stem}.json"
        merged = None
        if os.path.exists(dst):
            try:
                with open(p) as f:
                    new = json.load(f)
                with open(dst) as f:
                    old = json.load(f)
                if isinstance(new, dict) and isinstance(old, dict):
                    merged = _merge(old, new)
            except (json.JSONDecodeError, OSError):
                merged = None
        if merged is not None:
            with open(dst, "w") as f:
                json.dump(merged, f, indent=1)
        else:
            shutil.copyfile(p, dst)
        promoted.append(dst)
    return promoted


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    t_all = time.monotonic()
    failures = []
    for name, desc in MODULES:
        if args.only and args.only not in name:
            continue
        mod = __import__(f"benchmarks.bench_{name}", fromlist=["main"])
        print(f"\n=== bench_{name}: {desc} ===", flush=True)
        t0 = time.monotonic()
        before = _snapshot()
        try:
            mod.main(quick=not args.full)
            promoted = promote_artifacts(before)
            print(f"=== bench_{name} done in "
                  f"{time.monotonic() - t0:.1f}s"
                  + (f"; promoted {', '.join(promoted)}" if promoted else "")
                  + " ===", flush=True)
        except Exception as e:  # noqa: BLE001 — keep the suite running
            failures.append(name)
            print(f"=== bench_{name} FAILED: {type(e).__name__}: {e} ===")
            traceback.print_exc()
    print(f"\nTOTAL {time.monotonic() - t_all:.1f}s; "
          f"failures: {failures or 'none'}")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
