"""Mesh context + activation sharding constraints.

Model code calls ``shard(x, logical_name)`` at block boundaries.  With no
installed context this is a no-op (single-device tests); under
``use_sharding(ctx)`` it applies ``with_sharding_constraint`` with the
PartitionSpec the rules assigned to that logical activation — the same model
code serves laptop smoke tests and the 512-chip dry-run.
"""
from __future__ import annotations

import contextlib
import contextvars
from dataclasses import dataclass, field

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_CTX: contextvars.ContextVar = contextvars.ContextVar(
    "repro_sharding_ctx", default=None)


@dataclass(frozen=True)
class MeshInfo:
    """Physical mesh + the axis roles the rules map logical dims onto."""

    mesh: Mesh
    dp: tuple[str, ...]           # data-parallel axes, e.g. ("pod", "data")
    tp: str = "model"             # tensor-parallel axis
    # FSDP axes for parameter/optimizer shards; None -> same as dp.  The
    # multi-pod policy keeps FSDP *intra-pod* (("data",)) so per-layer
    # weight gathers never cross the slow DCI links — the pod axis then
    # carries one grad all-reduce per step instead (§Perf iteration C1).
    fsdp_over: tuple[str, ...] | None = None

    @property
    def tp_size(self) -> int:
        axes = self.tp if isinstance(self.tp, tuple) else (self.tp,)
        n = 1
        for a in axes:
            n *= self.mesh.shape[a]
        return n

    @property
    def dp_size(self) -> int:
        n = 1
        for a in self.dp:
            n *= self.mesh.shape[a]
        return n

    @property
    def fsdp(self):
        """Axes over which parameter/optimizer shards are scattered."""
        if self.fsdp_over is not None:
            return tuple(self.fsdp_over)
        return self.dp if len(self.dp) == 1 else tuple(self.dp)

    def named(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)


@dataclass
class ShardingCtx:
    mi: MeshInfo
    act_specs: dict[str, P] = field(default_factory=dict)


@contextlib.contextmanager
def use_sharding(ctx: ShardingCtx | None):
    tok = _CTX.set(ctx)
    try:
        yield
    finally:
        _CTX.reset(tok)


def current_ctx() -> ShardingCtx | None:
    return _CTX.get()


def shard(x, name: str):
    """Constrain activation ``x`` to the logical sharding ``name``."""
    ctx = _CTX.get()
    if ctx is None:
        return x
    spec = ctx.act_specs.get(name)
    if spec is None:
        return x
    # Pad the spec with trailing None to the rank of x.
    ps = tuple(spec) + (None,) * (x.ndim - len(spec))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mi.mesh, P(*ps)))
