"""Tiered static-adjacency ScoreGraph assembly for 3D / hierarchical grids.

The 2D homogeneous builder (``core.topology.HomogGraphBatch``) exploits the
fact that an R x C grid's candidate-link structure is *static*: each cell
adjacency either carries a D2D link (both facing PHYs exist) or not, so
link inference is masked selection over a fixed adjacency table.  This
module generalizes that trick along three axes at once:

* **a third grid dimension** — placements are ``[R, C, Z]``; vertical
  (TSV) adjacencies join the same cell across layers,
* **weight tiers** — every adjacency carries a tier index
  (``TIER_PLANAR`` / ``TIER_BACKBONE`` / ``TIER_VERTICAL``) and the tier
  latency values enter :meth:`Grid3DGraphBatch.build` as a *runtime*
  ``[3]`` operand (like ``edge_len`` / norms / weights), so sweeping
  ``tsv_slowdown`` or backbone factors never retraces,
* **pluggable adjacency generation** — a family is just a list of
  :class:`AdjRecord`; ``stack`` families use the full planar mesh + TSV
  pillars, ``gateway`` families keep planar links intra-cluster and join
  clusters only through per-cluster gateway PHYs
  (``W_INTRA < W_BACKBONE < W_VERTICAL``), and registered *augmentations*
  (``torus`` wraparound, ``express`` skip links — the
  ``@register_augmentation`` registry) add long-range candidates instead
  of the paper's greedy leftover-PHY augmentation.

PHY attachment per adjacency endpoint: a planar endpoint names the facing
side (4-PHY chiplets use that side's PHY; 1-PHY chiplets participate only
when rotated to face it); a vertical endpoint (``loc == -1``) attaches at
the chiplet's first PHY regardless of rotation — the TSV is a through-die
via, not a shoreline PHY.

``score_graph3d_host`` is the independent host reference (python loops,
same padded slot layout) the device builder is tested bit-for-bit against.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.chiplets import ArchSpec
from repro.core.registries import AUGMENTATIONS, register_augmentation
from repro.core.topology import (DIR_DELTA, INF, OPP_DIR, ROT_DIR,
                                 ScoreGraph, _UnionFind)

TIER_PLANAR, TIER_BACKBONE, TIER_VERTICAL = 0, 1, 2
N_TIERS = 3


@dataclass(frozen=True)
class AdjRecord:
    """One static candidate adjacency of a 3D grid family.

    ``cell1``/``cell2`` are flat cell ids ``(r * C + c) * Z + z``;
    ``loc1``/``loc2`` the facing side's ``"nesw"`` local PHY index or -1
    for a vertical (any-PHY) attachment; ``rot1``/``rot2`` the rotation a
    1-PHY chiplet must have to participate (-1 = any); ``tier`` indexes
    the runtime tier-latency vector; ``length`` is the in-plane mm gap
    between the attachment points (0.0 for touching cells and TSVs).
    """

    cell1: int
    cell2: int
    loc1: int
    loc2: int
    rot1: int
    rot2: int
    tier: int
    length: float


def _cid(r: int, c: int, z: int, C: int, Z: int) -> int:
    return (r * C + c) * Z + z


def _side_mid(r: int, c: int, side: str, sz: float) -> tuple[float, float]:
    """In-plane mm position of a cell side's midpoint (the PHY spot)."""
    mids = {"n": (sz / 2, sz), "s": (sz / 2, 0.0),
            "e": (sz, sz / 2), "w": (0.0, sz / 2)}
    mx, my = mids[side]
    return (c * sz + mx, r * sz + my)


def _planar_record(arch: ArchSpec, r, c, z, rr, cc, d: str, C, Z,
                   tier: int) -> AdjRecord:
    o = OPP_DIR[d]
    sz = arch.chiplets[0].w
    length = arch.dist(_side_mid(r, c, d, sz), _side_mid(rr, cc, o, sz))
    return AdjRecord(cell1=_cid(r, c, z, C, Z), cell2=_cid(rr, cc, z, C, Z),
                     loc1="nesw".index(d), loc2="nesw".index(o),
                     rot1=ROT_DIR.index(d), rot2=ROT_DIR.index(o),
                     tier=tier, length=float(length))


def grid3d_adjacency(arch: ArchSpec, R: int, C: int, Z: int, *,
                     kind: str = "stack",
                     cluster: tuple[int, int] | None = None
                     ) -> list[AdjRecord]:
    """Base adjacency records of a 3D grid family (augmentations ride on
    top via the ``AUGMENTATIONS`` registry).

    ``stack``: the full planar mesh per layer (``TIER_PLANAR``) plus a TSV
    pillar per cell (``TIER_VERTICAL``).  ``gateway``: planar adjacencies
    only *within* a ``cluster = (cr, cc)`` tile; clusters are joined by
    backbone links between the gateway cells (each cluster's low corner)
    of grid-adjacent clusters (``TIER_BACKBONE``), and TSVs exist only at
    gateways — traffic between clusters or layers must route through the
    gateway hierarchy.
    """
    if kind not in ("stack", "gateway"):
        raise ValueError(f"unknown 3D family kind {kind!r}")
    if kind == "gateway":
        if cluster is None:
            raise ValueError("gateway families need cluster=(cr, cc)")
        cr, cc = cluster
        if R % cr or C % cc:
            raise ValueError(f"cluster {cluster} does not tile {R}x{C}")
    recs: list[AdjRecord] = []
    sz = arch.chiplets[0].w
    is_gw = (lambda r, c: r % cr == 0 and c % cc == 0) \
        if kind == "gateway" else (lambda r, c: True)
    for z in range(Z):
        # Planar adjacencies, each scanned once ("n"/"e") like the 2D rep.
        for r in range(R):
            for c in range(C):
                for d in ("n", "e"):
                    dr, dc = DIR_DELTA[d]
                    rr, cc2 = r + dr, c + dc
                    if not (0 <= rr < R and 0 <= cc2 < C):
                        continue
                    if kind == "gateway" and \
                            (r // cr, c // cc) != (rr // cr, cc2 // cc):
                        continue      # cross-cluster mesh link: backbone only
                    recs.append(_planar_record(arch, r, c, z, rr, cc2, d,
                                               C, Z, TIER_PLANAR))
        # Backbone links between grid-adjacent clusters' gateways.
        if kind == "gateway":
            for br in range(R // cr):
                for bc in range(C // cc):
                    r0, c0 = br * cr, bc * cc
                    if bc + 1 < C // cc:        # east neighbor cluster
                        c1 = (bc + 1) * cc
                        length = arch.dist(_side_mid(r0, c0, "e", sz),
                                           _side_mid(r0, c1, "w", sz))
                        recs.append(AdjRecord(
                            cell1=_cid(r0, c0, z, C, Z),
                            cell2=_cid(r0, c1, z, C, Z),
                            loc1="nesw".index("e"), loc2="nesw".index("w"),
                            rot1=ROT_DIR.index("e"), rot2=ROT_DIR.index("w"),
                            tier=TIER_BACKBONE, length=float(length)))
                    if br + 1 < R // cr:        # north neighbor cluster
                        r1 = (br + 1) * cr
                        length = arch.dist(_side_mid(r0, c0, "n", sz),
                                           _side_mid(r1, c0, "s", sz))
                        recs.append(AdjRecord(
                            cell1=_cid(r0, c0, z, C, Z),
                            cell2=_cid(r1, c0, z, C, Z),
                            loc1="nesw".index("n"), loc2="nesw".index("s"),
                            rot1=ROT_DIR.index("n"), rot2=ROT_DIR.index("s"),
                            tier=TIER_BACKBONE, length=float(length)))
    # Vertical TSV pillars (every cell for stacks, gateways only for the
    # hierarchy).  loc/rot -1: attach at the chiplet's first PHY.
    for r in range(R):
        for c in range(C):
            if not is_gw(r, c):
                continue
            for z in range(Z - 1):
                recs.append(AdjRecord(
                    cell1=_cid(r, c, z, C, Z), cell2=_cid(r, c, z + 1, C, Z),
                    loc1=-1, loc2=-1, rot1=-1, rot2=-1,
                    tier=TIER_VERTICAL, length=0.0))
    return recs


@register_augmentation("torus")
def torus_augment(R: int, C: int, Z: int, sz_mm: float,
                  params: dict) -> list[AdjRecord]:
    """Wraparound candidate links per layer: row wrap
    ``(r, C-1) e <-> (r, 0) w`` and column wrap ``(R-1, c) n <-> (0, c) s``
    (``TIER_BACKBONE``; the wrap length is the physical span)."""
    recs = []
    for z in range(Z):
        for r in range(R):
            if C > 2:     # C == 2 wrap duplicates the mesh adjacency
                recs.append(AdjRecord(
                    cell1=_cid(r, C - 1, z, C, Z), cell2=_cid(r, 0, z, C, Z),
                    loc1="nesw".index("e"), loc2="nesw".index("w"),
                    rot1=ROT_DIR.index("e"), rot2=ROT_DIR.index("w"),
                    tier=TIER_BACKBONE, length=float(C * sz_mm)))
        for c in range(C):
            if R > 2:
                recs.append(AdjRecord(
                    cell1=_cid(R - 1, c, z, C, Z), cell2=_cid(0, c, z, C, Z),
                    loc1="nesw".index("n"), loc2="nesw".index("s"),
                    rot1=ROT_DIR.index("n"), rot2=ROT_DIR.index("s"),
                    tier=TIER_BACKBONE, length=float(R * sz_mm)))
    return recs


@register_augmentation("express")
def express_augment(R: int, C: int, Z: int, sz_mm: float,
                    params: dict) -> list[AdjRecord]:
    """Express skip links per layer: ``(r, c) <-> (r, c + stride)`` and
    ``(r, c) <-> (r + stride, c)`` (default stride 2, ``TIER_BACKBONE``) —
    the SW3D-style long-range shortcuts."""
    stride = int(params.get("stride", 2))
    if stride < 2:
        raise ValueError("express stride must be >= 2")
    recs = []
    for z in range(Z):
        for r in range(R):
            for c in range(C - stride):
                recs.append(AdjRecord(
                    cell1=_cid(r, c, z, C, Z),
                    cell2=_cid(r, c + stride, z, C, Z),
                    loc1="nesw".index("e"), loc2="nesw".index("w"),
                    rot1=ROT_DIR.index("e"), rot2=ROT_DIR.index("w"),
                    tier=TIER_BACKBONE,
                    length=float((stride - 1) * sz_mm)))
        for c in range(C):
            for r in range(R - stride):
                recs.append(AdjRecord(
                    cell1=_cid(r, c, z, C, Z),
                    cell2=_cid(r + stride, c, z, C, Z),
                    loc1="nesw".index("n"), loc2="nesw".index("s"),
                    rot1=ROT_DIR.index("n"), rot2=ROT_DIR.index("s"),
                    tier=TIER_BACKBONE,
                    length=float((stride - 1) * sz_mm)))
    return recs


def family_records(arch: ArchSpec, R: int, C: int, Z: int, *,
                   kind: str = "stack",
                   cluster: tuple[int, int] | None = None,
                   augment: str = "none",
                   augment_params: dict | None = None) -> list[AdjRecord]:
    """Base adjacency + the named registered augmentation's candidates."""
    recs = grid3d_adjacency(arch, R, C, Z, kind=kind, cluster=cluster)
    if augment != "none":
        fn = AUGMENTATIONS.get(augment)
        recs = recs + fn(R, C, Z, arch.chiplets[0].w, augment_params or {})
    return recs


def default_tier_values(arch: ArchSpec, *, tsv_slowdown: float = 4.0,
                        backbone_factor: float = 2.0) -> np.ndarray:
    """Tier latency vector ``[W_INTRA, W_BACKBONE, W_VERTICAL]`` [cycles].

    A D2D hop always crosses two PHYs; the tier scales only the *link*
    part: planar = ``2*l_phy + l_link``, backbone = ``2*l_phy +
    l_link*backbone_factor`` (longer span / serialized hierarchy link),
    vertical = ``2*l_phy + l_link*tsv_slowdown`` (TSV slowdown).  With the
    defaults (l_phy 12, l_link 1): 25 < 26 < 28.
    """
    lp, ll = arch.latency.l_phy, arch.latency.l_link
    return np.array([2.0 * lp + ll,
                     2.0 * lp + ll * backbone_factor,
                     2.0 * lp + ll * tsv_slowdown], np.float32)


# ---------------------------------------------------------------------------
# Device builder.
# ---------------------------------------------------------------------------


class Grid3DGraphBatch:
    """Batched ``(types, rot[, tiers]) -> stacked ScoreGraph arrays`` for
    one 3D grid family (its static :class:`AdjRecord` list)."""

    def __init__(self, arch: ArchSpec, R: int, C: int, Z: int,
                 records: list[AdjRecord],
                 tier_values: np.ndarray | None = None):
        self.arch, self.R, self.C, self.Z = arch, R, C, Z
        self.records = tuple(records)
        n = len(arch.chiplets)
        phy_base = np.zeros(n + 1, dtype=np.int64)
        for i, ch in enumerate(arch.chiplets):
            phy_base[i + 1] = phy_base[i] + ch.n_phys()
        Vp = int(phy_base[-1])
        self.Vp, self.N = Vp, n
        self.V = Vp + 2 * n
        self.e_max = 2 * len(records)
        self._nphys = jnp.asarray(
            np.array([ch.n_phys() for ch in arch.chiplets], np.int32))
        self._phy_base = jnp.asarray(phy_base[:-1].astype(np.int32))
        by_kind = {k: [i for i, ch in enumerate(arch.chiplets)
                       if ch.kind == k] for k in (0, 1, 2)}
        maxc = max(1, max(len(v) for v in by_kind.values()))
        table = np.zeros((3, maxc), np.int32)
        for k, ids in by_kind.items():
            table[k, :len(ids)] = ids
        self._kind_table = jnp.asarray(table)
        self._W_static = jnp.asarray(static_weight_matrix(arch))
        self._a_cell1 = np.array([a.cell1 for a in records], np.int32)
        self._a_cell2 = np.array([a.cell2 for a in records], np.int32)
        self._a_loc1 = np.array([a.loc1 for a in records], np.int32)
        self._a_loc2 = np.array([a.loc2 for a in records], np.int32)
        self._a_rot1 = np.array([a.rot1 for a in records], np.int32)
        self._a_rot2 = np.array([a.rot2 for a in records], np.int32)
        self._a_tier = jnp.asarray(
            np.array([a.tier for a in records], np.int32))
        self._a_len = jnp.asarray(
            np.array([a.length for a in records], np.float32))
        self._tiers_default = jnp.asarray(
            default_tier_values(arch) if tier_values is None
            else np.asarray(tier_values, np.float32))
        # §V-A get_area on the stacked package: the *footprint* is one
        # layer's R x C cells — stacking Z layers does not grow it.
        sz = arch.chiplets[0].w * arch.chiplets[0].h
        self.area = np.float32(sz * R * C)

    def _instances(self, tflat: jnp.ndarray) -> jnp.ndarray:
        """Flat-scan instance ids per cell ([B, cells], -1 for empty)."""
        inst = jnp.full(tflat.shape, -1, jnp.int32)
        for k in range(3):
            mk = tflat == k
            rank = jnp.cumsum(mk, axis=1) - 1
            rank = jnp.clip(rank, 0, self._kind_table.shape[1] - 1)
            inst = jnp.where(mk, self._kind_table[k][rank], inst)
        return inst

    def _phy_at(self, inst, rot, loc4, rotidx):
        """Global PHY index facing the adjacency (or -1).  ``loc4 == -1``
        (vertical attachment) resolves to the chiplet's first PHY for any
        rotation."""
        ic = jnp.clip(inst, 0)
        base = self._phy_base[ic]
        four = self._nphys[ic] == 4
        planar = jnp.where(four, base + jnp.maximum(loc4, 0),
                           jnp.where(rot == rotidx, base, -1))
        return jnp.where(loc4 < 0, base, planar)

    def build(self, types: jnp.ndarray, rot: jnp.ndarray,
              tiers: jnp.ndarray | None = None) -> dict:
        """[B, R, C, Z] stacked placements -> batched ScoreGraph arrays
        (``stack_graphs`` keys; jit/vmap-able).  ``tiers`` is the runtime
        ``[N_TIERS]`` latency vector (defaults to the construction-time
        values) — pass it as a jit operand so tsv/backbone sweeps never
        retrace."""
        B = types.shape[0]
        tflat = types.reshape(B, -1).astype(jnp.int32)
        rflat = rot.reshape(B, -1).astype(jnp.int32)
        tiers = (self._tiers_default if tiers is None
                 else jnp.asarray(tiers, jnp.float32))
        inst = self._instances(tflat)
        i1 = inst[:, self._a_cell1]
        i2 = inst[:, self._a_cell2]
        p = self._phy_at(i1, rflat[:, self._a_cell1], self._a_loc1,
                         self._a_rot1)
        q = self._phy_at(i2, rflat[:, self._a_cell2], self._a_loc2,
                         self._a_rot2)
        valid = (i1 >= 0) & (i2 >= 0) & (p >= 0) & (q >= 0)
        pu = jnp.where(valid, p, 0)
        qu = jnp.where(valid, q, 0)
        vals = jnp.where(valid, tiers[self._a_tier][None, :], INF)

        def one(pu1, qu1, v1):
            return self._W_static.at[pu1, qu1].min(v1).at[qu1, pu1].min(v1)

        W = jax.vmap(one)(pu, qu, vals)
        ed = jnp.stack([jnp.stack([pu, qu], axis=-1),
                        jnp.stack([qu, pu], axis=-1)], axis=2)
        edges = ed.reshape(B, self.e_max, 2).astype(jnp.int32)
        mask = jnp.broadcast_to(valid[:, :, None],
                                valid.shape + (2,)).reshape(B, self.e_max)
        elen = jnp.where(valid, self._a_len[None, :], 0.0)
        edge_len = jnp.broadcast_to(elen[:, :, None],
                                    elen.shape + (2,)).reshape(B, self.e_max)
        area = jnp.full((B,), self.area, jnp.float32)
        return dict(W=W, edges=edges, edge_mask=mask, area=area,
                    edge_len=edge_len)


def static_weight_matrix(arch: ArchSpec) -> np.ndarray:
    """Placement-independent part of W (diagonal, internal relay edges,
    virtual source/sink edges) — shared by the device builder and the host
    reference."""
    n = len(arch.chiplets)
    phy_base = np.zeros(n + 1, dtype=np.int64)
    for i, ch in enumerate(arch.chiplets):
        phy_base[i + 1] = phy_base[i] + ch.n_phys()
    Vp = int(phy_base[-1])
    V = Vp + 2 * n
    owner = np.zeros(Vp, dtype=np.int64)
    for i in range(n):
        owner[phy_base[i]:phy_base[i + 1]] = i
    W = np.full((V, V), INF, dtype=np.float32)
    np.fill_diagonal(W, 0.0)
    lr = np.float32(arch.latency.l_relay)
    for c in range(n):
        idx = np.nonzero(owner == c)[0]
        if arch.chiplets[c].relay:
            for a in range(len(idx)):
                for b in range(a + 1, len(idx)):
                    p, q = int(idx[a]), int(idx[b])
                    W[p, q] = min(W[p, q], lr)
                    W[q, p] = min(W[q, p], lr)
        W[Vp + c, idx] = 0.0
        W[idx, Vp + n + c] = 0.0
    return W


# ---------------------------------------------------------------------------
# Host reference (independent python-loop implementation, same slot layout).
# ---------------------------------------------------------------------------


def _host_instances(arch: ArchSpec, tflat: np.ndarray) -> np.ndarray:
    """Flat-scan instance assignment: the j-th cell of kind k gets the
    arch's j-th chiplet instance of that kind."""
    by_kind = {k: [i for i, ch in enumerate(arch.chiplets) if ch.kind == k]
               for k in (0, 1, 2)}
    counters = {k: 0 for k in by_kind}
    inst = np.full(tflat.shape, -1, np.int64)
    for j, k in enumerate(tflat):
        k = int(k)
        if k < 0:
            continue
        inst[j] = by_kind[k][counters[k]]
        counters[k] += 1
    return inst


def _host_phy(arch: ArchSpec, phy_base: np.ndarray, inst: int, rot: int,
              loc4: int, rotidx: int) -> int:
    if inst < 0:
        return -1
    base = int(phy_base[inst])
    if loc4 < 0:                       # vertical: first PHY, any rotation
        return base
    if arch.chiplets[inst].n_phys() == 4:
        return base + loc4
    return base if rot == rotidx else -1


def score_graph3d_host(arch: ArchSpec, records, types: np.ndarray,
                       rot: np.ndarray, tier_values: np.ndarray,
                       area: float) -> ScoreGraph:
    """Host reference: one placement -> ScoreGraph with the device
    builder's padded slot layout (slot 2k/2k+1 = record k's pq/qp rows,
    zeroed when the adjacency is not realized), so stacked host graphs
    compare bit-for-bit against :meth:`Grid3DGraphBatch.build`."""
    n = len(arch.chiplets)
    phy_base = np.zeros(n + 1, dtype=np.int64)
    for i, ch in enumerate(arch.chiplets):
        phy_base[i + 1] = phy_base[i] + ch.n_phys()
    tflat = np.asarray(types).reshape(-1)
    rflat = np.asarray(rot).reshape(-1)
    inst = _host_instances(arch, tflat)
    W = static_weight_matrix(arch).copy()
    A = len(records)
    edges = np.zeros((2 * A, 2), np.int32)
    mask = np.zeros((2 * A,), bool)
    elen = np.zeros((2 * A,), np.float32)
    tiers = np.asarray(tier_values, np.float32)
    links: list[tuple[int, int]] = []
    for k, a in enumerate(records):
        p = _host_phy(arch, phy_base, int(inst[a.cell1]),
                      int(rflat[a.cell1]), a.loc1, a.rot1)
        q = _host_phy(arch, phy_base, int(inst[a.cell2]),
                      int(rflat[a.cell2]), a.loc2, a.rot2)
        if p < 0 or q < 0:
            continue
        v = np.float32(tiers[a.tier])
        W[p, q] = min(W[p, q], v)
        W[q, p] = min(W[q, p], v)
        edges[2 * k] = (p, q)
        edges[2 * k + 1] = (q, p)
        mask[2 * k] = mask[2 * k + 1] = True
        elen[2 * k] = elen[2 * k + 1] = np.float32(a.length)
        links.append((int(inst[a.cell1]), int(inst[a.cell2])))
    # Chiplet-level connectivity (planar + vertical links both count).
    uf = _UnionFind(n)
    for u, v in links:
        uf.union(u, v)
    present = [int(i) for i in inst if i >= 0]
    connected = len({uf.find(i) for i in present}) == 1 if present else False
    return ScoreGraph(W=W, edges=edges, edge_mask=mask,
                      area=np.float32(area), connected=connected,
                      edge_len=elen)
