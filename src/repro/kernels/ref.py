"""Pure-jnp oracles for every Pallas kernel (the ground truth in tests).

Each function here is the semantic specification; the Pallas kernels in this
package must match them to ``assert_allclose`` tolerance across the shape /
dtype sweeps in ``tests/test_kernels_*.py``.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

INF_CUT = 1.0e8
_COUNT_CLIP = 1.0e30


# ---------------------------------------------------------------------------
# Min-plus / APSP (PlaceIT scoring hot spot).
# ---------------------------------------------------------------------------

def minplus_ref(A: jnp.ndarray, B: jnp.ndarray) -> jnp.ndarray:
    """Tropical (min, +) matrix product: out[i,j] = min_k A[i,k] + B[k,j]."""
    return jnp.min(A[..., :, :, None] + B[..., None, :, :], axis=-2)


def apsp_ref(W: jnp.ndarray) -> jnp.ndarray:
    """All-pairs shortest path distances by repeated min-plus squaring."""
    V = W.shape[-1]
    D = W
    n = max(1, math.ceil(math.log2(max(V - 1, 2))))
    for _ in range(n):
        D = jnp.minimum(D, minplus_ref(D, D))
    return D


def fw_counts_ref(W: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Floyd-Warshall distances + shortest-path counts.  W: [..., V, V].

    Identical math to ``repro.core.proxies.fw_counts_ref`` (re-exported there)
    — kept here as the kernel oracle.
    """
    V = W.shape[-1]
    D0 = W
    off = ~jnp.eye(V, dtype=bool)
    N0 = jnp.where((W < INF_CUT) & off, 1.0, 0.0) + jnp.eye(V, dtype=W.dtype)

    def body(k, carry):
        D, Ncnt = carry
        dik = jax.lax.dynamic_slice_in_dim(D, k, 1, axis=-1)
        dkj = jax.lax.dynamic_slice_in_dim(D, k, 1, axis=-2)
        nik = jax.lax.dynamic_slice_in_dim(Ncnt, k, 1, axis=-1)
        nkj = jax.lax.dynamic_slice_in_dim(Ncnt, k, 1, axis=-2)
        cand = dik + dkj
        ncand = jnp.minimum(nik * nkj, _COUNT_CLIP)
        notk = jnp.arange(V) != k
        mask = notk[:, None] & notk[None, :]
        lt = (cand < D) & mask
        eq = (cand == D) & mask & (cand < INF_CUT)
        D = jnp.where(lt, cand, D)
        Ncnt = jnp.where(lt, ncand, Ncnt + jnp.where(eq, ncand, 0.0))
        Ncnt = jnp.minimum(Ncnt, _COUNT_CLIP)
        return D, Ncnt

    return jax.lax.fori_loop(0, V, body, (D0, N0))


# ---------------------------------------------------------------------------
# Attention (training / prefill).
# ---------------------------------------------------------------------------

def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                  causal: bool = True, window: int | None = None,
                  scale: float | None = None,
                  softcap: float | None = None,
                  pos_offset=None) -> jnp.ndarray:
    """GQA attention oracle.

    q: [B, Sq, Hq, d]; k, v: [B, Sk, Hkv, d] with Hq % Hkv == 0.
    ``window``: sliding-window size (attend to keys in (i-window, i]).
    ``pos_offset``: absolute position of query 0 (may be traced); defaults
    to end-alignment (Sk - Sq), supporting Sq != Sk (decode/prefill chunks).
    """
    B, Sq, Hq, d = q.shape
    _, Sk, Hkv, _ = k.shape
    assert Hq % Hkv == 0
    g = Hq // Hkv
    scale = (d ** -0.5) if scale is None else scale
    qh = q.reshape(B, Sq, Hkv, g, d)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qh.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    if pos_offset is None:
        pos_offset = Sk - Sq
    qpos = jnp.arange(Sq) + pos_offset
    kpos = jnp.arange(Sk)
    mask = jnp.ones((Sq, Sk), dtype=bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= kpos[None, :] > qpos[:, None] - window
    logits = jnp.where(mask[None, None, None], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)  # fully-masked rows -> zero output
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return out.reshape(B, Sq, Hq, d).astype(q.dtype)


def decode_attention_ref(q: jnp.ndarray, k_cache: jnp.ndarray,
                         v_cache: jnp.ndarray, lengths: jnp.ndarray, *,
                         scale: float | None = None,
                         window: int | None = None,
                         softcap: float | None = None) -> jnp.ndarray:
    """Single-token GQA decode oracle.

    q: [B, Hq, d]; caches: [B, S, Hkv, d]; lengths: [B] valid prefix length
    (the new token's position is lengths-1, already written to the cache).
    """
    B, Hq, d = q.shape
    _, S, Hkv, _ = k_cache.shape
    g = Hq // Hkv
    scale = (d ** -0.5) if scale is None else scale
    qh = q.reshape(B, Hkv, g, d)
    logits = jnp.einsum("bhgd,bkhd->bhgk", qh.astype(jnp.float32),
                        k_cache.astype(jnp.float32)) * scale
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    kpos = jnp.arange(S)[None]                       # [1, S]
    mask = kpos < lengths[:, None]
    if window is not None:
        mask &= kpos > (lengths[:, None] - 1 - window)
    logits = jnp.where(mask[:, None, None], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)
    out = jnp.einsum("bhgk,bkhd->bhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, Hq, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# Mamba-1 selective scan.
# ---------------------------------------------------------------------------

def selective_scan_ref(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
                       B: jnp.ndarray, C: jnp.ndarray, D: jnp.ndarray,
                       h0: jnp.ndarray | None = None
                       ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Sequential selective-scan oracle (Mamba-1, diagonal A).

    x, dt: [Bt, S, Di]; A: [Di, N]; B, C: [Bt, S, N]; D: [Di].
    Discretization (ZOH on A, Euler on B, as in the Mamba paper):
        h_t = exp(dt_t * A) * h_{t-1} + (dt_t * x_t) B_t
        y_t = (h_t C_t).sum(N) + D * x_t
    Returns (y [Bt, S, Di], h_final [Bt, Di, N]).
    """
    Bt, S, Di = x.shape
    N = A.shape[-1]
    if h0 is None:
        h0 = jnp.zeros((Bt, Di, N), dtype=jnp.float32)

    def step(h, inp):
        xt, dtt, Bt_, Ct = inp                     # [Bt,Di],[Bt,Di],[Bt,N],[Bt,N]
        dA = jnp.exp(dtt[..., None] * A[None])     # [Bt, Di, N]
        dBx = (dtt * xt)[..., None] * Bt_[:, None, :]
        h = dA * h + dBx
        y = jnp.einsum("bdn,bn->bd", h, Ct) + D[None] * xt
        return h, y

    xs = (jnp.moveaxis(x, 1, 0).astype(jnp.float32),
          jnp.moveaxis(dt, 1, 0).astype(jnp.float32),
          jnp.moveaxis(B, 1, 0).astype(jnp.float32),
          jnp.moveaxis(C, 1, 0).astype(jnp.float32))
    h, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), h


# ---------------------------------------------------------------------------
# RG-LRU (recurrentgemma / Griffin).
# ---------------------------------------------------------------------------

def rglru_ref(x: jnp.ndarray, a: jnp.ndarray,
              h0: jnp.ndarray | None = None
              ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Diagonal linear recurrence oracle: h_t = a_t * h_{t-1} + b_t where
    b_t = sqrt(1 - a_t^2) * x_t  (the RG-LRU input normalization).

    x, a: [B, S, D] (a in (0, 1)).  Returns (h [B, S, D], h_final [B, D]).
    """
    if h0 is None:
        h0 = jnp.zeros(x.shape[:1] + x.shape[2:], dtype=jnp.float32)
    b = jnp.sqrt(jnp.maximum(1.0 - a.astype(jnp.float32) ** 2, 0.0)) \
        * x.astype(jnp.float32)

    def step(h, inp):
        at, bt = inp
        h = at * h + bt
        return h, h

    hf, hs = jax.lax.scan(
        step, h0, (jnp.moveaxis(a.astype(jnp.float32), 1, 0),
                   jnp.moveaxis(b, 1, 0)))
    return jnp.moveaxis(hs, 0, 1).astype(x.dtype), hf
