"""End-to-end LM training driver (deliverable b): train a model for a few
hundred steps on the synthetic pipeline with the fault-tolerant loop
(checkpoint/restart, straggler telemetry) through the public API.

CPU default: a ~10M-parameter llama-family model, 300 steps (the identical
script runs any assigned arch at full size on a real cluster via
``repro.launch.train``).

  PYTHONPATH=src python examples/train_lm.py [--steps 300] [--d-model 256]
"""
import argparse
import dataclasses

import jax

from repro.configs import get_config
from repro.data.pipeline import DataConfig, TokenStream
from repro.models.model import build_model
from repro.train.loop import LoopConfig, run
from repro.train.optimizer import OptConfig
from repro.train.step import build_train_step, init_state


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_example_ckpt")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced(
        n_layers=args.layers, d_model=args.d_model,
        d_ff=args.d_model * 3, vocab=4096, vocab_pad_to=512,
        n_heads=args.d_model // 64, n_kv_heads=max(args.d_model // 128, 1),
        head_dim=64)
    model = build_model(cfg)
    n_params = model.param_count(model.init(jax.random.PRNGKey(0)))
    print(f"model: {cfg.name}  ({n_params/1e6:.1f}M params)")

    opt = OptConfig(lr=3e-3, warmup_steps=20, total_steps=args.steps,
                    weight_decay=0.01)
    state = init_state(model, opt, jax.random.PRNGKey(0))
    step = build_train_step(model, opt, microbatches=1)
    stream = TokenStream(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                    global_batch=args.batch))
    loop = LoopConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                      ckpt_every=100, log_every=20)
    state, ls = run(loop, state=state, train_step=jax.jit(step),
                    stream=stream)
    if ls.history:
        first, last = ls.history[0][1], ls.history[-1][1]
        print(f"\nloss {first:.3f} -> {last:.3f} over {ls.step} steps "
              f"({ls.n_stragglers} straggler steps)")


if __name__ == "__main__":
    main()
