#!/usr/bin/env sh
# Tier-1 CI: fast test pass (slow-marked tests excluded) + a quick
# pipeline-throughput bench smoke (set CI_SKIP_BENCH=1 to skip it).
#   scripts/ci.sh [extra pytest args...]
set -eu
cd "$(dirname "$0")/.."
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m pytest -q -m "not slow" "$@"
if [ "${CI_SKIP_BENCH:-0}" != "1" ]; then
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
        python -m benchmarks.run --only pipeline
fi
