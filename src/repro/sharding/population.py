"""Population-axis sharding for stacked ScoreGraph scoring.

The jitted batched scorer (``proxies.make_scorer``) is elementwise over
its leading population axis — every row is one placement's ScoreGraph plus
its per-row normalizer/weight vectors.  That makes device parallelism a
pure data partition: :func:`shard_scorer` wraps a compiled scorer with
``shard_map`` over a 1-D ``"pop"`` mesh so each device scores its slice of
the stacked batch, with no cross-device collectives at all.

Rows are padded (by repeating row 0) to a multiple of the device count
and the padding is sliced off on the way out, so any batch size works.
On a single device the wrapper runs the *same* per-row computation on the
same data — bit-for-bit identical to the unwrapped scorer (pinned by
``tests/test_design_service.py``) — which is the safe fallback
``run_sweep(shard=True)`` and the design service rely on when no
multi-device mesh exists.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def population_mesh(devices=None) -> Mesh:
    """1-D mesh over ``devices`` (default: all) with axis name ``"pop"``."""
    devs = list(jax.devices()) if devices is None else list(devices)
    return Mesh(np.array(devs), ("pop",))


def n_pop_devices(mesh: Mesh | None = None) -> int:
    return int((mesh or population_mesh()).devices.size)


def _per_row(v, rows: int) -> np.ndarray:
    """Broadcast a [D] runtime vector to per-row [rows, D] (already-2-D
    vectors pass through) so it shards along ``"pop"`` like the batch."""
    v = np.asarray(v, np.float32)
    if v.ndim == 1:
        v = np.broadcast_to(v, (rows,) + v.shape)
    return np.ascontiguousarray(v)


def shard_scorer(scorer, mesh: Mesh | None = None):
    """Wrap a jitted batched scorer so the population axis is split across
    ``mesh``'s devices with ``shard_map``.

    Returns ``call(batch, norms, weights) -> metrics`` with the scorer's
    signature; ``norms``/``weights`` may be single vectors or per-row
    matrices (they are always broadcast per-row before sharding, which is
    value-identical to the scorer's own internal broadcast).
    """
    mesh = mesh or population_mesh()
    n = n_pop_devices(mesh)

    sharded = shard_map(
        lambda b, no, w: scorer(b, no, w), mesh=mesh,
        in_specs=(P("pop"), P("pop"), P("pop")), out_specs=P("pop"),
        check_rep=False)

    def call(batch, norms, weights):
        rows = int(np.asarray(batch["W"]).shape[0])
        norms = _per_row(norms, rows)
        weights = _per_row(weights, rows)
        pad = (-rows) % n
        if pad:
            def padrow(v):
                v = jnp.asarray(v)
                return jnp.concatenate([v, jnp.repeat(v[:1], pad, axis=0)])
            batch = {k: padrow(v) for k, v in batch.items()}
            norms = np.concatenate(
                [norms, np.repeat(norms[:1], pad, axis=0)])
            weights = np.concatenate(
                [weights, np.repeat(weights[:1], pad, axis=0)])
        out = sharded(batch, jnp.asarray(norms), jnp.asarray(weights))
        return {k: v[:rows] for k, v in out.items()}

    call.mesh = mesh
    call.n_devices = n
    return call
