"""Generic decoder stack: dense / MoE / SSM / hybrid layers, one code path.

Layers are grouped into homogeneous runs (``LMConfig.layer_plan``); each
group's parameters are stacked on a leading axis and applied with
``lax.scan`` (O(1) HLO size in depth — required for the 64 AOT dry-run
compiles) or with an unrolled python loop (``scan_layers=False`` — exact
``cost_analysis`` FLOPs, used by the roofline dry-run; XLA counts a while
body once, see DESIGN.md §7).  ``remat`` wraps each layer body with
``jax.checkpoint``.

Layer kinds:
    attn   — GQA attention + SwiGLU MLP (dense; also encoder with mask off)
    moe    — GQA attention + top-k MoE MLP
    mamba  — Mamba-1 block
    rec    — RG-LRU recurrent block + MLP (griffin)
    lattn  — local (sliding-window) attention + MLP (griffin)
    super  — one griffin super-block: cfg.pattern of rec/lattn sub-blocks
    xdec   — decoder layer with cross-attention (encoder-decoder)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .config import LMConfig
from . import layers as L
from .moe import moe_init, moe_mlp
from .rglru import rglru_cache_init, rglru_decode, rglru_init, rglru_train
from .ssm import mamba_cache_init, mamba_decode, mamba_init, mamba_train


# ---------------------------------------------------------------------------
# Single-layer init / apply, dispatched on kind.
# ---------------------------------------------------------------------------

def layer_init(kind: str, key, cfg: LMConfig) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    if kind == "attn":
        return {"attn": L.attn_init(k1, cfg), "mlp": L.mlp_init(k2, cfg)}
    if kind == "moe":
        return {"attn": L.attn_init(k1, cfg), "moe": moe_init(k2, cfg)}
    if kind == "mamba":
        return {"mamba": mamba_init(k1, cfg)}
    if kind == "rec":
        return {"rec": rglru_init(k1, cfg), "mlp": L.mlp_init(k2, cfg)}
    if kind == "lattn":
        return {"attn": L.attn_init(k1, cfg), "mlp": L.mlp_init(k2, cfg)}
    if kind == "super":
        out = {}
        for i, ch in enumerate(cfg.pattern):
            sub = "rec" if ch == "r" else "lattn"
            out[f"s{i}"] = layer_init(sub, jax.random.fold_in(key, i), cfg)
        return out
    if kind == "xdec":
        return {"attn": L.attn_init(k1, cfg), "xattn": L.xattn_init(k2, cfg),
                "mlp": L.mlp_init(k3, cfg)}
    raise ValueError(kind)


def _win(cfg: LMConfig) -> int | None:
    return cfg.window or None


def layer_train(kind: str, p, x, cfg: LMConfig, pos, extra) -> tuple:
    """Returns (x, aux) — aux is the MoE load-balance loss contribution."""
    aux = jnp.zeros((), jnp.float32)
    if kind == "attn":
        x = L.attn_train(p["attn"], x, cfg, pos,
                         causal=not extra.get("bidir", False))
        x = L.mlp(p["mlp"], x, cfg)
    elif kind == "moe":
        x = L.attn_train(p["attn"], x, cfg, pos)
        x, aux = moe_mlp(p["moe"], x, cfg)
    elif kind == "mamba":
        x = mamba_train(p["mamba"], x, cfg)
    elif kind == "rec":
        x = rglru_train(p["rec"], x, cfg)
        x = L.mlp(p["mlp"], x, cfg)
    elif kind == "lattn":
        x = L.attn_train(p["attn"], x, cfg, pos, window=_win(cfg))
        x = L.mlp(p["mlp"], x, cfg)
    elif kind == "super":
        for i, ch in enumerate(cfg.pattern):
            sub = "rec" if ch == "r" else "lattn"
            x, a = layer_train(sub, p[f"s{i}"], x, cfg, pos, extra)
            aux = aux + a
    elif kind == "xdec":
        x = L.attn_train(p["attn"], x, cfg, pos)
        x = L.xattn(p["xattn"], x, extra["memory"], cfg)
        x = L.mlp(p["mlp"], x, cfg)
    else:
        raise ValueError(kind)
    return x, aux


def layer_prefill(kind: str, p, x, cfg: LMConfig, pos, cache_len: int,
                  extra) -> tuple:
    aux = jnp.zeros((), jnp.float32)
    if kind == "attn":
        x, c = L.attn_prefill(p["attn"], x, cfg, pos, cache_len=cache_len)
        x = L.mlp(p["mlp"], x, cfg)
    elif kind == "moe":
        x, c = L.attn_prefill(p["attn"], x, cfg, pos, cache_len=cache_len)
        x, aux = moe_mlp(p["moe"], x, cfg)
    elif kind == "mamba":
        x, c = mamba_train(p["mamba"], x, cfg, return_cache=True)
    elif kind == "rec":
        x, c = rglru_train(p["rec"], x, cfg, return_cache=True)
        x = L.mlp(p["mlp"], x, cfg)
    elif kind == "lattn":
        w = _win(cfg)
        cl = min(cache_len, w) if w else cache_len
        x, c = L.attn_prefill(p["attn"], x, cfg, pos, window=w, cache_len=cl)
        x = L.mlp(p["mlp"], x, cfg)
    elif kind == "super":
        c = {}
        for i, ch in enumerate(cfg.pattern):
            sub = "rec" if ch == "r" else "lattn"
            x, ci, a = layer_prefill(sub, p[f"s{i}"], x, cfg, pos, cache_len,
                                     extra)
            c[f"s{i}"] = ci
            aux = aux + a
    elif kind == "xdec":
        x, c = L.attn_prefill(p["attn"], x, cfg, pos, cache_len=cache_len)
        x = L.xattn(p["xattn"], x, extra["memory"], cfg)
        c = {"self": c, "cross": L.xattn_kv(p["xattn"], extra["memory"], cfg)}
        x = L.mlp(p["mlp"], x, cfg)
    else:
        raise ValueError(kind)
    return x, c, aux


def layer_decode(kind: str, p, x, cache, cfg: LMConfig, length, extra
                 ) -> tuple:
    if kind in ("attn", "moe"):
        x, cache = L.attn_decode(p["attn"], x, cache, cfg, length)
        if kind == "attn":
            x = L.mlp(p["mlp"], x, cfg)
        else:
            x, _ = moe_mlp(p["moe"], x, cfg)
    elif kind == "mamba":
        x, cache = mamba_decode(p["mamba"], x, cache, cfg, length)
    elif kind == "rec":
        x, cache = rglru_decode(p["rec"], x, cache, cfg, length)
        x = L.mlp(p["mlp"], x, cfg)
    elif kind == "lattn":
        x, cache = L.attn_decode(p["attn"], x, cache, cfg, length,
                                 window=_win(cfg))
        x = L.mlp(p["mlp"], x, cfg)
    elif kind == "super":
        nc = {}
        for i, ch in enumerate(cfg.pattern):
            sub = "rec" if ch == "r" else "lattn"
            x, nc[f"s{i}"] = layer_decode(sub, p[f"s{i}"], x, cache[f"s{i}"],
                                          cfg, length, extra)
        cache = nc
    elif kind == "xdec":
        x, sc = L.attn_decode(p["attn"], x, cache["self"], cfg, length)
        x = L.xattn_decode(p["xattn"], x, cache["cross"], cfg,
                           extra["mem_len"])
        x = L.mlp(p["mlp"], x, cfg)
        cache = {"self": sc, "cross": cache["cross"]}
    else:
        raise ValueError(kind)
    return x, cache


def layer_cache_init(kind: str, cfg: LMConfig, B: int, cache_len: int,
                     mem_len: int = 0):
    if kind in ("attn", "moe"):
        return L.attn_cache_init(cfg, B, cache_len)
    if kind == "mamba":
        return mamba_cache_init(cfg, B)
    if kind == "rec":
        return rglru_cache_init(cfg, B)
    if kind == "lattn":
        return L.attn_cache_init(cfg, B, cache_len, window=_win(cfg))
    if kind == "super":
        return {f"s{i}": layer_cache_init("rec" if ch == "r" else "lattn",
                                          cfg, B, cache_len)
                for i, ch in enumerate(cfg.pattern)}
    if kind == "xdec":
        kv = jnp.zeros((B, mem_len, cfg.n_kv_heads, cfg.hd),
                       jnp.dtype(cfg.dtype))
        return {"self": L.attn_cache_init(cfg, B, cache_len),
                "cross": {"k": kv, "v": kv}}
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Stacks of homogeneous groups: init + scan/unrolled application.
# ---------------------------------------------------------------------------

def stack_init(key, cfg: LMConfig, plan=None) -> list:
    groups = []
    plan = plan if plan is not None else cfg.layer_plan()
    for gi, (kind, n) in enumerate(plan):
        keys = jax.random.split(jax.random.fold_in(key, gi), n)
        groups.append(jax.vmap(
            lambda k, kind=kind: layer_init(kind, k, cfg))(keys))
    return groups


def _idx(tree, i: int):
    return jax.tree.map(lambda t: t[i], tree)


def stack_train(groups, x, cfg: LMConfig, pos, extra=None, plan=None):
    extra = extra or {}
    aux = jnp.zeros((), jnp.float32)
    plan = plan if plan is not None else cfg.layer_plan()
    for (kind, n), gp in zip(plan, groups):
        body = functools.partial(layer_train, kind, cfg=cfg, pos=pos,
                                 extra=extra)
        if cfg.remat:
            body = jax.checkpoint(
                lambda p, x, _b=body: _b(p, x),
                policy=jax.checkpoint_policies.nothing_saveable)
        if cfg.scan_layers:
            def scan_body(carry, p, _b=body):
                x, aux = carry
                x, a = _b(p, x)
                return (x, aux + a), None
            (x, aux), _ = jax.lax.scan(scan_body, (x, aux), gp)
        else:
            for i in range(n):
                x, a = body(_idx(gp, i), x)
                aux = aux + a
    return x, aux


def stack_prefill(groups, x, cfg: LMConfig, pos, cache_len: int,
                  extra=None, plan=None):
    extra = extra or {}
    aux = jnp.zeros((), jnp.float32)
    caches = []
    plan = plan if plan is not None else cfg.layer_plan()
    for (kind, n), gp in zip(plan, groups):
        body = functools.partial(layer_prefill, kind, cfg=cfg, pos=pos,
                                 cache_len=cache_len, extra=extra)
        if cfg.scan_layers:
            def scan_body(carry, p, _b=body):
                x, aux = carry
                x, c, a = _b(p, x)
                return (x, aux + a), c
            (x, aux), cs = jax.lax.scan(scan_body, (x, aux), gp)
        else:
            per = []
            for i in range(n):
                x, c, a = body(_idx(gp, i), x)
                per.append(c)
                aux = aux + a
            cs = jax.tree.map(lambda *ts: jnp.stack(ts), *per)
        caches.append(cs)
    return x, caches, aux


def stack_decode(groups, x, caches, cfg: LMConfig, length, extra=None,
                 plan=None):
    """Decode pass.  In scan mode the stacked caches are a *loop carry*
    updated in place with dynamic_update_slice (XLA aliases carried while
    buffers — one resident cache copy instead of the separate read/write
    stacks a (xs, ys)-scan would allocate; at 32k contexts the KV cache is
    the dominant decode buffer)."""
    extra = extra or {}
    new_caches = []
    plan = plan if plan is not None else cfg.layer_plan()
    for (kind, n), gp, cs in zip(plan, groups, caches):
        body = functools.partial(layer_decode, kind, cfg=cfg, length=length,
                                 extra=extra)
        if cfg.scan_layers:
            def loop_body(i, carry, _b=body, gp=gp):
                x, cs = carry
                p = _idx(gp, i)
                c = jax.tree.map(
                    lambda t: jax.lax.dynamic_index_in_dim(
                        t, i, 0, keepdims=False), cs)
                x, c_new = _b(p, x, c)
                cs = jax.tree.map(
                    lambda buf, cn: jax.lax.dynamic_update_index_in_dim(
                        buf, cn.astype(buf.dtype), i, 0), cs, c_new)
                return x, cs

            x, cs = jax.lax.fori_loop(0, n, loop_body, (x, cs))
        else:
            outs = []
            for i in range(n):
                x, c = body(_idx(gp, i), x, _idx(cs, i))
                outs.append(c)
            cs = jax.tree.map(lambda *ts: jnp.stack(ts), *outs)
        new_caches.append(cs)
    return x, new_caches


def stack_cache_init(cfg: LMConfig, B: int, cache_len: int, plan=None,
                     mem_len: int = 0):
    caches = []
    plan = plan if plan is not None else cfg.layer_plan()
    for kind, n in plan:
        one = layer_cache_init(kind, cfg, B, cache_len, mem_len=mem_len)
        caches.append(jax.tree.map(
            lambda t: jnp.broadcast_to(t[None], (n,) + t.shape).copy(), one))
    return caches
