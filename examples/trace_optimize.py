"""Traffic-driven placement search: optimize directly against a trace.

  PYTHONPATH=src python examples/trace_optimize.py

The proxy cost function scores placements on uniform per-class traffic.
With the layered netsim (``repro.netsim``) a real trace becomes a
first-class optimization target instead:

1. generate a Netrace-like dependency trace (``core.traces``) and compile
   it into a :class:`~repro.netsim.Workload` — fixed-shape per-pair demand
   tensors, hashable and serde-able;
2. add a ``trace-lat`` objective term: the device-resident rate model
   (ECMP load distribution + saturating queueing delay) scores every
   candidate placement against the workload *inside* the jitted scorer.
   The workload is a runtime operand, so swapping traces or scaling
   injection rates re-dispatches the same compiled scorer — zero retraces;
3. sweep the proxy-only and trace-guided configs under the same budget
   and seed, then host-simulate both winners on the trace with the
   event-driven oracle (``repro.netsim.sim``) to see the guided search
   land at a lower simulated latency.
"""
import numpy as np

from repro.core.api import Budget, ExperimentConfig, make_rep, run_sweep
from repro.core.baseline import MeshBaseline
from repro.core.chiplets import paper_arch
from repro.core.objective import Objective, TermSpec
from repro.core.traces import TraceRegion, generate_trace
from repro.netsim import ChipletNet, NetSim, Workload


def main():
    arch = paper_arch("homog32", "placeit")
    _, geo_b, links_b = MeshBaseline(arch).build()
    net_base = ChipletNet.from_links(arch, geo_b, links_b)

    # -- 1. trace -> workload ------------------------------------------------
    regions = (TraceRegion(5000, 20000),)
    trace = generate_trace(net_base, regions, seed=7)
    cycles = sum(r.n_cycles for r in regions)
    wl = Workload.from_trace(trace, arch.kinds(), cycles, name="parsec-like")
    print(f"trace: {len(trace)} packets over {cycles} cycles -> {wl}")
    print(f"  per-class rates [pk/cycle]: "
          f"{np.round(wl.rate.sum(axis=(1, 2)), 4)}")

    # -- 2. proxy-only vs trace-guided sweep, same budget/seed ---------------
    base = dict(arch="homog32", config="placeit", algorithms=("ga",),
                budget=Budget(evals=400), norm_samples=32, chunk=16, seed=0)
    guided_obj = Objective().with_terms(TermSpec("trace-lat", weight=2.0))
    res = run_sweep([
        ExperimentConfig(**base),
        ExperimentConfig(**base, objective=guided_obj, workload=wl),
    ])
    print(f"\nsweep: scorers compiled {res.stats.scorers_built}, "
          f"scorer dispatches {res.stats.score_calls}")

    # -- 3. host-simulate both winners on the trace --------------------------
    rep = make_rep(arch, "homog32", None)

    def host_latency(sol):
        links, _ = rep.links_of(sol)
        net = ChipletNet.from_links(arch, rep.geometry(sol), links)
        ok = [p for p in trace if net.next_hop[p.src, p.dst] >= 0]
        return NetSim(net, arch).run(ok, mode="authentic").avg_latency

    lat_mesh = NetSim(net_base, arch).run(trace).avg_latency
    lat_proxy = host_latency(res.runs[0].records[0].result.best_sol)
    lat_guided = host_latency(res.runs[1].records[0].result.best_sol)
    print(f"\nhost-simulated average packet latency [cycles]:")
    print(f"  2D-mesh baseline : {lat_mesh:8.2f}")
    print(f"  proxy-only best  : {lat_proxy:8.2f}")
    print(f"  trace-lat best   : {lat_guided:8.2f}  "
          f"({100 * (1 - lat_guided / lat_proxy):+.1f}% vs proxy)")


if __name__ == "__main__":
    main()
