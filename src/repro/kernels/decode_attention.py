"""Flash-decode Pallas kernel: single-token GQA attention over a KV cache.

The decode step's attention is memory-bound: it streams the whole KV cache
(B, S, Hkv, d) from HBM once per token.  The kernel tiles the cache along S
and keeps the online-softmax running state for the g = Hq/Hkv query rows of
one KV head in VMEM, so HBM traffic is exactly one cache read — the roofline
minimum.  Valid-length masking supports ragged batches; sliding-window
masking supports recurrentgemma local attention at 500k contexts (only the
last `window` positions are ever resident).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from . import _compat

NEG_INF = -1.0e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref,
                   m_scr, l_scr, acc_scr, *,
                   scale: float, window: int | None,
                   softcap: float | None, bs: int, g: int):
    isb = pl.program_id(2)
    ns = pl.num_programs(2)

    @pl.when(isb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[0, 0]
    kpos = isb * bs + jax.lax.broadcasted_iota(jnp.int32, (g, bs), 1)
    run = (isb * bs) < length
    if window is not None:
        run &= (isb * bs + bs - 1) > (length - 1 - window)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)        # (g, d)
        k = k_ref[0, 0].astype(jnp.float32)        # (bs, d)
        v = v_ref[0, 0].astype(jnp.float32)        # (bs, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        mask = kpos < length
        if window is not None:
            mask &= kpos > (length - 1 - window)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(isb == ns - 1)
    def _finalize():
        l = l_scr[...]
        o_ref[0, 0] = (acc_scr[...] / jnp.where(l == 0.0, 1.0, l)
                       ).astype(o_ref.dtype)


def decode_attention_pallas(q: jnp.ndarray, k_cache: jnp.ndarray,
                            v_cache: jnp.ndarray, lengths: jnp.ndarray, *,
                            scale: float | None = None,
                            window: int | None = None,
                            softcap: float | None = None,
                            bs: int = 256,
                            interpret: bool = True) -> jnp.ndarray:
    """q: [B, Hq, d]; caches: [B, S, Hkv, d]; lengths: [B] -> [B, Hq, d]."""
    B, Hq, d = q.shape
    _, S, Hkv, _ = k_cache.shape
    assert Hq % Hkv == 0
    g = Hq // Hkv
    scale = (d ** -0.5) if scale is None else scale
    qt = q.reshape(B, Hkv, g, d)
    kt = jnp.swapaxes(k_cache, 1, 2)               # [B, Hkv, S, d]
    vt = jnp.swapaxes(v_cache, 1, 2)
    bs_ = min(bs, max(8, S))
    Sp = -(-S // bs_) * bs_
    if Sp != S:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, Sp - S), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, Sp - S), (0, 0)))
    lens = lengths.astype(jnp.int32).reshape(B, 1)
    kern = functools.partial(_decode_kernel, scale=scale, window=window,
                             softcap=softcap, bs=bs_, g=g)
    out = pl.pallas_call(
        kern,
        grid=(B, Hkv, Sp // bs_),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, h, s: (b, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, g, d), lambda b, h, s: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bs_, d), lambda b, h, s: (b, h, s, 0)),
            pl.BlockSpec((1, 1, bs_, d), lambda b, h, s: (b, h, s, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d), lambda b, h, s: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, g, d), q.dtype),
        scratch_shapes=[pltpu.VMEM((g, 1), jnp.float32),
                        pltpu.VMEM((g, 1), jnp.float32),
                        pltpu.VMEM((g, d), jnp.float32)],
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(lens, qt, kt, vt)
    return out.reshape(B, Hq, d)
