"""Production meshes (defined as functions — importing this module never
touches jax device state).

Single pod: (16, 16) = 256 chips, axes (data, model).
Multi-pod:  (2, 16, 16) = 512 chips, axes (pod, data, model) — the pod axis
carries cross-pod data parallelism (and FSDP participation for the largest
models); `model` stays intra-pod where ICI is fastest.
"""
from __future__ import annotations

import jax

from ..sharding.partition import MeshInfo


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh_info(mesh) -> MeshInfo:
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    return MeshInfo(mesh=mesh, dp=dp, tp="model")


def make_host_mesh(n_model: int = 1):
    """Tiny mesh over whatever devices exist (CPU tests / examples)."""
    n = len(jax.devices())
    assert n % n_model == 0
    return jax.make_mesh((n // n_model, n_model), ("data", "model"))
