"""While-aware HLO cost analysis: exactness vs XLA on unrolled modules and
trip-count recovery on scanned modules."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_cost import analyze_hlo, parse_module, xla_cost_analysis


def compile_(f, *specs):
    return jax.jit(f).lower(*specs).compile()


def test_dot_flops_exact():
    M, K, N = 64, 96, 128
    f = lambda a, b: a @ b
    c = compile_(f, jax.ShapeDtypeStruct((M, K), jnp.float32),
                 jax.ShapeDtypeStruct((K, N), jnp.float32))
    got = analyze_hlo(c.as_text(), 1)
    assert got["flops"] == pytest.approx(2 * M * K * N, rel=1e-6)


def test_scan_trip_count_multiplies():
    D, L = 64, 7

    def f(x, ws):
        def body(c, w):
            return jnp.tanh(c @ w), None
        return jax.lax.scan(body, x, ws)[0]

    x = jax.ShapeDtypeStruct((32, D), jnp.float32)
    ws = jax.ShapeDtypeStruct((L, D, D), jnp.float32)
    c = compile_(f, x, ws)
    got = analyze_hlo(c.as_text(), 1)
    xla = xla_cost_analysis(c)["flops"]       # body counted once
    assert got["flops"] >= L * 2 * 32 * D * D * 0.99
    assert got["flops"] >= xla * (L - 1)      # strictly trip-scaled


def test_scan_equals_unrolled():
    D, L = 48, 5

    def f_scan(x, ws):
        def body(c, w):
            return jnp.tanh(c @ w), None
        return jax.lax.scan(body, x, ws)[0]

    def f_unroll(x, ws):
        for i in range(L):
            x = jnp.tanh(x @ ws[i])
        return x

    x = jax.ShapeDtypeStruct((16, D), jnp.float32)
    ws = jax.ShapeDtypeStruct((L, D, D), jnp.float32)
    a = analyze_hlo(compile_(f_scan, x, ws).as_text(), 1)
    b = analyze_hlo(compile_(f_unroll, x, ws).as_text(), 1)
    assert a["flops"] == pytest.approx(b["flops"], rel=0.02)


def test_matches_xla_on_unrolled_train_step():
    """End-to-end: close to XLA cost_analysis on a real (unrolled) model
    train step (elementwise flops are the gap).  XLA introduces its own
    while loops even in unrolled modules and counts their bodies once, so
    the comparison disables trip scaling (``while_trips=False``)."""
    import functools
    from repro.configs import get_config
    from repro.models.model import build_model
    from repro.train.optimizer import OptConfig
    from repro.train.step import build_train_step, init_state

    cfg = get_config("tinyllama-1.1b").reduced(
        n_layers=2, scan_layers=False, d_model=64, d_ff=128, vocab=256,
        vocab_pad_to=128)
    model = build_model(cfg)
    opt = OptConfig()
    state = jax.eval_shape(functools.partial(
        init_state, model, opt), jax.random.PRNGKey(0))
    step = build_train_step(model, opt)
    batch = {"tokens": jax.ShapeDtypeStruct((2, 32), jnp.int32),
             "labels": jax.ShapeDtypeStruct((2, 32), jnp.int32)}
    c = jax.jit(step).lower(state, batch).compile()
    mine = analyze_hlo(c.as_text(), 1, while_trips=False)
    xla = xla_cost_analysis(c)
    assert mine["flops"] == pytest.approx(xla["flops"], rel=0.12)
    assert mine["bytes"] == pytest.approx(xla["bytes accessed"], rel=0.35)


def test_collective_parse_spmd():
    """Collectives parsed with group sizes from a real SPMD module.

    (Runs single-device: constructs HLO text manually.)"""
    hlo = """
HloModule test

ENTRY %main (p: f32[64,128]) -> f32[64,128] {
  %p = f32[64,128]{1,0} parameter(0)
  %ag = f32[64,512]{1,0} all-gather(%p), replica_groups={{0,1,2,3}}, dimensions={1}
  %slice = f32[64,128]{1,0} slice(%ag), slice={[0:64], [0:128]}
  %ar = f32[64,128]{1,0} all-reduce(%slice), replica_groups={{0,1},{2,3}}, to_apply=%add
  ROOT %out = f32[64,128]{1,0} add(%ar, %p)
}
"""
    got = analyze_hlo(hlo, 4)
    coll = got["collectives"]
    assert coll["all-gather"]["count"] == 1
    # all-gather result 64*512*4 bytes, g=4 -> wire = R*(3/4)
    assert coll["all-gather"]["wire_bytes_per_chip"] == pytest.approx(
        64 * 512 * 4 * 3 / 4)
    # all-reduce g=2 -> 2*R*(1/2) = R
    assert coll["all-reduce"]["wire_bytes_per_chip"] == pytest.approx(
        64 * 128 * 4)


def test_parse_module_structure():
    def f(x):
        def body(c, _):
            return jnp.tanh(c), None
        return jax.lax.scan(body, x, None, length=3)[0]

    c = compile_(f, jax.ShapeDtypeStruct((8, 8), jnp.float32))
    comps, entry = parse_module(c.as_text())
    assert entry is not None
    assert any("while" in i.op for comp in comps.values()
               for i in comp.instrs)
