#!/usr/bin/env sh
# Tier-1 CI: fast test pass (slow-marked tests excluded) + quick bench
# smokes for the pipeline-throughput (incl. the large-V blocked-tile FW
# kernel section, which quick mode limits to homog100, and the arch3d
# 3D/hierarchical-family prep section), pareto-frontier, design-service
# and device-netsim benches (set CI_SKIP_BENCH=1 to skip them).
#   scripts/ci.sh [extra pytest args...]
#
# Coverage: when pytest-cov is installed, the test pass also reports
# line coverage for src/repro/core/ and enforces CI_COV_FLOOR.  When it
# is NOT installed, coverage degrades *loudly*: a skip line is printed,
# and a nonzero CI_COV_FLOOR (an explicit ask to enforce a floor) fails
# the run instead of silently measuring nothing.
set -eu
cd "$(dirname "$0")/.."
COV_ARGS=""
if python -c "import pytest_cov" 2>/dev/null; then
    COV_ARGS="--cov=repro.core --cov-report=term \
--cov-fail-under=${CI_COV_FLOOR:-0}"
else
    echo "ci.sh: pytest-cov unavailable, coverage skipped" >&2
    if [ "${CI_COV_FLOOR:-0}" != "0" ]; then
        echo "ci.sh: CI_COV_FLOOR=${CI_COV_FLOOR} set but pytest-cov is" \
             "not importable; cannot enforce a coverage floor" >&2
        exit 1
    fi
fi
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m pytest -q -m "not slow" $COV_ARGS "$@"
if [ "${CI_SKIP_BENCH:-0}" != "1" ]; then
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
        python -m benchmarks.run --only pipeline
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
        python -m benchmarks.run --only pareto
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
        python -m benchmarks.run --only design_service
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
        python -m benchmarks.run --only netsim
fi
