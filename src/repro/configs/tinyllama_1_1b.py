"""Assigned architecture config: tinyllama-1.1b (see registry for source).

Exposes CONFIG (exact published hyper-parameters) and SMOKE (reduced copy
for CPU smoke tests).  Select with ``--arch tinyllama-1.1b``.
"""
from .registry import get_config

CONFIG = get_config("tinyllama-1.1b")
SMOKE = CONFIG.reduced()
