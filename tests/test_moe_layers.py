"""MoE dispatch invariants (property-based) + layer-level numerics."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.configs import get_config
from repro.models import moe
from repro.models.config import LMConfig
from repro.models.layers import rms_norm, rope


def moe_cfg(E, K, cf=64.0):
    return get_config("moonshot-v1-16b-a3b").reduced(
        n_experts=E, top_k=K, capacity_factor=cf, d_model=32, d_ff=48,
        n_layers=1)


@given(st.integers(2, 8), st.integers(1, 3), st.integers(0, 1000))
@settings(max_examples=15, deadline=None)
def test_moe_dropless_matches_dense_mixture(E, K, seed):
    """With ample capacity, capacity-sort dispatch == explicit per-token
    top-k mixture of expert MLPs."""
    K = min(K, E)
    cfg = moe_cfg(E, K)
    key = jax.random.PRNGKey(seed)
    p = moe.moe_init(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 8, cfg.d_model),
                          jnp.float32)
    y, aux = moe.moe_mlp(p, x, cfg)

    # oracle: explicit mixture
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    logits = h.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    gv, ei = jax.lax.top_k(probs, K)
    gv = gv / gv.sum(-1, keepdims=True)
    outs = jnp.einsum("bsd,edf->bsef", h, p["we1"])
    outs3 = jnp.einsum("bsd,edf->bsef", h, p["we3"])
    hh = jax.nn.silu(outs) * outs3
    ye = jnp.einsum("bsef,efd->bsed", hh, p["we2"])
    mix = jnp.zeros_like(x)
    for k in range(K):
        sel = jnp.take_along_axis(ye, ei[..., k][..., None, None],
                                  axis=2)[:, :, 0]
        mix = mix + gv[..., k][..., None] * sel
    np.testing.assert_allclose(np.asarray(y - x), np.asarray(mix),
                               rtol=2e-4, atol=2e-4)
    assert np.isfinite(float(aux))


def test_moe_capacity_drops_tokens():
    """cf=tiny forces drops; output stays finite and residual-passthrough."""
    cfg = dataclasses.replace(moe_cfg(4, 2), capacity_factor=0.01)
    p = moe.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, cfg.d_model),
                          jnp.float32)
    y, _ = moe.moe_mlp(p, x, cfg)
    assert np.isfinite(np.asarray(y)).all()


def test_moe_decode_capacity_one():
    assert moe._capacity(moe_cfg(8, 2), 1) == 1
    assert moe._capacity(moe_cfg(8, 2), 128) >= 128 * 2 / 8


@given(st.integers(1, 64), st.integers(0, 100))
@settings(max_examples=20, deadline=None)
def test_rope_preserves_norm_and_relativity(S, seed):
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (1, S, 2, 16), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (1, S))
    y = rope(x, pos, 10000.0)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(y), axis=-1),
        np.linalg.norm(np.asarray(x), axis=-1), rtol=1e-4)
    # relative property: <rope(q,i), rope(k,j)> depends only on i-j
    q = jax.random.normal(jax.random.fold_in(key, 1), (1, 1, 1, 16))
    k = jax.random.normal(jax.random.fold_in(key, 2), (1, 1, 1, 16))
    def dot_at(i, j):
        qi = rope(q, jnp.full((1, 1), i), 1e4)
        kj = rope(k, jnp.full((1, 1), j), 1e4)
        return float(jnp.sum(qi * kj))
    np.testing.assert_allclose(dot_at(3, 1), dot_at(7, 5), rtol=1e-4,
                               atol=1e-5)


def test_rms_norm_scale_invariance():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 3, 64), jnp.float32)
    w = jnp.zeros(64)
    y1 = rms_norm(x, w, 1e-6)
    y2 = rms_norm(x * 1000.0, w, 1e-6)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-3,
                               atol=1e-5)
