"""3D & hierarchical arch families (``repro.arch3d``) + satellites.

Covers the new-subsystem acceptance gates:

* family record structure (hand-counted adjacency per family),
* device graph builder bit-for-bit vs the independent host reference
  (all four families, both chiplet configs, random + mutated placements),
* TSV latency model with hand-computed expectations — the vertical tier
  value IS the stacked-pair distance, and sweeping ``tsv_slowdown``
  flips which arrangement (stacked vs planar) infers the shorter route,
* zero-retrace: tier vectors are runtime jit operands; reps differing
  only in tier factors share compiled ``DevicePipeline`` stages,
* end-to-end: ``run_sweep`` (ga-batched + trace-lat) and ``DesignEngine``
  on 3D families,
* the ``trace-thr`` objective term (device == float64 host),
* workload-aware Pareto axes over trace-term weights,
* 3-objective hypervolume device sweep vs the host recursion (+ the
  n > 3 host-fallback warning).
"""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.arch3d import (FAMILIES3D, TIER_BACKBONE, TIER_PLANAR,
                          TIER_VERTICAL, default_tier_values, make_rep3d)
from repro.core.api import (Budget, ExperimentConfig, arch_family, make_rep,
                            make_evaluator, paper_defaults, run_sweep)
from repro.core.chiplets import ARCH3D, TRAFFIC_TYPES, resolve_arch
from repro.core.objective import (Objective, TermSpec, objective_cost_host)
from repro.core.optimize import DevicePipeline
from repro.core.pareto import (ParetoGridSpec, _hv_rec, hypervolume,
                               run_pareto_sweep)
from repro.core.proxies import fw_counts_ref
from repro.core.topology import stack_graphs
from repro.netsim import Workload

FAMILIES = tuple(FAMILIES3D)


def _rep(name, config="baseline", **kw):
    arch = resolve_arch(name, config)
    rep = make_rep3d(arch, name)
    return dataclasses.replace(rep, **kw) if kw else rep


def _wl(arch, traffic="c2m", rate=0.01):
    return Workload.synthetic(arch.kinds(), traffic, rate)


# ---------------------------------------------------------------------------
# Family structure.
# ---------------------------------------------------------------------------

def test_family_record_counts_hand_counted():
    # stack3d32 (4x4x2): 24 planar/layer * 2 + 16 TSV pillars
    assert len(_rep("stack3d32").records) == 64
    # gw3d64 (4x4x4, 2x2 clusters): 16 intra/layer * 4 + 4 backbone/layer
    # * 4 + 4 gateways * 3 vertical pairs
    assert len(_rep("gw3d64").records) == 92
    # torus3d32: 64 + (4 row + 4 col wraps) * 2 layers
    assert len(_rep("torus3d32").records) == 80
    # express3d32: 64 + 16 stride-2 skips * 2 layers
    assert len(_rep("express3d32").records) == 96


def test_family_tier_structure():
    rep = _rep("gw3d64")
    tiers = {a.tier for a in rep.records}
    assert tiers == {TIER_PLANAR, TIER_BACKBONE, TIER_VERTICAL}
    # W_INTRA < W_BACKBONE < W_VERTICAL with the default factors
    tv = rep.tier_values
    assert tv[TIER_PLANAR] < tv[TIER_BACKBONE] < tv[TIER_VERTICAL]
    np.testing.assert_array_equal(tv, np.float32([25.0, 26.0, 28.0]))
    # gateway verticals exist only at cluster-corner gateways
    verts = [a for a in rep.records if a.tier == TIER_VERTICAL]
    assert len(verts) == 4 * (rep.Z - 1)


def test_resolve_and_defaults_dispatch():
    for name in FAMILIES:
        fam, n = arch_family(name)
        assert fam == "arch3d" and n == sum(ARCH3D[name])
        arch = resolve_arch(name)
        assert len(arch.chiplets) == n
        assert paper_defaults(name).mutation_mode == "neighbor-one"
        rep = make_rep(arch, name)
        assert rep.records  # api dispatches to the arch3d factory


def test_unknown_family_and_bad_augment_raise():
    arch = resolve_arch("stack3d32")
    with pytest.raises(ValueError, match="unknown 3D arch family"):
        make_rep3d(arch, "stack3d999")
    with pytest.raises(KeyError):
        _rep("stack3d32", augment="no-such-augment").records
    with pytest.raises(ValueError, match="stride"):
        _rep("express3d32", augment_params={"stride": 1})


def test_custom_augmentation_registers():
    from repro.arch3d.topology import AdjRecord, _cid
    from repro.core.registries import AUGMENTATIONS, register_augmentation

    if "diag-test" not in AUGMENTATIONS.names():
        @register_augmentation("diag-test")
        def diag(R, C, Z, sz_mm, params):
            return [AdjRecord(cell1=_cid(0, 0, 0, C, Z),
                              cell2=_cid(1, 1, 0, C, Z),
                              loc1=1, loc2=3, rot1=1, rot2=3,
                              tier=TIER_BACKBONE, length=float(sz_mm))]

    rep = _rep("stack3d32", augment="diag-test")
    assert len(rep.records) == 65


# ---------------------------------------------------------------------------
# Device builder bit-for-bit vs the host reference.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", FAMILIES)
@pytest.mark.parametrize("config", ["baseline", "placeit"])
def test_builder_bitforbit_vs_host(name, config):
    rep = _rep(name, config)
    rng = np.random.default_rng(123)
    sols = [rep.random(rng) for _ in range(3)]
    for s in list(sols):
        sols.append(rep.mutate(s, rng))
    host = stack_graphs([rep.score_graph(s) for s in sols])
    dev = rep.graph_batch().build(
        jnp.asarray(np.stack([s[0] for s in sols])),
        jnp.asarray(np.stack([s[1] for s in sols])))
    for k in ("W", "edges", "edge_mask", "area", "edge_len"):
        assert np.array_equal(np.asarray(host[k]), np.asarray(dev[k])), k


def test_gateway_rotations_avoid_recordless_sides():
    """1-PHY chiplets in a gateway family never roll a rotation whose
    side carries no record (e.g. cross-cluster) — the fix that makes
    connected gateway placements findable."""
    rep = _rep("gw3d64")
    rng = np.random.default_rng(7)
    for _ in range(5):
        types, rot = rep.random(rng)
        tflat, rflat = types.reshape(-1), rot.reshape(-1)
        for cell in range(tflat.shape[0]):
            k = int(tflat[cell])
            if k >= 0 and rep._rotatable.get(k, False):
                anyr = [i for i in range(4) if rep._rot_other[cell][i]]
                assert int(rflat[cell]) in anyr


# ---------------------------------------------------------------------------
# TSV latency model (hand-computed expectations).
# ---------------------------------------------------------------------------

def test_tier_values_formula():
    arch = resolve_arch("stack3d32")
    lp, ll = arch.latency.l_phy, arch.latency.l_link
    tv = default_tier_values(arch, tsv_slowdown=16.0, backbone_factor=3.0)
    np.testing.assert_array_equal(
        tv, np.float32([2 * lp + ll, 2 * lp + 3 * ll, 2 * lp + 16 * ll]))


@pytest.mark.parametrize("tsv", [1.0, 4.0, 16.0])
def test_vertical_pair_distance_is_tier_value(tsv):
    """FW distance between directly stacked chiplets == 2*l_phy +
    l_link*tsv_slowdown (the TSV latency model, hand-computed)."""
    from repro.arch3d.topology import _host_instances
    rep = _rep("stack3d32", "placeit", tsv_slowdown=tsv)
    arch = rep.arch
    expect = 2 * arch.latency.l_phy + arch.latency.l_link * tsv
    rng = np.random.default_rng(0)
    sol = rep.random(rng)
    D, _ = fw_counts_ref(jnp.asarray(rep.score_graph(sol).W))
    D = np.asarray(D)
    Vp, N = rep.layout.Vp, len(arch.chiplets)
    inst = _host_instances(arch, sol[0].reshape(-1)).reshape(4, 4, 2)
    hit = False
    for r in range(4):
        for c in range(4):
            i, j = inst[r, c, 0], inst[r, c, 1]
            if i >= 0 and j >= 0:
                d = D[Vp + i, Vp + N + j]
                assert d <= expect + 1e-4     # direct TSV bounds the route
                hit |= abs(d - expect) < 1e-4
    assert hit    # some stacked pair takes the TSV at exactly the tier cost


def test_tsv_slowdown_flips_preferred_arrangement():
    """Regression with hand-computed expectation: whether a hot pair is
    cheaper stacked (one TSV: 2*l_phy + l_link*tsv) or planar-adjacent
    (2*l_phy + l_link) flips with ``tsv_slowdown`` — the vertical-link
    multiplier demonstrably changes the inferred topology."""
    from repro.arch3d.topology import _host_instances
    rng = np.random.default_rng(0)
    sol = _rep("stack3d32", "placeit").random(rng)
    inst = _host_instances(resolve_arch("stack3d32", "placeit"),
                           sol[0].reshape(-1)).reshape(4, 4, 2)
    # a stacked pair and a planar-adjacent pair from the same placement
    sp = next((int(inst[r, c, 0]), int(inst[r, c, 1]))
              for r in range(4) for c in range(4)
              if inst[r, c, 0] >= 0 and inst[r, c, 1] >= 0)
    pp = next((int(inst[r, c, 0]), int(inst[r, c + 1, 0]))
              for r in range(4) for c in range(3)
              if inst[r, c, 0] >= 0 and inst[r, c + 1, 0] >= 0)
    for tsv, stacked_wins in ((0.5, True), (16.0, False)):
        rep = _rep("stack3d32", "placeit", tsv_slowdown=tsv)
        D, _ = fw_counts_ref(jnp.asarray(rep.score_graph(sol).W))
        D = np.asarray(D)
        Vp, N = rep.layout.Vp, 32
        d_stack = D[Vp + sp[0], Vp + N + sp[1]]
        d_plane = D[Vp + pp[0], Vp + N + pp[1]]
        assert np.isclose(d_stack, 24.0 + tsv, atol=1e-4)
        assert np.isclose(d_plane, 25.0, atol=1e-4)
        assert (d_stack < d_plane) == stacked_wins


# ---------------------------------------------------------------------------
# Zero-retrace: tiers are runtime operands.
# ---------------------------------------------------------------------------

def test_tier_swap_shares_stages_and_never_retraces():
    repA = _rep("stack3d32")
    repB = dataclasses.replace(repA, tsv_slowdown=16.0,
                               backbone_factor=4.0)
    assert repA.device_stage_key() == repB.device_stage_key()
    assert DevicePipeline._stages(repA) is DevicePipeline._stages(repB)

    gb = repA.graph_batch()
    traces = []

    @jax.jit
    def build(t, r, tiers):
        traces.append(1)
        return gb.build(t, r, tiers)

    rng = np.random.default_rng(0)
    sol = repA.random(rng)
    t = jnp.asarray(sol[0][None])
    r = jnp.asarray(sol[1][None])
    d1 = build(t, r, jnp.asarray(repA.tier_values))
    d2 = build(t, r, jnp.asarray(repB.tier_values))
    assert len(traces) == 1                      # one trace, two tier sets
    assert not np.array_equal(np.asarray(d1["W"]), np.asarray(d2["W"]))


# ---------------------------------------------------------------------------
# End-to-end through the batched pipeline.
# ---------------------------------------------------------------------------

def test_run_sweep_ga_batched_trace_lat_3d():
    arch = resolve_arch("stack3d32", "baseline")
    obj = Objective().with_terms(TermSpec("trace-lat", weight=0.5))
    cfg = ExperimentConfig(arch="stack3d32", algorithms=("ga-batched",),
                           budget=Budget(evals=48), norm_samples=4,
                           chunk=8, objective=obj, workload=_wl(arch))
    res = run_sweep([cfg])
    rec = res.runs[0].records[0]
    assert np.isfinite(rec.result.best_cost) and rec.result.best_cost > 0
    assert np.asarray(rec.result.best_sol[0]).shape == (4, 4, 2)
    assert res.stats.scorers_built == 1


def test_mixed_family_sweep_same_layout_different_edges():
    """stack3d32 and torus3d32 share a ScoreLayout but emit different
    edge-slot counts; ``scorer_shape_key`` must keep their compiled
    scorers distinct so lockstep stacking never concatenates unlike
    batches (regression: TypeError in score_stacked)."""
    def cfg(name):
        arch = resolve_arch(name, "baseline")
        return ExperimentConfig(arch=name, algorithms=("ga-batched",),
                                budget=Budget(evals=24), norm_samples=4,
                                chunk=8)
    res = run_sweep([cfg("stack3d32"), cfg("torus3d32")])
    assert len(res.runs) == 2
    for run in res.runs:
        assert np.isfinite(run.records[0].result.best_cost)
    assert res.stats.scorers_built == 2


def test_design_engine_runs_3d_family():
    from repro.serve.design import DesignEngine, DesignRequest
    arch = resolve_arch("gw3d64", "placeit")
    obj = Objective().with_terms(TermSpec("trace-lat", weight=0.5))
    cfg = ExperimentConfig(arch="gw3d64", config="placeit",
                           algorithms=("ga-batched",),
                           budget=Budget(evals=32), norm_samples=4,
                           chunk=8, objective=obj, workload=_wl(arch))
    eng = DesignEngine()
    rid = eng.submit(DesignRequest(config=cfg, request_id="t3d"))
    eng.run()
    resp = eng.result(rid)
    assert resp.status == "done", getattr(resp, "error", None)
    rec = resp.records[0]
    assert np.isfinite(rec.result.best_cost)
    assert np.asarray(rec.result.best_sol[0]).shape == (4, 4, 4)


# ---------------------------------------------------------------------------
# trace-thr objective term.
# ---------------------------------------------------------------------------

def test_trace_thr_device_cost_agrees_with_host():
    arch = resolve_arch("stack3d32", "baseline")
    rep = _rep("stack3d32")
    obj = Objective().with_terms(TermSpec("trace-thr", weight=0.5))
    ev = make_evaluator(rep, arch, rng=np.random.default_rng(0),
                        norm_samples=4, chunk=4, objective=obj,
                        workload=_wl(arch))
    rng = np.random.default_rng(1)
    _, graphs = ev.generate_valid(rep.random, rng, 6)
    batch = ev._with_demand(stack_graphs(graphs))
    metrics = ev.score_batch(batch)
    for t in TRAFFIC_TYPES:
        assert f"trace_thr_{t}" in metrics
    # traffic is c2m-only: that class saturates somewhere (thr > 0),
    # demandless classes contribute exactly 0
    assert (np.asarray(metrics["trace_thr_c2m"]) > 0).all()
    assert float(np.abs(np.asarray(metrics["trace_thr_c2i"])).max()) == 0.0
    host = objective_cost_host(metrics, obj, ev.norm, batch=batch)
    np.testing.assert_allclose(ev.costs_from(metrics), host,
                               rtol=1e-4, atol=1e-5)
    # the term adds a strictly positive summand over the trace-free base
    base = objective_cost_host(metrics, Objective(), ev.norm)
    assert (host > base).all()


def test_trace_thr_requires_workload():
    rep = _rep("stack3d32")
    arch = rep.arch
    obj = Objective().with_terms(TermSpec("trace-thr"))
    with pytest.raises(ValueError, match="workload"):
        make_evaluator(rep, arch, rng=np.random.default_rng(0),
                       norm_samples=2, chunk=4, objective=obj)


# ---------------------------------------------------------------------------
# Workload-aware Pareto axes.
# ---------------------------------------------------------------------------

def test_pareto_grid_over_trace_term_weights():
    arch = resolve_arch("stack3d32", "baseline")
    obj = Objective(terms=()).with_terms(
        TermSpec("trace-lat", weight=0.5), TermSpec("trace-thr", weight=0.5))
    spec = ParetoGridSpec(term_weights={"trace-lat": (0.2, 1.0),
                                        "trace-thr": (0.2, 1.0)})
    cfg = ExperimentConfig(arch="stack3d32", algorithms=("ga-batched",),
                           budget=Budget(evals=24), norm_samples=4,
                           chunk=8, objective=obj, workload=_wl(arch))
    front = run_pareto_sweep(cfg, spec).fronts[0]
    assert front.term_names == ("trace-lat", "trace-thr")
    Y = np.asarray(front.matrix)
    assert Y.shape == (front.n_candidates, 2) and np.isfinite(Y).all()
    assert front.n_candidates == spec.n_points
    assert len(front.points) >= 1
    # 3D placements round-trip through the provenance records
    assert front.points[0].sol()[0].shape == (4, 4, 2)


# ---------------------------------------------------------------------------
# 3-objective hypervolume.
# ---------------------------------------------------------------------------

def test_hv3d_matches_host_recursion():
    rng = np.random.default_rng(0)
    ref = np.array([1.2, 1.3, 1.1])
    for _ in range(15):
        Y = rng.uniform(0, 1, size=(int(rng.integers(1, 12)), 3))
        d = hypervolume(Y, ref)
        h = hypervolume(Y, ref, device=False)
        assert abs(d - h) < 1e-6 * max(1.0, h)
    # hand-computed: one point dominating a 0.5-cube corner
    assert np.isclose(hypervolume([[0.5, 0.5, 0.5]], [1, 1, 1]), 0.125)
    # points on/beyond the reference contribute nothing
    assert hypervolume([[1.0, 1.0, 1.0], [2.0, 0.1, 0.1]],
                       [1.0, 1.0, 1.0]) == pytest.approx(
        float(_hv_rec(np.minimum([[1, 1, 1], [2, .1, .1]], 1.0),
                      np.ones(3))))


def test_hypervolume_n4_warns_and_falls_back():
    rng = np.random.default_rng(1)
    Y = rng.uniform(0, 1, (5, 4))
    ref = np.full(4, 1.5)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        v = hypervolume(Y, ref)
        assert any("no device path" in str(x.message) for x in w)
    assert v == pytest.approx(_hv_rec(np.minimum(Y, ref), ref))
