"""Design service demo: 8 tenants, one continuous-batching engine.

Eight mixed requests — homogeneous and heterogeneous architectures,
different seeds and objective weightings, one Pareto-grid request with a
device-resident population archive — are submitted to a single
:class:`repro.serve.design.DesignEngine`.  Requests that share a compiled
scorer (same layout/chunk/backend/objective *structure*) are scored as
one stacked batched call per tick; each tenant streams ``progress`` /
``front`` updates and resolves to a :class:`DesignResponse` whose records
are bit-for-bit what a solo ``run_sweep`` would have produced.

  PYTHONPATH=src python examples/design_service.py
"""
import dataclasses

from repro.core.api import Budget, DesignRequest, ExperimentConfig
from repro.core.pareto import ParetoGridSpec
from repro.serve.design import DesignEngine


def tenant_requests() -> list[DesignRequest]:
    homog = ExperimentConfig(
        arch="homog32", algorithms=("br", "ga"), budget=Budget(evals=24),
        norm_samples=6, chunk=4, params={"br": {"batch": 6}})
    hetero = ExperimentConfig(
        arch="hetero32", algorithms=("br",), budget=Budget(evals=16),
        norm_samples=6, chunk=4, params={"br": {"batch": 4}})
    reqs = []
    # Four homogeneous tenants, different seeds: one compiled scorer,
    # their generations stack into single dispatches.
    for seed in range(4):
        reqs.append(DesignRequest(
            config=dataclasses.replace(homog, seed=seed),
            request_id=f"homog-seed{seed}"))
    # Two heterogeneous tenants (their own scorer group).
    for seed in range(2):
        reqs.append(DesignRequest(
            config=dataclasses.replace(hetero, seed=seed),
            request_id=f"hetero-seed{seed}"))
    # One tenant with a tight deadline (demonstrates the timeout path on
    # slow machines; usually completes in time).
    reqs.append(DesignRequest(
        config=dataclasses.replace(homog, seed=7),
        request_id="homog-deadline", timeout_s=120.0))
    # One Pareto-grid tenant with a population archive: the grid's
    # scalarizations stack with the other homog tenants, and every
    # evaluated placement competes for the streamed front.
    reqs.append(DesignRequest(
        config=dataclasses.replace(homog, seed=9, algorithms=("br",),
                                   archive_k=16),
        pareto_grid=ParetoGridSpec(term_weights={"area": (0.5, 2.0)}),
        request_id="homog-pareto"))
    return reqs


def main() -> None:
    engine = DesignEngine(max_active=8)
    ids = [engine.submit(r) for r in tenant_requests()]
    ticks = engine.run()
    print(f"engine drained in {ticks} ticks; stats: {engine.stats}\n")
    for rid in ids:
        resp = engine.result(rid)
        kinds = ",".join(u.kind for u in resp.updates)
        best = "-" if resp.best_cost is None else f"{resp.best_cost:.4f}"
        front = ("" if resp.front is None
                 else f"  front={len(resp.front.points)} pts "
                      f"of {resp.front.n_candidates} candidates")
        print(f"{rid:16s} {resp.status:8s} best={best:9s} "
              f"updates=[{kinds}]{front}")
    n_seq = sum(len(engine.result(r).records) for r in ids)
    print(f"\n{engine.stats.score_calls} scorer dispatches served "
          f"{n_seq} runs across {len(ids)} tenants "
          f"({engine.stats.stacked_rounds} stacked rounds).")


if __name__ == "__main__":
    main()
