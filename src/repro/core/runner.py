"""DEPRECATED experiment runner — thin shim over :mod:`repro.core.api`.

The old monolithic ``Experiment`` dataclass (string-keyed ``if/elif``
dispatch, raw ``PAPER_PARAMS`` dicts, opaque ``fw_impl`` hook) has been
replaced by the registry-driven API:

* :class:`repro.core.api.ExperimentConfig` + :func:`repro.core.api.run_experiment`
* ``@register_optimizer`` / ``@register_scorer_backend`` for new algorithms
  and scorer backends
* :func:`repro.core.api.run_sweep` for batched multi-config runs

``Experiment`` remains as a deprecated compatibility wrapper that builds an
``ExperimentConfig`` and delegates; it will be removed once downstream
callers migrate (see ROADMAP).
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass, field
from typing import Any

# Re-exported for backwards compatibility.
from .api import (GRID_DIMS, Budget, ExperimentConfig, RunRecord,  # noqa: F401
                  baseline_cost, best_by_algorithm, make_rep,
                  run_experiment, summarize)
from .chiplets import ArchSpec


@dataclass
class Experiment:
    """Deprecated: use ``ExperimentConfig`` + ``run_experiment``."""

    arch_name: str                     # homog32 | homog64 | hetero32 | hetero64
    config: str = "baseline"           # baseline | placeit (§VII)
    algorithms: tuple[str, ...] = ("br", "ga", "sa")
    repetitions: int = 1
    max_evals: int | None = 300        # per repetition (None -> use seconds)
    time_budget_s: float | None = None
    norm_samples: int = 100            # paper: 500
    seed: int = 0
    sa_chains: int = 1
    fw_impl: Any = None                # legacy hook; prefer config.backend
    records: list[RunRecord] = field(default_factory=list)

    def to_config(self) -> ExperimentConfig:
        params = {}
        if self.sa_chains != 1:
            params["sa"] = {"chains": self.sa_chains}
        return ExperimentConfig(
            arch=self.arch_name, config=self.config,
            algorithms=tuple(self.algorithms), repetitions=self.repetitions,
            budget=Budget(evals=self.max_evals, seconds=self.time_budget_s),
            norm_samples=self.norm_samples, seed=self.seed, params=params)

    def _warn(self):
        warnings.warn(
            "Experiment is deprecated; use repro.core.api.ExperimentConfig "
            "with run_experiment()/run_sweep()", DeprecationWarning,
            stacklevel=3)

    def make_rep(self, arch: ArchSpec):
        return make_rep(arch, self.arch_name)

    def run(self) -> list[RunRecord]:
        self._warn()
        self.records.extend(
            run_experiment(self.to_config(), fw_impl=self.fw_impl))
        return self.records

    def baseline_cost(self) -> tuple[float, dict]:
        self._warn()
        return baseline_cost(self.to_config(), fw_impl=self.fw_impl)
