"""Placement-based ICI topology inference (paper §V-A get_network, §VI-A).

The common output of both placement representations is a ``ScoreGraph``: a
PHY-level latency graph augmented with virtual per-chiplet source/sink nodes,
plus the directed D2D edge list used for throughput (link-load) estimation.

Node layout (V = Vp + 2*N):
    [0, Vp)          PHY nodes
    [Vp, Vp+N)       virtual *source* nodes, one per chiplet (out-edges only)
    [Vp+N, Vp+2N)    virtual *sink* nodes, one per chiplet (in-edges only)

Edge weights [cycles]:
    src_c -> p (p in PHYs(c)) : 0     (injection picks any own PHY)
    p -> dst_c (p in PHYs(c)) : 0     (ejection from any own PHY)
    D2D link  p <-> q         : 2*L_P + L_L   (PHY out + link + PHY in)
    internal  p <-> q same chiplet, relay-capable : L_R

Because virtual sources have no in-edges and sinks no out-edges, no path can
"tunnel" through a chiplet via its virtual nodes; through-traffic is possible
only across internal edges, which exist exactly for relay-capable chiplets —
this encodes the paper's relay semantics without per-node surcharges.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from .chiplets import ArchSpec

INF = np.float32(1.0e9)

# Canonical grid-direction conventions of the homogeneous representation:
# facing direction of a single-PHY chiplet after rotation r, the opposite
# direction, and the (row, col) delta per direction (row grows northwards).
# placement_homog imports these (this module cannot import it back).
ROT_DIR = ("s", "e", "n", "w")
OPP_DIR = {"n": "s", "s": "n", "e": "w", "w": "e"}
DIR_DELTA = {"n": (1, 0), "s": (-1, 0), "e": (0, 1), "w": (0, -1)}


@dataclass
class PlacedPhys:
    """Geometry of one concrete placement, host-side."""

    pos: np.ndarray       # [Vp, 2] float32, PHY positions in mm
    owner: np.ndarray     # [Vp] int32, owning chiplet instance
    relay: np.ndarray     # [N] bool, per chiplet instance
    kinds: np.ndarray     # [N] int8, chiplet kind per instance
    area: float           # enclosing-rectangle area in mm^2


@dataclass
class ScoreGraph:
    """Fixed-shape scoring input for one placement (stackable into batches)."""

    W: np.ndarray          # [V, V] float32 latency weights (diag 0, INF else)
    edges: np.ndarray      # [E_max, 2] int32 directed D2D edges (padded)
    edge_mask: np.ndarray  # [E_max] bool
    area: np.float32
    connected: bool
    edge_len: np.ndarray | None = None   # [E_max] float32 link lengths [mm]

    @property
    def V(self) -> int:
        return self.W.shape[0]


class _UnionFind:
    def __init__(self, n: int):
        self.p = list(range(n))

    def find(self, a: int) -> int:
        while self.p[a] != a:
            self.p[a] = self.p[self.p[a]]
            a = self.p[a]
        return a

    def union(self, a: int, b: int) -> bool:
        ra, rb = self.find(a), self.find(b)
        if ra == rb:
            return False
        self.p[ra] = rb
        return True


def infer_links_mst(arch: ArchSpec, geo: PlacedPhys,
                    strict_phy_use: bool = False
                    ) -> tuple[list[tuple[int, int]], bool]:
    """§VI-A topology inference: MST over the PHY graph + augmentation.

    Returns (links, connected).  ``links`` are undirected PHY index pairs.

    * Internal edges (weight 0 for MST purposes) join all PHYs of a
      relay-capable chiplet.
    * Candidate edges join PHYs of different chiplets at distance <=
      max_link_mm; their MST weight is the link length.
    * D2D links = candidate edges picked by the MST, then remaining candidate
      edges in increasing-weight order whenever both endpoint PHYs are still
      unused by a D2D link.
    * ``strict_phy_use=True`` additionally forbids the MST itself from
      assigning two links to one PHY (beyond-paper physical constraint; the
      paper's formulation is the default).
    """
    Vp = geo.pos.shape[0]
    uf = _UnionFind(Vp)
    # Internal (free) unions inside relay chiplets.
    for c in np.unique(geo.owner):
        idx = np.nonzero(geo.owner == c)[0]
        if geo.relay[c]:
            for k in range(1, len(idx)):
                uf.union(int(idx[0]), int(idx[k]))
    # Candidate edges (vectorized pairwise distances).
    diff = geo.pos[:, None, :] - geo.pos[None, :, :]
    if arch.distance == "manhattan":
        dist = np.abs(diff).sum(-1)
    else:
        dist = np.sqrt((diff ** 2).sum(-1))
    same_owner = geo.owner[:, None] == geo.owner[None, :]
    upper = np.triu(np.ones((Vp, Vp), dtype=bool), k=1)
    ok = upper & ~same_owner & (dist <= arch.max_link_mm + 1e-9)
    pp, qq = np.nonzero(ok)
    order = np.argsort(dist[pp, qq], kind="stable")
    cands: list[tuple[float, int, int]] = [
        (float(dist[pp[i], qq[i]]), int(pp[i]), int(qq[i])) for i in order]
    phy_used = np.zeros(Vp, dtype=bool)
    links: list[tuple[int, int]] = []
    # Kruskal over candidate edges (internal edges already merged, weight 0).
    for d, p, q in cands:
        if strict_phy_use and (phy_used[p] or phy_used[q]):
            continue
        if uf.union(p, q):
            links.append((p, q))
            phy_used[p] = phy_used[q] = True
    # Connectivity: some single component must contain at least one PHY of
    # every chiplet.  (A chiplet with several PHYs and no relay has its PHYs
    # in separate UF nodes; any one of them inside the common component
    # suffices.  Checking only the component with the most PHYs is wrong: a
    # smaller component can be the one touching every chiplet.)
    comp_of_phy = np.array([uf.find(p) for p in range(Vp)])
    owners = np.unique(geo.owner)
    connected = False
    for root in np.unique(comp_of_phy):
        members = comp_of_phy == root
        if all(members[geo.owner == c].any() for c in owners):
            connected = True
            break
    # Augmentation: add remaining candidates joining two unused PHYs.
    for d, p, q in cands:
        if not phy_used[p] and not phy_used[q] and (p, q) not in links:
            links.append((p, q))
            phy_used[p] = phy_used[q] = True
    return links, connected


def build_score_graph(arch: ArchSpec, geo: PlacedPhys,
                      links: list[tuple[int, int]], e_max: int,
                      connected: bool) -> ScoreGraph:
    """Assemble the fixed-shape ScoreGraph from geometry + chosen D2D links."""
    Vp = geo.pos.shape[0]
    N = geo.kinds.shape[0]
    V = Vp + 2 * N
    W = np.full((V, V), INF, dtype=np.float32)
    np.fill_diagonal(W, 0.0)
    d2d = np.float32(arch.latency.d2d_cost())
    lr = np.float32(arch.latency.l_relay)
    # Internal relay edges.
    for c in range(N):
        if not geo.relay[c]:
            continue
        idx = np.nonzero(geo.owner == c)[0]
        for a in range(len(idx)):
            for b in range(a + 1, len(idx)):
                p, q = int(idx[a]), int(idx[b])
                W[p, q] = min(W[p, q], lr)
                W[q, p] = min(W[q, p], lr)
    # D2D links.
    for p, q in links:
        W[p, q] = min(W[p, q], d2d)
        W[q, p] = min(W[q, p], d2d)
    # Virtual source/sink edges.
    for c in range(N):
        idx = np.nonzero(geo.owner == c)[0]
        W[Vp + c, idx] = 0.0          # src_c -> own PHYs
        W[idx, Vp + N + c] = 0.0      # own PHYs -> dst_c
    edges = np.zeros((e_max, 2), dtype=np.int32)
    mask = np.zeros((e_max,), dtype=bool)
    elen = np.zeros((e_max,), dtype=np.float32)
    n_e = 0
    for p, q in links:
        d = np.float32(arch.dist(tuple(geo.pos[p]), tuple(geo.pos[q])))
        for (u, v) in ((p, q), (q, p)):
            if n_e >= e_max:  # pragma: no cover - e_max sized generously
                raise ValueError("e_max too small")
            edges[n_e] = (u, v)
            mask[n_e] = True
            elen[n_e] = d
            n_e += 1
    return ScoreGraph(W=W, edges=edges, edge_mask=mask,
                      area=np.float32(geo.area), connected=connected,
                      edge_len=elen)


def stack_graphs(graphs: list[ScoreGraph]) -> dict:
    """Stack per-placement ScoreGraphs into batched arrays for JAX scoring."""
    return dict(
        W=np.stack([g.W for g in graphs]),
        edges=np.stack([g.edges for g in graphs]),
        edge_mask=np.stack([g.edge_mask for g in graphs]),
        area=np.array([g.area for g in graphs], dtype=np.float32),
        edge_len=np.stack([np.zeros(g.edges.shape[0], np.float32)
                           if g.edge_len is None else g.edge_len
                           for g in graphs]),
    )


# ---------------------------------------------------------------------------
# Batched ScoreGraph assembly for the homogeneous grid.
#
# §V-A get_network as array ops: the candidate-link structure of an R x C
# grid is *static* — each of the A = R(C-1) + (R-1)C cell adjacencies either
# carries a D2D link (both facing PHYs exist) or not — so link inference is
# masked selection over a fixed adjacency table instead of the heterogeneous
# path's MST + union-find.  Everything about the graph that does not depend
# on the placement (diagonal, internal relay edges, virtual source/sink
# edges) is precomputed host-side into one static weight matrix; a batch of
# placements only scatters its D2D links on top.  Connectivity is NOT
# decided here: the scorer derives it from the Floyd-Warshall distance
# matrix (a placement is connected iff no virtual src->sink distance reaches
# ``proxies.INF_CUT``), so invalid individuals are masked-and-resampled in
# batch by the optimizer drivers instead of retried one at a time.
# ---------------------------------------------------------------------------


class HomogGraphBatch:
    """Batched ``(types, rot) -> stacked ScoreGraph arrays`` for one grid."""

    def __init__(self, arch: ArchSpec, R: int, C: int,
                 area: float | None = None):
        self.arch, self.R, self.C = arch, R, C
        n = len(arch.chiplets)
        phy_base = np.zeros(n + 1, dtype=np.int64)
        for i, ch in enumerate(arch.chiplets):
            phy_base[i + 1] = phy_base[i] + ch.n_phys()
        Vp = int(phy_base[-1])
        self.Vp, self.N = Vp, n
        self.V = Vp + 2 * n
        self.e_max = 2 * (R * (C - 1) + (R - 1) * C)
        self._nphys = jnp.asarray(
            np.array([ch.n_phys() for ch in arch.chiplets], np.int32))
        self._phy_base = jnp.asarray(phy_base[:-1].astype(np.int32))
        # Row-major instance assignment table: j-th chiplet of kind k.
        by_kind = {k: [i for i, ch in enumerate(arch.chiplets)
                       if ch.kind == k] for k in (0, 1, 2)}
        maxc = max(1, max(len(v) for v in by_kind.values()))
        table = np.zeros((3, maxc), np.int32)
        for k, ids in by_kind.items():
            table[k, :len(ids)] = ids
        self._kind_table = jnp.asarray(table)
        # Static part of W: diagonal, internal relay edges, virtual edges.
        owner = np.zeros(Vp, dtype=np.int64)
        for i in range(n):
            owner[phy_base[i]:phy_base[i + 1]] = i
        W = np.full((self.V, self.V), INF, dtype=np.float32)
        np.fill_diagonal(W, 0.0)
        lr = np.float32(arch.latency.l_relay)
        for c in range(n):
            idx = np.nonzero(owner == c)[0]
            if arch.chiplets[c].relay:
                for a in range(len(idx)):
                    for b2 in range(a + 1, len(idx)):
                        p, q = int(idx[a]), int(idx[b2])
                        W[p, q] = min(W[p, q], lr)
                        W[q, p] = min(W[q, p], lr)
            W[Vp + c, idx] = 0.0
            W[idx, Vp + n + c] = 0.0
        self._W_static = jnp.asarray(W)
        self._d2d = np.float32(arch.latency.d2d_cost())
        # Static adjacency table: cell pair + facing directions, scanning
        # each adjacency once ("n"/"e"), as in HomogRep.links_of.
        cell1, cell2, loc1, loc2, rot1, rot2 = [], [], [], [], [], []
        for r in range(R):
            for c in range(C):
                for d in ("n", "e"):
                    dr, dc = DIR_DELTA[d]
                    rr, cc = r + dr, c + dc
                    if not (0 <= rr < R and 0 <= cc < C):
                        continue
                    o = OPP_DIR[d]
                    cell1.append(r * C + c)
                    cell2.append(rr * C + cc)
                    loc1.append("nesw".index(d))    # 4-PHY local index
                    loc2.append("nesw".index(o))
                    rot1.append(ROT_DIR.index(d))  # 1-PHY rotation
                    rot2.append(ROT_DIR.index(o))
        self._a_cell1 = np.array(cell1, np.int32)
        self._a_cell2 = np.array(cell2, np.int32)
        self._a_loc1 = np.array(loc1, np.int32)
        self._a_loc2 = np.array(loc2, np.int32)
        self._a_rot1 = np.array(rot1, np.int32)
        self._a_rot2 = np.array(rot2, np.int32)
        # Static per-adjacency link lengths: distance between the facing
        # side midpoints of the two cells (HomogRep.geometry's PHY spots;
        # 0.0 for touching chiplets).  float32, matching the host
        # build_score_graph's edge_len.
        sz_mm = arch.chiplets[0].w
        mids = {"n": (sz_mm / 2, sz_mm), "s": (sz_mm / 2, 0.0),
                "e": (sz_mm, sz_mm / 2), "w": (0.0, sz_mm / 2)}

        def _side_pos(cell, side):
            r, c = divmod(int(cell), C)
            mx, my = mids[side]
            pa = np.array([c * sz_mm + mx, r * sz_mm + my], np.float32)
            return (float(pa[0]), float(pa[1]))

        alen = [np.float32(arch.dist(_side_pos(c1, "nesw"[l1]),
                                     _side_pos(c2, "nesw"[l2])))
                for c1, c2, l1, l2 in zip(cell1, cell2, loc1, loc2)]
        self._a_len = jnp.asarray(np.array(alen, np.float32))
        # §V-A get_area: identical for every placement on the grid.  A
        # masked rep (hex arrangement) passes its own cell count via
        # ``area`` — masked cells are not part of the package.
        sz = arch.chiplets[0].w * arch.chiplets[0].h
        self.area = np.float32(sz * R * C if area is None else area)

    def _instances(self, tflat: jnp.ndarray) -> jnp.ndarray:
        """Row-major instance ids per cell ([B, cells], -1 for empty)."""
        inst = jnp.full(tflat.shape, -1, jnp.int32)
        for k in range(3):
            mk = tflat == k
            rank = jnp.cumsum(mk, axis=1) - 1
            rank = jnp.clip(rank, 0, self._kind_table.shape[1] - 1)
            inst = jnp.where(mk, self._kind_table[k][rank], inst)
        return inst

    def _phy_at(self, inst, rot, loc4, rotidx):
        """Global PHY index facing the adjacency (or -1)."""
        ic = jnp.clip(inst, 0)
        four = self._nphys[ic] == 4
        single = rot == rotidx
        return jnp.where(four, self._phy_base[ic] + loc4,
                         jnp.where(single, self._phy_base[ic], -1))

    def build(self, types: jnp.ndarray, rot: jnp.ndarray) -> dict:
        """[B, R, C] stacked placements -> batched ScoreGraph arrays
        (same keys as :func:`stack_graphs`; jit/vmap-able)."""
        B = types.shape[0]
        tflat = types.reshape(B, -1).astype(jnp.int32)
        rflat = rot.reshape(B, -1).astype(jnp.int32)
        inst = self._instances(tflat)
        i1 = inst[:, self._a_cell1]
        i2 = inst[:, self._a_cell2]
        p = self._phy_at(i1, rflat[:, self._a_cell1], self._a_loc1,
                         self._a_rot1)
        q = self._phy_at(i2, rflat[:, self._a_cell2], self._a_loc2,
                         self._a_rot2)
        valid = (i1 >= 0) & (i2 >= 0) & (p >= 0) & (q >= 0)
        pu = jnp.where(valid, p, 0)
        qu = jnp.where(valid, q, 0)
        vals = jnp.where(valid, self._d2d, INF)    # INF scatter-min: no-op

        def one(pu1, qu1, v1):
            return self._W_static.at[pu1, qu1].min(v1).at[qu1, pu1].min(v1)

        W = jax.vmap(one)(pu, qu, vals)
        ed = jnp.stack([jnp.stack([pu, qu], axis=-1),
                        jnp.stack([qu, pu], axis=-1)], axis=2)
        edges = ed.reshape(B, self.e_max, 2).astype(jnp.int32)
        mask = jnp.broadcast_to(valid[:, :, None],
                                valid.shape + (2,)).reshape(B, self.e_max)
        elen = jnp.where(valid, self._a_len[None, :], 0.0)
        edge_len = jnp.broadcast_to(elen[:, :, None],
                                    elen.shape + (2,)).reshape(B, self.e_max)
        area = jnp.full((B,), self.area, jnp.float32)
        return dict(W=W, edges=edges, edge_mask=mask, area=area,
                    edge_len=edge_len)


def build_score_graphs_batched(arch: ArchSpec, R: int, C: int,
                               types, rot) -> dict:
    """One-shot convenience wrapper around :class:`HomogGraphBatch`."""
    return HomogGraphBatch(arch, R, C).build(types, rot)


# ---------------------------------------------------------------------------
# Batched ScoreGraph assembly for heterogeneous placements.
#
# §VI-A link inference as fixed-shape array ops.  Unlike the grid, the
# candidate-link structure is data-dependent (pairwise PHY distances of a
# corner placement), so the host path runs Kruskal + union-find per
# individual.  Here the same result is computed on device:
#
# * a padded candidate-edge tensor over the *static* cross-chiplet PHY
#   pairs (row-major p < q order, exactly the host's np.nonzero
#   enumeration); per placement an edge is valid iff its length is within
#   max_link_mm;
# * per placement, candidates get distinct integer weights: their rank
#   under a stable sort by length (ties broken by enumeration order) —
#   precisely the order the host's stable Kruskal consumes.  With distinct
#   weights the MST is unique, so a batched Boruvka (log2 rounds of
#   per-component min-edge selection + pointer-jumping star contraction)
#   returns bit-for-bit the host's Kruskal edge set.  The host's weight-0
#   relay-internal edges are pre-merged into the initial component labels;
# * the paper's greedy augmentation (remaining candidates joining two
#   still-unused PHYs, in weight order) is a masked argmin scan — at most
#   Vp // 2 additions, each round taking the globally cheapest eligible
#   edge, which is exactly the sequential scan's acceptance set;
# * ``connected`` is derived from the final component labels with the same
#   rule as the (fixed) host check: some single component must contain at
#   least one PHY of every chiplet.  It is returned in the batch dict so
#   the device pipeline can mask-and-resample without trusting the
#   scorer's FW-reachability flag (subtly laxer on multi-PHY non-relay
#   chiplets).
# ---------------------------------------------------------------------------


class HeteroGraphBatch:
    """Batched ``PHY positions -> stacked ScoreGraph arrays`` for one arch."""

    def __init__(self, arch: ArchSpec):
        self.arch = arch
        n = len(arch.chiplets)
        phy_base = np.zeros(n + 1, dtype=np.int64)
        for i, ch in enumerate(arch.chiplets):
            phy_base[i + 1] = phy_base[i] + ch.n_phys()
        Vp = int(phy_base[-1])
        self.Vp, self.N, self.V = Vp, n, Vp + 2 * n
        self.e_max = 2 * Vp
        self.L = Vp                   # undirected link slots (== host e_max/2)
        owner = np.zeros(Vp, dtype=np.int64)
        for i in range(n):
            owner[phy_base[i]:phy_base[i + 1]] = i
        # Static candidate pairs, row-major upper-triangle (host order).
        pp, qq = np.nonzero(np.triu(np.ones((Vp, Vp), bool), k=1)
                            & (owner[:, None] != owner[None, :]))
        self.E = len(pp)
        self._u = jnp.asarray(pp.astype(np.int32))
        self._v = jnp.asarray(qq.astype(np.int32))
        # Working set: only the Ecap cheapest candidates enter the Borůvka /
        # augmentation scans.  Valid (<= max_link_mm) edges are sparse —
        # empirically < 5 * Vp even on dense corner placements — so 8 * Vp
        # leaves ample margin; the overflow flag triggers the exact host
        # fallback in the pipeline should a placement ever exceed it.
        self.Ecap = int(min(self.E, 8 * Vp))
        # Initial components: relay-internal (weight-0) unions pre-applied.
        comp0 = np.arange(Vp)
        for c in range(n):
            if arch.chiplets[c].relay:
                idx = np.nonzero(owner == c)[0]
                comp0[idx] = idx[0]
        self._comp0 = jnp.asarray(comp0.astype(np.int32))
        n_comp = len(np.unique(comp0))
        self._bor_rounds = max(1, int(np.ceil(np.log2(max(n_comp, 2)))))
        self._jump_rounds = int(np.ceil(np.log2(max(Vp, 2)))) + 1
        self._aug_rounds = Vp // 2
        self._owner_oh = jnp.asarray(owner[:, None] == np.arange(n)[None, :])
        # Static part of W: diagonal, internal relay edges, virtual edges.
        W = np.full((self.V, self.V), INF, dtype=np.float32)
        np.fill_diagonal(W, 0.0)
        lr = np.float32(arch.latency.l_relay)
        for c in range(n):
            idx = np.nonzero(owner == c)[0]
            if arch.chiplets[c].relay:
                for a in range(len(idx)):
                    for b2 in range(a + 1, len(idx)):
                        p, q = int(idx[a]), int(idx[b2])
                        W[p, q] = min(W[p, q], lr)
                        W[q, p] = min(W[q, p], lr)
            W[Vp + c, idx] = 0.0
            W[idx, Vp + n + c] = 0.0
        self._W_static = jnp.asarray(W)
        self._d2d = np.float32(arch.latency.d2d_cost())
        self._max_link = np.float32(arch.max_link_mm + 1e-9)

    # -- per-placement link inference (vmapped in build) ---------------------
    def _links_one(self, pos: jnp.ndarray):
        """pos [Vp, 2] -> (links [Ecap] bool, eu/ev [Ecap], comp [Vp],
        overflow bool).  Edges are compacted to the Ecap cheapest candidates
        (stable (length, enum-order) sort), so their index IS the distinct
        Kruskal rank."""
        u, v, Ec, Vp = self._u, self._v, self.Ecap, self.Vp
        d = pos[u] - pos[v]
        if self.arch.distance == "manhattan":
            dist = jnp.abs(d).sum(-1)
        else:
            dist = jnp.sqrt((d ** 2).sum(-1))
        valid = dist <= self._max_link
        overflow = valid.sum() > Ec
        srt = jnp.argsort(jnp.where(valid, dist, jnp.inf))[:Ec]
        eu, ev = u[srt], v[srt]
        elen = dist[srt].astype(jnp.float32)
        evalid = valid[srt]
        rank = jnp.arange(Ec, dtype=jnp.int32)
        node = jnp.arange(Vp, dtype=jnp.int32)
        comp = self._comp0
        sel = jnp.zeros(Ec, bool)
        for _ in range(self._bor_rounds):
            cu, cv = comp[eu], comp[ev]
            cross = evalid & (cu != cv)
            r = jnp.where(cross, rank, Ec)
            best = jnp.full(Vp, Ec, jnp.int32).at[cu].min(r).at[cv].min(r)
            min_u = cross & (rank == best[cu])    # unique per component:
            min_v = cross & (rank == best[cv])    # ranks are distinct
            sel = sel | min_u | min_v
            ptr = node
            ptr = ptr.at[jnp.where(min_u, cu, Vp)].set(cv, mode="drop")
            ptr = ptr.at[jnp.where(min_v, cv, Vp)].set(cu, mode="drop")
            # Star contraction: break the 2-cycles, then pointer-jump.
            ptr = jnp.where((ptr[ptr] == node) & (node < ptr), node, ptr)
            for _ in range(self._jump_rounds):
                ptr = ptr[ptr]
            comp = ptr[comp]
        # Greedy augmentation: repeatedly take the cheapest candidate whose
        # endpoint PHYs are both unused (== the host's sorted scan).
        used = jnp.zeros(Vp, bool)
        used = used.at[jnp.where(sel, eu, Vp)].set(True, mode="drop")
        used = used.at[jnp.where(sel, ev, Vp)].set(True, mode="drop")

        def aug_round(_, carry):
            used, aug = carry
            elig = evalid & ~sel & ~aug & ~used[eu] & ~used[ev]
            r = jnp.where(elig, rank, Ec)
            e = jnp.argmin(r)
            take = r[e] < Ec
            aug = aug.at[e].max(take)
            used = used.at[eu[e]].max(take).at[ev[e]].max(take)
            return used, aug

        _, aug = jax.lax.fori_loop(0, self._aug_rounds, aug_round,
                                   (used, jnp.zeros(Ec, bool)))
        return sel | aug, eu, ev, elen, comp, overflow

    def _graph_one(self, pos: jnp.ndarray):
        links, eu, ev, elen, comp, overflow = self._links_one(pos)
        # Compact chosen links into fixed slots (weight order; the scorer is
        # edge-order invariant, and padding is zeroed like the host's).
        rank = jnp.arange(self.Ecap, dtype=jnp.int32)
        order_idx = jnp.argsort(jnp.where(links, rank, self.Ecap))[:self.L]
        smask = jnp.arange(self.L) < links.sum()
        su = jnp.where(smask, eu[order_idx], 0)
        sv = jnp.where(smask, ev[order_idx], 0)
        sl = jnp.where(smask, elen[order_idx], 0.0)
        vals = jnp.where(smask, self._d2d, INF)       # INF scatter-min: no-op
        W = self._W_static.at[su, sv].min(vals).at[sv, su].min(vals)
        edges = jnp.stack([jnp.stack([su, sv], axis=-1),
                           jnp.stack([sv, su], axis=-1)],
                          axis=1).reshape(self.e_max, 2).astype(jnp.int32)
        mask = jnp.repeat(smask, 2)
        edge_len = jnp.repeat(sl, 2)
        # Fixed host connectivity rule: one component covers every chiplet.
        cov = jnp.zeros((self.Vp, self.N), bool).at[comp].max(self._owner_oh)
        connected = cov.all(axis=1).any()
        return W, edges, mask, edge_len, connected, overflow

    def build(self, ppos: jnp.ndarray, area: jnp.ndarray) -> dict:
        """[B, Vp, 2] PHY positions + [B] areas -> batched ScoreGraph arrays:
        stack_graphs keys plus the component-derived ``connected`` [B] and
        an ``overflow`` [B] flag (candidate count above Ecap; the caller
        must recompute those rows host-side — they are vanishingly rare).
        jit/vmap-able."""
        W, edges, mask, elen, conn, ovf = jax.vmap(self._graph_one)(ppos)
        return dict(W=W, edges=edges, edge_mask=mask, edge_len=elen,
                    area=jnp.asarray(area, jnp.float32), connected=conn,
                    overflow=ovf)
