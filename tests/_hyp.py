"""Optional-hypothesis shim.

``hypothesis`` is not part of the pinned environment.  Importing it at
module level made four test modules fail *collection*, taking their
non-property tests down with them.  Import ``given``/``settings``/``st``
from here instead: with hypothesis installed they are the real thing;
without it, ``@given`` tests are individually skipped and everything else
in the module still collects and runs.
"""
from __future__ import annotations

import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    class _DummyStrategies:
        """st.<anything>(...) -> None; only used as decorator arguments."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _DummyStrategies()

    def given(*_a, **_k):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*_a, **_k):
        return lambda f: f
