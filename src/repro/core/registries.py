"""Named registries for the experiment API (optimizers, scorer backends,
objective terms, schedule ramps, grid augmentations).

The PlaceIT pipeline is pluggable at five seams:

* **optimizers** — search algorithms over a placement representation, all
  with the uniform signature ``(evaluator, rng, budget, params) -> OptResult``
  plus a typed params dataclass (``api.BRParams`` etc.).
* **scorer backends** — the Floyd-Warshall ``W -> (D, Ncnt)`` implementation
  that dominates evaluation time (paper Table V): the pure-XLA reference,
  the Pallas VMEM-resident kernel, or the size-dispatched blocked-tile
  kernel for 100+-chiplet archs, selected by name (``"fw-ref"``,
  ``"fw-pallas"``, ``"fw-tiled"``).
* **objective terms** — the summands of the placement cost function
  (paper §IV-B): the built-in ``lat`` / ``inv-thr`` / ``area`` terms plus
  penalty terms, composed into an ``objective.Objective`` and lowered into
  the jitted scorer by ``objective.compile_objective``.
* **schedule ramps** — the shapes of constraint-hardening weight ramps
  (``objective.Schedule``): built-in ``linear`` / ``cosine`` / ``step``,
  with the uniform signature ``(t, start, end, params) -> scale`` over the
  run's progress fraction ``t`` in [0, 1].
* **augmentations** — alternatives to the paper's greedy augmentation for
  grid families: extra static candidate adjacencies (wraparound, express
  skip links) with the uniform signature
  ``(R, C, Z, sz_mm, params) -> list[AdjRecord]``
  (see ``repro.arch3d.topology``); built-in ``torus`` / ``express``.

Entries are registered with decorators::

    @register_optimizer("tabu", params_cls=TabuParams)
    def tabu(evaluator, rng, budget, params): ...

    @register_scorer_backend("fw-mine")
    def _build():            # zero-arg factory -> fw_impl callable
        return my_fw_impl

    @register_objective_term("power", host_fn=power_host)
    def power(sample, norms, objective, params): ...   # jnp scalar

Backends are registered as zero-arg *factories* so optional dependencies
(e.g. Pallas) are only imported when the backend is actually selected.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable


class Registry:
    """A named, typo-friendly mapping used for all pluggable seams."""

    def __init__(self, kind: str):
        self.kind = kind
        self._items: dict[str, Any] = {}

    def add(self, name: str, obj: Any) -> Any:
        if name in self._items:
            raise ValueError(f"duplicate {self.kind} {name!r}")
        self._items[name] = obj
        return obj

    def get(self, name: str) -> Any:
        try:
            return self._items[name]
        except KeyError:
            raise KeyError(
                f"unknown {self.kind} {name!r}; registered: "
                f"{', '.join(sorted(self._items)) or '(none)'}") from None

    def names(self) -> tuple[str, ...]:
        return tuple(sorted(self._items))

    def __contains__(self, name: str) -> bool:
        return name in self._items


@dataclass(frozen=True)
class OptimizerEntry:
    name: str
    fn: Callable            # (evaluator, rng, budget, params) -> OptResult
    params_cls: type        # typed hyper-parameter dataclass


@dataclass(frozen=True)
class ObjectiveTermEntry:
    """One cost-function summand (see ``repro.core.objective``).

    ``fn(sample, norms, objective, params) -> scalar`` is the per-placement
    device implementation (pure ``jnp``; traced inside the jitted scorer's
    vmap).  ``host_fn(metrics, batch, norms, objective, params) -> [B]
    float64`` is the optional batched host-numpy implementation used for
    reporting and for the legacy ``cost.total_cost`` equivalence; when
    omitted, the device ``fn`` is vmapped on host arrays instead (float32).
    """

    name: str
    fn: Callable
    host_fn: Callable | None = None


OPTIMIZERS = Registry("optimizer")
SCORER_BACKENDS = Registry("scorer backend")
OBJECTIVE_TERMS = Registry("objective term")
SCHEDULE_RAMPS = Registry("schedule ramp")
AUGMENTATIONS = Registry("augmentation")


def register_optimizer(name: str, *, params_cls: type):
    """Decorator: register ``fn(evaluator, rng, budget, params)`` under
    ``name`` with its typed params dataclass."""
    def deco(fn):
        OPTIMIZERS.add(name, OptimizerEntry(name, fn, params_cls))
        return fn
    return deco


def register_scorer_backend(name: str):
    """Decorator: register a zero-arg factory returning the fw_impl
    callable ``W -> (D, Ncnt)`` under ``name``."""
    def deco(factory):
        SCORER_BACKENDS.add(name, factory)
        return factory
    return deco


def register_objective_term(name: str, *, host_fn: Callable | None = None):
    """Decorator: register a per-placement cost term
    ``fn(sample, norms, objective, params) -> scalar`` (jnp; lowered into
    the jitted scorer) under ``name``, with an optional float64 batched
    ``host_fn`` for host-side reporting/equivalence paths."""
    def deco(fn):
        OBJECTIVE_TERMS.add(name, ObjectiveTermEntry(name, fn, host_fn))
        return fn
    return deco


def register_schedule_ramp(name: str):
    """Decorator: register a weight-ramp shape
    ``fn(t, start, end, params) -> scale`` under ``name`` (``t`` is the
    run's progress fraction in [0, 1]; see ``objective.Schedule``)."""
    def deco(fn):
        SCHEDULE_RAMPS.add(name, fn)
        return fn
    return deco


def register_augmentation(name: str):
    """Decorator: register a grid augmentation
    ``fn(R, C, Z, sz_mm, params) -> list[AdjRecord]`` under ``name`` —
    extra static candidate adjacencies (masked like the base grid's) that
    replace the paper's greedy leftover-PHY augmentation on grid families
    (see ``repro.arch3d.topology``)."""
    def deco(fn):
        AUGMENTATIONS.add(name, fn)
        return fn
    return deco


def resolve_backend(backend) -> Callable:
    """Resolve a backend name (or pass through a raw callable) to the
    fw_impl function.  Raw callables are allowed for the legacy
    ``Experiment.fw_impl`` shim and for experimentation."""
    if callable(backend):
        return backend
    return SCORER_BACKENDS.get(backend)()
