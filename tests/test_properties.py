"""Property-based tests for the device-resident pipeline.

Randomized-seed invariants via the optional-hypothesis shim (``_hyp``):
with hypothesis installed, ``@given`` draws seeds; without it, the same
checks run over a deterministic seed sweep (so this layer never goes
dark).  These replace the former hand-picked-seed operator spot checks in
``test_batched_pipeline.py``.

Covered properties:

* ``HomogBatch`` / ``Homog3DBatch`` / ``HeteroBatch`` operator invariants
  on randomized PRNG keys — permutation validity (per-kind chiplet counts
  preserved by random/mutate/merge), rotation ranges (non-isomorphic
  per-kind sets; grid PHYs face occupied neighbors, 3D rotations from the
  record-backed candidate cascade), merge carrying parent matches, and
  PRNG determinism (same key -> identical batch, distinct keys -> change).
* ``HeteroGraphBatch`` batched Borůvka vs the host Kruskal + union-find
  on randomized corner placements: bit-for-bit W / D2D edge set / area /
  component-derived ``connected``.
"""
import jax
import numpy as np
import pytest

from _hyp import HAVE_HYPOTHESIS, given, settings, st
from _invariants import (assert_valid_hetero_batch,
                         assert_valid_homog3d_batch,
                         assert_valid_homog_batch)

from repro.arch3d.families import make_rep3d
from repro.core.chiplets import IO, MEMORY, paper_arch, resolve_arch
from repro.core.placement_hetero import HeteroRep
from repro.core.placement_homog import HomogRep
from repro.core.topology import HeteroGraphBatch

ARCH = paper_arch("homog32", "baseline")
HARCH = paper_arch("hetero32", "baseline")
ARCH3 = resolve_arch("stack3d32", "baseline")
R, C = 8, 5
B = 12          # batch size per drawn seed

FALLBACK_SEEDS = [0, 3, 17, 255, 99991]
MAXEX = 12      # hypothesis examples per property


@pytest.fixture(scope="module")
def rep():
    return HomogRep(ARCH, R=R, C=C)


@pytest.fixture(scope="module")
def ops(rep):
    return rep.batch_ops()


@pytest.fixture(scope="module")
def hrep():
    return HeteroRep(HARCH)


@pytest.fixture(scope="module")
def hops(hrep):
    return hrep.batch_ops()


@pytest.fixture(scope="module")
def hgb():
    return HeteroGraphBatch(HARCH)


@pytest.fixture(scope="module")
def rep3():
    return make_rep3d(ARCH3, "stack3d32")


@pytest.fixture(scope="module")
def ops3(rep3):
    return rep3.batch_ops()


# ---------------------------------------------------------------------------
# Core property checks (shared by @given and the deterministic sweep).
# ---------------------------------------------------------------------------

def check_homog_ops(rep, ops, seed: int):
    k0, k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 4)
    t, r = ops.random_batch(k0, B)
    assert t.dtype == np.int8 and t.shape == (B, R, C)
    assert_valid_homog_batch(rep, t, r)
    # PRNG determinism: same key -> identical batch
    t2, r2 = ops.random_batch(k0, B)
    assert np.array_equal(np.asarray(t), np.asarray(t2))
    assert np.array_equal(np.asarray(r), np.asarray(r2))
    # mutation keeps invariants and changes at least one placement
    mt, mr = ops.mutate_batch(k1, t, r)
    assert_valid_homog_batch(rep, mt, mr)
    changed = (np.asarray(mt) != np.asarray(t)).any(axis=(1, 2)) \
        | (np.asarray(mr) != np.asarray(r)).any(axis=(1, 2))
    assert changed.any()
    # merge keeps invariants and carries cells both parents agree on
    tb, rb = ops.random_batch(k2, B)
    tg, rg = ops.merge_batch(k3, t, r, tb, rb)
    assert_valid_homog_batch(rep, tg, rg)
    t_, tb_, tg_ = np.asarray(t), np.asarray(tb), np.asarray(tg)
    r_, rb_, rg_ = np.asarray(r), np.asarray(rb), np.asarray(rg)
    for b in range(B):
        match = t_[b] == tb_[b]
        assert (tg_[b][match] == t_[b][match]).all()
        # carried rotations where both parents agree on type+rotation,
        # for the single-PHY kinds (baseline memory/IO)
        rot_match = match & (r_[b] == rb_[b]) & np.isin(t_[b], [MEMORY, IO])
        assert (rg_[b][rot_match] == r_[b][rot_match]).all()


def check_homog3d_ops(rep3, ops3, seed: int):
    """The 3D rep's operator invariants (mirrors ``check_homog_ops`` on
    the [B, R, C, Z] solution shape)."""
    R3, C3, Z3 = rep3.R, rep3.C, rep3.Z
    k0, k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 4)
    t, r = ops3.random_batch(k0, B)
    assert t.dtype == np.int8 and t.shape == (B, R3, C3, Z3)
    assert_valid_homog3d_batch(rep3, t, r)
    # PRNG determinism: same key -> identical batch
    t2, r2 = ops3.random_batch(k0, B)
    assert np.array_equal(np.asarray(t), np.asarray(t2))
    assert np.array_equal(np.asarray(r), np.asarray(r2))
    mt, mr = ops3.mutate_batch(k1, t, r)
    assert_valid_homog3d_batch(rep3, mt, mr)
    changed = (np.asarray(mt) != np.asarray(t)).any(axis=(1, 2, 3)) \
        | (np.asarray(mr) != np.asarray(r)).any(axis=(1, 2, 3))
    assert changed.any()
    tb, rb = ops3.random_batch(k2, B)
    tg, rg = ops3.merge_batch(k3, t, r, tb, rb)
    assert_valid_homog3d_batch(rep3, tg, rg)
    t_, tb_, tg_ = np.asarray(t), np.asarray(tb), np.asarray(tg)
    r_, rb_, rg_ = np.asarray(r), np.asarray(rb), np.asarray(rg)
    for b in range(B):
        match = t_[b] == tb_[b]
        assert (tg_[b][match] == t_[b][match]).all()
        rot_match = match & (r_[b] == rb_[b]) & np.isin(t_[b], [MEMORY, IO])
        assert (rg_[b][rot_match] == r_[b][rot_match]).all()


def check_hetero_ops(hrep, hops, seed: int):
    k0, k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 4)
    o, r = hops.random_batch(k0, B)
    assert o.dtype == np.int8
    assert_valid_hetero_batch(hrep, o, r)
    o2, r2 = hops.random_batch(k0, B)
    assert np.array_equal(np.asarray(o), np.asarray(o2))
    assert np.array_equal(np.asarray(r), np.asarray(r2))
    mo, mr = hops.mutate_batch(k1, o, r)
    assert_valid_hetero_batch(hrep, mo, mr)
    changed = (np.asarray(mo) != np.asarray(o)).any(axis=1) \
        | (np.asarray(mr) != np.asarray(r)).any(axis=1)
    assert changed.any()
    ob, rb = hops.random_batch(k2, B)
    og, rg = hops.merge_batch(k3, o, r, ob, rb)
    assert_valid_hetero_batch(hrep, og, rg)
    o_, ob_, og_ = np.asarray(o), np.asarray(ob), np.asarray(og)
    r_, rb_, rg_ = np.asarray(r), np.asarray(rb), np.asarray(rg)
    for b in range(B):
        match = o_[b] == ob_[b]
        assert (og_[b][match] == o_[b][match]).all()
        rmatch = match & (r_[b] == rb_[b])
        assert (rg_[b][rmatch] == r_[b][rmatch]).all()


def check_hetero_boruvka_matches_kruskal(hrep, hops, hgb, seed: int,
                                         n: int = 6):
    """Randomized placements: device Borůvka == host Kruskal bit-for-bit."""
    import jax.numpy as jnp
    rng = np.random.default_rng(seed)
    sols = [hrep.random(rng) for _ in range(n)]
    host = [hrep.score_graph(s) for s in sols]
    ppos, area = hops.geometry_batch(np.stack([s[0] for s in sols]),
                                     np.stack([s[1] for s in sols]))
    batch = {k: np.asarray(v)
             for k, v in hgb.build(jnp.asarray(ppos),
                                   jnp.asarray(area)).items()}
    assert not batch.pop("overflow").any()
    for i, g in enumerate(host):
        assert np.array_equal(batch["W"][i], g.W)
        mine = {(int(u), int(v))
                for (u, v), m in zip(batch["edges"][i],
                                     batch["edge_mask"][i]) if m}
        ref = {(int(u), int(v))
               for (u, v), m in zip(g.edges, g.edge_mask) if m}
        assert mine == ref
        assert float(batch["area"][i]) == float(g.area)
        assert bool(batch["connected"][i]) == g.connected


# ---------------------------------------------------------------------------
# Hypothesis-drawn seeds (skipped individually when hypothesis is absent).
# ---------------------------------------------------------------------------

@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
@settings(max_examples=MAXEX, deadline=None)
def test_homog_operator_invariants_property(rep, ops, seed):
    check_homog_ops(rep, ops, seed)


@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
@settings(max_examples=MAXEX, deadline=None)
def test_homog3d_operator_invariants_property(rep3, ops3, seed):
    check_homog3d_ops(rep3, ops3, seed)


@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
@settings(max_examples=MAXEX, deadline=None)
def test_hetero_operator_invariants_property(hrep, hops, seed):
    check_hetero_ops(hrep, hops, seed)


@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
@settings(max_examples=8, deadline=None)
def test_hetero_boruvka_vs_kruskal_property(hrep, hops, hgb, seed):
    check_hetero_boruvka_matches_kruskal(hrep, hops, hgb, seed)


# ---------------------------------------------------------------------------
# Deterministic seed sweep: the same properties when hypothesis is not
# installed (the pinned environment), so the layer always runs.
# ---------------------------------------------------------------------------

@pytest.mark.skipif(HAVE_HYPOTHESIS,
                    reason="hypothesis drives the property above")
@pytest.mark.parametrize("seed", FALLBACK_SEEDS)
def test_homog_operator_invariants_seeds(rep, ops, seed):
    check_homog_ops(rep, ops, seed)


@pytest.mark.skipif(HAVE_HYPOTHESIS,
                    reason="hypothesis drives the property above")
@pytest.mark.parametrize("seed", FALLBACK_SEEDS)
def test_homog3d_operator_invariants_seeds(rep3, ops3, seed):
    check_homog3d_ops(rep3, ops3, seed)


@pytest.mark.skipif(HAVE_HYPOTHESIS,
                    reason="hypothesis drives the property above")
@pytest.mark.parametrize("seed", FALLBACK_SEEDS)
def test_hetero_operator_invariants_seeds(hrep, hops, seed):
    check_hetero_ops(hrep, hops, seed)


@pytest.mark.skipif(HAVE_HYPOTHESIS,
                    reason="hypothesis drives the property above")
@pytest.mark.parametrize("seed", FALLBACK_SEEDS)
def test_hetero_boruvka_vs_kruskal_seeds(hrep, hops, hgb, seed):
    check_hetero_boruvka_matches_kruskal(hrep, hops, hgb, seed)


# ---------------------------------------------------------------------------
# Blocked-tile FW + path counts (PR 7): bit-for-bit parity with the
# sequential reference on randomized sparse/disconnected graphs and on
# every paper arch's real scoring matrix, plus count-clip saturation.
# ---------------------------------------------------------------------------

import functools

import jax.numpy as jnp

from repro.kernels import ref as kref
from repro.kernels.minplus import _COUNT_CLIP, fw_counts_tiled_pallas

# One jitted instance per tile size so repeated property draws share the
# compiled executable instead of re-tracing per seed.
_TILED16 = jax.jit(functools.partial(fw_counts_tiled_pallas, bt=16))
_TILED128 = jax.jit(functools.partial(fw_counts_tiled_pallas, bt=128))
_FW_REF = jax.jit(kref.fw_counts_ref)


def check_fw_tiled_random(seed: int):
    """Random INF-heavy graph (disconnected components common): the tiled
    kernel must match the reference bit-for-bit on D and N."""
    rng = np.random.default_rng(seed)
    V = int(rng.integers(9, 40))
    W = np.full((2, V, V), 1e9, np.float32)
    for b in range(2):
        np.fill_diagonal(W[b], 0.0)
        n_edges = int(rng.integers(0, 3 * V))   # 0 => fully disconnected
        if n_edges:
            i = rng.integers(0, V, n_edges)
            j = rng.integers(0, V, n_edges)
            w = rng.integers(1, 9, n_edges).astype(np.float32)
            W[b, i, j] = np.minimum(W[b, i, j], w)
            W[b, j, i] = np.minimum(W[b, j, i], w)
            np.fill_diagonal(W[b], 0.0)
    Wj = jnp.asarray(W)
    D1, N1 = _TILED16(Wj)
    D2, N2 = _FW_REF(Wj)
    assert np.array_equal(np.asarray(D1), np.asarray(D2))
    assert np.array_equal(np.asarray(N1), np.asarray(N2))


@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
@settings(max_examples=6, deadline=None)
def test_fw_tiled_random_graphs_property(seed):
    check_fw_tiled_random(seed)


@pytest.mark.skipif(HAVE_HYPOTHESIS,
                    reason="hypothesis drives the property above")
@pytest.mark.parametrize("seed", FALLBACK_SEEDS)
def test_fw_tiled_random_graphs_seeds(seed):
    check_fw_tiled_random(seed)


@pytest.mark.parametrize("arch_name", ["homog32", "homog64",
                                       "hetero32", "hetero64"])
def test_fw_tiled_paper_arch_parity(arch_name):
    """Bit-for-bit D and N on the real scoring matrix of each paper arch
    (the acceptance criterion for the fw-tiled backend)."""
    from repro.core.api import make_rep
    arch = paper_arch(arch_name, "baseline")
    rep = make_rep(arch, arch_name)
    rng = np.random.default_rng(5)
    W = jnp.asarray(rep.score_graph(rep.random(rng)).W)
    D1, N1 = _TILED128(W)
    D2, N2 = _FW_REF(W)
    assert np.array_equal(np.asarray(D1), np.asarray(D2))
    assert np.array_equal(np.asarray(N1), np.asarray(N2))


def test_fw_tiled_count_clip_saturation():
    """K layered stages of M parallel midpoints give M^(K-1) shortest
    paths — far past _COUNT_CLIP, so both kernels must saturate to the
    clip identically (and bit-for-bit vs each other)."""
    M, K = 10, 32
    V = 2 + (K - 1) * M
    W = np.full((V, V), 1e9, np.float32)
    np.fill_diagonal(W, 0.0)

    def node(stage, m):
        if stage == 0:
            return 0
        if stage == K:
            return 1
        return 2 + (stage - 1) * M + m

    for s in range(K):
        for ma in range(M if s > 0 else 1):
            for mb in range(M if s < K - 1 else 1):
                W[node(s, ma), node(s + 1, mb)] = 1.0
    Wj = jnp.asarray(W)
    D1, N1 = _TILED128(Wj)
    D2, N2 = _FW_REF(Wj)
    assert np.array_equal(np.asarray(D1), np.asarray(D2))
    assert np.array_equal(np.asarray(N1), np.asarray(N2))
    assert float(N1[0, 1]) == np.float32(_COUNT_CLIP)
