"""End-to-end system behaviour: the paper's full pipeline on a small budget.

Optimize a placement (GA), extract its ICI topology, simulate a
cache-coherency trace on it AND on the 2D-mesh baseline, and check the
PlaceIT design is at least competitive — the §VII comparison in miniature.
"""
import numpy as np
import pytest

from repro.core.baseline import MeshBaseline
from repro.core.chiplets import paper_arch
from repro.core.netsim import ChipletNet, NetSim
from repro.core.optimize import Evaluator, genetic_algorithm
from repro.core.placement_homog import HomogRep
from repro.core.traces import TraceRegion, generate_trace


def test_placeit_pipeline_end_to_end():
    arch = paper_arch("homog32", "placeit")
    rep = HomogRep(arch, R=8, C=5, mutation_mode="neighbor-one")
    rng = np.random.default_rng(0)
    ev = Evaluator(rep, arch, rng=rng, norm_samples=16)
    res = genetic_algorithm(ev, rng, population=12, elitism=3, tournament=3,
                            max_generations=4)
    assert res.best_sol is not None

    # --- simulate a trace on the optimized design -----------------------
    links, _ = rep.links_of(res.best_sol)
    geo = rep.geometry(res.best_sol)
    net_opt = ChipletNet.from_links(arch, geo, links)

    mb = MeshBaseline(arch)
    _, geo_b, links_b = mb.build()
    net_base = ChipletNet.from_links(arch, geo_b, links_b)

    regions = (TraceRegion(1200, 30_000),)
    lat = {}
    for name, net in (("placeit", net_opt), ("baseline", net_base)):
        pkts = generate_trace(net, regions, seed=3)
        sim = NetSim(net, arch)
        lat[name] = sim.run(pkts, mode="authentic").avg_latency
    # small budget -> just require competitiveness and valid outputs
    assert np.isfinite(lat["placeit"]) and np.isfinite(lat["baseline"])
    assert lat["placeit"] < lat["baseline"] * 1.3


def test_metrics_beat_baseline_on_weighted_terms():
    """GA-optimized design should beat the mesh baseline on the highest-
    weighted proxy (C2M latency) — the paper's core claim, small budget."""
    arch = paper_arch("homog32", "baseline")
    rep = HomogRep(arch, R=8, C=5)
    rng = np.random.default_rng(1)
    ev = Evaluator(rep, arch, rng=rng, norm_samples=16)
    res = genetic_algorithm(ev, rng, population=16, elitism=4, tournament=4,
                            max_generations=5)
    g_base = MeshBaseline(arch).build()[0]
    base = {k: float(v[0]) for k, v in ev.score([g_base]).items()}
    assert res.best_metrics["lat_c2m"] < base["lat_c2m"]
