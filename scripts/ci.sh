#!/usr/bin/env sh
# Tier-1 CI: fast test pass (slow-marked tests excluded) + quick bench
# smokes for the pipeline-throughput and pareto-frontier benches (set
# CI_SKIP_BENCH=1 to skip them).
#   scripts/ci.sh [extra pytest args...]
#
# Coverage: when pytest-cov is installed, the test pass also reports
# line coverage for src/repro/core/ and enforces CI_COV_FLOOR
# (default 0 = report-only on this first PR; once a baseline number is
# measured in an environment with pytest-cov, pin it via CI_COV_FLOOR).
# The pinned container has no pytest-cov/coverage, so the flags are
# gated on importability rather than assumed.
set -eu
cd "$(dirname "$0")/.."
COV_ARGS=""
if python -c "import pytest_cov" 2>/dev/null; then
    COV_ARGS="--cov=repro.core --cov-report=term \
--cov-fail-under=${CI_COV_FLOOR:-0}"
fi
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m pytest -q -m "not slow" $COV_ARGS "$@"
if [ "${CI_SKIP_BENCH:-0}" != "1" ]; then
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
        python -m benchmarks.run --only pipeline
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
        python -m benchmarks.run --only pareto
fi
