"""Pluggable objective API: serialization round-trips, registry error
paths, bit-for-bit equivalence of the default Objective with the legacy
total_cost formula, device-vs-host cost agreement (including the new
penalty terms), trace-derived mixes, normalizer policies, the degenerate-
normalizer flag, and in-scorer ranking."""
import dataclasses
import warnings

import numpy as np
import pytest

from repro.core.api import (Budget, ExperimentConfig, clear_scorer_cache,
                            make_evaluator, make_rep, run_experiment,
                            run_sweep, scorer_cache_stats)
from repro.core.chiplets import TRAFFIC_TYPES, paper_arch
from repro.core.cost import CostNormalizers, total_cost
from repro.core.objective import (NORM_DIM, Objective, TermSpec, TrafficMix,
                                  compile_objective, norms_vec,
                                  objective_cost_host)
from repro.core.registries import OBJECTIVE_TERMS, register_objective_term
from repro.core.topology import stack_graphs
from repro.core.traces import TraceMix


def _evaluator(arch_name, config="baseline", objective=None, n=8,
               workload=None):
    arch = paper_arch(arch_name, config)
    rep = make_rep(arch, arch_name)
    return make_evaluator(rep, arch, rng=np.random.default_rng(0),
                          norm_samples=n, chunk=4, objective=objective,
                          workload=workload), rep


def _scored(ev, n=6, seed=1):
    rng = np.random.default_rng(seed)
    sols, graphs = ev.generate_valid(ev.rep.random, rng, n)
    return ev.score(graphs), stack_graphs(graphs)


# ---------------------------------------------------------------------------
# Serialization.
# ---------------------------------------------------------------------------

def test_traffic_mix_roundtrip_and_validation():
    m = TrafficMix(lat=(1, 2, 3, 4), thr=(0.5, 0.5, 0.5, 0.5))
    assert TrafficMix.from_dict(m.to_dict()) == m
    assert TrafficMix.paper() == TrafficMix()
    assert TrafficMix().lat == (0.1, 2.0, 0.1, 2.0)
    with pytest.raises(ValueError, match="weights"):
        TrafficMix(lat=(1, 2, 3))
    with pytest.raises(ValueError, match="finite"):
        TrafficMix(lat=(1, 2, 3, -4))
    with pytest.raises(ValueError, match="unknown TrafficMix"):
        TrafficMix.from_dict({"lat": [1, 2, 3, 4], "bogus": 1})


def test_objective_roundtrip_dict_json():
    obj = Objective(
        mix=TrafficMix(lat=(1, 1, 1, 1), thr=(2, 2, 2, 2)), w_area=0.5,
        normalizer="median",
        terms=("lat", "inv-thr", "area",
               {"name": "link-length-cap", "weight": 2.0,
                "params": {"cap_mm": 1.5}}))
    assert Objective.from_dict(obj.to_dict()) == obj
    assert Objective.from_json(obj.to_json()) == obj
    assert hash(Objective.from_json(obj.to_json())) == hash(obj)
    # terms are normalized to TermSpec with sorted hashable params
    assert obj.terms[3] == TermSpec("link-length-cap", weight=2.0,
                                    params={"cap_mm": 1.5})
    with pytest.raises(ValueError, match="unknown Objective keys"):
        Objective.from_dict({"bogus": 1})
    with pytest.raises(ValueError, match="normalizer policy"):
        Objective(normalizer="nope")


def test_experiment_config_carries_objective():
    obj = Objective().with_terms(TermSpec("node-degree",
                                          params={"max_degree": 3}))
    cfg = ExperimentConfig(arch="homog32", objective=obj)
    assert ExperimentConfig.from_dict(cfg.to_dict()) == cfg
    assert ExperimentConfig.from_json(cfg.to_json()).objective == obj
    # old serialized configs (no objective key) load with the paper default
    d = cfg.to_dict()
    del d["objective"]
    assert ExperimentConfig.from_dict(d).objective == Objective()


# ---------------------------------------------------------------------------
# Registry error paths.
# ---------------------------------------------------------------------------

def test_unknown_term_raises_with_registered_list():
    obj = Objective(terms=("lat", "no-such-term"))
    with pytest.raises(KeyError, match="unknown objective term"):
        compile_objective(obj)
    # ... and therefore fails fast when an evaluator is built around it
    with pytest.raises(KeyError, match="no-such-term"):
        _evaluator("homog32", objective=obj)


def test_duplicate_term_registration_raises():
    assert "lat" in OBJECTIVE_TERMS
    with pytest.raises(ValueError, match="duplicate objective term"):
        @register_objective_term("lat")
        def _dup(sample, norms, obj, params):  # pragma: no cover
            return 0.0


def test_custom_term_is_drop_in():
    if "test-flat" not in OBJECTIVE_TERMS:
        @register_objective_term("test-flat")
        def _flat(sample, norms, obj, params):
            return params.get("value", 1.0) + 0.0 * sample["area"]

    ev, _ = _evaluator("homog32", objective=Objective().with_terms(
        TermSpec("test-flat", weight=2.0, params={"value": 3.0})))
    metrics, batch = _scored(ev, n=4)
    base = objective_cost_host(metrics, Objective(), ev.norm)
    np.testing.assert_allclose(ev.costs_from(metrics), base + 6.0,
                               rtol=1e-4)


# ---------------------------------------------------------------------------
# Equivalence: default Objective == legacy total_cost; device == host.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch_name",
                         ["homog32", "homog64", "hetero32", "hetero64"])
def test_default_objective_is_legacy_total_cost_bit_for_bit(arch_name):
    from repro.core.cost import cost_components
    arch = paper_arch(arch_name, "baseline")
    ev, _ = _evaluator(arch_name, n=6)
    metrics, _ = _scored(ev, n=5)
    host = objective_cost_host(metrics, Objective(), ev.norm)
    assert host.dtype == np.float64
    # total_cost delegates to the objective layer; the *independent*
    # reference is the original numpy component formula (cost_components,
    # untouched by the objective layer) summed in the canonical grouped
    # order (all lat, all inv-thr, area).  Note: the pre-objective
    # total_cost summed components interleaved per traffic type, which
    # differs from the grouped order in the last float64 ulp.
    comp = cost_components(metrics, arch, ev.norm)
    ref = (sum(comp[f"lat_{t}"] for t in TRAFFIC_TYPES)
           + sum(comp[f"thr_{t}"] for t in TRAFFIC_TYPES) + comp["area"])
    assert np.array_equal(host, ref)
    assert np.array_equal(total_cost(metrics, arch, ev.norm), ref)
    # the deprecated ArchSpec.w_* alias constructs exactly this objective
    assert Objective.from_arch(arch) == Objective()
    assert arch.default_objective() == Objective()


@pytest.mark.parametrize("arch_name", ["homog32", "hetero32"])
def test_device_cost_agrees_with_host_incl_penalty_terms(arch_name):
    obj = Objective(terms=(
        "lat", "inv-thr", "area",
        {"name": "link-length-cap", "weight": 2.0, "params": {"cap_mm": 1.5}},
        {"name": "node-degree", "weight": 0.25, "params": {"max_degree": 2}}))
    ev, _ = _evaluator(arch_name, config="placeit", objective=obj)
    metrics, batch = _scored(ev)
    assert "cost" in metrics                 # cost computed in-scorer
    host = objective_cost_host(metrics, obj, ev.norm, batch=batch)
    np.testing.assert_allclose(ev.costs_from(metrics), host,
                               rtol=1e-4, atol=1e-5)
    # on hetero placements the penalties actually bite
    if arch_name == "hetero32":
        base = objective_cost_host(metrics, Objective(), ev.norm)
        assert (host - base).max() > 0


def test_penalty_terms_hand_computed():
    # 4 PHYs, two undirected links: 0-1 (len 2.5) and 1-2 (len 0.5).
    edges = np.array([[[0, 1], [1, 0], [1, 2], [2, 1], [0, 0], [0, 0]]],
                     np.int32)
    mask = np.array([[1, 1, 1, 1, 0, 0]], bool)
    elen = np.array([[2.5, 2.5, 0.5, 0.5, 9.9, 9.9]], np.float32)
    batch = {"edges": edges, "edge_mask": mask, "edge_len": elen}
    metrics = {"area": np.array([1.0])}
    obj = Objective(terms=(
        {"name": "link-length-cap", "params": {"cap_mm": 1.0}},
        {"name": "node-degree", "params": {"max_degree": 1}}))
    n = CostNormalizers.from_samples(
        {**{f"lat_{t}": np.array([1.0]) for t in TRAFFIC_TYPES},
         **{f"thr_{t}": np.array([1.0]) for t in TRAFFIC_TYPES},
         "area": np.array([1.0])})
    cost = objective_cost_host(metrics, obj, n, batch=batch)
    # link overage: (2.5-1.0) + 0 = 1.5; degree overage: node 1 has
    # degree 2 -> 1 over the cap.
    np.testing.assert_allclose(cost, [1.5 + 1.0])


# ---------------------------------------------------------------------------
# Trace-derived mixes.
# ---------------------------------------------------------------------------

def test_trace_mix_shares_and_weights():
    tm = TraceMix()
    for fw in (True, False):
        shares = tm.class_shares(flit_weighted=fw)
        assert abs(sum(shares.values()) - 1.0) < 1e-12
        assert shares["c2m"] == max(shares.values())   # §V-B: 80-95% C2M
        assert shares["c2i"] == 0.0                    # no direct C<->I
    mix = TrafficMix.from_trace_mix(tm)
    assert mix.lat == mix.thr
    assert abs(sum(mix.lat) - 4.2) < 1e-9              # paper-sum scaling
    # it is a valid config value end to end
    cfg = ExperimentConfig(arch="homog32", objective=Objective(mix=mix))
    assert ExperimentConfig.from_json(cfg.to_json()) == cfg


def test_trace_mix_shares_match_generated_trace():
    from repro.core.baseline import MeshBaseline
    from repro.core.netsim import ChipletNet
    from repro.core.traces import TraceRegion, generate_trace, trace_stats
    arch = paper_arch("homog32", "baseline")
    _, geo, links = MeshBaseline(arch).build()
    net = ChipletNet.from_links(arch, geo, links)
    pk = generate_trace(net, (TraceRegion(4000, 40_000),), seed=3)
    stats = trace_stats(pk, net)
    want = TraceMix().class_shares(flit_weighted=False)
    got = {"c2c": stats["c2c"], "c2m": stats["c2m"] + stats["m2c"],
           "m2i": stats["m2i"] + stats["i2m"]}
    for k, v in got.items():
        assert abs(v - want[k]) < 0.03, (k, v, want[k])


# ---------------------------------------------------------------------------
# Normalizer policies + degenerate-normalizer flag.
# ---------------------------------------------------------------------------

def _norm_metrics(lat=10.0):
    m = {f"lat_{t}": np.array([lat, lat * 3]) for t in TRAFFIC_TYPES}
    m |= {f"thr_{t}": np.array([0.25, 1.0]) for t in TRAFFIC_TYPES}
    m["area"] = np.array([100.0, 300.0])
    return m


def test_normalizer_policies():
    m = _norm_metrics()
    assert CostNormalizers.from_samples(m).lat["c2c"] == 20.0
    assert CostNormalizers.from_samples(m, policy="median").area == 200.0
    ones = CostNormalizers.from_samples(m, policy="ones")
    assert ones.lat["c2c"] == ones.inv_thr["m2i"] == ones.area == 1.0
    ev, _ = _evaluator("homog32",
                       objective=Objective(normalizer="ones"), n=4)
    assert np.array_equal(ev.norm_vec, np.ones(NORM_DIM, np.float32))


def test_degenerate_norms_warn_and_flag():
    m = _norm_metrics(lat=1.0e9)         # every sample disconnected
    with pytest.warns(RuntimeWarning, match="disconnected"):
        n = CostNormalizers.from_samples(m)
    assert n.degenerate == TRAFFIC_TYPES
    assert n.lat["c2m"] == 1.0 and n.inv_thr["c2m"] == 1.0
    # healthy draws leave the flag empty
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert CostNormalizers.from_samples(_norm_metrics()).degenerate == ()


def test_degenerate_norms_propagate_to_run_records(monkeypatch):
    orig = CostNormalizers.from_samples

    def degenerate(metrics, policy="mean"):
        n = orig(metrics, policy)
        n.degenerate = ("c2i",)
        return n

    monkeypatch.setattr(CostNormalizers, "from_samples",
                        staticmethod(degenerate))
    cfg = ExperimentConfig(arch="homog32", algorithms=("br",),
                           budget=Budget(evals=4), norm_samples=4, chunk=4)
    (rec,) = run_experiment(cfg)
    assert rec.degenerate_norms == ("c2i",)
    (rec,) = run_sweep([cfg]).records
    assert rec.degenerate_norms == ("c2i",)


# ---------------------------------------------------------------------------
# In-scorer ranking + objective-keyed scorer sharing.
# ---------------------------------------------------------------------------

def test_topk_matches_host_order():
    ev, _ = _evaluator("homog32")
    rng = np.random.default_rng(5)
    _, graphs = ev.generate_valid(ev.rep.random, rng, 9)
    costs, metrics = ev.costs(graphs)
    ck, ik = ev.topk(graphs, k=4)
    order = np.argsort(costs, kind="stable")[:4]
    np.testing.assert_allclose(ck, costs[order], rtol=1e-6)
    assert ck[0] == costs[ik[0]] and set(ik) == set(order)


def test_scorer_cache_keys_on_objective_structure():
    clear_scorer_cache()
    base = dict(arch="homog32", algorithms=("br",), budget=Budget(evals=4),
                norm_samples=4, chunk=4)
    same = [ExperimentConfig(**base, seed=s) for s in (0, 1)]
    res = run_sweep(same)
    assert res.stats.scorers_built == 1         # shared across seeds
    # Objective *weights* are runtime vectors: a mix-only change shares
    # the compiled scorer AND the stacked scoring group (per-row weight
    # vectors keep each run's costs exact) — the Pareto-grid fast path.
    reweighted = ExperimentConfig(**base, objective=Objective(
        mix=TrafficMix(lat=(1, 1, 1, 1), thr=(1, 1, 1, 1))))
    res2 = run_sweep([same[0], reweighted])
    assert res2.stats.scorers_built == 0        # same structure -> shared
    assert res2.stats.stacked_groups == 1
    # ... and the shared-scorer run is bit-for-bit the solo run
    solo = run_experiment(reweighted)
    assert res2.runs[1].records[0].result.best_cost \
        == solo[0].result.best_cost
    # a different term *structure* still forces a new compilation and
    # never stacks with the default-structure runs
    restructured = ExperimentConfig(**base, objective=Objective().with_terms(
        TermSpec("link-length-cap", params={"cap_mm": 2.0})))
    res3 = run_sweep([same[0], restructured])
    stats = scorer_cache_stats()
    assert res3.stats.scorers_built == 1
    assert res3.stats.stacked_groups == 0
    assert stats["misses"] == 2


def test_termspec_accepts_string_and_bool_params():
    t = TermSpec("lat", params={"mode": "soft", "hard": True, "cap": 2})
    assert t.param_dict() == {"mode": "soft", "hard": True, "cap": 2.0}
    assert TermSpec.from_dict(t.to_dict()) == t and hash(t) == hash(t)
    with pytest.raises(TypeError, match="JSON scalars"):
        TermSpec("lat", params={"bad": [1, 2]})


def test_topk_respects_hetero_connectivity_override():
    # A hetero device batch carries its own Borůvka-component `connected`
    # (stricter than the scorer's FW reachability); topk must never rank a
    # host-rule-invalid row first.
    ev, rep = _evaluator("hetero32")
    from repro.core.optimize import DevicePipeline
    pipe = DevicePipeline(ev)
    import jax
    o, r, batch = pipe._gen(jax.random.PRNGKey(0), 8)
    batch = {k: np.asarray(v) for k, v in dict(batch).items()}
    conn = batch["connected"].astype(bool).copy()
    costs = ev.costs_from(ev.score_batch(
        {k: v for k, v in batch.items() if k not in ("connected",
                                                     "overflow")}))
    # force the cheapest row invalid and check it is demoted
    cheapest = int(np.argmin(np.where(conn, costs, np.inf)))
    conn2 = conn.copy()
    conn2[cheapest] = False
    batch["connected"] = conn2
    ck, ik = ev.topk(batch, k=3)
    assert cheapest not in set(int(i) for i in ik if np.isfinite(ck[0]))
    valid_sorted = np.argsort(np.where(conn2, costs, np.inf))[:3]
    assert int(ik[0]) == int(valid_sorted[0])


# ---------------------------------------------------------------------------
# trace-lat: traffic-driven objective term (device == host, plumbing).
# ---------------------------------------------------------------------------

def _trace_workload(arch_name, traffic="c2m", rate=0.01):
    from repro.netsim import Workload
    arch = paper_arch(arch_name, "baseline")
    return Workload.synthetic(arch.kinds(), traffic, rate)


@pytest.mark.parametrize("arch_name", ["homog32", "hetero32"])
def test_trace_lat_device_cost_agrees_with_host(arch_name):
    obj = Objective().with_terms(TermSpec("trace-lat", weight=0.5))
    wl = _trace_workload(arch_name)
    ev, _ = _evaluator(arch_name, objective=obj, workload=wl)
    metrics, batch = _scored(ev)
    # the fused scorer emits the per-class traffic metrics...
    for t in TRAFFIC_TYPES:
        assert f"trace_lat_{t}" in metrics
    assert "trace_max_load" in metrics
    # ...and the float64 host recomputation matches the device cost
    host = objective_cost_host(metrics, obj, ev.norm, batch=batch)
    np.testing.assert_allclose(ev.costs_from(metrics), host,
                               rtol=1e-4, atol=1e-5)
    # traffic on c2m only: the term adds a strictly positive summand
    base = objective_cost_host(metrics, Objective(), ev.norm)
    assert (host > base).all()


def test_trace_lat_requires_matching_workload():
    obj = Objective().with_terms(TermSpec("trace-lat"))
    with pytest.raises(ValueError, match="workload"):
        _evaluator("homog32", objective=obj)
    with pytest.raises(ValueError, match="arch has 40"):
        _evaluator("homog32", objective=obj,
                   workload=_trace_workload("homog64"))
    # host recomputation without trace metrics in the sample fails fast
    with pytest.raises(KeyError, match="trace_lat"):
        objective_cost_host({"area": np.ones(1)},
                            Objective(terms=("trace-lat",)),
                            _evaluator("homog32")[0].norm)


def test_experiment_config_carries_workload():
    wl = _trace_workload("homog32")
    obj = Objective().with_terms(TermSpec("trace-lat"))
    cfg = ExperimentConfig(arch="homog32", objective=obj, workload=wl)
    back = ExperimentConfig.from_json(cfg.to_json())
    assert back == cfg and back.workload == wl
    assert hash(back) == hash(cfg)
    # configs without a workload key still load (stacked-PR compat)
    d = cfg.to_dict()
    del d["workload"]
    assert ExperimentConfig.from_dict(d).workload is None


def test_workload_swap_reuses_compiled_scorer_and_stacks():
    """Workloads are runtime operands: sweeping traffic patterns neither
    recompiles nor unstacks — the acceptance gate for the netsim layer."""
    clear_scorer_cache()
    obj = Objective().with_terms(TermSpec("trace-lat", weight=0.5))
    base = dict(arch="homog32", algorithms=("br",), budget=Budget(evals=4),
                norm_samples=4, chunk=4, objective=obj)
    cfgs = [ExperimentConfig(**base, workload=_trace_workload(
        "homog32", traffic=t)) for t in ("c2m", "c2c")]
    res = run_sweep(cfgs)
    assert res.stats.scorers_built == 1          # compiled once...
    assert res.stats.stacked_groups == 1         # ...and dispatched stacked
    # per-row demand keeps each run exact: solo reruns agree bit-for-bit
    for cfg, run in zip(cfgs, res.runs):
        (solo,) = run_experiment(cfg)
        assert run.records[0].result.best_cost == solo.result.best_cost
    # a second sweep over new traffic patterns causes zero retraces
    more = [ExperimentConfig(**base, workload=_trace_workload(
        "homog32", rate=r)) for r in (0.003, 0.03)]
    res2 = run_sweep(more)
    assert res2.stats.scorers_built == 0


def test_drive_stacked_rejects_mismatched_request_keys():
    from repro.core.optimize import drive_stacked
    ev, rep = _evaluator("homog32")
    rng = np.random.default_rng(0)
    _, graphs = ev.generate_valid(ev.rep.random, rng, 2)

    def gen_graphs():
        yield graphs
        return None

    def gen_bogus():
        from repro.core.topology import stack_graphs
        b = stack_graphs(graphs)
        b["extra_key"] = np.zeros(2)
        yield b
        return None

    with pytest.raises(ValueError, match="disagree on batch keys"):
        drive_stacked([(gen_graphs(), ev), (gen_bogus(), ev)])
