"""Traffic workloads as fixed-shape demand tensors.

A :class:`Workload` compiles either a dependency trace (``core.traces``)
or §VII-B synthetic traffic into per-traffic-class chiplet-pair packet
rates plus per-class mean packet sizes:

* ``rate [K, n, n]`` — packets/cycle injected from chiplet ``s`` to
  chiplet ``d``, per traffic class (``K = len(TRAFFIC_TYPES)``),
* ``flits [K]``     — mean flits per packet of that class.

The shape depends only on the chiplet count ``n``, never on the trace
content, so a workload is a *runtime operand* of the jitted scorer (like
the norm/weight vectors): swapping traces or scaling injection rates
re-dispatches the same compiled computation with a different ``[DEM]``
vector (``DEM = demand_dim(n)``) and causes zero retraces.

Workloads are value-hashable (content digest) and JSON-serde-able, so
they participate in evaluator/scorer cache keys (``ExperimentConfig``)
and cross-config stacking.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field

import numpy as np

from repro.core.chiplets import COMPUTE, IO, MEMORY, TRAFFIC_TYPES

K = len(TRAFFIC_TYPES)

# (src kind, dst kind) -> traffic-class index.  Classes fold direction:
# a memory->compute reply accounts under "c2m" just like the request.
_CLASS_OF = {
    (COMPUTE, COMPUTE): 0,
    (COMPUTE, MEMORY): 1, (MEMORY, COMPUTE): 1,
    (COMPUTE, IO): 2, (IO, COMPUTE): 2,
    (MEMORY, IO): 3, (IO, MEMORY): 3,
}

_KIND_OF = {"c": COMPUTE, "m": MEMORY, "i": IO}


def demand_dim(n: int) -> int:
    """Length of the packed demand vector for an ``n``-chiplet arch."""
    return K * n * n + K


@dataclass(frozen=True, eq=False)
class Workload:
    """Per-class chiplet-pair packet rates + mean packet sizes.

    Equality and hashing are by content digest, so structurally equal
    workloads (e.g. deserialized copies) share evaluator cache entries.
    """

    n: int                       # chiplets
    rate: np.ndarray             # [K, n, n] float32 packets/cycle
    flits: np.ndarray            # [K] float32 mean flits/packet
    name: str = ""
    _digest: str = field(init=False, repr=False, default="")

    def __post_init__(self):
        rate = np.ascontiguousarray(np.asarray(self.rate, np.float32))
        flits = np.ascontiguousarray(np.asarray(self.flits, np.float32))
        if rate.shape != (K, self.n, self.n):
            raise ValueError(
                f"rate must be [K={K}, n={self.n}, n={self.n}], "
                f"got {rate.shape}")
        if flits.shape != (K,):
            raise ValueError(f"flits must be [{K}], got {flits.shape}")
        rate.setflags(write=False)
        flits.setflags(write=False)
        object.__setattr__(self, "rate", rate)
        object.__setattr__(self, "flits", flits)
        h = hashlib.sha256()
        h.update(np.int64(self.n).tobytes())
        h.update(rate.tobytes())
        h.update(flits.tobytes())
        object.__setattr__(self, "_digest", h.hexdigest()[:16])

    # -- identity ----------------------------------------------------------

    def digest(self) -> str:
        return self._digest

    def __hash__(self):
        return hash((self.n, self._digest))

    def __eq__(self, other):
        return (isinstance(other, Workload) and self.n == other.n
                and self._digest == other._digest)

    def __repr__(self):
        tot = float(self.rate.sum())
        return (f"Workload(n={self.n}, name={self.name!r}, "
                f"total_rate={tot:.4g}, digest={self._digest})")

    # -- device operand ----------------------------------------------------

    def vec(self) -> np.ndarray:
        """Packed ``[demand_dim(n)]`` float32 runtime operand: raveled
        per-class rates followed by the per-class flit sizes."""
        return np.concatenate(
            [self.rate.ravel(), self.flits]).astype(np.float32)

    # -- constructors ------------------------------------------------------

    @staticmethod
    def from_trace(packets, kinds, n_cycles: int,
                   name: str = "trace") -> "Workload":
        """Compile a packet trace (``core.traces.generate_trace`` output,
        or any iterable with ``src``/``dst``/``flits`` fields) into mean
        injection rates over ``n_cycles`` cycles.

        ``kinds`` is the per-chiplet kind array (e.g. ``net.kinds``).
        Packets whose (src kind, dst kind) pair maps to no paper traffic
        class (e.g. memory-to-memory) are ignored.
        """
        kinds = np.asarray(kinds)
        n = int(kinds.shape[0])
        if n_cycles <= 0:
            raise ValueError(f"n_cycles must be positive, got {n_cycles}")
        rate = np.zeros((K, n, n), np.float64)
        fl_sum = np.zeros(K, np.float64)
        fl_cnt = np.zeros(K, np.float64)
        for p in packets:
            k = _CLASS_OF.get((int(kinds[p.src]), int(kinds[p.dst])))
            if k is None or p.src == p.dst:
                continue
            rate[k, p.src, p.dst] += 1.0
            fl_sum[k] += p.flits
            fl_cnt[k] += 1.0
        rate /= float(n_cycles)
        flits = np.where(fl_cnt > 0, fl_sum / np.maximum(fl_cnt, 1.0), 1.0)
        return Workload(n=n, rate=rate, flits=flits, name=name)

    @staticmethod
    def synthetic(kinds, traffic: str, rate: float,
                  data_flits: int = 9, name: str = "") -> "Workload":
        """§VII-B synthetic load: every source chiplet of the class's src
        kind injects ``rate`` packets/cycle, spread uniformly over the
        destination kind (matching ``sim.synthetic_packets`` semantics).
        """
        if traffic not in TRAFFIC_TYPES:
            raise ValueError(
                f"unknown traffic type {traffic!r}; one of {TRAFFIC_TYPES}")
        kinds = np.asarray(kinds)
        n = int(kinds.shape[0])
        k = TRAFFIC_TYPES.index(traffic)
        ks, kd = _KIND_OF[traffic[0]], _KIND_OF[traffic[2]]
        srcs = np.nonzero(kinds == ks)[0]
        dsts = np.nonzero(kinds == kd)[0]
        dem = np.zeros((K, n, n), np.float64)
        for s in srcs:
            tgt = dsts[dsts != s]
            if tgt.size:
                dem[k, s, tgt] = rate / tgt.size
        flits = np.full(K, 1.0)
        flits[k] = float(data_flits)
        return Workload(n=n, rate=dem, flits=flits,
                        name=name or f"synthetic-{traffic}")

    def scaled(self, factor: float) -> "Workload":
        """Same spatial pattern at ``factor``x the injection rate."""
        return Workload(n=self.n, rate=self.rate * float(factor),
                        flits=self.flits, name=self.name)

    # -- serde -------------------------------------------------------------

    def to_dict(self) -> dict:
        return {
            "n": self.n,
            "rate": np.asarray(self.rate, np.float64).tolist(),
            "flits": np.asarray(self.flits, np.float64).tolist(),
            "name": self.name,
        }

    @staticmethod
    def from_dict(d: dict) -> "Workload":
        extra = set(d) - {"n", "rate", "flits", "name"}
        if extra:
            raise ValueError(f"unknown Workload keys: {sorted(extra)}")
        return Workload(n=int(d["n"]), rate=np.asarray(d["rate"]),
                        flits=np.asarray(d["flits"]),
                        name=str(d.get("name", "")))
