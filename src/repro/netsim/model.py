"""Device-resident traffic rate model over stacked ScoreGraphs.

This is the searchable counterpart of the event-driven oracle in
``repro.netsim.sim``: a batched, jitted queueing approximation whose
per-placement outputs (``trace_lat_{t}`` / ``trace_thr_{t}`` per traffic
class) the ``trace-lat`` / ``trace-thr`` objective terms turn into cost
summands, so placements are optimized *directly against traffic* instead
of the uniform-pair proxies.

Per placement, given the Floyd-Warshall distances ``D`` and shortest-path
counts ``Ncnt`` the proxy scorer already computes:

1. distribute each chiplet pair's packet rate over all equal-cost
   shortest paths with ECMP/Brandes fractions (the same
   on-shortest-path test as the throughput proxy),
2. accumulate per-link *flit* loads ``rho`` [flits/cycle],
3. charge a saturating M/M/1-style queueing delay
   ``q = min(rho / (1 - rho), Q_CAP)`` per traversed link (clipped, so
   past-saturation placements rank by how overloaded they are instead of
   producing inf/nan),
4. per-pair latency = path latency ``D[s, d]`` + router pipeline per hop
   + queueing along the path + serialization (``flits - 1``), reduced to
   a demand-weighted mean per traffic class.

Demand enters as a packed runtime vector (``workload.Workload.vec()``),
never as a trace-time constant — swapping workloads re-dispatches the
same compiled scorer.  Calibration against the event-driven simulator is
on *relative orderings* across placements (rank correlation, see
``tests/test_netsim.py``), not absolute cycle counts.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.chiplets import TRAFFIC_TYPES
from repro.core.proxies import INF_CUT, Layout, fw_counts_ref

from .sim import ROUTER_PIPELINE
from .workload import K, demand_dim

# Queueing-delay divergence cap [cycles]: rho/(1-rho) saturates here, so
# an overloaded link costs a large-but-finite, still-monotone penalty.
Q_CAP = 1.0e4

TRACE_METRIC_KEYS = (
    tuple(f"trace_lat_{t}" for t in TRAFFIC_TYPES)
    + tuple(f"trace_thr_{t}" for t in TRAFFIC_TYPES)
    + ("trace_max_load",))


def unpack_demand(dem_vec, n: int):
    """Split a packed ``[demand_dim(n)]`` vector into
    (``rate [K, n, n]``, ``flits [K]``)."""
    rate = jnp.reshape(dem_vec[:K * n * n], (K, n, n))
    flits = dem_vec[K * n * n:]
    return rate, flits


def trace_metrics_one(D, Ncnt, W, edges, edge_mask, dem_vec, *, srcs, dsts,
                      router_pipeline: float = ROUTER_PIPELINE):
    """Traffic metrics for one placement (jit/vmap-able).

    ``srcs``/``dsts`` are the static virtual source/sink node indices of
    the arch's chiplets (``layout.Vp + i`` / ``layout.Vp + N + i``), so
    chiplet-level demand maps onto the PHY-level FW matrices.  Returns
    ``trace_lat_{t}`` per traffic class (0 where the class has no
    demand), ``trace_thr_{t}`` — the class's maximum sustainable
    aggregate injection rate [flits/cycle]: its demand scaled by the
    largest factor alpha that keeps every link load under capacity given
    the *other* classes' fixed loads (``alpha = min_e headroom_e /
    rho_k_e``, capped at ``Q_CAP``) — plus ``trace_max_load``
    (bottleneck link flit load).
    """
    srcs = jnp.asarray(srcs)
    dsts = jnp.asarray(dsts)
    n = srcs.shape[0]
    rate, flits = unpack_demand(dem_vec, n)
    eu, ev = edges[:, 0], edges[:, 1]
    w_e = W[eu, ev]
    Dsd = D[srcs][:, dsts]                                   # [n, n]
    Dsu = D[srcs][:, eu]                                     # [n, E]
    Dvd = D[ev][:, dsts]                                     # [E, n]
    Nsu = Ncnt[srcs][:, eu]
    Nvd = Ncnt[ev][:, dsts]
    Nsd = jnp.maximum(Ncnt[srcs][:, dsts], 1.0)
    # ECMP: edge (u, v) lies on a shortest s->d path iff
    # D[s,u] + w(u,v) + D[v,d] == D[s,d]; the Brandes fraction
    # N[s,u]*N[v,d]/N[s,d] is the share of s->d traffic crossing it.
    # Padded edge rows ((0, 0), weight 0) would pass the on-path test
    # spuriously, so the mask applies *inside* the selection.
    on_sp = (
        jnp.abs(Dsu[:, :, None] + w_e[None, :, None] + Dvd[None, :, :]
                - Dsd[:, None, :]) < 0.5
    ) & (Dsd[:, None, :] < INF_CUT) & edge_mask[None, :, None]
    use = jnp.where(
        on_sp, Nsu[:, :, None] * Nvd[None, :, :] / Nsd[:, None, :],
        0.0)                                                 # [n, E, n]
    # Per-link flit load, summed over classes, and its queueing delay.
    # rho/(1-rho) counts waits in units of the link's mean *service* time
    # (wormhole holds a link `flits` cycles per packet), so it is scaled
    # by the link's flits-per-packet to land in cycles.
    fload = (rate * flits[:, None, None]).sum(axis=0)        # [n, n] flits
    pload = rate.sum(axis=0)                                 # [n, n] packets
    rho = jnp.einsum("st,set->e", fload, use)
    pkt = jnp.einsum("st,set->e", pload, use)
    serv = rho / jnp.maximum(pkt, 1e-12)                     # cycles/packet
    q = jnp.minimum(
        serv * rho / jnp.maximum(1.0 - rho, 1.0 / Q_CAP), Q_CAP)
    queue = jnp.einsum("set,e->st", use, q)                  # [n, n]
    hops = use.sum(axis=1)                                   # expected D2D hops
    reach = Dsd < INF_CUT
    base = jnp.where(reach, Dsd + router_pipeline * hops + queue, 0.0)
    # Per-class link loads and the saturation throughput: scale class k's
    # demand by alpha until its most loaded link exhausts the headroom the
    # other classes leave (1 - sum_{j!=k} rho_j); unreachable pairs carry
    # no `use` so they never load a link.  Classes using no link (or with
    # no demand) get alpha = Q_CAP / thr = 0 respectively.
    fk = rate * flits[:, None, None]                         # [K, n, n]
    rho_k = jnp.einsum("kst,set->ke", fk, use)               # [K, E]
    other = jnp.maximum(rho[None, :] - rho_k, 0.0)
    ratio = jnp.where(
        edge_mask[None, :] & (rho_k > 1e-12),
        jnp.maximum(1.0 - other, 1.0 / Q_CAP) / jnp.maximum(rho_k, 1e-12),
        jnp.inf)
    alpha = jnp.minimum(jnp.min(ratio, axis=1), Q_CAP)       # [K]
    out = {"trace_max_load": jnp.where(edge_mask, rho, 0.0).max()}
    for k, t in enumerate(TRAFFIC_TYPES):
        r = jnp.where(reach, rate[k], 0.0)
        tot = r.sum()
        lat = (r * base).sum() / jnp.maximum(tot, 1e-12) + (flits[k] - 1.0)
        out[f"trace_lat_{t}"] = jnp.where(tot > 0, lat, 0.0)
        out[f"trace_thr_{t}"] = jnp.where(
            tot > 0, alpha[k] * tot * flits[k], 0.0)
    return out


def make_trace_model(layout: Layout, *, fw_impl=fw_counts_ref,
                     router_pipeline: float = ROUTER_PIPELINE):
    """Standalone jitted batched rate model: ``model(batch, demand)`` maps
    a stacked ScoreGraph batch (``W [P,V,V]``, ``edges``, ``edge_mask``)
    plus a packed demand operand (``[DEM]`` shared, or ``[P, DEM]``
    per-row) to a dict of ``[P]`` arrays (``TRACE_METRIC_KEYS``).

    Inside the search pipeline the same computation runs fused into
    ``make_scorer``; this entry point serves calibration tests and
    benchmarks that want traffic metrics without an objective.
    """
    srcs = layout.Vp + np.arange(layout.N, dtype=np.int32)
    dsts = layout.Vp + layout.N + np.arange(layout.N, dtype=np.int32)
    dim = demand_dim(layout.N)

    @jax.jit
    def model(batch, demand):
        P = batch["W"].shape[0]
        dem = jnp.broadcast_to(
            jnp.asarray(demand, jnp.float32), (P, dim))

        def one(w, e, m, d):
            D, Ncnt = fw_impl(w)
            return trace_metrics_one(D, Ncnt, w, e, m, d, srcs=srcs,
                                     dsts=dsts,
                                     router_pipeline=router_pipeline)

        return jax.vmap(one)(batch["W"], batch["edges"],
                             batch["edge_mask"], dem)

    return model
