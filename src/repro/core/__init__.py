# The paper's primary contribution — implement the SYSTEM here
# (scheduler, optimizer, data path, serving loop, etc.) in the
# host framework. Add sibling subpackages for substrates.
#
# Public entry point: the registry-driven experiment API.
from .api import (Budget, ExperimentConfig, RunRecord, SweepResult,  # noqa: F401
                  baseline_cost, best_by_algorithm, run_experiment,
                  run_sweep, summarize)
from .objective import (Objective, TermSpec, TrafficMix,  # noqa: F401
                        compile_objective, objective_cost_host)
from .registries import (OBJECTIVE_TERMS, OPTIMIZERS,  # noqa: F401
                         SCORER_BACKENDS, register_objective_term,
                         register_optimizer, register_scorer_backend)
