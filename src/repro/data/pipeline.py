"""Deterministic, shardable, resumable synthetic token pipeline.

Production posture without shipping a corpus: the stream is a seeded
counter-mode PRNG over (shard, step) — any (host, step) batch is
reconstructible from the cursor alone, which is what makes checkpoint
restart and elastic rescaling exact:

* determinism     — batch(step) is a pure function of (seed, step, shard).
* sharding        — ``n_shards``/``shard_id`` carve the global batch; the
                    union over shards equals the single-host stream.
* resumability    — the cursor is just the step index (saved in checkpoint
                    extras); no file offsets to replay.

Documents are Zipf-distributed token runs with BOS/EOS framing so the loss
has real structure (prefix prediction is learnable).  Labels are inputs
shifted left; the final position is masked (-1).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_shards: int = 1
    shard_id: int = 0
    bos: int = 1
    eos: int = 2
    zipf_a: float = 1.3
    doc_len_mean: int = 64

    @property
    def local_batch(self) -> int:
        assert self.global_batch % self.n_shards == 0
        return self.global_batch // self.n_shards


class TokenStream:
    """Stateless batch generator with an explicit integer cursor."""

    def __init__(self, cfg: DataConfig, step: int = 0):
        self.cfg = cfg
        self.step = step

    def batch_at(self, step: int) -> dict:
        cfg = self.cfg
        rows = []
        for r in range(cfg.local_batch):
            # Global row id → identical stream for any sharding layout.
            grow = cfg.shard_id * cfg.local_batch + r
            rng = np.random.default_rng(
                (cfg.seed, step, grow))
            toks = self._row(rng)
            rows.append(toks)
        tokens = np.stack(rows).astype(np.int32)
        labels = np.concatenate(
            [tokens[:, 1:], np.full((tokens.shape[0], 1), -1, np.int32)],
            axis=1)
        return {"tokens": tokens, "labels": labels}

    def _row(self, rng: np.random.Generator) -> np.ndarray:
        cfg = self.cfg
        out = np.empty(cfg.seq_len, np.int64)
        i = 0
        while i < cfg.seq_len:
            n = int(rng.geometric(1.0 / cfg.doc_len_mean))
            n = min(max(4, n), cfg.seq_len - i)
            doc = rng.zipf(cfg.zipf_a, size=n) % (cfg.vocab - 3) + 3
            # Learnable structure: second half of a doc repeats the first.
            half = n // 2
            doc[half:half * 2] = doc[:half]
            doc[0] = cfg.bos
            if i + n < cfg.seq_len:
                doc[-1] = cfg.eos
            out[i:i + n] = doc
            i += n
        return out

    def __iter__(self):
        return self

    def __next__(self) -> dict:
        b = self.batch_at(self.step)
        self.step += 1
        return b

    # -- cursor (checkpoint extras) -----------------------------------------
    def cursor(self) -> dict:
        return {"step": self.step}

    @classmethod
    def from_cursor(cls, cfg: DataConfig, cursor: dict) -> "TokenStream":
        return cls(cfg, step=int(cursor.get("step", 0)))
