"""Per-architecture smoke tests (REDUCED configs, deliverable f) + the
prefill/decode = full-forward consistency property.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, eligible_shapes, get_config, input_specs
from repro.models.model import build_model

B, S = 2, 24
KEY = jax.random.PRNGKey(0)


def make_batch(cfg, with_labels=True):
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.array(
        rng.integers(3, cfg.vocab, size=(B, S)), jnp.int32)}
    if with_labels:
        batch["labels"] = jnp.array(
            rng.integers(0, cfg.vocab, size=(B, S)), jnp.int32)
    if cfg.frontend == "patch":
        batch["patch_embeds"] = jnp.array(
            rng.standard_normal((B, cfg.n_frontend_tokens, cfg.d_model)),
            cfg.dtype)
    if cfg.family == "encdec":
        batch["src_embeds"] = jnp.array(
            rng.standard_normal((B, S, cfg.d_model)), cfg.dtype)
    return batch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_arch_smoke_train_step(arch):
    """One forward/train step on CPU: output shapes + no NaNs."""
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(KEY)
    batch = make_batch(cfg)
    loss, metrics = jax.jit(model.loss_fn)(params, batch)
    assert np.isfinite(float(loss))
    grads = jax.grad(lambda p: model.loss_fn(p, batch)[0])(params)
    gn = sum(float(jnp.sum(jnp.square(g.astype(jnp.float32))))
             for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_arch_prefill_decode_consistency(arch):
    """Greedy next-token from (prefill S, decode 1) == full forward S+1.

    MoE runs dropless here (large capacity factor): capacity dropping is
    position-dependent, so a dropped last token would (correctly) differ
    between the S-token and 1-token dispatch.
    """
    cfg = get_config(arch).reduced()
    if cfg.n_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=64.0)
    model = build_model(cfg)
    params = model.init(KEY)
    rng = np.random.default_rng(1)
    toks = jnp.array(rng.integers(3, cfg.vocab, size=(B, S + 1)), jnp.int32)
    batch_full = {"tokens": toks,
                  "labels": jnp.zeros((B, S + 1), jnp.int32)}
    pre = {"tokens": toks[:, :S]}
    if cfg.frontend == "patch":
        pe = jnp.array(rng.standard_normal(
            (B, cfg.n_frontend_tokens, cfg.d_model)), cfg.dtype)
        batch_full["patch_embeds"] = pe
        pre["patch_embeds"] = pe
    if cfg.family == "encdec":
        se = jnp.array(rng.standard_normal((B, S + 1, cfg.d_model)),
                       cfg.dtype)
        batch_full["src_embeds"] = se
        pre["src_embeds"] = se

    n_front = cfg.n_frontend_tokens if cfg.frontend == "patch" else 0
    cache_len = S + 8 + n_front          # frontend prefix occupies slots too
    logits_pre, caches = jax.jit(
        lambda p, b: model.prefill(p, b, cache_len))(params, pre)
    db = {"tokens": toks[:, S:S + 1],
          "lengths": jnp.full((B,), S + n_front, jnp.int32)}
    if cfg.family == "encdec":
        db["mem_len"] = jnp.full((B,), S + 1, jnp.int32)
    logits_dec, _ = jax.jit(model.decode_step)(params, db, caches)

    # full forward logits at the last position
    from repro.models.model import loss_fn as _  # noqa
    import repro.models.model as M
    x, pos, nf = M._prep_inputs(cfg, params, batch_full)
    extra = {}
    if cfg.family == "encdec":
        extra["memory"] = M._encode(cfg, params, batch_full["src_embeds"])
    from repro.models.transformer import stack_train
    h, _aux = stack_train(params["groups"], x, cfg, pos, extra=extra,
                          plan=M._dec_plan(cfg))
    from repro.models.layers import rms_norm
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits_full = M._logits(cfg, params, h)[:, -1]

    a = np.array(logits_dec, np.float32)
    b = np.array(logits_full, np.float32)
    # argmax agreement + numeric closeness
    np.testing.assert_allclose(a, b, rtol=5e-2, atol=5e-2)
    assert (a.argmax(-1) == b.argmax(-1)).mean() == 1.0


def test_hybrid_layer_plan_matches_paper_pattern():
    cfg = get_config("recurrentgemma-9b")
    plan = cfg.layer_plan()
    # 38 = 12 x (r,r,a) + 2 tail recurrent blocks
    assert plan[0] == ("super", 12)
    assert plan[1:] == [("rec", 1), ("rec", 1)]


def test_eligible_shapes():
    assert "long_500k" in eligible_shapes("falcon-mamba-7b")
    assert "long_500k" in eligible_shapes("recurrentgemma-9b")
    assert "long_500k" not in eligible_shapes("qwen3-1.7b")
    total = sum(len(eligible_shapes(a)) for a in ARCHS)
    assert total == 32          # 10*3 + 2


def test_input_specs_shapes():
    s = input_specs("grok-1-314b", "train_4k")
    assert s["tokens"].shape == (256, 4096)
    s = input_specs("llava-next-34b", "prefill_32k")
    assert s["patch_embeds"].shape == (32, 576, 7168)
    s = input_specs("seamless-m4t-medium", "decode_32k")
    assert s["tokens"].shape == (128, 1) and "mem_len" in s


def test_scan_vs_unrolled_equivalence():
    cfg = get_config("tinyllama-1.1b").reduced(n_layers=3)
    cfg_u = dataclasses.replace(cfg, scan_layers=False)
    m_s, m_u = build_model(cfg), build_model(cfg_u)
    params = m_s.init(KEY)
    batch = make_batch(cfg)
    ls, _ = jax.jit(m_s.loss_fn)(params, batch)
    lu, _ = jax.jit(m_u.loss_fn)(params, batch)
    assert abs(float(ls) - float(lu)) < 1e-4
