#!/usr/bin/env sh
# Tier-1 CI: fast test pass (slow-marked tests excluded).
#   scripts/ci.sh [extra pytest args...]
set -eu
cd "$(dirname "$0")/.."
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    exec python -m pytest -q -m "not slow" "$@"
