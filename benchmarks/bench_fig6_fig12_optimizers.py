"""Paper Figs. 6 & 12: BR / GA / SA optimization results vs the 2D-mesh
baseline, homogeneous (§V-B) and heterogeneous (§VI-B) architectures.

Budgets are evaluation-count based (CPU-friendly stand-in for the paper's
3600 s wall budget); the claims validated are the paper's *orderings*:
every algorithm beats the baseline; GA/SA beat BR.

Runs through the registry-driven API: one declarative ``ExperimentConfig``
per architecture, executed with ``run_experiment``.
"""
from __future__ import annotations

import json
import os

from repro.core.api import (Budget, ExperimentConfig, baseline_cost,
                            best_by_algorithm, run_experiment, summarize)

from .common import budget, emit, out_dir


def run(quick: bool = True, archs=("homog32", "hetero32")) -> dict:
    evals = budget(quick, 420, 3000)
    reps = budget(quick, 2, 10)
    results = {}
    for arch_name in archs:
        cfg = ExperimentConfig(
            arch=arch_name, config="baseline",
            algorithms=("br", "ga", "sa"), repetitions=reps,
            budget=Budget(evals=evals),
            norm_samples=budget(quick, 32, 500),
            params={"sa": {"chains": budget(quick, 8, 1)}})
        recs = run_experiment(cfg)
        base_cost, base_metrics = baseline_cost(cfg)
        best = best_by_algorithm(recs)
        fig = "fig6" if arch_name.startswith("homog") else "fig12"
        res = {"baseline_cost": base_cost}
        for algo, r in best.items():
            res[algo] = r.result.best_cost
            emit(f"{fig}_{arch_name}_{algo}_best_cost",
                 round(r.result.best_cost, 4),
                 f"baseline={base_cost:.4f}")
        # the paper's qualitative claims
        emit(f"{fig}_{arch_name}_all_beat_baseline",
             all(res[a] < base_cost for a in ("br", "ga", "sa")))
        emit(f"{fig}_{arch_name}_ga_beats_br", res["ga"] <= res["br"])
        emit(f"{fig}_{arch_name}_sa_beats_br", res["sa"] <= res["br"])
        res["rows"] = summarize(recs)
        results[arch_name] = res
    with open(os.path.join(out_dir(), "fig6_fig12.json"), "w") as f:
        json.dump(results, f, indent=1, default=float)
    return results


def main(quick: bool = True):
    run(quick)


if __name__ == "__main__":
    main(quick=os.environ.get("BENCH_FULL", "") != "1")
