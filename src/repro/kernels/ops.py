"""Public kernel API: jit'd wrappers that pick Pallas-on-TPU / interpret-on-
CPU / pure-jnp reference, uniformly switchable via ``impl``.

impl semantics:
  'auto'   — Pallas compiled on TPU; pure-jnp reference elsewhere (interpret
             mode is a correctness tool, far too slow for production CPU use).
  'pallas' — force the Pallas kernel (interpret=True off-TPU). Tests use this.
  'ref'    — force the pure-jnp oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .decode_attention import decode_attention_pallas
from .flash_attention import flash_attention_pallas
from .minplus import (apsp_tiled_pallas, fw_counts_pallas,
                      fw_counts_tiled_pallas, minplus_tiled_pallas)
from .rglru_scan import rglru_scan_pallas
from .selective_scan import selective_scan_pallas


def on_tpu() -> bool:
    return jax.devices()[0].platform == "tpu"


def _resolve(impl: str) -> str:
    if impl == "auto":
        return "pallas" if on_tpu() else "ref"
    return impl


def _interp() -> bool:
    return not on_tpu()


# -- min-plus / APSP ---------------------------------------------------------

def fw_counts(W: jnp.ndarray, impl: str = "auto"
              ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Floyd-Warshall distances + path counts; [.., V, V] -> (D, N)."""
    if _resolve(impl) == "pallas":
        return fw_counts_pallas(W, interpret=_interp())
    return ref.fw_counts_ref(W)


def minplus(A: jnp.ndarray, B: jnp.ndarray, impl: str = "auto",
            **tiles) -> jnp.ndarray:
    if _resolve(impl) == "pallas":
        return minplus_tiled_pallas(A, B, interpret=_interp(), **tiles)
    return ref.minplus_ref(A, B)


def apsp(W: jnp.ndarray, impl: str = "auto", **tiles) -> jnp.ndarray:
    if _resolve(impl) == "pallas":
        return apsp_tiled_pallas(W, interpret=_interp(), **tiles)
    return ref.apsp_ref(W)


# -- attention ----------------------------------------------------------------

def flash_attention(q, k, v, *, causal=True, window=None, scale=None,
                    softcap=None, pos_offset=None, impl: str = "auto",
                    **blocks):
    if _resolve(impl) == "pallas" and pos_offset is None:
        return flash_attention_pallas(
            q, k, v, causal=causal, window=window, scale=scale,
            softcap=softcap, interpret=_interp(), **blocks)
    return ref.attention_ref(q, k, v, causal=causal, window=window,
                             scale=scale, softcap=softcap,
                             pos_offset=pos_offset)


def decode_attention(q, k_cache, v_cache, lengths, *, scale=None, window=None,
                     softcap=None, impl: str = "auto", **blocks):
    if _resolve(impl) == "pallas":
        return decode_attention_pallas(
            q, k_cache, v_cache, lengths, scale=scale, window=window,
            softcap=softcap, interpret=_interp(), **blocks)
    return ref.decode_attention_ref(q, k_cache, v_cache, lengths, scale=scale,
                                    window=window, softcap=softcap)


# -- recurrences ---------------------------------------------------------------

def selective_scan(x, dt, A, B, C, D, h0=None, impl: str = "auto", **kw):
    if _resolve(impl) == "pallas":
        return selective_scan_pallas(x, dt, A, B, C, D, h0,
                                     interpret=_interp(), **kw)
    return ref.selective_scan_ref(x, dt, A, B, C, D, h0)


def rglru_scan(x, a, h0=None, impl: str = "auto", **kw):
    if _resolve(impl) == "pallas":
        return rglru_scan_pallas(x, a, h0, interpret=_interp(), **kw)
    return ref.rglru_ref(x, a, h0)


# Scorer adapter: `repro.core.proxies.make_scorer(fw_impl=...)` expects a
# W -> (D, N) callable; this binds the Pallas FW kernel into the PlaceIT
# evaluation path (the paper's hot spot, DESIGN.md §3).
def fw_impl_pallas(W):
    return fw_counts_pallas(W, interpret=_interp())


fw_impl_ref = ref.fw_counts_ref

# Above this padded V the VMEM-resident FW's working set (~3 x Vp^2 x 4B
# for W, D, N) no longer fits a 16 MB TPU VMEM budget: 768 -> ~6.8 MB
# fits, the next 128-multiple (896 -> ~9.2 MB plus scratch) is already
# marginal and 1024 -> ~12.6 MB fails in practice.  The blocked-tile FW
# keeps O(bt^2) per grid program regardless of V.
FW_TILED_AUTO_V = 768


def fw_counts_tiled(W: jnp.ndarray, *, bt: int = 128
                    ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Blocked-tile FW + path counts (bit-for-bit == fw_counts_ref)."""
    return fw_counts_tiled_pallas(W, bt=bt, interpret=_interp())


def fw_impl_tiled(W):
    """Size-dispatched FW scorer impl: VMEM-resident kernel while the
    padded V fits (< FW_TILED_AUTO_V), blocked-tile kernel beyond.  Both
    are bit-for-bit equal to ``fw_counts_ref``, so the dispatch point is
    invisible in results."""
    V = W.shape[-1]
    if max(128, -(-V // 128) * 128) <= FW_TILED_AUTO_V:
        return fw_counts_pallas(W, interpret=_interp())
    return fw_counts_tiled_pallas(W, interpret=_interp())
