"""3D / hierarchical family sweep: stacked grids vs the flat mesh.

The same 32-chiplet set (24 compute, 4 memory, 4 IO) is arranged three
ways — the paper's flat 2D grid and two ``repro.arch3d`` families (a
2-layer TSV-stacked grid and its torus augmentation) — and each is
optimized with the batched GA under the same objective (base terms plus
trace latency on a synthetic C2M workload).  Vertical links pay a
``tsv_slowdown`` multiplier on the link latency; because the tier vector
is a runtime jit operand, the slowdown sweep at the end reuses every
compiled stage (watch ``scorers_built``).

  PYTHONPATH=src python examples/topo3d_sweep.py [--evals 96]
"""
import argparse
import dataclasses

import numpy as np

from repro.arch3d import default_tier_values, make_rep3d
from repro.core.api import (Budget, ExperimentConfig, make_evaluator,
                            run_sweep)
from repro.core.chiplets import resolve_arch
from repro.core.objective import Objective, TermSpec
from repro.netsim import Workload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--evals", type=int, default=96)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    obj = Objective().with_terms(TermSpec("trace-lat", weight=0.5))

    def cfg(arch_name):
        arch = resolve_arch(arch_name, "baseline")
        return ExperimentConfig(
            arch=arch_name, algorithms=("ga-batched",),
            budget=Budget(evals=args.evals), seed=args.seed,
            norm_samples=8, chunk=16, objective=obj,
            workload=Workload.synthetic(arch.kinds(), "c2m", 0.01))

    names = ["homog32", "stack3d32", "torus3d32"]
    res = run_sweep([cfg(n) for n in names])
    print(f"{'family':12s} {'grid':10s} {'best cost':>10s}")
    for name, run in zip(names, res.runs):
        rec = run.records[0]
        shape = "x".join(str(d) for d in np.asarray(
            rec.result.best_sol[0]).shape)
        print(f"{name:12s} {shape:10s} {rec.result.best_cost:10.3f}")
    print(f"(scorers built: {res.stats.scorers_built} — one per distinct "
          "graph layout)\n")

    # TSV-slowdown sweep on the stacked family: the tier vector is a
    # runtime operand, so no stage recompiles between sweep points.
    arch = resolve_arch("stack3d32", "baseline")
    base = make_rep3d(arch, "stack3d32")
    wl = Workload.synthetic(arch.kinds(), "c2m", 0.01)
    print("tsv_slowdown sweep (stack3d32, shared compiled stages):")
    print("  tiers default = "
          f"{[float(v) for v in default_tier_values(arch)]}")
    from repro.core.registries import OPTIMIZERS
    entry = OPTIMIZERS.get("ga-batched")
    for tsv in (1.0, 4.0, 16.0):
        rep = dataclasses.replace(base, tsv_slowdown=tsv)
        ev = make_evaluator(rep, arch, rng=np.random.default_rng(0),
                            norm_samples=8, chunk=16, objective=obj,
                            workload=wl)
        res = entry.fn(ev, np.random.default_rng(args.seed),
                       Budget(evals=args.evals), entry.params_cls())
        print(f"  tsv={tsv:5.1f}  tiers="
              f"{[float(v) for v in rep.tier_values]}  "
              f"best cost={res.best_cost:.3f}")


if __name__ == "__main__":
    main()
