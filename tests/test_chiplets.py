"""Chiplet geometry + rotation semantics (paper §VI-A, Fig. 8)."""
import math

import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core.chiplets import (COMPUTE, IO, MEMORY, Chiplet, LatencyParams,
                                 heterogeneous_chiplet, homogeneous_chiplet,
                                 paper_arch)


def test_rotation_reanchors():
    ch = Chiplet("t", COMPUTE, 2.0, 4.0, ((2.0, 1.0),), relay=True)
    r = ch.rotated(1)
    assert (r.w, r.h) == (4.0, 2.0)
    # (x,y) -> (h-y, x): (2,1) -> (3,2)
    assert r.phys == ((3.0, 2.0),)


@given(st.floats(1.0, 10.0), st.floats(1.0, 10.0),
       st.lists(st.tuples(st.floats(0, 1), st.floats(0, 1)), min_size=1,
                max_size=4))
@settings(max_examples=50, deadline=None)
def test_rotation_four_times_is_identity(w, h, rel):
    phys = tuple((round(x * w, 6), round(y * h, 6)) for x, y in rel)
    ch = Chiplet("t", MEMORY, w, h, phys, relay=False)
    r4 = ch.rotated(1).rotated(1).rotated(1).rotated(1)
    assert math.isclose(r4.w, ch.w, abs_tol=1e-6)
    for (a, b), (c, d) in zip(r4.phys, ch.phys):
        assert math.isclose(a, c, abs_tol=1e-6)
        assert math.isclose(b, d, abs_tol=1e-6)


def test_rotation_classes():
    # 4 PHYs mid-side, square: rotation-invariant -> only rotation 0
    ch = homogeneous_chiplet(COMPUTE, "baseline")
    assert ch.allowed_rotations() == (0,)
    # single PHY south, square: all 4 rotations distinct
    ch1 = homogeneous_chiplet(MEMORY, "baseline")
    assert len(ch1.allowed_rotations()) == 4
    # rectangle with centered PHYs on all sides: 180° symmetric (hybrid)
    ch2 = Chiplet("h", MEMORY, 2.0, 4.0,
                  ((1.0, 0.0), (1.0, 4.0), (0.0, 2.0), (2.0, 2.0)), True)
    assert len(ch2.allowed_rotations()) == 2


def test_paper_archs_counts():
    for name, n in [("homog32", 40), ("homog64", 80), ("hetero32", 40),
                    ("hetero64", 80)]:
        arch = paper_arch(name, "baseline")
        assert len(arch.chiplets) == n
        c, m, i = arch.counts()
        assert c in (32, 64) and m == i == c // 8


def test_latency_params():
    lp = LatencyParams()
    assert lp.d2d_cost() == 25.0      # 2*12 + 1 (Table III)


def test_baseline_vs_placeit_config():
    mb = homogeneous_chiplet(MEMORY, "baseline")
    mp = homogeneous_chiplet(MEMORY, "placeit")
    assert mb.n_phys() == 1 and not mb.relay
    assert mp.n_phys() == 4 and mp.relay
    hb = heterogeneous_chiplet(IO, "baseline")
    assert not hb.relay
