"""Shared placement-batch invariant checks.

Used by the property-based layer (``test_properties.py`` — randomized
seeds via the optional-hypothesis shim) and the deterministic pipeline
tests (``test_batched_pipeline.py``).  Expected chiplet counts are derived
from the representation's arch, so the helpers work for any architecture.
"""
from __future__ import annotations

import numpy as np

from repro.core.chiplets import COMPUTE, IO, MEMORY


def counts_of(types):
    return {k: int((np.asarray(types) == k).sum())
            for k in (COMPUTE, MEMORY, IO)}


def arch_counts(arch):
    kinds = np.asarray(arch.kinds())
    return {k: int((kinds == k).sum()) for k in (COMPUTE, MEMORY, IO)}


def assert_valid_homog_batch(rep, t, r):
    """Host-side invariants for a stacked [B, R, C] (types, rot) batch:
    per-kind cell counts, zero rotation on non-rotatable cells, and PHYs
    facing an occupied neighbor whenever one exists."""
    want = arch_counts(rep.arch)
    t, r = np.asarray(t), np.asarray(r)
    for b in range(t.shape[0]):
        assert counts_of(t[b]) == want
        assert (r[b][t[b] == COMPUTE] == 0).all()
        assert (r[b][t[b] < 0] == 0).all()
        for rr in range(rep.R):
            for cc in range(rep.C):
                k = t[b, rr, cc]
                if k >= 0 and rep._rotatable.get(int(k), False):
                    occ = rep._occupied_dirs(t[b], rr, cc)
                    if occ:    # PHY must face a chiplet when one exists
                        assert int(r[b, rr, cc]) in occ


def assert_valid_homog3d_batch(rep, t, r):
    """Host-side invariants for a stacked [B, R, C, Z] (types, rot) batch
    (``repro.arch3d.Homog3DRep``): per-kind cell counts, zero rotation on
    non-rotatable cells, and rotations drawn from the cell's record-backed
    candidate cascade (link-partner occupied -> any record -> all four)."""
    want = arch_counts(rep.arch)
    t, r = np.asarray(t), np.asarray(r)
    for b in range(t.shape[0]):
        assert counts_of(t[b]) == want
        assert (r[b][t[b] == COMPUTE] == 0).all()
        assert (r[b][t[b] < 0] == 0).all()
        tflat = t[b].reshape(-1)
        rflat = r[b].reshape(-1)
        for cell in range(tflat.shape[0]):
            k = tflat[cell]
            if k >= 0 and rep._rotatable.get(int(k), False):
                per_rot = rep._rot_other[cell]
                occ = [rr for rr in range(4)
                       if any(tflat[o] >= 0 for o in per_rot[rr])]
                anyr = [rr for rr in range(4) if per_rot[rr]]
                assert int(rflat[cell]) in (occ or anyr or [0, 1, 2, 3])


def assert_valid_hetero_batch(rep, o, r):
    """Host-side invariants for a stacked [B, N] (order, rots) batch:
    per-kind counts (type-sequence validity) and per-kind non-isomorphic
    rotation sets."""
    want = arch_counts(rep.arch)
    o, r = np.asarray(o), np.asarray(r)
    for b in range(o.shape[0]):
        assert counts_of(o[b]) == want
        for k, rr in zip(o[b], r[b]):
            assert int(rr) in rep._allowed_rot[int(k)]
