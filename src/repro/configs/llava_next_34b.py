"""Assigned architecture config: llava-next-34b (see registry for source).

Exposes CONFIG (exact published hyper-parameters) and SMOKE (reduced copy
for CPU smoke tests).  Select with ``--arch llava-next-34b``.
"""
from .registry import get_config

CONFIG = get_config("llava-next-34b")
SMOKE = CONFIG.reduced()
