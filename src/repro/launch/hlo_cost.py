"""While-aware HLO cost analysis (flops / bytes / collectives).

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE — with
scan-over-layers (and microbatch accumulation scans) that undercounts by the
trip count.  This module parses the post-optimization HLO text into its
computation graph, computes per-computation costs bottom-up, and multiplies
through while-loop trip counts (recovered from the loop-condition constant),
giving exact totals for scanned programs:

    flops        2 * prod(result dims) * prod(contracting dims) per dot
                 (convolutions likewise; elementwise flops are ignored —
                 <1% for transformer workloads, cross-checked against
                 XLA cost_analysis on unrolled modules in tests)
    bytes        operands-read + outputs-written per instruction, with
                 gather/slice reading only output-sized data (XLA's model)
    collectives  per-kind wire bytes per chip (ring estimates), trip-scaled

This is also the §Perf profiling tool: ``collective_schedule`` lists every
collective with its computation path, shape and wire bytes.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
                "f8e4m3": 1, "f8e5m2fnuz": 1, "s4": 1, "u4": 1}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*?)\s+([\w\-]+)\(")
_CALLED_RE = re.compile(
    r"(?:calls|to_apply|body|condition|branch_computations)="
    r"\{?%?([\w\.\-]+(?:,\s*%?[\w\.\-]+)*)\}?")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")


def xla_cost_analysis(compiled) -> dict:
    """``compiled.cost_analysis()`` normalized across jax versions.

    Depending on the jax version it returns a flat dict, a one-element list
    of dicts (one per executable), or None; callers always want the flat
    per-module dict.
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else None
    return dict(ca) if ca else {}


def shape_elems_bytes(type_str: str) -> tuple[int, int]:
    """Total (elements, bytes) over all array shapes in a type string."""
    elems = tot = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        tot += n * _DTYPE_BYTES[dt]
    return elems, tot


def shape_dims(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class Instr:
    name: str
    type_str: str
    op: str
    line: str
    called: list[str] = field(default_factory=list)


@dataclass
class Computation:
    name: str
    instrs: list[Instr] = field(default_factory=list)
    shapes: dict[str, str] = field(default_factory=dict)   # instr -> type


def parse_module(hlo: str) -> tuple[dict[str, Computation], str]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    entry = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        if not line or line.lstrip().startswith("//"):
            continue
        if not line.startswith(" ") and line.endswith("{") and "->" in line:
            m = _COMP_HDR_RE.match(line.strip())
            if m:
                cur = Computation(m.group(1))
                comps[cur.name] = cur
                if line.strip().startswith("ENTRY"):
                    entry = cur.name
                continue
        if line.strip() == "}":
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, type_str, op = m.groups()
        called = []
        mc = _CALLED_RE.findall(line)
        for grp in mc:
            for c in grp.split(","):
                called.append(c.strip().lstrip("%"))
        instr = Instr(name, type_str, op, line, called)
        cur.instrs.append(instr)
        cur.shapes[name] = type_str
    if entry is None and comps:
        entry = list(comps)[-1]
    return comps, entry


def _operand_names(line: str, op: str) -> list[str]:
    """Operand instruction names inside op(...) — %-prefixed identifiers."""
    lparen = line.find(op + "(")
    if lparen < 0:
        return []
    seg = line[lparen + len(op) + 1:]
    depth, out, cur_tok = 1, [], []
    for ch in seg:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
        cur_tok.append(ch)
    args = "".join(cur_tok)
    return re.findall(r"%([\w\.\-]+)", args)


def _dot_flops(comp: Computation, ins: Instr) -> float:
    out_elems, _ = shape_elems_bytes(ins.type_str)
    mc = _CONTRACT_RE.search(ins.line)
    ops = _operand_names(ins.line, ins.op)
    if not mc or not ops:
        return 2.0 * out_elems           # fallback
    lhs_type = comp.shapes.get(ops[0])
    if lhs_type is None:
        return 2.0 * out_elems
    dims = shape_dims(lhs_type)
    k = 1
    for d in mc.group(1).split(","):
        if d and int(d) < len(dims):
            k *= dims[int(d)]
    return 2.0 * out_elems * k


def _collective_wire(ins: Instr, n_chips: int,
                     pod_size: int = 256) -> tuple[str, float, bool]:
    """Returns (kind, wire_bytes_per_chip, crosses_pod).

    A collective crosses the pod boundary (DCI links, far slower than ICI)
    when its replica group mixes device ids from different pods."""
    kind = ins.op.replace("-start", "")
    _, R = shape_elems_bytes(ins.type_str)
    g = n_chips
    cross = n_chips > pod_size
    mg = _GROUPS_RE.search(ins.line)
    if mg:
        ids = [int(x) for x in mg.group(1).split(",") if x.strip()]
        g = len(ids)
        cross = len({i // pod_size for i in ids}) > 1
    else:
        mg2 = _GROUPS_V2_RE.search(ins.line)
        if mg2:
            g = int(mg2.group(2))
            cross = n_chips > pod_size and g > pod_size
    g = max(g, 1)
    if kind == "all-gather":
        wire = R * (g - 1) / g
    elif kind == "all-reduce":
        wire = 2 * R * (g - 1) / g
    elif kind == "reduce-scatter":
        wire = R * (g - 1)
    elif kind == "all-to-all":
        wire = R * (g - 1) / g
    else:  # collective-permute
        wire = R
    return kind, wire, cross


def _trip_count(comps: dict[str, Computation], cond_name: str) -> int:
    """Trip count from the loop condition's comparison constant."""
    cond = comps.get(cond_name)
    if cond is None:
        return 1
    consts = []
    for ins in cond.instrs:
        for m in _CONST_RE.finditer(ins.line):
            consts.append(int(m.group(1)))
    return max(consts) if consts else 1


_SKIP_BYTES_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
                   "bitcast", "copy", "after-all", "custom-call"}


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    convert_bytes: float = 0.0    # dtype-convert traffic: real on the CPU
    #                               backend (no native bf16 matmul), fused
    #                               away on TPU — reported separately so the
    #                               roofline can use TPU-native bytes.
    coll: dict = field(default_factory=dict)       # kind -> [count, wire]
    schedule: list = field(default_factory=list)   # (path, kind, wire, shape)

    def add(self, other: "Cost", scale: float, path: str,
            with_bytes: bool = True):
        self.flops += scale * other.flops
        if with_bytes:
            self.bytes += scale * other.bytes
            self.convert_bytes += scale * other.convert_bytes
        for k, (c, w) in other.coll.items():
            e = self.coll.setdefault(k, [0, 0.0])
            e[0] += int(scale * c)
            e[1] += scale * w
        for (p, k, w, sh) in other.schedule:
            self.schedule.append((f"{path}/{p}" if p else path, k,
                                  scale * w, sh))


def analyze_hlo(hlo: str, n_chips: int, *, while_trips: bool = True) -> dict:
    """Cost totals for one HLO module.

    ``while_trips=False`` counts every while body once — XLA
    ``cost_analysis`` semantics, useful to validate the per-instruction
    model against XLA on modules where the compiler introduced its own
    loops; the default multiplies through recovered trip counts (the whole
    point of this module).
    """
    comps, entry = parse_module(hlo)
    memo: dict[str, Cost] = {}

    def cost_of(name: str) -> Cost:
        if name in memo:
            return memo[name]
        comp = comps.get(name)
        c = Cost()
        memo[name] = c                       # break accidental cycles
        if comp is None:
            return c
        for ins in comp.instrs:
            if ins.op == "while":
                body = cond = None
                mb = re.search(r"body=%?([\w\.\-]+)", ins.line)
                mcnd = re.search(r"condition=%?([\w\.\-]+)", ins.line)
                if mb:
                    body = mb.group(1)
                if mcnd:
                    cond = mcnd.group(1)
                trips = _trip_count(comps, cond) \
                    if cond and while_trips else 1
                if body:
                    c.add(cost_of(body), trips, f"while[{trips}]:{body}")
                continue
            if ins.op in ("fusion", "call", "conditional", "map", "reduce",
                          "reduce-window", "sort", "scatter",
                          "select-and-scatter"):
                # flops/collectives of fused sub-computations count; their
                # internal traffic does NOT (fusion keeps it on-chip).
                for sub in ins.called:
                    c.add(cost_of(sub), 1.0, sub, with_bytes=False)
            if ins.op == "dot":
                c.flops += _dot_flops(comp, ins)
            elif ins.op == "convolution":
                out_elems, _ = shape_elems_bytes(ins.type_str)
                c.flops += 2.0 * out_elems  # lower bound (no window parse)
            base = ins.op.replace("-start", "")
            if base in COLLECTIVE_OPS and not ins.op.endswith("-done"):
                kind, wire, cross = _collective_wire(ins, n_chips)
                key = kind + ("/cross-pod" if cross else "")
                e = c.coll.setdefault(key, [0, 0.0])
                e[0] += 1
                e[1] += wire
                c.schedule.append(("", key, wire, ins.type_str[:48]))
            # bytes: operands read + output written
            if ins.op in _SKIP_BYTES_OPS:
                continue
            _, out_b = shape_elems_bytes(ins.type_str)
            if ins.op in ("gather", "dynamic-slice"):
                add_b = 2 * out_b          # output-sized read + write
            elif ins.op in ("dynamic-update-slice",):
                add_b = 3 * out_b
            else:
                opers = _operand_names(ins.line, ins.op)
                rb = 0
                for o in opers:
                    t = comp.shapes.get(o)
                    if t:
                        rb += shape_elems_bytes(t)[1]
                add_b = rb + out_b
            c.bytes += add_b
            if ins.op == "convert" or (ins.op == "fusion"
                                       and "convert" in ins.name):
                c.convert_bytes += add_b
        return c

    total = cost_of(entry)
    coll_total = sum(w for _, (cnt, w) in total.coll.items())
    cross_total = sum(w for k, (cnt, w) in total.coll.items()
                      if k.endswith("/cross-pod"))
    return {
        "flops": total.flops,
        "bytes": total.bytes,
        "convert_bytes": total.convert_bytes,
        "collectives": {k: {"count": cnt, "wire_bytes_per_chip": w}
                        for k, (cnt, w) in total.coll.items()},
        "wire_bytes_per_chip": coll_total,
        "cross_pod_bytes_per_chip": cross_total,
        "schedule": sorted(total.schedule, key=lambda t: -t[2])[:40],
    }
