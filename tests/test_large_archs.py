"""100+-chiplet arch families (PR 7): resolve_arch, hex masks, pipeline.

The HexaMesh-regime archs (homog100/homog144/homog256 on full square
grids, hex127 on the centered-hexagonal mask) must flow through the same
seams as the paper archs: ``resolve_arch`` -> ``make_rep`` (mask-aware
``HomogRep``) -> batched device operators -> ``run_sweep``.  The scorer's
chunk clamp keeps large-V scoring inside a fixed element budget without
changing results (chunk-invariance).
"""
import jax
import numpy as np
import pytest

from _invariants import assert_valid_homog_batch

from repro.core import api
from repro.core.chiplets import LARGE_HOMOG, paper_arch, resolve_arch
from repro.core.placement_homog import hex_mask


def test_resolve_arch_names():
    for name, (nc, nm, ni) in LARGE_HOMOG.items():
        arch = resolve_arch(name)
        assert len(arch.chiplets) == nc + nm + ni
    # paper names still resolve to the paper archs
    assert resolve_arch("homog32").name == paper_arch("homog32").name
    with pytest.raises(ValueError):
        resolve_arch("homog999")


def test_arch_family_and_defaults():
    # "hex127" has no homog prefix / 32/64 substring; the large-name
    # special case must keep it out of the hetero-64 bucket.
    assert api.arch_family("hex127") == ("homog", 127)
    assert api.arch_family("homog100") == ("homog", 100)
    assert api.arch_family("homog32") == ("homog", 32)
    d = api.paper_defaults("hex127")
    assert d.mutation_mode == "neighbor-one"
    # paper archs keep their Table III/IV defaults
    assert api.paper_defaults("homog32").ga.population == 200


def test_hex_mask_geometry():
    m = hex_mask(7)
    assert m.shape == (13, 13)
    assert int(m.sum()) == 127                    # centered hexagonal n=7
    assert np.array_equal(m, m[::-1])   # row widths mirror top/bottom
    # rows are contiguous spans: width == span between first/last True
    for row in m:
        idx = np.flatnonzero(row)
        assert idx[-1] - idx[0] + 1 == len(idx)
    assert int(m[6].sum()) == 13 and int(m[0].sum()) == 7


@pytest.fixture(scope="module")
def hexrep():
    arch = resolve_arch("hex127")
    return api.make_rep(arch, "hex127")


def test_hex127_rep_shape(hexrep):
    assert (hexrep.R, hexrep.C) == (13, 13)
    assert hexrep.allowed is not None
    # area counts only allowed cells, not the full 13x13 bounding box
    sz = hexrep.arch.chiplets[0].w * hexrep.arch.chiplets[0].h
    assert hexrep.area == pytest.approx(sz * 127)


def test_hex127_host_ops_respect_mask(hexrep):
    rng = np.random.default_rng(0)
    off = ~hexrep.allowed
    s = hexrep.random(rng)
    assert (s[0][off] == -1).all()
    for _ in range(5):
        s = hexrep.mutate(s, rng)
        assert (s[0][off] == -1).all()
    s2 = hexrep.random(rng)
    sm = hexrep.merge(s, s2, rng)
    assert (sm[0][off] == -1).all()
    for kind, ids in hexrep._kind_instances.items():
        assert (sm[0] == kind).sum() == len(ids)


def test_hex127_device_ops_respect_mask(hexrep):
    ops = hexrep.batch_ops()
    off = ~hexrep.allowed
    k0, k1, k2 = jax.random.split(jax.random.PRNGKey(0), 3)
    t, r = ops.random_batch(k0, 6)
    assert_valid_homog_batch(hexrep, t, r)
    assert (np.asarray(t)[:, off] == -1).all()
    mt, mr = ops.mutate_batch(k1, t, r)
    assert_valid_homog_batch(hexrep, mt, mr)
    assert (np.asarray(mt)[:, off] == -1).all()
    gt, gr = ops.merge_batch(k2, t, r, mt, mr)
    assert_valid_homog_batch(hexrep, gt, gr)
    assert (np.asarray(gt)[:, off] == -1).all()


def test_unmasked_rep_unchanged():
    # A degenerate all-True mask must normalize away (no special-casing
    # downstream, stage-cache key stays the unmasked one).
    from repro.core.placement_homog import HomogRep
    arch = paper_arch("homog32")
    rep = HomogRep(arch, R=8, C=5,
                   allowed=np.ones((8, 5), bool))
    assert rep.allowed is None


def test_homog100_run_sweep_smoke():
    """The 100+-chiplet family end-to-end through run_sweep (V=552):
    host-validity BR with a tiny budget so the smoke stays bounded."""
    cfg = api.ExperimentConfig(
        arch="homog100", algorithms=("br",),
        budget=api.Budget(evals=4), repetitions=1, seed=0,
        norm_samples=2, chunk=4, backend="fw-ref",
        params={"br": api.BRParams(batch=4)})
    res = api.run_sweep([cfg])
    rec = res.records[0]
    assert np.isfinite(rec.result.best_cost)
    types = rec.result.best_sol[0]
    assert (types >= 0).sum() == 100              # all chiplets placed


def test_chunk_clamp_is_result_invariant(monkeypatch):
    """Force the clamp active (tiny element budget -> eff chunk 1) and
    check scores are bit-for-bit the unclamped scorer's: the clamp may
    only change batching, never results."""
    from repro.core import proxies
    from repro.core.optimize import DevicePipeline, Evaluator

    arch = paper_arch("homog32")
    rep = api.make_rep(arch, "homog32")
    ev = Evaluator(rep, arch, rng=np.random.default_rng(0), norm_samples=2)
    pipe = DevicePipeline(ev)
    _, _, g = pipe._gen(jax.random.PRNGKey(0), 5)
    base = {k: np.asarray(v) for k, v in ev.score_batch(dict(g)).items()}

    monkeypatch.setattr(proxies, "_CHUNK_ELEM_BUDGET", 1)
    clamped_scorer = proxies.make_scorer(rep.layout, chunk=16)
    clamped = {k: np.asarray(v)
               for k, v in clamped_scorer(dict(g)).items()}
    for k in ("lat_c2m", "thr_c2m", "area", "connected"):
        assert np.array_equal(base[k], clamped[k]), k
