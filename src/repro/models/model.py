"""Top-level model assembly: build_model(cfg) → init / loss / prefill /
decode for every assigned architecture family.

Batch conventions (same keys as ``repro.configs.input_specs``):
  train   {"tokens": (B,S) i32, "labels": (B,S) i32}
          + {"patch_embeds": (B,P,D)} for VLM (anyres frontend STUB)
          + {"src_embeds": (B,Se,D)} for enc-dec (audio frontend STUB)
  prefill {"tokens": (B,S)} (+ stub embeds)
  decode  {"tokens": (B,1), "lengths": (B,)} + caches (+ mem_len enc-dec)

Loss: token-level cross-entropy (labels = -1 are masked) + MoE router
load-balancing aux.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..sharding.partition import shard
from .config import LMConfig
from .layers import rms_norm, rms_norm_init
from .transformer import (stack_cache_init, stack_decode, stack_init,
                          stack_prefill, stack_train)


def _dt(cfg):
    return jnp.dtype(cfg.dtype)


@dataclass(frozen=True)
class Model:
    cfg: LMConfig
    init: Callable
    loss_fn: Callable                  # (params, batch) -> (loss, metrics)
    prefill: Callable                  # (params, batch) -> (logits, caches)
    decode_step: Callable              # (params, batch, caches) -> (logits, caches)
    init_cache: Callable               # (B, cache_len) -> caches
    param_count: Callable


def _enc_plan(cfg: LMConfig):
    return [("attn", cfg.n_enc_layers)]


def _dec_plan(cfg: LMConfig):
    if cfg.family == "encdec":
        return [("xdec", cfg.n_layers)]
    return cfg.layer_plan()


def init_params(cfg: LMConfig, key) -> dict:
    k_e, k_g, k_h, k_enc = jax.random.split(key, 4)
    D, Vp = cfg.d_model, cfg.vocab_padded
    p = {
        "embed": (jax.random.normal(k_e, (Vp, D), jnp.float32)
                  * (D ** -0.5)).astype(_dt(cfg)),
        "groups": stack_init(k_g, cfg, plan=_dec_plan(cfg)),
        "final_norm": rms_norm_init(D),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = (jax.random.normal(k_h, (D, Vp), jnp.float32)
                        * (D ** -0.5)).astype(_dt(cfg))
    if cfg.family == "encdec":
        p["enc_groups"] = stack_init(k_enc, cfg, plan=_enc_plan(cfg))
        p["enc_norm"] = rms_norm_init(D)
    return p


def _logits(cfg, p, x):
    head = p["embed"].T if cfg.tie_embeddings else p["lm_head"]
    return shard((x @ head).astype(jnp.float32), "logits")


def _embed(cfg, p, tokens):
    return p["embed"][tokens] * jnp.asarray(
        cfg.d_model ** 0.5, _dt(cfg))


def _encode(cfg, p, src_embeds):
    B, Se, D = src_embeds.shape
    pos = jnp.broadcast_to(jnp.arange(Se)[None], (B, Se))
    x, _ = stack_train(p["enc_groups"], src_embeds.astype(_dt(cfg)), cfg, pos,
                       extra={"bidir": True}, plan=_enc_plan(cfg))
    return rms_norm(x, p["enc_norm"], cfg.norm_eps)


def _prep_inputs(cfg, p, batch):
    """Token embeddings (+ stub-frontend prefix), positions, #prefix."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = _embed(cfg, p, tokens)
    n_front = 0
    if cfg.frontend == "patch" and "patch_embeds" in batch:
        pe = batch["patch_embeds"].astype(_dt(cfg))
        n_front = pe.shape[1]
        x = jnp.concatenate([pe, x], axis=1)
    pos = jnp.broadcast_to(jnp.arange(x.shape[1])[None], (B, x.shape[1]))
    return shard(x, "act"), pos, n_front


def loss_fn(cfg: LMConfig, params, batch):
    x, pos, n_front = _prep_inputs(cfg, params, batch)
    extra = {}
    if cfg.family == "encdec":
        extra["memory"] = _encode(cfg, params, batch["src_embeds"])
    x, aux = stack_train(params["groups"], x, cfg, pos, extra=extra,
                         plan=_dec_plan(cfg))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if n_front:
        x = x[:, n_front:]
    logits = _logits(cfg, params, x)
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    lab = jnp.maximum(labels, 0)
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, lab[..., None], axis=-1)[..., 0]
    ntok = jnp.maximum(mask.sum(), 1.0)
    ce = -(ll * mask).sum() / ntok
    loss = ce + cfg.router_aux_weight * aux
    return loss, {"ce": ce, "aux": aux, "ntok": ntok}


def prefill(cfg: LMConfig, params, batch, cache_len: int):
    x, pos, n_front = _prep_inputs(cfg, params, batch)
    extra = {}
    if cfg.family == "encdec":
        extra["memory"] = _encode(cfg, params, batch["src_embeds"])
    x, caches, _ = stack_prefill(params["groups"], x, cfg, pos, cache_len,
                                 extra=extra, plan=_dec_plan(cfg))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = _logits(cfg, params, x[:, -1:])
    return logits[:, 0], caches


def decode_step(cfg: LMConfig, params, batch, caches):
    """batch: {"tokens": (B,1), "lengths": (B,)} (+ "mem_len" enc-dec)."""
    tokens, lengths = batch["tokens"], batch["lengths"]
    x = _embed(cfg, params, tokens)
    extra = {}
    if cfg.family == "encdec":
        extra["mem_len"] = batch["mem_len"]
    x, caches = stack_decode(params["groups"], x, caches, cfg, lengths,
                             extra=extra, plan=_dec_plan(cfg))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = _logits(cfg, params, x)
    return logits[:, 0], caches


def init_cache(cfg: LMConfig, B: int, cache_len: int, mem_len: int = 0):
    return stack_cache_init(cfg, B, cache_len, plan=_dec_plan(cfg),
                            mem_len=mem_len)


def param_count(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


def build_model(cfg: LMConfig) -> Model:
    return Model(
        cfg=cfg,
        init=functools.partial(init_params, cfg),
        loss_fn=functools.partial(loss_fn, cfg),
        prefill=functools.partial(prefill, cfg),
        decode_step=functools.partial(decode_step, cfg),
        init_cache=functools.partial(init_cache, cfg),
        param_count=param_count,
    )
