"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret mode.

Every kernel is exercised over a grid of shapes and dtypes and must
``assert_allclose`` against its ``ref.py`` oracle.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from repro.kernels import ops, ref

R = np.random.default_rng(7)


def randn(*shape, dtype=np.float32):
    return R.standard_normal(shape).astype(dtype)


# ---------------------------------------------------------------------------
# min-plus / FW
# ---------------------------------------------------------------------------

def random_graph(V, n_edges, seed=0, batch=1):
    rng = np.random.default_rng(seed)
    W = np.full((batch, V, V), 1e9, np.float32)
    for b in range(batch):
        np.fill_diagonal(W[b], 0)
        for _ in range(n_edges):
            i, j = rng.integers(V, size=2)
            if i != j:
                w = float(rng.integers(1, 9))
                W[b, i, j] = min(W[b, i, j], w)
                W[b, j, i] = min(W[b, j, i], w)
    return W


@pytest.mark.parametrize("V,edges,batch", [(8, 12, 1), (40, 120, 2),
                                           (130, 400, 1)])
def test_fw_counts_kernel(V, edges, batch):
    W = jnp.array(random_graph(V, edges, seed=V, batch=batch))
    D1, N1 = ops.fw_counts(W, impl="pallas")
    D2, N2 = ref.fw_counts_ref(W)
    assert_allclose(np.array(D1), np.array(D2), rtol=0)
    assert_allclose(np.array(N1), np.array(N2), rtol=0)


@pytest.mark.parametrize("m,k,n,tiles", [(64, 64, 64, dict(bm=32, bn=32, bk=32)),
                                         (100, 70, 130, dict(bm=32, bn=128, bk=32)),
                                         (128, 128, 128, dict())])
def test_minplus_tiled(m, k, n, tiles):
    A = jnp.array(R.random((m, k), np.float32) * 10)
    B = jnp.array(R.random((k, n), np.float32) * 10)
    o1 = ops.minplus(A, B, impl="pallas", **tiles)
    o2 = ref.minplus_ref(A, B)
    assert_allclose(np.array(o1), np.array(o2), rtol=1e-6)


def test_apsp_tiled_matches_fw():
    W = jnp.array(random_graph(48, 150, seed=3)[0])
    D1 = ops.apsp(W, impl="pallas", bm=32, bn=32, bk=32)
    D2, _ = ref.fw_counts_ref(W)
    assert_allclose(np.minimum(np.array(D1), 1e9),
                    np.minimum(np.array(D2), 1e9), rtol=1e-6)


@pytest.mark.parametrize("V", [2, 3])
def test_apsp_tiny_v(V):
    # Repeated-squaring edge cases: the iteration count is host math
    # (ceil(log2(max(V-1, 2)))); V=2 and V=3 must still converge.
    W = np.full((V, V), 1e9, np.float32)
    np.fill_diagonal(W, 0.0)
    W[0, V - 1] = W[V - 1, 0] = 5.0
    if V == 3:
        W[0, 1] = W[1, 0] = 2.0
        W[1, 2] = W[2, 1] = 2.0        # 0->2 via 1 (cost 4) beats direct 5
    W = jnp.asarray(W)
    D1 = ops.apsp(W, impl="pallas", bm=8, bn=8, bk=8)
    D2 = ref.apsp_ref(W)
    assert_allclose(np.minimum(np.array(D1), 1e9),
                    np.minimum(np.array(D2), 1e9), rtol=0)
    if V == 3:
        assert float(D1[0, 2]) == 4.0


# Blocked-tile FW with path counts (PR 7): must be bit-for-bit equal to
# the sequential reference — including multi-block tilings where the
# pivot block, panels and outer tiles all exercise distinct kernels.
@pytest.mark.parametrize("V,edges,batch,bt", [
    (8, 12, 1, 4),          # tiny tile, nb=2
    (13, 30, 2, 4),         # V not a tile multiple, nb=4
    (40, 120, 2, 16),       # nb=3 with padding
    (130, 400, 1, 64),      # nb=3, realistic size
    (130, 400, 2, 128),     # nb=2, production tile size
    (5, 0, 1, 4),           # fully disconnected (all-INF off-diagonal)
])
def test_fw_counts_tiled_bitforbit(V, edges, batch, bt):
    from repro.kernels.minplus import fw_counts_tiled_pallas
    W = jnp.array(random_graph(V, edges, seed=V + edges, batch=batch))
    D1, N1 = fw_counts_tiled_pallas(W, bt=bt)
    D2, N2 = ref.fw_counts_ref(W)
    assert_allclose(np.array(D1), np.array(D2), rtol=0)
    assert_allclose(np.array(N1), np.array(N2), rtol=0)


def test_fw_tiled_auto_dispatch():
    # fw_impl_tiled routes small V to the VMEM-resident kernel and large V
    # to the blocked-tile kernel; both must agree with the reference, so
    # the dispatch point is invisible in results.
    from repro.kernels.ops import FW_TILED_AUTO_V, fw_impl_tiled
    W = jnp.array(random_graph(24, 60, seed=1)[0])
    D1, N1 = fw_impl_tiled(W)
    D2, N2 = ref.fw_counts_ref(W)
    assert_allclose(np.array(D1), np.array(D2), rtol=0)
    assert_allclose(np.array(N1), np.array(N2), rtol=0)
    assert max(128, -(-24 // 128) * 128) <= FW_TILED_AUTO_V  # vmem path hit


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

ATTN_CASES = [
    dict(B=1, Sq=16, Sk=16, Hq=4, Hkv=4, d=16, causal=True),
    dict(B=2, Sq=24, Sk=24, Hq=4, Hkv=2, d=32, causal=True),
    dict(B=2, Sq=24, Sk=24, Hq=6, Hkv=2, d=16, causal=False),
    dict(B=1, Sq=8, Sk=32, Hq=4, Hkv=1, d=16, causal=True),   # chunk
    dict(B=1, Sq=32, Sk=32, Hq=2, Hkv=2, d=16, causal=True, window=7),
    dict(B=1, Sq=16, Sk=16, Hq=4, Hkv=4, d=16, causal=True, softcap=8.0),
]


@pytest.mark.parametrize("case", ATTN_CASES)
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_flash_attention(case, dtype):
    case = dict(case)
    B, Sq, Sk = case.pop("B"), case.pop("Sq"), case.pop("Sk")
    Hq, Hkv, d = case.pop("Hq"), case.pop("Hkv"), case.pop("d")
    q = jnp.array(randn(B, Sq, Hq, d)).astype(dtype)
    k = jnp.array(randn(B, Sk, Hkv, d)).astype(dtype)
    v = jnp.array(randn(B, Sk, Hkv, d)).astype(dtype)
    o1 = ops.flash_attention(q, k, v, impl="pallas", bq=8, bk=8, **case)
    o2 = ref.attention_ref(q, k, v, **case)
    tol = 2e-5 if dtype == np.float32 else 2e-2
    assert_allclose(np.array(o1, np.float32), np.array(o2, np.float32),
                    rtol=tol, atol=tol)


@pytest.mark.parametrize("S,Hq,Hkv,d,window", [
    (33, 4, 2, 16, None), (64, 8, 8, 32, None), (40, 4, 1, 16, 9)])
def test_decode_attention(S, Hq, Hkv, d, window):
    B = 3
    q = jnp.array(randn(B, Hq, d))
    kc = jnp.array(randn(B, S, Hkv, d))
    vc = jnp.array(randn(B, S, Hkv, d))
    lens = jnp.array([S, S // 2, 1], jnp.int32)
    o1 = ops.decode_attention(q, kc, vc, lens, impl="pallas", bs=8,
                              window=window)
    o2 = ref.decode_attention_ref(q, kc, vc, lens, window=window)
    assert_allclose(np.array(o1), np.array(o2), rtol=3e-5, atol=3e-5)


# ---------------------------------------------------------------------------
# recurrences
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("Bt,S,Di,N", [(1, 8, 16, 4), (2, 12, 20, 8),
                                       (2, 7, 130, 4)])
def test_selective_scan(Bt, S, Di, N):
    x = jnp.array(randn(Bt, S, Di))
    dt = jnp.array(0.1 + R.random((Bt, S, Di)).astype(np.float32))
    A = jnp.array(-R.random((Di, N)).astype(np.float32))
    Bm = jnp.array(randn(Bt, S, N))
    Cm = jnp.array(randn(Bt, S, N))
    Dm = jnp.array(randn(Di))
    y1, h1 = ops.selective_scan(x, dt, A, Bm, Cm, Dm, impl="pallas", bd=8)
    y2, h2 = ref.selective_scan_ref(x, dt, A, Bm, Cm, Dm)
    assert_allclose(np.array(y1), np.array(y2), rtol=3e-5, atol=3e-5)
    assert_allclose(np.array(h1), np.array(h2), rtol=3e-5, atol=3e-5)


def test_selective_scan_carries_state():
    """Splitting a sequence across two kernel calls == one call."""
    Bt, S, Di, N = 1, 16, 8, 4
    x = jnp.array(randn(Bt, S, Di))
    dt = jnp.array(0.1 + R.random((Bt, S, Di)).astype(np.float32))
    A = jnp.array(-R.random((Di, N)).astype(np.float32))
    Bm, Cm = jnp.array(randn(Bt, S, N)), jnp.array(randn(Bt, S, N))
    Dm = jnp.array(randn(Di))
    y_full, h_full = ref.selective_scan_ref(x, dt, A, Bm, Cm, Dm)
    h = None
    ys = []
    for s0 in (0, 8):
        sl = slice(s0, s0 + 8)
        y, h = ops.selective_scan(x[:, sl], dt[:, sl], A, Bm[:, sl],
                                  Cm[:, sl], Dm, h, impl="pallas", bd=8)
        ys.append(np.array(y))
    assert_allclose(np.concatenate(ys, 1), np.array(y_full), rtol=3e-5,
                    atol=3e-5)
    assert_allclose(np.array(h), np.array(h_full), rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("B,S,D", [(1, 8, 16), (2, 20, 40), (2, 5, 130)])
def test_rglru_scan(B, S, D):
    x = jnp.array(randn(B, S, D))
    a = jnp.array((0.05 + 0.9 * R.random((B, S, D))).astype(np.float32))
    y1, h1 = ops.rglru_scan(x, a, impl="pallas", bd=8)
    y2, h2 = ref.rglru_ref(x, a)
    assert_allclose(np.array(y1), np.array(y2), rtol=3e-5, atol=3e-5)
    assert_allclose(np.array(h1), np.array(h2), rtol=3e-5, atol=3e-5)
