"""Heterogeneous placement representation (paper §VI-A, Figs. 7-10).

The optimization algorithms do not operate on chiplet coordinates.  They
operate on the *(order, rotations)* pair that is fed to a deterministic
corner-placement algorithm; every such pair yields an overlap-free placement.

Isomorphism avoidance (Fig. 8):
* the order is a sequence of chiplet *types*, not IDs (two different orders
  by ID can produce the same placement; orders by type cannot);
* rotations are restricted per type to the non-isomorphic set computed from
  the chiplet geometry (rotation-invariant -> {0}, rotation-hybrid ->
  {0, 90}, rotation-sensitive -> all four).

Corner placement (Fig. 7): chiplets are placed one at a time.  Candidate
anchors are the L-corners formed by already-placed rectangles (bottom-left
corner-point set); the anchor minimizing the side of the minimum enclosing
*square* wins (step 3).  Overlap created by the greedy choice is resolved by
the paper's step-4 rule: overlap to the right pushes the chiplet up; overlap
above pushes it right.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from .chiplets import COMPUTE, IO, MEMORY, ArchSpec, Chiplet
from .proxies import Layout
from .topology import (PlacedPhys, ScoreGraph, build_score_graph,
                       infer_links_mst)

Sol = tuple[np.ndarray, np.ndarray]  # (order [N] kinds int8, rots [N] int8)


def sol_key(sol: Sol) -> bytes:
    return sol[0].tobytes() + sol[1].tobytes()


def _overlap(x, y, w, h, rects) -> int:
    """Index of the first placed rect overlapping (x,y,w,h), or -1."""
    if len(rects) == 0:
        return -1
    rx, ry, rw, rh = rects[:, 0], rects[:, 1], rects[:, 2], rects[:, 3]
    ov = (x < rx + rw - 1e-9) & (rx < x + w - 1e-9) & \
         (y < ry + rh - 1e-9) & (ry < y + h - 1e-9)
    idx = np.nonzero(ov)[0]
    return int(idx[0]) if len(idx) else -1


def corner_place(dims: list[tuple[float, float]]
                 ) -> np.ndarray:
    """Place rectangles in order; returns [N, 2] lower-left positions.

    Deterministic; never produces overlaps.  See module docstring.
    """
    n = len(dims)
    out = np.zeros((n, 2), dtype=np.float64)
    rects = np.zeros((0, 4), dtype=np.float64)
    for i, (w, h) in enumerate(dims):
        if i == 0:
            out[i] = (0.0, 0.0)
            rects = np.array([[0.0, 0.0, w, h]])
            continue
        # Candidate anchors: right-of and top-of corners of placed rects.
        cands = [(0.0, 0.0)]
        for (rx, ry, rw, rh) in rects:
            cands.append((rx + rw, ry))
            cands.append((rx, ry + rh))
        best = None
        cur_w = float((rects[:, 0] + rects[:, 2]).max())
        cur_h = float((rects[:, 1] + rects[:, 3]).max())
        for (cx, cy) in cands:
            x, y = cx, cy
            ok = False
            for _ in range(4 * n):          # bounded resolution loop
                j = _overlap(x, y, w, h, rects)
                if j < 0:
                    ok = True
                    break
                rx, ry, rw, rh = rects[j]
                # Step 4, from the overlap geometry: a blocking rect whose
                # bottom edge lies strictly above the candidate's bottom
                # overlaps from *above* -> move right past it; otherwise the
                # rect reaches the candidate's level, i.e. overlaps to the
                # *right* -> move up on top of it.  Both moves strictly
                # increase x or y, so the loop terminates.
                if ry > y + 1e-9:
                    x = rx + rw
                else:
                    y = ry + rh
            if not ok:
                continue
            side = max(max(cur_w, x + w), max(cur_h, y + h))
            key = (side, x + y, y, x)
            if best is None or key < best[0]:
                best = (key, x, y)
        assert best is not None
        _, x, y = best
        out[i] = (x, y)
        rects = np.concatenate([rects, [[x, y, w, h]]])
    return out


def corner_place_batch(dims: np.ndarray) -> np.ndarray:
    """Vectorized :func:`corner_place` across a population.

    ``dims`` is [B, N, 2] (w, h) per chiplet in placement order; returns
    [B, N, 2] lower-left positions.  The algorithm is inherently sequential
    per individual — each chiplet's candidate anchors depend on all earlier
    placements — so this runs the same N placement steps, but array-at-a-time
    across the whole population.  Bit-for-bit identical to the scalar path:
    the overlap-resolution moves are the same, and although candidates are
    enumerated in a different order, equal selection keys imply equal
    positions, so the lexicographic minimum is order-independent.
    """
    B, N, _ = dims.shape
    out = np.zeros((B, N, 2), dtype=np.float64)
    rects = np.zeros((B, N, 4), dtype=np.float64)
    rects[:, 0, 2:] = dims[:, 0]
    b_idx = np.arange(B)
    for i in range(1, N):
        wv = dims[:, i, 0][:, None]                      # [B, 1]
        hv = dims[:, i, 1][:, None]
        placed = rects[:, :i]                            # [B, i, 4]
        right = np.stack([placed[:, :, 0] + placed[:, :, 2],
                          placed[:, :, 1]], axis=-1)
        top = np.stack([placed[:, :, 0],
                        placed[:, :, 1] + placed[:, :, 3]], axis=-1)
        cands = np.concatenate(
            [np.zeros((B, 1, 2)), right, top], axis=1)   # [B, K, 2]
        x = cands[:, :, 0].copy()
        y = cands[:, :, 1].copy()
        ok = np.zeros(x.shape, dtype=bool)
        rx = placed[:, None, :, 0]
        ry = placed[:, None, :, 1]
        rw = placed[:, None, :, 2]
        rh = placed[:, None, :, 3]
        for _ in range(4 * N):                           # bounded resolution
            ov = ((x[:, :, None] < rx + rw - 1e-9)
                  & (rx < x[:, :, None] + wv[:, :, None] - 1e-9)
                  & (y[:, :, None] < ry + rh - 1e-9)
                  & (ry < y[:, :, None] + hv[:, :, None] - 1e-9))
            any_ov = ov.any(-1)
            ok |= ~any_ov
            pending = any_ov & ~ok
            if not pending.any():
                break
            blk = placed[b_idx[:, None], ov.argmax(-1)]  # first overlap [B,K,4]
            move_right = blk[:, :, 1] > y + 1e-9         # blocker above anchor
            nx = np.where(move_right, blk[:, :, 0] + blk[:, :, 2], x)
            ny = np.where(move_right, y, blk[:, :, 1] + blk[:, :, 3])
            x = np.where(pending, nx, x)
            y = np.where(pending, ny, y)
        cur_w = (placed[:, :, 0] + placed[:, :, 2]).max(1)[:, None]
        cur_h = (placed[:, :, 1] + placed[:, :, 3]).max(1)[:, None]
        side = np.maximum(np.maximum(cur_w, x + wv), np.maximum(cur_h, y + hv))
        k0 = np.where(ok, side, np.inf)
        k1 = np.where(ok, x + y, np.inf)
        k2 = np.where(ok, y, np.inf)
        k3 = np.where(ok, x, np.inf)
        sel = np.lexsort((k3, k2, k1, k0))[:, 0]         # primary key: k0
        assert ok[b_idx, sel].all()
        xi, yi = x[b_idx, sel], y[b_idx, sel]
        out[:, i, 0], out[:, i, 1] = xi, yi
        rects[:, i] = np.stack([xi, yi, dims[:, i, 0], dims[:, i, 1]], axis=-1)
    return out


@dataclass
class HeteroRep:
    """Placement representation + operators for heterogeneous chiplet shapes."""

    arch: ArchSpec
    mutation_mode: str = "any-one"

    def __post_init__(self):
        self._kind_instances = {
            k: [i for i, ch in enumerate(self.arch.chiplets) if ch.kind == k]
            for k in (COMPUTE, MEMORY, IO)
        }
        n = len(self.arch.chiplets)
        self._phy_base = np.zeros(n + 1, dtype=np.int64)
        for i, ch in enumerate(self.arch.chiplets):
            self._phy_base[i + 1] = self._phy_base[i] + ch.n_phys()
        # One prototype chiplet per kind (instances of a kind are identical).
        self._proto: dict[int, Chiplet] = {
            k: self.arch.chiplets[ids[0]]
            for k, ids in self._kind_instances.items() if ids
        }
        self._allowed_rot = {k: ch.allowed_rotations()
                             for k, ch in self._proto.items()}

    @property
    def layout(self) -> Layout:
        return Layout(Vp=int(self._phy_base[-1]), kinds=self.arch.kinds())

    @property
    def e_max(self) -> int:
        return 2 * int(self._phy_base[-1])

    # -- representation functions ------------------------------------------
    def random(self, rng: np.random.Generator) -> Sol:
        order = np.array([k for k, ids in self._kind_instances.items()
                          for _ in ids], dtype=np.int8)
        rng.shuffle(order)
        rots = np.array([rng.choice(self._allowed_rot[int(k)])
                         for k in order], dtype=np.int8)
        return order, rots

    def mutate(self, sol: Sol, rng: np.random.Generator) -> Sol:
        order = sol[0].copy()
        rots = sol[1].copy()
        both = self.mutation_mode.endswith("both")
        do_swap = both or bool(rng.integers(2))
        do_rot = both or not do_swap
        if do_swap:
            for _ in range(100):
                i, j = rng.integers(len(order), size=2)
                if order[i] != order[j]:
                    order[i], order[j] = order[j], order[i]
                    rots[i], rots[j] = rots[j], rots[i]
                    for p in (i, j):
                        if rots[p] not in self._allowed_rot[int(order[p])]:
                            rots[p] = rng.choice(
                                self._allowed_rot[int(order[p])])
                    break
        if do_rot:
            cand = [i for i in range(len(order))
                    if len(self._allowed_rot[int(order[i])]) > 1]
            if cand:
                i = cand[int(rng.integers(len(cand)))]
                rots[i] = rng.choice(self._allowed_rot[int(order[i])])
        return order, rots

    def merge(self, a: Sol, b: Sol, rng: np.random.Generator) -> Sol:
        """Fig. 10: carry over matching types/rotations, randomize the rest."""
        oa, ra = a
        ob, rb = b
        n = len(oa)
        order = np.full(n, -1, dtype=np.int8)
        match = oa == ob
        order[match] = oa[match]
        remaining = {k: len(ids) for k, ids in self._kind_instances.items()}
        for k in remaining:
            remaining[k] -= int((order == k).sum())
        fill = [k for k, cnt in remaining.items() for _ in range(cnt)]
        fill = np.array(fill, dtype=np.int8)
        rng.shuffle(fill)
        order[order == -1] = fill
        rots = np.zeros(n, dtype=np.int8)
        rmatch = match & (ra == rb)
        rots[rmatch] = ra[rmatch]
        for i in range(n):
            if not rmatch[i] or rots[i] not in self._allowed_rot[int(order[i])]:
                rots[i] = rng.choice(self._allowed_rot[int(order[i])])
        return order, rots

    # -- geometry / network --------------------------------------------------
    def place(self, sol: Sol) -> tuple[np.ndarray, list[Chiplet], np.ndarray]:
        """Run the corner-placement algorithm.

        Returns (positions [N,2] in *order* order, rotated chiplets, instance
        ids per order position).
        """
        order, rots = sol
        chips = [self._proto[int(k)].rotated(int(r))
                 for k, r in zip(order, rots)]
        pos = corner_place([(c.w, c.h) for c in chips])
        counters = {k: 0 for k in self._kind_instances}
        inst = np.zeros(len(order), dtype=np.int64)
        for p, k in enumerate(order):
            inst[p] = self._kind_instances[int(k)][counters[int(k)]]
            counters[int(k)] += 1
        return pos, chips, inst

    def geometry(self, sol: Sol) -> PlacedPhys:
        pos, chips, inst = self.place(sol)
        Vp = int(self._phy_base[-1])
        ppos = np.zeros((Vp, 2), dtype=np.float32)
        owner = np.zeros(Vp, dtype=np.int32)
        for i, ch in enumerate(self.arch.chiplets):
            owner[self._phy_base[i]:self._phy_base[i + 1]] = i
        for p, ch in enumerate(chips):
            i = int(inst[p])
            for li, (x, y) in enumerate(ch.phys):
                ppos[self._phy_base[i] + li] = (pos[p, 0] + x, pos[p, 1] + y)
        # get_area: minimal enclosing rectangle (§VI-A).
        xs = np.array([pos[p, 0] + chips[p].w for p in range(len(chips))])
        ys = np.array([pos[p, 1] + chips[p].h for p in range(len(chips))])
        area = float(xs.max() * ys.max())
        relay = np.array([ch.relay for ch in self.arch.chiplets])
        kinds = np.array(self.arch.kinds(), dtype=np.int8)
        return PlacedPhys(pos=ppos, owner=owner, relay=relay, kinds=kinds,
                          area=area)

    def score_graph(self, sol: Sol) -> ScoreGraph:
        geo = self.geometry(sol)
        links, connected = infer_links_mst(self.arch, geo)
        return build_score_graph(self.arch, geo, links, self.e_max, connected)

    def is_connected(self, sol: Sol) -> bool:
        geo = self.geometry(sol)
        _, connected = infer_links_mst(self.arch, geo)
        return connected

    def batch_ops(self) -> "HeteroBatch":
        """Cached vectorized (device-resident) operators for this arch."""
        if not hasattr(self, "_batch_ops"):
            self._batch_ops = HeteroBatch(self)
        return self._batch_ops


# ---------------------------------------------------------------------------
# Device-resident batched operators.
#
# Mirrors placement_homog.HomogBatch for the heterogeneous representation:
# the host operators above generate/mutate/merge one (order, rots) pair at a
# time; HeteroBatch makes the same decisions as pure JAX array ops over
# stacked [B, N] arrays keyed by a PRNG key.  Equivalence with the host
# operators is *distributional* — every random choice is uniform over the
# same candidate set — not bit-for-bit (different RNG streams).  The corner
# placement itself is inherently sequential per individual and stays
# host-side, but vectorized across the population (geometry_batch /
# corner_place_batch).
# ---------------------------------------------------------------------------

_KINDS3 = (COMPUTE, MEMORY, IO)
_SWAP_TRIES = 128     # host caps at 100 sequential tries; pre-drawn here
_ROT_DRAW = 12        # lcm of possible |allowed_rotations| in {1, 2, 3, 4, 6}


class HeteroBatch:
    """Vectorized ``random/mutate/merge`` + batch geometry for one arch."""

    def __init__(self, rep: HeteroRep):
        self.rep = rep
        self.N = len(rep.arch.chiplets)
        self.Vp = int(rep._phy_base[-1])
        fill = [k for k, ids in rep._kind_instances.items() for _ in ids]
        self._kinds_fill = jnp.asarray(np.array(fill, dtype=np.int8))
        self._counts = np.array(
            [len(rep._kind_instances.get(k, ())) for k in _KINDS3], np.int32)
        # Per-kind non-isomorphic rotation sets (Fig. 8), as padded tables.
        rot_table = np.zeros((3, 4), np.int8)
        rot_count = np.ones(3, np.int32)
        allowed = np.zeros((3, 4), bool)
        for k, rl in rep._allowed_rot.items():
            rot_table[k, :len(rl)] = rl
            rot_count[k] = len(rl)
            allowed[k, list(rl)] = True
        self._rot_table = jnp.asarray(rot_table)
        self._rot_count = jnp.asarray(rot_count)
        self._allowed_mask = jnp.asarray(allowed)
        self._multi_rot = jnp.asarray(rot_count > 1)
        # Rotated geometry tables (host-side, float64 like corner_place).
        self._pmax = max(ch.n_phys() for ch in rep.arch.chiplets)
        self._dims_table = np.zeros((3, 4, 2), np.float64)
        self._phys_table = np.zeros((3, 4, self._pmax, 2), np.float64)
        self._nphys_kind = np.zeros(3, np.int64)
        for k, proto in rep._proto.items():
            self._nphys_kind[k] = proto.n_phys()
            for r in range(4):
                ch = proto.rotated(r)
                self._dims_table[k, r] = (ch.w, ch.h)
                self._phys_table[k, r, :len(ch.phys)] = ch.phys

    # -- rotation draws ------------------------------------------------------
    def _uniform_rot(self, key, kind: jnp.ndarray) -> jnp.ndarray:
        """Uniform draw from each position's allowed-rotation set.  Exact:
        the draw range is a multiple of every possible set size."""
        draws = jax.random.randint(key, kind.shape, 0, _ROT_DRAW)
        return self._rot_table[kind, draws % self._rot_count[kind]]

    def _onehot(self, idx: jnp.ndarray, flag: jnp.ndarray) -> jnp.ndarray:
        return (jnp.arange(self.N)[None, :] == idx[:, None]) & flag[:, None]

    # -- the representation functions, batched -------------------------------
    def random_batch(self, key, n: int) -> tuple[jnp.ndarray, jnp.ndarray]:
        """n independent uniform (order, rots): a random permutation of the
        chiplet-kind multiset, rotations uniform over each kind's set."""
        k1, k2 = jax.random.split(key)
        keys = jax.random.split(k1, n)
        order = jax.vmap(
            lambda k: jax.random.permutation(k, self._kinds_fill))(keys)
        rots = self._uniform_rot(k2, order.astype(jnp.int32))
        return order, rots

    def mutate_batch(self, key, order, rots
                     ) -> tuple[jnp.ndarray, jnp.ndarray]:
        """Batched ``mutate``: per individual either a swap of two
        differing-type positions or a re-roll of one multi-rotation chiplet
        (or both, per ``mutation_mode``), host first-valid-try semantics."""
        B = order.shape[0]
        both = self.rep.mutation_mode.endswith("both")
        kcoin, ki, kj, kfix, kpick, krot = jax.random.split(key, 6)
        if both:
            do_swap = jnp.ones(B, bool)
            do_rot = jnp.ones(B, bool)
        else:
            do_swap = jax.random.bernoulli(kcoin, 0.5, (B,))
            do_rot = ~do_swap
        # Pre-drawn swap tries; the first valid one is the host's accepted
        # draw (identical first-success distribution).
        i = jax.random.randint(ki, (B, _SWAP_TRIES), 0, self.N)
        j = jax.random.randint(kj, (B, _SWAP_TRIES), 0, self.N)
        oi = jnp.take_along_axis(order, i, axis=1)
        oj = jnp.take_along_axis(order, j, axis=1)
        valid = oi != oj
        first = jnp.argmax(valid, axis=1)
        sel = lambda a: jnp.take_along_axis(a, first[:, None], axis=1)[:, 0]
        do_it = do_swap & valid.any(axis=1)
        s1 = jnp.where(do_it, sel(i), 0)
        s2 = jnp.where(do_it, sel(j), 0)       # s1 == s2 == 0 -> no-op swap
        b = jnp.arange(B)
        o1, o2 = order[b, s1], order[b, s2]
        order2 = order.at[b, s1].set(o2).at[b, s2].set(o1)
        r1, r2 = rots[b, s1], rots[b, s2]
        rots2 = rots.at[b, s1].set(r2).at[b, s2].set(r1)
        kind = order2.astype(jnp.int32)
        # Host fixes swapped rotations only when illegal for the new kind.
        swapped = self._onehot(s1, do_it) | self._onehot(s2, do_it)
        legal = self._allowed_mask[kind, rots2.astype(jnp.int32)]
        rots2 = jnp.where(swapped & ~legal,
                          self._uniform_rot(kfix, kind), rots2)
        # Rotation move: uniform pick among multi-rotation positions.
        multi = self._multi_rot[kind]
        g = jax.random.gumbel(kpick, (B, self.N))
        pick = jnp.argmax(jnp.where(multi, g, -jnp.inf), axis=1)
        upd = self._onehot(pick, do_rot & multi.any(axis=1))
        rots2 = jnp.where(upd, self._uniform_rot(krot, kind), rots2)
        return order2, rots2.astype(rots.dtype)

    def merge_batch(self, key, oa, ra, ob, rb
                    ) -> tuple[jnp.ndarray, jnp.ndarray]:
        """Batched Fig. 10 merge: carry agreeing types, distribute leftover
        chiplets uniformly over disagreeing positions (random-rank fill ==
        host's shuffled fill), carry rotations only where both agree."""
        B = oa.shape[0]
        k1, k2 = jax.random.split(key)
        match = oa == ob
        carried = jnp.where(match, oa, -2)
        rem = [self._counts[k] - (carried == k).sum(axis=1) for k in range(3)]
        prio = jax.random.uniform(k1, (B, self.N))
        prio = jnp.where(match, 2.0, prio)     # matched positions rank last
        rank = jnp.argsort(jnp.argsort(prio, axis=1), axis=1)
        c0 = rem[0][:, None]
        c1 = c0 + rem[1][:, None]
        fill = jnp.where(rank < c0, COMPUTE,
                         jnp.where(rank < c1, MEMORY, IO))
        order = jnp.where(match, oa, fill.astype(oa.dtype))
        rmatch = match & (ra == rb)
        rots = jnp.where(rmatch, ra,
                         self._uniform_rot(k2, order.astype(jnp.int32)))
        return order, rots.astype(ra.dtype)

    # -- batch geometry (host-side numpy; sequential only over N) ------------
    def geometry_batch(self, order: np.ndarray, rots: np.ndarray
                       ) -> tuple[np.ndarray, np.ndarray]:
        """Stacked [B, N] (order, rots) -> (PHY positions [B, Vp, 2] float32,
        areas [B] float32).  Bit-for-bit equal to ``HeteroRep.geometry`` per
        individual (same corner placement, same float32 rounding)."""
        order = np.asarray(order, dtype=np.int64)
        rots = np.asarray(rots, dtype=np.int64)
        B, N = order.shape
        dims = self._dims_table[order, rots]                 # [B, N, 2]
        pos = corner_place_batch(dims)
        inst = np.zeros((B, N), np.int64)
        for k, ids in self.rep._kind_instances.items():
            if not ids:
                continue
            mk = order == k
            rank = np.cumsum(mk, axis=1) - 1
            ids_a = np.asarray(ids)
            inst = np.where(mk, ids_a[np.clip(rank, 0, len(ids_a) - 1)], inst)
        offs = self._phys_table[order, rots]                 # [B, N, P, 2]
        cnt = self._nphys_kind[order]                        # [B, N]
        base = self.rep._phy_base[:-1][inst]                 # [B, N]
        li = np.arange(self._pmax)
        gi = base[:, :, None] + li[None, None, :]
        live = li[None, None, :] < cnt[:, :, None]
        coords = (pos[:, :, None, :] + offs).astype(np.float32)
        ppos = np.zeros((B, self.Vp, 2), np.float32)
        b_idx = np.broadcast_to(np.arange(B)[:, None, None], gi.shape)
        ppos[b_idx[live], gi[live]] = coords[live]
        area = ((pos[:, :, 0] + dims[:, :, 0]).max(axis=1)
                * (pos[:, :, 1] + dims[:, :, 1]).max(axis=1))
        return ppos, area.astype(np.float32)
