"""Design a 2.5D accelerator package for an LM workload (paper §IV-B made
first-class): the compiled dry-run of a training/serving step yields the
traffic signature; PlaceIT co-optimizes the chiplet placement + ICI topology
for it.

  PYTHONPATH=src python examples/design_accelerator.py \
      [--artifact artifacts/dryrun/qwen3-1.7b__train_4k__single.json]
"""
import argparse
import glob
import json
import os

from repro.core.bridge import (TrafficSignature, codesign,
                               signature_from_artifact)


def main():
    from repro.core.registries import OPTIMIZERS, SCORER_BACKENDS

    ap = argparse.ArgumentParser()
    ap.add_argument("--artifact", default=None)
    ap.add_argument("--evals", type=int, default=120)
    ap.add_argument("--optimizer", default="ga",
                    choices=OPTIMIZERS.names())
    ap.add_argument("--backend", default="fw-ref",
                    choices=SCORER_BACKENDS.names(),
                    help="scorer backend (fw-pallas = Pallas min-plus "
                         "kernel)")
    args = ap.parse_args()

    art = args.artifact
    if art is None:
        cands = sorted(glob.glob("artifacts/dryrun/*__single.json"))
        art = cands[0] if cands else None
    if art and os.path.exists(art):
        mp = art.replace("__single", "__multi")
        sig = signature_from_artifact(
            art, multi_pod_rec=mp if os.path.exists(mp) else None)
        print(f"workload signature from {art}")
    else:
        print("no dry-run artifact found; using a synthetic decode "
              "signature")
        sig = TrafficSignature("demo", "decode_32k", "decode", t_comp=0.2,
                               t_mem=2.0, t_coll=0.6, io_share=0.15)
    print(f"  t_comp={sig.t_comp:.3g}s t_mem={sig.t_mem:.3g}s "
          f"t_coll={sig.t_coll:.3g}s io_share={sig.io_share:.2f}\n")

    out = codesign(sig, max_evals=args.evals, norm_samples=24,
                   optimizer=args.optimizer, backend=args.backend)
    print(f"package: {out['package']}")
    print(f"cost weights: {out['weights']}")
    print(f"PlaceIT cost  : {out['placeit_cost']:.3f}")
    print(f"2D-mesh cost  : {out['baseline_cost']:.3f}")
    print(f"improvement   : {100 * out['improvement']:.1f}%")
    print("\nper-metric (placeit vs baseline):")
    for k in sorted(out["best_metrics"]):
        if k == "area":
            continue
        print(f"  {k:10s} {out['best_metrics'][k]:10.2f}  "
              f"{out['baseline_metrics'][k]:10.2f}")


if __name__ == "__main__":
    main()
