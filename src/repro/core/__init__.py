# The paper's primary contribution — implement the SYSTEM here
# (scheduler, optimizer, data path, serving loop, etc.) in the
# host framework. Add sibling subpackages for substrates.
#
# Public entry point: the registry-driven experiment API.
from .api import (Budget, ExperimentConfig, RunRecord, SweepConfig,  # noqa: F401
                  SweepResult, baseline_cost, best_by_algorithm,
                  run_experiment, run_sweep, summarize)
from .objective import (Objective, Ramp, Schedule, TermSpec,  # noqa: F401
                        TrafficMix, compile_objective, compile_schedule,
                        objective_cost_host, weights_vec)
from .pareto import (ParetoFront, ParetoGridSpec, ParetoPoint,  # noqa: F401
                     hypervolume, nondominated_mask, run_pareto,
                     run_pareto_sweep)
from .registries import (OBJECTIVE_TERMS, OPTIMIZERS,  # noqa: F401
                         SCHEDULE_RAMPS, SCORER_BACKENDS,
                         register_objective_term, register_optimizer,
                         register_schedule_ramp, register_scorer_backend)
