"""3D & hierarchical arch families: TSV-aware stacked grids, gateway
backbones, and express/torus augmentation — pluggable through
``chiplets.resolve_arch`` / ``api.make_rep`` into the batched pipeline.
"""
from .families import FAMILIES3D, Family3DSpec, make_rep3d
from .placement import Homog3DBatch, Homog3DRep
from .topology import (TIER_BACKBONE, TIER_PLANAR, TIER_VERTICAL, AdjRecord,
                       Grid3DGraphBatch, default_tier_values, family_records,
                       grid3d_adjacency, score_graph3d_host)

__all__ = [
    "AdjRecord", "FAMILIES3D", "Family3DSpec", "Grid3DGraphBatch",
    "Homog3DBatch", "Homog3DRep", "TIER_BACKBONE", "TIER_PLANAR",
    "TIER_VERTICAL", "default_tier_values", "family_records",
    "grid3d_adjacency", "make_rep3d", "score_graph3d_host",
]
