"""Design service: continuous-batching throughput vs sequential runs.

Two sections (PR 6):

* **batching** — N concurrent tenant requests (mixed seeds/objective
  weights, same term structure) through one :class:`DesignEngine` vs the
  same N configs run back-to-back with ``run_experiment``-style
  sequential sweeps.  Reports scorer dispatches (the engine stacks every
  tick's pending generations into one call), requests/s, and the
  streamed-update counts.  Results are bit-for-bit identical either way
  (asserted here on every measured run).
* **shard** — the same engine with the population-axis ``shard_map``
  wrapper on, pinning the single-device fallback's overhead (and, on a
  multi-device host, the scaling path).

Results go to stdout as BENCH lines and to
``artifacts/bench/design_service.json``; ``benchmarks.run`` merges that
into ``BENCH_design_service.json`` at the repo root.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from .common import budget, emit, out_dir


def _requests(n: int, evals: int, norm_samples: int):
    from repro.core.api import Budget, DesignRequest, ExperimentConfig
    reqs = []
    for i in range(n):
        cfg = ExperimentConfig(
            arch="homog32", algorithms=("br", "ga"),
            budget=Budget(evals=evals), norm_samples=norm_samples,
            chunk=4, seed=i, params={"br": {"batch": 4}})
        reqs.append(DesignRequest(config=cfg, request_id=f"tenant-{i}"))
    return reqs


def _batching_stats(quick: bool) -> dict:
    from repro.core.api import clear_scorer_cache, run_sweep
    from repro.serve.design import DesignEngine
    n = budget(quick, 4, 8)
    evals = budget(quick, 12, 60)
    norm_samples = budget(quick, 4, 16)
    reqs = _requests(n, evals, norm_samples)

    clear_scorer_cache()
    eng = DesignEngine(max_active=n)
    t0 = time.perf_counter()
    for r in reqs:
        eng.submit(r)
    eng.run()
    t_engine = time.perf_counter() - t0
    responses = [eng.result(r.request_id) for r in reqs]

    # Sequential baseline: one isolated sweep per tenant, back-to-back.
    clear_scorer_cache()
    t0 = time.perf_counter()
    seq_calls = 0
    seq_records = []
    for r in reqs:
        sw = run_sweep([r.config], fold_repetitions=False)
        seq_calls += sw.stats.score_calls
        seq_records.extend(sw.records)
    t_seq = time.perf_counter() - t0

    eng_records = [rec for resp in responses for rec in resp.records]
    for a, b in zip(eng_records, seq_records):
        assert a.result.best_cost == b.result.best_cost, \
            "engine result diverged from sequential run"
    updates = [len([u for u in resp.updates if u.kind == "progress"])
               for resp in responses]
    return dict(
        n_requests=n, evals_per_request=evals,
        engine_score_calls=eng.stats.score_calls,
        sequential_score_calls=seq_calls,
        stacked_rounds=eng.stats.stacked_rounds,
        ticks=eng.stats.ticks,
        engine_seconds=t_engine, sequential_seconds=t_seq,
        engine_req_per_s=n / t_engine, sequential_req_per_s=n / t_seq,
        min_progress_updates=min(updates),
        rows_scored=eng.stats.rows_scored)


def _shard_stats(quick: bool) -> dict:
    from repro.serve.design import DesignEngine
    n = budget(quick, 2, 4)
    reqs = _requests(n, budget(quick, 12, 60), budget(quick, 4, 16))
    eng = DesignEngine(max_active=n, shard=True)
    t0 = time.perf_counter()
    for r in reqs:
        eng.submit(r)
    eng.run()
    t = time.perf_counter() - t0
    base = DesignEngine(max_active=n)
    for r in reqs:
        base.submit(r)
    base.run()
    for r in reqs:
        a, b = eng.result(r.request_id), base.result(r.request_id)
        for x, y in zip(a.records, b.records):
            assert x.result.best_cost == y.result.best_cost, \
                "sharded result diverged from unsharded"
    return dict(n_requests=n, devices=eng.stats.shard_devices,
                seconds=t, score_calls=eng.stats.score_calls)


def run(quick: bool = True) -> dict:
    results: dict = {}
    bs = _batching_stats(quick)
    results["batching"] = bs
    emit("design_service_dispatch_ratio",
         round(bs["sequential_score_calls"]
               / max(bs["engine_score_calls"], 1), 2),
         f"{bs['sequential_score_calls']} sequential vs "
         f"{bs['engine_score_calls']} engine scorer dispatches for "
         f"{bs['n_requests']} tenants (bit-for-bit asserted)")
    emit("design_service_req_per_s", round(bs["engine_req_per_s"], 2),
         f"vs {bs['sequential_req_per_s']:.2f} sequential; "
         f"{bs['min_progress_updates']} streamed updates/request min")
    ss = _shard_stats(quick)
    results["shard"] = ss
    emit("design_service_shard_devices", ss["devices"],
         f"population shard_map over {ss['devices']} device(s), "
         "bit-for-bit vs unsharded")
    with open(os.path.join(out_dir(), "design_service.json"), "w") as f:
        json.dump(results, f, indent=1, default=float)
    return results


def main(quick: bool = True):
    run(quick)


if __name__ == "__main__":
    main(quick=os.environ.get("BENCH_FULL", "") != "1")
