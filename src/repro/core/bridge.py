"""Bridge: LM-workload traffic signature → PlaceIT package co-design.

The paper's §IV-B sketches using "estimates of the ICI latency and
throughput under a certain application trace ... to design a domain-specific
accelerator, e.g., for machine learning training and inference".  This
module is that idea made first-class: the *compiled* LM step (a dry-run
artifact from ``launch.dryrun``) yields a traffic signature

    t_comp  — FLOP residency        → compute-chiplet count pressure
    t_mem   — HBM bytes residency   → C2M traffic (core ↔ HBM-stack chiplet)
    t_coll  — ICI wire residency    → C2C traffic (core ↔ core collectives)
    io      — cross-pod (DCN) share → C2I / M2I traffic (IO chiplets)

which is converted into the paper's nine cost-function weights and fed to
the PlaceIT optimizer over a TPU-class 2.5D package (compute = tensor-core
dies, memory = HBM stacks, IO = ICI/DCN PHY dies).  Decode workloads weight
latency (one small step per token); training weights throughput.

Output: optimized placement + inferred ICI topology + metrics, compared to
the 2D-mesh baseline — "design the package for the model you are about to
train".
"""
from __future__ import annotations

import json
from dataclasses import dataclass

import numpy as np

from .api import Budget, GAParams, make_evaluator
from .baseline import MeshBaseline
from .chiplets import ArchSpec, LatencyParams, heterogeneous_arch
from .cost import total_cost
from .placement_hetero import HeteroRep
from .registries import OPTIMIZERS

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9


@dataclass(frozen=True)
class TrafficSignature:
    arch: str
    shape: str
    kind: str                   # train | prefill | decode
    t_comp: float
    t_mem: float
    t_coll: float
    io_share: float             # fraction of collective bytes crossing pods

    @property
    def total(self) -> float:
        return max(self.t_comp + self.t_mem + self.t_coll, 1e-30)


def signature_from_artifact(path_or_rec, *, multi_pod_rec=None
                            ) -> TrafficSignature:
    """Build the signature from a dry-run JSON artifact (single-pod), and
    optionally estimate the cross-pod share from the multi-pod artifact."""
    rec = path_or_rec
    if isinstance(path_or_rec, str):
        with open(path_or_rec) as f:
            rec = json.load(f)
    t_comp = rec["flops_total"] / PEAK_FLOPS
    t_mem = rec["bytes_accessed_total"] / HBM_BW
    t_coll = rec["collectives"]["wire_bytes_per_chip"] / LINK_BW
    io_share = 0.05
    if multi_pod_rec is not None:
        mp = multi_pod_rec
        if isinstance(mp, str):
            with open(mp) as f:
                mp = json.load(f)
        w_single = rec["collectives"]["wire_bytes_per_chip"]
        w_multi = mp["collectives"]["wire_bytes_per_chip"]
        # extra wire bytes on the multi-pod mesh ≈ cross-pod traffic
        io_share = float(np.clip((w_multi - w_single)
                                 / max(w_multi, 1e-9), 0.01, 0.9))
    shape = rec["shape"]
    kind = ("train" if shape.startswith("train")
            else "prefill" if shape.startswith("prefill") else "decode")
    return TrafficSignature(rec["arch"], shape, kind, t_comp, t_mem, t_coll,
                            io_share)


def weights_from_signature(sig: TrafficSignature) -> dict:
    """The paper's nine cost weights from the workload residencies.

    Throughput weights follow the byte-volume shares (what saturates
    links); latency weights follow them too but are boosted for decode
    (one dependent small step per generated token) and damped for train
    (pipelined, throughput-bound).
    """
    s = sig.total
    c2c = sig.t_coll / s                     # core<->core collectives
    c2m = sig.t_mem / s                      # core<->HBM
    c2i = m2i = sig.io_share * max(c2c, c2m)
    lat_boost = {"train": 0.5, "prefill": 1.0, "decode": 3.0}[sig.kind]
    base = dict(
        w_thr=(max(c2c, 0.02), max(c2m, 0.02), max(c2i, 0.02),
               max(m2i, 0.02)),
        w_lat=tuple(lat_boost * w for w in
                    (max(c2c, 0.02), max(c2m, 0.02), max(c2i, 0.02),
                     max(m2i, 0.02))),
        w_area=1.0,
    )
    # normalize so weights sum to ~10 (same scale as the paper's 2/0.1 mix)
    tot = sum(base["w_thr"]) + sum(base["w_lat"]) + base["w_area"]
    scale = 10.0 / tot
    return dict(
        w_thr=tuple(round(w * scale, 3) for w in base["w_thr"]),
        w_lat=tuple(round(w * scale, 3) for w in base["w_lat"]),
        w_area=round(base["w_area"] * scale, 3),
    )


def tpu_like_package(sig: TrafficSignature, *, n_compute: int = 8,
                     n_memory: int = 4, n_io: int = 2) -> ArchSpec:
    """A TPU-class 2.5D package: tensor-core dies + HBM stacks + IO dies.

    Compute-heavy workloads get more compute dies; memory-bound decode gets
    more HBM stacks (one extra per 20% memory residency).
    """
    s = sig.total
    mem_share = sig.t_mem / s
    comp_share = sig.t_comp / s
    n_memory = max(2, int(round(n_memory * (0.5 + 1.5 * mem_share))))
    n_compute = max(4, int(round(n_compute * (0.5 + 1.5 * comp_share))))
    w = weights_from_signature(sig)
    arch = heterogeneous_arch(n_compute, n_memory, n_io, config="placeit",
                              latency=LatencyParams())
    import dataclasses
    return dataclasses.replace(
        arch, name=f"tpu_like_{sig.arch}_{sig.shape}",
        w_lat=w["w_lat"], w_thr=w["w_thr"], w_area=w["w_area"])


def codesign(sig: TrafficSignature, *, seed: int = 0, max_evals: int = 300,
             norm_samples: int = 64, optimizer: str = "ga",
             backend: str = "fw-ref", params=None) -> dict:
    """Run the co-optimization for the workload; compare to mesh baseline.

    ``optimizer``/``backend`` name entries in the registries, so a custom
    search algorithm or the Pallas scorer kernel are one string away.
    """
    arch = tpu_like_package(sig)
    rng = np.random.default_rng(seed)
    rep = HeteroRep(arch, mutation_mode="any-one")
    ev = make_evaluator(rep, arch, rng=rng, norm_samples=norm_samples,
                        backend=backend)
    entry = OPTIMIZERS.get(optimizer)
    if params is None:
        params = (GAParams(population=20, elitism=4, tournament=4)
                  if optimizer == "ga" else entry.params_cls())
    res = entry.fn(ev, rng, Budget(evals=max_evals), params)
    base_graph = MeshBaseline(arch).build()[0]
    base_metrics = ev.score([base_graph])
    base_cost = float(np.asarray(
        total_cost(base_metrics, arch, ev.norm))[0])
    return {
        "workload": f"{sig.arch}/{sig.shape}",
        "signature": dict(t_comp=sig.t_comp, t_mem=sig.t_mem,
                          t_coll=sig.t_coll, io_share=sig.io_share),
        "weights": weights_from_signature(sig),
        "package": dict(n_compute=arch.counts()[0],
                        n_memory=arch.counts()[1], n_io=arch.counts()[2]),
        "placeit_cost": res.best_cost,
        "baseline_cost": base_cost,
        "improvement": (base_cost - res.best_cost) / base_cost,
        "best_metrics": res.best_metrics,
        "baseline_metrics": {k: float(v[0]) for k, v in
                             base_metrics.items()},
        "best_sol": res.best_sol,
        "n_evaluated": res.n_evaluated,
    }
