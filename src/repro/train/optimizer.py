"""AdamW with fp32 states, global-norm clipping, LR schedules, and optional
int8 gradient compression with error feedback.

No optax dependency — states are plain pytrees so the sharding rules and the
checkpoint manager treat them exactly like parameters (FSDP-sharded).

Gradient compression (DESIGN.md §6): block-wise int8 quantization with an
error-feedback accumulator.  ``compressed_psum`` is the shard_map building
block a real deployment uses for the cross-pod all-reduce (8x fewer bytes on
the pod axis); ``compress_grads`` applies the same quantization numerics
inside the optimizer so convergence effects are testable on CPU.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    betas: tuple[float, float] = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"          # cosine | linear | const
    compress_int8: bool = False       # int8 grad quantization + err feedback
    compress_block: int = 256
    state_int8: bool = False          # 8-bit Adam m/v (row-wise scales)


def lr_at(cfg: OptConfig, step) -> jnp.ndarray:
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    if cfg.schedule == "cosine":
        decay = 0.5 * (1 + jnp.cos(jnp.pi * t))
    elif cfg.schedule == "linear":
        decay = 1.0 - t
    else:
        decay = 1.0
    return cfg.lr * warm * decay


# ---------------------------------------------------------------------------
# int8 block quantization + error feedback
# ---------------------------------------------------------------------------

def quantize_int8(x: jnp.ndarray, block: int = 256):
    """Block-wise symmetric int8 quantization: returns (q, scales)."""
    flat = x.reshape(-1)
    n = flat.shape[0]
    nb = -(-n // block)
    pad = nb * block - n
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blk = flat.reshape(nb, block).astype(jnp.float32)
    scale = jnp.max(jnp.abs(blk), axis=1, keepdims=True) / 127.0
    q = jnp.round(blk / jnp.where(scale == 0, 1.0, scale)
                  ).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale, shape, block: int = 256):
    blk = q.astype(jnp.float32) * scale
    flat = blk.reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape)


def compress_grads(grads, err, block: int = 256):
    """Quantize grads+err to int8 and return (dequantized, new_err)."""
    def one(g, e):
        tot = g.astype(jnp.float32) + e
        q, s = quantize_int8(tot, block)
        deq = dequantize_int8(q, s, g.shape, block).astype(g.dtype)
        return deq, (tot - deq.astype(jnp.float32)).astype(e.dtype)

    flat = jax.tree.map(one, grads, err)
    deq = jax.tree.map(lambda t: t[0], flat,
                       is_leaf=lambda t: isinstance(t, tuple))
    new_err = jax.tree.map(lambda t: t[1], flat,
                           is_leaf=lambda t: isinstance(t, tuple))
    return deq, new_err


def compressed_psum(x: jnp.ndarray, axis_name: str, block: int = 256):
    """shard_map building block: int8-quantized all-reduce over an axis.

    Each participant quantizes its contribution; the reduction runs over the
    (q, scale) pair — 8x fewer payload bytes on the wire than fp32 psum.
    """
    q, s = quantize_int8(x, block)
    # Dequantize locally, then reduce: payload that crossed the axis is int8.
    deq = dequantize_int8(q, s, x.shape, block)
    return jax.lax.psum(deq, axis_name)


# ---------------------------------------------------------------------------
# 8-bit Adam state (row-wise int8 + fp32 scale per row; DESIGN.md §6 —
# cuts optimizer HBM from 8 to ~2 bytes/param, the difference between
# grok-314B training fitting one v5e pod or not)
# ---------------------------------------------------------------------------

def _q8(x: jnp.ndarray) -> dict:
    """Quadratic-map int8: code c -> sign(c) * (|c|/127)^2 * rowmax.

    Quantizing in sqrt-space concentrates resolution near zero — linear
    int8 zeroes small second moments and Adam's 1/sqrt(v) explodes
    (bitsandbytes' dynamic-map rationale)."""
    s = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    xn = x / jnp.where(s == 0, 1.0, s)
    q = (jnp.round(jnp.sqrt(jnp.abs(xn)) * 127.0) * jnp.sign(xn)
         ).astype(jnp.int8)
    return {"q": q, "s": s[..., 0]}


def _dq8(t) -> jnp.ndarray:
    if isinstance(t, dict):
        c = t["q"].astype(jnp.float32) / 127.0
        return jnp.sign(c) * c * c * t["s"][..., None]
    return t


def _maybe_q8(x: jnp.ndarray, use: bool):
    # tiny leaves (norms, biases) stay fp32 — not worth the scale overhead
    return _q8(x) if use and x.ndim >= 2 else x


_IS_Q8 = lambda t: isinstance(t, dict) and set(t) == {"q", "s"}


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw_init(cfg: OptConfig, params):
    def zeros_state(p):
        z = jnp.zeros(p.shape, jnp.float32)
        return _maybe_q8(z, cfg.state_int8)

    state = {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(zeros_state, params),
        "v": jax.tree.map(zeros_state, params),
    }
    if cfg.compress_int8:
        state["err"] = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return state


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(cfg: OptConfig, grads, state, params):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9)) \
        if cfg.clip_norm else 1.0
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)
    if cfg.compress_int8:
        grads, new_err = compress_grads(grads, state["err"],
                                        cfg.compress_block)
    b1, b2 = cfg.betas
    lr = lr_at(cfg, step)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = b1 * _dq8(m) + (1 - b1) * g
        v = b2 * _dq8(v) + (1 - b2) * g * g
        mh, vh = m / bc1, v / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if cfg.weight_decay:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), \
            _maybe_q8(m, cfg.state_int8), _maybe_q8(v, cfg.state_int8)

    out = jax.tree.map(upd, params, grads, state["m"], state["v"],
                       is_leaf=_IS_Q8)
    is3 = lambda t: isinstance(t, tuple) and len(t) == 3
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=is3)
    new_state = {
        "step": step,
        "m": jax.tree.map(lambda t: t[1], out, is_leaf=is3),
        "v": jax.tree.map(lambda t: t[2], out, is_leaf=is3),
    }
    if cfg.compress_int8:
        new_state["err"] = new_err
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
