"""Latency / throughput proxies (paper §IV-A, RapidChiplet-style), in JAX.

Given a batch of ``ScoreGraph``s we compute, per placement and per traffic
type t in {C2C, C2M, C2I, M2I} (directed: C->C, C->M, C->I, M->I):

* ``lat_t``  — mean shortest-path latency [cycles] over (src, dst) chiplet
  pairs of the type, on the PHY-level graph (relay semantics encoded in the
  graph construction, see ``topology.py``).
* ``thr_t``  — sustainable per-source injection rate (fraction of theoretical
  peak, in [0, 1]): uniform-random traffic of the type is routed over all
  shortest paths with ECMP splitting (Brandes path-counting); the bottleneck
  link determines the saturation rate  alpha* = 1 / max_link_load.

The whole computation is expressed as a batched Floyd-Warshall with
shortest-path *counting* — each iteration is a rank-1 min-plus update — so it
vmaps over placements and runs on TPU.  A blocked variant whose inner update
is a Pallas min-plus matmul kernel can be swapped in via ``fw_impl`` (see
``repro.kernels``): this is the evaluation hot spot that dominates PlaceIT's
runtime (paper Table V).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from .chiplets import COMPUTE, IO, MEMORY, TRAFFIC_TYPES, ArchSpec
from .objective import (NORM_DIM, TRACE_TERMS, compile_objective, weight_dim,
                        weights_vec)

INF_CUT = 1.0e8   # entries >= this are treated as "unreachable"
_COUNT_CLIP = 1.0e30

# Per-chunk element budget for the scorer's dominant intermediates (~256 MB
# of float32 at 64M elements, times the chunk's vmap width before the
# clamp kicks in).  Chosen so every paper arch keeps its full default
# chunk (V <= ~450 -> clamp inactive) while 100+-chiplet archs
# (V in the hundreds-to-thousands) shrink gracefully.
_CHUNK_ELEM_BUDGET = 1 << 26


@dataclass(frozen=True)
class Layout:
    """Static (arch-level) node layout shared by every placement in a batch."""

    Vp: int
    kinds: tuple[int, ...]    # chiplet kind per instance

    @property
    def N(self) -> int:
        return len(self.kinds)

    def src_nodes(self, kind: int) -> np.ndarray:
        base = self.Vp
        return np.array([base + c for c, k in enumerate(self.kinds)
                         if k == kind], dtype=np.int32)

    def dst_nodes(self, kind: int) -> np.ndarray:
        base = self.Vp + self.N
        return np.array([base + c for c, k in enumerate(self.kinds)
                         if k == kind], dtype=np.int32)


def layout_for(arch: ArchSpec) -> Layout:
    Vp = sum(ch.n_phys() for ch in arch.chiplets)
    return Layout(Vp=Vp, kinds=arch.kinds())


# ---------------------------------------------------------------------------
# Floyd-Warshall with shortest-path counting (reference implementation).
# ---------------------------------------------------------------------------

def fw_counts_ref(W: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """All-pairs shortest paths + path counts.  W: [..., V, V] with 0 diag.

    Returns (D, Ncnt) of the same shape.  Correctness of the counting relies
    on every shortest path being decomposed uniquely at its highest-indexed
    intermediate vertex; rows/columns k are masked each iteration to avoid
    self-contributions through D[k, k] = 0.
    """
    V = W.shape[-1]
    D0 = W
    off = ~jnp.eye(V, dtype=bool)
    N0 = jnp.where((W < INF_CUT) & off, 1.0, 0.0) + jnp.eye(V, dtype=W.dtype)

    def body(k, carry):
        D, Ncnt = carry
        dik = jax.lax.dynamic_slice_in_dim(D, k, 1, axis=-1)      # [..., V, 1]
        dkj = jax.lax.dynamic_slice_in_dim(D, k, 1, axis=-2)      # [..., 1, V]
        nik = jax.lax.dynamic_slice_in_dim(Ncnt, k, 1, axis=-1)
        nkj = jax.lax.dynamic_slice_in_dim(Ncnt, k, 1, axis=-2)
        cand = dik + dkj
        ncand = jnp.minimum(nik * nkj, _COUNT_CLIP)
        notk = jnp.arange(V) != k
        mask = notk[:, None] & notk[None, :]
        lt = (cand < D) & mask
        eq = (cand == D) & mask & (cand < INF_CUT)
        D = jnp.where(lt, cand, D)
        Ncnt = jnp.where(lt, ncand, Ncnt + jnp.where(eq, ncand, 0.0))
        Ncnt = jnp.minimum(Ncnt, _COUNT_CLIP)
        return D, Ncnt

    return jax.lax.fori_loop(0, V, body, (D0, N0))


# ---------------------------------------------------------------------------
# Per-placement metric computation.
# ---------------------------------------------------------------------------

def _type_pairs(layout: Layout) -> dict:
    """Static (srcs, dsts, same_kind) node-index sets per traffic type."""
    ep = {
        "c2c": (COMPUTE, COMPUTE),
        "c2m": (COMPUTE, MEMORY),
        "c2i": (COMPUTE, IO),
        "m2i": (MEMORY, IO),
    }
    out = {}
    for t, (ks, kd) in ep.items():
        out[t] = (layout.src_nodes(ks), layout.dst_nodes(kd), ks == kd)
    return out


def _metrics_one(W, edges, edge_mask, area, *, pairs, conn, fw_impl,
                 dem_vec=None, trace_fn=None):
    """All nine cost components for a single placement (jit/vmap-able).

    With a packed demand operand ``dem_vec`` and a ``trace_fn`` (the netsim
    rate model bound to this layout), the output additionally carries the
    per-class ``trace_lat_{t}`` traffic metrics — computed from the same
    FW solve, so the traffic term costs no extra shortest-path pass."""
    D, Ncnt = fw_impl(W)
    eu, ev = edges[:, 0], edges[:, 1]
    w_e = W[eu, ev]
    out = {"area": area}
    # In-scorer connectivity (paper's validity check, derived from the FW
    # distance matrix instead of a host-side union-find): the placement is
    # connected iff every virtual source reaches every virtual sink.
    src_all, dst_all = conn
    out["connected"] = jnp.all(
        D[jnp.asarray(src_all)][:, jnp.asarray(dst_all)] < INF_CUT)
    for t, (srcs, dsts, same) in pairs.items():
        srcs = jnp.asarray(srcs)
        dsts = jnp.asarray(dsts)
        Dsd = D[srcs][:, dsts]                                   # [S, T]
        S, T = Dsd.shape
        if same:
            # Exclude the self pair (src chiplet == dst chiplet).  The node
            # sets enumerate the same chiplets in the same order.
            pair_ok = ~jnp.eye(S, dtype=bool)
        else:
            pair_ok = jnp.ones((S, T), dtype=bool)
        n_pairs = pair_ok.sum()
        lat = jnp.where(pair_ok, Dsd, 0.0).sum() / jnp.maximum(n_pairs, 1)
        # --- ECMP link loads (Brandes fractions) -------------------------
        dem = pair_ok.astype(W.dtype) / jnp.maximum(
            pair_ok.sum(axis=1, keepdims=True), 1)               # [S, T]
        Dsu = D[srcs][:, eu]                                     # [S, E]
        Dvd = D[ev][:, dsts]                                     # [E, T]
        Nsu = Ncnt[srcs][:, eu]
        Nvd = Ncnt[ev][:, dsts]
        Nsd = jnp.maximum(Ncnt[srcs][:, dsts], 1.0)
        on_sp = (
            jnp.abs(Dsu[:, :, None] + w_e[None, :, None] + Dvd[None, :, :]
                    - Dsd[:, None, :]) < 0.5
        ) & (Dsd[:, None, :] < INF_CUT)
        frac = Nsu[:, :, None] * Nvd[None, :, :] / Nsd[:, None, :]
        load = jnp.einsum("st,set->e",
                          dem, jnp.where(on_sp, frac, 0.0))
        load = jnp.where(edge_mask, load, 0.0)
        max_load = load.max()
        thr = jnp.where(max_load > 0, jnp.minimum(1.0, 1.0 / max_load), 1.0)
        out[f"lat_{t}"] = lat
        out[f"thr_{t}"] = thr
    if dem_vec is not None and trace_fn is not None:
        out.update(trace_fn(D, Ncnt, W, edges, edge_mask, dem_vec))
    return out


def make_scorer(layout: Layout, *, fw_impl=fw_counts_ref, chunk: int = 16,
                objective=None):
    """Build a jitted batched scorer: dict of stacked arrays -> metric dict.

    Placements are scored in chunks of ``chunk`` via ``lax.map`` to bound
    memory; within a chunk, everything is vmapped.

    Besides the nine cost metrics the output carries a ``connected`` bool
    per placement (virtual all-src -> all-sink reachability on the FW
    distance matrix) so batched optimizers can mask-and-resample invalid
    individuals without a host-side union-find pass.

    With an ``objective`` (an :class:`repro.core.objective.Objective`),
    the compiled cost terms are lowered into the same jitted call and the
    output gains a per-placement ``cost`` — no host-side cost formula.
    The normalizer values enter as the runtime ``norms`` argument (a
    ``[NORM_DIM]`` vector, or ``[P, NORM_DIM]`` for per-row normalizers in
    stacked cross-run scoring), so normalizer draws never retrace.  The
    objective *weights* likewise enter as the runtime ``weights`` argument
    (``[W_FIXED + n_terms]`` or per-row ``[P, ...]``, default the
    objective's own :func:`~repro.core.objective.weights_vec`), so Pareto
    weight grids and constraint-hardening schedules share one compiled
    scorer — only the term structure is trace-time.

    When the objective carries a trace term (``trace-lat`` /
    ``trace-thr``), the batch must also carry a ``_demand`` key
    (``[P, demand_dim(N)]`` packed workload rows, see
    :mod:`repro.netsim.workload`); the traffic rate model then runs
    fused on the same FW solve and the output gains per-class
    ``trace_lat_{t}`` / ``trace_thr_{t}`` metrics.  Demand is a runtime
    operand like norms and weights: different workloads/mixes never
    retrace.
    """
    pairs = _type_pairs(layout)
    conn = (layout.Vp + np.arange(layout.N, dtype=np.int32),
            layout.Vp + layout.N + np.arange(layout.N, dtype=np.int32))
    needs_demand = objective is not None and any(
        t.name in TRACE_TERMS for t in objective.terms)
    trace_fn = None
    if needs_demand:
        # Lazy import: repro.netsim.model imports this module for the FW
        # reference and INF_CUT; binding at build time keeps the traffic
        # model out of the import graph of proxy-only scorers.
        from repro.netsim.model import trace_metrics_one
        trace_fn = functools.partial(trace_metrics_one,
                                     srcs=conn[0], dsts=conn[1])
    one = functools.partial(_metrics_one, pairs=pairs, conn=conn,
                            fw_impl=fw_impl, trace_fn=trace_fn)
    pair_elems = max(len(s) * len(d) for s, d, _ in pairs.values())
    cobj = compile_objective(objective, layout) \
        if objective is not None else None
    Vp = layout.Vp
    if cobj is not None:
        WDIM = weight_dim(objective)
        default_w = weights_vec(objective)

    @jax.jit
    def score(batch, norms=None, weights=None):
        batch = dict(batch)
        P = batch["W"].shape[0]
        # Clamp the chunk so one vmapped chunk's dominant intermediates —
        # the [V, V] FW matrices and the [S, E, T] ECMP on-shortest-path
        # tensor — stay within a fixed element budget.  Shapes are static
        # under jit, so this is trace-time host math; results are
        # chunk-invariant, so the clamp never changes scores.  At paper
        # sizes (V <= ~450) the clamp is inactive (eff == chunk); in the
        # 100+-chiplet regime it shrinks the chunk instead of OOMing.
        V = batch["W"].shape[-1]
        E = batch["edges"].shape[1]
        per = max(V * V, pair_elems * E)
        if needs_demand:
            if "_demand" not in batch:
                raise ValueError(
                    "objective has a trace term (trace-lat/trace-thr) but "
                    "the batch carries no '_demand' workload operand; "
                    "score through an Evaluator built with a workload "
                    "(see repro.netsim.workload.Workload)")
            # The rate model's [N, E, N] ECMP tensor joins the budget.
            per = max(per, layout.N * layout.N * E)
        eff = max(1, min(chunk, _CHUNK_ELEM_BUDGET // per))
        if cobj is not None:
            if norms is None:
                norms = jnp.ones((NORM_DIM,), jnp.float32)
            batch["_norms"] = jnp.broadcast_to(
                jnp.asarray(norms, jnp.float32), (P, NORM_DIM))
            if weights is None:
                weights = default_w
            batch["_weights"] = jnp.broadcast_to(
                jnp.asarray(weights, jnp.float32), (P, WDIM))
        pad = (-P) % eff
        padded = {k: jnp.concatenate([v, jnp.repeat(v[:1], pad, axis=0)])
                  if pad else v for k, v in batch.items()}

        def score_chunk(c):
            extras = {k: c[k]
                      for k in ("edge_len", "_norms", "_weights", "_demand")
                      if k in c}

            def one_full(w, e, m, a, ex):
                out = one(w, e, m, a, dem_vec=ex.get("_demand"))
                if cobj is not None:
                    sample = dict(out, edges=e, edge_mask=m, area=a, Vp=Vp)
                    if "edge_len" in ex:
                        sample["edge_len"] = ex["edge_len"]
                    out["cost"] = cobj.cost_one(sample, ex["_norms"],
                                                ex["_weights"])
                return out

            return jax.vmap(one_full)(c["W"], c["edges"], c["edge_mask"],
                                      c["area"], extras)

        chunked = {k: v.reshape((-1, eff) + v.shape[1:])
                   for k, v in padded.items()}
        res = jax.lax.map(score_chunk, chunked)
        return {k: v.reshape(-1)[:P] for k, v in res.items()}

    return score


def make_ranker(scorer):
    """Fused in-scorer ranking: score a batch and select the ``k`` best
    placements (ascending cost) on device in one jitted call.  ``scorer``
    must have been built with an objective (it emits ``cost``).  Returns
    ``rank(batch, norms, k, valid, weights) -> (costs [k], indices [k])``;
    rows where ``valid`` is False (e.g. the hetero Borůvka-component
    connectivity rule, stricter than the scorer's FW reachability) rank
    last with infinite cost."""
    @functools.partial(jax.jit, static_argnames=("k",))
    def rank(batch, norms, k: int = 1, valid=None, weights=None):
        out = scorer(batch, norms, weights)
        cost = out["cost"]
        if valid is not None:
            cost = jnp.where(jnp.asarray(valid), cost, jnp.inf)
        neg, idx = jax.lax.top_k(-cost, k)
        return -neg, idx

    return rank


METRIC_KEYS = tuple(
    [f"lat_{t}" for t in TRAFFIC_TYPES]
    + [f"thr_{t}" for t in TRAFFIC_TYPES]
    + ["area"]
)
