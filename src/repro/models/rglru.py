"""Griffin recurrent block with RG-LRU (recurrentgemma-9b).

Structure (Griffin / recurrentgemma):
    x -> norm -> two branches:
      gate branch : linear(D, d_rnn) -> GeLU
      rec  branch : linear(D, d_rnn) -> causal conv(width 4) -> RG-LRU
    out = (rec * gate) @ out_proj

RG-LRU recurrence (per channel):
    r_t = sigmoid(W_a u_t + b_a)          recurrence gate
    i_t = sigmoid(W_i u_t + b_i)          input gate
    a_t = exp(-c * softplus(Lambda) * r_t)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * u_t)

The scan is the Pallas ``rglru_scan`` kernel on TPU (ref scan elsewhere).
State is (B, d_rnn) — constant in context length, so recurrentgemma runs
``long_500k``.  c = 8 (paper constant).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..kernels import ops
from ..sharding.partition import shard
from .config import LMConfig
from .layers import dense_init, rms_norm, rms_norm_init

_C = 8.0


def rglru_init(key, cfg: LMConfig) -> dict:
    ks = jax.random.split(key, 6)
    D, R = cfg.d_model, cfg.d_rnn_
    W = cfg.conv_width
    dt = jnp.dtype(cfg.dtype)
    # Lambda init so that a^c = sigmoid(Lambda)^c lies in (0.9, 0.999).
    u = jax.random.uniform(ks[0], (R,), jnp.float32, 0.9 ** (1 / _C),
                           0.999 ** (1 / _C))
    lam = jnp.log(u / (1.0 - u))
    return {
        "norm": rms_norm_init(D),
        "rg_in": dense_init(ks[1], D, R, dt),
        "rg_gate": dense_init(ks[2], D, R, dt),
        "rg_conv_w": (jax.random.normal(ks[3], (W, R), jnp.float32)
                      * (W ** -0.5)).astype(dt),
        "rg_conv_b": jnp.zeros((R,), dt),
        "rg_a": dense_init(ks[4], R, R, jnp.float32, scale=R ** -0.5),
        "rg_i": dense_init(ks[5], R, R, jnp.float32, scale=R ** -0.5),
        "rg_lambda": lam,
        "rg_out": dense_init(jax.random.fold_in(key, 7), R, D, dt),
    }


def _conv_causal(u, w, b, state=None):
    W = w.shape[0]
    if state is None:
        state = jnp.zeros((u.shape[0], W - 1, u.shape[2]), u.dtype)
    ext = jnp.concatenate([state, u], axis=1)
    y = sum(ext[:, i:i + u.shape[1]] * w[i][None, None] for i in range(W))
    return y + b[None, None], ext[:, -(W - 1):]


def _gates(p, u):
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(uf @ p["rg_a"])
    i = jax.nn.sigmoid(uf @ p["rg_i"])
    log_a = -_C * jax.nn.softplus(p["rg_lambda"])[None, None] * r
    a = jnp.exp(log_a)
    return a, (i * uf)


def rglru_train(p, x, cfg: LMConfig, *, return_cache: bool = False):
    B, S, D = x.shape
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    gate = jax.nn.gelu(h @ p["rg_gate"])
    u = shard(h @ p["rg_in"], "act_inner")
    u, conv_state = _conv_causal(u, p["rg_conv_w"], p["rg_conv_b"])
    a, xin = _gates(p, u)
    hs, hT = ops.rglru_scan(xin.astype(u.dtype), a.astype(u.dtype),
                            impl=cfg.attn_impl)
    y = hs.astype(x.dtype) * gate
    o = y @ p["rg_out"]
    out = x + shard(o, "act")
    if not return_cache:
        return out
    return out, {"conv": conv_state, "h": shard(hT, "state")}


def rglru_decode(p, x, cache, cfg: LMConfig, length):
    B = x.shape[0]
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    gate = jax.nn.gelu(h @ p["rg_gate"])
    u = h @ p["rg_in"]
    u, conv_state = _conv_causal(u, p["rg_conv_w"], p["rg_conv_b"],
                                 state=cache["conv"])
    a, xin = _gates(p, u)
    a0 = a[:, 0]
    hn = a0 * cache["h"] + jnp.sqrt(jnp.maximum(1 - a0 * a0, 0.0)) * xin[:, 0]
    y = hn[:, None].astype(x.dtype) * gate
    o = y @ p["rg_out"]
    return x + o, {"conv": conv_state, "h": hn}


def rglru_cache_init(cfg: LMConfig, B: int):
    return {
        "conv": jnp.zeros((B, cfg.conv_width - 1, cfg.d_rnn_),
                          jnp.dtype(cfg.dtype)),
        "h": jnp.zeros((B, cfg.d_rnn_), jnp.float32),
    }
