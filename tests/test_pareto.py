"""Pareto sweep engine + constraint-hardening schedules.

Acceptance pins:

* the front from the stacked device path is **bit-for-bit** the host
  brute-force dominance over the same cost matrix on all four paper
  archs;
* a schedule-ramped run reaches a feasible (cap-respecting) placement
  that the unramped run misses.

Plus: hand-computed dominance/hypervolume cases, grid expansion +
single-scorer stacking (the weights-are-runtime fast path), serde
round-trips for ``ParetoGridSpec`` / ``ParetoFront`` / ``SweepConfig`` /
``Schedule``, and registry error paths.
"""
import dataclasses

import numpy as np
import pytest

from repro.core.api import (Budget, ExperimentConfig, SweepConfig,
                            clear_scorer_cache, make_rep, run_experiment,
                            run_sweep)
from repro.core.chiplets import paper_arch
from repro.core.objective import (Objective, Ramp, Schedule, TermSpec,
                                  TrafficMix, compile_schedule,
                                  weights_vec)
from repro.core.pareto import (ParetoFront, ParetoGridSpec, hypervolume,
                               nondominated_mask, nondominated_mask_host,
                               run_pareto, run_pareto_sweep)
from repro.core.registries import SCHEDULE_RAMPS, register_schedule_ramp


def tiny_cfg(arch, **kw):
    base = dict(arch=arch, algorithms=("br",), budget=Budget(evals=4),
                norm_samples=3, chunk=4, params={"br": {"batch": 4}})
    base.update(kw)
    return ExperimentConfig(**base)


GRID = ParetoGridSpec(term_weights={"lat": (0.5, 2.0), "area": (0.5, 2.0)})


# ---------------------------------------------------------------------------
# Dominance + hypervolume primitives.
# ---------------------------------------------------------------------------

def test_dominance_hand_computed():
    Y = np.array([[1, 5], [2, 2], [5, 1], [3, 3], [1, 5]], np.float32)
    mask = nondominated_mask(Y)
    # (3,3) is dominated by (2,2); duplicates never dominate each other
    assert mask.tolist() == [True, True, True, False, True]
    assert np.array_equal(mask, nondominated_mask_host(Y))
    # single point and empty-dominance edge cases
    assert nondominated_mask(np.array([[1.0, 2.0]])).tolist() == [True]


def test_dominance_device_matches_host_on_random_matrices():
    rng = np.random.default_rng(0)
    for b, d in ((32, 2), (64, 3), (128, 4)):
        Y = (rng.random((b, d)) * 10).astype(np.float32)
        Y[rng.integers(0, b, b // 4)] = Y[rng.integers(0, b, b // 4)]
        assert np.array_equal(nondominated_mask(Y),
                              nondominated_mask_host(Y))


def test_hypervolume_hand_computed():
    # union of [1,6]x[5,6], [2,6]x[2,6], [5,6]x[1,6] = 18
    Y = np.array([[1, 5], [2, 2], [5, 1]], np.float64)
    assert hypervolume(Y, [6, 6]) == pytest.approx(18.0)
    assert hypervolume(Y, [6, 6], device=False) == pytest.approx(18.0)
    # 3D: two disjoint unit boxes against ref (2,2,2)
    Y3 = np.array([[1, 0, 0], [0, 1, 1]], np.float64)
    want = (1 * 2 * 2) + (2 * 1 * 1) - (1 * 1 * 1)
    assert hypervolume(Y3, [2, 2, 2]) == pytest.approx(want)
    # points beyond the reference contribute nothing
    assert hypervolume(np.array([[7.0, 7.0]]), [6, 6]) == 0.0
    assert hypervolume(np.zeros((0, 2)), [6, 6]) == 0.0


def test_hypervolume_2d_device_matches_host_recursion():
    rng = np.random.default_rng(1)
    for _ in range(5):
        Y = rng.random((12, 2)) * 4
        ref = [4.5, 4.5]
        assert hypervolume(Y, ref) == pytest.approx(
            hypervolume(Y, ref, device=False), rel=1e-6)


# ---------------------------------------------------------------------------
# Grid expansion.
# ---------------------------------------------------------------------------

def test_grid_points_share_structure_and_roundtrip():
    base = Objective()
    pts = GRID.points(base)
    assert len(pts) == GRID.n_points == 4
    assert len({obj.structure_key() for _, obj in pts}) == 1
    labels = [lab for lab, _ in pts]
    assert labels == ["area=0.5|lat=0.5", "area=0.5|lat=2",
                      "area=2|lat=0.5", "area=2|lat=2"]
    w = weights_vec(pts[0][1])
    assert w[9] == 0.5 and w[11] == 0.5        # lat + area term weights
    assert ParetoGridSpec.from_json(GRID.to_json()) == GRID
    with pytest.raises(ValueError, match="unknown objective term"):
        ParetoGridSpec(term_weights={"bogus": (1.0,)}).points(base)
    with pytest.raises(ValueError, match="empty weight axis"):
        ParetoGridSpec(term_weights={"lat": ()})
    with pytest.raises(ValueError, match="unknown ParetoGridSpec keys"):
        ParetoGridSpec.from_dict({"bogus": 1})


def test_grid_mix_axis():
    g = ParetoGridSpec(mixes=(TrafficMix(),
                              TrafficMix(lat=(1, 1, 1, 1),
                                         thr=(1, 1, 1, 1))))
    pts = g.points(Objective())
    assert g.n_points == len(pts) == 2
    assert pts[0][1].mix != pts[1][1].mix
    assert ParetoGridSpec.from_dict(g.to_dict()) == g


# ---------------------------------------------------------------------------
# Acceptance: stacked device front == host brute force, all four archs.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch_name",
                         ["homog32", "homog64", "hetero32", "hetero64"])
def test_front_device_bit_for_bit_vs_host_all_archs(arch_name):
    res = run_pareto_sweep(tiny_cfg(arch_name), GRID)
    (front,) = res.fronts
    Y = np.asarray(front.matrix, np.float32)
    assert Y.shape == (4, 3)                  # 4 grid points x 3 terms
    dev = nondominated_mask(Y)                # the mask the front used
    host = nondominated_mask_host(Y)          # brute-force reference
    assert np.array_equal(dev, host)
    assert len(front.points) == int(dev.sum()) >= 1
    assert front.term_names == ("lat", "inv-thr", "area")
    # provenance: every front point maps back to its expanded config,
    # scalarization and a valid placement
    rep = make_rep(paper_arch(arch_name, "baseline"), arch_name)
    for p in front.points:
        assert p.algorithm == "br"
        assert res.runs[p.cfg_index].config.objective == p.objective
        g = rep.score_graph(p.sol())
        assert g.connected
    assert front.hypervolume > 0
    # full round-trip of the record
    assert ParetoFront.from_json(front.to_json()).to_dict() \
        == front.to_dict()


def test_grid_sweep_shares_one_scorer_and_stacks():
    clear_scorer_cache()
    cfg = tiny_cfg("homog32", algorithms=("br", "ga"),
                   budget=Budget(evals=8),
                   params={"br": {"batch": 4},
                           "ga": {"population": 4, "elitism": 1,
                                  "tournament": 2}})
    res = run_pareto_sweep(cfg, GRID)
    # 4 scalarizations x 2 algorithms: one compiled scorer (weights are
    # runtime), one lockstep group, one shared normalizer draw
    assert res.stats.scorers_built == 1
    assert res.stats.stacked_groups == 1
    assert res.stats.evaluators_built == 1
    assert len(res.runs) == 4
    (front,) = res.fronts
    assert front.n_candidates == 8            # every (grid, algo) record
    # stacked grid results are bit-for-bit the per-point solo runs
    solo = run_experiment(res.runs[2].config)
    assert [r.result.best_cost for r in res.runs[2].records] \
        == [r.result.best_cost for r in solo]


def test_sweep_config_roundtrip_and_dispatch():
    sc = SweepConfig(configs=(tiny_cfg("homog32"),), pareto_grid=GRID)
    assert SweepConfig.from_json(sc.to_json()) == sc
    res = run_sweep(sc)
    assert res.fronts is not None and len(res.fronts) == 1
    assert res.fronts[0].n_candidates == 4
    with pytest.raises(ValueError, match="unknown SweepConfig keys"):
        SweepConfig.from_dict({"bogus": 1})
    # without a grid, SweepConfig is plain run_sweep
    plain = run_sweep(SweepConfig(configs=(tiny_cfg("homog32"),)))
    assert plain.fronts is None


# ---------------------------------------------------------------------------
# Schedules: serde, registry, ramp math.
# ---------------------------------------------------------------------------

def test_schedule_serde_and_ramp_math():
    s = Schedule(ramps={
        "link-length-cap": {"kind": "linear", "start": 0.0, "end": 1.0},
        "node-degree": Ramp("step", start=0.0, end=2.0,
                            params={"at": 0.25})})
    assert Schedule.from_json(s.to_json()) == s
    assert s.scales_at(0.0) == {"link-length-cap": 0.0, "node-degree": 0.0}
    assert s.scales_at(0.5) == {"link-length-cap": 0.5, "node-degree": 2.0}
    assert s.scales_at(1.0) == {"link-length-cap": 1.0, "node-degree": 2.0}
    cos = Ramp("cosine", start=0.0, end=1.0)
    assert cos.scale_at(0.0) == pytest.approx(0.0)
    assert cos.scale_at(0.5) == pytest.approx(0.5)
    assert cos.scale_at(1.0) == pytest.approx(1.0)
    assert cos.scale_at(2.0) == pytest.approx(1.0)    # progress clamps
    with pytest.raises(KeyError, match="unknown schedule ramp"):
        Ramp("bogus")
    with pytest.raises(ValueError, match="unknown Schedule keys"):
        Schedule.from_dict({"bogus": 1})
    assert {"linear", "cosine", "step"} <= set(SCHEDULE_RAMPS.names())


def test_custom_ramp_is_drop_in():
    if "test-quad" not in SCHEDULE_RAMPS:
        @register_schedule_ramp("test-quad")
        def _quad(t, start, end, params):
            return start + (end - start) * t * t

    r = Ramp("test-quad", start=0.0, end=4.0)
    assert r.scale_at(0.5) == pytest.approx(1.0)


def test_compiled_schedule_scales_term_slots_only():
    obj = Objective().with_terms(TermSpec("node-degree", weight=50.0,
                                          params={"max_degree": 1}))
    cs = compile_schedule(Schedule(ramps={
        "node-degree": {"kind": "linear", "start": 0.0, "end": 1.0}}), obj)
    base = weights_vec(obj)
    w0, w1 = cs.weights_at(0.0), cs.weights_at(1.0)
    assert w0[-1] == 0.0 and w1[-1] == 50.0
    np.testing.assert_array_equal(w0[:-1], base[:-1])  # others untouched
    with pytest.raises(ValueError, match="unknown objective term"):
        compile_schedule(Schedule(ramps={"bogus": {}}), obj)


def test_experiment_config_schedule_roundtrip():
    sched = Schedule(ramps={"area": {"kind": "cosine",
                                     "start": 0.5, "end": 1.0}})
    cfg = tiny_cfg("homog32", schedule=sched)
    assert ExperimentConfig.from_dict(cfg.to_dict()) == cfg
    assert ExperimentConfig.from_json(cfg.to_json()).schedule == sched
    # old serialized configs (no schedule key) load unchanged
    d = cfg.to_dict()
    del d["schedule"]
    assert ExperimentConfig.from_dict(d).schedule is None


def test_schedule_is_noop_free_when_absent():
    """No schedule -> byte-identical trajectories to the pre-schedule
    code path (the generators only tag requests when one is attached)."""
    cfg = tiny_cfg("homog32", algorithms=("sa",), budget=Budget(evals=8),
                   params={"sa": {"chains": 2}})
    a = run_experiment(cfg)[0]
    b = run_experiment(cfg)[0]
    assert a.result.best_cost == b.result.best_cost
    assert [(n, c) for _, n, c in a.result.history] \
        == [(n, c) for _, n, c in b.result.history]


# ---------------------------------------------------------------------------
# Acceptance: constraint hardening reaches feasibility the unramped run
# misses.
# ---------------------------------------------------------------------------

def _degree_overage(rep, sol, cap=1):
    g = rep.score_graph(sol)
    E = np.asarray(g.edges)[np.asarray(g.edge_mask)]
    deg = np.bincount(E[:, 0], minlength=rep.layout.Vp)
    return int(np.maximum(deg - cap, 0).sum())


def test_schedule_ramp_reaches_feasible_placement_unramped_misses():
    """hetero32, router-radix constraint (every PHY carries at most one
    D2D link): the ramped run (node-degree penalty hardened 0 -> full
    over the GA's generations) ends on a cap-respecting placement; the
    unramped run (paper objective, no hardening) ends cap-violating.
    Deterministic: fixed seeds, device PRNG streams."""
    pen = Objective().with_terms(TermSpec("node-degree", weight=50.0,
                                          params={"max_degree": 1}))
    sched = Schedule(ramps={"node-degree": {"kind": "linear",
                                            "start": 0.0, "end": 1.0}})
    base = dict(arch="hetero32", algorithms=("ga-batched",),
                budget=Budget(evals=80), norm_samples=6, chunk=4, seed=4,
                params={"ga-batched": {"population": 10, "elitism": 2,
                                       "tournament": 3}})
    rep = make_rep(paper_arch("hetero32", "baseline"), "hetero32")
    plain = run_experiment(ExperimentConfig(**base))[0]
    ramped = run_experiment(ExperimentConfig(**base, objective=pen,
                                             schedule=sched))[0]
    assert _degree_overage(rep, plain.result.best_sol) > 0
    assert _degree_overage(rep, ramped.result.best_sol) == 0
    # the final-weights re-rank recorded the hardened best in the history
    assert ramped.result.history[-1][2] == ramped.result.best_cost
    # hardening beats the constant-full-weight penalty on final cost:
    # the ramp explores through infeasible space early and still ends
    # feasible (both costs are comparable — same final weights)
    const = run_experiment(ExperimentConfig(**base, objective=pen))[0]
    assert _degree_overage(rep, const.result.best_sol) == 0
    assert ramped.result.best_cost <= const.result.best_cost
