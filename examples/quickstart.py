"""Quickstart: co-optimize a chiplet placement + ICI topology (the paper's
core loop) and compare it to the 2D-mesh baseline.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.baseline import MeshBaseline
from repro.core.chiplets import TYPE_NAMES, paper_arch
from repro.core.optimize import Evaluator, genetic_algorithm
from repro.core.placement_homog import HomogRep


def ascii_placement(types) -> str:
    glyph = {-1: " .", 0: " C", 1: " M", 2: " I"}
    return "\n".join("".join(glyph[int(t)] for t in row)
                     for row in types[::-1])


def main():
    arch = paper_arch("homog32", "baseline")   # 32C + 4M + 4I, 3x3mm
    rep = HomogRep(arch, R=8, C=5, mutation_mode="neighbor-one")
    rng = np.random.default_rng(0)

    print("== PlaceIT quickstart: homog32, GA, small budget ==")
    ev = Evaluator(rep, arch, rng=rng, norm_samples=32)
    res = genetic_algorithm(ev, rng, population=24, elitism=5, tournament=5,
                            max_generations=10)
    base_cost_graph = MeshBaseline(arch).build()[0]
    base = {k: float(v[0]) for k, v in ev.score([base_cost_graph]).items()}

    print(f"\noptimized placement (cost {res.best_cost:.3f}, "
          f"{res.n_evaluated} placements evaluated):")
    print(ascii_placement(res.best_sol[0]))
    print("\nmetric            placeit   2D-mesh   delta")
    for t in ("c2c", "c2m", "c2i", "m2i"):
        o, b = res.best_metrics[f"lat_{t}"], base[f"lat_{t}"]
        print(f"lat_{t} [cyc]     {o:8.1f}  {b:8.1f}  {100*(o/b-1):+6.1f}%")
    for t in ("c2c", "c2m", "c2i", "m2i"):
        o, b = res.best_metrics[f"thr_{t}"], base[f"thr_{t}"]
        print(f"thr_{t} [frac]    {o:8.3f}  {b:8.3f}  {100*(o/b-1):+6.1f}%")


if __name__ == "__main__":
    main()
