"""Cycle-level ICI packet simulator — "BookSim2-lite" (paper §VII-A).

This is the host-side *calibration oracle* of the layered netsim package:
the device-resident rate model (``repro.netsim.model``) is validated
against it on relative orderings (see ``tests/test_netsim.py``).

BookSim2 models input-queued VC routers with a four-stage pipeline and
wormhole flow control.  We reproduce the latency-relevant behaviour at the
granularity that the paper's comparisons need (relative latency/throughput of
PlaceIT topologies vs the 2D-mesh baseline):

* chiplet-level routers with a ``router_pipeline``-cycle pipeline per hop,
* wormhole serialization: a link is held for ``flits`` cycles per packet,
* D2D hop latency = 2*L_P + L_L (PHY out + wire + PHY in),
* relay surcharge L_R when a packet passes *through* a chiplet,
* shortest-path routing over the D2D latency graph (non-relay chiplets are
  not valid intermediates),
* dependency-driven injection (Netrace semantics): *authentic* mode injects
  a packet at max(trace cycle, dependency completion); *idealized* mode as
  soon as dependencies are done.

Deviations from BookSim2 (documented, DESIGN.md §3): no VC allocation
conflicts or credit stalls; contention is modeled at link occupancy
granularity.  We validate relative orderings, not absolute cycle counts.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field

import numpy as np

from repro.core.chiplets import COMPUTE, IO, MEMORY, ArchSpec
from repro.core.topology import PlacedPhys

ROUTER_PIPELINE = 4  # BookSim's 4-stage pipeline (§VII-A)


@dataclass
class ChipletNet:
    """Chiplet-level network extracted from a placement + D2D link list."""

    n: int                      # number of chiplets
    kinds: np.ndarray           # [n] int8
    relay: np.ndarray           # [n] bool
    adj: np.ndarray             # [n, n] float latency (inf if no link)
    next_hop: np.ndarray        # [n, n] int32 routing table (-1 unreachable)
    dist: np.ndarray            # [n, n] float total latency

    @staticmethod
    def from_links(arch: ArchSpec, geo: PlacedPhys,
                   links: list[tuple[int, int]]) -> "ChipletNet":
        n = geo.kinds.shape[0]
        inf = np.float64(np.inf)
        adj = np.full((n, n), inf)
        np.fill_diagonal(adj, 0.0)
        d2d = arch.latency.d2d_cost()
        for p, q in links:
            a, b = int(geo.owner[p]), int(geo.owner[q])
            if a != b:
                adj[a, b] = min(adj[a, b], d2d)
                adj[b, a] = min(adj[b, a], d2d)
        # Shortest paths where intermediate nodes must be relay-capable;
        # a relay hop costs L_R on top of the incident link latencies.
        dist = adj.copy()
        nxt = np.full((n, n), -1, dtype=np.int32)
        for i in range(n):
            for j in range(n):
                if i != j and np.isfinite(adj[i, j]):
                    nxt[i, j] = j
        lr = arch.latency.l_relay
        for k in range(n):
            if not geo.relay[k]:
                continue
            via = dist[:, k:k + 1] + lr + dist[k:k + 1, :]
            upd = via < dist
            np.fill_diagonal(upd, False)
            if upd.any():
                dist = np.where(upd, via, dist)
                nxt = np.where(upd, nxt[:, k:k + 1], nxt)
        return ChipletNet(n=n, kinds=geo.kinds, relay=geo.relay, adj=adj,
                          next_hop=nxt, dist=dist)

    def path(self, src: int, dst: int) -> list[int]:
        if self.next_hop[src, dst] < 0:
            raise ValueError(f"no route {src}->{dst}")
        out = [src]
        while out[-1] != dst:
            out.append(int(self.next_hop[out[-1], dst]))
            if len(out) > self.n + 1:  # pragma: no cover
                raise RuntimeError("routing loop")
        return out


@dataclass(frozen=True)
class Packet:
    """One trace packet.  Pure input data: simulation state (injection and
    completion times) lives in the simulator and in ``SimResult.times``,
    so the same packet list can be re-run under different modes or on
    different networks without carry-over."""

    pid: int
    src: int
    dst: int
    flits: int
    cycle: int                        # earliest injection cycle (trace time)
    deps: tuple[int, ...] = ()        # pids that must complete first


@dataclass
class SimResult:
    n_done: int
    avg_latency: float
    p99_latency: float
    makespan: float
    latencies: np.ndarray | None = field(repr=False, default=None)
    # pid -> (inject_t, finish_t) for every completed packet.
    times: dict[int, tuple[float, float]] | None = field(
        repr=False, default=None)


class NetSim:
    """Event-driven wormhole-lite simulator over a ChipletNet."""

    def __init__(self, net: ChipletNet, arch: ArchSpec):
        self.net = net
        self.arch = arch
        self.hop_lat = arch.latency.d2d_cost() + ROUTER_PIPELINE
        self.relay_lat = arch.latency.l_relay

    def run(self, packets: list[Packet], mode: str = "authentic",
            max_cycles: float = 1e12) -> SimResult:
        """Simulate all packets; returns latency stats.

        mode='authentic': inject at max(cycle, deps done).
        mode='idealized': inject as soon as deps are done (stress test).

        Input packets are never mutated; per-packet injection/finish
        times are reported in ``SimResult.times``.
        """
        assert mode in ("authentic", "idealized")
        by_pid = {p.pid: p for p in packets}
        children: dict[int, list[Packet]] = {}
        n_deps: dict[int, int] = {}
        for p in packets:
            live = [d for d in p.deps if d in by_pid]
            n_deps[p.pid] = len(live)
            for d in live:
                children.setdefault(d, []).append(p)
        link_free: dict[tuple[int, int], float] = {}
        # Event heap: (time, seq, packet)
        heap: list = []
        seq = 0
        for p in packets:
            if n_deps[p.pid] == 0:
                t = float(p.cycle) if mode == "authentic" else 0.0
                heapq.heappush(heap, (t, seq, p))
                seq += 1
        times: dict[int, tuple[float, float]] = {}
        while heap:
            t, _, p = heapq.heappop(heap)
            if t > max_cycles:
                break
            # Route the packet hop by hop, reserving links.
            path = self.net.path(p.src, p.dst)
            now = t
            for h in range(len(path) - 1):
                u, v = path[h], path[h + 1]
                free = link_free.get((u, v), 0.0)
                start = max(now, free)
                # Wormhole: header advances, link busy for `flits` cycles.
                link_free[(u, v)] = start + p.flits
                now = start + self.hop_lat
                if h + 1 < len(path) - 1:       # intermediate chiplet relays
                    now += self.relay_lat
            finish = now + p.flits - 1          # tail flit arrival
            times[p.pid] = (t, finish)
            for ch in children.get(p.pid, []):
                n_deps[ch.pid] -= 1
                if n_deps[ch.pid] == 0:
                    if mode == "authentic":
                        ti = max(float(ch.cycle), finish)
                    else:
                        ti = finish
                    heapq.heappush(heap, (ti, seq, ch))
                    seq += 1
        if not times:
            return SimResult(0, float("nan"), float("nan"), 0.0,
                             np.zeros(0), {})
        lat = np.array([f - i for i, f in times.values()])
        return SimResult(
            n_done=len(times),
            avg_latency=float(lat.mean()),
            p99_latency=float(np.percentile(lat, 99)),
            makespan=float(max(f for _, f in times.values())),
            latencies=lat,
            times=times,
        )


# ---------------------------------------------------------------------------
# Synthetic traffic (paper §VII-B): per-type uniform-random src/dst load.
# ---------------------------------------------------------------------------

def synthetic_packets(net: ChipletNet, traffic: str, rate: float,
                      n_cycles: int, rng: np.random.Generator,
                      data_flits: int = 9) -> list[Packet]:
    """Bernoulli injection per source chiplet at `rate` [packets/cycle].

    traffic in {c2c, c2m, c2i, m2i}; dst drawn uniformly from the dst kind.
    """
    kind_of = {"c": COMPUTE, "m": MEMORY, "i": IO}
    ks, kd = kind_of[traffic[0]], kind_of[traffic[2]]
    srcs = np.nonzero(net.kinds == ks)[0]
    dsts = np.nonzero(net.kinds == kd)[0]
    packets: list[Packet] = []
    pid = 0
    for s in srcs:
        n_inj = rng.binomial(n_cycles, min(rate, 1.0))
        cycles = np.sort(rng.integers(0, n_cycles, size=n_inj))
        for cyc in cycles:
            d = int(rng.choice(dsts))
            if d == int(s):
                continue
            packets.append(Packet(pid, int(s), d, data_flits, int(cyc)))
            pid += 1
    return packets


def latency_throughput_curve(net: ChipletNet, arch: ArchSpec, traffic: str,
                             rates: list[float], n_cycles: int = 2000,
                             seed: int = 0) -> list[tuple[float, float]]:
    """(rate, avg latency) samples; latency diverges past saturation.

    Each rate point draws its traffic from an independent deterministic
    stream seeded by ``(seed, rate index)``, so points are statistically
    independent of each other yet the whole curve is reproducible from
    ``seed`` alone.
    """
    sim = NetSim(net, arch)
    out = []
    for ri, r in enumerate(rates):
        rng = np.random.default_rng((seed, ri))
        pkts = synthetic_packets(net, traffic, r, n_cycles, rng)
        res = sim.run(pkts, mode="authentic")
        out.append((r, res.avg_latency))
    return out
