"""2D-mesh baseline architectures (paper §VII, Fig. 13).

The baseline is the de-facto standard 2.5D layout used by Simba, Dojo and
others: a regular grid of compute-chiplets in the center with memory- and
IO-chiplets distributed along the perimeter.  Links form a 2D mesh between
adjacent compute-chiplets; each memory/IO chiplet connects to its adjacent
compute chiplet (via its single PHY in the *baseline* chiplet configuration,
via the facing PHY in the *placeit* configuration).

The baseline is expressed through the same ``ScoreGraph`` interface as
optimized placements, so it is scored by the identical proxy/cost pipeline —
apples-to-apples with PlaceIT outputs (§VII-B..E) — and can be fed to the
packet-level simulator (``netsim.py``).
"""
from __future__ import annotations

import math

import numpy as np

from .chiplets import COMPUTE, IO, MEMORY, ArchSpec
from .proxies import Layout
from .topology import PlacedPhys, ScoreGraph, build_score_graph


def _grid_dims(n: int) -> tuple[int, int]:
    """Near-square grid (rows, cols) with rows*cols == n (or minimal cover)."""
    r = int(math.floor(math.sqrt(n)))
    while r > 1 and n % r != 0:
        r -= 1
    if r == 1:  # prime count: use minimal covering near-square grid
        r = int(math.floor(math.sqrt(n)))
        return r, int(math.ceil(n / r))
    return r, n // r


class MeshBaseline:
    """Constructs the §VII baseline placement + 2D-mesh ICI topology.

    Geometry: compute grid cells of pitch = max chiplet dimension; memory
    chiplets are split between the west and east flanks, IO chiplets between
    the south and north flanks, each facing (and linked to) the nearest
    compute chiplet.
    """

    def __init__(self, arch: ArchSpec):
        self.arch = arch
        kinds = arch.kinds()
        self.idx_c = [i for i, k in enumerate(kinds) if k == COMPUTE]
        self.idx_m = [i for i, k in enumerate(kinds) if k == MEMORY]
        self.idx_i = [i for i, k in enumerate(kinds) if k == IO]
        n = len(arch.chiplets)
        self._phy_base = np.zeros(n + 1, dtype=np.int64)
        for i, ch in enumerate(arch.chiplets):
            self._phy_base[i + 1] = self._phy_base[i] + ch.n_phys()
        self.R, self.C = _grid_dims(len(self.idx_c))
        # Grid pitch from the compute chiplets; flanks use their own widths
        # (a uniform max-chiplet pitch would inflate the baseline area and
        # flatter PlaceIT's area comparison, §VII-E).
        self.pitch = max(max(arch.chiplets[i].w, arch.chiplets[i].h)
                         for i in self.idx_c)
        self._flank_w = max((max(arch.chiplets[i].w, arch.chiplets[i].h)
                             for i in self.idx_m), default=0.0)
        self._flank_h = max((max(arch.chiplets[i].w, arch.chiplets[i].h)
                             for i in self.idx_i), default=0.0)

    # -- placement ---------------------------------------------------------
    def _positions(self) -> tuple[dict[int, tuple[float, float]], dict[int, int]]:
        """Chiplet-instance -> lower-left position [mm]; and -> rotation."""
        P = self.pitch
        pos: dict[int, tuple[float, float]] = {}
        rot: dict[int, int] = {}

        def center(inst: int, cx: float, cy: float):
            ch = self.arch.chiplets[inst]
            pos[inst] = (cx - ch.w / 2.0, cy - ch.h / 2.0)

        # Compute grid at the origin; flanks sit just outside it.
        for n_, inst in enumerate(self.idx_c):
            r, c = divmod(n_, self.C)
            center(inst, (c + 0.5) * P, (r + 0.5) * P)
            rot[inst] = 0
        # Memory chiplets: split W/E flank, evenly spread over rows.
        mw = self.idx_m[: (len(self.idx_m) + 1) // 2]
        me = self.idx_m[(len(self.idx_m) + 1) // 2:]
        for side, group in (("w", mw), ("e", me)):
            for j, inst in enumerate(group):
                row = int(round((j + 0.5) * self.R / max(len(group), 1) - 0.5))
                row = min(max(row, 0), self.R - 1)
                cx = (-self._flank_w / 2 if side == "w"
                      else self.C * P + self._flank_w / 2)
                center(inst, cx, (row + 0.5) * P)
                # Single-PHY chiplets: rotate so the PHY faces the grid.
                rot[inst] = self._facing_rotation(inst, side)
        # IO chiplets: split S/N flank, evenly spread over cols.
        is_ = self.idx_i[: (len(self.idx_i) + 1) // 2]
        in_ = self.idx_i[(len(self.idx_i) + 1) // 2:]
        for side, group in (("s", is_), ("n", in_)):
            for j, inst in enumerate(group):
                col = int(round((j + 0.5) * self.C / max(len(group), 1) - 0.5))
                col = min(max(col, 0), self.C - 1)
                cy = (-self._flank_h / 2 if side == "s"
                      else self.R * P + self._flank_h / 2)
                center(inst, (col + 0.5) * P, cy)
                rot[inst] = self._facing_rotation(inst, side)
        return pos, rot

    def _facing_rotation(self, inst: int, side: str) -> int:
        """Rotation that turns the chiplet's PHY centroid toward the grid."""
        ch = self.arch.chiplets[inst]
        if ch.n_phys() >= 4:
            return 0
        want = {"w": "e", "e": "w", "s": "n", "n": "s"}[side]
        best, best_score = 0, -1e9
        for r in ch.allowed_rotations() if ch.n_phys() == 1 else range(4):
            rc = ch.rotated(r)
            mx = float(np.mean([p[0] for p in rc.phys])) - rc.w / 2
            my = float(np.mean([p[1] for p in rc.phys])) - rc.h / 2
            score = {"e": mx, "w": -mx, "n": my, "s": -my}[want]
            if score > best_score:
                best, best_score = r, score
        return best

    # -- topology ------------------------------------------------------------
    def _closest_phys(self, rotated, pos, a: int, b: int) -> tuple[int, int, float]:
        """Globally-indexed closest PHY pair between chiplet instances a, b."""
        best = (-1, -1, 1e18)
        for ia, (xa, ya) in enumerate(rotated[a].phys):
            pa = (pos[a][0] + xa, pos[a][1] + ya)
            for ib, (xb, yb) in enumerate(rotated[b].phys):
                pb = (pos[b][0] + xb, pos[b][1] + yb)
                d = self.arch.dist(pa, pb)
                if d < best[2]:
                    best = (int(self._phy_base[a] + ia),
                            int(self._phy_base[b] + ib), d)
        return best

    def build(self) -> tuple[ScoreGraph, PlacedPhys, list[tuple[int, int]]]:
        pos, rot = self._positions()
        rotated = {i: self.arch.chiplets[i].rotated(rot[i])
                   for i in range(len(self.arch.chiplets))}
        # PHY geometry
        Vp = int(self._phy_base[-1])
        ppos = np.zeros((Vp, 2), dtype=np.float32)
        owner = np.zeros(Vp, dtype=np.int32)
        for i in range(len(self.arch.chiplets)):
            owner[self._phy_base[i]:self._phy_base[i + 1]] = i
            for li, (x, y) in enumerate(rotated[i].phys):
                ppos[self._phy_base[i] + li] = (pos[i][0] + x, pos[i][1] + y)
        xs = [pos[i][0] + rotated[i].w for i in pos]
        ys = [pos[i][1] + rotated[i].h for i in pos]
        x0 = [pos[i][0] for i in pos]
        y0 = [pos[i][1] for i in pos]
        area = float((max(xs) - min(x0)) * (max(ys) - min(y0)))
        geo = PlacedPhys(
            pos=ppos, owner=owner,
            relay=np.array([c.relay for c in self.arch.chiplets]),
            kinds=np.array(self.arch.kinds(), dtype=np.int8), area=area)
        # Mesh links between grid-adjacent compute chiplets (grid may have
        # empty tail slots when the compute count is prime).
        links: list[tuple[int, int]] = []
        flat = np.full(self.R * self.C, -1, dtype=np.int64)
        flat[:len(self.idx_c)] = self.idx_c
        grid = flat.reshape(self.R, self.C)
        for r in range(self.R):
            for c in range(self.C):
                if grid[r, c] < 0:
                    continue
                if c + 1 < self.C and grid[r, c + 1] >= 0:
                    p, q, _ = self._closest_phys(rotated, pos,
                                                 int(grid[r, c]),
                                                 int(grid[r, c + 1]))
                    links.append((p, q))
                if r + 1 < self.R and grid[r + 1, c] >= 0:
                    p, q, _ = self._closest_phys(rotated, pos,
                                                 int(grid[r, c]),
                                                 int(grid[r + 1, c]))
                    links.append((p, q))
        # Memory/IO chiplets: link to the nearest compute chiplet.
        for inst in self.idx_m + self.idx_i:
            best = None
            for cc in self.idx_c:
                p, q, d = self._closest_phys(rotated, pos, inst, cc)
                if best is None or d < best[2]:
                    best = (p, q, d)
            links.append((best[0], best[1]))
        e_max = 2 * max(len(links), Vp)
        g = build_score_graph(self.arch, geo, links, e_max, connected=True)
        return g, geo, links

    @property
    def layout(self) -> Layout:
        return Layout(Vp=int(self._phy_base[-1]), kinds=self.arch.kinds())


def baseline_graph(arch: ArchSpec) -> ScoreGraph:
    """Convenience: the baseline ScoreGraph for an architecture."""
    return MeshBaseline(arch).build()[0]
