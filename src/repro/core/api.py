"""Registry-driven experiment API (config-first, sweepable, pluggable).

The PlaceIT pipeline — placement representation -> topology inference ->
proxy scoring -> BR/GA/SA search (paper §II, §IV) — is exposed here as a
declarative, serializable API:

* :class:`ExperimentConfig` — one experiment (arch x chiplet config x
  algorithms x budget x seeds), round-trips to/from dict/JSON so sweeps
  can live in files and CLIs.
* :class:`Budget` — evaluation-count and/or wall-clock budget, shared by
  every optimizer.
* Typed per-algorithm hyper-parameters (:class:`BRParams`,
  :class:`GAParams`, :class:`SAParams`) that absorb the paper's
  Table III/IV values; new algorithms register via
  ``@register_optimizer(name, params_cls=...)`` with the uniform signature
  ``(evaluator, rng, budget, params) -> OptResult``.
* Named scorer backends (``"fw-ref"``, ``"fw-pallas"``) replacing the old
  ``fw_impl: Any`` hook; the Pallas min-plus kernel is one string away.
* :func:`run_experiment` — faithful re-implementation of the legacy
  ``Experiment.run`` loop (same seeds, same trajectories) on top of the
  registries.
* :func:`run_sweep` — many configs at once, sharing one ``Evaluator``
  (normalizers) per (arch, seed) and one *jitted scorer* per (layout,
  chunk, backend, objective) across the whole sweep, and folding SA
  repetitions into extra chains of a single batched call.  This is the
  fast path: no recompilation between repetitions or configs.
* ``objective:`` — a typed, serializable cost function
  (:class:`repro.core.objective.Objective`): traffic-mix weights,
  normalizer policy, registry-driven extra terms.  The compiled objective
  is lowered into the jitted scorer, so per-placement cost and top-k
  selection run on device (``Evaluator.topk``).

Per-algorithm RNG streams are derived with :func:`algo_seed` from a stable
CRC32 digest of the algorithm name — unlike Python's ``hash()``, this does
not vary with ``PYTHONHASHSEED``, so runs reproduce across processes.
"""
from __future__ import annotations

import dataclasses
import json
import time
import zlib
from dataclasses import dataclass, field
from typing import Any, Callable, Mapping

import numpy as np

from .baseline import MeshBaseline
from .cache import LRUCache
from .chiplets import (ARCH3D, LARGE_HOMOG, ArchSpec, paper_arch,
                       resolve_arch)
from .objective import Objective, Schedule, TrafficMix
from .optimize import (Evaluator, OptResult, best_random,
                       best_random_batched, best_random_batched_steps,
                       best_random_steps, drive_stacked, genetic_algorithm,
                       genetic_algorithm_batched,
                       genetic_algorithm_batched_steps,
                       genetic_algorithm_steps, simulated_annealing,
                       simulated_annealing_batched,
                       simulated_annealing_batched_steps,
                       simulated_annealing_steps)
from .placement_hetero import HeteroRep
from .placement_homog import HomogRep, hex_mask
from .proxies import fw_counts_ref, make_scorer
from .registries import (OPTIMIZERS, SCORER_BACKENDS, OptimizerEntry,
                         register_optimizer, register_scorer_backend,
                         resolve_backend)

# Paper §V-B grid sizes: R*C >= N with one spare row of slack.
GRID_DIMS = {32 + 4 + 4: (8, 5), 64 + 8 + 8: (10, 8)}

# 100+-chiplet (HexaMesh-regime) grids: (R, C, hex side or None).  hex127
# places 127 chiplets on the centered-hexagonal mask of side 7 (13x13
# grid, 127 allowed cells).
LARGE_GRIDS = {
    "homog100": (10, 10, None),
    "homog144": (12, 12, None),
    "homog256": (16, 16, None),
    "hex127": (13, 13, 7),
}


# ---------------------------------------------------------------------------
# Budget + typed per-algorithm hyper-parameters.
# ---------------------------------------------------------------------------

_DEFAULT_EVALS = object()          # sentinel: "300 unless seconds is given"


@dataclass(frozen=True)
class Budget:
    """Evaluation and/or wall-clock budget; at least one must be set.

    ``evals`` is per repetition (deterministic, CI-friendly); ``seconds``
    matches the paper's 3600 s wall budget.  When both are set the first
    one to expire stops the run.  ``Budget()`` means 300 evals;
    ``Budget(seconds=3600.0)`` means one hour with *no* eval cap (the
    default cap only applies when no wall budget is given).
    """

    evals: int | None = _DEFAULT_EVALS  # type: ignore[assignment]
    seconds: float | None = None

    def __post_init__(self):
        if self.evals is _DEFAULT_EVALS:
            object.__setattr__(
                self, "evals", None if self.seconds is not None else 300)
        if self.evals is None and self.seconds is None:
            raise ValueError("Budget needs evals and/or seconds")

    def scaled(self, k: int) -> "Budget":
        """Budget for ``k`` repetitions folded into one batched call."""
        return dataclasses.replace(
            self, evals=None if self.evals is None else self.evals * k)

    def to_dict(self) -> dict:
        return {"evals": self.evals, "seconds": self.seconds}

    @classmethod
    def from_dict(cls, d: Mapping) -> "Budget":
        return cls(evals=d.get("evals"), seconds=d.get("seconds"))


@dataclass(frozen=True)
class BRParams:
    """Best Random (§II-B1)."""

    batch: int = 32            # placements per vmapped scoring call


@dataclass(frozen=True)
class GAParams:
    """Genetic Algorithm (§II-B2; Table III/IV)."""

    population: int = 50
    elitism: int = 8
    tournament: int = 8
    p_mutation: float = 0.5


@dataclass(frozen=True)
class SAParams:
    """Simulated Annealing (§II-B3; Table III/IV + adaptive cooling).

    ``chains`` > 1 runs independent chains scored as one batch per step;
    optimizers whose params carry a ``chains`` field are eligible for
    repetition-folding in :func:`run_sweep`.
    """

    t0_temp: float = 35.0
    block_len: int = 50
    alpha: float = 1.0
    beta: float = 5.0
    chains: int = 1


# ---------------------------------------------------------------------------
# Optimizer registry entries: uniform (evaluator, rng, budget, params).
# ---------------------------------------------------------------------------

# Budget -> driver-kwargs mappings, shared by the registered entry points
# and the run_sweep step-generator factories (_br_steps/_ga_steps below) so
# the stacked and unstacked paths can never diverge.

def _br_kwargs(budget: Budget, params: BRParams) -> dict:
    return dict(max_evals=budget.evals, time_budget_s=budget.seconds,
                batch=params.batch)


def _ga_kwargs(budget: Budget, params: GAParams) -> dict:
    max_gen = (None if budget.evals is None
               else max(1, budget.evals // params.population))
    return dict(population=params.population, elitism=params.elitism,
                tournament=params.tournament, p_mutation=params.p_mutation,
                time_budget_s=budget.seconds, max_generations=max_gen)


@register_optimizer("br", params_cls=BRParams)
def _run_br(evaluator: Evaluator, rng: np.random.Generator, budget: Budget,
            params: BRParams) -> OptResult:
    return best_random(evaluator, rng, **_br_kwargs(budget, params))


@register_optimizer("ga", params_cls=GAParams)
def _run_ga(evaluator: Evaluator, rng: np.random.Generator, budget: Budget,
            params: GAParams) -> OptResult:
    return genetic_algorithm(evaluator, rng, **_ga_kwargs(budget, params))


def _sa_kwargs(budget: Budget, params: SAParams) -> dict:
    max_it = (None if budget.evals is None
              else max(1, budget.evals // params.chains))
    return dict(t0_temp=params.t0_temp, block_len=params.block_len,
                alpha=params.alpha, beta=params.beta, chains=params.chains,
                time_budget_s=budget.seconds, max_iters=max_it)


@register_optimizer("sa", params_cls=SAParams)
def _run_sa(evaluator: Evaluator, rng: np.random.Generator, budget: Budget,
            params: SAParams) -> OptResult:
    return simulated_annealing(evaluator, rng, **_sa_kwargs(budget, params))


# Device-resident variants (homogeneous grids only): whole generations /
# chain-blocks are produced as fused generate→graph→score device calls via
# optimize.DevicePipeline, with invalid individuals masked-and-resampled in
# batch.  Same typed params as their host-loop counterparts; paper defaults
# apply through the "-batched" suffix stripping in _base_params.

@register_optimizer("br-batched", params_cls=BRParams)
def _run_br_batched(evaluator: Evaluator, rng: np.random.Generator,
                    budget: Budget, params: BRParams) -> OptResult:
    return best_random_batched(evaluator, rng, max_evals=budget.evals,
                               time_budget_s=budget.seconds,
                               batch=params.batch)


def _ga_batched_kwargs(budget: Budget, params: GAParams) -> dict:
    # ga-batched scores elites once (population up front, then only the
    # population - elitism children per generation), so the evals->
    # generations conversion differs from the host GA's evals//population.
    per_gen = max(params.population - params.elitism, 1)
    max_gen = (None if budget.evals is None
               else max(1, (budget.evals - params.population) // per_gen))
    return dict(population=params.population, elitism=params.elitism,
                tournament=params.tournament, p_mutation=params.p_mutation,
                time_budget_s=budget.seconds, max_generations=max_gen)


@register_optimizer("ga-batched", params_cls=GAParams)
def _run_ga_batched(evaluator: Evaluator, rng: np.random.Generator,
                    budget: Budget, params: GAParams) -> OptResult:
    return genetic_algorithm_batched(evaluator, rng,
                                     **_ga_batched_kwargs(budget, params))


@register_optimizer("sa-batched", params_cls=SAParams)
def _run_sa_batched(evaluator: Evaluator, rng: np.random.Generator,
                    budget: Budget, params: SAParams) -> OptResult:
    return simulated_annealing_batched(evaluator, rng,
                                       **_sa_kwargs(budget, params))


# ---------------------------------------------------------------------------
# Scorer backends (the fw_impl seam; paper Table V hot spot).
# ---------------------------------------------------------------------------

@register_scorer_backend("fw-ref")
def _backend_fw_ref() -> Callable:
    """Pure-XLA Floyd-Warshall + path counts (the default)."""
    return fw_counts_ref


@register_scorer_backend("fw-pallas")
def _backend_fw_pallas() -> Callable:
    """Pallas VMEM-resident FW kernel (compiled on TPU, interpret on CPU).

    Imported lazily so missing/incompatible Pallas never blocks "fw-ref".
    """
    from repro.kernels.ops import fw_impl_pallas
    return fw_impl_pallas


@register_scorer_backend("fw-tiled")
def _backend_fw_tiled() -> Callable:
    """Size-dispatched Pallas FW: the VMEM-resident kernel while the
    padded V fits ``ops.FW_TILED_AUTO_V``, the blocked-tile three-phase
    kernel beyond (O(bt^2) per grid program — the 100+-chiplet regime).
    Both paths are bit-for-bit equal to "fw-ref"."""
    from repro.kernels.ops import fw_impl_tiled
    return fw_impl_tiled


# ---------------------------------------------------------------------------
# Paper Table III/IV defaults, typed.
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ArchDefaults:
    ga: GAParams
    sa: SAParams
    mutation_mode: str


PAPER_DEFAULTS: dict[tuple[str, int], ArchDefaults] = {
    ("homog", 32): ArchDefaults(
        ga=GAParams(population=200, elitism=30, tournament=30),
        sa=SAParams(t0_temp=40.0, block_len=250),
        mutation_mode="neighbor-one"),
    ("homog", 64): ArchDefaults(
        ga=GAParams(population=50, elitism=8, tournament=8),
        sa=SAParams(t0_temp=35.0, block_len=50),
        mutation_mode="neighbor-one"),
    ("hetero", 32): ArchDefaults(
        ga=GAParams(population=30, elitism=6, tournament=6),
        sa=SAParams(t0_temp=33.0, block_len=50),
        mutation_mode="any-one"),
    ("hetero", 64): ArchDefaults(
        ga=GAParams(population=20, elitism=5, tournament=5),
        sa=SAParams(t0_temp=28.0, block_len=45),
        mutation_mode="any-one"),
}


# Defaults for the 100+-chiplet families: GA/SA shapes from the paper's
# homog64 row (the closest calibrated point); population kept modest so a
# generation's scoring batch stays device-friendly at V in the hundreds.
LARGE_DEFAULTS = ArchDefaults(
    ga=GAParams(population=50, elitism=8, tournament=8),
    sa=SAParams(t0_temp=35.0, block_len=50),
    mutation_mode="neighbor-one")

# Defaults for the 3D / hierarchical families (repro.arch3d): homog64-row
# GA/SA shapes, slightly smaller population — the stacked grids are
# denser (every cell occupied), so generations converge in fewer, larger
# moves.
ARCH3D_DEFAULTS = ArchDefaults(
    ga=GAParams(population=32, elitism=6, tournament=6),
    sa=SAParams(t0_temp=35.0, block_len=50),
    mutation_mode="neighbor-one")


def arch_family(arch_name: str) -> tuple[str, int]:
    if arch_name in LARGE_GRIDS:
        # Large homog families ("hex127" has no "homog" prefix and no
        # 32/64 substring — the paper heuristics below would misfile it).
        n = sum(LARGE_HOMOG[arch_name])
        return "homog", n
    if arch_name in ARCH3D:
        # 3D/hierarchical families ("stack3d32" contains "32" but is not
        # a paper arch; keyed before the heuristics).
        return "arch3d", sum(ARCH3D[arch_name])
    fam = "homog" if arch_name.startswith("homog") else "hetero"
    size = 32 if "32" in arch_name else 64
    return fam, size


def paper_defaults(arch_name: str) -> ArchDefaults:
    if arch_name in LARGE_GRIDS:
        return LARGE_DEFAULTS
    if arch_name in ARCH3D:
        return ARCH3D_DEFAULTS
    return PAPER_DEFAULTS[arch_family(arch_name)]


def algo_seed(seed: int, repetition: int, algo: str) -> int:
    """Stable per-(repetition, algorithm) RNG stream — CRC32, not hash(),
    so the stream survives PYTHONHASHSEED / process changes."""
    return seed + 1000 * repetition + zlib.crc32(algo.encode()) % 997


def make_rep(arch: ArchSpec, arch_name: str,
             mutation_mode: str | None = None):
    """Placement representation for a named architecture (§V-A / §VI-A,
    plus the LARGE_GRIDS 100+-chiplet families)."""
    fam, _ = arch_family(arch_name)
    mode = mutation_mode or paper_defaults(arch_name).mutation_mode
    if fam == "arch3d":
        # Lazy import: core must not depend on the arch3d package at
        # import time (arch3d imports core.topology/proxies).
        from repro.arch3d.families import make_rep3d
        return make_rep3d(arch, arch_name, mutation_mode=mode)
    if fam == "homog":
        if arch_name in LARGE_GRIDS:
            R, C, hex_side = LARGE_GRIDS[arch_name]
            allowed = None if hex_side is None else hex_mask(hex_side)
            return HomogRep(arch, R=R, C=C, mutation_mode=mode,
                            allowed=allowed)
        n = len(arch.chiplets)
        R, C = GRID_DIMS.get(n, (int(np.ceil(np.sqrt(n))),) * 2)
        return HomogRep(arch, R=R, C=C, mutation_mode=mode)
    return HeteroRep(arch, mutation_mode=mode)


# ---------------------------------------------------------------------------
# Jitted-scorer cache: one compilation per (layout, chunk, backend,
# objective *structure*) — bounded LRU so a long-lived service (the
# design engine serves many tenants' structures) cannot leak compiled
# executables.  Evictions are counted and surfaced through
# scorer_cache_stats() / SweepStats.scorer_evictions.
# ---------------------------------------------------------------------------

SCORER_CACHE_CAPACITY = 64

_SCORER_CACHE: LRUCache = LRUCache(SCORER_CACHE_CAPACITY)
_SCORER_STATS = {"hits": 0, "misses": 0}


def get_scorer(layout, *, chunk: int, backend: str,
               objective: Objective | None = None,
               shape_key=None) -> Callable:
    """Cached jitted batched scorer (with the compiled objective lowered
    in).  Two Evaluators over the same layout (e.g. sweep repetitions, or
    configs differing only in budget/seed) share one compiled function
    instead of re-tracing; normalizers and objective *weights* are runtime
    arguments, so different normalizer draws — and objectives differing
    only in traffic-mix / area / term weights, e.g. the scalarizations of
    a Pareto grid — share too.  Only the term structure
    (:meth:`Objective.structure_key`: names + params) forces a new
    compilation.  Callers must pass their weights at call time
    (``Evaluator`` always does); the baked-in defaults belong to whichever
    objective compiled first.

    ``shape_key`` splits the cache for representations whose graph-array
    shapes are not determined by the layout alone: 3D families over the
    same chiplet set (``repro.arch3d``, e.g. stack3d32 vs torus3d32)
    share a ``Layout`` but emit different edge-slot counts, and
    ``run_sweep`` groups lockstep-stacked runs by scorer identity — a
    shared callable would concatenate unlike batches."""
    objective = objective if objective is not None else Objective()
    key = (layout, chunk, backend, objective.structure_key(), shape_key)
    hit = key in _SCORER_CACHE
    _SCORER_STATS["hits" if hit else "misses"] += 1
    if not hit:
        _SCORER_CACHE[key] = make_scorer(
            layout, chunk=chunk, fw_impl=resolve_backend(backend),
            objective=objective)
    return _SCORER_CACHE[key]


def scorer_cache_stats() -> dict:
    return dict(_SCORER_STATS, evictions=_SCORER_CACHE.evictions,
                size=len(_SCORER_CACHE),
                capacity=_SCORER_CACHE.capacity)


def set_scorer_cache_capacity(n: int) -> None:
    """Bound the compiled-scorer LRU (evicting down if needed)."""
    _SCORER_CACHE.set_capacity(n)


def clear_scorer_cache() -> None:
    _SCORER_CACHE.clear()
    _SCORER_CACHE.evictions = 0
    _SCORER_STATS.update(hits=0, misses=0)


def clear_pipeline_cache() -> None:
    """Drop the device pipeline's cached jitted produce→graph stages
    (per-grid static W matrices included); the scorer cache is separate."""
    from .optimize import DevicePipeline
    DevicePipeline.clear_stage_cache()


def make_evaluator(rep, arch: ArchSpec, *, rng: np.random.Generator,
                   norm_samples: int, chunk: int = 16,
                   backend: str = "fw-ref", fw_impl=None,
                   objective: Objective | None = None,
                   schedule: Schedule | None = None,
                   norm=None, archive_k: int = 0,
                   workload=None) -> Evaluator:
    """Evaluator wired to a named backend; raw ``fw_impl`` callables (the
    legacy hook) bypass the cache.  ``objective`` defaults to the default
    ``Objective`` built from the arch's (deprecated) ``w_*`` weights —
    i.e. the paper formula for paper archs.  ``schedule`` attaches
    constraint-hardening weight ramps; ``norm`` re-uses an existing
    normalizer draw (see :class:`repro.core.optimize.Evaluator`);
    ``archive_k`` > 0 attaches a device-resident top-K population archive
    (:class:`repro.core.optimize.PopArchive`); ``workload`` (a
    :class:`repro.netsim.workload.Workload`) backs a ``trace-lat``
    objective term — it is a runtime scorer operand, so it does not enter
    the compiled-scorer cache key."""
    objective = (objective if objective is not None
                 else Objective.from_arch(arch))
    if fw_impl is not None:
        return Evaluator(rep, arch, rng=rng, norm_samples=norm_samples,
                         chunk=chunk, fw_impl=fw_impl, objective=objective,
                         schedule=schedule, norm=norm, archive_k=archive_k,
                         workload=workload)
    scorer = get_scorer(rep.layout, chunk=chunk, backend=backend,
                        objective=objective,
                        shape_key=getattr(rep, "scorer_shape_key", None))
    return Evaluator(rep, arch, rng=rng, norm_samples=norm_samples,
                     chunk=chunk, scorer=scorer, objective=objective,
                     schedule=schedule, norm=norm, archive_k=archive_k,
                     workload=workload)


# ---------------------------------------------------------------------------
# ExperimentConfig: declarative, serializable.
# ---------------------------------------------------------------------------

@dataclass(frozen=True, eq=True)
class ExperimentConfig:
    """One experiment: architecture x chiplet config x algorithms.

    ``params`` holds per-algorithm overrides (typed dataclasses or plain
    dicts); anything unspecified falls back to the paper's Table III/IV
    defaults for the architecture.  Round-trips via to/from_dict/json.
    """

    arch: str                              # homog32|homog64|hetero32|hetero64
    config: str = "baseline"               # baseline | placeit (§VII)
    algorithms: tuple[str, ...] = ("br", "ga", "sa")
    repetitions: int = 1
    budget: Budget = field(default_factory=Budget)
    norm_samples: int = 100                # paper: 500
    seed: int = 0
    backend: str = "fw-ref"
    chunk: int = 16
    mutation_mode: str | None = None       # None -> paper default
    params: dict = field(default_factory=dict)
    # Cost function (repro.core.objective); the default reproduces the
    # paper formula bit-for-bit, so old serialized configs load unchanged.
    objective: Objective = field(default_factory=Objective)
    # Constraint-hardening weight ramps over each run's progress
    # (repro.core.objective.Schedule); None = static weights.
    schedule: Schedule | None = None
    # > 0 keeps a device-resident top-K archive of every evaluated
    # (cost, placement) row (repro.core.optimize.PopArchive) — thickens
    # Pareto fronts at no extra search cost.  0 = off (legacy behavior).
    archive_k: int = 0
    # Traffic workload (repro.netsim.workload.Workload, or its dict form)
    # backing a `trace-lat` objective term; None for proxy-only search.
    workload: object | None = None

    def __post_init__(self):
        object.__setattr__(self, "algorithms", tuple(self.algorithms))
        if not isinstance(self.objective, Objective):
            object.__setattr__(self, "objective",
                              Objective.from_dict(self.objective))
        if self.workload is not None and isinstance(self.workload, Mapping):
            from repro.netsim.workload import Workload
            object.__setattr__(self, "workload",
                              Workload.from_dict(self.workload))
        if self.schedule is not None and \
                not isinstance(self.schedule, Schedule):
            object.__setattr__(self, "schedule",
                              Schedule.from_dict(self.schedule))
        # Normalize overrides to typed params (validates algo names too).
        norm = {}
        for algo, ov in self.params.items():
            entry: OptimizerEntry = OPTIMIZERS.get(algo)
            if isinstance(ov, entry.params_cls):
                norm[algo] = ov
            else:
                norm[algo] = dataclasses.replace(
                    self._base_params(algo, entry), **dict(ov))
        object.__setattr__(self, "params", norm)

    def _base_params(self, algo: str, entry: OptimizerEntry):
        try:
            d = paper_defaults(self.arch)
        except KeyError:
            d = None
        # "-batched" variants inherit their host-loop counterpart's paper
        # defaults (same search hyper-parameters, different execution).
        base = algo[:-len("-batched")] if algo.endswith("-batched") else algo
        if d is not None and isinstance(getattr(d, base, None),
                                        entry.params_cls):
            return getattr(d, base)
        return entry.params_cls()

    def resolved_params(self, algo: str):
        """Paper defaults for this arch, overridden by ``self.params``."""
        if algo in self.params:
            return self.params[algo]
        return self._base_params(algo, OPTIMIZERS.get(algo))

    # -- serialization ----------------------------------------------------
    def to_dict(self) -> dict:
        return {
            "arch": self.arch, "config": self.config,
            "algorithms": list(self.algorithms),
            "repetitions": self.repetitions,
            "budget": self.budget.to_dict(),
            "norm_samples": self.norm_samples, "seed": self.seed,
            "backend": self.backend, "chunk": self.chunk,
            "mutation_mode": self.mutation_mode,
            "params": {a: dataclasses.asdict(p)
                       for a, p in self.params.items()},
            "objective": self.objective.to_dict(),
            "schedule": (None if self.schedule is None
                         else self.schedule.to_dict()),
            "archive_k": self.archive_k,
            "workload": (None if self.workload is None
                         else self.workload.to_dict()),
        }

    @classmethod
    def from_dict(cls, d: Mapping) -> "ExperimentConfig":
        d = dict(d)
        unknown = set(d) - {f.name for f in dataclasses.fields(cls)}
        if unknown:
            raise ValueError(f"unknown ExperimentConfig keys: "
                             f"{sorted(unknown)}")
        if isinstance(d.get("budget"), Mapping):
            d["budget"] = Budget.from_dict(d["budget"])
        if "algorithms" in d:
            d["algorithms"] = tuple(d["algorithms"])
        return cls(**d)

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=1)

    @classmethod
    def from_json(cls, s: str) -> "ExperimentConfig":
        return cls.from_dict(json.loads(s))

    def __eq__(self, other):
        if not isinstance(other, ExperimentConfig):
            return NotImplemented
        return self.to_dict() == other.to_dict()

    def __hash__(self):
        # The generated field-tuple hash would choke on the params dict;
        # hash the canonical serialized form instead (consistent with
        # __eq__, insensitive to params insertion order).  Workloads hash
        # by content digest instead of their full [K, n, n] rate payload.
        d = self.to_dict()
        if d.get("workload") is not None:
            d["workload"] = self.workload.digest()
        return hash(json.dumps(d, sort_keys=True))


# ---------------------------------------------------------------------------
# run_experiment: the legacy Experiment.run loop over the registries.
# ---------------------------------------------------------------------------

@dataclass
class RunRecord:
    arch: str
    config: str
    algorithm: str
    repetition: int
    result: OptResult
    seconds: float
    # Traffic types whose cost normalizer fell back to 1.0 because every
    # norm sample was disconnected (see cost.CostNormalizers.degenerate);
    # non-empty means the run's costs are partially unnormalized.
    degenerate_norms: tuple = ()


def run_experiment(config: ExperimentConfig, *, fw_impl=None
                   ) -> list[RunRecord]:
    """Run every (repetition x algorithm) of one config.

    Reproduces the legacy ``Experiment.run`` loop structure exactly: one
    fresh Evaluator (and normalizer draw) per repetition, one RNG stream
    per algorithm.  The only deliberate difference is the per-algorithm
    stream derivation (:func:`algo_seed`'s CRC32 instead of the old
    ``hash()``, which varied with PYTHONHASHSEED), so results reproduce
    across processes but differ from pre-API saved runs.  ``fw_impl`` is
    the legacy raw-callable hook; prefer ``config.backend``.
    """
    arch = resolve_arch(config.arch, config.config)
    entries = [OPTIMIZERS.get(a) for a in config.algorithms]   # fail fast
    records: list[RunRecord] = []
    for rep_i in range(config.repetitions):
        rng = np.random.default_rng(config.seed + 1000 * rep_i)
        rep = make_rep(arch, config.arch, config.mutation_mode)
        ev = make_evaluator(rep, arch, rng=rng,
                            norm_samples=config.norm_samples,
                            chunk=config.chunk, backend=config.backend,
                            fw_impl=fw_impl, objective=config.objective,
                            schedule=config.schedule,
                            archive_k=config.archive_k,
                            workload=config.workload)
        for entry in entries:
            t0 = time.monotonic()
            rng_a = np.random.default_rng(
                algo_seed(config.seed, rep_i, entry.name))
            res = entry.fn(ev, rng_a, config.budget,
                           config.resolved_params(entry.name))
            records.append(RunRecord(config.arch, config.config, entry.name,
                                     rep_i, res, time.monotonic() - t0,
                                     degenerate_norms=ev.degenerate_norms))
    return records


def baseline_cost(config: ExperimentConfig, *, fw_impl=None
                  ) -> tuple[float, dict]:
    """2D-mesh baseline scored with the same normalizers (§VII)."""
    arch = resolve_arch(config.arch, config.config)
    rng = np.random.default_rng(config.seed)
    rep = make_rep(arch, config.arch, config.mutation_mode)
    ev = make_evaluator(rep, arch, rng=rng,
                        norm_samples=config.norm_samples,
                        chunk=config.chunk, backend=config.backend,
                        fw_impl=fw_impl, objective=config.objective,
                        workload=config.workload)
    g = MeshBaseline(arch).build()[0]
    metrics = ev.score([g])
    cost = float(np.asarray(ev.costs_from(metrics))[0])
    return cost, {k: float(v[0]) for k, v in metrics.items()}


# ---------------------------------------------------------------------------
# run_sweep: batched multi-config execution.
# ---------------------------------------------------------------------------

@dataclass
class SweepRun:
    config: ExperimentConfig
    records: list[RunRecord]


@dataclass
class SweepStats:
    scorers_built: int         # jit compilations triggered by this sweep
    evaluators_built: int      # normalizer draws (shared across reps)
    n_evaluated: int
    seconds: float
    score_calls: int = 0       # scorer dispatches across the whole sweep
    stacked_groups: int = 0    # lockstep groups with >= 2 runs
    scorer_evictions: int = 0  # compiled scorers dropped by the LRU
    shard_devices: int = 1     # devices the population axis was split over


@dataclass
class SweepResult:
    runs: list[SweepRun]
    stats: SweepStats
    # Per base-config Pareto fronts (repro.core.pareto.ParetoFront) when
    # the sweep was launched from a SweepConfig with a pareto_grid.
    fronts: list | None = None

    @property
    def records(self) -> list[RunRecord]:
        return [r for run in self.runs for r in run.records]


@dataclass(frozen=True)
class SweepConfig:
    """A whole sweep as one serializable value.

    ``configs`` are the base experiments.  With a ``pareto_grid``
    (:class:`repro.core.pareto.ParetoGridSpec`), each base config is
    expanded into one config per grid scalarization (same term structure,
    different runtime weights — they share one jitted scorer and stack in
    lockstep), and ``run_sweep`` attaches one
    :class:`repro.core.pareto.ParetoFront` per base config to
    ``SweepResult.fronts``.
    """

    configs: tuple = ()
    pareto_grid: object | None = None      # pareto.ParetoGridSpec
    fold_repetitions: bool = True
    stack_scoring: bool = True
    shard: bool = False                    # shard_map over the pop axis

    def __post_init__(self):
        object.__setattr__(self, "configs", tuple(
            c if isinstance(c, ExperimentConfig)
            else ExperimentConfig.from_dict(c) for c in self.configs))
        if self.pareto_grid is not None:
            from .pareto import ParetoGridSpec
            if not isinstance(self.pareto_grid, ParetoGridSpec):
                object.__setattr__(self, "pareto_grid",
                                  ParetoGridSpec.from_dict(self.pareto_grid))

    def to_dict(self) -> dict:
        return {"configs": [c.to_dict() for c in self.configs],
                "pareto_grid": (None if self.pareto_grid is None
                                else self.pareto_grid.to_dict()),
                "fold_repetitions": self.fold_repetitions,
                "stack_scoring": self.stack_scoring,
                "shard": self.shard}

    @classmethod
    def from_dict(cls, d: Mapping) -> "SweepConfig":
        unknown = set(d) - {f.name for f in dataclasses.fields(cls)}
        if unknown:
            raise ValueError(f"unknown SweepConfig keys: {sorted(unknown)}")
        return cls(**dict(d))

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=1)

    @classmethod
    def from_json(cls, s: str) -> "SweepConfig":
        return cls.from_dict(json.loads(s))


# Step-generator factories for optimizers that support lockstep stacked
# scoring in run_sweep: same Budget -> kwargs mapping as the registered
# entry points (shared helpers above), different executor.

def _br_steps(ev, rng, budget: Budget, params: BRParams):
    return best_random_steps(ev, rng, **_br_kwargs(budget, params))


def _ga_steps(ev, rng, budget: Budget, params: GAParams):
    return genetic_algorithm_steps(ev, rng, **_ga_kwargs(budget, params))


def _sa_steps(ev, rng, budget: Budget, params: SAParams):
    return simulated_annealing_steps(ev, rng, **_sa_kwargs(budget, params))


def _br_batched_steps(ev, rng, budget: Budget, params: BRParams):
    return best_random_batched_steps(ev, rng, **_br_kwargs(budget, params))


def _ga_batched_steps(ev, rng, budget: Budget, params: GAParams):
    return genetic_algorithm_batched_steps(
        ev, rng, **_ga_batched_kwargs(budget, params))


def _sa_batched_steps(ev, rng, budget: Budget, params: SAParams):
    return simulated_annealing_batched_steps(
        ev, rng, **_sa_kwargs(budget, params))


# Every optimizer is a step generator now, so the whole family stacks —
# including SA (host chains) and the device-resident *-batched drivers
# (their requests are pre-stacked device batches).  ROADMAP item closed.
_SWEEP_STACKABLE = {
    "br": _br_steps, "ga": _ga_steps, "sa": _sa_steps,
    "br-batched": _br_batched_steps, "ga-batched": _ga_batched_steps,
    "sa-batched": _sa_batched_steps,
}


def stackable_steps(algo: str):
    """Step-generator factory ``(ev, rng, budget, params) -> generator``
    for a lockstep-stackable optimizer, or ``None`` if ``algo`` only runs
    synchronously.  Public seam for the design service (serve.design)."""
    return _SWEEP_STACKABLE.get(algo)


# ---------------------------------------------------------------------------
# Design-service request/response schema (engine: repro.serve.design).
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class DesignRequest:
    """One tenant's placement-design request.

    ``config`` is a normal :class:`ExperimentConfig`; with a
    ``pareto_grid`` (:class:`repro.core.pareto.ParetoGridSpec`) it is
    expanded into one run per grid scalarization and the streamed/final
    results carry a Pareto front.  ``timeout_s`` is wall time measured
    from admission; the engine resolves the request as ``"timeout"`` when
    it expires mid-run.  Round-trips via to/from_dict.
    """

    config: ExperimentConfig
    request_id: str = ""
    pareto_grid: object | None = None      # pareto.ParetoGridSpec
    timeout_s: float | None = None

    def __post_init__(self):
        if not isinstance(self.config, ExperimentConfig):
            object.__setattr__(self, "config",
                              ExperimentConfig.from_dict(self.config))
        if self.pareto_grid is not None:
            from .pareto import ParetoGridSpec
            if not isinstance(self.pareto_grid, ParetoGridSpec):
                object.__setattr__(self, "pareto_grid",
                                  ParetoGridSpec.from_dict(self.pareto_grid))

    def to_dict(self) -> dict:
        return {"config": self.config.to_dict(),
                "request_id": self.request_id,
                "pareto_grid": (None if self.pareto_grid is None
                                else self.pareto_grid.to_dict()),
                "timeout_s": self.timeout_s}

    @classmethod
    def from_dict(cls, d: Mapping) -> "DesignRequest":
        unknown = set(d) - {f.name for f in dataclasses.fields(cls)}
        if unknown:
            raise ValueError(f"unknown DesignRequest keys: "
                             f"{sorted(unknown)}")
        return cls(**dict(d))


@dataclass
class DesignUpdate:
    """One streamed increment for a request.

    ``kind`` is ``"progress"`` (a generation/round completed; carries the
    best-so-far cost), ``"front"`` (partial Pareto front recomputed),
    or a terminal ``"done"`` / ``"cancelled"`` / ``"timeout"`` /
    ``"error"``.
    """

    request_id: str
    kind: str
    tick: int = 0                 # engine tick the update was emitted on
    generation: int = 0           # scoring rounds completed for the request
    best_cost: float | None = None
    front: object | None = None   # pareto.ParetoFront (kind="front")
    error: str | None = None

    def to_dict(self) -> dict:
        return {"request_id": self.request_id, "kind": self.kind,
                "tick": self.tick, "generation": self.generation,
                "best_cost": self.best_cost,
                "front_size": (None if self.front is None
                               else len(self.front.points)),
                "error": self.error}


@dataclass
class DesignResponse:
    """Terminal result for a request: the per-run records (same shape as
    :func:`run_experiment` output), the final Pareto front when a grid or
    archive produced one, and the stream of updates that led here."""

    request_id: str
    status: str                   # done | cancelled | timeout | error
    records: list = field(default_factory=list)    # list[RunRecord]
    front: object | None = None   # pareto.ParetoFront
    updates: list = field(default_factory=list)    # list[DesignUpdate]
    seconds: float = 0.0
    error: str | None = None

    @property
    def best_cost(self) -> float | None:
        costs = [r.result.best_cost for r in self.records
                 if r.result is not None]
        return min(costs) if costs else None

    def to_dict(self) -> dict:
        return {"request_id": self.request_id, "status": self.status,
                "records": summarize(self.records),
                "front_size": (None if self.front is None
                               else len(self.front.points)),
                "updates": [u.to_dict() for u in self.updates],
                "seconds": self.seconds, "error": self.error}


@dataclass
class _SweepUnit:
    """One (config, algorithm, repetition) run inside a sweep."""

    cfg_i: int
    cfg: ExperimentConfig
    algo: str
    rep_i: int                 # -1 for a folded batch record
    ev: Evaluator
    entry: OptimizerEntry
    params: Any
    budget: Budget
    result: OptResult | None = None
    seconds: float = 0.0


def run_sweep(configs, *, fold_repetitions: bool = True,
              stack_scoring: bool = True, shard: bool = False
              ) -> SweepResult:
    """Run many configs, amortizing compilation and normalization.

    ``configs`` may also be a :class:`SweepConfig`; with a ``pareto_grid``
    the base configs are expanded per grid scalarization and per-config
    Pareto fronts are attached to ``SweepResult.fronts``
    (``repro.core.pareto``).

    Unlike per-config :func:`run_experiment` (which re-draws normalizers
    per repetition for legacy fidelity), a sweep shares one Evaluator per
    (arch, config, seed, norm_samples, chunk, backend, mutation_mode) and
    one jitted scorer per (layout, chunk, backend) across *all* configs.
    With ``fold_repetitions`` (default), repetitions of chain-style
    optimizers (params with a ``chains`` field, e.g. SA) are folded into
    extra independent chains of a single batched call — same total search
    effort, one dispatch — raising evals/s further.  Folding only applies
    to pure evaluation budgets: a wall-clock budget covers one sequential
    run, so folding it would shrink per-repetition effort by ~k, and such
    configs run repetition-by-repetition instead.

    With ``stack_scoring`` (default), runs of *any* registered-stackable
    optimizer — BR/GA/SA host loops and the device-resident ``*-batched``
    drivers — from configs that share a jitted scorer (same layout, chunk,
    backend and objective *structure*; weights are runtime, so a Pareto
    grid of scalarizations stacks — e.g. GA populations from configs
    differing only in seed, hyper-parameters or objective weights)
    execute in lockstep with their per-round scoring requests
    concatenated into a single vmapped call
    (:func:`repro.core.optimize.drive_stacked`); per-row normalizer and
    weight vectors keep each run's in-scorer costs exact.
    Results are bit-for-bit identical to unstacked execution; only the
    number of device dispatches changes (``stats.score_calls``).  Runs
    with a wall-clock budget are excluded (interleaving would consume
    their time budget with the group's work, like repetition folding —
    see above).  A stacked record's ``seconds`` is its *attributed* wall
    time — its own generator resumes plus its proportional share of each
    stacked scoring call — so :func:`summarize`'s per-record evals/s
    stays meaningful.

    Because the Evaluator is shared, each record's ``n_generated`` is the
    number of placements generated *by that run* (a per-call delta), not
    the legacy cumulative counter.

    With ``shard`` every stackable run (stacked groups *and* singletons)
    routes its scoring through :func:`repro.sharding.population
    .shard_scorer`, splitting the population axis across all local
    devices with ``shard_map``.  On one device this is bit-for-bit
    identical to the unsharded path (the wrapper runs the same per-row
    computation); ``stats.shard_devices`` records the mesh size.
    """
    if isinstance(configs, SweepConfig):
        sc = configs
        if sc.pareto_grid is not None:
            from .pareto import run_pareto_sweep
            return run_pareto_sweep(
                sc.configs, sc.pareto_grid,
                fold_repetitions=sc.fold_repetitions,
                stack_scoring=sc.stack_scoring, shard=sc.shard)
        return run_sweep(sc.configs, fold_repetitions=sc.fold_repetitions,
                         stack_scoring=sc.stack_scoring, shard=sc.shard)
    t0 = time.monotonic()
    miss0 = _SCORER_STATS["misses"]
    evict0 = _SCORER_CACHE.evictions
    # Normalizer draws depend only on (arch, config, seed, samples, chunk,
    # backend, mutation_mode, policy) — never on the objective's terms or
    # weights — so evaluators for different scalarizations of one base
    # config (e.g. a Pareto grid) share one draw instead of re-generating
    # norm_samples placements each.
    norm_cache: dict[tuple, Evaluator] = {}
    ev_cache: dict[tuple, Evaluator] = {}
    units: list[_SweepUnit] = []
    for cfg_i, cfg in enumerate(configs):
        arch = resolve_arch(cfg.arch, cfg.config)
        nkey = (cfg.arch, cfg.config, cfg.seed, cfg.norm_samples, cfg.chunk,
                cfg.backend, cfg.mutation_mode, cfg.objective.normalizer)
        key = nkey + (cfg.objective, cfg.schedule, cfg.archive_k,
                      cfg.workload)
        if key not in ev_cache:
            rng = np.random.default_rng(cfg.seed)
            rep = make_rep(arch, cfg.arch, cfg.mutation_mode)
            base = norm_cache.get(nkey)
            ev_cache[key] = make_evaluator(
                rep, arch, rng=rng, norm_samples=cfg.norm_samples,
                chunk=cfg.chunk, backend=cfg.backend,
                objective=cfg.objective, schedule=cfg.schedule,
                norm=None if base is None else base.norm,
                archive_k=cfg.archive_k, workload=cfg.workload)
            if base is None:
                norm_cache[nkey] = ev_cache[key]
        ev = ev_cache[key]
        for algo in cfg.algorithms:
            entry = OPTIMIZERS.get(algo)
            params = cfg.resolved_params(algo)
            foldable = (fold_repetitions and cfg.repetitions > 1
                        and hasattr(params, "chains")
                        and cfg.budget.seconds is None)
            if foldable:
                p = dataclasses.replace(
                    params, chains=params.chains * cfg.repetitions)
                units.append(_SweepUnit(
                    cfg_i, cfg, algo, -1, ev, entry, p,
                    cfg.budget.scaled(cfg.repetitions)))
            else:
                for rep_i in range(cfg.repetitions):
                    units.append(_SweepUnit(cfg_i, cfg, algo, rep_i, ev,
                                            entry, params, cfg.budget))

    # Lockstep groups: stackable units sharing one jitted scorer.  Wall-
    # clock-budgeted runs never stack: interleaving would consume each
    # run's time budget with the whole group's work.
    groups: dict[int, list[_SweepUnit]] = {}
    if stack_scoring or shard:
        for u in units:
            if u.algo in _SWEEP_STACKABLE and u.budget.seconds is None:
                groups.setdefault(id(u.ev.scorer), []).append(u)
        if not stack_scoring:       # shard-only: each run on its own
            groups = {id(u): [u] for us in groups.values() for u in us}
        elif not shard:             # stacking alone only pays off for >1
            groups = {k: v for k, v in groups.items() if len(v) > 1}
    stacked = {id(u) for us in groups.values() for u in us}
    stacked_groups = sum(1 for us in groups.values() if len(us) > 1)

    shard_devices = 1
    mesh = None
    if shard:
        from repro.sharding.population import n_pop_devices, population_mesh
        mesh = population_mesh()
        shard_devices = n_pop_devices(mesh)

    for us in groups.values():
        items = []
        for u in us:
            rng_a = np.random.default_rng(
                algo_seed(u.cfg.seed, max(u.rep_i, 0), u.algo))
            items.append((_SWEEP_STACKABLE[u.algo](u.ev, rng_a, u.budget,
                                                   u.params), u.ev))
        score_fn = None
        if shard:
            from repro.sharding.population import shard_scorer
            score_fn = shard_scorer(us[0].ev.scorer, mesh)
        results, gen_counts, run_secs = drive_stacked(items,
                                                      score_fn=score_fn)
        for u, res, g, s in zip(us, results, gen_counts, run_secs):
            res.n_generated = g
            u.result, u.seconds = res, s
    for u in units:
        if id(u) in stacked:
            continue
        ta = time.monotonic()
        g0 = u.ev.n_generated
        rng_a = np.random.default_rng(
            algo_seed(u.cfg.seed, max(u.rep_i, 0), u.algo))
        res = u.entry.fn(u.ev, rng_a, u.budget, u.params)
        res.n_generated = u.ev.n_generated - g0
        u.result, u.seconds = res, time.monotonic() - ta

    runs = [SweepRun(cfg, []) for cfg in configs]
    for u in units:          # units were built in config order
        runs[u.cfg_i].records.append(
            RunRecord(u.cfg.arch, u.cfg.config, u.algo, u.rep_i, u.result,
                      u.seconds, degenerate_norms=u.ev.degenerate_norms))
    stats = SweepStats(
        scorers_built=_SCORER_STATS["misses"] - miss0,
        evaluators_built=len(norm_cache),
        n_evaluated=sum(r.result.n_evaluated
                        for run in runs for r in run.records),
        seconds=time.monotonic() - t0,
        score_calls=sum(ev.n_score_calls for ev in ev_cache.values()),
        stacked_groups=stacked_groups,
        scorer_evictions=_SCORER_CACHE.evictions - evict0,
        shard_devices=shard_devices)
    return SweepResult(runs, stats)


# ---------------------------------------------------------------------------
# Reporting helpers (shared with the legacy runner module).
# ---------------------------------------------------------------------------

def summarize(records: list[RunRecord]) -> list[dict]:
    rows = []
    for r in records:
        rows.append(dict(
            arch=r.arch, config=r.config, algorithm=r.algorithm,
            repetition=r.repetition, best_cost=r.result.best_cost,
            n_evaluated=r.result.n_evaluated,
            n_generated=r.result.n_generated, seconds=round(r.seconds, 2),
            evals_per_s=round(r.result.n_evaluated / max(r.seconds, 1e-9),
                              1),
        ))
    return rows


def best_by_algorithm(records: list[RunRecord]) -> dict[str, RunRecord]:
    out: dict[str, RunRecord] = {}
    for r in records:
        if r.algorithm not in out \
                or r.result.best_cost < out[r.algorithm].result.best_cost:
            out[r.algorithm] = r
    return out
