"""Assigned architecture config: seamless-m4t-medium (see registry for source).

Exposes CONFIG (exact published hyper-parameters) and SMOKE (reduced copy
for CPU smoke tests).  Select with ``--arch seamless-m4t-medium``.
"""
from .registry import get_config

CONFIG = get_config("seamless-m4t-medium")
SMOKE = CONFIG.reduced()
