"""Typed, registry-driven placement objectives (paper §IV-B, pluggable).

The paper's cost function is a *user-defined* mix of four traffic types
plus area.  This module makes that mix — and the whole cost formula — a
first-class, serializable configuration instead of weights hard-wired into
``ArchSpec``:

* :class:`TrafficMix` — typed per-traffic-type latency/throughput weights
  (paper §V-B values by default), including a closed-form derivation from
  a :class:`repro.core.traces.TraceMix` (weight the classes the way a
  dependency-driven trace actually loads them).
* :class:`TermSpec` / :class:`Objective` — a cost function as a weighted
  sum of named *terms* from the ``@register_objective_term`` registry
  (``repro.core.registries.OBJECTIVE_TERMS``).  The default
  ``(lat, inv-thr, area)`` triple reproduces the paper formula bit-for-bit
  on the host float64 path; extra terms (``link-length-cap``,
  ``node-degree``) turn physical constraints into soft penalties.
* :func:`compile_objective` — lowers the selected terms into a
  per-placement ``jnp`` cost function that ``proxies.make_scorer`` fuses
  into the jitted scorer, so per-placement cost (and argmin / top-k
  selection, see ``proxies.make_ranker``) happens on device.  Normalizers
  enter as a *runtime vector* (:func:`norms_vec`), not trace-time
  constants, so evaluators with different normalizer draws share one
  compiled scorer.  The objective *weights* — traffic-mix, ``w_area`` and
  per-term weights — are likewise a runtime vector
  (:func:`weights_vec`), so a whole grid of scalarizations (Pareto
  sweeps, ``repro.core.pareto``) and weight ramps across a run
  (:class:`Schedule`) share a single compiled scorer; only the term
  *structure* (:meth:`Objective.structure_key` — names and params) is
  trace-time.
* :class:`Schedule` — constraint-hardening ramps: per-term weight scale
  factors (``linear | cosine | step``, from the
  ``@register_schedule_ramp`` registry) applied across optimizer progress
  without retracing.
* :func:`objective_cost_host` — the float64 host evaluation used for
  reporting and equivalence tests; ``cost.total_cost`` delegates here.

Term implementations see a per-placement ``sample`` dict: the nine metric
scalars (``lat_*`` / ``thr_*`` / ``area``) plus the graph arrays
(``edges`` [E,2], ``edge_mask`` [E], ``edge_len`` [E] in mm) and the
static PHY count ``Vp``.  ``norms`` is a dict of the nine normalizer
scalars (``lat_*`` / ``inv_thr_*`` / ``area``) *plus* the runtime weight
scalars ``w_lat_*`` / ``w_thr_*`` / ``w_area`` — terms must read mix
weights from there (not from ``objective.mix``, which is only the
compile-time default) so they stay correct under runtime weight vectors.
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from typing import Mapping

import jax
import jax.numpy as jnp
import numpy as np

from .chiplets import TRAFFIC_TYPES, ArchSpec
from .registries import (OBJECTIVE_TERMS, SCHEDULE_RAMPS, ObjectiveTermEntry,
                         register_objective_term, register_schedule_ramp)

_EPS = 1.0e-6

# Objective terms that turn the scorer into a traffic-driven evaluation:
# they read the netsim rate model's per-class metrics, so the evaluator
# must carry a workload whose packed demand rides along as the runtime
# ``_demand`` operand (see repro.netsim.workload / proxies.make_scorer).
TRACE_TERMS = ("trace-lat", "trace-thr")

# Normalizer vector layout (stable; the jitted scorer takes this as a
# runtime argument so normalizer draws never retrace):
NORM_SLOTS = tuple([f"lat_{t}" for t in TRAFFIC_TYPES]
                   + [f"inv_thr_{t}" for t in TRAFFIC_TYPES] + ["area"])
NORM_DIM = len(NORM_SLOTS)

# Weight vector layout: the fixed slots shared by every objective, then one
# weight per term.  Like the normalizers, this enters the jitted scorer as
# a runtime argument ([W_FIXED + n_terms] or per-row [P, ...]), so Pareto
# weight grids and schedule ramps never retrace.
WEIGHT_SLOTS = tuple([f"w_lat_{t}" for t in TRAFFIC_TYPES]
                     + [f"w_thr_{t}" for t in TRAFFIC_TYPES] + ["w_area"])
W_FIXED = len(WEIGHT_SLOTS)

NORMALIZER_POLICIES = ("mean", "median", "ones")


def norms_vec(norm) -> np.ndarray:
    """``cost.CostNormalizers`` -> flat float32 vector in NORM_SLOTS order."""
    out = np.empty(NORM_DIM, np.float32)
    for i, t in enumerate(TRAFFIC_TYPES):
        out[i] = norm.lat[t]
        out[4 + i] = norm.inv_thr[t]
    out[8] = norm.area
    return out


def weights_vec(objective: "Objective") -> np.ndarray:
    """Objective weights -> flat float32 vector: WEIGHT_SLOTS order (mix
    lat, mix thr, w_area), then one per-term weight in term order."""
    out = np.empty(W_FIXED + len(objective.terms), np.float32)
    out[0:4] = objective.mix.lat
    out[4:8] = objective.mix.thr
    out[8] = objective.w_area
    for j, t in enumerate(objective.terms):
        out[W_FIXED + j] = t.weight
    return out


def weight_dim(objective: "Objective") -> int:
    return W_FIXED + len(objective.terms)


def _norms_dict_from_row(row):
    d = {}
    for i, t in enumerate(TRAFFIC_TYPES):
        d[f"lat_{t}"] = row[i]
        d[f"inv_thr_{t}"] = row[4 + i]
    d["area"] = row[8]
    return d


def _mix_weights_from_row(row):
    """The fixed weight slots of a runtime weight vector, keyed like the
    entries term implementations read from their ``norms`` mapping."""
    d = {}
    for i, t in enumerate(TRAFFIC_TYPES):
        d[f"w_lat_{t}"] = row[i]
        d[f"w_thr_{t}"] = row[4 + i]
    d["w_area"] = row[8]
    return d


def _mix_weights_static(objective: "Objective"):
    """Same mapping, from the objective's own (python-float) weights."""
    d = {}
    for i, t in enumerate(TRAFFIC_TYPES):
        d[f"w_lat_{t}"] = objective.mix.lat[i]
        d[f"w_thr_{t}"] = objective.mix.thr[i]
    d["w_area"] = objective.w_area
    return d


# ---------------------------------------------------------------------------
# TrafficMix: typed per-type weights.
# ---------------------------------------------------------------------------

_PAPER_W = (0.1, 2.0, 0.1, 2.0)     # §V-B: C2M / M2I weighted 2, C2C / C2I 0.1


@dataclass(frozen=True)
class TrafficMix:
    """Latency/throughput weights per traffic type (order TRAFFIC_TYPES)."""

    lat: tuple = _PAPER_W
    thr: tuple = _PAPER_W

    def __post_init__(self):
        for name in ("lat", "thr"):
            v = tuple(float(x) for x in getattr(self, name))
            if len(v) != len(TRAFFIC_TYPES):
                raise ValueError(
                    f"TrafficMix.{name} needs {len(TRAFFIC_TYPES)} weights "
                    f"(order {TRAFFIC_TYPES}), got {len(v)}")
            if not all(np.isfinite(x) and x >= 0.0 for x in v):
                raise ValueError(f"TrafficMix.{name} weights must be finite "
                                 f"and non-negative: {v}")
            object.__setattr__(self, name, v)

    @classmethod
    def paper(cls) -> "TrafficMix":
        return cls()

    @classmethod
    def from_trace_mix(cls, mix, *, flit_weighted: bool = True,
                       scale: float = 4.2) -> "TrafficMix":
        """Weights proportional to the traffic a §VII-A dependency trace
        actually generates (``traces.TraceMix.class_shares``; directions
        folded into the four chiplet-pair classes).  ``scale`` sets the
        overall traffic-vs-area balance — the default makes the weights
        sum to the paper mix's 4.2, so ``w_area`` keeps its meaning."""
        shares = mix.class_shares(flit_weighted=flit_weighted)
        w = tuple(scale * shares[t] for t in TRAFFIC_TYPES)
        return cls(lat=w, thr=w)

    def to_dict(self) -> dict:
        return {"lat": list(self.lat), "thr": list(self.thr)}

    @classmethod
    def from_dict(cls, d: Mapping) -> "TrafficMix":
        unknown = set(d) - {"lat", "thr"}
        if unknown:
            raise ValueError(f"unknown TrafficMix keys: {sorted(unknown)}")
        return cls(**{k: tuple(v) for k, v in d.items()})


# ---------------------------------------------------------------------------
# TermSpec + Objective.
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class TermSpec:
    """One weighted term: a registry name plus hashable keyword params.

    Param values may be numbers, strings or bools (anything JSON-scalar
    and hashable); numbers are normalized to float so serialization
    round-trips compare equal.
    """

    name: str
    weight: float = 1.0
    params: tuple = ()              # sorted ((key, value), ...) pairs

    @staticmethod
    def _coerce(v):
        if isinstance(v, bool) or isinstance(v, str):
            return v
        if isinstance(v, (int, float)):
            return float(v)
        raise TypeError(f"TermSpec param values must be JSON scalars "
                        f"(number/str/bool), got {type(v).__name__}: {v!r}")

    def __post_init__(self):
        p = self.params
        items = p.items() if isinstance(p, Mapping) else p
        p = tuple(sorted((str(k), self._coerce(v)) for k, v in items))
        object.__setattr__(self, "params", p)
        object.__setattr__(self, "weight", float(self.weight))

    def param_dict(self) -> dict:
        return dict(self.params)

    def to_dict(self) -> dict:
        return {"name": self.name, "weight": self.weight,
                "params": dict(self.params)}

    @classmethod
    def from_dict(cls, d) -> "TermSpec":
        if isinstance(d, TermSpec):
            return d
        if isinstance(d, str):
            return cls(name=d)
        unknown = set(d) - {"name", "weight", "params"}
        if unknown:
            raise ValueError(f"unknown TermSpec keys: {sorted(unknown)}")
        return cls(**dict(d))


DEFAULT_TERMS = (TermSpec("lat"), TermSpec("inv-thr"), TermSpec("area"))


@dataclass(frozen=True)
class Objective:
    """A placement cost function: traffic mix x normalizer policy x terms.

    The default value reproduces the paper's §IV-B formula (and the
    deprecated ``ArchSpec.w_lat/w_thr/w_area`` weights) bit-for-bit on the
    host float64 path.  Hashable — it keys the jitted-scorer cache and the
    sweep's stacked-scoring groups.
    """

    mix: TrafficMix = field(default_factory=TrafficMix)
    w_area: float = 2.0
    normalizer: str = "mean"        # mean | median | ones
    terms: tuple = DEFAULT_TERMS

    def __post_init__(self):
        if isinstance(self.mix, Mapping):
            object.__setattr__(self, "mix", TrafficMix.from_dict(self.mix))
        object.__setattr__(self, "w_area", float(self.w_area))
        object.__setattr__(
            self, "terms",
            tuple(TermSpec.from_dict(t) for t in self.terms))
        if self.normalizer not in NORMALIZER_POLICIES:
            raise ValueError(
                f"unknown normalizer policy {self.normalizer!r}; one of "
                f"{NORMALIZER_POLICIES}")

    @classmethod
    def from_arch(cls, arch: ArchSpec, **kw) -> "Objective":
        """Bridge for the deprecated ``ArchSpec.w_*`` weight fields."""
        return cls(mix=TrafficMix(lat=arch.w_lat, thr=arch.w_thr),
                   w_area=arch.w_area, **kw)

    def with_terms(self, *extra: TermSpec) -> "Objective":
        return dataclasses.replace(self, terms=self.terms + tuple(extra))

    def structure_key(self) -> tuple:
        """The trace-time identity of this objective: term names + params.

        All *weights* (traffic mix, ``w_area``, per-term) are runtime
        vector entries (:func:`weights_vec`), so objectives that differ
        only in weights share one compiled scorer — this key (not the full
        objective) keys the jitted-scorer cache and the sweep's stacked-
        scoring groups.
        """
        return tuple((t.name, t.params) for t in self.terms)

    # -- serialization ----------------------------------------------------
    def to_dict(self) -> dict:
        return {"mix": self.mix.to_dict(), "w_area": self.w_area,
                "normalizer": self.normalizer,
                "terms": [t.to_dict() for t in self.terms]}

    @classmethod
    def from_dict(cls, d: Mapping) -> "Objective":
        unknown = set(d) - {f.name for f in dataclasses.fields(cls)}
        if unknown:
            raise ValueError(f"unknown Objective keys: {sorted(unknown)}")
        return cls(**dict(d))

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=1)

    @classmethod
    def from_json(cls, s: str) -> "Objective":
        return cls.from_dict(json.loads(s))


# ---------------------------------------------------------------------------
# Built-in terms.  Device fns are per-placement jnp (traced inside the
# scorer's vmap); host fns are batched float64 numpy whose accumulation
# order matches the legacy ``cost.cost_components`` formula exactly.
# Traffic-mix / area weights are read from the ``norms`` mapping
# (``w_lat_*`` / ``w_thr_*`` / ``w_area``), which carries either the
# runtime weight-vector entries or the objective's own python floats —
# never from ``obj.mix`` directly, which would freeze them at trace time.
# ---------------------------------------------------------------------------

def _lat_host(metrics, batch, norms, obj, params):
    acc = None
    for i, t in enumerate(TRAFFIC_TYPES):
        v = (norms[f"w_lat_{t}"] * np.asarray(metrics[f"lat_{t}"],
                                              np.float64)
             / max(norms[f"lat_{t}"], _EPS))
        acc = v if acc is None else acc + v
    return acc


@register_objective_term("lat", host_fn=_lat_host)
def _lat(sample, norms, obj, params):
    """Normalized mean shortest-path latency, weighted per traffic type."""
    acc = 0.0
    for i, t in enumerate(TRAFFIC_TYPES):
        acc = acc + (norms[f"w_lat_{t}"] * sample[f"lat_{t}"]
                     / jnp.maximum(norms[f"lat_{t}"], _EPS))
    return acc


def _inv_thr_host(metrics, batch, norms, obj, params):
    acc = None
    for i, t in enumerate(TRAFFIC_TYPES):
        v = (norms[f"w_thr_{t}"]
             * (1.0 / np.maximum(np.asarray(metrics[f"thr_{t}"],
                                            np.float64), _EPS))
             / max(norms[f"inv_thr_{t}"], _EPS))
        acc = v if acc is None else acc + v
    return acc


@register_objective_term("inv-thr", host_fn=_inv_thr_host)
def _inv_thr(sample, norms, obj, params):
    """Normalized inverse saturation throughput ("lower is better")."""
    acc = 0.0
    for i, t in enumerate(TRAFFIC_TYPES):
        acc = acc + (norms[f"w_thr_{t}"]
                     / jnp.maximum(sample[f"thr_{t}"], _EPS)
                     / jnp.maximum(norms[f"inv_thr_{t}"], _EPS))
    return acc


def _area_host(metrics, batch, norms, obj, params):
    return (norms["w_area"] * np.asarray(metrics["area"], np.float64)
            / max(norms["area"], _EPS))


@register_objective_term("area", host_fn=_area_host)
def _area(sample, norms, obj, params):
    """Normalized enclosing-rectangle area (§V-A get_area)."""
    return (norms["w_area"] * sample["area"]
            / jnp.maximum(norms["area"], _EPS))


def _link_len_host(metrics, batch, norms, obj, params):
    cap = params.get("cap_mm", 3.0)
    over = np.maximum(np.asarray(batch["edge_len"], np.float64) - cap, 0.0)
    return 0.5 * np.where(np.asarray(batch["edge_mask"]), over, 0.0).sum(-1)


@register_objective_term("link-length-cap", host_fn=_link_len_host)
def _link_len(sample, norms, obj, params):
    """Soft D2D link-length budget: total mm of link length above
    ``cap_mm`` over the placement's (undirected) links.  Zero whenever all
    links respect the cap — tighten ``cap_mm`` below ``max_link_mm`` to
    bias the search toward shorter (lower-energy) interposer routes."""
    cap = params.get("cap_mm", 3.0)
    over = jnp.maximum(sample["edge_len"] - cap, 0.0)
    return 0.5 * jnp.where(sample["edge_mask"], over, 0.0).sum()


def _node_degree_host(metrics, batch, norms, obj, params):
    cap = params.get("max_degree", 4.0)
    E = np.asarray(batch["edges"])
    M = np.asarray(batch["edge_mask"])
    out = np.zeros(E.shape[0], np.float64)
    for b in range(E.shape[0]):
        deg = np.bincount(E[b, M[b], 0])
        out[b] = np.maximum(deg - cap, 0.0).sum()
    return out


@register_objective_term("node-degree", host_fn=_node_degree_host)
def _node_degree(sample, norms, obj, params):
    """Per-PHY link-count penalty: sum of degree overage above
    ``max_degree`` (a router-radix proxy).  Out-degree over the directed
    edge list equals the undirected PHY degree."""
    cap = params.get("max_degree", 4.0)
    deg = jnp.zeros(sample["Vp"], jnp.float32).at[
        sample["edges"][:, 0]].add(
        jnp.where(sample["edge_mask"], 1.0, 0.0))
    return jnp.maximum(deg - cap, 0.0).sum()


def _trace_lat_host(metrics, batch, norms, obj, params):
    if "trace_lat_c2c" not in metrics:
        raise KeyError(
            "trace-lat host evaluation needs trace_lat_* metrics; score "
            "through an evaluator built with a workload so the scorer "
            "emits them")
    acc = None
    for t in TRAFFIC_TYPES:
        v = (norms[f"w_lat_{t}"]
             * np.asarray(metrics[f"trace_lat_{t}"], np.float64)
             / max(norms[f"lat_{t}"], _EPS))
        acc = v if acc is None else acc + v
    return acc


@register_objective_term("trace-lat", host_fn=_trace_lat_host)
def _trace_lat(sample, norms, obj, params):
    """Normalized traffic-weighted packet latency from the device netsim
    rate model (``repro.netsim.model``): per traffic class, the
    demand-weighted mean of path latency + per-hop router pipeline +
    saturating ECMP queueing delay + serialization, under the class's
    workload demand.  Requires an evaluator-attached workload
    (``ExperimentConfig(workload=...)``), which enters the scorer as the
    runtime ``_demand`` operand — swapping traces or injection rates
    never retraces.  Normalized by the same per-class latency scale as
    the ``lat`` proxy term (both are cycles), weighted by the runtime
    traffic-mix weights."""
    acc = 0.0
    for t in TRAFFIC_TYPES:
        acc = acc + (norms[f"w_lat_{t}"] * sample[f"trace_lat_{t}"]
                     / jnp.maximum(norms[f"lat_{t}"], _EPS))
    return acc


def _trace_thr_host(metrics, batch, norms, obj, params):
    if "trace_thr_c2c" not in metrics:
        raise KeyError(
            "trace-thr host evaluation needs trace_thr_* metrics; score "
            "through an evaluator built with a workload so the scorer "
            "emits them")
    acc = None
    for t in TRAFFIC_TYPES:
        thr = np.asarray(metrics[f"trace_thr_{t}"], np.float64)
        inv = np.where(thr > 0, 1.0 / np.maximum(thr, _EPS), 0.0)
        v = norms[f"w_thr_{t}"] * inv / max(norms[f"inv_thr_{t}"], _EPS)
        acc = v if acc is None else acc + v
    return acc


@register_objective_term("trace-thr", host_fn=_trace_thr_host)
def _trace_thr(sample, norms, obj, params):
    """Normalized per-class *throughput* cost from the device netsim rate
    model: per traffic class, the maximum sustainable aggregate flit
    injection rate before some link saturates (the class's demand scaled
    up against the other classes' fixed link loads — see
    ``repro.netsim.model``).  Cost is the inverse (lower is better),
    normalized by the same per-class inverse-throughput scale as the
    ``inv-thr`` proxy term and weighted by the runtime traffic-mix
    throughput weights; classes without demand contribute 0.  Requires an
    evaluator-attached workload, which enters the scorer as the runtime
    ``_demand`` operand — swapping traces or rates never retraces."""
    acc = 0.0
    for t in TRAFFIC_TYPES:
        thr = sample[f"trace_thr_{t}"]
        inv = jnp.where(thr > 0, 1.0 / jnp.maximum(thr, _EPS), 0.0)
        acc = acc + (norms[f"w_thr_{t}"] * inv
                     / jnp.maximum(norms[f"inv_thr_{t}"], _EPS))
    return acc


# ---------------------------------------------------------------------------
# Compilation: Objective -> per-placement device cost function.
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class CompiledObjective:
    """An :class:`Objective` resolved against the term registry.

    ``cost_one(sample, norms_row[, weights_row])`` is the per-placement
    jnp cost — pure, vmappable, with the normalizer vector (and optionally
    the weight vector, see :func:`weights_vec`) as runtime arguments so
    one compiled scorer serves every normalizer draw, every weight
    scalarization of the same term structure, and — stacked — per-row
    norms/weights from different runs in one call.  ``term_values``
    returns the weighted per-term costs individually (the columns of a
    Pareto cost matrix); ``cost_one`` is their sequential sum.
    """

    objective: Objective
    entries: tuple

    def term_values(self, sample, norms_row, weights_row=None):
        """Weighted per-term jnp scalars, in term order."""
        norms = _norms_dict_from_row(norms_row)
        if weights_row is None:
            norms.update(_mix_weights_static(self.objective))
            tw = [spec.weight for spec in self.objective.terms]
        else:
            norms.update(_mix_weights_from_row(weights_row))
            tw = [weights_row[W_FIXED + j]
                  for j in range(len(self.objective.terms))]
        return [tw[j] * entry.fn(sample, norms, self.objective,
                                 spec.param_dict())
                for j, (spec, entry) in enumerate(
                    zip(self.objective.terms, self.entries))]

    def cost_one(self, sample, norms_row, weights_row=None):
        total = jnp.float32(0.0)
        for v in self.term_values(sample, norms_row, weights_row):
            total = total + v
        return total


def compile_objective(objective: Objective, layout=None) -> CompiledObjective:
    """Resolve ``objective.terms`` against OBJECTIVE_TERMS (fails fast on
    unknown names) into a :class:`CompiledObjective`."""
    entries = tuple(OBJECTIVE_TERMS.get(s.name) for s in objective.terms)
    return CompiledObjective(objective, entries)


# ---------------------------------------------------------------------------
# Host evaluation (reporting, legacy total_cost, device-agreement tests).
# ---------------------------------------------------------------------------

def _host_norms(norm, objective: Objective) -> dict:
    d = {}
    for t in TRAFFIC_TYPES:
        d[f"lat_{t}"] = norm.lat[t]
        d[f"inv_thr_{t}"] = norm.inv_thr[t]
    d["area"] = norm.area
    d.update(_mix_weights_static(objective))
    return d


def _host_fallback(entry: ObjectiveTermEntry, objective, spec, metrics,
                   batch, norm, vp: int | None) -> np.ndarray:
    """vmap the device term over host arrays (float32) when no dedicated
    host implementation exists."""
    sample = {k: jnp.asarray(np.asarray(v))
              for k, v in metrics.items()
              if k not in ("cost", "connected", "overflow")}
    if batch is not None:
        for k in ("edges", "edge_mask", "edge_len"):
            if k in batch:
                sample[k] = jnp.asarray(np.asarray(batch[k]))
        if vp is None and "edges" in batch:
            # Heuristic lower bound on the PHY count (exact when the
            # highest-numbered PHY carries a link); pass ``vp`` for terms
            # that size arrays by the true layout.Vp.
            vp = int(np.asarray(batch["edges"]).max()) + 1
    row = jnp.asarray(norms_vec(norm))
    params = spec.param_dict()
    statics = _mix_weights_static(objective)
    out = jax.vmap(lambda s: entry.fn(dict(s, Vp=vp or 0),
                                      _norms_dict_from_row(row) | statics,
                                      objective, params))(sample)
    return np.asarray(out, np.float64)


def objective_cost_host(metrics: dict, objective: Objective, norm, *,
                        batch: dict | None = None,
                        vp: int | None = None) -> np.ndarray:
    """Batched float64 host cost.  For the default ``Objective`` this is
    bit-for-bit ``cost.total_cost`` (same weights, same grouped float64
    accumulation: all lat, all inv-thr, area).  Graph-dependent terms
    (``link-length-cap``, ``node-degree``) additionally need the stacked
    graph ``batch``; ``vp`` supplies the true ``layout.Vp`` to host-
    fallback terms that size per-PHY arrays."""
    cobj = compile_objective(objective)
    norms = _host_norms(norm, objective)
    total = None
    for spec, entry in zip(objective.terms, cobj.entries):
        if entry.host_fn is not None:
            v = np.asarray(entry.host_fn(metrics, batch, norms, objective,
                                         spec.param_dict()), np.float64)
        else:
            v = _host_fallback(entry, objective, spec, metrics, batch, norm,
                               vp)
        v = spec.weight * v
        total = v if total is None else total + v
    if total is None:                       # no terms: zero cost
        some = np.asarray(metrics["area"], np.float64)
        total = np.zeros_like(some)
    return total


# ---------------------------------------------------------------------------
# Constraint-hardening schedules: per-term weight scale ramps over a run.
#
# Because the objective weights are a *runtime* vector in the jitted scorer
# (see weights_vec), ramping a penalty weight across optimizer generations
# is just a different [W_FIXED + n_terms] vector per scoring request — no
# retrace.  Ramp shapes come from the @register_schedule_ramp registry
# (registries.SCHEDULE_RAMPS): fn(t, start, end, params) -> scale, with t
# the run's progress fraction in [0, 1].
# ---------------------------------------------------------------------------

@register_schedule_ramp("linear")
def _ramp_linear(t, start, end, params):
    """start -> end, linearly in progress."""
    return start + (end - start) * t


@register_schedule_ramp("cosine")
def _ramp_cosine(t, start, end, params):
    """start -> end along a half cosine (slow start, slow finish)."""
    return end + (start - end) * 0.5 * (1.0 + np.cos(np.pi * t))


@register_schedule_ramp("step")
def _ramp_step(t, start, end, params):
    """start before progress ``at`` (default 0.5), end from there on."""
    return end if t >= params.get("at", 0.5) else start


@dataclass(frozen=True)
class Ramp:
    """One ramp: a registry kind plus start/end scales and params."""

    kind: str = "linear"
    start: float = 0.0
    end: float = 1.0
    params: tuple = ()              # sorted ((key, value), ...) pairs

    def __post_init__(self):
        p = self.params
        items = p.items() if isinstance(p, Mapping) else p
        object.__setattr__(self, "params", tuple(
            sorted((str(k), float(v)) for k, v in items)))
        object.__setattr__(self, "start", float(self.start))
        object.__setattr__(self, "end", float(self.end))
        SCHEDULE_RAMPS.get(self.kind)          # fail fast on unknown kinds

    def scale_at(self, t: float) -> float:
        t = min(max(float(t), 0.0), 1.0)
        return float(SCHEDULE_RAMPS.get(self.kind)(
            t, self.start, self.end, dict(self.params)))

    def to_dict(self) -> dict:
        return {"kind": self.kind, "start": self.start, "end": self.end,
                "params": dict(self.params)}

    @classmethod
    def from_dict(cls, d) -> "Ramp":
        if isinstance(d, Ramp):
            return d
        unknown = set(d) - {"kind", "start", "end", "params"}
        if unknown:
            raise ValueError(f"unknown Ramp keys: {sorted(unknown)}")
        return cls(**dict(d))


@dataclass(frozen=True)
class Schedule:
    """Per-term weight-scale ramps applied over a run's progress.

    ``ramps`` maps objective term names to :class:`Ramp`s; at progress
    ``t`` the term's runtime weight is ``spec.weight * ramp.scale_at(t)``.
    Classic constraint hardening ramps a penalty term from 0 to full
    strength (``Ramp("linear", start=0.0, end=1.0)``), letting the search
    move through infeasible regions early and forcing feasibility late.
    Hashable and JSON round-trippable like :class:`Objective`; validated
    against the objective's terms when compiled (``compile_schedule``).
    """

    ramps: tuple = ()               # sorted ((term_name, Ramp), ...)

    def __post_init__(self):
        r = self.ramps
        items = r.items() if isinstance(r, Mapping) else r
        object.__setattr__(self, "ramps", tuple(sorted(
            (str(k), Ramp.from_dict(v)) for k, v in items)))

    def scales_at(self, t: float) -> dict:
        return {name: ramp.scale_at(t) for name, ramp in self.ramps}

    def to_dict(self) -> dict:
        return {"ramps": {name: ramp.to_dict() for name, ramp in self.ramps}}

    @classmethod
    def from_dict(cls, d) -> "Schedule":
        if isinstance(d, Schedule):
            return d
        unknown = set(d) - {"ramps"}
        if unknown:
            raise ValueError(f"unknown Schedule keys: {sorted(unknown)}")
        return cls(**dict(d))

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=1)

    @classmethod
    def from_json(cls, s: str) -> "Schedule":
        return cls.from_dict(json.loads(s))


class CompiledSchedule:
    """A :class:`Schedule` bound to an objective's weight vector.

    ``weights_at(t)`` returns the [W_FIXED + n_terms] float32 runtime
    weight vector at progress ``t``: the objective's base weights with
    each ramped term's weight slot scaled.  Rows for a whole trajectory
    share the compiled scorer — weights are runtime, nothing retraces.
    """

    def __init__(self, schedule: Schedule, objective: Objective):
        self.schedule = schedule
        self.objective = objective
        self._base = weights_vec(objective)
        names = [t.name for t in objective.terms]
        unknown = [n for n, _ in schedule.ramps if n not in names]
        if unknown:
            raise ValueError(
                f"schedule ramps unknown objective term(s) {unknown}; "
                f"objective has {names}")
        self._slots = [(np.nonzero([n == name for n in names])[0] + W_FIXED,
                        ramp) for name, ramp in schedule.ramps]

    def weights_at(self, t: float) -> np.ndarray:
        out = self._base.copy()
        for slots, ramp in self._slots:
            out[slots] = out[slots] * np.float32(ramp.scale_at(t))
        return out


def compile_schedule(schedule, objective: Objective) -> CompiledSchedule:
    """Validate + bind a schedule (or its dict form) to an objective."""
    return CompiledSchedule(Schedule.from_dict(schedule)
                            if not isinstance(schedule, Schedule)
                            else schedule, objective)
