"""Design service (serve.design) + sharding + caches + archives.

Acceptance pins (ISSUE 6):

* the engine batches >= 2 concurrent compatible requests into one
  stacked scorer group — strictly fewer scorer dispatches than the same
  requests run sequentially — and streams >= 2 incremental updates per
  request before the terminal one;
* records/fronts are **bit-for-bit** what ``run_sweep``/``run_pareto``
  produce for the same configs, independent of arrival order;
* ``run_sweep(shard=True)`` (population-axis ``shard_map``) is
  bit-for-bit identical to the unsharded path on one device;
* request lifecycle: cancel (queued + active), timeout, error isolation;
* the scorer/evaluator LRUs bound compiled artifacts and count
  evictions; the device population archive thickens Pareto fronts.
"""
import dataclasses

import numpy as np
import pytest

from repro.core.api import (Budget, DesignRequest, DesignResponse,
                            DesignUpdate, ExperimentConfig, SweepConfig,
                            clear_scorer_cache, run_sweep,
                            scorer_cache_stats, set_scorer_cache_capacity,
                            stackable_steps)
from repro.core.cache import LRUCache
from repro.core.pareto import (FrontCandidate, IncrementalFront,
                               ParetoGridSpec, compute_front, run_pareto)
from repro.serve.design import DesignEngine


def tiny_cfg(arch="homog32", **kw):
    base = dict(arch=arch, algorithms=("br", "ga"), budget=Budget(evals=12),
                norm_samples=3, chunk=4, params={"br": {"batch": 4}})
    base.update(kw)
    return ExperimentConfig(**base)


GRID = ParetoGridSpec(term_weights={"area": (0.5, 2.0)})


# ---------------------------------------------------------------------------
# LRUCache unit behavior.
# ---------------------------------------------------------------------------

def test_lru_eviction_order_and_counter():
    evicted = []
    c = LRUCache(2, on_evict=lambda k, v: evicted.append(k))
    c["a"], c["b"] = 1, 2
    _ = c["a"]                    # refresh: b is now LRU
    c["c"] = 3
    assert "b" not in c and "a" in c and "c" in c
    assert c.evictions == 1 and evicted == ["b"]


def test_lru_pinning_protects_and_overflows():
    c = LRUCache(1)
    c["a"] = 1
    c.pin("a")
    c["b"] = 2                    # a pinned, b unpinned -> b evicted
    assert "a" in c and "b" not in c
    c.pin("a")                    # refcount 2
    c.unpin("a")
    c["b"] = 2                    # still pinned once
    assert "a" in c
    c.unpin("a")                  # last unpin shrinks
    c["d"] = 4
    assert len(c) == 1
    with pytest.raises(KeyError):
        c.pin("nope")


def test_lru_set_capacity_shrinks():
    c = LRUCache(4)
    for i in range(4):
        c[i] = i
    c.set_capacity(2)
    assert len(c) == 2 and c.evictions == 2
    with pytest.raises(ValueError):
        c.set_capacity(0)


def test_scorer_cache_bounded_counts_evictions():
    clear_scorer_cache()
    set_scorer_cache_capacity(1)
    try:
        run_sweep([tiny_cfg(algorithms=("br",))])
        run_sweep([tiny_cfg("hetero32", algorithms=("br",))])
        res = run_sweep([tiny_cfg(algorithms=("br",))])  # re-compiles
        stats = scorer_cache_stats()
        assert stats["capacity"] == 1 and stats["size"] == 1
        assert stats["evictions"] >= 2
        assert res.stats.scorer_evictions >= 1
    finally:
        set_scorer_cache_capacity(64)
        clear_scorer_cache()


# ---------------------------------------------------------------------------
# Sharded population path.
# ---------------------------------------------------------------------------

def test_shard_bitforbit_vs_run_sweep():
    cfgs = [tiny_cfg(seed=0), tiny_cfg(seed=1)]
    plain = run_sweep(cfgs)
    sharded = run_sweep(cfgs, shard=True)
    assert sharded.stats.shard_devices >= 1
    for a, b in zip(plain.records, sharded.records):
        assert a.result.best_cost == b.result.best_cost
        assert np.array_equal(a.result.best_sol[0], b.result.best_sol[0])
        assert np.array_equal(a.result.best_sol[1], b.result.best_sol[1])


def test_shard_scorer_pads_any_batch():
    from repro.sharding.population import population_mesh, shard_scorer
    from repro.core.api import make_evaluator, make_rep
    from repro.core.chiplets import paper_arch
    from repro.core.topology import stack_graphs
    arch = paper_arch("homog32", "baseline")
    rep = make_rep(arch, "homog32")
    rng = np.random.default_rng(0)
    ev = make_evaluator(rep, arch, rng=rng, norm_samples=3, chunk=4)
    sols = [rep.random(rng) for _ in range(3)]         # odd batch size
    batch = stack_graphs([rep.score_graph(s) for s in sols])
    wrapped = shard_scorer(ev.scorer, population_mesh())
    out = wrapped(batch, ev.norm_vec, ev.weights_vec)
    ref = ev.score_batch(batch)
    for k in ref:
        assert np.array_equal(np.asarray(out[k]), np.asarray(ref[k])), k


def test_sweepconfig_shard_serde_roundtrip():
    sc = SweepConfig(configs=(tiny_cfg(),), shard=True)
    rt = SweepConfig.from_json(sc.to_json())
    assert rt.shard is True
    assert rt.configs[0] == sc.configs[0]


# ---------------------------------------------------------------------------
# Request schema serde.
# ---------------------------------------------------------------------------

def test_design_request_serde_roundtrip():
    req = DesignRequest(config=tiny_cfg(archive_k=8), request_id="t1",
                        pareto_grid=GRID, timeout_s=5.0)
    rt = DesignRequest.from_dict(req.to_dict())
    assert rt.config == req.config and rt.config.archive_k == 8
    assert rt.pareto_grid.n_points == GRID.n_points
    assert rt.timeout_s == 5.0
    with pytest.raises(ValueError, match="unknown DesignRequest"):
        DesignRequest.from_dict({"config": tiny_cfg().to_dict(),
                                 "nope": 1})


def test_experiment_config_archive_k_serde():
    cfg = tiny_cfg(archive_k=5)
    assert ExperimentConfig.from_dict(cfg.to_dict()) == cfg
    assert ExperimentConfig.from_dict(cfg.to_dict()).archive_k == 5


# ---------------------------------------------------------------------------
# Engine: batching + streaming + parity.
# ---------------------------------------------------------------------------

def test_engine_batches_and_streams():
    eng = DesignEngine()
    r1 = eng.submit(DesignRequest(config=tiny_cfg(seed=0)))
    r2 = eng.submit(DesignRequest(config=tiny_cfg(seed=1)))
    eng.run()
    # >= 2 compatible tenants stacked into shared dispatches...
    assert eng.stats.stacked_rounds >= 1
    seq = sum(run_sweep([c], fold_repetitions=False).stats.score_calls
              for c in (tiny_cfg(seed=0), tiny_cfg(seed=1)))
    assert eng.stats.score_calls < seq
    # ...and each request streamed >= 2 incremental updates pre-terminal.
    for rid in (r1, r2):
        resp = eng.result(rid)
        assert resp.status == "done"
        kinds = [u.kind for u in resp.updates]
        assert kinds[-1] == "done"
        assert sum(k == "progress" for k in kinds[:-1]) >= 2


def test_engine_bitforbit_vs_run_sweep():
    cfgs = [tiny_cfg(seed=0), tiny_cfg(seed=1)]
    eng = DesignEngine()
    rids = [eng.submit(DesignRequest(config=c)) for c in cfgs]
    eng.run()
    sw = run_sweep(cfgs, fold_repetitions=False)
    eng_records = [r for rid in rids for r in eng.result(rid).records]
    assert len(eng_records) == len(sw.records)
    for a, b in zip(eng_records, sw.records):
        assert (a.algorithm, a.repetition) == (b.algorithm, b.repetition)
        assert a.result.best_cost == b.result.best_cost
        assert np.array_equal(a.result.best_sol[0], b.result.best_sol[0])
        assert np.array_equal(a.result.best_sol[1], b.result.best_sol[1])


def test_engine_arrival_order_determinism():
    def run_order(cfg_seeds):
        eng = DesignEngine()
        rids = {s: eng.submit(DesignRequest(config=tiny_cfg(seed=s)))
                for s in cfg_seeds}
        eng.run()
        return {s: [(r.algorithm, r.result.best_cost,
                     np.asarray(r.result.best_sol[0]).tobytes())
                    for r in eng.result(rid).records]
                for s, rid in rids.items()}

    a = run_order([0, 1, 2])
    b = run_order([2, 0, 1])
    assert a == b


def test_engine_sharded_bitforbit():
    eng_s = DesignEngine(shard=True)
    eng_p = DesignEngine()
    for eng in (eng_s, eng_p):
        eng.submit(DesignRequest(config=tiny_cfg(seed=3),
                                 request_id="t"))
        eng.run()
    a, b = eng_s.result("t"), eng_p.result("t")
    for x, y in zip(a.records, b.records):
        assert x.result.best_cost == y.result.best_cost
        assert np.array_equal(x.result.best_sol[0], y.result.best_sol[0])


def test_engine_mixed_homog_hetero():
    eng = DesignEngine()
    rh = eng.submit(DesignRequest(config=tiny_cfg(seed=0)))
    rx = eng.submit(DesignRequest(config=tiny_cfg(
        "hetero32", algorithms=("br",), seed=0)))
    eng.run()
    assert eng.result(rh).status == "done"
    assert eng.result(rx).status == "done"
    # different layouts never share a scorer group, but both drain
    assert eng.stats.completed == 2


# ---------------------------------------------------------------------------
# Engine: lifecycle (cancel / timeout / error / capacity).
# ---------------------------------------------------------------------------

def test_engine_cancel_queued_and_active():
    eng = DesignEngine()
    rq = eng.submit(DesignRequest(config=tiny_cfg(seed=0)))
    assert eng.cancel(rq) is True
    assert eng.result(rq).status == "cancelled"
    assert eng.cancel(rq) is False          # already terminal

    ra = eng.submit(DesignRequest(config=tiny_cfg(seed=1)))
    eng.step()                              # admitted + first round
    assert eng.status(ra) == "active"
    assert eng.cancel(ra) is True
    eng.run()
    resp = eng.result(ra)
    assert resp.status == "cancelled"
    assert resp.updates[-1].kind == "cancelled"
    assert eng.stats.cancelled == 2


def test_engine_timeout_zero_never_runs():
    eng = DesignEngine()
    rid = eng.submit(DesignRequest(config=tiny_cfg(), timeout_s=0.0))
    eng.run()
    resp = eng.result(rid)
    assert resp.status == "timeout" and resp.records == []
    assert eng.stats.timeouts == 1


def test_engine_bad_config_is_isolated():
    eng = DesignEngine()
    bad = DesignRequest(config=tiny_cfg(), pareto_grid=ParetoGridSpec(
        term_weights={"no-such-term": (1.0,)}))
    rb = eng.submit(bad)
    rg = eng.submit(DesignRequest(config=tiny_cfg(seed=1)))
    eng.run()
    assert eng.result(rb).status == "error"
    assert "no-such-term" in eng.result(rb).error
    assert eng.result(rg).status == "done"  # healthy tenant unaffected
    assert eng.stats.errors == 1


def test_engine_max_active_queues_fifo():
    eng = DesignEngine(max_active=1)
    r1 = eng.submit(DesignRequest(config=tiny_cfg(seed=0)))
    r2 = eng.submit(DesignRequest(config=tiny_cfg(seed=1)))
    eng.step()
    assert eng.status(r1) == "active" and eng.status(r2) == "queued"
    eng.run()
    assert eng.result(r1).status == "done"
    assert eng.result(r2).status == "done"


def test_engine_result_none_while_running():
    eng = DesignEngine()
    rid = eng.submit(DesignRequest(config=tiny_cfg()))
    assert eng.result(rid) is None
    eng.step()
    assert eng.result(rid) is None          # still active
    eng.run()
    assert isinstance(eng.result(rid), DesignResponse)


def test_engine_evaluator_lru_eviction_counter():
    eng = DesignEngine(evaluator_cache=1)
    for seed in range(3):
        eng.submit(DesignRequest(config=tiny_cfg(
            seed=seed, algorithms=("br",))))
        eng.run()
    assert eng.stats.evaluators_built == 3
    assert eng.stats.evaluator_evictions >= 2


# ---------------------------------------------------------------------------
# Incremental fronts + population archive.
# ---------------------------------------------------------------------------

def test_incremental_front_matches_compute_front():
    cfg = tiny_cfg(algorithms=("br",))
    sw = run_pareto_sweep_entries(cfg)
    one_shot = compute_front(cfg, sw)
    inc = IncrementalFront(cfg)
    from repro.core.pareto import candidates_from_records
    cands = candidates_from_records(sw)
    inc.add(cands[:1])
    streamed = inc.add(cands[1:])
    assert streamed.hypervolume == one_shot.hypervolume
    assert len(streamed.points) == len(one_shot.points)
    for p, q in zip(streamed.points, one_shot.points):
        assert p.terms == q.terms and p.label == q.label


def run_pareto_sweep_entries(cfg):
    """Grid-expanded (label, cfg_i, objective, record) entries for cfg."""
    import dataclasses as dc
    expanded = [(label, obj, dc.replace(cfg, objective=obj))
                for label, obj in GRID.points(cfg.objective)]
    sweep = run_sweep([c for _, _, c in expanded],
                      fold_repetitions=False)
    entries = []
    for i, (label, obj, _) in enumerate(expanded):
        for rec in sweep.runs[i].records:
            entries.append((label, i, obj, rec))
    return entries


def test_archive_thickens_front_deterministically():
    cfg = tiny_cfg(algorithms=("br",), budget=Budget(evals=8))
    f0 = run_pareto(cfg, GRID)
    f1 = run_pareto(dataclasses.replace(cfg, archive_k=6), GRID)
    assert f1.n_candidates > f0.n_candidates
    assert {p.algorithm for p in f1.points} >= {"archive"} or \
        len(f1.points) >= len(f0.points)
    f2 = run_pareto(dataclasses.replace(cfg, archive_k=6), GRID)
    assert f1.to_dict() == f2.to_dict()     # archive runs reproduce


def test_archive_on_optresult_shape_and_order():
    cfg = tiny_cfg(algorithms=("br",), archive_k=5)
    res = run_sweep([cfg]).records[0].result
    assert res.archive is not None
    costs = np.asarray(res.archive["costs"])
    assert costs.shape[0] <= 5
    assert np.all(np.diff(costs) >= 0)      # sorted best-first
    assert np.all(np.isfinite(costs))
    assert np.asarray(res.archive["a"]).shape[0] == costs.shape[0]
    # the run's own best is the archive head
    assert costs[0] == pytest.approx(res.best_cost)


def test_engine_front_matches_run_pareto():
    cfg = tiny_cfg(algorithms=("br",), budget=Budget(evals=8),
                   archive_k=6)
    eng = DesignEngine()
    rid = eng.submit(DesignRequest(config=cfg, pareto_grid=GRID))
    eng.run()
    resp = eng.result(rid)
    assert resp.status == "done" and resp.front is not None
    assert any(u.kind == "front" for u in resp.updates)
    ref = run_pareto(cfg, GRID, fold_repetitions=False)
    assert resp.front.hypervolume == ref.hypervolume
    assert len(resp.front.points) == len(ref.points)


def test_stackable_steps_accessor():
    assert stackable_steps("ga") is not None
    assert stackable_steps("not-an-algo") is None


def test_design_update_serde():
    u = DesignUpdate("r1", "progress", tick=3, generation=2, best_cost=1.5)
    d = u.to_dict()
    assert d["request_id"] == "r1" and d["kind"] == "progress"
    assert d["front_size"] is None
