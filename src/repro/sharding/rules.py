"""Sharding rules: logical parameter/activation axes → PartitionSpec.

Strategy (DESIGN.md §6), per (arch, shape, mesh):

* parameters + optimizer state: FSDP over the data axes (and pod axis on the
  multi-pod mesh) on the d_model-ish dimension; tensor-parallel over `model`
  on heads / d_ff / vocab / experts;
* activations: batch over (pod, data); d_ff and (when divisible) head dims
  over `model`;
* KV caches: kv-heads over `model` when divisible, otherwise the *sequence*
  dimension over `model` (flash-decode-style split — softmax reductions over
  the sharded seq dim become small collectives);
* MoE experts: expert-parallel over `model` when n_experts % tp == 0
  (moonshot 64e), else tensor-parallel inside each expert (grok 8e).

Leaf names are globally unique across block types, so the rule table is a
flat name → trailing-dims spec map; stacked (scanned) parameters get a
leading None automatically (rank padding).
"""
from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P

from ..models.config import LMConfig
from .partition import MeshInfo, ShardingCtx

_NONE = "-"   # replicated dim marker


def _rule_table(cfg: LMConfig, mi: MeshInfo) -> dict[str, tuple]:
    fsdp = tuple(mi.fsdp) or None
    tp = mi.tp
    kv_tp = tp if (cfg.n_kv_heads * cfg.hd) % mi.tp_size == 0 else None
    ep = cfg.n_experts > 0 and cfg.n_experts % mi.tp_size == 0
    t = {
        # embeddings / head
        "embed": (tp, fsdp),
        "lm_head": (fsdp, tp),
        "front_w": (None, fsdp),
        # norms
        "norm": (None,), "q_norm": (None,), "k_norm": (None,),
        "final_norm": (None,), "enc_norm": (None,),
        # attention
        "wq": (fsdp, tp), "wk": (fsdp, kv_tp), "wv": (fsdp, kv_tp),
        "wo": (tp, fsdp),
        "bq": (tp,), "bk": (kv_tp,), "bv": (kv_tp,),
        # dense mlp
        "w1": (fsdp, tp), "w3": (fsdp, tp), "w2": (tp, fsdp),
        # router + experts
        "router": (fsdp, None),
        "we1": (tp, fsdp, None) if ep else (None, fsdp, tp),
        "we3": (tp, fsdp, None) if ep else (None, fsdp, tp),
        "we2": (tp, None, fsdp) if ep else (None, tp, fsdp),
        # mamba
        "in_proj": (fsdp, tp), "out_proj": (tp, fsdp),
        "conv_w": (None, tp), "conv_b": (tp,),
        "x_proj": (tp, None), "dt_w": (None, tp), "dt_b": (tp,),
        "A_log": (tp, None), "Dskip": (tp,),
        # rg-lru (griffin)
        "rg_in": (fsdp, tp), "rg_gate": (fsdp, tp), "rg_out": (tp, fsdp),
        "rg_conv_w": (None, tp), "rg_conv_b": (tp,),
        "rg_a": (None, tp), "rg_i": (None, tp), "rg_lambda": (tp,),
    }
    return t


def param_pspecs(cfg: LMConfig, params, mi: MeshInfo):
    """PartitionSpec pytree matching ``params`` (works on ShapeDtypeStructs).

    A leaf's rule comes from its dict key; extra leading dims (layer stacking)
    are replicated.  Unknown leaves are replicated (and listed for review via
    ``unknown_leaves``).
    """
    table = _rule_table(cfg, mi)

    def spec_of(path, leaf):
        names = [str(e.key) for e in path
                 if isinstance(e, jax.tree_util.DictKey)]
        name = names[-1] if names else None
        q8 = None
        if name in ("q", "s") and len(names) >= 2:   # 8-bit Adam state
            q8, name = name, names[-2]
        rule = table.get(name)
        ndim = len(leaf.shape)
        if rule is None:
            return P()
        rule = tuple(rule)
        if q8 == "s":               # row scales: drop the last (quantized) dim
            rule = rule[:-1]
        if len(rule) > ndim:        # e.g. bias tables on unstacked use
            rule = rule[-ndim:]
        pad = ndim - len(rule)
        return P(*((None,) * pad + rule))

    return jax.tree_util.tree_map_with_path(spec_of, params)


def unknown_leaves(cfg: LMConfig, params, mi: MeshInfo) -> list[str]:
    table = _rule_table(cfg, mi)
    out = []

    def visit(path, leaf):
        names = [str(e.key) for e in path
                 if isinstance(e, jax.tree_util.DictKey)]
        if not names or names[-1] not in table:
            out.append("/".join(names))
        return leaf

    jax.tree_util.tree_map_with_path(visit, params)
    return out


def activation_specs(cfg: LMConfig, mi: MeshInfo, *,
                     cache_len: int = 0,
                     seq_shard_attn: bool = False) -> dict[str, P]:
    """Logical activation name → PartitionSpec (see layers.shard calls).

    ``seq_shard_attn``: when query heads cannot shard over the model axis
    (smollm 15H), shard the attention *sequence* dim instead (context
    parallel) — replicated-head attention otherwise wastes tp_size x the
    flops/bytes (§Perf iteration A1).  Only valid for S > 1 paths.
    """
    dp = tuple(mi.dp) or None
    tp = mi.tp
    tp_n = mi.tp_size
    heads_div = cfg.n_heads_p % tp_n == 0
    heads_ax = tp if heads_div else None
    kv_ax = tp if cfg.n_kv_heads % tp_n == 0 else None
    q_spec = (P(dp, None, heads_ax, None) if heads_div or not seq_shard_attn
              else P(dp, tp, None, None))
    # KV cache: prefer head sharding; fall back to sequence sharding.
    if kv_ax is not None:
        cache_spec = P(dp, None, kv_ax, None)
    elif cache_len and cache_len % tp_n == 0:
        cache_spec = P(dp, tp, None, None)
    else:
        cache_spec = P(dp, None, None, None)
    ep = cfg.n_experts > 0 and cfg.n_experts % tp_n == 0
    return {
        "act": P(dp, None, None),
        "act_ff": P(dp, None, tp),
        "act_heads": q_spec,
        "act_kv": P(dp, None, kv_ax, None),
        "cache": cache_spec,
        "logits": P(dp, None, tp),
        "batch": P(dp, None),
        # MoE dispatch buffers: (B, E, C, D) / (B, E, C, F)
        "moe_disp": P(dp, tp if ep else None, None, None),
        "moe_ff": P(dp, tp if ep else None, None, None if ep else tp),
        # mamba / rg-lru inner activations: (B, S, d_inner)
        "act_inner": P(dp, None, tp),
        # recurrent states: (B, d_inner[, N]) / (B, d_rnn)
        "state": P(dp, tp),
    }


def make_ctx(cfg: LMConfig, mi: MeshInfo, *, cache_len: int = 0,
             seq_shard_attn: bool = False) -> ShardingCtx:
    return ShardingCtx(mi=mi, act_specs=activation_specs(
        cfg, mi, cache_len=cache_len, seq_shard_attn=seq_shard_attn))


def cache_pspecs(cfg: LMConfig, cache_tree, mi: MeshInfo, *,
                 cache_len: int = 0):
    """PartitionSpecs for decode caches (leaves: k/v, conv, h)."""
    acts = activation_specs(cfg, mi, cache_len=cache_len)
    dp, tp, tp_n = tuple(mi.dp) or None, mi.tp, mi.tp_size
    inner_ax = tp if cfg.d_inner % tp_n == 0 else None
    rnn_ax = tp if cfg.d_rnn_ % tp_n == 0 else None

    def spec_of(path, leaf):
        name = None
        for e in reversed(path):
            if isinstance(e, jax.tree_util.DictKey):
                name = str(e.key)
                break
        nd = len(leaf.shape)
        if name in ("k", "v"):
            rule = tuple(acts["cache"])
        elif name == "conv":
            ax = rnn_ax if cfg.family == "hybrid" else inner_ax
            rule = (dp, None, ax)
        elif name == "h":
            # mamba h: (B, Di, N); rg-lru h: (B, D_rnn)
            rule = (dp, inner_ax, None) if cfg.family == "ssm" \
                else (dp, rnn_ax)
        else:
            rule = (dp,)
        rule = tuple(rule)[:nd]
        pad = nd - len(rule)
        return P(*((None,) * pad + rule))

    return jax.tree_util.tree_map_with_path(spec_of, cache_tree)


def batch_pspecs(batch_tree, mi: MeshInfo):
    """Batch inputs: leading dim over the data axes, rest replicated."""
    dp = tuple(mi.dp) or None

    def spec_of(leaf):
        nd = len(leaf.shape)
        return P(*((dp,) + (None,) * (nd - 1)))

    return jax.tree.map(spec_of, batch_tree)
