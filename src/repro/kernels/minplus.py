"""Pallas TPU kernels for min-plus / APSP — PlaceIT's scoring hot spot.

PlaceIT evaluates thousands of placements; every evaluation runs an
all-pairs-shortest-path with path *counting* over the PHY-level latency
graph (V = #PHYs + 2*#chiplets, a few hundred nodes).  On TPU the XLA
`fori_loop` formulation round-trips the (V, V) distance and count matrices
through HBM on every one of the V rank-1 relaxation steps.  Both kernels
below keep the working set VMEM-resident instead:

* ``fw_counts_pallas`` — batched whole-matrix Floyd-Warshall **with path
  counts**: one grid program per placement, (V, V) D and N matrices live in
  VMEM for the entire V-step relaxation.  This is the kernel the scorer
  uses (exact same math as ``ref.fw_counts_ref``).  V is padded to a
  multiple of 128 (lane width) with isolated nodes.

* ``minplus_tiled_pallas`` — blocked tropical matmul (distances only) for
  graphs too large for a VMEM-resident FW; the classic (i, j, k) tiling
  with an accumulate-min inner loop.  Used for beyond-paper-scale APSP via
  repeated squaring.

* ``fw_counts_tiled_pallas`` — blocked-tile Floyd-Warshall **with path
  counts** for the 100+-chiplet regime (HexaMesh scale), where 3 x (V, V)
  float32 no longer fits VMEM.  The classic three-phase blocked FW
  (diagonal block -> row/col panels -> outer tiles), batched over
  placements; each grid program's (D, N) working set is one (bt, bt)
  tile.  Bit-for-bit equal to ``ref.fw_counts_ref`` — see the per-pivot
  snapshot scheme below.

Hardware note (DESIGN.md §3): (min, +) has no MXU mapping — these are VPU
kernels; tiles are (8k, 128)-aligned.  Off-TPU all kernels default to
interpret mode (``interpret=None`` auto-selects from the JAX backend).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from . import _compat

INF_CUT = 1.0e8
_COUNT_CLIP = 1.0e30


def _default_interpret() -> bool:
    """Interpret off-TPU, compile on TPU — callers no longer thread the
    flag; pass an explicit bool to override."""
    return jax.default_backend() != "tpu"


def _resolve_interpret(interpret) -> bool:
    return _default_interpret() if interpret is None else bool(interpret)


# ---------------------------------------------------------------------------
# Batched VMEM-resident Floyd-Warshall with path counts.
# ---------------------------------------------------------------------------

def _fw_counts_kernel(w_ref, d_ref, n_ref, *, V: int):
    W = w_ref[0]                                   # (V, V) fp32 in VMEM
    row = jax.lax.broadcasted_iota(jnp.int32, (V, V), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (V, V), 1)
    eye = (row == col)
    N0 = jnp.where((W < INF_CUT) & ~eye, 1.0, 0.0) + eye.astype(W.dtype)

    def body(k, carry):
        D, N = carry
        dik = jax.lax.dynamic_slice(D, (0, k), (V, 1))     # column k
        dkj = jax.lax.dynamic_slice(D, (k, 0), (1, V))     # row k
        nik = jax.lax.dynamic_slice(N, (0, k), (V, 1))
        nkj = jax.lax.dynamic_slice(N, (k, 0), (1, V))
        cand = dik + dkj
        ncand = jnp.minimum(nik * nkj, _COUNT_CLIP)
        notk = (row != k) & (col != k)
        lt = (cand < D) & notk
        eq = (cand == D) & notk & (cand < INF_CUT)
        D = jnp.where(lt, cand, D)
        N = jnp.where(lt, ncand, N + jnp.where(eq, ncand, 0.0))
        N = jnp.minimum(N, _COUNT_CLIP)
        return D, N

    D, N = jax.lax.fori_loop(0, V, body, (W, N0))
    d_ref[0] = D
    n_ref[0] = N


def _pad_isolated(W: jnp.ndarray, Vp: int) -> jnp.ndarray:
    """Pad [B, V, V] up to [B, Vp, Vp] with isolated nodes (diag 0, else
    INF); padded rows/cols never interact with real nodes, so the result
    restricted to real indices is bit-for-bit the unpadded computation."""
    B, V0, _ = W.shape
    if Vp == V0:
        return W
    pad = jnp.full((B, Vp, Vp), 1e9, dtype=W.dtype)
    pad = pad.at[:, :V0, :V0].set(W)
    idx = jnp.arange(V0, Vp)
    return pad.at[:, idx, idx].set(0.0)


def fw_counts_pallas(W: jnp.ndarray, *, interpret: bool | None = None
                     ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Batched FW + counts.  W: [B, V, V] float32, V % 128 == 0 preferred.

    Pads V up to a multiple of 128 with isolated nodes (diag 0, else INF);
    padded rows/cols do not interact with real nodes.
    """
    interpret = _resolve_interpret(interpret)
    squeeze = W.ndim == 2
    if squeeze:
        W = W[None]
    B, V0, _ = W.shape
    Vp = max(128, -(-V0 // 128) * 128)
    W = _pad_isolated(W, Vp)
    kern = functools.partial(_fw_counts_kernel, V=Vp)
    D, N = pl.pallas_call(
        kern,
        grid=(B,),
        in_specs=[pl.BlockSpec((1, Vp, Vp), lambda b: (b, 0, 0))],
        out_specs=[pl.BlockSpec((1, Vp, Vp), lambda b: (b, 0, 0)),
                   pl.BlockSpec((1, Vp, Vp), lambda b: (b, 0, 0))],
        out_shape=[jax.ShapeDtypeStruct((B, Vp, Vp), W.dtype),
                   jax.ShapeDtypeStruct((B, Vp, Vp), W.dtype)],
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(W)
    D, N = D[:, :V0, :V0], N[:, :V0, :V0]
    if squeeze:
        D, N = D[0], N[0]
    return D, N


# ---------------------------------------------------------------------------
# Blocked-tile Floyd-Warshall WITH path counts (the 100+-chiplet regime).
#
# Naive blocked FW (fully relax the pivot block and panels, then one
# min-plus GEMM over the outer tiles) is correct for distances but WRONG
# for path counts: replaying a whole pivot block against an outer tile
# with end-of-block panel values double-counts paths that tie through
# several pivots.  The scheme below is exact — bit-for-bit equal to the
# sequential ``fw_counts_ref`` — because every tile replays the reference's
# per-pivot rank-1 updates with the reference's operands:
#
# * Pivots k inside a block are processed strictly in order.  At pivot k,
#   row k and column k are themselves masked from the update (the ``notk``
#   mask), so their time-k values equal their state after pivots < k.
# * Phase 1 (diagonal block) records, for each local pivot k, *snapshots*
#   of row k and column k at time k.  Phase 2 (row/col panels) consumes
#   the diagonal snapshots and records full panel snapshots at time k.
#   Phase 3 (outer tiles) replays the per-pivot updates from the column-
#   and row-panel snapshots.  Each (cell, pivot) update therefore sees
#   exactly the operands the sequential algorithm saw, in the same order,
#   evaluated by the same jnp expressions — float32 equality is bitwise,
#   not approximate (no re-association anywhere).
# * Phase 3 must *skip* the pivot row/col tiles (min is idempotent for D,
#   but N's tie-accumulation is not) — they were already updated exactly
#   once by phases 1/2.
#
# D and N live in HBM between the per-pivot-block pallas_calls (a host
# Python loop unrolled at trace time); each grid program touches only
# (bt, bt) tiles, so VMEM stays O(bt^2) regardless of V.
# ---------------------------------------------------------------------------

def _fw_init_counts(W: jnp.ndarray) -> jnp.ndarray:
    """N0: 1 for finite off-diagonal edges, identity diagonal (== ref)."""
    V = W.shape[-1]
    eye = jnp.eye(V, dtype=bool)
    return jnp.where((W < INF_CUT) & ~eye, 1.0, 0.0) + eye.astype(W.dtype)


def _fw_step(Td, Tn, a_d, a_n, b_d, b_n, mask):
    """One rank-1 pivot update on a tile — the exact ref.fw_counts_ref
    expressions (operand order preserved for bitwise equality).  ``mask``
    is the ``notk`` mask restricted to the tile (or None when the tile
    provably excludes row/col k)."""
    cand = a_d + b_d
    ncand = jnp.minimum(a_n * b_n, _COUNT_CLIP)
    lt = cand < Td
    eq = (cand == Td) & (cand < INF_CUT)
    if mask is not None:
        lt = lt & mask
        eq = eq & mask
    Td = jnp.where(lt, cand, Td)
    Tn = jnp.where(lt, ncand, Tn + jnp.where(eq, ncand, 0.0))
    Tn = jnp.minimum(Tn, _COUNT_CLIP)
    return Td, Tn


def _fw_diag_kernel(d_ref, n_ref, do_ref, no_ref, rd_ref, rn_ref,
                    cd_ref, cn_ref, *, bt: int):
    """Phase 1: relax the (bt, bt) pivot block over its own bt pivots,
    emitting per-pivot row snapshots (rd/rn, row k at time k) and column
    snapshots (cd/cn, column k at time k)."""
    row = jax.lax.broadcasted_iota(jnp.int32, (bt, bt), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (bt, bt), 1)

    def body(k, carry):
        D, N, RD, RN, CD, CN = carry
        b_d = jax.lax.dynamic_slice(D, (k, 0), (1, bt))   # row k @ time k
        b_n = jax.lax.dynamic_slice(N, (k, 0), (1, bt))
        a_d = jax.lax.dynamic_slice(D, (0, k), (bt, 1))   # col k @ time k
        a_n = jax.lax.dynamic_slice(N, (0, k), (bt, 1))
        RD = jax.lax.dynamic_update_slice(RD, b_d, (k, 0))
        RN = jax.lax.dynamic_update_slice(RN, b_n, (k, 0))
        CD = jax.lax.dynamic_update_slice(CD, a_d, (0, k))
        CN = jax.lax.dynamic_update_slice(CN, a_n, (0, k))
        D, N = _fw_step(D, N, a_d, a_n, b_d, b_n, (row != k) & (col != k))
        return D, N, RD, RN, CD, CN

    z = jnp.zeros((bt, bt), d_ref.dtype)
    D, N, RD, RN, CD, CN = jax.lax.fori_loop(
        0, bt, body, (d_ref[0], n_ref[0], z, z, z, z))
    do_ref[0], no_ref[0] = D, N
    rd_ref[0], rn_ref[0] = RD, RN
    cd_ref[0], cn_ref[0] = CD, CN


def _fw_panel_kernel(d_ref, n_ref, sd_ref, sn_ref, dd_ref, dn_ref,
                     ds2_ref, ds3_ref, od_ref, on_ref, pd_ref, pn_ref,
                     *, bt: int, kk: int, is_row: bool):
    """Phase 2: relax one (bt, bt) panel tile over the block's bt pivots,
    consuming the diagonal snapshots (sd/sn) and emitting this panel's own
    per-pivot snapshots (pd/pn).  The tile at the pivot block itself
    (j == kk) copies phase 1's results instead of re-updating (N's
    tie-accumulation is not idempotent).

    Row panels (is_row): tile rows are the pivot rows; the pivot's "a"
    operand D[i, k] is the diagonal *column* snapshot, the "b" operand
    D[k, j] is the tile's own row k (masked at pivot k, so current ==
    time-k).  Col panels are the transpose."""
    j = pl.program_id(1)

    @pl.when(j == kk)
    def _copy_diag():
        od_ref[0], on_ref[0] = dd_ref[0], dn_ref[0]
        pd_ref[0], pn_ref[0] = ds2_ref[0], ds3_ref[0]

    @pl.when(j != kk)
    def _relax():
        SD, SN = sd_ref[0], sn_ref[0]
        iot = jax.lax.broadcasted_iota(jnp.int32, (bt, bt), 0 if is_row
                                       else 1)

        def body(k, carry):
            D, N, PD, PN = carry
            if is_row:
                own_d = jax.lax.dynamic_slice(D, (k, 0), (1, bt))
                own_n = jax.lax.dynamic_slice(N, (k, 0), (1, bt))
                PD = jax.lax.dynamic_update_slice(PD, own_d, (k, 0))
                PN = jax.lax.dynamic_update_slice(PN, own_n, (k, 0))
                a_d = jax.lax.dynamic_slice(SD, (0, k), (bt, 1))
                a_n = jax.lax.dynamic_slice(SN, (0, k), (bt, 1))
                b_d, b_n = own_d, own_n
            else:
                own_d = jax.lax.dynamic_slice(D, (0, k), (bt, 1))
                own_n = jax.lax.dynamic_slice(N, (0, k), (bt, 1))
                PD = jax.lax.dynamic_update_slice(PD, own_d, (0, k))
                PN = jax.lax.dynamic_update_slice(PN, own_n, (0, k))
                b_d = jax.lax.dynamic_slice(SD, (k, 0), (1, bt))
                b_n = jax.lax.dynamic_slice(SN, (k, 0), (1, bt))
                a_d, a_n = own_d, own_n
            D, N = _fw_step(D, N, a_d, a_n, b_d, b_n, iot != k)
            return D, N, PD, PN

        z = jnp.zeros((bt, bt), d_ref.dtype)
        D, N, PD, PN = jax.lax.fori_loop(
            0, bt, body, (d_ref[0], n_ref[0], z, z))
        od_ref[0], on_ref[0] = D, N
        pd_ref[0], pn_ref[0] = PD, PN


def _fw_outer_kernel(d_ref, n_ref, cd_ref, cn_ref, rd_ref, rn_ref,
                     od_ref, on_ref, *, bt: int, kk: int):
    """Phase 3: replay the block's bt pivots on one outer (bt, bt) tile
    from the col-panel (cd/cn) and row-panel (rd/rn) snapshots.  Pivot
    row/col tiles pass through unchanged — they were already updated by
    phases 1/2 (re-applying would double-count N ties)."""
    i, j = pl.program_id(1), pl.program_id(2)

    @pl.when((i == kk) | (j == kk))
    def _copy():
        od_ref[0], on_ref[0] = d_ref[0], n_ref[0]

    @pl.when((i != kk) & (j != kk))
    def _relax():
        CD, CN = cd_ref[0], cn_ref[0]
        RD, RN = rd_ref[0], rn_ref[0]

        def body(k, carry):
            D, N = carry
            a_d = jax.lax.dynamic_slice(CD, (0, k), (bt, 1))
            a_n = jax.lax.dynamic_slice(CN, (0, k), (bt, 1))
            b_d = jax.lax.dynamic_slice(RD, (k, 0), (1, bt))
            b_n = jax.lax.dynamic_slice(RN, (k, 0), (1, bt))
            return _fw_step(D, N, a_d, a_n, b_d, b_n, None)

        D, N = jax.lax.fori_loop(0, bt, body, (d_ref[0], n_ref[0]))
        od_ref[0], on_ref[0] = D, N


def fw_counts_tiled_pallas(W: jnp.ndarray, *, bt: int = 128,
                           interpret: bool | None = None
                           ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Blocked three-phase FW + path counts; bit-for-bit == fw_counts_ref.

    W: [B, V, V] (or [V, V]) float32 with 0 diagonal.  V is padded to a
    multiple of ``bt`` with isolated nodes.  Per-grid-program working set
    is O(bt^2) — use this when 3 x V^2 x 4B exceeds VMEM (see
    ``ops.FW_TILED_AUTO_V`` for the dispatch knee).
    """
    interpret = _resolve_interpret(interpret)
    squeeze = W.ndim == 2
    if squeeze:
        W = W[None]
    B, V0, _ = W.shape
    Vt = max(bt, -(-V0 // bt) * bt)
    nb = Vt // bt
    W = _pad_isolated(W, Vt)
    D, N = W, _fw_init_counts(W)

    spec = pl.BlockSpec((1, bt, bt), lambda b: (b, 0, 0))
    shp = jax.ShapeDtypeStruct((B, bt, bt), W.dtype)

    for kk in range(nb):
        k0 = kk * bt
        # -- phase 1: pivot block + per-pivot row/col snapshots ------------
        dD = jax.lax.dynamic_slice(D, (0, k0, k0), (B, bt, bt))
        dN = jax.lax.dynamic_slice(N, (0, k0, k0), (B, bt, bt))
        dD2, dN2, rdD, rdN, cdD, cdN = pl.pallas_call(
            functools.partial(_fw_diag_kernel, bt=bt),
            grid=(B,),
            in_specs=[spec, spec],
            out_specs=[spec] * 6,
            out_shape=[shp] * 6,
            compiler_params=_compat.CompilerParams(
                dimension_semantics=("parallel",)),
            interpret=interpret,
        )(dD, dN)

        # -- phase 2: row + col panels, emitting panel snapshots -----------
        tile_j = pl.BlockSpec((1, bt, bt), lambda b, j: (b, 0, j))
        tile_i = pl.BlockSpec((1, bt, bt), lambda b, j: (b, j, 0))
        fixed = pl.BlockSpec((1, bt, bt), lambda b, j: (b, 0, 0))
        row_shp = jax.ShapeDtypeStruct((B, bt, Vt), W.dtype)
        col_shp = jax.ShapeDtypeStruct((B, Vt, bt), W.dtype)
        rowD = jax.lax.dynamic_slice(D, (0, k0, 0), (B, bt, Vt))
        rowN = jax.lax.dynamic_slice(N, (0, k0, 0), (B, bt, Vt))
        rowD2, rowN2, rsD, rsN = pl.pallas_call(
            functools.partial(_fw_panel_kernel, bt=bt, kk=kk, is_row=True),
            grid=(B, nb),
            in_specs=[tile_j, tile_j] + [fixed] * 6,
            out_specs=[tile_j] * 4,
            out_shape=[row_shp] * 4,
            compiler_params=_compat.CompilerParams(
                dimension_semantics=("parallel", "parallel")),
            interpret=interpret,
        )(rowD, rowN, cdD, cdN, dD2, dN2, rdD, rdN)
        colD = jax.lax.dynamic_slice(D, (0, 0, k0), (B, Vt, bt))
        colN = jax.lax.dynamic_slice(N, (0, 0, k0), (B, Vt, bt))
        colD2, colN2, csD, csN = pl.pallas_call(
            functools.partial(_fw_panel_kernel, bt=bt, kk=kk, is_row=False),
            grid=(B, nb),
            in_specs=[tile_i, tile_i] + [fixed] * 6,
            out_specs=[tile_i] * 4,
            out_shape=[col_shp] * 4,
            compiler_params=_compat.CompilerParams(
                dimension_semantics=("parallel", "parallel")),
            interpret=interpret,
        )(colD, colN, rdD, rdN, dD2, dN2, cdD, cdN)
        D = jax.lax.dynamic_update_slice(D, rowD2, (0, k0, 0))
        N = jax.lax.dynamic_update_slice(N, rowN2, (0, k0, 0))
        D = jax.lax.dynamic_update_slice(D, colD2, (0, 0, k0))
        N = jax.lax.dynamic_update_slice(N, colN2, (0, 0, k0))

        # -- phase 3: outer tiles from the panel snapshots -----------------
        full = pl.BlockSpec((1, bt, bt), lambda b, i, j: (b, i, j))
        cpan = pl.BlockSpec((1, bt, bt), lambda b, i, j: (b, i, 0))
        rpan = pl.BlockSpec((1, bt, bt), lambda b, i, j: (b, 0, j))
        D, N = pl.pallas_call(
            functools.partial(_fw_outer_kernel, bt=bt, kk=kk),
            grid=(B, nb, nb),
            in_specs=[full, full, cpan, cpan, rpan, rpan],
            out_specs=[full, full],
            out_shape=[jax.ShapeDtypeStruct((B, Vt, Vt), W.dtype)] * 2,
            compiler_params=_compat.CompilerParams(
                dimension_semantics=("parallel", "parallel", "parallel")),
            interpret=interpret,
        )(D, N, csD, csN, rsD, rsN)

    D, N = D[:, :V0, :V0], N[:, :V0, :V0]
    if squeeze:
        D, N = D[0], N[0]
    return D, N


# ---------------------------------------------------------------------------
# Tiled min-plus matmul (distances only) for large V.
# ---------------------------------------------------------------------------

def _minplus_kernel(a_ref, b_ref, o_ref, *, bk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.full_like(o_ref, 1e9)

    a = a_ref[...]                                  # (bm, bk)
    b = b_ref[...]                                  # (bk, bn)

    def body(t, acc):
        # Rank-1 (min, +) update: keeps the working set at (bm, bn).
        return jnp.minimum(acc, a[:, t][:, None] + b[t, :][None, :])

    o_ref[...] = jax.lax.fori_loop(0, bk, body, o_ref[...])


def minplus_tiled_pallas(A: jnp.ndarray, B: jnp.ndarray, *,
                         bm: int = 128, bn: int = 128, bk: int = 128,
                         interpret: bool | None = None) -> jnp.ndarray:
    """Tropical matmul out[i,j] = min_k A[i,k] + B[k,j], tiled for VMEM.

    A: [M, K], B: [K, N]; M, N, K padded to tile multiples with +INF
    (identity of min) — padding never wins the min.
    """
    interpret = _resolve_interpret(interpret)
    M, K = A.shape
    K2, N = B.shape
    assert K == K2
    Mp, Kp, Np = (-(-M // bm) * bm, -(-K // bk) * bk, -(-N // bn) * bn)
    Ap = jnp.full((Mp, Kp), 1e9, A.dtype).at[:M, :K].set(A)
    Bp = jnp.full((Kp, Np), 1e9, B.dtype).at[:K, :N].set(B)
    out = pl.pallas_call(
        functools.partial(_minplus_kernel, bk=bk),
        grid=(Mp // bm, Np // bn, Kp // bk),
        in_specs=[pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
                  pl.BlockSpec((bk, bn), lambda i, j, k: (k, j))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), A.dtype),
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(Ap, Bp)
    return out[:M, :N]


def apsp_tiled_pallas(W: jnp.ndarray, *, interpret: bool | None = None,
                      **tile_kw) -> jnp.ndarray:
    """APSP by repeated tiled min-plus squaring (distances only).

    ceil(log2(V-1)) squarings suffice: after t rounds D covers all paths
    of <= 2^t hops, and a shortest path has at most V-1 hops.  V is a
    Python int here, so the count is host math, not a traced op.
    """
    V = W.shape[-1]
    D = W
    n_iter = max(1, math.ceil(math.log2(max(V - 1, 2))))
    for _ in range(n_iter):
        D = jnp.minimum(D, minplus_tiled_pallas(D, D, interpret=interpret,
                                                **tile_kw))
    return D
