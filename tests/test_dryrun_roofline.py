"""Dry-run machinery (reduced mesh, subprocess) + roofline math."""
import json
import os
import subprocess
import sys

import pytest

from repro.configs import all_cells
from repro.launch.roofline import (active_params, model_flops, roofline_row)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_all_cells_count():
    assert len(all_cells()) == 32          # 10*3 + 2 long_500k


def test_active_params_moe():
    dense = active_params("qwen3-1.7b")
    assert dense > 1.9e9
    grok_total = 316e9
    grok_active = active_params("grok-1-314b")
    # top-2 of 8 experts: active well below total, above attention-only
    assert 6e10 < grok_active < 1.2e11
    moon = active_params("moonshot-v1-16b-a3b")
    assert 2e9 < moon < 4.5e9              # "A3B"


def test_model_flops_shapes():
    t = model_flops("qwen3-1.7b", "train_4k")
    p = model_flops("qwen3-1.7b", "prefill_32k")
    d = model_flops("qwen3-1.7b", "decode_32k")
    assert t == pytest.approx(6 * active_params("qwen3-1.7b") * 256 * 4096)
    assert p == pytest.approx(2 * active_params("qwen3-1.7b") * 32 * 32768)
    assert d == pytest.approx(2 * active_params("qwen3-1.7b") * 128)


def test_roofline_row_math():
    rec = {
        "arch": "qwen3-1.7b", "shape": "decode_32k", "mesh": "single",
        "n_chips": 256,
        "flops_total": 197e12 * 0.001,          # 1 ms compute
        "bytes_accessed_total": 819e9 * 0.004,  # 4 ms memory
        "collectives": {"wire_bytes_per_chip": 50e9 * 0.002},
        "memory_analysis": {"argument_size_in_bytes": int(8e9),
                            "temp_size_in_bytes": int(2e9),
                            "output_size_in_bytes": int(1e9),
                            "alias_size_in_bytes": int(1e9)},
    }
    row = roofline_row(rec)
    assert row["dominant"] == "memory"
    assert row["t_memory_s"] == pytest.approx(0.004)
    assert row["hbm_gb_per_chip"] == pytest.approx(10.0)
    assert row["fits_16gb"]


@pytest.mark.slow
def test_dryrun_subprocess_small_mesh(tmp_path):
    """The real dry-run driver on a reduced 2x4 mesh (8 host devices):
    lower + compile + analyses for one full-config cell."""
    env = dict(os.environ,
               REPRO_DRYRUN_DEVICES="8",
               REPRO_TEST_MESH="2x4",
               PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "seamless-m4t-medium", "--shape", "decode_32k",
         "--mesh", "single", "--out", str(tmp_path)],
        env=env, capture_output=True, text=True, timeout=900)
    assert "[OK ]" in out.stdout, out.stdout + out.stderr
    rec = json.load(open(
        tmp_path / "seamless-m4t-medium__decode_32k__single.json"))
    assert rec["ok"]
    assert rec["flops_total"] > 0
    assert rec["collectives"]["wire_bytes_per_chip"] >= 0
    assert rec["memory_analysis"]["argument_size_in_bytes"] > 0
