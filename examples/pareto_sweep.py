"""Pareto sweep + constraint hardening: the multi-objective workflow.

  PYTHONPATH=src python examples/pareto_sweep.py

PlaceIT's cost function scalarizes a multi-objective space (latency vs
throughput vs area, paper §IV-B).  This example

1. sweeps a grid of latency/throughput weightings — every scalarization
   shares ONE compiled scorer (weights are runtime vectors) and the grid
   executes in lockstep-stacked scoring calls — and prints the resulting
   non-dominated front with its hypervolume;
2. re-runs the best trade-off with a constraint-hardening schedule: a
   router-radix penalty (``node-degree``) ramped from 0 to full strength
   over the run, so the search explores freely early and lands on a
   feasible placement late.
"""
import numpy as np

from repro.core.api import Budget, ExperimentConfig, run_experiment
from repro.core.objective import Objective, Schedule, TermSpec
from repro.core.pareto import ParetoGridSpec, run_pareto_sweep


def main():
    base = ExperimentConfig(
        arch="hetero32", algorithms=("ga-batched",),
        budget=Budget(evals=60), norm_samples=16, chunk=8, seed=0,
        params={"ga-batched": {"population": 10, "elitism": 2,
                               "tournament": 3}})
    grid = ParetoGridSpec(term_weights={"lat": (0.5, 1.0, 2.0),
                                        "inv-thr": (0.5, 2.0)})
    print(f"== Pareto sweep: {grid.n_points} scalarizations of "
          f"{base.arch} ==")
    res = run_pareto_sweep(base, grid)
    print(f"scorers compiled: {res.stats.scorers_built} "
          f"(shared across the whole grid); lockstep groups: "
          f"{res.stats.stacked_groups}; scorer dispatches: "
          f"{res.stats.score_calls}")
    (front,) = res.fronts
    print(f"\nfront: {len(front.points)} non-dominated of "
          f"{front.n_candidates} candidates; hypervolume "
          f"{front.hypervolume:.4f} vs ref {np.round(front.ref_point, 3)}")
    print(f"terms: {front.term_names}")
    for p in front.points:
        print(f"  {p.label:24s} terms={np.round(p.terms, 3)} "
              f"cost(own)={p.cost:.3f}")

    print("\n== Constraint hardening: node-degree <= 1 (router radix) ==")
    pen = base.objective.with_terms(
        TermSpec("node-degree", weight=50.0, params={"max_degree": 1}))
    sched = Schedule(ramps={"node-degree": {"kind": "linear",
                                            "start": 0.0, "end": 1.0}})
    hard = ExperimentConfig.from_dict({**base.to_dict(),
                                       "objective": pen.to_dict(),
                                       "schedule": sched.to_dict()})
    (rec,) = run_experiment(hard)
    print(f"ramped best cost (final weights): {rec.result.best_cost:.3f}")
    print("serialized schedule:", sched.to_json().replace("\n", " "))


if __name__ == "__main__":
    main()
