"""Batched serving engine: prefill + decode with slot-based continuous
batching.

The engine owns a fixed pool of ``n_slots`` sequences and one jitted decode
step over the whole pool (static shapes — one compile).  Requests join free
slots via per-request prefill; every engine tick decodes all active slots in
one batched call; finished slots (EOS or max_tokens) free immediately and
the queue refills them — the vLLM-style loop reduced to its JAX-native
essentials.  Slot state lives in the pooled KV cache; joining writes the
request's prefilled cache into its slot with ``tree_map`` dynamic updates.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..models.model import Model


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # [S] int32
    max_tokens: int = 32
    out_tokens: list = field(default_factory=list)
    done: bool = False


@dataclass
class EngineConfig:
    n_slots: int = 4
    cache_len: int = 256
    eos: int = 2
    temperature: float = 0.0           # 0 -> greedy


class ServeEngine:
    def __init__(self, model: Model, params, cfg: EngineConfig):
        self.model = model
        self.params = params
        self.cfg = cfg
        self.caches = model.init_cache(cfg.n_slots, cfg.cache_len)
        self.lengths = np.zeros(cfg.n_slots, np.int32)
        self.last_tok = np.zeros(cfg.n_slots, np.int32)
        self.slot_req: list[Request | None] = [None] * cfg.n_slots
        self.queue: list[Request] = []
        self._decode = jax.jit(model.decode_step)
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, cfg.cache_len))

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)

    def _join(self, slot: int, req: Request):
        B = 1
        prompt = jnp.asarray(req.prompt[None], jnp.int32)
        logits, cache1 = self._prefill(self.params, {"tokens": prompt})
        # Write the single-row prefill cache into the pooled cache at `slot`.
        self.caches = jax.tree.map(
            lambda pool, one: pool.at[:, slot].set(one[:, 0]),
            self.caches, cache1)
        tok = self._sample(np.asarray(logits)[0])
        self.slot_req[slot] = req
        self.lengths[slot] = len(req.prompt)
        self.last_tok[slot] = tok
        req.out_tokens.append(int(tok))

    def _sample(self, logits: np.ndarray) -> int:
        if self.cfg.temperature <= 0:
            return int(np.argmax(logits))
        p = np.exp((logits - logits.max()) / self.cfg.temperature)
        p /= p.sum()
        return int(np.random.default_rng(0).choice(len(p), p=p))

    def _retire(self, slot: int):
        req = self.slot_req[slot]
        req.done = True
        self.slot_req[slot] = None
        self.lengths[slot] = 0

    # ------------------------------------------------------------------
    def step(self):
        """One engine tick: refill slots, batched decode, retire finished."""
        for slot in range(self.cfg.n_slots):
            if self.slot_req[slot] is None and self.queue:
                self._join(slot, self.queue.pop(0))
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return False
        batch = {
            "tokens": jnp.asarray(self.last_tok[:, None], jnp.int32),
            "lengths": jnp.asarray(self.lengths, jnp.int32),
        }
        logits, self.caches = self._decode(self.params, batch, self.caches)
        logits = np.asarray(logits)
        for slot in active:
            tok = self._sample(logits[slot])
            req = self.slot_req[slot]
            req.out_tokens.append(tok)
            self.lengths[slot] += 1
            self.last_tok[slot] = tok
            hit_eos = tok == self.cfg.eos
            full = (len(req.out_tokens) >= req.max_tokens
                    or int(self.lengths[slot]) + 1 >= self.cfg.cache_len)
            if hit_eos or full:
                self._retire(slot)
        return True

    def run(self, max_ticks: int = 10_000) -> int:
        ticks = 0
        while ticks < max_ticks and (self.queue
                                     or any(self.slot_req)):
            if not self.step():
                break
            ticks += 1
        return ticks
