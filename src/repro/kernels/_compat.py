"""Version compatibility for Pallas TPU APIs.

jax < 0.5 spells the Mosaic compiler-params class ``TPUCompilerParams``;
newer jax uses ``CompilerParams``.  Kernel modules import the alias from
here instead of monkey-patching the jax module globally.
"""
from __future__ import annotations

import jax.experimental.pallas.tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams
