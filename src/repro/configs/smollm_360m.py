"""Assigned architecture config: smollm-360m (see registry for source).

Exposes CONFIG (exact published hyper-parameters) and SMOKE (reduced copy
for CPU smoke tests).  Select with ``--arch smollm-360m``.
"""
from .registry import get_config

CONFIG = get_config("smollm-360m")
SMOKE = CONFIG.reduced()
