"""Assigned-architecture configs (one module per arch) + registry."""
from .registry import (ARCHS, SHAPES, all_cells, cache_specs,
                       eligible_shapes, get_config, input_specs)

__all__ = ["ARCHS", "SHAPES", "all_cells", "cache_specs", "eligible_shapes",
           "get_config", "input_specs"]
