"""train_step builder: grads (+ microbatch accumulation) + AdamW update.

The built step is a single jit-able pure function over (state, batch);
sharding comes from the in/out shardings that ``launch.dryrun`` /
``launch.train`` attach, plus the activation constraints inside the model
(``sharding.partition.shard``).

Microbatching: the global batch is split on the leading axis and grads are
accumulated — in scan mode via ``lax.scan`` (O(1) HLO), in unrolled mode via
a python loop (exact cost analysis for the roofline).  Accumulation is in
fp32 regardless of param dtype.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from ..models.model import Model
from .optimizer import OptConfig, adamw_init, adamw_update


def init_state(model: Model, opt_cfg: OptConfig, key) -> dict:
    params = model.init(key)
    return {"params": params, "opt": adamw_init(opt_cfg, params)}


def state_specs(model: Model, opt_cfg: OptConfig):
    """ShapeDtypeStruct pytree of the train state (no allocation)."""
    return jax.eval_shape(
        functools.partial(init_state, model, opt_cfg), jax.random.PRNGKey(0))


def build_train_step(model: Model, opt_cfg: OptConfig, *,
                     microbatches: int = 1,
                     accum_dtype: str = "float32"):
    """``accum_dtype='bfloat16'`` halves the microbatch grad-accumulator
    footprint (the dominant live buffer for 100B+ FSDP training); noise is
    bounded by the later fp32 Adam math."""
    cfg = model.cfg
    acc_dt = jnp.dtype(accum_dtype)

    def loss_of(params, batch):
        loss, metrics = model.loss_fn(params, batch)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_of, has_aux=True)

    def split_mb(batch):
        return jax.tree.map(
            lambda x: x.reshape((microbatches, x.shape[0] // microbatches)
                                + x.shape[1:]), batch)

    def train_step(state, batch):
        params = state["params"]
        if microbatches == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            mbs = split_mb(batch)
            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, acc_dt), params)

            def acc_one(carry, mb):
                g_acc, l_acc = carry
                (l, _), g = grad_fn(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(acc_dt), g_acc, g)
                return (g_acc, l_acc + l), None

            if cfg.scan_layers:
                (grads, loss_sum), _ = jax.lax.scan(
                    acc_one, (zero, jnp.zeros((), jnp.float32)), mbs)
            else:
                carry = (zero, jnp.zeros((), jnp.float32))
                for i in range(microbatches):
                    carry, _ = acc_one(
                        carry, jax.tree.map(lambda x: x[i], mbs))
                grads, loss_sum = carry
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = loss_sum / microbatches
            metrics = {}
        new_params, new_opt, opt_metrics = adamw_update(
            opt_cfg, grads, state["opt"], params)
        out_metrics = {"loss": loss, **opt_metrics,
                       **{k: v for k, v in metrics.items()}}
        return {"params": new_params, "opt": new_opt}, out_metrics

    return train_step
