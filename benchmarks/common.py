"""Shared benchmark utilities: CSV emission + budgets."""
from __future__ import annotations

import os
import time


def emit(name: str, value, derived: str = ""):
    """One CSV record: name,value,derived — run.py collects these."""
    print(f"BENCH,{name},{value},{derived}", flush=True)


def budget(quick: bool, quick_val, full_val):
    return quick_val if quick else full_val


def out_dir() -> str:
    d = os.path.join("artifacts", "bench")
    os.makedirs(d, exist_ok=True)
    return d
