"""Beyond-paper: device-resident netsim rate model (PR 8).

Two claims:

* ``rate_model_throughput`` — scoring traffic against a workload with the
  batched device rate model is cheaper per placement than event-driven
  host simulation of the same trace, and the gap widens with trace
  length: host cost scales with the packet count, the rate model's does
  not (it is also fused into the search scorer, where the FW pass is
  shared with the proxy metrics).  That is what makes traffic a
  searchable objective instead of a post-hoc check.
* ``trace_guided_search`` — under the same budget and seed, a sweep whose
  objective carries the ``trace-lat`` term lands on a placement with a
  *lower host-simulated trace latency* than the proxy-only sweep; and
  swapping workloads between configs compiles no extra scorers (demand is
  a runtime operand).
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.core.api import (Budget, ExperimentConfig, make_rep, run_sweep,
                            clear_scorer_cache)
from repro.core.baseline import MeshBaseline
from repro.core.chiplets import paper_arch
from repro.core.netsim import ChipletNet, NetSim
from repro.core.objective import Objective, TermSpec
from repro.core.topology import stack_graphs
from repro.core.traces import TraceRegion, generate_trace
from repro.netsim import Workload, make_trace_model

from .common import budget, emit, out_dir


def _trace_workload(arch, quick):
    _, geo_b, links_b = MeshBaseline(arch).build()
    net_base = ChipletNet.from_links(arch, geo_b, links_b)
    regions = (TraceRegion(budget(quick, 5000, 20000),
                           budget(quick, 20000, 80000)),)
    trace = generate_trace(net_base, regions, seed=7)
    cycles = sum(r.n_cycles for r in regions)
    wl = Workload.from_trace(trace, arch.kinds(), cycles, name="parsec-like")
    return net_base, trace, wl


def bench_throughput(quick: bool) -> dict:
    """Placements/s: device rate model (batched) vs host event sim."""
    arch = paper_arch("homog32", "baseline")
    rep = make_rep(arch, "homog32", None)
    _, trace, wl = _trace_workload(arch, quick)
    P = budget(quick, 64, 256)
    rng = np.random.default_rng(0)
    sols, graphs, nets = [], [], []
    while len(sols) < P:
        s = rep.random(rng)
        g = rep.score_graph(s)
        if not g.connected:
            continue
        sols.append(s)
        graphs.append(g)
        links, _ = rep.links_of(s)
        nets.append(ChipletNet.from_links(arch, rep.geometry(s), links))
    batch = stack_graphs(graphs)
    model = make_trace_model(rep.layout)
    dem = wl.vec()
    np.asarray(model(batch, dem)["trace_lat_c2m"])   # compile + warm up
    t0 = time.perf_counter()
    reps = budget(quick, 3, 10)
    for _ in range(reps):
        out = model(batch, dem)
        np.asarray(out["trace_lat_c2m"])
    dev_s = (time.perf_counter() - t0) / reps
    dev_rate = P / dev_s

    n_host = min(budget(quick, 6, 16), P)
    t0 = time.perf_counter()
    for net in nets[:n_host]:
        ok = [p for p in trace if net.next_hop[p.src, p.dst] >= 0]
        NetSim(net, arch).run(ok, mode="authentic")
    host_s = (time.perf_counter() - t0) / n_host
    host_rate = 1.0 / host_s
    speedup = dev_rate / host_rate
    emit("netsim_device_placements_per_s", round(dev_rate, 1),
         f"batch={P}")
    emit("netsim_host_placements_per_s", round(host_rate, 2),
         f"trace={len(trace)}pk")
    emit("netsim_device_vs_host_speedup", round(speedup, 1))
    return dict(batch=P, device_placements_per_s=dev_rate,
                host_placements_per_s=host_rate, speedup=speedup)


def bench_guided(quick: bool) -> dict:
    """trace-lat-guided sweep vs proxy-only sweep, host-simulated."""
    arch = paper_arch("homog32", "placeit")
    rep = make_rep(arch, "homog32", None)
    net_base, trace, wl = _trace_workload(arch, quick)
    guided_obj = Objective().with_terms(TermSpec("trace-lat", weight=2.0))

    def host_latency(sol):
        links, _ = rep.links_of(sol)
        net = ChipletNet.from_links(arch, rep.geometry(sol), links)
        ok = [p for p in trace if net.next_hop[p.src, p.dst] >= 0]
        return NetSim(net, arch).run(ok, mode="authentic").avg_latency

    lat_mesh = NetSim(net_base, arch).run(trace).avg_latency
    evals = budget(quick, 400, 1500)
    seeds = range(budget(quick, 1, 3))
    per_seed = {}
    wins = 0
    clear_scorer_cache()
    for seed in seeds:
        base = dict(arch="homog32", config="placeit", algorithms=("ga",),
                    budget=Budget(evals=evals), norm_samples=32, chunk=16,
                    seed=seed)
        res = run_sweep([
            ExperimentConfig(**base),
            ExperimentConfig(**base, objective=guided_obj, workload=wl),
            # same objective structure, different workload: must not
            # compile a third scorer (demand is a runtime operand)
            ExperimentConfig(**base, objective=guided_obj,
                             workload=wl.scaled(0.5)),
        ])
        built = res.stats.scorers_built
        assert built <= 2, f"workload swap recompiled: {built} scorers"
        lat_proxy = host_latency(res.runs[0].records[0].result.best_sol)
        lat_guided = host_latency(res.runs[1].records[0].result.best_sol)
        wins += int(lat_guided < lat_proxy)
        per_seed[f"seed{seed}"] = dict(proxy=lat_proxy, guided=lat_guided,
                                       scorers_built=built)
        emit(f"netsim_guided_seed{seed}_host_lat", round(lat_guided, 2),
             f"proxy={lat_proxy:.2f} mesh={lat_mesh:.2f}")
    n = len(per_seed)
    emit("netsim_guided_beats_proxy", f"{wins}/{n}")
    return dict(mesh_baseline=lat_mesh, evals=evals, seeds=n,
                guided_wins=wins, runs=per_seed)


def run(quick: bool = True) -> dict:
    results = dict(rate_model_throughput=bench_throughput(quick),
                   trace_guided_search=bench_guided(quick))
    with open(os.path.join(out_dir(), "netsim_device.json"), "w") as f:
        json.dump(results, f, indent=1, default=float)
    return results


def main(quick: bool = True):
    run(quick)


if __name__ == "__main__":
    main(quick=os.environ.get("BENCH_FULL", "") != "1")
