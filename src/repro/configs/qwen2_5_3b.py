"""Assigned architecture config: qwen2.5-3b (see registry for source).

Exposes CONFIG (exact published hyper-parameters) and SMOKE (reduced copy
for CPU smoke tests).  Select with ``--arch qwen2.5-3b``.
"""
from .registry import get_config

CONFIG = get_config("qwen2.5-3b")
SMOKE = CONFIG.reduced()
