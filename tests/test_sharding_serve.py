"""Sharding rules validity on the FULL production configs + serving engine.

The rules tests run against real (unreduced) configs — every PartitionSpec
must divide its dimension on the 16x16 and 2x16x16 meshes.  This is the
host-side contract the 512-device dry-run relies on.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES, eligible_shapes, get_config
from repro.models.model import build_model, init_cache, init_params
from repro.sharding import rules
from repro.sharding.partition import MeshInfo


class FakeMesh:
    """Shape-only stand-in (no devices needed for divisibility checks)."""

    def __init__(self, shape: dict):
        self.shape = shape
        self.axis_names = tuple(shape)

    @property
    def size(self):
        n = 1
        for v in self.shape.values():
            n *= v
        return n


MESHES = {
    "single": FakeMesh({"data": 16, "model": 16}),
    "multi": FakeMesh({"pod": 2, "data": 16, "model": 16}),
}


def axis_size(mesh, entry):
    if entry is None:
        return 1
    axes = entry if isinstance(entry, tuple) else (entry,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def check_divisible(tree, specs, mesh, what):
    from jax.sharding import PartitionSpec

    is_spec = lambda x: isinstance(x, PartitionSpec)
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    spec_leaves = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=is_spec)[0]
    spec_map = {jax.tree_util.keystr(p): s for p, s in spec_leaves}
    for path, leaf in leaves:
        spec = spec_map[jax.tree_util.keystr(path)]
        for d, entry in enumerate(tuple(spec)):
            n = axis_size(mesh, entry)
            assert leaf.shape[d] % n == 0, (
                f"{what}{jax.tree_util.keystr(path)} dim {d} "
                f"({leaf.shape[d]}) not divisible by {entry} ({n})")


@pytest.mark.parametrize("arch", sorted(ARCHS))
@pytest.mark.parametrize("mesh_kind", ["single", "multi"])
def test_param_pspecs_divide_full_configs(arch, mesh_kind):
    from repro.launch.dryrun import prod_config

    cfg, _ = prod_config(arch, "train_4k")
    mesh = MESHES[mesh_kind]
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    mi = MeshInfo(mesh=mesh, dp=dp, tp="model")
    shapes = jax.eval_shape(
        functools.partial(init_params, cfg), jax.random.PRNGKey(0))
    specs = rules.param_pspecs(cfg, shapes, mi)
    check_divisible(shapes, specs, mesh, f"{arch}/")
    assert rules.unknown_leaves(cfg, shapes, mi) == []


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_cache_pspecs_divide(arch):
    from repro.launch.dryrun import prod_config

    for shape in eligible_shapes(arch):
        if SHAPES[shape].kind != "decode":
            continue
        cfg, _ = prod_config(arch, shape)
        sh = SHAPES[shape]
        mesh = MESHES["single"]
        dp = ("data",) if sh.global_batch > 1 else ()
        tp = "model" if sh.global_batch > 1 else ("data", "model")
        mi = MeshInfo(mesh=mesh, dp=dp, tp=tp)
        mem_len = sh.seq_len if cfg.family == "encdec" else 0
        cache = jax.eval_shape(lambda: init_cache(
            cfg, sh.global_batch, sh.seq_len, mem_len=mem_len))
        specs = rules.cache_pspecs(cfg, cache, mi, cache_len=sh.seq_len)
        check_divisible(cache, specs, mesh, f"{arch}/{shape}/cache/")


def test_batch_pspecs():
    mi = MeshInfo(mesh=MESHES["multi"], dp=("pod", "data"), tp="model")
    batch = {"tokens": jax.ShapeDtypeStruct((256, 4096), jnp.int32)}
    specs = rules.batch_pspecs(batch, mi)
    assert tuple(specs["tokens"])[0] == ("pod", "data")


# ---------------------------------------------------------------------------
# serving engine
# ---------------------------------------------------------------------------

def test_engine_serves_all_requests():
    from repro.serve.engine import EngineConfig, Request, ServeEngine

    cfg = get_config("qwen3-1.7b").reduced(n_layers=2)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params,
                      EngineConfig(n_slots=2, cache_len=64, eos=-1))
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(3, cfg.vocab, size=5 + i)
                    .astype(np.int32), max_tokens=4) for i in range(5)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert all(r.done for r in reqs)
    assert all(len(r.out_tokens) == 4 for r in reqs)


def test_engine_greedy_matches_prefill_extension():
    """Engine's token 2 == greedy next-token after re-prefilling with
    (prompt + token 1): the KV-cache path is consistent."""
    cfg = get_config("tinyllama-1.1b").reduced(n_layers=2)
    from repro.serve.engine import EngineConfig, Request, ServeEngine

    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    eng = ServeEngine(model, params,
                      EngineConfig(n_slots=1, cache_len=64, eos=-1))
    prompt = np.arange(3, 11, dtype=np.int32)
    req = Request(0, prompt, max_tokens=3)
    eng.submit(req)
    eng.run()
    t1, t2 = req.out_tokens[0], req.out_tokens[1]
    logits, _ = jax.jit(lambda p, b: model.prefill(p, b, 64))(
        params, {"tokens": jnp.asarray(
            np.concatenate([prompt, [t1]])[None], jnp.int32)})
    assert int(np.argmax(np.asarray(logits)[0])) == t2
