"""Beyond-paper benches: (a) workload→package co-design (bridge) driven by
real dry-run artifacts; (b) the roofline table summary (§Roofline)."""
from __future__ import annotations

import glob
import json
import os

import numpy as np

from repro.core.bridge import codesign, signature_from_artifact
from repro.launch.roofline import ARTIFACT_DIR, format_table, report

from .common import budget, emit, out_dir


def run(quick: bool = True):
    # --- roofline summary over dry-run artifacts -------------------------
    rows = report("single")
    ok = [r for r in rows if "error" not in r]
    if ok:
        emit("roofline_cells_analyzed", len(ok))
        emit("roofline_cells_fit_16gb",
             sum(1 for r in ok if r["fits_16gb"]))
        dom = {}
        for r in ok:
            dom[r["dominant"]] = dom.get(r["dominant"], 0) + 1
        emit("roofline_dominant_terms", json.dumps(dom).replace(",", ";"))
        best = max(ok, key=lambda r: r["roofline_fraction"])
        emit("roofline_best_fraction",
             round(best["roofline_fraction"], 4),
             f"{best['arch']}/{best['shape']}")

    # --- bridge co-design on up to 3 real workload signatures ------------
    arts = sorted(glob.glob(os.path.join(ARTIFACT_DIR, "*__single.json")))
    picks = [a for a in arts if any(
        k in a for k in ("qwen3-1.7b__train_4k", "falcon-mamba-7b__decode",
                         "grok-1-314b__train_4k"))]
    results = {}
    for art in picks[: budget(quick, 2, 3)]:
        rec = json.load(open(art))
        if not rec.get("ok"):
            continue
        mp = art.replace("__single", "__multi")
        sig = signature_from_artifact(
            rec, multi_pod_rec=mp if os.path.exists(mp) else None)
        out = codesign(sig, max_evals=budget(quick, 60, 400),
                       norm_samples=budget(quick, 16, 64))
        key = f"{sig.arch}_{sig.shape}"
        results[key] = {k: v for k, v in out.items() if k != "best_sol"}
        emit(f"bridge_{key}_improvement",
             round(out["improvement"], 4),
             f"pkg={out['package']}")
    with open(os.path.join(out_dir(), "bridge.json"), "w") as f:
        json.dump(results, f, indent=1, default=float)


def main(quick: bool = True):
    run(quick)


if __name__ == "__main__":
    main()
