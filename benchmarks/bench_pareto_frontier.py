"""Pareto frontier engine: device dominance + stacked scalarization grids.

Two sections (PR 5):

* **dominance** — non-dominated masking over a [B, n] cost matrix: the
  brute-force host reference (per-point python/numpy scan, what the
  literature's naive front extraction does) vs the jitted vectorized
  [B, B, n] comparison (``pareto.nondominated_mask``), plus the 2D
  hypervolume sweep.  The device mask is bit-for-bit the host mask
  (asserted here on every measured matrix).
* **grid_sweep** — a TrafficMix/weight scalarization grid run through
  ``run_pareto_sweep``: because objective weights are *runtime* vectors,
  the whole grid shares one compiled scorer and executes in
  ``drive_stacked`` lockstep.  Reports scorer compilations, lockstep
  groups and scorer dispatches vs the same grid unstacked, and the
  resulting front size/hypervolume.

Results go to stdout as BENCH lines and to
``artifacts/bench/pareto_frontier.json``; ``benchmarks.run`` merges that
into ``BENCH_pareto_frontier.json`` at the repo root.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from .common import budget, emit, out_dir


def _dominance_rates(B: int, d: int = 3, reps: int = 5
                     ) -> tuple[float, float, int]:
    """(host_matrices_per_s, device_matrices_per_s, front_size)."""
    from repro.core.pareto import nondominated_mask, nondominated_mask_host
    rng = np.random.default_rng(0)
    Y = (rng.random((B, d)) ** 2).astype(np.float32)
    dev = nondominated_mask(Y)                      # warm the jit
    host = nondominated_mask_host(Y)
    assert np.array_equal(dev, host), "device front != host brute force"

    t_host = np.inf
    for _ in range(max(1, reps // 2)):
        t0 = time.perf_counter()
        nondominated_mask_host(Y)
        t_host = min(t_host, time.perf_counter() - t0)
    t_dev = np.inf
    for _ in range(reps):
        t0 = time.perf_counter()
        np.asarray(nondominated_mask(Y))
        t_dev = min(t_dev, time.perf_counter() - t0)
    return 1.0 / t_host, 1.0 / t_dev, int(dev.sum())


def _grid_sweep_stats(quick: bool) -> dict:
    from repro.core.api import (Budget, ExperimentConfig,
                                clear_scorer_cache)
    from repro.core.pareto import ParetoGridSpec, run_pareto_sweep
    evals = budget(quick, 8, 48)
    cfg = ExperimentConfig(
        arch="homog32", algorithms=("br",), budget=Budget(evals=evals),
        norm_samples=budget(quick, 4, 16), chunk=4,
        params={"br": {"batch": 4}})
    grid = ParetoGridSpec(term_weights={
        "lat": (0.5, 1.0, 2.0), "inv-thr": (0.5, 2.0)})
    clear_scorer_cache()
    t0 = time.perf_counter()
    stacked = run_pareto_sweep(cfg, grid)
    t_stacked = time.perf_counter() - t0
    t0 = time.perf_counter()
    unstacked = run_pareto_sweep(cfg, grid, stack_scoring=False)
    t_unstacked = time.perf_counter() - t0
    (front,) = stacked.fronts
    return dict(
        grid_points=grid.n_points,
        scorers_built=stacked.stats.scorers_built,
        stacked_groups=stacked.stats.stacked_groups,
        stacked_score_calls=stacked.stats.score_calls,
        unstacked_score_calls=unstacked.stats.score_calls,
        stacked_seconds=t_stacked, unstacked_seconds=t_unstacked,
        front_size=len(front.points), n_candidates=front.n_candidates,
        hypervolume=front.hypervolume)


def run(quick: bool = True) -> dict:
    results: dict = {}
    # dominance masks: host brute force vs jitted device comparison
    for B in budget(quick, (64, 256), (256, 1024, 4096)):
        h, d, fs = _dominance_rates(B)
        results[f"dominance_B{B}"] = dict(
            host_per_s=h, device_per_s=d, speedup=d / h, front_size=fs)
        emit(f"pareto_dominance_B{B}_speedup", round(d / h, 1),
             f"{d / h:.1f}x device [B,B,n] mask over host brute force "
             "(bit-for-bit asserted)")
    # one stacked scorer across a whole scalarization grid
    gs = _grid_sweep_stats(quick)
    results["grid_sweep"] = gs
    emit("pareto_grid_scorers_built", gs["scorers_built"],
         f"{gs['grid_points']} scalarizations share one compiled scorer "
         "(weights are runtime)")
    emit("pareto_grid_dispatch_ratio",
         round(gs["unstacked_score_calls"]
               / max(gs["stacked_score_calls"], 1), 2),
         f"{gs['unstacked_score_calls']} unstacked vs "
         f"{gs['stacked_score_calls']} stacked scorer dispatches")
    emit("pareto_grid_front_size", gs["front_size"],
         f"non-dominated of {gs['n_candidates']} candidates; "
         f"hypervolume {gs['hypervolume']:.3f}")
    with open(os.path.join(out_dir(), "pareto_frontier.json"), "w") as f:
        json.dump(results, f, indent=1, default=float)
    return results


def main(quick: bool = True):
    run(quick)


if __name__ == "__main__":
    main(quick=os.environ.get("BENCH_FULL", "") != "1")
