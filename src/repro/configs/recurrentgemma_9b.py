"""Assigned architecture config: recurrentgemma-9b (see registry for source).

Exposes CONFIG (exact published hyper-parameters) and SMOKE (reduced copy
for CPU smoke tests).  Select with ``--arch recurrentgemma-9b``.
"""
from .registry import get_config

CONFIG = get_config("recurrentgemma-9b")
SMOKE = CONFIG.reduced()
