"""Quickstart: co-optimize a chiplet placement + ICI topology (the paper's
core loop) and compare it to the 2D-mesh baseline — through the declarative
experiment API.

  PYTHONPATH=src python examples/quickstart.py

The whole experiment is one serializable config: swap ``"ga"`` for ``"sa"``
or ``"br"``, change ``backend`` to ``"fw-pallas"`` to use the Pallas
min-plus kernel, or dump ``cfg.to_json()`` into a sweep file.  The cost
function is an explicit ``Objective`` (paper §IV-B traffic mix + area):
change the ``TrafficMix`` weights, derive them from a trace
(``TrafficMix.from_trace_mix``), or append penalty terms such as
``TermSpec("link-length-cap", params={"cap_mm": 2.0})``.
"""
from repro.core.api import (Budget, ExperimentConfig, GAParams,
                            baseline_cost, run_experiment)
from repro.core.objective import Objective, TrafficMix


def ascii_placement(types) -> str:
    glyph = {-1: " .", 0: " C", 1: " M", 2: " I"}
    return "\n".join("".join(glyph[int(t)] for t in row)
                     for row in types[::-1])


def main():
    cfg = ExperimentConfig(
        arch="homog32", config="baseline",      # 32C + 4M + 4I, 3x3mm
        algorithms=("ga",),
        budget=Budget(evals=240),
        norm_samples=32,
        params={"ga": GAParams(population=24, elitism=5, tournament=5)},
        # The paper's §IV-B cost function, spelled out: C2M/M2I traffic and
        # area weighted 2.0, C2C/C2I 0.1.  This is also the default.
        objective=Objective(mix=TrafficMix(lat=(0.1, 2.0, 0.1, 2.0),
                                           thr=(0.1, 2.0, 0.1, 2.0)),
                            w_area=2.0),
    )
    print("== PlaceIT quickstart: homog32, GA, small budget ==")
    print(f"config: {cfg.to_json()}\n")

    res = run_experiment(cfg)[0].result
    _, base = baseline_cost(cfg)

    print(f"optimized placement (cost {res.best_cost:.3f}, "
          f"{res.n_evaluated} placements evaluated):")
    print(ascii_placement(res.best_sol[0]))
    print("\nmetric            placeit   2D-mesh   delta")
    for t in ("c2c", "c2m", "c2i", "m2i"):
        o, b = res.best_metrics[f"lat_{t}"], base[f"lat_{t}"]
        print(f"lat_{t} [cyc]     {o:8.1f}  {b:8.1f}  {100*(o/b-1):+6.1f}%")
    for t in ("c2c", "c2m", "c2i", "m2i"):
        o, b = res.best_metrics[f"thr_{t}"], base[f"thr_{t}"]
        print(f"thr_{t} [frac]    {o:8.3f}  {b:8.3f}  {100*(o/b-1):+6.1f}%")


if __name__ == "__main__":
    main()
