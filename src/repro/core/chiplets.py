"""Chiplet, PHY and architecture specifications (paper §IV, Tables II-IV).

Every chiplet is categorized as compute / memory / IO (paper assumption 1).
A chiplet knows its dimensions [mm], its PHY positions (paper assumption 2),
and whether it can relay traffic (assumption 5).  PHYs share one protocol and
data width (assumptions 3-4) so any two PHYs can be joined by a D2D link.

Rotation semantics (§VI-A, Fig. 8): a chiplet is *rotation-invariant* /
*rotation-hybrid* / *rotation-sensitive* depending on whether shape and PHY
locations change under rotation; we compute the class from the geometry and
expose only non-isomorphic rotations to the optimizer.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Sequence

COMPUTE, MEMORY, IO = 0, 1, 2
TYPE_NAMES = ("compute", "memory", "io")
TRAFFIC_TYPES = ("c2c", "c2m", "c2i", "m2i")
# (src type, dst type) unordered chiplet-type pairs per traffic class; loads /
# latencies are evaluated over ordered pairs in both directions.
TRAFFIC_ENDPOINTS = {
    "c2c": (COMPUTE, COMPUTE),
    "c2m": (COMPUTE, MEMORY),
    "c2i": (COMPUTE, IO),
    "m2i": (MEMORY, IO),
}


@dataclass(frozen=True)
class Chiplet:
    """A chiplet type: rectangle (w, h) in mm with PHYs on its perimeter."""

    name: str
    kind: int                      # COMPUTE | MEMORY | IO
    w: float
    h: float
    phys: tuple[tuple[float, float], ...]  # (x, y) in chiplet-local mm
    relay: bool

    # ---- rotation geometry -------------------------------------------------
    def rotated(self, rot: int) -> "Chiplet":
        """Rotate by rot*90 degrees counter-clockwise about the origin corner.

        The rotated chiplet is re-anchored so its bounding box has its lower
        left corner at (0, 0) again.
        """
        rot = rot % 4
        if rot == 0:
            return self
        w, h, phys = self.w, self.h, self.phys
        for _ in range(rot):
            # (x, y) -> (-y, x), then shift by old h to re-anchor.
            phys = tuple((h - y, x) for (x, y) in phys)
            w, h = h, w
        return dataclasses.replace(self, w=w, h=h, phys=phys)

    def _canon(self) -> tuple:
        return (
            round(self.w, 6),
            round(self.h, 6),
            tuple(sorted((round(x, 6), round(y, 6)) for x, y in self.phys)),
        )

    def allowed_rotations(self) -> tuple[int, ...]:
        """Non-isomorphic rotations (Fig. 8 right).

        rotation-invariant -> (0,), rotation-hybrid (180deg symmetric) ->
        (0, 1), rotation-sensitive -> (0, 1, 2, 3).  Intermediate symmetry
        classes are handled generically by keeping one representative per
        distinct geometry.
        """
        seen: dict[tuple, int] = {}
        for r in range(4):
            key = self.rotated(r)._canon()
            seen.setdefault(key, r)
        return tuple(sorted(set(seen.values())))

    def n_phys(self) -> int:
        return len(self.phys)


def _mid_side_phys(w: float, h: float, sides: str) -> tuple[tuple[float, float], ...]:
    """PHYs centered on the requested sides; 'n','e','s','w'."""
    out = []
    for s in sides:
        if s == "n":
            out.append((w / 2, h))
        elif s == "s":
            out.append((w / 2, 0.0))
        elif s == "e":
            out.append((w, h / 2))
        elif s == "w":
            out.append((0.0, h / 2))
        else:  # pragma: no cover - config error
            raise ValueError(s)
    return tuple(out)


@dataclass(frozen=True)
class LatencyParams:
    """Cycle latencies (Table II): PHY, link, relay."""

    l_phy: float = 12.0
    l_link: float = 1.0
    l_relay: float = 10.0

    def d2d_cost(self) -> float:
        # One D2D hop crosses the sending PHY, the link and the receiving PHY.
        return 2.0 * self.l_phy + self.l_link


@dataclass(frozen=True)
class ArchSpec:
    """An architecture to be optimized (Table II bottom)."""

    name: str
    chiplets: tuple[Chiplet, ...]        # one entry per chiplet *instance*
    latency: LatencyParams
    max_link_mm: float = 3.0
    distance: str = "euclidean"          # or "manhattan"
    # Cost-function weights (paper §V-B): area & C2M/M2I get 2, C2C/C2I 0.1.
    # DEPRECATED alias: these fields only seed the *default* objective
    # (objective.Objective.from_arch / default_objective()); prefer an
    # explicit ``Objective`` (ExperimentConfig.objective or
    # Evaluator(objective=...)) for custom mixes and extra cost terms.
    w_lat: tuple[float, float, float, float] = (0.1, 2.0, 0.1, 2.0)
    w_thr: tuple[float, float, float, float] = (0.1, 2.0, 0.1, 2.0)
    w_area: float = 2.0

    def default_objective(self):
        """The deprecated ``w_*`` weight fields as a typed
        :class:`repro.core.objective.Objective` (the migration bridge)."""
        from .objective import Objective
        return Objective.from_arch(self)

    def counts(self) -> tuple[int, int, int]:
        c = sum(1 for x in self.chiplets if x.kind == COMPUTE)
        m = sum(1 for x in self.chiplets if x.kind == MEMORY)
        i = sum(1 for x in self.chiplets if x.kind == IO)
        return c, m, i

    def kinds(self) -> tuple[int, ...]:
        return tuple(x.kind for x in self.chiplets)

    def dist(self, a: tuple[float, float], b: tuple[float, float]) -> float:
        dx, dy = a[0] - b[0], a[1] - b[1]
        if self.distance == "manhattan":
            return abs(dx) + abs(dy)
        return math.hypot(dx, dy)


# ---------------------------------------------------------------------------
# Paper architectures.
#
# Homogeneous (§V-B): 3mm x 3mm chiplets.  Two chiplet configurations are
# evaluated (§VII): *baseline* = memory/IO chiplets have a single PHY and
# cannot relay; *placeit* = every chiplet has four PHYs and relay capability.
# Compute chiplets always have 4 PHYs + relay.
# ---------------------------------------------------------------------------

def homogeneous_chiplet(kind: int, config: str) -> Chiplet:
    name = TYPE_NAMES[kind]
    if kind == COMPUTE or config == "placeit":
        return Chiplet(name, kind, 3.0, 3.0, _mid_side_phys(3.0, 3.0, "nesw"),
                       relay=True)
    if config == "baseline":
        # Single PHY (south side by convention; rotation orients it).
        return Chiplet(name, kind, 3.0, 3.0, _mid_side_phys(3.0, 3.0, "s"),
                       relay=False)
    raise ValueError(config)


def homogeneous_arch(n_compute: int, n_memory: int, n_io: int,
                     config: str = "baseline",
                     latency: LatencyParams = LatencyParams()) -> ArchSpec:
    chips = (
        tuple(homogeneous_chiplet(COMPUTE, config) for _ in range(n_compute))
        + tuple(homogeneous_chiplet(MEMORY, config) for _ in range(n_memory))
        + tuple(homogeneous_chiplet(IO, config) for _ in range(n_io))
    )
    return ArchSpec(
        name=f"homog_{n_compute}c{n_memory}m{n_io}i_{config}",
        chiplets=chips, latency=latency,
    )


# Heterogeneous (§VI-B, Fig. 11).  Fig. 11 is an image we cannot read; the
# dimensions below are documented substitutes (DESIGN.md §3): compute 3x3 with
# 4 PHYs, memory 3x5 with 2 PHYs on one long side, IO 2x4 with 1 PHY.
def heterogeneous_chiplet(kind: int, config: str) -> Chiplet:
    if kind == COMPUTE:
        return Chiplet("compute", kind, 3.0, 3.0,
                       _mid_side_phys(3.0, 3.0, "nesw"), relay=True)
    if kind == MEMORY:
        if config == "placeit":
            return Chiplet("memory", kind, 3.0, 5.0,
                           _mid_side_phys(3.0, 5.0, "nesw"), relay=True)
        # two PHYs spread along the east (long) side
        return Chiplet("memory", kind, 3.0, 5.0,
                       ((3.0, 1.25), (3.0, 3.75)), relay=False)
    if kind == IO:
        if config == "placeit":
            return Chiplet("io", kind, 2.0, 4.0,
                           _mid_side_phys(2.0, 4.0, "nesw"), relay=True)
        return Chiplet("io", kind, 2.0, 4.0, _mid_side_phys(2.0, 4.0, "e"),
                       relay=False)
    raise ValueError(kind)


def heterogeneous_arch(n_compute: int, n_memory: int, n_io: int,
                       config: str = "baseline",
                       latency: LatencyParams = LatencyParams()) -> ArchSpec:
    chips = (
        tuple(heterogeneous_chiplet(COMPUTE, config) for _ in range(n_compute))
        + tuple(heterogeneous_chiplet(MEMORY, config) for _ in range(n_memory))
        + tuple(heterogeneous_chiplet(IO, config) for _ in range(n_io))
    )
    return ArchSpec(
        name=f"hetero_{n_compute}c{n_memory}m{n_io}i_{config}",
        chiplets=chips, latency=latency, max_link_mm=3.0, distance="euclidean",
    )


def paper_arch(which: str, config: str = "baseline") -> ArchSpec:
    """The paper's four experiment architectures (§V-B, §VI-B)."""
    if which == "homog32":
        return homogeneous_arch(32, 4, 4, config)
    if which == "homog64":
        return homogeneous_arch(64, 8, 8, config)
    if which == "hetero32":
        return heterogeneous_arch(32, 4, 4, config)
    if which == "hetero64":
        return heterogeneous_arch(64, 8, 8, config)
    raise ValueError(which)


# 100+-chiplet homogeneous families (the HexaMesh regime, PAPERS.md):
# (n_compute, n_memory, n_io).  Compute:memory:io stays ~10.5:1:1 like the
# paper's homog arches; hex127 is a centered-hexagonal arrangement (side 7
# -> 127 cells) placed on a masked square grid.
LARGE_HOMOG = {
    "homog100": (84, 8, 8),
    "homog144": (120, 12, 12),
    "homog256": (224, 16, 16),
    "hex127": (107, 10, 10),
}


def large_arch(which: str, config: str = "baseline") -> ArchSpec:
    """100+-chiplet homogeneous architectures beyond the paper's four."""
    try:
        nc, nm, ni = LARGE_HOMOG[which]
    except KeyError:
        raise ValueError(which) from None
    return homogeneous_arch(nc, nm, ni, config)


# 3D / hierarchical families (repro.arch3d): chiplet counts per family
# name — (n_compute, n_memory, n_io), homogeneous 3mm chiplets; grid dims
# and family structure live in ``repro.arch3d.families.FAMILIES3D``.
# Counts fill the grids exactly (32 = 4x4x2, 64 = 4x4x4) while keeping
# roughly the paper's compute-heavy shape.
ARCH3D = {
    "stack3d32": (24, 4, 4),
    "stack3d64": (52, 6, 6),
    "gw3d64": (52, 6, 6),
    "torus3d32": (24, 4, 4),
    "express3d32": (24, 4, 4),
}


def arch3d_arch(which: str, config: str = "baseline") -> ArchSpec:
    """A 3D/hierarchical family's ArchSpec (homogeneous chiplet mix; the
    3D structure lives in the representation, not the chiplet set)."""
    try:
        nc, nm, ni = ARCH3D[which]
    except KeyError:
        raise ValueError(which) from None
    return homogeneous_arch(nc, nm, ni, config)


def resolve_arch(which: str, config: str = "baseline") -> ArchSpec:
    """Any named architecture: the paper's four, a LARGE_HOMOG family, or
    a 3D/hierarchical ARCH3D family."""
    if which in LARGE_HOMOG:
        return large_arch(which, config)
    if which in ARCH3D:
        return arch3d_arch(which, config)
    return paper_arch(which, config)
