"""Homogeneous placement representation (paper §V-A, Fig. 5).

A placement is an R x C grid; each cell holds a compute-, memory- or
IO-chiplet or is empty.  All chiplets are 3mm x 3mm.  Chiplets with a single
PHY (memory/IO in the *baseline* chiplet configuration) can be rotated so the
PHY faces N/E/S/W; chiplets with four PHYs cannot (isomorphic placements).

The solution object is a pair of int8 numpy arrays ``(types, rot)`` of shape
[R, C]; ``types`` holds -1 for empty or the chiplet kind, ``rot`` in {0..3}
encodes the facing direction of single-PHY chiplets (0=S, 1=E, 2=N, 3=W —
matching ``Chiplet.rotated``).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .chiplets import COMPUTE, IO, MEMORY, ArchSpec
from .proxies import Layout
from .topology import PlacedPhys, ScoreGraph, _UnionFind, build_score_graph

# Facing direction of the single PHY after rot r (base chiplet has PHY south).
_ROT_DIR = ("s", "e", "n", "w")
# Grid deltas per direction (row grows northwards).
_DIR_DELTA = {"n": (1, 0), "s": (-1, 0), "e": (0, 1), "w": (0, -1)}
_OPP = {"n": "s", "s": "n", "e": "w", "w": "e"}


Sol = tuple[np.ndarray, np.ndarray]  # (types [R,C], rot [R,C])


def sol_key(sol: Sol) -> bytes:
    return sol[0].tobytes() + sol[1].tobytes()


@dataclass
class HomogRep:
    """Placement representation + operators for homogeneous chiplet shapes."""

    arch: ArchSpec
    R: int
    C: int
    mutation_mode: str = "neighbor-one"   # any-both | any-one | neighbor-both | neighbor-one

    def __post_init__(self):
        n = len(self.arch.chiplets)
        if self.R * self.C < n:
            raise ValueError("grid too small for chiplet count")
        self._kind_instances = {
            k: [i for i, ch in enumerate(self.arch.chiplets) if ch.kind == k]
            for k in (COMPUTE, MEMORY, IO)
        }
        self._phy_base = np.zeros(n + 1, dtype=np.int64)
        for i, ch in enumerate(self.arch.chiplets):
            self._phy_base[i + 1] = self._phy_base[i] + ch.n_phys()
        self._rotatable = {
            k: self.arch.chiplets[self._kind_instances[k][0]].n_phys() == 1
            for k in (COMPUTE, MEMORY, IO) if self._kind_instances[k]
        }

    # -- static properties ---------------------------------------------------
    @property
    def layout(self) -> Layout:
        return Layout(Vp=int(self._phy_base[-1]), kinds=self.arch.kinds())

    @property
    def e_max(self) -> int:
        return 2 * (self.R * (self.C - 1) + (self.R - 1) * self.C)

    @property
    def area(self) -> float:
        # §V-A get_area: chiplet_size * R * C (identical for all placements).
        sz = self.arch.chiplets[0].w * self.arch.chiplets[0].h
        return float(sz * self.R * self.C)

    # -- helpers ---------------------------------------------------------
    def _occupied_dirs(self, types: np.ndarray, r: int, c: int) -> list[int]:
        """Rotations whose PHY faces an occupied neighbor cell."""
        out = []
        for rot, d in enumerate(_ROT_DIR):
            dr, dc = _DIR_DELTA[d]
            rr, cc = r + dr, c + dc
            if 0 <= rr < self.R and 0 <= cc < self.C and types[rr, cc] >= 0:
                out.append(rot)
        return out

    def _inside_dirs(self, r: int, c: int) -> list[int]:
        out = []
        for rot, d in enumerate(_ROT_DIR):
            dr, dc = _DIR_DELTA[d]
            rr, cc = r + dr, c + dc
            if 0 <= rr < self.R and 0 <= cc < self.C:
                out.append(rot)
        return out

    def _roll_rotation(self, types: np.ndarray, r: int, c: int,
                       rng: np.random.Generator) -> int:
        """Pick a rotation: PHY must face another chiplet, not the outside."""
        cands = self._occupied_dirs(types, r, c) or self._inside_dirs(r, c) \
            or [0, 1, 2, 3]
        return int(rng.choice(cands))

    def _fix_rotations(self, types: np.ndarray, rot: np.ndarray,
                       rng: np.random.Generator) -> None:
        """Re-roll rotations of single-PHY chiplets in-place."""
        for r in range(self.R):
            for c in range(self.C):
                k = types[r, c]
                if k >= 0 and self._rotatable.get(int(k), False):
                    rot[r, c] = self._roll_rotation(types, r, c, rng)
                else:
                    rot[r, c] = 0

    # -- the four representation functions (§IV) --------------------------
    def random(self, rng: np.random.Generator) -> Sol:
        cells = self.R * self.C
        flat = np.full(cells, -1, dtype=np.int8)
        kinds = [k for k, ids in self._kind_instances.items()
                 for _ in ids]
        pos = rng.choice(cells, size=len(kinds), replace=False)
        flat[pos] = np.array(kinds, dtype=np.int8)
        types = flat.reshape(self.R, self.C)
        rot = np.zeros_like(types)
        self._fix_rotations(types, rot, rng)
        return types, rot

    def mutate(self, sol: Sol, rng: np.random.Generator) -> Sol:
        types = sol[0].copy()
        rot = sol[1].copy()
        neighbor = self.mutation_mode.startswith("neighbor")
        both = self.mutation_mode.endswith("both")
        do_swap = True
        do_rot = both or not any(self._rotatable.values())
        if not both and any(self._rotatable.values()):
            do_swap = bool(rng.integers(2))
            do_rot = not do_swap
        if do_swap:
            self._swap(types, rot, rng, neighbor)
        if do_rot and any(self._rotatable.values()):
            self._rotate_one(types, rot, rng)
        return types, rot

    def _swap(self, types, rot, rng, neighbor: bool) -> None:
        """Swap two cells of *different* types (empty counts as a type)."""
        for _ in range(200):
            r1 = int(rng.integers(self.R))
            c1 = int(rng.integers(self.C))
            if neighbor:
                d = _ROT_DIR[int(rng.integers(4))]
                dr, dc = _DIR_DELTA[d]
                r2, c2 = r1 + dr, c1 + dc
                if not (0 <= r2 < self.R and 0 <= c2 < self.C):
                    continue
            else:
                r2 = int(rng.integers(self.R))
                c2 = int(rng.integers(self.C))
            if types[r1, c1] == types[r2, c2]:
                continue
            if types[r1, c1] < 0 and types[r2, c2] < 0:
                continue
            types[r1, c1], types[r2, c2] = types[r2, c2], types[r1, c1]
            rot[r1, c1], rot[r2, c2] = rot[r2, c2], rot[r1, c1]
            for (r, c) in ((r1, c1), (r2, c2)):
                k = types[r, c]
                if k >= 0 and self._rotatable.get(int(k), False):
                    rot[r, c] = self._roll_rotation(types, r, c, rng)
                else:
                    rot[r, c] = 0
            return

    def _rotate_one(self, types, rot, rng) -> None:
        cand = [(r, c) for r in range(self.R) for c in range(self.C)
                if types[r, c] >= 0
                and self._rotatable.get(int(types[r, c]), False)]
        if not cand:
            return
        r, c = cand[int(rng.integers(len(cand)))]
        rot[r, c] = self._roll_rotation(types, r, c, rng)

    def merge(self, a: Sol, b: Sol, rng: np.random.Generator) -> Sol:
        """§V-A merge: keep matching types/rotations, randomize the rest."""
        ta, ra_ = a
        tb, rb_ = b
        types = np.full_like(ta, -2)            # -2 = unresolved
        match = ta == tb
        types[match] = ta[match]
        # Count how many chiplets of each kind were carried over.
        remaining = {k: len(ids) for k, ids in self._kind_instances.items()}
        for k in remaining:
            remaining[k] -= int((types == k).sum())
        # Fill unresolved cells with leftover chiplets + empties.
        unresolved = np.argwhere(types == -2)
        fill = []
        for k, n in remaining.items():
            fill += [k] * n
        fill += [-1] * (len(unresolved) - len(fill))
        fill = np.array(fill, dtype=np.int8)
        rng.shuffle(fill)
        for (r, c), v in zip(unresolved, fill):
            types[r, c] = v
        rot = np.zeros_like(types)
        rot_match = match & (ra_ == rb_)
        rot[rot_match] = ra_[rot_match]
        # Re-roll rotations that were not carried over (or face emptiness).
        for r in range(self.R):
            for c in range(self.C):
                k = types[r, c]
                if k >= 0 and self._rotatable.get(int(k), False):
                    if not rot_match[r, c]:
                        rot[r, c] = self._roll_rotation(types, r, c, rng)
                else:
                    rot[r, c] = 0
        return types, rot

    # -- geometry / network ---------------------------------------------
    def _assign_instances(self, types: np.ndarray) -> np.ndarray:
        """Row-major scan assigns concrete chiplet instance ids to cells."""
        inst = np.full((self.R, self.C), -1, dtype=np.int64)
        counters = {k: 0 for k in self._kind_instances}
        for r in range(self.R):
            for c in range(self.C):
                k = int(types[r, c])
                if k < 0:
                    continue
                inst[r, c] = self._kind_instances[k][counters[k]]
                counters[k] += 1
        return inst

    def _phy_of(self, inst: int, types, rot, r: int, c: int,
                direction: str) -> int:
        """Global PHY index of chiplet ``inst`` facing ``direction`` or -1."""
        ch = self.arch.chiplets[inst]
        if ch.n_phys() == 4:
            # base phys order is n, e, s, w (see homogeneous_chiplet)
            local = "nesw".index(direction)
            return int(self._phy_base[inst]) + local
        if _ROT_DIR[int(rot[r, c])] == direction:
            return int(self._phy_base[inst])
        return -1

    def links_of(self, sol: Sol) -> tuple[list[tuple[int, int]], np.ndarray]:
        """§V-A get_network: connect opposing PHYs of adjacent chiplets."""
        types, rot = sol
        inst = self._assign_instances(types)
        links: list[tuple[int, int]] = []
        for r in range(self.R):
            for c in range(self.C):
                if types[r, c] < 0:
                    continue
                for d in ("n", "e"):       # scan each adjacency once
                    dr, dc = _DIR_DELTA[d]
                    rr, cc = r + dr, c + dc
                    if not (0 <= rr < self.R and 0 <= cc < self.C):
                        continue
                    if types[rr, cc] < 0:
                        continue
                    p = self._phy_of(int(inst[r, c]), types, rot, r, c, d)
                    q = self._phy_of(int(inst[rr, cc]), types, rot, rr, cc,
                                     _OPP[d])
                    if p >= 0 and q >= 0:
                        links.append((p, q))
        return links, inst

    def is_connected(self, sol: Sol) -> bool:
        types, _ = sol
        links, inst = self.links_of(sol)
        n = len(self.arch.chiplets)
        uf = _UnionFind(n)
        owner = self._owner_of_phys(inst)
        for p, q in links:
            uf.union(int(owner[p]), int(owner[q]))
        cells = inst[inst >= 0]
        roots = {uf.find(int(i)) for i in cells}
        return len(roots) == 1

    def _owner_of_phys(self, inst: np.ndarray) -> np.ndarray:
        Vp = int(self._phy_base[-1])
        owner = np.zeros(Vp, dtype=np.int32)
        for i, ch in enumerate(self.arch.chiplets):
            owner[self._phy_base[i]:self._phy_base[i + 1]] = i
        return owner

    def geometry(self, sol: Sol) -> PlacedPhys:
        types, rot = sol
        inst = self._assign_instances(types)
        Vp = int(self._phy_base[-1])
        pos = np.zeros((Vp, 2), dtype=np.float32)
        sz = self.arch.chiplets[0].w
        for r in range(self.R):
            for c in range(self.C):
                i = int(inst[r, c])
                if i < 0:
                    continue
                ch = self.arch.chiplets[i].rotated(int(rot[r, c])
                                                   if self.arch.chiplets[i]
                                                   .n_phys() == 1 else 0)
                ox, oy = c * sz, r * sz
                for li, (x, y) in enumerate(ch.phys):
                    pos[self._phy_base[i] + li] = (ox + x, oy + y)
        owner = self._owner_of_phys(inst)
        relay = np.array([ch.relay for ch in self.arch.chiplets])
        kinds = np.array(self.arch.kinds(), dtype=np.int8)
        return PlacedPhys(pos=pos, owner=owner, relay=relay, kinds=kinds,
                          area=self.area)

    def score_graph(self, sol: Sol) -> ScoreGraph:
        links, _ = self.links_of(sol)
        geo = self.geometry(sol)
        return build_score_graph(self.arch, geo, links, self.e_max,
                                 self.is_connected(sol))
