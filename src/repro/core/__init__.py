# The paper's primary contribution — implement the SYSTEM here
# (scheduler, optimizer, data path, serving loop, etc.) in the
# host framework. Add sibling subpackages for substrates.
#
# Public entry point: the registry-driven experiment API.
from .api import (Budget, ExperimentConfig, RunRecord, SweepResult,  # noqa: F401
                  baseline_cost, best_by_algorithm, run_experiment,
                  run_sweep, summarize)
from .registries import (OPTIMIZERS, SCORER_BACKENDS,  # noqa: F401
                         register_optimizer, register_scorer_backend)
