"""Experiment runner (paper Fig. 3): launch BR/GA/SA runs over architectures.

The runner wires together: architecture spec -> placement representation ->
evaluator (batched JAX scoring + cost normalizers) -> optimization algorithm,
with repetitions, and scores the 2D-mesh baseline with the *same* normalizers
so the comparison matches the paper's (§VII).

Budgets are expressed in evaluations by default (deterministic, CI-friendly);
wall-clock budgets — the paper's 3600 s — are also supported.
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field
from typing import Any

import numpy as np

from .baseline import MeshBaseline
from .chiplets import ArchSpec, paper_arch
from .cost import total_cost
from .optimize import (Evaluator, OptResult, best_random, genetic_algorithm,
                       simulated_annealing)
from .placement_hetero import HeteroRep
from .placement_homog import HomogRep

# Paper Table III/IV hyper-parameters per (arch family, size).
PAPER_PARAMS = {
    ("homog", 32): dict(ga=dict(population=200, elitism=30, tournament=30,
                                p_mutation=0.5),
                        sa=dict(t0_temp=40.0, block_len=250),
                        mutation_mode="neighbor-one"),
    ("homog", 64): dict(ga=dict(population=50, elitism=8, tournament=8,
                                p_mutation=0.5),
                        sa=dict(t0_temp=35.0, block_len=50),
                        mutation_mode="neighbor-one"),
    ("hetero", 32): dict(ga=dict(population=30, elitism=6, tournament=6,
                                 p_mutation=0.5),
                         sa=dict(t0_temp=33.0, block_len=50),
                         mutation_mode="any-one"),
    ("hetero", 64): dict(ga=dict(population=20, elitism=5, tournament=5,
                                 p_mutation=0.5),
                         sa=dict(t0_temp=28.0, block_len=45),
                         mutation_mode="any-one"),
}

# Paper §V-B grid sizes: R*C >= N with one spare row of slack.
GRID_DIMS = {32 + 4 + 4: (8, 5), 64 + 8 + 8: (10, 8)}


@dataclass
class RunRecord:
    arch: str
    config: str
    algorithm: str
    repetition: int
    result: OptResult
    seconds: float


@dataclass
class Experiment:
    """One experiment = one architecture x chiplet config, several algos."""

    arch_name: str                     # homog32 | homog64 | hetero32 | hetero64
    config: str = "baseline"           # baseline | placeit (§VII)
    algorithms: tuple[str, ...] = ("br", "ga", "sa")
    repetitions: int = 1
    max_evals: int | None = 300        # per repetition (None -> use seconds)
    time_budget_s: float | None = None
    norm_samples: int = 100            # paper: 500
    seed: int = 0
    sa_chains: int = 1
    fw_impl: Any = None                # plug in the Pallas APSP here
    records: list[RunRecord] = field(default_factory=list)

    def make_rep(self, arch: ArchSpec):
        fam = "homog" if self.arch_name.startswith("homog") else "hetero"
        size = 32 if "32" in self.arch_name else 64
        mode = PAPER_PARAMS[(fam, size)]["mutation_mode"]
        if fam == "homog":
            n = len(arch.chiplets)
            R, C = GRID_DIMS.get(n, (int(np.ceil(np.sqrt(n))),) * 2)
            return HomogRep(arch, R=R, C=C, mutation_mode=mode)
        return HeteroRep(arch, mutation_mode=mode)

    def run(self) -> list[RunRecord]:
        arch = paper_arch(self.arch_name, self.config)
        fam = "homog" if self.arch_name.startswith("homog") else "hetero"
        size = 32 if "32" in self.arch_name else 64
        params = PAPER_PARAMS[(fam, size)]
        for rep_i in range(self.repetitions):
            rng = np.random.default_rng(self.seed + 1000 * rep_i)
            rep = self.make_rep(arch)
            ev = Evaluator(rep, arch, rng=rng, norm_samples=self.norm_samples,
                           fw_impl=self.fw_impl)
            for algo in self.algorithms:
                t0 = time.monotonic()
                rng_a = np.random.default_rng(
                    self.seed + 1000 * rep_i + hash(algo) % 997)
                if algo == "br":
                    res = best_random(ev, rng_a, max_evals=self.max_evals,
                                      time_budget_s=self.time_budget_s)
                elif algo == "ga":
                    ga = params["ga"]
                    max_gen = (None if self.max_evals is None
                               else max(1, self.max_evals // ga["population"]))
                    res = genetic_algorithm(
                        ev, rng_a, time_budget_s=self.time_budget_s,
                        max_generations=max_gen, **ga)
                elif algo == "sa":
                    sa = params["sa"]
                    max_it = (None if self.max_evals is None
                              else max(1, self.max_evals // self.sa_chains))
                    res = simulated_annealing(
                        ev, rng_a, chains=self.sa_chains,
                        time_budget_s=self.time_budget_s, max_iters=max_it,
                        **sa)
                else:  # pragma: no cover
                    raise ValueError(algo)
                self.records.append(RunRecord(
                    self.arch_name, self.config, algo, rep_i, res,
                    time.monotonic() - t0))
        return self.records

    # -- baseline scored with the same pipeline ---------------------------
    def baseline_cost(self) -> tuple[float, dict]:
        arch = paper_arch(self.arch_name, self.config)
        rng = np.random.default_rng(self.seed)
        rep = self.make_rep(arch)
        ev = Evaluator(rep, arch, rng=rng, norm_samples=self.norm_samples,
                       fw_impl=self.fw_impl)
        g = MeshBaseline(arch).build()[0]
        # Pad the baseline graph's edge list to the rep's fixed shape if
        # needed (shapes differ between baseline and placement graphs).
        metrics = ev.score([g])
        cost = float(np.asarray(total_cost(metrics, arch, ev.norm))[0])
        return cost, {k: float(v[0]) for k, v in metrics.items()}


def summarize(records: list[RunRecord]) -> list[dict]:
    rows = []
    for r in records:
        rows.append(dict(
            arch=r.arch, config=r.config, algorithm=r.algorithm,
            repetition=r.repetition, best_cost=r.result.best_cost,
            n_evaluated=r.result.n_evaluated,
            n_generated=r.result.n_generated, seconds=round(r.seconds, 2),
            evals_per_s=round(r.result.n_evaluated / max(r.seconds, 1e-9), 1),
        ))
    return rows


def best_by_algorithm(records: list[RunRecord]) -> dict[str, RunRecord]:
    out: dict[str, RunRecord] = {}
    for r in records:
        if r.algorithm not in out \
                or r.result.best_cost < out[r.algorithm].result.best_cost:
            out[r.algorithm] = r
    return out
