"""Placement-based ICI topology inference (paper §V-A get_network, §VI-A).

The common output of both placement representations is a ``ScoreGraph``: a
PHY-level latency graph augmented with virtual per-chiplet source/sink nodes,
plus the directed D2D edge list used for throughput (link-load) estimation.

Node layout (V = Vp + 2*N):
    [0, Vp)          PHY nodes
    [Vp, Vp+N)       virtual *source* nodes, one per chiplet (out-edges only)
    [Vp+N, Vp+2N)    virtual *sink* nodes, one per chiplet (in-edges only)

Edge weights [cycles]:
    src_c -> p (p in PHYs(c)) : 0     (injection picks any own PHY)
    p -> dst_c (p in PHYs(c)) : 0     (ejection from any own PHY)
    D2D link  p <-> q         : 2*L_P + L_L   (PHY out + link + PHY in)
    internal  p <-> q same chiplet, relay-capable : L_R

Because virtual sources have no in-edges and sinks no out-edges, no path can
"tunnel" through a chiplet via its virtual nodes; through-traffic is possible
only across internal edges, which exist exactly for relay-capable chiplets —
this encodes the paper's relay semantics without per-node surcharges.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .chiplets import ArchSpec

INF = np.float32(1.0e9)


@dataclass
class PlacedPhys:
    """Geometry of one concrete placement, host-side."""

    pos: np.ndarray       # [Vp, 2] float32, PHY positions in mm
    owner: np.ndarray     # [Vp] int32, owning chiplet instance
    relay: np.ndarray     # [N] bool, per chiplet instance
    kinds: np.ndarray     # [N] int8, chiplet kind per instance
    area: float           # enclosing-rectangle area in mm^2


@dataclass
class ScoreGraph:
    """Fixed-shape scoring input for one placement (stackable into batches)."""

    W: np.ndarray          # [V, V] float32 latency weights (diag 0, INF else)
    edges: np.ndarray      # [E_max, 2] int32 directed D2D edges (padded)
    edge_mask: np.ndarray  # [E_max] bool
    area: np.float32
    connected: bool

    @property
    def V(self) -> int:
        return self.W.shape[0]


class _UnionFind:
    def __init__(self, n: int):
        self.p = list(range(n))

    def find(self, a: int) -> int:
        while self.p[a] != a:
            self.p[a] = self.p[self.p[a]]
            a = self.p[a]
        return a

    def union(self, a: int, b: int) -> bool:
        ra, rb = self.find(a), self.find(b)
        if ra == rb:
            return False
        self.p[ra] = rb
        return True


def infer_links_mst(arch: ArchSpec, geo: PlacedPhys,
                    strict_phy_use: bool = False
                    ) -> tuple[list[tuple[int, int]], bool]:
    """§VI-A topology inference: MST over the PHY graph + augmentation.

    Returns (links, connected).  ``links`` are undirected PHY index pairs.

    * Internal edges (weight 0 for MST purposes) join all PHYs of a
      relay-capable chiplet.
    * Candidate edges join PHYs of different chiplets at distance <=
      max_link_mm; their MST weight is the link length.
    * D2D links = candidate edges picked by the MST, then remaining candidate
      edges in increasing-weight order whenever both endpoint PHYs are still
      unused by a D2D link.
    * ``strict_phy_use=True`` additionally forbids the MST itself from
      assigning two links to one PHY (beyond-paper physical constraint; the
      paper's formulation is the default).
    """
    Vp = geo.pos.shape[0]
    uf = _UnionFind(Vp)
    # Internal (free) unions inside relay chiplets.
    for c in np.unique(geo.owner):
        idx = np.nonzero(geo.owner == c)[0]
        if geo.relay[c]:
            for k in range(1, len(idx)):
                uf.union(int(idx[0]), int(idx[k]))
    # Candidate edges (vectorized pairwise distances).
    diff = geo.pos[:, None, :] - geo.pos[None, :, :]
    if arch.distance == "manhattan":
        dist = np.abs(diff).sum(-1)
    else:
        dist = np.sqrt((diff ** 2).sum(-1))
    same_owner = geo.owner[:, None] == geo.owner[None, :]
    upper = np.triu(np.ones((Vp, Vp), dtype=bool), k=1)
    ok = upper & ~same_owner & (dist <= arch.max_link_mm + 1e-9)
    pp, qq = np.nonzero(ok)
    order = np.argsort(dist[pp, qq], kind="stable")
    cands: list[tuple[float, int, int]] = [
        (float(dist[pp[i], qq[i]]), int(pp[i]), int(qq[i])) for i in order]
    phy_used = np.zeros(Vp, dtype=bool)
    links: list[tuple[int, int]] = []
    # Kruskal over candidate edges (internal edges already merged, weight 0).
    for d, p, q in cands:
        if strict_phy_use and (phy_used[p] or phy_used[q]):
            continue
        if uf.union(p, q):
            links.append((p, q))
            phy_used[p] = phy_used[q] = True
    # Connectivity: every chiplet's component must be the same.
    roots = {uf.find(int(np.nonzero(geo.owner == c)[0][0]))
             for c in np.unique(geo.owner)}
    # A chiplet with several PHYs and no relay: its PHYs are separate UF nodes;
    # the chiplet counts as connected if ANY of its PHYs is in the main
    # component.  Compute per-chiplet connectivity against the largest root.
    comp_of_phy = np.array([uf.find(p) for p in range(Vp)])
    main = np.bincount(comp_of_phy).argmax()
    connected = True
    for c in np.unique(geo.owner):
        idx = np.nonzero(geo.owner == c)[0]
        if not np.any(comp_of_phy[idx] == main):
            connected = False
            break
    if len(roots) > 1 and not connected:
        pass  # fall through; caller will retry the generating operation
    # Augmentation: add remaining candidates joining two unused PHYs.
    for d, p, q in cands:
        if not phy_used[p] and not phy_used[q] and (p, q) not in links:
            links.append((p, q))
            phy_used[p] = phy_used[q] = True
    return links, connected


def build_score_graph(arch: ArchSpec, geo: PlacedPhys,
                      links: list[tuple[int, int]], e_max: int,
                      connected: bool) -> ScoreGraph:
    """Assemble the fixed-shape ScoreGraph from geometry + chosen D2D links."""
    Vp = geo.pos.shape[0]
    N = geo.kinds.shape[0]
    V = Vp + 2 * N
    W = np.full((V, V), INF, dtype=np.float32)
    np.fill_diagonal(W, 0.0)
    d2d = np.float32(arch.latency.d2d_cost())
    lr = np.float32(arch.latency.l_relay)
    # Internal relay edges.
    for c in range(N):
        if not geo.relay[c]:
            continue
        idx = np.nonzero(geo.owner == c)[0]
        for a in range(len(idx)):
            for b in range(a + 1, len(idx)):
                p, q = int(idx[a]), int(idx[b])
                W[p, q] = min(W[p, q], lr)
                W[q, p] = min(W[q, p], lr)
    # D2D links.
    for p, q in links:
        W[p, q] = min(W[p, q], d2d)
        W[q, p] = min(W[q, p], d2d)
    # Virtual source/sink edges.
    for c in range(N):
        idx = np.nonzero(geo.owner == c)[0]
        W[Vp + c, idx] = 0.0          # src_c -> own PHYs
        W[idx, Vp + N + c] = 0.0      # own PHYs -> dst_c
    edges = np.zeros((e_max, 2), dtype=np.int32)
    mask = np.zeros((e_max,), dtype=bool)
    n_e = 0
    for p, q in links:
        for (u, v) in ((p, q), (q, p)):
            if n_e >= e_max:  # pragma: no cover - e_max sized generously
                raise ValueError("e_max too small")
            edges[n_e] = (u, v)
            mask[n_e] = True
            n_e += 1
    return ScoreGraph(W=W, edges=edges, edge_mask=mask,
                      area=np.float32(geo.area), connected=connected)


def stack_graphs(graphs: list[ScoreGraph]) -> dict:
    """Stack per-placement ScoreGraphs into batched arrays for JAX scoring."""
    return dict(
        W=np.stack([g.W for g in graphs]),
        edges=np.stack([g.edges for g in graphs]),
        edge_mask=np.stack([g.edge_mask for g in graphs]),
        area=np.array([g.area for g in graphs], dtype=np.float32),
    )
