"""The user-defined cost function (paper §IV-B).

cost(placement) = sum_t w_lat[t] * lat_t / E[lat_t]
               + sum_t w_thr[t] * (1/thr_t) / E[1/thr_t]
               + w_area * area / E[area]

where the expectations are *normalizers*: statistics of each raw component
over ``norm_samples`` random placements (Table II, "Norm. Samples").
Throughput enters inverted so that every term is "lower is better".

This module keeps the legacy entry points (:class:`CostNormalizers`,
:func:`cost_components`, :func:`total_cost`); the formula itself now lives
in the pluggable ``repro.core.objective`` layer — :func:`total_cost`
evaluates the default :class:`~repro.core.objective.Objective` built from
the (deprecated) ``ArchSpec.w_*`` weights.  Same weights, same float64
component math (``cost_components`` is unchanged and serves as the
independent reference in the tests); the only numerical change is the
summation order — components are now accumulated grouped by term (all
lat, all inv-thr, area) instead of interleaved per traffic type, which
shifts totals by at most one float64 ulp versus the historical
``sum(cost_components(...).values())``.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass, field

import numpy as np

from .chiplets import TRAFFIC_TYPES, ArchSpec
from .objective import Objective, objective_cost_host

_EPS = 1.0e-6


@dataclass
class CostNormalizers:
    lat: dict = field(default_factory=dict)     # type -> mean latency
    inv_thr: dict = field(default_factory=dict)  # type -> mean 1/throughput
    area: float = 1.0
    # Traffic types whose normalizer fell back to 1.0 because *every* norm
    # sample was disconnected (lat >= 1e8).  A non-empty tuple means the
    # corresponding cost terms are unnormalized and skew the total.
    degenerate: tuple = ()

    @staticmethod
    def from_samples(metrics: dict, policy: str = "mean"
                     ) -> "CostNormalizers":
        """Normalizers from random-placement metrics.

        ``policy`` is the objective's normalizer policy: ``"mean"`` (the
        paper's expectation), ``"median"`` (robust to heavy-tailed
        latency/throughput draws), or ``"ones"`` (raw, unnormalized
        components).
        """
        if policy == "ones":
            return CostNormalizers(
                lat={t: 1.0 for t in TRAFFIC_TYPES},
                inv_thr={t: 1.0 for t in TRAFFIC_TYPES}, area=1.0)
        stat = {"mean": np.mean, "median": np.median}[policy]
        n = CostNormalizers()
        bad = []
        for t in TRAFFIC_TYPES:
            lat = np.asarray(metrics[f"lat_{t}"], dtype=np.float64)
            thr = np.asarray(metrics[f"thr_{t}"], dtype=np.float64)
            ok = lat < 1.0e8
            if ok.any():
                n.lat[t] = float(stat(lat[ok]))
                n.inv_thr[t] = float(stat(1.0 / np.maximum(thr[ok], _EPS)))
            else:
                n.lat[t] = 1.0
                n.inv_thr[t] = 1.0
                bad.append(t)
        n.area = float(stat(np.asarray(metrics["area"], dtype=np.float64)))
        if bad:
            n.degenerate = tuple(bad)
            warnings.warn(
                f"all norm samples disconnected for traffic type(s) "
                f"{', '.join(bad)}; normalizers fall back to 1.0 and the "
                f"corresponding cost terms are unnormalized "
                f"(degenerate_norms flag set)", RuntimeWarning, stacklevel=2)
        return n


def cost_components(metrics: dict, arch: ArchSpec,
                    norm: CostNormalizers) -> dict:
    """Normalized, weighted components (9 of them, Fig. 4)."""
    comp = {}
    for i, t in enumerate(TRAFFIC_TYPES):
        lat = np.asarray(metrics[f"lat_{t}"], dtype=np.float64)
        thr = np.asarray(metrics[f"thr_{t}"], dtype=np.float64)
        comp[f"lat_{t}"] = arch.w_lat[i] * lat / max(norm.lat[t], _EPS)
        comp[f"thr_{t}"] = (arch.w_thr[i]
                            * (1.0 / np.maximum(thr, _EPS))
                            / max(norm.inv_thr[t], _EPS))
    comp["area"] = (arch.w_area
                    * np.asarray(metrics["area"], dtype=np.float64)
                    / max(norm.area, _EPS))
    return comp


def total_cost(metrics: dict, arch: ArchSpec, norm: CostNormalizers
               ) -> np.ndarray:
    """Legacy entry point: the default objective built from the
    (deprecated) ``ArchSpec.w_*`` weights, evaluated on host float64.
    Summation is grouped by term (all lat, all inv-thr, area) — the
    canonical order shared with the objective layer."""
    return objective_cost_host(metrics, Objective.from_arch(arch), norm)
