"""Named registries for the experiment API (optimizers, scorer backends).

The PlaceIT pipeline is pluggable at two seams:

* **optimizers** — search algorithms over a placement representation, all
  with the uniform signature ``(evaluator, rng, budget, params) -> OptResult``
  plus a typed params dataclass (``api.BRParams`` etc.).
* **scorer backends** — the Floyd-Warshall ``W -> (D, Ncnt)`` implementation
  that dominates evaluation time (paper Table V): the pure-XLA reference or
  the Pallas VMEM-resident kernel, selected by name (``"fw-ref"``,
  ``"fw-pallas"``).

Entries are registered with decorators::

    @register_optimizer("tabu", params_cls=TabuParams)
    def tabu(evaluator, rng, budget, params): ...

    @register_scorer_backend("fw-mine")
    def _build():            # zero-arg factory -> fw_impl callable
        return my_fw_impl

Backends are registered as zero-arg *factories* so optional dependencies
(e.g. Pallas) are only imported when the backend is actually selected.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable


class Registry:
    """A named, typo-friendly mapping used for all pluggable seams."""

    def __init__(self, kind: str):
        self.kind = kind
        self._items: dict[str, Any] = {}

    def add(self, name: str, obj: Any) -> Any:
        if name in self._items:
            raise ValueError(f"duplicate {self.kind} {name!r}")
        self._items[name] = obj
        return obj

    def get(self, name: str) -> Any:
        try:
            return self._items[name]
        except KeyError:
            raise KeyError(
                f"unknown {self.kind} {name!r}; registered: "
                f"{', '.join(sorted(self._items)) or '(none)'}") from None

    def names(self) -> tuple[str, ...]:
        return tuple(sorted(self._items))

    def __contains__(self, name: str) -> bool:
        return name in self._items


@dataclass(frozen=True)
class OptimizerEntry:
    name: str
    fn: Callable            # (evaluator, rng, budget, params) -> OptResult
    params_cls: type        # typed hyper-parameter dataclass


OPTIMIZERS = Registry("optimizer")
SCORER_BACKENDS = Registry("scorer backend")


def register_optimizer(name: str, *, params_cls: type):
    """Decorator: register ``fn(evaluator, rng, budget, params)`` under
    ``name`` with its typed params dataclass."""
    def deco(fn):
        OPTIMIZERS.add(name, OptimizerEntry(name, fn, params_cls))
        return fn
    return deco


def register_scorer_backend(name: str):
    """Decorator: register a zero-arg factory returning the fw_impl
    callable ``W -> (D, Ncnt)`` under ``name``."""
    def deco(factory):
        SCORER_BACKENDS.add(name, factory)
        return factory
    return deco


def resolve_backend(backend) -> Callable:
    """Resolve a backend name (or pass through a raw callable) to the
    fw_impl function.  Raw callables are allowed for the legacy
    ``Experiment.fw_impl`` shim and for experimentation."""
    if callable(backend):
        return backend
    return SCORER_BACKENDS.get(backend)()
