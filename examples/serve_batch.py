"""Batched serving with the slot engine (deliverable b): prefill + decode,
continuous batching over more requests than slots.

  PYTHONPATH=src python examples/serve_batch.py [--requests 12]
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models.model import build_model
from repro.serve.engine import EngineConfig, Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-tokens", type=int, default=24)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, EngineConfig(
        n_slots=args.slots, cache_len=128, eos=-1))

    rng = np.random.default_rng(0)
    reqs = []
    for i in range(args.requests):
        plen = int(rng.integers(4, 32))
        r = Request(i, rng.integers(3, cfg.vocab, size=plen)
                    .astype(np.int32), max_tokens=args.max_tokens)
        reqs.append(r)
        eng.submit(r)
    t0 = time.monotonic()
    ticks = eng.run()
    dt = time.monotonic() - t0
    done = sum(r.done for r in reqs)
    n_tok = sum(len(r.out_tokens) for r in reqs)
    print(f"{done}/{len(reqs)} requests served, {n_tok} tokens, "
          f"{ticks} engine ticks, {dt:.1f}s "
          f"({n_tok / max(dt, 1e-9):.1f} tok/s on CPU, "
          f"{args.slots} slots)")
    print("sample output:", reqs[0].out_tokens[:10])


if __name__ == "__main__":
    main()
