"""Paper Figs. 14 & 15: synthetic per-type traffic — latency & throughput
of PlaceIT designs vs the 2D-mesh baseline, in both chiplet configurations
(*baseline*: 1 PHY / no relay on mem+IO; *placeit*: 4 PHY + relay).

Validated claims: C2M / C2I / M2I latency improve in every configuration
(§VII-B headline: C2M up to -28%, M2I up to -62%); throughput gains need
the *placeit* configuration.
"""
from __future__ import annotations

import json
import os

import numpy as np

from repro.core.api import (Budget, GAParams, make_evaluator, make_rep,
                            paper_defaults)
from repro.core.baseline import MeshBaseline
from repro.core.chiplets import TRAFFIC_TYPES, paper_arch
from repro.core.registries import OPTIMIZERS

from .common import budget, emit, out_dir


def optimize_and_compare(arch_name: str, config: str, quick: bool) -> dict:
    arch = paper_arch(arch_name, config)
    rep = make_rep(arch, arch_name)
    rng = np.random.default_rng(0)
    ev = make_evaluator(rep, arch, rng=rng,
                        norm_samples=budget(quick, 32, 500))
    ga = paper_defaults(arch_name).ga
    pop = budget(quick, 24, ga.population)
    params = GAParams(population=pop,
                      elitism=budget(quick, 5, ga.elitism),
                      tournament=budget(quick, 5, ga.tournament),
                      p_mutation=ga.p_mutation)
    res = OPTIMIZERS.get("ga").fn(
        ev, rng, Budget(evals=pop * budget(quick, 8, 50)), params)
    base = {k: float(v[0]) for k, v in ev.score(
        [MeshBaseline(arch).build()[0]]).items()}
    opt = res.best_metrics
    out = {}
    for t in TRAFFIC_TYPES:
        lat_red = 1.0 - opt[f"lat_{t}"] / base[f"lat_{t}"]
        thr_gain = opt[f"thr_{t}"] / max(base[f"thr_{t}"], 1e-9) - 1.0
        out[f"lat_{t}_reduction"] = lat_red
        out[f"thr_{t}_gain"] = thr_gain
        emit(f"fig14_15_{config}_{t}_latency_reduction",
             round(lat_red, 3),
             f"opt={opt[f'lat_{t}']:.1f}cyc base={base[f'lat_{t}']:.1f}cyc")
    return out


def run(quick: bool = True) -> dict:
    results = {}
    for config in ("baseline", "placeit"):
        results[config] = optimize_and_compare("homog32", config, quick)
    # headline checks
    emit("fig14_c2m_latency_improves",
         results["baseline"]["lat_c2m_reduction"] > 0)
    emit("fig14_m2i_latency_improves",
         results["baseline"]["lat_m2i_reduction"] > 0)
    emit("fig15_placeit_config_c2m_thr_gain",
         round(results["placeit"]["thr_c2m_gain"], 3))
    with open(os.path.join(out_dir(), "fig14_15.json"), "w") as f:
        json.dump(results, f, indent=1, default=float)
    return results


def main(quick: bool = True):
    run(quick)


if __name__ == "__main__":
    main(quick=os.environ.get("BENCH_FULL", "") != "1")
