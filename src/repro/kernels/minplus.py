"""Pallas TPU kernels for min-plus / APSP — PlaceIT's scoring hot spot.

PlaceIT evaluates thousands of placements; every evaluation runs an
all-pairs-shortest-path with path *counting* over the PHY-level latency
graph (V = #PHYs + 2*#chiplets, a few hundred nodes).  On TPU the XLA
`fori_loop` formulation round-trips the (V, V) distance and count matrices
through HBM on every one of the V rank-1 relaxation steps.  Both kernels
below keep the working set VMEM-resident instead:

* ``fw_counts_pallas`` — batched whole-matrix Floyd-Warshall **with path
  counts**: one grid program per placement, (V, V) D and N matrices live in
  VMEM for the entire V-step relaxation.  This is the kernel the scorer
  uses (exact same math as ``ref.fw_counts_ref``).  V is padded to a
  multiple of 128 (lane width) with isolated nodes.

* ``minplus_tiled_pallas`` — blocked tropical matmul (distances only) for
  graphs too large for a VMEM-resident FW; the classic (i, j, k) tiling
  with an accumulate-min inner loop.  Used for beyond-paper-scale APSP via
  repeated squaring.

Hardware note (DESIGN.md §3): (min, +) has no MXU mapping — these are VPU
kernels; tiles are (8k, 128)-aligned.  On CPU both run via interpret=True.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from . import _compat

INF_CUT = 1.0e8
_COUNT_CLIP = 1.0e30


# ---------------------------------------------------------------------------
# Batched VMEM-resident Floyd-Warshall with path counts.
# ---------------------------------------------------------------------------

def _fw_counts_kernel(w_ref, d_ref, n_ref, *, V: int):
    W = w_ref[0]                                   # (V, V) fp32 in VMEM
    row = jax.lax.broadcasted_iota(jnp.int32, (V, V), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (V, V), 1)
    eye = (row == col)
    N0 = jnp.where((W < INF_CUT) & ~eye, 1.0, 0.0) + eye.astype(W.dtype)

    def body(k, carry):
        D, N = carry
        dik = jax.lax.dynamic_slice(D, (0, k), (V, 1))     # column k
        dkj = jax.lax.dynamic_slice(D, (k, 0), (1, V))     # row k
        nik = jax.lax.dynamic_slice(N, (0, k), (V, 1))
        nkj = jax.lax.dynamic_slice(N, (k, 0), (1, V))
        cand = dik + dkj
        ncand = jnp.minimum(nik * nkj, _COUNT_CLIP)
        notk = (row != k) & (col != k)
        lt = (cand < D) & notk
        eq = (cand == D) & notk & (cand < INF_CUT)
        D = jnp.where(lt, cand, D)
        N = jnp.where(lt, ncand, N + jnp.where(eq, ncand, 0.0))
        N = jnp.minimum(N, _COUNT_CLIP)
        return D, N

    D, N = jax.lax.fori_loop(0, V, body, (W, N0))
    d_ref[0] = D
    n_ref[0] = N


def fw_counts_pallas(W: jnp.ndarray, *, interpret: bool = True
                     ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Batched FW + counts.  W: [B, V, V] float32, V % 128 == 0 preferred.

    Pads V up to a multiple of 128 with isolated nodes (diag 0, else INF);
    padded rows/cols do not interact with real nodes.
    """
    squeeze = W.ndim == 2
    if squeeze:
        W = W[None]
    B, V0, _ = W.shape
    Vp = max(128, -(-V0 // 128) * 128)
    if Vp != V0:
        pad = jnp.full((B, Vp, Vp), 1e9, dtype=W.dtype)
        pad = pad.at[:, :V0, :V0].set(W)
        idx = jnp.arange(V0, Vp)
        pad = pad.at[:, idx, idx].set(0.0)
        W = pad
    kern = functools.partial(_fw_counts_kernel, V=Vp)
    D, N = pl.pallas_call(
        kern,
        grid=(B,),
        in_specs=[pl.BlockSpec((1, Vp, Vp), lambda b: (b, 0, 0))],
        out_specs=[pl.BlockSpec((1, Vp, Vp), lambda b: (b, 0, 0)),
                   pl.BlockSpec((1, Vp, Vp), lambda b: (b, 0, 0))],
        out_shape=[jax.ShapeDtypeStruct((B, Vp, Vp), W.dtype),
                   jax.ShapeDtypeStruct((B, Vp, Vp), W.dtype)],
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(W)
    D, N = D[:, :V0, :V0], N[:, :V0, :V0]
    if squeeze:
        D, N = D[0], N[0]
    return D, N


# ---------------------------------------------------------------------------
# Tiled min-plus matmul (distances only) for large V.
# ---------------------------------------------------------------------------

def _minplus_kernel(a_ref, b_ref, o_ref, *, bk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.full_like(o_ref, 1e9)

    a = a_ref[...]                                  # (bm, bk)
    b = b_ref[...]                                  # (bk, bn)

    def body(t, acc):
        # Rank-1 (min, +) update: keeps the working set at (bm, bn).
        return jnp.minimum(acc, a[:, t][:, None] + b[t, :][None, :])

    o_ref[...] = jax.lax.fori_loop(0, bk, body, o_ref[...])


def minplus_tiled_pallas(A: jnp.ndarray, B: jnp.ndarray, *,
                         bm: int = 128, bn: int = 128, bk: int = 128,
                         interpret: bool = True) -> jnp.ndarray:
    """Tropical matmul out[i,j] = min_k A[i,k] + B[k,j], tiled for VMEM.

    A: [M, K], B: [K, N]; M, N, K padded to tile multiples with +INF
    (identity of min) — padding never wins the min.
    """
    M, K = A.shape
    K2, N = B.shape
    assert K == K2
    Mp, Kp, Np = (-(-M // bm) * bm, -(-K // bk) * bk, -(-N // bn) * bn)
    Ap = jnp.full((Mp, Kp), 1e9, A.dtype).at[:M, :K].set(A)
    Bp = jnp.full((Kp, Np), 1e9, B.dtype).at[:K, :N].set(B)
    out = pl.pallas_call(
        functools.partial(_minplus_kernel, bk=bk),
        grid=(Mp // bm, Np // bn, Kp // bk),
        in_specs=[pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
                  pl.BlockSpec((bk, bn), lambda i, j, k: (k, j))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), A.dtype),
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(Ap, Bp)
    return out[:M, :N]


def apsp_tiled_pallas(W: jnp.ndarray, *, interpret: bool = True,
                      **tile_kw) -> jnp.ndarray:
    """APSP by repeated tiled min-plus squaring (distances only)."""
    V = W.shape[-1]
    D = W
    n_iter = max(1, int(jnp.ceil(jnp.log2(max(V - 1, 2)))))
    for _ in range(n_iter):
        D = jnp.minimum(D, minplus_tiled_pallas(D, D, interpret=interpret,
                                                **tile_kw))
    return D
