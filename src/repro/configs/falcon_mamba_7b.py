"""Assigned architecture config: falcon-mamba-7b (see registry for source).

Exposes CONFIG (exact published hyper-parameters) and SMOKE (reduced copy
for CPU smoke tests).  Select with ``--arch falcon-mamba-7b``.
"""
from .registry import get_config

CONFIG = get_config("falcon-mamba-7b")
SMOKE = CONFIG.reduced()
