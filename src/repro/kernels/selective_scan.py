"""Mamba-1 selective-scan Pallas kernel (falcon-mamba's hot op).

The CUDA reference keeps the per-channel SSM state in registers/SMEM while
streaming the sequence.  The TPU adaptation (DESIGN.md §3): grid over
(batch, channel-blocks); the (bd, N) state lives in VMEM scratch; the kernel
walks the sequence with a fori_loop, reading (bd,) input slices and writing
(bd,) outputs per step — HBM traffic is one pass over x/dt/B/C/y, the
roofline minimum for this memory-bound op.  The recurrence itself is VPU
element-wise work (no MXU mapping for a diagonal SSM).

Layout: channel-minor (B, S, D) inputs are transposed to (B, D, S) by the
wrapper so each time step reads a contiguous lane vector.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from . import _compat


def _sscan_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, h0_ref,
                  y_ref, hf_ref, h_scr, *, S: int):
    # Blocks: x/dt/y (1, bd, S); a (bd, N); b/c (1, S, N); d (1, bd);
    # h0/hf (1, bd, N); scratch h (bd, N) fp32.
    A = a_ref[...].astype(jnp.float32)              # (bd, N)
    Dskip = d_ref[0].astype(jnp.float32)            # (bd,)
    h_scr[...] = h0_ref[0].astype(jnp.float32)

    def step(t, _):
        xt = x_ref[0, :, t].astype(jnp.float32)     # (bd,)
        dtt = dt_ref[0, :, t].astype(jnp.float32)   # (bd,)
        Bt = b_ref[0, t, :].astype(jnp.float32)     # (N,)
        Ct = c_ref[0, t, :].astype(jnp.float32)     # (N,)
        dA = jnp.exp(dtt[:, None] * A)              # (bd, N)
        h = dA * h_scr[...] + (dtt * xt)[:, None] * Bt[None, :]
        h_scr[...] = h
        y = jnp.sum(h * Ct[None, :], axis=1) + Dskip * xt
        y_ref[0, :, t] = y.astype(y_ref.dtype)
        return 0

    jax.lax.fori_loop(0, S, step, 0)
    hf_ref[0] = h_scr[...].astype(hf_ref.dtype)


def selective_scan_pallas(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
                          B: jnp.ndarray, C: jnp.ndarray, D: jnp.ndarray,
                          h0: jnp.ndarray | None = None, *,
                          bd: int = 128, interpret: bool = True
                          ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x, dt: [Bt, S, Di]; A: [Di, N]; B, C: [Bt, S, N]; D: [Di].

    Returns (y [Bt, S, Di], h_final [Bt, Di, N]).  Matches
    ``ref.selective_scan_ref``.
    """
    Bt, S, Di = x.shape
    N = A.shape[-1]
    if h0 is None:
        h0 = jnp.zeros((Bt, Di, N), dtype=jnp.float32)
    bd_ = min(bd, Di)
    Dp = -(-Di // bd_) * bd_
    xt = jnp.swapaxes(x, 1, 2)                      # (Bt, Di, S)
    dtt = jnp.swapaxes(dt, 1, 2)
    if Dp != Di:
        padc = ((0, 0), (0, Dp - Di), (0, 0))
        xt, dtt = jnp.pad(xt, padc), jnp.pad(dtt, padc)
        A = jnp.pad(A, ((0, Dp - Di), (0, 0)))
        D = jnp.pad(D, (0, Dp - Di))
        h0 = jnp.pad(h0, ((0, 0), (0, Dp - Di), (0, 0)))
    kern = functools.partial(_sscan_kernel, S=S)
    y, hf = pl.pallas_call(
        kern,
        grid=(Bt, Dp // bd_),
        in_specs=[
            pl.BlockSpec((1, bd_, S), lambda b, i: (b, i, 0)),   # x
            pl.BlockSpec((1, bd_, S), lambda b, i: (b, i, 0)),   # dt
            pl.BlockSpec((bd_, N), lambda b, i: (i, 0)),         # A
            pl.BlockSpec((1, S, N), lambda b, i: (b, 0, 0)),     # B
            pl.BlockSpec((1, S, N), lambda b, i: (b, 0, 0)),     # C
            pl.BlockSpec((1, bd_), lambda b, i: (b, i)),         # D (skip)
            pl.BlockSpec((1, bd_, N), lambda b, i: (b, i, 0)),   # h0
        ],
        out_specs=[pl.BlockSpec((1, bd_, S), lambda b, i: (b, i, 0)),
                   pl.BlockSpec((1, bd_, N), lambda b, i: (b, i, 0))],
        out_shape=[jax.ShapeDtypeStruct((Bt, Dp, S), x.dtype),
                   jax.ShapeDtypeStruct((Bt, Dp, N), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((bd_, N), jnp.float32)],
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(xt, dtt, A, jnp.asarray(B), jnp.asarray(C),
      jnp.broadcast_to(D[None], (Bt, Dp)), h0)
    y = jnp.swapaxes(y, 1, 2)[:, :, :Di]
    return y, hf[:, :Di]
