"""Mamba-1 block (falcon-mamba-7b): causal conv + selective SSM scan.

Structure (Mamba paper):
    x -> in_proj -> (u, z)                u, z: [B, S, d_inner]
    u -> causal depthwise conv(width 4) -> silu
    (dt, B, C) = x_proj(u);  dt = softplus(dt_proj(dt) + bias)
    y = selective_scan(u, dt, A=-exp(A_log), B, C, D)
    out = (y * silu(z)) @ out_proj

Training/prefill uses a *chunked* scan: ``lax.scan`` over sequence chunks
carrying the (B, d_inner, N) state, with the cheap within-chunk recurrence
unrolled — state tensors never materialize beyond one chunk (DESIGN.md §3).
On TPU the inner chunk can be swapped for the Pallas ``selective_scan``
kernel.  Decode keeps (conv window, ssm state) in the cache — O(1) per
token, which is what makes falcon-mamba a ``long_500k`` architecture.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..kernels import ops
from ..sharding.partition import shard
from .config import LMConfig
from .layers import dense_init, rms_norm, rms_norm_init


def mamba_init(key, cfg: LMConfig) -> dict:
    ks = jax.random.split(key, 6)
    D, Di, N, R = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.dt_rank_
    W = cfg.ssm_conv
    dt = jnp.dtype(cfg.dtype)
    # S4D-real initialization for A; dt bias giving softplus(dt) ~ U(1e-3, 0.1)
    A = jnp.broadcast_to(jnp.arange(1, N + 1, dtype=jnp.float32)[None], (Di, N))
    u = jax.random.uniform(ks[4], (Di,), jnp.float32)
    dt_init = jnp.exp(u * (jnp.log(0.1) - jnp.log(1e-3)) + jnp.log(1e-3))
    dt_bias = dt_init + jnp.log1p(-jnp.exp(-dt_init))  # inverse softplus
    return {
        "norm": rms_norm_init(D),
        "in_proj": dense_init(ks[0], D, 2 * Di, dt),
        "conv_w": (jax.random.normal(ks[1], (W, Di), jnp.float32)
                   * (W ** -0.5)).astype(dt),
        "conv_b": jnp.zeros((Di,), dt),
        "x_proj": dense_init(ks[2], Di, R + 2 * N, dt),
        "dt_w": dense_init(ks[3], R, Di, jnp.float32, scale=R ** -0.5),
        "dt_b": dt_bias,
        "A_log": jnp.log(A),
        "Dskip": jnp.ones((Di,), jnp.float32),
        "out_proj": dense_init(ks[5], Di, D, dt),
    }


def _conv_causal(u, w, b, state=None):
    """Depthwise causal conv. u: [B, S, Di]; w: [W, Di]; state: [B, W-1, Di].

    Returns (y [B, S, Di], new_state [B, W-1, Di]).
    """
    W = w.shape[0]
    if state is None:
        state = jnp.zeros((u.shape[0], W - 1, u.shape[2]), u.dtype)
    ext = jnp.concatenate([state, u], axis=1)                # [B, S+W-1, Di]
    y = sum(ext[:, i:i + u.shape[1]] * w[i][None, None] for i in range(W))
    return y + b[None, None], ext[:, -(W - 1):]


def _ssm_params(p, u, cfg: LMConfig):
    R, N = cfg.dt_rank_, cfg.ssm_state
    xdbc = u @ p["x_proj"]                                    # [B,S,R+2N]
    dt_r, Bm, Cm = jnp.split(xdbc, [R, R + N], axis=-1)
    dt = jax.nn.softplus(dt_r.astype(jnp.float32) @ p["dt_w"]
                         + p["dt_b"][None, None])
    A = -jnp.exp(p["A_log"])
    return dt, A, Bm.astype(jnp.float32), Cm.astype(jnp.float32)


def _scan_chunked(u, dt, A, Bm, Cm, Dskip, h0, chunk: int, impl: str):
    """lax.scan over chunks; inside each chunk the Pallas/ref kernel runs."""
    B, S, Di = u.shape
    nc = -(-S // chunk)
    pad = nc * chunk - S
    if pad:
        u = jnp.pad(u, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    resh = lambda t: jnp.moveaxis(
        t.reshape(B, nc, chunk, *t.shape[2:]), 1, 0)

    def body(h, xs):
        uc, dtc, bc, cc = xs
        y, h = ops.selective_scan(uc, dtc, A, bc, cc, Dskip, h, impl=impl)
        return h, y

    hT, ys = jax.lax.scan(body, h0, (resh(u), resh(dt), resh(Bm), resh(Cm)))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, nc * chunk, Di)[:, :S]
    return y, hT


def mamba_train(p, x, cfg: LMConfig, *, chunk: int = 256,
                return_cache: bool = False, cache_len: int = 0):
    """x: [B, S, D] -> [B, S, D] (+ cache when prefilling)."""
    B, S, D = x.shape
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    uz = h @ p["in_proj"]
    u, z = jnp.split(uz, 2, axis=-1)
    u = shard(u, "act_inner")
    u, conv_state = _conv_causal(u, p["conv_w"], p["conv_b"])
    u = jax.nn.silu(u)
    dt, A, Bm, Cm = _ssm_params(p, u, cfg)
    h0 = jnp.zeros((B, cfg.d_inner, cfg.ssm_state), jnp.float32)
    if S <= chunk:
        y, hT = ops.selective_scan(u, dt, A, Bm, Cm, p["Dskip"], h0,
                                   impl=cfg.attn_impl)
    else:
        y, hT = _scan_chunked(u, dt, A, Bm, Cm, p["Dskip"], h0, chunk,
                              cfg.attn_impl)
    y = y * jax.nn.silu(z)
    o = y @ p["out_proj"]
    out = x + shard(o, "act")
    if not return_cache:
        return out
    cache = {"conv": conv_state, "h": shard(hT, "state")}
    return out, cache


def mamba_decode(p, x, cache, cfg: LMConfig, length):
    """One token: x [B, 1, D]; cache {conv [B, W-1, Di], h [B, Di, N]}."""
    B = x.shape[0]
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    uz = h @ p["in_proj"]
    u, z = jnp.split(uz, 2, axis=-1)
    u, conv_state = _conv_causal(u, p["conv_w"], p["conv_b"],
                                 state=cache["conv"])
    u = jax.nn.silu(u)
    dt, A, Bm, Cm = _ssm_params(p, u, cfg)
    dA = jnp.exp(dt[:, 0, :, None] * A[None])                # [B, Di, N]
    hn = dA * cache["h"] + (dt[:, 0] * u[:, 0].astype(jnp.float32)
                            )[..., None] * Bm[:, 0, None, :]
    y = jnp.einsum("bdn,bn->bd", hn, Cm[:, 0]) + p["Dskip"][None] \
        * u[:, 0].astype(jnp.float32)
    y = y[:, None].astype(x.dtype) * jax.nn.silu(z)
    o = y @ p["out_proj"]
    return x + o, {"conv": conv_state, "h": hn}


def mamba_cache_init(cfg: LMConfig, B: int):
    return {
        "conv": jnp.zeros((B, cfg.ssm_conv - 1, cfg.d_inner),
                          jnp.dtype(cfg.dtype)),
        "h": jnp.zeros((B, cfg.d_inner, cfg.ssm_state), jnp.float32),
    }
