"""Roofline analysis from dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch × shape) cell on the single-pod mesh, the three roofline terms on
TPU v5e (task constants):

    compute    = HLO_FLOPs_per_chip   / 197e12  FLOP/s (bf16)
    memory     = HLO_bytes_per_chip   / 819e9   B/s (HBM)
    collective = wire_bytes_per_chip  / 50e9    B/s (per ICI link)

HLO_FLOPs/bytes come from the while-aware analyzer (``hlo_cost``) over the
compiled module — they are per-chip quantities (the module is the SPMD
per-device program).  ``MODEL_FLOPS`` is the useful-work floor:
6·N·D for dense training, 6·N_active·D for MoE, and the fwd-only variants
(2·N·D) for prefill; decode uses 2·N_active per token.  The ratio
MODEL_FLOPS / HLO_FLOPs exposes remat/replication/padding waste.
"""
from __future__ import annotations

import glob
import json
import os

from ..configs import SHAPES, get_config

PEAK_FLOPS = 197e12          # bf16 FLOP/s per v5e chip
HBM_BW = 819e9               # B/s per chip
LINK_BW = 50e9               # B/s per ICI link
DCI_BW = 12.5e9              # B/s per chip across pods (assumption, DESIGN)

ARTIFACT_DIR = os.path.join("artifacts", "dryrun")


def active_params(arch: str) -> int:
    """Parameters touched per token (MoE: top_k of n_experts)."""
    import functools

    import jax

    from ..models.model import init_params

    cfg = get_config(arch)
    shapes = jax.eval_shape(
        functools.partial(init_params, cfg), jax.random.PRNGKey(0))
    total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
        keys = [str(e.key) for e in path
                if isinstance(e, jax.tree_util.DictKey)]
        n = leaf.size
        if any(k in ("we1", "we2", "we3") for k in keys) and cfg.n_experts:
            n = n * cfg.top_k // cfg.n_experts
        total += n
    return int(total)


def model_flops(arch: str, shape: str) -> float:
    """Useful-work floor for the cell (global, not per-chip)."""
    cfg = get_config(arch)
    sh = SHAPES[shape]
    n_act = active_params(arch)
    tokens = sh.global_batch * sh.seq_len
    if sh.kind == "train":
        return 6.0 * n_act * tokens
    if sh.kind == "prefill":
        return 2.0 * n_act * tokens
    # decode: one token per sequence
    return 2.0 * n_act * sh.global_batch


def load_cells(mesh: str = "single", out_dir: str = ARTIFACT_DIR
               ) -> list[dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(out_dir, f"*__{mesh}.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def roofline_row(rec: dict) -> dict:
    n_chips = rec["n_chips"]
    t_comp = rec["flops_total"] / PEAK_FLOPS
    # TPU-native bytes: the CPU backend materializes bf16<->f32 converts
    # around every dot (no native bf16 matmul); on the MXU those fuse away.
    # Both raw and corrected are recorded; terms use the corrected value.
    bytes_tpu = (rec["bytes_accessed_total"]
                 - rec.get("convert_bytes_total", 0.0))
    t_mem = bytes_tpu / HBM_BW
    wire = rec["collectives"]["wire_bytes_per_chip"]
    cross = rec["collectives"].get("cross_pod_bytes_per_chip", 0.0)
    t_coll = (wire - cross) / LINK_BW + cross / DCI_BW
    dominant = max((t_comp, "compute"), (t_mem, "memory"),
                   (t_coll, "collective"))[1]
    mf = model_flops(rec["arch"], rec["shape"]) / n_chips
    ratio = mf / max(rec["flops_total"], 1.0)
    # roofline fraction: useful work vs what the dominant term costs
    t_dom = max(t_comp, t_mem, t_coll)
    frac = (mf / PEAK_FLOPS) / max(t_dom, 1e-30)
    mem = rec.get("memory_analysis", {})
    hbm_gb = (mem.get("argument_size_in_bytes", 0)
              + mem.get("temp_size_in_bytes", 0)
              + mem.get("output_size_in_bytes", 0)
              - mem.get("alias_size_in_bytes", 0)) / 1e9
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "t_compute_s": t_comp, "t_memory_s": t_mem, "t_collective_s": t_coll,
        "t_memory_cpu_raw_s": rec["bytes_accessed_total"] / HBM_BW,
        "dominant": dominant,
        "model_flops_per_chip": mf,
        "hlo_flops_per_chip": rec["flops_total"],
        "useful_ratio": ratio,
        "roofline_fraction": min(frac, 1.0),
        "hbm_gb_per_chip": hbm_gb,
        "fits_16gb": hbm_gb <= 16.0,
    }


def report(mesh: str = "single", out_dir: str = ARTIFACT_DIR) -> list[dict]:
    rows = []
    for rec in load_cells(mesh, out_dir):
        if not rec.get("ok"):
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "mesh": rec["mesh"], "error": rec.get("error")})
            continue
        rows.append(roofline_row(rec))
    return rows


def format_table(rows: list[dict]) -> str:
    hdr = (f"{'arch':22s} {'shape':12s} {'t_comp':>9s} {'t_mem':>9s} "
           f"{'t_coll':>9s} {'dom':>10s} {'MF/HLO':>7s} {'roofl%':>7s} "
           f"{'HBM_GB':>7s} fits")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        if "error" in r:
            lines.append(f"{r['arch']:22s} {r['shape']:12s} ERROR: "
                         f"{str(r['error'])[:60]}")
            continue
        lines.append(
            f"{r['arch']:22s} {r['shape']:12s} "
            f"{r['t_compute_s']:9.2e} {r['t_memory_s']:9.2e} "
            f"{r['t_collective_s']:9.2e} {r['dominant']:>10s} "
            f"{r['useful_ratio']:7.3f} {100*r['roofline_fraction']:6.1f}% "
            f"{r['hbm_gb_per_chip']:7.2f} "
            f"{'Y' if r['fits_16gb'] else 'N'}")
    return "\n".join(lines)


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--out", default=ARTIFACT_DIR)
    ap.add_argument("--json", default=None)
    args = ap.parse_args()
    rows = report(args.mesh, args.out)
    print(format_table(rows))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
