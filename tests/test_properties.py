"""Property-based tests for the device-resident pipeline.

Randomized-seed invariants via the optional-hypothesis shim (``_hyp``):
with hypothesis installed, ``@given`` draws seeds; without it, the same
checks run over a deterministic seed sweep (so this layer never goes
dark).  These replace the former hand-picked-seed operator spot checks in
``test_batched_pipeline.py``.

Covered properties:

* ``HomogBatch`` / ``HeteroBatch`` operator invariants on randomized PRNG
  keys — permutation validity (per-kind chiplet counts preserved by
  random/mutate/merge), rotation ranges (non-isomorphic per-kind sets;
  grid PHYs face occupied neighbors), merge carrying parent matches, and
  PRNG determinism (same key -> identical batch, distinct keys -> change).
* ``HeteroGraphBatch`` batched Borůvka vs the host Kruskal + union-find
  on randomized corner placements: bit-for-bit W / D2D edge set / area /
  component-derived ``connected``.
"""
import jax
import numpy as np
import pytest

from _hyp import HAVE_HYPOTHESIS, given, settings, st
from _invariants import assert_valid_hetero_batch, assert_valid_homog_batch

from repro.core.chiplets import IO, MEMORY, paper_arch
from repro.core.placement_hetero import HeteroRep
from repro.core.placement_homog import HomogRep
from repro.core.topology import HeteroGraphBatch

ARCH = paper_arch("homog32", "baseline")
HARCH = paper_arch("hetero32", "baseline")
R, C = 8, 5
B = 12          # batch size per drawn seed

FALLBACK_SEEDS = [0, 3, 17, 255, 99991]
MAXEX = 12      # hypothesis examples per property


@pytest.fixture(scope="module")
def rep():
    return HomogRep(ARCH, R=R, C=C)


@pytest.fixture(scope="module")
def ops(rep):
    return rep.batch_ops()


@pytest.fixture(scope="module")
def hrep():
    return HeteroRep(HARCH)


@pytest.fixture(scope="module")
def hops(hrep):
    return hrep.batch_ops()


@pytest.fixture(scope="module")
def hgb():
    return HeteroGraphBatch(HARCH)


# ---------------------------------------------------------------------------
# Core property checks (shared by @given and the deterministic sweep).
# ---------------------------------------------------------------------------

def check_homog_ops(rep, ops, seed: int):
    k0, k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 4)
    t, r = ops.random_batch(k0, B)
    assert t.dtype == np.int8 and t.shape == (B, R, C)
    assert_valid_homog_batch(rep, t, r)
    # PRNG determinism: same key -> identical batch
    t2, r2 = ops.random_batch(k0, B)
    assert np.array_equal(np.asarray(t), np.asarray(t2))
    assert np.array_equal(np.asarray(r), np.asarray(r2))
    # mutation keeps invariants and changes at least one placement
    mt, mr = ops.mutate_batch(k1, t, r)
    assert_valid_homog_batch(rep, mt, mr)
    changed = (np.asarray(mt) != np.asarray(t)).any(axis=(1, 2)) \
        | (np.asarray(mr) != np.asarray(r)).any(axis=(1, 2))
    assert changed.any()
    # merge keeps invariants and carries cells both parents agree on
    tb, rb = ops.random_batch(k2, B)
    tg, rg = ops.merge_batch(k3, t, r, tb, rb)
    assert_valid_homog_batch(rep, tg, rg)
    t_, tb_, tg_ = np.asarray(t), np.asarray(tb), np.asarray(tg)
    r_, rb_, rg_ = np.asarray(r), np.asarray(rb), np.asarray(rg)
    for b in range(B):
        match = t_[b] == tb_[b]
        assert (tg_[b][match] == t_[b][match]).all()
        # carried rotations where both parents agree on type+rotation,
        # for the single-PHY kinds (baseline memory/IO)
        rot_match = match & (r_[b] == rb_[b]) & np.isin(t_[b], [MEMORY, IO])
        assert (rg_[b][rot_match] == r_[b][rot_match]).all()


def check_hetero_ops(hrep, hops, seed: int):
    k0, k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 4)
    o, r = hops.random_batch(k0, B)
    assert o.dtype == np.int8
    assert_valid_hetero_batch(hrep, o, r)
    o2, r2 = hops.random_batch(k0, B)
    assert np.array_equal(np.asarray(o), np.asarray(o2))
    assert np.array_equal(np.asarray(r), np.asarray(r2))
    mo, mr = hops.mutate_batch(k1, o, r)
    assert_valid_hetero_batch(hrep, mo, mr)
    changed = (np.asarray(mo) != np.asarray(o)).any(axis=1) \
        | (np.asarray(mr) != np.asarray(r)).any(axis=1)
    assert changed.any()
    ob, rb = hops.random_batch(k2, B)
    og, rg = hops.merge_batch(k3, o, r, ob, rb)
    assert_valid_hetero_batch(hrep, og, rg)
    o_, ob_, og_ = np.asarray(o), np.asarray(ob), np.asarray(og)
    r_, rb_, rg_ = np.asarray(r), np.asarray(rb), np.asarray(rg)
    for b in range(B):
        match = o_[b] == ob_[b]
        assert (og_[b][match] == o_[b][match]).all()
        rmatch = match & (r_[b] == rb_[b])
        assert (rg_[b][rmatch] == r_[b][rmatch]).all()


def check_hetero_boruvka_matches_kruskal(hrep, hops, hgb, seed: int,
                                         n: int = 6):
    """Randomized placements: device Borůvka == host Kruskal bit-for-bit."""
    import jax.numpy as jnp
    rng = np.random.default_rng(seed)
    sols = [hrep.random(rng) for _ in range(n)]
    host = [hrep.score_graph(s) for s in sols]
    ppos, area = hops.geometry_batch(np.stack([s[0] for s in sols]),
                                     np.stack([s[1] for s in sols]))
    batch = {k: np.asarray(v)
             for k, v in hgb.build(jnp.asarray(ppos),
                                   jnp.asarray(area)).items()}
    assert not batch.pop("overflow").any()
    for i, g in enumerate(host):
        assert np.array_equal(batch["W"][i], g.W)
        mine = {(int(u), int(v))
                for (u, v), m in zip(batch["edges"][i],
                                     batch["edge_mask"][i]) if m}
        ref = {(int(u), int(v))
               for (u, v), m in zip(g.edges, g.edge_mask) if m}
        assert mine == ref
        assert float(batch["area"][i]) == float(g.area)
        assert bool(batch["connected"][i]) == g.connected


# ---------------------------------------------------------------------------
# Hypothesis-drawn seeds (skipped individually when hypothesis is absent).
# ---------------------------------------------------------------------------

@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
@settings(max_examples=MAXEX, deadline=None)
def test_homog_operator_invariants_property(rep, ops, seed):
    check_homog_ops(rep, ops, seed)


@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
@settings(max_examples=MAXEX, deadline=None)
def test_hetero_operator_invariants_property(hrep, hops, seed):
    check_hetero_ops(hrep, hops, seed)


@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
@settings(max_examples=8, deadline=None)
def test_hetero_boruvka_vs_kruskal_property(hrep, hops, hgb, seed):
    check_hetero_boruvka_matches_kruskal(hrep, hops, hgb, seed)


# ---------------------------------------------------------------------------
# Deterministic seed sweep: the same properties when hypothesis is not
# installed (the pinned environment), so the layer always runs.
# ---------------------------------------------------------------------------

@pytest.mark.skipif(HAVE_HYPOTHESIS,
                    reason="hypothesis drives the property above")
@pytest.mark.parametrize("seed", FALLBACK_SEEDS)
def test_homog_operator_invariants_seeds(rep, ops, seed):
    check_homog_ops(rep, ops, seed)


@pytest.mark.skipif(HAVE_HYPOTHESIS,
                    reason="hypothesis drives the property above")
@pytest.mark.parametrize("seed", FALLBACK_SEEDS)
def test_hetero_operator_invariants_seeds(hrep, hops, seed):
    check_hetero_ops(hrep, hops, seed)


@pytest.mark.skipif(HAVE_HYPOTHESIS,
                    reason="hypothesis drives the property above")
@pytest.mark.parametrize("seed", FALLBACK_SEEDS)
def test_hetero_boruvka_vs_kruskal_seeds(hrep, hops, hgb, seed):
    check_hetero_boruvka_matches_kruskal(hrep, hops, hgb, seed)
