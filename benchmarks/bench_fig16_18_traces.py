"""Paper Figs. 16-18: trace-driven simulation — average packet latency of
PlaceIT designs vs the 2D-mesh baseline, authentic and idealized injection
(§VII-C/D).  Traces are Netrace-like generated cache-coherency chains
(Table VI region structure; §V-B message mix).

Validated claims: PlaceIT reduces average packet latency on (almost) all
trace regions; idealized mode stresses the ICI harder.
"""
from __future__ import annotations

import json
import os

import numpy as np

from repro.core.baseline import MeshBaseline
from repro.core.chiplets import paper_arch
from repro.core.netsim import ChipletNet, NetSim
from repro.core.optimize import Evaluator, genetic_algorithm
from repro.core.placement_homog import HomogRep
from repro.core.traces import TraceRegion, generate_trace

from .common import budget, emit, out_dir


def run(quick: bool = True) -> dict:
    results = {}
    regions = (TraceRegion(budget(quick, 1500, 20000),
                           budget(quick, 40000, 400000)),
               TraceRegion(budget(quick, 2500, 40000),
                           budget(quick, 30000, 300000)))
    for config in ("baseline", "placeit"):
        arch = paper_arch("homog32", config)
        rep = HomogRep(arch, R=8, C=5, mutation_mode="neighbor-one")
        rng = np.random.default_rng(0)
        ev = Evaluator(rep, arch, rng=rng,
                       norm_samples=budget(quick, 32, 500))
        res = genetic_algorithm(ev, rng, population=budget(quick, 24, 200),
                                elitism=5, tournament=5,
                                max_generations=budget(quick, 8, 40))
        links, _ = rep.links_of(res.best_sol)
        geo = rep.geometry(res.best_sol)
        net_opt = ChipletNet.from_links(arch, geo, links)
        _, geo_b, links_b = MeshBaseline(arch).build()
        net_base = ChipletNet.from_links(arch, geo_b, links_b)
        sim_o, sim_b = NetSim(net_opt, arch), NetSim(net_base, arch)
        per_mode = {}
        for mode in ("authentic", "idealized"):
            for ri, reg in enumerate(regions):
                lo = sim_o.run(generate_trace(net_opt, (reg,), seed=ri),
                               mode=mode).avg_latency
                lb = sim_b.run(generate_trace(net_base, (reg,), seed=ri),
                               mode=mode).avg_latency
                speedup = lb / lo
                per_mode[f"{mode}_r{ri}"] = dict(
                    placeit=lo, baseline=lb, speedup=speedup)
                emit(f"fig16_{config}_{mode}_region{ri}_speedup",
                     round(speedup, 3),
                     f"opt={lo:.1f} base={lb:.1f}")
        sp = [v["speedup"] for v in per_mode.values()]
        results[config] = dict(regions=per_mode,
                               mean_speedup=float(np.mean(sp)))
        emit(f"fig16_{config}_mean_speedup",
             round(float(np.mean(sp)), 3))
    with open(os.path.join(out_dir(), "fig16_18.json"), "w") as f:
        json.dump(results, f, indent=1, default=float)
    return results


def main(quick: bool = True):
    run(quick)


if __name__ == "__main__":
    main(quick=os.environ.get("BENCH_FULL", "") != "1")
