"""Training substrate: optimizer, microbatching, compression, checkpoint
atomicity/restart/elastic-remesh, data pipeline determinism."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import checkpoint as ckpt
from repro.configs import get_config
from repro.data.pipeline import DataConfig, TokenStream
from repro.models.model import build_model
from repro.train.loop import LoopConfig, run
from repro.train.optimizer import (OptConfig, adamw_init, adamw_update,
                                   compress_grads, dequantize_int8, lr_at,
                                   quantize_int8)
from repro.train.step import build_train_step, init_state

CFG = get_config("tinyllama-1.1b").reduced(
    n_layers=2, d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
    d_ff=128, vocab=128, vocab_pad_to=64)


def small_setup(microbatches=1, **opt_kw):
    model = build_model(CFG)
    opt = OptConfig(lr=1e-2, warmup_steps=2, total_steps=50,
                    weight_decay=0.0, **opt_kw)
    state = init_state(model, opt, jax.random.PRNGKey(0))
    step = jax.jit(build_train_step(model, opt, microbatches=microbatches))
    ds = DataConfig(vocab=CFG.vocab, seq_len=32, global_batch=8)
    return model, opt, state, step, TokenStream(ds)


def test_loss_decreases():
    _, _, state, step, stream = small_setup()
    losses = []
    for i in range(25):
        state, m = step(state, stream.batch_at(i))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.2


def test_microbatch_equivalence():
    """Grad accumulation over 4 microbatches == single big batch."""
    _, _, s1, step1, stream = small_setup(microbatches=1)
    _, _, s4, step4, _ = small_setup(microbatches=4)
    b = stream.batch_at(0)
    n1, m1 = step1(s1, b)
    n4, m4 = step4(s4, b)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                               rtol=1e-5)
    for a, c in zip(jax.tree.leaves(n1["params"]),
                    jax.tree.leaves(n4["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(c, np.float32),
                                   rtol=2e-2, atol=2e-5)


def test_lr_schedule():
    opt = OptConfig(lr=1.0, warmup_steps=10, total_steps=110,
                    schedule="cosine")
    assert float(lr_at(opt, 0)) == 0.0
    assert float(lr_at(opt, 10)) == pytest.approx(1.0)
    assert float(lr_at(opt, 110)) == pytest.approx(0.0, abs=1e-6)
    assert 0.4 < float(lr_at(opt, 60)) < 0.6


def test_quantize_roundtrip():
    x = jnp.array(np.random.default_rng(0).standard_normal(1000),
                  jnp.float32)
    q, s = quantize_int8(x, block=128)
    y = dequantize_int8(q, s, x.shape, block=128)
    err = np.abs(np.array(x) - np.array(y)).max()
    scale = np.abs(np.array(x)).max()
    assert err <= scale / 127.0 + 1e-6


def test_compression_error_feedback_converges():
    """int8-compressed training still reduces the loss; error feedback
    keeps the accumulated quantization bias bounded."""
    _, _, state, step, stream = small_setup(compress_int8=True)
    losses = []
    for i in range(25):
        state, m = step(state, stream.batch_at(i))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.2
    err_norm = sum(float(jnp.sum(jnp.abs(e)))
                   for e in jax.tree.leaves(state["opt"]["err"]))
    assert np.isfinite(err_norm)


def test_state_int8_converges_and_shrinks():
    """8-bit Adam states: loss still decreases; state bytes ~4x smaller."""
    _, _, s32, step32, stream = small_setup()
    _, _, s8, step8, _ = small_setup(state_int8=True)
    b32 = sum(x.nbytes for x in jax.tree.leaves(s32["opt"]["m"]))
    b8 = sum(x.nbytes for x in jax.tree.leaves(s8["opt"]["m"]))
    assert b8 < b32 / 3
    losses = []
    for i in range(25):
        s8, m = step8(s8, stream.batch_at(i))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.2


def test_compressed_psum_matches_psum():
    from jax.experimental.shard_map import shard_map
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.train.optimizer import compressed_psum

    mesh = Mesh(np.array(jax.devices()[:1]), ("d",))
    x = jnp.linspace(-1, 1, 256)

    def f(x):
        return compressed_psum(x, "d")

    y = jax.jit(shard_map(f, mesh=mesh, in_specs=P(None),
                          out_specs=P(None)))(x)
    np.testing.assert_allclose(np.array(y), np.array(x), atol=1e-2)


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

def test_ckpt_roundtrip_and_keep(tmp_path):
    tree = {"a": jnp.arange(10, dtype=jnp.float32),
            "b": {"c": jnp.ones((3, 4), jnp.bfloat16)}}
    d = str(tmp_path)
    for s in (1, 2, 3, 4):
        ckpt.save(d, s, tree, extras={"cursor": {"step": s}}, keep=2)
    assert ckpt.committed_steps(d) == [3, 4]
    out, step, extras = ckpt.restore(d, tree)
    assert step == 4 and extras["cursor"]["step"] == 4
    np.testing.assert_array_equal(np.asarray(out["a"]), np.arange(10))


def test_ckpt_ignores_uncommitted(tmp_path):
    tree = {"a": jnp.arange(4)}
    d = str(tmp_path)
    ckpt.save(d, 1, tree)
    # simulate a crash mid-write: directory without .done marker
    os.makedirs(os.path.join(d, "step_000000099"))
    assert ckpt.latest_step(d) == 1


def test_ckpt_shape_mismatch_rejected(tmp_path):
    d = str(tmp_path)
    ckpt.save(d, 1, {"a": jnp.zeros((4,))})
    with pytest.raises(ValueError):
        ckpt.restore(d, {"a": jnp.zeros((5,))})


def test_elastic_remesh_restore(tmp_path):
    """Restore a checkpoint onto a different sharding layout."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    d = str(tmp_path)
    tree = {"w": jnp.arange(16, dtype=jnp.float32).reshape(4, 4)}
    ckpt.save(d, 5, tree)
    mesh = jax.make_mesh((1,), ("data",))
    sh = {"w": NamedSharding(mesh, P("data", None))}
    out, step, _ = ckpt.restore(d, tree, shardings=sh)
    assert out["w"].sharding == sh["w"]
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.asarray(tree["w"]))


def test_loop_restart_continues(tmp_path):
    model, opt, state, step, stream = small_setup()
    lc = LoopConfig(total_steps=6, ckpt_dir=str(tmp_path), ckpt_every=3,
                    log_every=100)
    state1, ls1 = run(lc, state=state, train_step=step, stream=stream,
                      log=lambda *a: None)
    assert ls1.step == 6
    # fresh state; loop must resume from step 6 and do nothing more
    lc2 = LoopConfig(total_steps=6, ckpt_dir=str(tmp_path), ckpt_every=3,
                     log_every=100)
    model2, opt2, state2, step2, stream2 = small_setup()
    state2b, ls2 = run(lc2, state=state2, train_step=step2, stream=stream2,
                       log=lambda *a: None)
    assert ls2.step == 6
    for a, b in zip(jax.tree.leaves(state1["params"]),
                    jax.tree.leaves(state2b["params"])):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_data_deterministic():
    dc = DataConfig(vocab=100, seq_len=64, global_batch=4, seed=3)
    a = TokenStream(dc).batch_at(7)
    b = TokenStream(dc).batch_at(7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])


def test_data_shards_partition_global_batch():
    dc = DataConfig(vocab=100, seq_len=32, global_batch=8, seed=1)
    full = TokenStream(dc).batch_at(3)["tokens"]
    parts = []
    for sid in range(4):
        dcs = DataConfig(vocab=100, seq_len=32, global_batch=8, seed=1,
                         n_shards=4, shard_id=sid)
        parts.append(TokenStream(dcs).batch_at(3)["tokens"])
    np.testing.assert_array_equal(np.concatenate(parts, 0), full)


def test_data_labels_shifted():
    dc = DataConfig(vocab=100, seq_len=32, global_batch=2)
    b = TokenStream(dc).batch_at(0)
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])
    assert (b["labels"][:, -1] == -1).all()


def test_data_resume_cursor():
    dc = DataConfig(vocab=100, seq_len=32, global_batch=2)
    s = TokenStream(dc)
    next(s)
    next(s)
    s2 = TokenStream.from_cursor(dc, s.cursor())
    np.testing.assert_array_equal(next(s)["tokens"], next(s2)["tokens"])
