"""Heterogeneous placement representation (paper §VI-A, Figs. 7-10).

The optimization algorithms do not operate on chiplet coordinates.  They
operate on the *(order, rotations)* pair that is fed to a deterministic
corner-placement algorithm; every such pair yields an overlap-free placement.

Isomorphism avoidance (Fig. 8):
* the order is a sequence of chiplet *types*, not IDs (two different orders
  by ID can produce the same placement; orders by type cannot);
* rotations are restricted per type to the non-isomorphic set computed from
  the chiplet geometry (rotation-invariant -> {0}, rotation-hybrid ->
  {0, 90}, rotation-sensitive -> all four).

Corner placement (Fig. 7): chiplets are placed one at a time.  Candidate
anchors are the L-corners formed by already-placed rectangles (bottom-left
corner-point set); the anchor minimizing the side of the minimum enclosing
*square* wins (step 3).  Overlap created by the greedy choice is resolved by
the paper's step-4 rule: overlap to the right pushes the chiplet up; overlap
above pushes it right.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .chiplets import COMPUTE, IO, MEMORY, ArchSpec, Chiplet
from .proxies import Layout
from .topology import (PlacedPhys, ScoreGraph, build_score_graph,
                       infer_links_mst)

Sol = tuple[np.ndarray, np.ndarray]  # (order [N] kinds int8, rots [N] int8)


def sol_key(sol: Sol) -> bytes:
    return sol[0].tobytes() + sol[1].tobytes()


def _overlap(x, y, w, h, rects) -> int:
    """Index of the first placed rect overlapping (x,y,w,h), or -1."""
    if len(rects) == 0:
        return -1
    rx, ry, rw, rh = rects[:, 0], rects[:, 1], rects[:, 2], rects[:, 3]
    ov = (x < rx + rw - 1e-9) & (rx < x + w - 1e-9) & \
         (y < ry + rh - 1e-9) & (ry < y + h - 1e-9)
    idx = np.nonzero(ov)[0]
    return int(idx[0]) if len(idx) else -1


def corner_place(dims: list[tuple[float, float]]
                 ) -> np.ndarray:
    """Place rectangles in order; returns [N, 2] lower-left positions.

    Deterministic; never produces overlaps.  See module docstring.
    """
    n = len(dims)
    out = np.zeros((n, 2), dtype=np.float64)
    rects = np.zeros((0, 4), dtype=np.float64)
    for i, (w, h) in enumerate(dims):
        if i == 0:
            out[i] = (0.0, 0.0)
            rects = np.array([[0.0, 0.0, w, h]])
            continue
        # Candidate anchors: right-of and top-of corners of placed rects.
        cands = [(0.0, 0.0)]
        for (rx, ry, rw, rh) in rects:
            cands.append((rx + rw, ry))
            cands.append((rx, ry + rh))
        best = None
        cur_w = float((rects[:, 0] + rects[:, 2]).max())
        cur_h = float((rects[:, 1] + rects[:, 3]).max())
        for (cx, cy) in cands:
            x, y = cx, cy
            moved_up_last = cx > 0 and any(
                abs(cx - (r[0] + r[2])) < 1e-9 for r in rects)
            ok = False
            for _ in range(4 * n):          # bounded resolution loop
                j = _overlap(x, y, w, h, rects)
                if j < 0:
                    ok = True
                    break
                rx, ry, rw, rh = rects[j]
                # Step 4: overlap on the right -> move to the top of the
                # blocking rect; overlap on top -> move right.
                if moved_up_last:
                    y = ry + rh
                else:
                    x = rx + rw
                moved_up_last = not moved_up_last
            if not ok:
                continue
            side = max(max(cur_w, x + w), max(cur_h, y + h))
            key = (side, x + y, y, x)
            if best is None or key < best[0]:
                best = (key, x, y)
        assert best is not None
        _, x, y = best
        out[i] = (x, y)
        rects = np.concatenate([rects, [[x, y, w, h]]])
    return out


@dataclass
class HeteroRep:
    """Placement representation + operators for heterogeneous chiplet shapes."""

    arch: ArchSpec
    mutation_mode: str = "any-one"

    def __post_init__(self):
        self._kind_instances = {
            k: [i for i, ch in enumerate(self.arch.chiplets) if ch.kind == k]
            for k in (COMPUTE, MEMORY, IO)
        }
        n = len(self.arch.chiplets)
        self._phy_base = np.zeros(n + 1, dtype=np.int64)
        for i, ch in enumerate(self.arch.chiplets):
            self._phy_base[i + 1] = self._phy_base[i] + ch.n_phys()
        # One prototype chiplet per kind (instances of a kind are identical).
        self._proto: dict[int, Chiplet] = {
            k: self.arch.chiplets[ids[0]]
            for k, ids in self._kind_instances.items() if ids
        }
        self._allowed_rot = {k: ch.allowed_rotations()
                             for k, ch in self._proto.items()}

    @property
    def layout(self) -> Layout:
        return Layout(Vp=int(self._phy_base[-1]), kinds=self.arch.kinds())

    @property
    def e_max(self) -> int:
        return 2 * int(self._phy_base[-1])

    # -- representation functions ------------------------------------------
    def random(self, rng: np.random.Generator) -> Sol:
        order = np.array([k for k, ids in self._kind_instances.items()
                          for _ in ids], dtype=np.int8)
        rng.shuffle(order)
        rots = np.array([rng.choice(self._allowed_rot[int(k)])
                         for k in order], dtype=np.int8)
        return order, rots

    def mutate(self, sol: Sol, rng: np.random.Generator) -> Sol:
        order = sol[0].copy()
        rots = sol[1].copy()
        both = self.mutation_mode.endswith("both")
        do_swap = both or bool(rng.integers(2))
        do_rot = both or not do_swap
        if do_swap:
            for _ in range(100):
                i, j = rng.integers(len(order), size=2)
                if order[i] != order[j]:
                    order[i], order[j] = order[j], order[i]
                    rots[i], rots[j] = rots[j], rots[i]
                    for p in (i, j):
                        if rots[p] not in self._allowed_rot[int(order[p])]:
                            rots[p] = rng.choice(
                                self._allowed_rot[int(order[p])])
                    break
        if do_rot:
            cand = [i for i in range(len(order))
                    if len(self._allowed_rot[int(order[i])]) > 1]
            if cand:
                i = cand[int(rng.integers(len(cand)))]
                rots[i] = rng.choice(self._allowed_rot[int(order[i])])
        return order, rots

    def merge(self, a: Sol, b: Sol, rng: np.random.Generator) -> Sol:
        """Fig. 10: carry over matching types/rotations, randomize the rest."""
        oa, ra = a
        ob, rb = b
        n = len(oa)
        order = np.full(n, -1, dtype=np.int8)
        match = oa == ob
        order[match] = oa[match]
        remaining = {k: len(ids) for k, ids in self._kind_instances.items()}
        for k in remaining:
            remaining[k] -= int((order == k).sum())
        fill = [k for k, cnt in remaining.items() for _ in range(cnt)]
        fill = np.array(fill, dtype=np.int8)
        rng.shuffle(fill)
        order[order == -1] = fill
        rots = np.zeros(n, dtype=np.int8)
        rmatch = match & (ra == rb)
        rots[rmatch] = ra[rmatch]
        for i in range(n):
            if not rmatch[i] or rots[i] not in self._allowed_rot[int(order[i])]:
                rots[i] = rng.choice(self._allowed_rot[int(order[i])])
        return order, rots

    # -- geometry / network --------------------------------------------------
    def place(self, sol: Sol) -> tuple[np.ndarray, list[Chiplet], np.ndarray]:
        """Run the corner-placement algorithm.

        Returns (positions [N,2] in *order* order, rotated chiplets, instance
        ids per order position).
        """
        order, rots = sol
        chips = [self._proto[int(k)].rotated(int(r))
                 for k, r in zip(order, rots)]
        pos = corner_place([(c.w, c.h) for c in chips])
        counters = {k: 0 for k in self._kind_instances}
        inst = np.zeros(len(order), dtype=np.int64)
        for p, k in enumerate(order):
            inst[p] = self._kind_instances[int(k)][counters[int(k)]]
            counters[int(k)] += 1
        return pos, chips, inst

    def geometry(self, sol: Sol) -> PlacedPhys:
        pos, chips, inst = self.place(sol)
        Vp = int(self._phy_base[-1])
        ppos = np.zeros((Vp, 2), dtype=np.float32)
        owner = np.zeros(Vp, dtype=np.int32)
        for i, ch in enumerate(self.arch.chiplets):
            owner[self._phy_base[i]:self._phy_base[i + 1]] = i
        for p, ch in enumerate(chips):
            i = int(inst[p])
            for li, (x, y) in enumerate(ch.phys):
                ppos[self._phy_base[i] + li] = (pos[p, 0] + x, pos[p, 1] + y)
        # get_area: minimal enclosing rectangle (§VI-A).
        xs = np.array([pos[p, 0] + chips[p].w for p in range(len(chips))])
        ys = np.array([pos[p, 1] + chips[p].h for p in range(len(chips))])
        area = float(xs.max() * ys.max())
        relay = np.array([ch.relay for ch in self.arch.chiplets])
        kinds = np.array(self.arch.kinds(), dtype=np.int8)
        return PlacedPhys(pos=ppos, owner=owner, relay=relay, kinds=kinds,
                          area=area)

    def score_graph(self, sol: Sol) -> ScoreGraph:
        geo = self.geometry(sol)
        links, connected = infer_links_mst(self.arch, geo)
        return build_score_graph(self.arch, geo, links, self.e_max, connected)

    def is_connected(self, sol: Sol) -> bool:
        geo = self.geometry(sol)
        _, connected = infer_links_mst(self.arch, geo)
        return connected
