"""Netrace-like dependency-driven traffic traces (paper §VII-A, Table VI).

The Netrace collection (PARSEC cache-coherency traces) is not
redistributable here; we generate traces with the *measured* statistics the
paper reports (§V-B): 0-5% C2C, 80-95% C2M, 3-16% M2I message mix, split
into five regions with per-region packet counts and injection rates shaped
like Table VI.  Dependencies follow cache-coherency transaction chains:

  L1 load miss : C->M  req(1 flit)  -> M->C  data(9)
  L2 miss      : C->M  req(1)       -> M->I  req(1) -> I->M data(9) -> M->C data(9)
  writeback    : C->M  data(9)      [-> M->I data(9) with p_wb_mem]
  coherence fwd: C->M  req(1)       -> M->C' ctrl(1) -> C'->C data(9)

Every chain is anchored at a trace cycle; *authentic* simulation injects at
max(cycle, deps-done), *idealized* at deps-done (paper §VII-C).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .chiplets import COMPUTE, IO, MEMORY
from .netsim import ChipletNet, Packet

CTRL_FLITS, DATA_FLITS = 1, 9  # paper §VII-A [15]


@dataclass(frozen=True)
class TraceRegion:
    n_packets: int
    n_cycles: int

    @property
    def injection_rate(self) -> float:
        return self.n_packets / max(self.n_cycles, 1)


# Region shape modeled on Table VI (scaled down; I = P/C kept in-range).
DEFAULT_REGIONS = (
    TraceRegion(1_890, 56_000),
    TraceRegion(12_000, 219_000 // 4),
    TraceRegion(24_000, 100_000),
    TraceRegion(1_950, 100_000),
    TraceRegion(1_290, 57_000),
)


@dataclass(frozen=True)
class TraceMix:
    """Transaction-type probabilities; defaults follow §V-B measurements."""

    p_l2_miss: float = 0.10       # of read transactions, go to memory/IO
    p_writeback: float = 0.15
    p_coherence: float = 0.03     # produces the small C2C share
    p_wb_mem: float = 0.30        # writebacks that propagate M->I

    def class_shares(self, flit_weighted: bool = True) -> dict:
        """Expected traffic share per cost-function class (closed form).

        Mirrors the emit structure of :func:`generate_trace` transaction by
        transaction, folding both directions of a chiplet pair into the
        proxy classes (``c2m`` includes M->C replies, ``m2i`` includes
        I->M data; the traces carry no direct C<->I traffic so ``c2i`` is
        0).  ``flit_weighted`` weighs packets by their flit count (ctrl 1 /
        data 9) — the load a trace actually puts on links — instead of
        counting messages.  Shares sum to 1; this is what
        ``objective.TrafficMix.from_trace_mix`` turns into cost weights.
        """
        wc, wd = (CTRL_FLITS, DATA_FLITS) if flit_weighted else (1, 1)
        p_coh, p_wb, p_l2 = self.p_coherence, self.p_writeback, self.p_l2_miss
        p_hit = 1.0 - p_coh - p_wb - p_l2
        n = {
            # coherence fwd: C->M req, M->C' ctrl (both class c2m), C'->C data
            "c2c": p_coh * wd,
            "c2m": (p_coh * (wc + wc)
                    + p_wb * wd                      # writeback C->M data
                    + p_l2 * (wc + wd)               # L2 miss C->M + M->C
                    + p_hit * (wc + wd)),            # read hit C->M + M->C
            "c2i": 0.0,
            # L2 miss M->I req + I->M data; writeback M->I with p_wb_mem
            "m2i": p_l2 * (wc + wd) + p_wb * self.p_wb_mem * wd,
        }
        tot = sum(n.values())
        return {k: v / tot for k, v in n.items()}


def generate_trace(net: ChipletNet, regions=DEFAULT_REGIONS,
                   mix: TraceMix = TraceMix(), seed: int = 0,
                   name: str = "synthetic_parsec_like") -> list[Packet]:
    """Generate a dependency-driven trace over the chiplets of ``net``."""
    rng = np.random.default_rng(seed)
    comp = np.nonzero(net.kinds == COMPUTE)[0]
    mem = np.nonzero(net.kinds == MEMORY)[0]
    io = np.nonzero(net.kinds == IO)[0]
    if len(mem) == 0 or len(comp) == 0:
        raise ValueError("trace needs compute and memory chiplets")
    packets: list[Packet] = []
    pid = 0

    def emit(src, dst, flits, cycle, deps=()) -> int:
        nonlocal pid
        packets.append(Packet(pid, int(src), int(dst), flits, int(cycle),
                              tuple(deps)))
        pid += 1
        return pid - 1

    t_base = 0
    for reg in regions:
        n_txn = 0
        # Each transaction emits >= 2 packets; budget by packet count.
        budget = reg.n_packets
        while budget > 0:
            c = rng.choice(comp)
            m = mem[int(rng.choice(len(mem)))]
            cyc = t_base + int(rng.integers(0, reg.n_cycles))
            u = rng.random()
            if u < mix.p_coherence and len(comp) > 1:
                c2 = rng.choice(comp[comp != c])
                a = emit(c, m, CTRL_FLITS, cyc)
                b = emit(m, c2, CTRL_FLITS, cyc, (a,))
                emit(c2, c, DATA_FLITS, cyc, (b,))
                budget -= 3
            elif u < mix.p_coherence + mix.p_writeback:
                a = emit(c, m, DATA_FLITS, cyc)
                budget -= 1
                if rng.random() < mix.p_wb_mem and len(io):
                    i = io[int(rng.choice(len(io)))]
                    emit(m, i, DATA_FLITS, cyc, (a,))
                    budget -= 1
            elif u < mix.p_coherence + mix.p_writeback + mix.p_l2_miss \
                    and len(io):
                i = io[int(rng.choice(len(io)))]
                a = emit(c, m, CTRL_FLITS, cyc)
                b = emit(m, i, CTRL_FLITS, cyc, (a,))
                d = emit(i, m, DATA_FLITS, cyc, (b,))
                emit(m, c, DATA_FLITS, cyc, (d,))
                budget -= 4
            else:
                a = emit(c, m, CTRL_FLITS, cyc)
                emit(m, c, DATA_FLITS, cyc, (a,))
                budget -= 2
            n_txn += 1
        t_base += reg.n_cycles
    return packets


def trace_stats(packets: list[Packet], net: ChipletNet) -> dict:
    """Message-mix shares — used to validate against §V-B measurements."""
    kinds = net.kinds
    n = {"c2c": 0, "c2m": 0, "m2c": 0, "m2i": 0, "i2m": 0, "other": 0}
    for p in packets:
        ks, kd = int(kinds[p.src]), int(kinds[p.dst])
        if ks == COMPUTE and kd == COMPUTE:
            n["c2c"] += 1
        elif ks == COMPUTE and kd == MEMORY:
            n["c2m"] += 1
        elif ks == MEMORY and kd == COMPUTE:
            n["m2c"] += 1
        elif ks == MEMORY and kd == IO:
            n["m2i"] += 1
        elif ks == IO and kd == MEMORY:
            n["i2m"] += 1
        else:
            n["other"] += 1
    tot = max(sum(n.values()), 1)
    return {k: v / tot for k, v in n.items()} | {"total": tot}
