"""Device-resident batched pipeline vs the host-loop reference.

Three layers of equivalence:

* **operators** — ``HomogBatch.random_batch/mutate_batch/merge_batch``
  sample the same distribution as the host operators (connectivity rate,
  cost distribution of random placements).  Per-operator *invariants*
  (chiplet counts, legal rotations, carried merge matches, PRNG
  determinism) live in the property-based layer, ``test_properties.py``,
  which sweeps randomized seeds instead of this module's former
  hand-picked spot checks;
* **graphs** — ``build_score_graphs_batched`` agrees *bit-for-bit* with
  the host ``score_graph`` path (W matrix, D2D edge set, area), and the
  scorer's FW-derived ``connected`` output agrees with the host
  union-find connectivity on the homog grid;
* **optimizers** — br/ga/sa-batched run through the registry API, improve
  over a single random placement, and return host-format solutions that
  the host path verifies as valid.

The heterogeneous section mirrors the layers for the corner-placement
representation: the batched Borůvka link inference (bit-for-bit vs the
fixed host MST path, including the component-derived ``connected``) and
the batched optimizers end-to-end on hetero32.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.api import Budget, ExperimentConfig, run_experiment
from repro.core.chiplets import paper_arch
from repro.core.optimize import DevicePipeline, Evaluator
from repro.core.placement_hetero import HeteroRep
from repro.core.placement_homog import HomogRep
from repro.core.proxies import make_scorer
from repro.core.topology import (HeteroGraphBatch, HomogGraphBatch,
                                 build_score_graphs_batched)

ARCH = paper_arch("homog32", "baseline")
R, C = 8, 5


@pytest.fixture(scope="module")
def rep():
    return HomogRep(ARCH, R=R, C=C)


@pytest.fixture(scope="module")
def ops(rep):
    return rep.batch_ops()


def test_random_batch_matches_host_distribution(rep, ops):
    """Connectivity rate and cost distribution of raw random placements
    agree between the host operator and the device operator (same
    distribution, different RNG streams)."""
    n = 96
    host_rng = np.random.default_rng(11)
    host = [rep.random(host_rng) for _ in range(n)]
    host_conn = np.array([rep.is_connected(s) for s in host])
    t, r = ops.random_batch(jax.random.PRNGKey(12), n)
    gb = HomogGraphBatch(ARCH, R, C)
    scorer = make_scorer(rep.layout, chunk=16)
    out = {k: np.asarray(v) for k, v in scorer(gb.build(t, r)).items()}
    dev_conn = out["connected"].astype(bool)
    p = host_conn.mean()
    # binomial 4-sigma band around the host estimate
    sigma = np.sqrt(max(p * (1 - p), 1e-4) / n)
    assert abs(dev_conn.mean() - p) < 4 * sigma + 2 / n
    # mean C2M latency over *connected* samples drawn from each stream
    host_out = {k: np.asarray(v) for k, v in scorer(
        gb.build(jnp.asarray(np.stack([s[0] for s in host])),
                 jnp.asarray(np.stack([s[1] for s in host])))).items()}
    if host_conn.any() and dev_conn.any():
        a = host_out["lat_c2m"][host_conn].mean()
        b = out["lat_c2m"][dev_conn].mean()
        assert b == pytest.approx(a, rel=0.25)


# ---------------------------------------------------------------------------
# Graphs: bit-for-bit against the host path.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("config", ["baseline", "placeit"])
def test_batched_graphs_bit_for_bit(config):
    arch = paper_arch("homog32", config)
    rep = HomogRep(arch, R=R, C=C)
    rng = np.random.default_rng(0)
    sols = [rep.random(rng) for _ in range(10)]
    host = [rep.score_graph(s) for s in sols]
    t = jnp.asarray(np.stack([s[0] for s in sols]))
    r = jnp.asarray(np.stack([s[1] for s in sols]))
    batch = build_score_graphs_batched(arch, R, C, t, r)
    W = np.asarray(batch["W"])
    E = np.asarray(batch["edges"])
    M = np.asarray(batch["edge_mask"])
    for i, g in enumerate(host):
        assert np.array_equal(W[i], g.W)           # byte-identical weights
        mine = {(int(u), int(v))
                for (u, v), m in zip(E[i], M[i]) if m}
        ref = {(int(u), int(v))
               for (u, v), m in zip(g.edges, g.edge_mask) if m}
        assert mine == ref
        assert float(batch["area"][i]) == float(g.area)
    # scorer-derived connectivity == host union-find connectivity
    scorer = make_scorer(rep.layout, chunk=4)
    out = {k: np.asarray(v) for k, v in scorer(batch).items()}
    assert np.array_equal(out["connected"].astype(bool),
                          np.array([g.connected for g in host]))
    # identical metrics whether graphs were assembled on host or device
    from repro.core.topology import stack_graphs
    ref_out = {k: np.asarray(v) for k, v in scorer(stack_graphs(host)).items()}
    for k in out:
        np.testing.assert_array_equal(out[k], ref_out[k])


# ---------------------------------------------------------------------------
# Batched optimizers through the registry API.
# ---------------------------------------------------------------------------

def test_batched_optimizers_improve_and_return_valid_solutions():
    cfg = ExperimentConfig(
        arch="homog32",
        algorithms=("br-batched", "ga-batched", "sa-batched"),
        budget=Budget(evals=24), norm_samples=8, chunk=8,
        params={"br-batched": {"batch": 8},
                "ga-batched": {"population": 8, "elitism": 2,
                               "tournament": 3},
                "sa-batched": {"chains": 4}})
    recs = run_experiment(cfg)
    rep = HomogRep(ARCH, R=R, C=C)
    for rec in recs:
        res = rec.result
        assert np.isfinite(res.best_cost)
        assert res.n_evaluated >= 8
        assert res.n_generated >= res.n_evaluated
        types, rot = res.best_sol
        assert types.dtype == np.int8 and types.shape == (R, C)
        g = rep.score_graph((types, rot))          # host-path validation
        assert g.connected
        assert res.history and res.history[-1][2] == res.best_cost


def test_device_pipeline_rejects_unknown_rep():
    with pytest.raises(TypeError, match="device_stage_key"):
        DevicePipeline._stages(object())


def test_pipeline_resampling_counts_generated(rep):
    """Mask-and-resample accounts resampled slots in n_generated, like the
    host retry loop counts retried individuals."""
    ev = Evaluator(rep, ARCH, rng=np.random.default_rng(0), norm_samples=8,
                   chunk=8)
    g0 = ev.n_generated
    pipe = ev.pipeline()
    t, r, metrics = pipe.sample_random(np.random.default_rng(1), 8)
    assert metrics["connected"].astype(bool).all()
    # baseline homog32 random placements are rarely connected: resampling
    # must have generated strictly more than the 8 returned
    assert ev.n_generated - g0 > 8


# ---------------------------------------------------------------------------
# Heterogeneous path: batched operators, Borůvka link inference, pipeline.
# ---------------------------------------------------------------------------

HARCH = paper_arch("hetero32", "baseline")
HN = 40


@pytest.fixture(scope="module")
def hrep():
    return HeteroRep(HARCH)


@pytest.fixture(scope="module")
def hops(hrep):
    return hrep.batch_ops()


def test_hetero_random_batch_matches_host_distribution(hrep, hops):
    """Connectivity rate of raw random placements agrees between the host
    operator (fixed corner placement + MST) and the device operator + the
    batched Borůvka (same distribution, different RNG streams)."""
    n = 64
    host_rng = np.random.default_rng(21)
    host_conn = np.array([hrep.is_connected(hrep.random(host_rng))
                          for _ in range(n)])
    o, r = hops.random_batch(jax.random.PRNGKey(22), n)
    ppos, area = hops.geometry_batch(np.asarray(o), np.asarray(r))
    gb = HeteroGraphBatch(HARCH)
    dev_conn = np.asarray(
        gb.build(jnp.asarray(ppos), jnp.asarray(area))["connected"])
    p = host_conn.mean()
    sigma = np.sqrt(max(p * (1 - p), 1e-4) / n)
    assert abs(dev_conn.mean() - p) < 4 * sigma + 2 / n


@pytest.mark.parametrize("config", ["baseline", "placeit"])
def test_hetero_batched_graphs_bit_for_bit(config):
    arch = paper_arch("hetero32", config)
    rep = HeteroRep(arch)
    ops = rep.batch_ops()
    gb = HeteroGraphBatch(arch)
    rng = np.random.default_rng(0)
    sols = [rep.random(rng) for _ in range(8)]
    host = [rep.score_graph(s) for s in sols]
    ppos, area = ops.geometry_batch(np.stack([s[0] for s in sols]),
                                    np.stack([s[1] for s in sols]))
    batch = {k: np.asarray(v)
             for k, v in gb.build(jnp.asarray(ppos),
                                  jnp.asarray(area)).items()}
    assert not batch.pop("overflow").any()
    for i, g in enumerate(host):
        assert np.array_equal(batch["W"][i], g.W)  # byte-identical weights
        mine = {(int(u), int(v))
                for (u, v), m in zip(batch["edges"][i],
                                     batch["edge_mask"][i]) if m}
        ref = {(int(u), int(v))
               for (u, v), m in zip(g.edges, g.edge_mask) if m}
        assert mine == ref
        assert float(batch["area"][i]) == float(g.area)
        # Borůvka-component connectivity == (fixed) host union-find rule
        assert bool(batch["connected"][i]) == g.connected
    # identical metrics whether graphs were assembled on host or device
    from repro.core.topology import stack_graphs
    batch.pop("connected")         # strip the extra key before scoring
    scorer = make_scorer(rep.layout, chunk=4)
    out = {k: np.asarray(v) for k, v in scorer(batch).items()}
    ref_out = {k: np.asarray(v)
               for k, v in scorer(stack_graphs(host)).items()}
    for k in out:
        np.testing.assert_array_equal(out[k], ref_out[k])


def test_hetero_batched_optimizers_improve_and_return_valid_solutions():
    cfg = ExperimentConfig(
        arch="hetero32",
        algorithms=("ga-batched", "sa-batched"),
        budget=Budget(evals=16), norm_samples=6, chunk=4,
        params={"ga-batched": {"population": 6, "elitism": 2,
                               "tournament": 3},
                "sa-batched": {"chains": 4}})
    recs = run_experiment(cfg)
    hrep = HeteroRep(HARCH)
    for rec in recs:
        res = rec.result
        assert np.isfinite(res.best_cost)
        assert res.n_evaluated >= 6
        assert res.n_generated >= res.n_evaluated
        order, rots = res.best_sol
        assert order.dtype == np.int8 and order.shape == (HN,)
        g = hrep.score_graph((order, rots))        # host-path validation
        assert g.connected
        assert res.history and res.history[-1][2] == res.best_cost


# ---------------------------------------------------------------------------
# Cross-config stacked scoring for the batched drivers + host SA (the
# remaining ROADMAP stacking item): every optimizer is a step generator
# now, so runs sharing a (layout, chunk, backend, objective) scorer fold
# into drive_stacked lockstep execution with fewer dispatches and
# bit-for-bit identical results.
# ---------------------------------------------------------------------------

def _stack_cfg(seed):
    from repro.core.api import SAParams
    return ExperimentConfig(
        arch="homog32", algorithms=("sa", "ga-batched", "sa-batched"),
        budget=Budget(evals=12), norm_samples=6, chunk=4, seed=seed,
        params={"sa": {"chains": 2},
                "ga-batched": {"population": 6, "elitism": 2,
                               "tournament": 3},
                "sa-batched": {"chains": 3}})


def test_sweep_stacks_sa_and_batched_drivers_bit_for_bit():
    from repro.core.api import run_sweep
    cfgs = [_stack_cfg(s) for s in (0, 1)]
    stacked = run_sweep(cfgs)
    unstacked = run_sweep(cfgs, stack_scoring=False)
    # one lockstep group covering all six runs (sa + both batched drivers
    # share the single jitted scorer), with strictly fewer dispatches
    assert stacked.stats.stacked_groups == 1
    assert stacked.stats.score_calls < unstacked.stats.score_calls
    for a, b in zip(stacked.records, unstacked.records):
        assert (a.algorithm, a.repetition) == (b.algorithm, b.repetition)
        assert a.result.best_cost == b.result.best_cost
        assert a.result.n_evaluated == b.result.n_evaluated
        assert a.result.n_generated == b.result.n_generated
        assert [(n, c) for _, n, c in a.result.history] \
            == [(n, c) for _, n, c in b.result.history]


def test_hetero_batched_drivers_stack_too():
    from repro.core.api import run_sweep
    cfgs = [ExperimentConfig(
        arch="hetero32", algorithms=("sa-batched",), budget=Budget(evals=8),
        norm_samples=4, chunk=4, seed=s,
        params={"sa-batched": {"chains": 4}}) for s in (0, 1)]
    stacked = run_sweep(cfgs)
    unstacked = run_sweep(cfgs, stack_scoring=False)
    assert stacked.stats.stacked_groups == 1
    for a, b in zip(stacked.records, unstacked.records):
        assert a.result.best_cost == b.result.best_cost
        assert a.result.n_generated == b.result.n_generated
