"""Assigned architecture config: grok-1-314b (see registry for source).

Exposes CONFIG (exact published hyper-parameters) and SMOKE (reduced copy
for CPU smoke tests).  Select with ``--arch grok-1-314b``.
"""
from .registry import get_config

CONFIG = get_config("grok-1-314b")
SMOKE = CONFIG.reduced()
