"""Device-resident Pareto sweeps over objective scalarizations.

PlaceIT's cost function is a scalarization of a fundamentally
multi-objective space — L1-to-L2 latency vs L2-to-memory latency vs
throughput vs area (paper §IV-B; RapidChiplet frames the same problem as
rapid design-space exploration).  The objective layer's runtime weight
vectors (``repro.core.objective.weights_vec``) make exploring that space
cheap: every scalarization of one term *structure* shares a single
compiled scorer, so a whole grid of weightings runs as one stacked sweep
(``drive_stacked`` lockstep, objective-keyed evaluator cache with shared
normalizer draws).

* :class:`ParetoGridSpec` — a serializable grid of scalarizations: a
  cartesian product of per-term weight axes and (optionally) a
  :class:`~repro.core.objective.TrafficMix` axis, expanded against a base
  :class:`~repro.core.objective.Objective`.
* :func:`nondominated_mask` — vectorized dominance on device: one jitted
  ``[B, B, n]`` comparison over the ``[B, n_objectives]`` cost matrix.
  :func:`nondominated_mask_host` is the brute-force host reference the
  device mask must match bit-for-bit (tested on all four paper archs).
* :func:`hypervolume` — exact dominated hypervolume vs a reference point
  (recursive dimension sweep on host for any n; a jitted sort-and-sweep
  device path for the n == 2 case).
* :class:`ParetoFront` / :class:`ParetoPoint` — typed, JSON
  round-trippable result records with per-point provenance: the grid
  label, expanded-config index, scalarization objective, algorithm /
  repetition, the placement itself, and the nine raw metrics.
* :func:`run_pareto_sweep` / :func:`run_pareto` — run one optimization
  population per grid point through ``api.run_sweep`` (stacked), re-score
  every run's best placement in a single stacked scorer call under the
  *base* objective, and compute the front over the per-term cost matrix.

The cost matrix columns are the base objective's weighted terms (float32,
straight from the device evaluation); dominance is invariant under the
positive per-column scaling the weights apply, so fronts are comparable
across weightings of the same structure.
"""
from __future__ import annotations

import dataclasses
import itertools
import json
import warnings
from dataclasses import dataclass, field
from typing import Mapping

import jax
import jax.numpy as jnp
import numpy as np

from .api import make_evaluator, make_rep, run_sweep
from .chiplets import resolve_arch
from .objective import (Objective, TrafficMix, compile_objective, norms_vec,
                        weights_vec)
from .topology import stack_graphs


# ---------------------------------------------------------------------------
# Grid specification.
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ParetoGridSpec:
    """A grid of objective scalarizations.

    ``term_weights`` maps objective term names to the weight values that
    term sweeps over; ``mixes`` is an optional axis of
    :class:`TrafficMix` values.  The grid is the cartesian product of all
    axes, expanded against a base objective with :meth:`points` — every
    expanded objective keeps the base term *structure*, so the whole grid
    shares one compiled scorer and stacks in ``run_sweep``.
    """

    term_weights: tuple = ()     # sorted ((term_name, (v, ...)), ...)
    mixes: tuple = ()            # optional TrafficMix axis

    def __post_init__(self):
        tw = self.term_weights
        items = tw.items() if isinstance(tw, Mapping) else tw
        object.__setattr__(self, "term_weights", tuple(sorted(
            (str(k), tuple(float(x) for x in v)) for k, v in items)))
        for name, vals in self.term_weights:
            if not vals:
                raise ValueError(f"empty weight axis for term {name!r}")
        object.__setattr__(self, "mixes", tuple(
            m if isinstance(m, TrafficMix) else TrafficMix.from_dict(m)
            for m in self.mixes))

    @property
    def n_points(self) -> int:
        n = 1
        for _, vals in self.term_weights:
            n *= len(vals)
        return n * max(1, len(self.mixes))

    def points(self, base: Objective) -> list[tuple[str, Objective]]:
        """Expand to ``(label, objective)`` pairs against ``base``."""
        names = [t.name for t in base.terms]
        for name, _ in self.term_weights:
            if name not in names:
                raise ValueError(
                    f"pareto grid sweeps unknown objective term {name!r}; "
                    f"objective has {names}")
        axes = [[(f"{name}={v:g}", name, v) for v in vals]
                for name, vals in self.term_weights]
        mix_axis = ([(f"mix={i}", None, m)
                     for i, m in enumerate(self.mixes)]
                    or [("", None, None)])
        out = []
        for combo in itertools.product(mix_axis, *axes):
            obj = base
            labels = []
            for lab, name, v in combo:
                if name is None:
                    if v is not None:       # TrafficMix axis
                        obj = dataclasses.replace(obj, mix=v)
                        labels.append(lab)
                    continue
                terms = tuple(dataclasses.replace(t, weight=v)
                              if t.name == name else t for t in obj.terms)
                obj = dataclasses.replace(obj, terms=terms)
                labels.append(lab)
            out.append(("|".join(labels) or "base", obj))
        return out

    def to_dict(self) -> dict:
        return {"term_weights": {k: list(v) for k, v in self.term_weights},
                "mixes": [m.to_dict() for m in self.mixes]}

    @classmethod
    def from_dict(cls, d: Mapping) -> "ParetoGridSpec":
        if isinstance(d, ParetoGridSpec):
            return d
        unknown = set(d) - {f.name for f in dataclasses.fields(cls)}
        if unknown:
            raise ValueError(
                f"unknown ParetoGridSpec keys: {sorted(unknown)}")
        return cls(**dict(d))

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=1)

    @classmethod
    def from_json(cls, s: str) -> "ParetoGridSpec":
        return cls.from_dict(json.loads(s))


# ---------------------------------------------------------------------------
# Dominance + hypervolume.
# ---------------------------------------------------------------------------

@jax.jit
def _nondom(Y):
    le = (Y[:, None, :] <= Y[None, :, :]).all(-1)
    lt = (Y[:, None, :] < Y[None, :, :]).any(-1)
    return ~(le & lt).any(axis=0)


def nondominated_mask(Y) -> np.ndarray:
    """Device dominance: ``mask[j]`` is True iff no row of the (lower is
    better) float32 cost matrix ``Y [B, n]`` dominates row ``j`` — one
    vectorized ``[B, B, n]`` comparison, jitted."""
    Y = jnp.asarray(np.asarray(Y, np.float32))
    return np.asarray(_nondom(Y))


def nondominated_mask_host(Y) -> np.ndarray:
    """Brute-force host reference for :func:`nondominated_mask` (same
    float32 matrix, same tie semantics: duplicates do not dominate each
    other)."""
    Y = np.asarray(Y, np.float32)
    B = Y.shape[0]
    mask = np.ones(B, bool)
    for j in range(B):
        for i in range(B):
            if (Y[i] <= Y[j]).all() and (Y[i] < Y[j]).any():
                mask[j] = False
                break
    return mask


@jax.jit
def _hv2d(P, ref):
    order = jnp.argsort(P[:, 0])
    p = P[order]

    def body(carry, row):
        best1, acc = carry
        acc = acc + (ref[0] - row[0]) * jnp.maximum(best1 - row[1], 0.0)
        return (jnp.minimum(best1, row[1]), acc), None

    (_, hv), _ = jax.lax.scan(body, (ref[1], jnp.zeros((), P.dtype)), p)
    return hv


@jax.jit
def _hv3d(P, ref):
    # Grid sweep over the x/y coordinate lattice: cell (i, j) spans
    # [xs[i], xs[i+1]) x [ys[j], ys[j+1]); its dominated depth is
    # ref_z - min z over points covering the cell's lower corner.
    xs = jnp.sort(P[:, 0])
    ys = jnp.sort(P[:, 1])
    dx = jnp.diff(jnp.append(xs, ref[0]))
    dy = jnp.diff(jnp.append(ys, ref[1]))
    cover = ((P[None, None, :, 0] <= xs[:, None, None])
             & (P[None, None, :, 1] <= ys[None, :, None]))
    z = jnp.min(jnp.where(cover, P[None, None, :, 2], ref[2]), axis=-1)
    return (dx[:, None] * dy[None, :] * (ref[2] - z)).sum()


def _hv_rec(pts: np.ndarray, ref: np.ndarray) -> float:
    """Exact hypervolume by recursive dimension sweep (host float64;
    fronts are small).  ``pts`` must be clipped to ``ref``."""
    if pts.shape[0] == 0:
        return 0.0
    if pts.shape[1] == 1:
        return float(ref[0] - pts[:, 0].min())
    order = np.argsort(pts[:, -1], kind="stable")
    pts = pts[order]
    zs = pts[:, -1]
    hv = 0.0
    for i in range(len(pts)):
        z_hi = zs[i + 1] if i + 1 < len(pts) else ref[-1]
        if z_hi > zs[i]:
            hv += (z_hi - zs[i]) * _hv_rec(pts[:i + 1, :-1], ref[:-1])
    return hv


def hypervolume(Y, ref, *, device: bool | None = None) -> float:
    """Dominated hypervolume of (lower is better) points ``Y [B, n]`` vs a
    reference point ``ref [n]`` (every coordinate worse than the front).

    Exact for any ``n``.  ``n == 2`` runs a jitted sort-and-sweep and
    ``n == 3`` a jitted coordinate-lattice sweep (O(B^3) elements — fronts
    are small) on device by default; pass ``device=False`` to force the
    host recursion, e.g. for testing.  ``n > 3`` always falls back to the
    host recursion (exponential in ``n``) and warns."""
    Y = np.asarray(Y, np.float64)
    ref = np.asarray(ref, np.float64)
    if Y.size == 0:
        return 0.0
    pts = np.minimum(Y, ref)             # clip: no negative contributions
    if device is None or device:
        if Y.shape[1] == 2:
            return float(_hv2d(jnp.asarray(pts), jnp.asarray(ref)))
        if Y.shape[1] == 3:
            return float(_hv3d(jnp.asarray(pts), jnp.asarray(ref)))
    if Y.shape[1] > 3:
        warnings.warn(
            f"hypervolume: no device path for n={Y.shape[1]} objectives; "
            "using the exact host recursion (cost grows exponentially "
            "with n)", stacklevel=2)
    return _hv_rec(pts, ref)


# ---------------------------------------------------------------------------
# Per-term cost matrix (the Pareto objective vectors), on device.
# ---------------------------------------------------------------------------

def term_matrix(metrics: dict, batch: dict, objective: Objective, norm,
                vp: int) -> np.ndarray:
    """``[B, n_terms]`` float32 weighted per-term costs for a scored,
    stacked batch — one jitted vmapped evaluation of the compiled
    objective's terms (the same device functions the scorer's in-jit
    ``cost`` sums)."""
    cobj = compile_objective(objective)
    row = jnp.asarray(norms_vec(norm))
    w = jnp.asarray(weights_vec(objective))
    sample = {k: jnp.asarray(np.asarray(v)) for k, v in metrics.items()
              if k not in ("cost", "connected", "overflow")}
    for k in ("edges", "edge_mask", "edge_len"):
        if k in batch:
            sample[k] = jnp.asarray(np.asarray(batch[k]))

    @jax.jit
    def mat(s):
        return jax.vmap(lambda si: jnp.stack(
            cobj.term_values(dict(si, Vp=vp), row, w)))(s)

    return np.asarray(mat(sample), np.float32)


# ---------------------------------------------------------------------------
# Typed result records.
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ParetoPoint:
    """One candidate with full provenance back to its config + placement.

    ``terms`` are the base-objective weighted per-term costs (the row of
    the front's cost matrix); ``cost`` is the scalar cost under the
    point's *own* scalarization (``objective``); ``placement`` serializes
    the winning solution (``types``/``rots`` — the homogeneous grid's
    [R, C] arrays or the heterogeneous (order, rotations) vectors).
    """

    label: str
    cfg_index: int
    algorithm: str
    repetition: int
    objective: Objective
    cost: float
    terms: tuple
    metrics: dict = field(default_factory=dict)
    placement: dict = field(default_factory=dict)

    def to_dict(self) -> dict:
        return {"label": self.label, "cfg_index": self.cfg_index,
                "algorithm": self.algorithm, "repetition": self.repetition,
                "objective": self.objective.to_dict(), "cost": self.cost,
                "terms": list(self.terms), "metrics": dict(self.metrics),
                "placement": dict(self.placement)}

    @classmethod
    def from_dict(cls, d: Mapping) -> "ParetoPoint":
        d = dict(d)
        d["objective"] = Objective.from_dict(d["objective"])
        d["terms"] = tuple(float(x) for x in d["terms"])
        return cls(**d)

    def sol(self):
        """The placement as the host representation's ``(a, b)`` arrays."""
        return (np.asarray(self.placement["types"], np.int8),
                np.asarray(self.placement["rots"], np.int8))


@dataclass(frozen=True)
class ParetoFront:
    """A non-dominated front over one base config's scalarization grid."""

    arch: str
    config: str
    term_names: tuple
    ref_point: tuple
    hypervolume: float
    points: tuple            # non-dominated ParetoPoints, by first term
    n_candidates: int
    matrix: tuple = ()       # full [B, n_terms] candidate cost matrix

    def to_dict(self) -> dict:
        return {"arch": self.arch, "config": self.config,
                "term_names": list(self.term_names),
                "ref_point": list(self.ref_point),
                "hypervolume": self.hypervolume,
                "points": [p.to_dict() for p in self.points],
                "n_candidates": self.n_candidates,
                "matrix": [list(r) for r in self.matrix]}

    @classmethod
    def from_dict(cls, d: Mapping) -> "ParetoFront":
        d = dict(d)
        d["term_names"] = tuple(d["term_names"])
        d["ref_point"] = tuple(float(x) for x in d["ref_point"])
        d["points"] = tuple(ParetoPoint.from_dict(p) for p in d["points"])
        d["matrix"] = tuple(tuple(float(x) for x in r)
                            for r in d.get("matrix", ()))
        return cls(**d)

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=1)

    @classmethod
    def from_json(cls, s: str) -> "ParetoFront":
        return cls.from_dict(json.loads(s))


# ---------------------------------------------------------------------------
# The sweep engine.
# ---------------------------------------------------------------------------

@dataclass
class FrontCandidate:
    """One placement proposed for a front, with provenance.

    ``sol`` is the representation's ``(a, b)`` solution pair;
    ``normalizers`` (optional) carries a run's normalizer draw so the
    front's evaluator reuses it instead of re-generating ``norm_samples``
    placements (first candidate that has one wins).
    """

    label: str
    cfg_index: int
    algorithm: str
    repetition: int
    objective: Objective
    cost: float
    sol: tuple
    normalizers: object | None = None


def candidates_from_records(entries) -> list[FrontCandidate]:
    """``(label, cfg_index, objective, RunRecord)`` tuples (the
    :func:`compute_front` input shape) -> best-placement candidates."""
    return [FrontCandidate(
        label=label, cfg_index=int(cfg_i), algorithm=rec.algorithm,
        repetition=rec.repetition, objective=obj,
        cost=float(rec.result.best_cost), sol=rec.result.best_sol,
        normalizers=rec.result.normalizers)
        for label, cfg_i, obj, rec in entries]


def archive_candidates(label: str, cfg_index: int, objective: Objective,
                       archive: Mapping, *, normalizers=None
                       ) -> list[FrontCandidate]:
    """Candidates from a :class:`repro.core.optimize.PopArchive` snapshot
    (``{"costs", "a", "b"}``) — every retained top-K row becomes one
    candidate tagged ``algorithm="archive"``, ``repetition=-1``."""
    costs = np.asarray(archive["costs"])
    return [FrontCandidate(
        label=f"{label}|archive", cfg_index=cfg_index,
        algorithm="archive", repetition=-1, objective=objective,
        cost=float(costs[i]),
        sol=(np.asarray(archive["a"][i]), np.asarray(archive["b"][i])),
        normalizers=normalizers)
        for i in range(costs.shape[0])]


class IncrementalFront:
    """A Pareto front that grows as candidates stream in.

    Each :meth:`add` re-scores only the *new* candidates (one stacked
    scorer call under the base objective), appends their rows to the
    running cost matrix, and recomputes the non-dominated mask over
    everything seen so far — the design service calls this per tick to
    stream partial fronts.  A single ``add`` of all candidates produces
    exactly :func:`compute_front`'s output (pinned by tests).
    """

    def __init__(self, base_cfg, *, ref_point=None):
        self.base_cfg = base_cfg
        self.ref_point = ref_point
        self._arch = resolve_arch(base_cfg.arch, base_cfg.config)
        self._rep = make_rep(self._arch, base_cfg.arch,
                             base_cfg.mutation_mode)
        self._ev = None                       # built on first add
        self._cands: list[FrontCandidate] = []
        self._rows: list[dict] = []           # per-candidate raw metrics
        self._Y: np.ndarray | None = None

    @property
    def n_candidates(self) -> int:
        return len(self._cands)

    def add(self, cands) -> ParetoFront:
        """Score ``cands`` (list of :class:`FrontCandidate`), fold them
        into the front, and return the updated :class:`ParetoFront`."""
        cands = list(cands)
        if not cands:
            return self.front()
        if self._ev is None:
            # Reuse a run's normalizer draw (carried on every OptResult)
            # so the matrix is normalized exactly like in-run costs — and
            # the (hetero-expensive) norm_samples draw is not paid twice.
            norm = next((c.normalizers for c in cands
                         if c.normalizers is not None), None)
            self._ev = make_evaluator(
                self._rep, self._arch,
                rng=np.random.default_rng(self.base_cfg.seed),
                norm_samples=self.base_cfg.norm_samples,
                chunk=self.base_cfg.chunk, backend=self.base_cfg.backend,
                objective=self.base_cfg.objective, norm=norm,
                workload=self.base_cfg.workload)
        graphs = [self._rep.score_graph(c.sol) for c in cands]
        batch = stack_graphs(graphs)
        metrics = self._ev.score_batch(batch)    # one stacked device call
        Y = term_matrix(metrics, batch, self.base_cfg.objective,
                        self._ev.norm, self._rep.layout.Vp)
        keys = [k for k in metrics if k not in ("cost", "connected")]
        self._rows.extend({k: float(metrics[k][i]) for k in keys}
                          for i in range(len(cands)))
        self._cands.extend(cands)
        self._Y = Y if self._Y is None else np.concatenate([self._Y, Y])
        return self.front()

    def front(self) -> ParetoFront:
        """The current front over everything added so far."""
        base_cfg = self.base_cfg
        term_names = tuple(t.name for t in base_cfg.objective.terms)
        if self._Y is None:
            return ParetoFront(
                arch=base_cfg.arch, config=base_cfg.config,
                term_names=term_names, ref_point=(), hypervolume=0.0,
                points=(), n_candidates=0)
        Y = self._Y
        mask = nondominated_mask(Y)
        if self.ref_point is None:
            span = Y.max(axis=0) - Y.min(axis=0)
            ref = Y.max(axis=0) + 0.05 * np.maximum(span, 1.0)
        else:
            ref = np.asarray(self.ref_point, np.float64)
        hv = hypervolume(Y[mask], ref)
        points = []
        for i in np.nonzero(mask)[0]:
            c = self._cands[int(i)]
            a, b = c.sol
            points.append(ParetoPoint(
                label=c.label, cfg_index=c.cfg_index,
                algorithm=c.algorithm, repetition=c.repetition,
                objective=c.objective, cost=c.cost,
                terms=tuple(float(x) for x in Y[i]),
                metrics=dict(self._rows[int(i)]),
                placement={"types": np.asarray(a).tolist(),
                           "rots": np.asarray(b).tolist()}))
        order = np.argsort([p.terms[0] for p in points], kind="stable")
        points = tuple(points[int(i)] for i in order)
        return ParetoFront(
            arch=base_cfg.arch, config=base_cfg.config,
            term_names=term_names, ref_point=tuple(float(x) for x in ref),
            hypervolume=float(hv), points=points,
            n_candidates=len(self._cands),
            matrix=tuple(tuple(float(x) for x in r) for r in Y))


def compute_front(base_cfg, entries, *, ref_point=None,
                  extra_candidates=()) -> ParetoFront:
    """Front over ``entries`` = ``(label, cfg_index, objective,
    RunRecord)`` tuples (``objective`` is the scalarization that produced
    the record), plus optional pre-built ``extra_candidates``
    (:class:`FrontCandidate`, e.g. population-archive rows).

    Re-scores every record's best placement in one stacked scorer call
    (device; base-config evaluator, shared scorer-cache entry), builds the
    ``[B, n_terms]`` cost matrix with :func:`term_matrix`, masks the
    non-dominated rows on device and reports the exact hypervolume vs
    ``ref_point`` (default: 5% beyond the per-term candidate maximum).
    """
    inc = IncrementalFront(base_cfg, ref_point=ref_point)
    return inc.add(candidates_from_records(entries)
                   + list(extra_candidates))


def run_pareto_sweep(base_configs, grid, *, fold_repetitions: bool = True,
                     stack_scoring: bool = True, shard: bool = False,
                     ref_point=None):
    """Expand every base config over ``grid``, run one stacked sweep, and
    attach a :class:`ParetoFront` per base config.

    Returns the underlying :class:`repro.core.api.SweepResult` (runs are
    the *expanded* configs, in base-config-major, grid-point-minor order)
    with ``fronts`` populated.  Because grid points share the base
    objective's term structure, the whole grid shares one jitted scorer
    and executes in ``drive_stacked`` lockstep — the per-row runtime
    weight vectors keep every scalarization's in-scorer costs exact.

    When configs carry ``archive_k`` > 0, each run's device-resident
    population archive (top-K of *every* evaluated placement) feeds extra
    front candidates (``algorithm="archive"``), thickening the front at
    no extra search cost.  ``shard`` forwards to :func:`run_sweep`.
    """
    grid = ParetoGridSpec.from_dict(grid) \
        if not isinstance(grid, ParetoGridSpec) else grid
    if not isinstance(base_configs, (list, tuple)):
        base_configs = (base_configs,)
    expanded, prov = [], []
    for b_i, cfg in enumerate(base_configs):
        for label, obj in grid.points(cfg.objective):
            prov.append((b_i, label, obj))
            expanded.append(dataclasses.replace(cfg, objective=obj))
    sweep = run_sweep(expanded, fold_repetitions=fold_repetitions,
                      stack_scoring=stack_scoring, shard=shard)
    fronts = []
    for b_i, cfg in enumerate(base_configs):
        entries, extras, seen = [], [], set()
        for i, run in enumerate(sweep.runs):
            if prov[i][0] != b_i:
                continue
            for rec in run.records:
                entries.append((prov[i][1], i, prov[i][2], rec))
            # The archive is per-evaluator (shared by a run's records);
            # the run's *last* snapshot is the cumulative archive.  Runs
            # sharing an evaluator would re-emit identical rows, so dedup
            # snapshots by content.
            snap = next((rec.result.archive for rec in
                         reversed(run.records)
                         if rec.result.archive is not None), None)
            if snap is not None:
                key = np.asarray(snap["costs"]).tobytes()
                if key not in seen:
                    seen.add(key)
                    norm = next((rec.result.normalizers
                                 for rec in run.records
                                 if rec.result.normalizers is not None),
                                None)
                    extras.extend(archive_candidates(
                        prov[i][1], i, prov[i][2], snap,
                        normalizers=norm))
        fronts.append(compute_front(cfg, entries, ref_point=ref_point,
                                    extra_candidates=extras))
    sweep.fronts = fronts
    return sweep


def run_pareto(base_cfg, grid, **kw) -> ParetoFront:
    """One base config, one grid -> its :class:`ParetoFront`."""
    return run_pareto_sweep(base_cfg, grid, **kw).fronts[0]
