"""Block-wise online-softmax (flash) attention Pallas kernel.

Used by the LM substrate for training and prefill.  Supports:
* causal masking (decoder LMs) and bidirectional (encoder),
* GQA: Hq query heads share Hq/Hkv KV heads (the kv BlockSpec index-map
  folds the group),
* sliding-window masking (recurrentgemma local attention),
* logit soft-capping (grok-style tanh cap),
* query/key position offset (Sq != Sk chunked prefill).

TPU mapping: the (bq, d) @ (bk, d)^T logits block hits the MXU; the online
max/sum rescale is VPU work; running (m, l, acc) live in VMEM scratch across
the sequential kv grid dimension.  Block sizes default to MXU-aligned
(128, 128).  CPU runs use interpret=True.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from . import _compat

NEG_INF = -1.0e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: int | None,
                  softcap: float | None, sq: int, sk: int,
                  bq: int, bk: int, pos_offset: int):
    iq, ik = pl.program_id(2), pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) \
        + pos_offset
    kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)

    # Block-level skip: entirely-masked tiles do no work.
    q_lo = iq * bq + pos_offset
    q_hi = q_lo + bq - 1
    k_lo = ik * bk
    run = k_lo < sk                               # padded kv tail
    if causal:
        run &= k_lo <= q_hi
    if window is not None:
        run &= (ik * bk + bk - 1) > (q_lo - window)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)        # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)        # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)        # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        mask = kpos < sk
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]                        # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = l_scr[...]
        o_ref[0, 0] = (acc_scr[...] / jnp.where(l == 0.0, 1.0, l)
                       ).astype(o_ref.dtype)


def flash_attention_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                           causal: bool = True, window: int | None = None,
                           scale: float | None = None,
                           softcap: float | None = None,
                           bq: int = 128, bk: int = 128,
                           interpret: bool = True) -> jnp.ndarray:
    """q: [B, Sq, Hq, d]; k, v: [B, Sk, Hkv, d] -> [B, Sq, Hq, d].

    Queries are end-aligned with keys (query i sits at position Sk-Sq+i).
    """
    B, Sq, Hq, d = q.shape
    _, Sk, Hkv, _ = k.shape
    assert Hq % Hkv == 0, (Hq, Hkv)
    g = Hq // Hkv
    scale = (d ** -0.5) if scale is None else scale
    # Head-major layout for blocking.
    qt = jnp.swapaxes(q, 1, 2)                     # [B, Hq, Sq, d]
    kt = jnp.swapaxes(k, 1, 2)                     # [B, Hkv, Sk, d]
    vt = jnp.swapaxes(v, 1, 2)
    bq_ = min(bq, max(8, Sq))
    bk_ = min(bk, max(8, Sk))
    Sqp, Skp = -(-Sq // bq_) * bq_, -(-Sk // bk_) * bk_
    if Sqp != Sq:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, Sqp - Sq), (0, 0)))
    if Skp != Sk:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, Skp - Sk), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, Skp - Sk), (0, 0)))
    kern = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, sq=Sq, sk=Sk, bq=bq_, bk=bk_,
        pos_offset=Sk - Sq)
    out = pl.pallas_call(
        kern,
        grid=(B, Hq, Sqp // bq_, Skp // bk_),
        in_specs=[
            pl.BlockSpec((1, 1, bq_, d), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bk_, d),
                         lambda b, h, iq, ik, g=g: (b, h // g, ik, 0)),
            pl.BlockSpec((1, 1, bk_, d),
                         lambda b, h, iq, ik, g=g: (b, h // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq_, d),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sqp, d), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq_, 1), jnp.float32),
                        pltpu.VMEM((bq_, 1), jnp.float32),
                        pltpu.VMEM((bq_, d), jnp.float32)],
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(qt, kt, vt)
    return jnp.swapaxes(out[:, :, :Sq], 1, 2)
