"""Optimization algorithms (paper §II-B) over placement representations.

Best Random (BR), Genetic Algorithm (GA) and Simulated Annealing (SA), all
driven through the four representation functions random_placement / mutate /
merge / get_cost (§IV).  Invalid placements (unconnected chiplets) cause the
generating operation to be repeated, exactly as in §V-A / §VI-A.

Beyond-paper adaptation (DESIGN.md §3): cost evaluation is *batched* — a GA
generation or a block of SA chains is scored in a single vmapped JAX call —
which is what makes the method TPU-friendly.  The faithful sequential
semantics are preserved: BR/GA evaluate the same individuals they would
sequentially; "SA x K chains" runs K independent faithful chains.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

from .cost import CostNormalizers, total_cost
from .proxies import make_scorer
from .topology import ScoreGraph, stack_graphs


@dataclass
class OptResult:
    best_sol: object
    best_cost: float
    best_metrics: dict
    # (wall_seconds, n_evaluated, best_cost_so_far) samples
    history: list = field(default_factory=list)
    n_generated: int = 0          # placements generated incl. retries
    n_evaluated: int = 0          # placements actually scored
    normalizers: CostNormalizers | None = None


class Evaluator:
    """rep + scorer + cost normalizers -> batched get_cost()."""

    def __init__(self, rep, arch, *, rng: np.random.Generator,
                 norm_samples: int = 500, chunk: int = 16, fw_impl=None,
                 scorer=None):
        self.rep = rep
        self.arch = arch
        if scorer is not None:
            # Pre-built (usually cached) jitted scorer — see api.get_scorer.
            self.scorer = scorer
        else:
            kw = {"chunk": chunk}
            if fw_impl is not None:
                kw["fw_impl"] = fw_impl
            self.scorer = make_scorer(rep.layout, **kw)
        self.n_generated = 0
        sols, graphs = self.generate_valid(
            lambda r: self.rep.random(r), rng, norm_samples)
        metrics = self.score(graphs)
        self.norm = CostNormalizers.from_samples(metrics)

    # -- generation with the paper's retry-until-connected semantics -------
    def generate_valid(self, op, rng: np.random.Generator, n: int,
                       max_tries: int = 500):
        sols, graphs = [], []
        while len(sols) < n:
            for _ in range(max_tries):
                s = op(rng)
                self.n_generated += 1
                g = self.rep.score_graph(s)
                if g.connected:
                    sols.append(s)
                    graphs.append(g)
                    break
            else:  # pragma: no cover - pathological architecture
                raise RuntimeError("could not generate a connected placement")
        return sols, graphs

    def score(self, graphs: list[ScoreGraph]) -> dict:
        batch = stack_graphs(graphs)
        return {k: np.asarray(v) for k, v in self.scorer(batch).items()}

    def costs(self, graphs: list[ScoreGraph]) -> tuple[np.ndarray, dict]:
        metrics = self.score(graphs)
        return np.asarray(total_cost(metrics, self.arch, self.norm)), metrics


def _metrics_row(metrics: dict, i: int) -> dict:
    return {k: float(v[i]) for k, v in metrics.items()}


# ---------------------------------------------------------------------------
# Best Random (§II-B1).
# ---------------------------------------------------------------------------

def best_random(ev: Evaluator, rng: np.random.Generator, *,
                time_budget_s: float | None = None,
                max_evals: int | None = None,
                batch: int = 32) -> OptResult:
    res = OptResult(None, np.inf, {})
    t0 = time.monotonic()
    while True:
        if time_budget_s is not None and time.monotonic() - t0 > time_budget_s:
            break
        if max_evals is not None and res.n_evaluated >= max_evals:
            break
        sols, graphs = ev.generate_valid(ev.rep.random, rng, batch)
        costs, metrics = ev.costs(graphs)
        res.n_evaluated += len(sols)
        i = int(np.argmin(costs))
        if costs[i] < res.best_cost:
            res.best_cost = float(costs[i])
            res.best_sol = sols[i]
            res.best_metrics = _metrics_row(metrics, i)
        res.history.append((time.monotonic() - t0, res.n_evaluated,
                            res.best_cost))
    res.n_generated = ev.n_generated
    res.normalizers = ev.norm
    return res


# ---------------------------------------------------------------------------
# Genetic Algorithm (§II-B2; parameters Table III/IV).
# ---------------------------------------------------------------------------

def genetic_algorithm(ev: Evaluator, rng: np.random.Generator, *,
                      population: int, elitism: int, tournament: int,
                      p_mutation: float = 0.5,
                      time_budget_s: float | None = None,
                      max_generations: int | None = None) -> OptResult:
    res = OptResult(None, np.inf, {})
    t0 = time.monotonic()
    sols, graphs = ev.generate_valid(ev.rep.random, rng, population)
    gen = 0
    while True:
        costs, metrics = ev.costs(graphs)
        res.n_evaluated += len(sols)
        order = np.argsort(costs)
        if costs[order[0]] < res.best_cost:
            res.best_cost = float(costs[order[0]])
            res.best_sol = sols[order[0]]
            res.best_metrics = _metrics_row(metrics, int(order[0]))
        res.history.append((time.monotonic() - t0, res.n_evaluated,
                            res.best_cost))
        gen += 1
        if time_budget_s is not None and time.monotonic() - t0 > time_budget_s:
            break
        if max_generations is not None and gen >= max_generations:
            break

        def tournament_pick() -> int:
            idx = rng.choice(len(sols), size=min(tournament, len(sols)),
                             replace=False)
            return int(idx[np.argmin(costs[idx])])

        elite_idx = order[:elitism]
        new_sols = [sols[i] for i in elite_idx]
        new_graphs = [graphs[i] for i in elite_idx]
        while len(new_sols) < population:
            pa, pb = sols[tournament_pick()], sols[tournament_pick()]

            def op(r, pa=pa, pb=pb):
                child = ev.rep.merge(pa, pb, r)
                if r.random() < p_mutation:
                    child = ev.rep.mutate(child, r)
                return child

            cs, cg = ev.generate_valid(op, rng, 1)
            new_sols += cs
            new_graphs += cg
        sols, graphs = new_sols, new_graphs
    res.n_generated = ev.n_generated
    res.normalizers = ev.norm
    return res


# ---------------------------------------------------------------------------
# Simulated Annealing (§II-B3; adaptive cooling, DESIGN.md §3).
#
# Cooling: after each block of L iterations at temperature T,
#     T <- alpha * T / (1 + beta * T / sigma_block)
# with sigma_block the std-dev of costs seen in the block (Aarts & van
# Laarhoven).  Table III/IV's (T0, L, alpha=1, beta) plug in directly.
# ``chains`` > 1 runs that many independent chains, evaluated as one batch
# per step (beyond-paper batching; chains never interact).
# ---------------------------------------------------------------------------

def simulated_annealing(ev: Evaluator, rng: np.random.Generator, *,
                        t0_temp: float, block_len: int,
                        alpha: float = 1.0, beta: float = 5.0,
                        chains: int = 1,
                        time_budget_s: float | None = None,
                        max_iters: int | None = None) -> OptResult:
    res = OptResult(None, np.inf, {})
    tstart = time.monotonic()
    sols, graphs = ev.generate_valid(ev.rep.random, rng, chains)
    costs, metrics = ev.costs(graphs)
    res.n_evaluated += chains
    temps = np.full(chains, float(t0_temp))
    block_costs: list[np.ndarray] = []
    i = int(np.argmin(costs))
    res.best_cost = float(costs[i])
    res.best_sol = sols[i]
    res.best_metrics = _metrics_row(metrics, i)
    it = 0
    while True:
        if time_budget_s is not None and \
                time.monotonic() - tstart > time_budget_s:
            break
        if max_iters is not None and it >= max_iters:
            break
        nb_sols, nb_graphs = [], []
        for c in range(chains):
            s, g = ev.generate_valid(
                lambda r, c=c: ev.rep.mutate(sols[c], r), rng, 1)
            nb_sols += s
            nb_graphs += g
        nb_costs, nb_metrics = ev.costs(nb_graphs)
        res.n_evaluated += chains
        delta = nb_costs - costs
        accept = (delta < 0) | (rng.random(chains)
                                < np.exp(-np.maximum(delta, 0)
                                         / np.maximum(temps, 1e-9)))
        for c in range(chains):
            if accept[c]:
                sols[c], graphs[c], costs[c] = \
                    nb_sols[c], nb_graphs[c], nb_costs[c]
        block_costs.append(nb_costs.copy())
        i = int(np.argmin(nb_costs))
        if nb_costs[i] < res.best_cost:
            res.best_cost = float(nb_costs[i])
            res.best_sol = nb_sols[i]
            res.best_metrics = _metrics_row(nb_metrics, i)
        it += 1
        if it % block_len == 0:
            blk = np.stack(block_costs)            # [L, chains]
            sigma = np.maximum(blk.std(axis=0), 1e-6)
            temps = alpha * temps / (1.0 + beta * temps / sigma)
            block_costs = []
        res.history.append((time.monotonic() - tstart, res.n_evaluated,
                            res.best_cost))
    res.n_generated = ev.n_generated
    res.normalizers = ev.norm
    return res


ALGORITHMS = {
    "br": best_random,
    "ga": genetic_algorithm,
    "sa": simulated_annealing,
}
