"""Registry-driven experiment API: serialization round-trips, registry
dispatch equivalence with the legacy runner loop, error paths, and
sweep-level scorer sharing / cross-config stacked scoring."""
import dataclasses
import importlib
import zlib

import numpy as np
import pytest

from repro.core import api
from repro.core.api import (Budget, ExperimentConfig, GAParams, SAParams,
                            algo_seed, clear_scorer_cache,
                            run_experiment, run_sweep, scorer_cache_stats)
from repro.core.chiplets import paper_arch
from repro.core.optimize import (Evaluator, best_random, genetic_algorithm,
                                 simulated_annealing)
from repro.core.placement_homog import HomogRep
from repro.core.registries import (OPTIMIZERS, SCORER_BACKENDS, Registry,
                                   register_optimizer, resolve_backend)

ARCH = "homog32"


def fast_cfg(**kw):
    base = dict(arch=ARCH, algorithms=("br",), budget=Budget(evals=8),
                norm_samples=8, chunk=4)
    base.update(kw)
    return ExperimentConfig(**base)


# ---------------------------------------------------------------------------
# Config serialization.
# ---------------------------------------------------------------------------

def test_config_roundtrip_dict_json():
    cfg = ExperimentConfig(
        arch="hetero32", config="placeit", algorithms=("sa", "ga"),
        repetitions=3, budget=Budget(evals=100, seconds=12.5),
        norm_samples=16, seed=7, backend="fw-pallas", chunk=8,
        params={"sa": {"chains": 4}, "ga": GAParams(population=10,
                                                    elitism=2,
                                                    tournament=2)})
    assert ExperimentConfig.from_dict(cfg.to_dict()) == cfg
    assert ExperimentConfig.from_json(cfg.to_json()) == cfg
    # params are normalized to typed dataclasses with paper defaults filled
    sa = cfg.resolved_params("sa")
    assert isinstance(sa, SAParams)
    assert sa.chains == 4
    assert sa.t0_temp == 33.0          # hetero32 paper default retained


def test_config_params_fall_back_to_paper_defaults():
    cfg = ExperimentConfig(arch="homog32")
    ga = cfg.resolved_params("ga")
    assert (ga.population, ga.elitism, ga.tournament) == (200, 30, 30)
    assert cfg.resolved_params("sa").block_len == 250


def test_budget_validation_and_scaling():
    with pytest.raises(ValueError):
        Budget(evals=None, seconds=None)
    assert Budget(evals=10).scaled(3).evals == 30
    assert Budget(seconds=5.0, evals=None).scaled(3).seconds == 5.0
    # default eval cap applies only when no wall budget is given
    assert Budget().evals == 300
    assert Budget(seconds=3600.0).evals is None
    assert Budget.from_dict({"seconds": 3600.0}) == Budget(seconds=3600.0)


def test_config_is_hashable_consistently_with_eq():
    a = ExperimentConfig(arch=ARCH, params={"sa": {"chains": 2}})
    b = ExperimentConfig.from_dict(a.to_dict())
    assert a == b and hash(a) == hash(b)
    assert len({a, b}) == 1


# ---------------------------------------------------------------------------
# Error paths.
# ---------------------------------------------------------------------------

def test_unknown_names_raise():
    with pytest.raises(KeyError, match="unknown optimizer 'nope'"):
        run_experiment(fast_cfg(algorithms=("nope",)))
    with pytest.raises(KeyError, match="unknown optimizer"):
        ExperimentConfig(arch=ARCH, params={"nope": {"x": 1}})
    with pytest.raises(KeyError, match="unknown scorer backend"):
        resolve_backend("no-such-backend")
    with pytest.raises(ValueError, match="unknown ExperimentConfig keys"):
        ExperimentConfig.from_dict({"arch": ARCH, "bogus": 1})
    with pytest.raises(TypeError):
        ExperimentConfig(arch=ARCH, params={"sa": {"not_a_field": 1}})


def test_registry_basics():
    r = Registry("thing")
    r.add("a", 1)
    with pytest.raises(ValueError, match="duplicate thing 'a'"):
        r.add("a", 2)
    assert "a" in r and r.get("a") == 1
    assert set(OPTIMIZERS.names()) >= {"br", "ga", "sa"}
    assert set(SCORER_BACKENDS.names()) >= {"fw-ref", "fw-pallas"}


def test_custom_optimizer_is_drop_in():
    if "first-valid" not in OPTIMIZERS:
        @dataclasses.dataclass(frozen=True)
        class FVParams:
            n: int = 2

        @register_optimizer("first-valid", params_cls=FVParams)
        def _first_valid(ev, rng, budget, params):
            sols, graphs = ev.generate_valid(ev.rep.random, rng, params.n)
            costs, metrics = ev.costs(graphs)
            i = int(np.argmin(costs))
            from repro.core.optimize import OptResult
            res = OptResult(sols[i], float(costs[i]),
                            {k: float(v[i]) for k, v in metrics.items()})
            res.n_evaluated = params.n
            return res

    recs = run_experiment(fast_cfg(algorithms=("first-valid",)))
    assert recs[0].algorithm == "first-valid"
    assert np.isfinite(recs[0].result.best_cost)
    assert recs[0].result.n_evaluated == 2


# ---------------------------------------------------------------------------
# Dispatch equivalence: run_experiment == the legacy Experiment.run loop.
# ---------------------------------------------------------------------------

def _costs(history):
    return [(n, c) for _, n, c in history]


def test_run_experiment_matches_legacy_loop_bit_for_bit():
    seed, evals, reps = 3, 24, 2
    cfg = ExperimentConfig(
        arch=ARCH, algorithms=("br", "ga", "sa"), repetitions=reps,
        budget=Budget(evals=evals), norm_samples=8, seed=seed,
        params={"ga": {"population": 8, "elitism": 2, "tournament": 3},
                "sa": {"chains": 2}})
    recs = run_experiment(cfg)

    # The legacy Experiment.run body, written out by hand.
    arch = paper_arch(ARCH, "baseline")
    legacy = []
    for rep_i in range(reps):
        rng = np.random.default_rng(seed + 1000 * rep_i)
        rep = HomogRep(arch, R=8, C=5, mutation_mode="neighbor-one")
        ev = Evaluator(rep, arch, rng=rng, norm_samples=8)
        for algo in ("br", "ga", "sa"):
            rng_a = np.random.default_rng(
                seed + 1000 * rep_i + zlib.crc32(algo.encode()) % 997)
            if algo == "br":
                res = best_random(ev, rng_a, max_evals=evals)
            elif algo == "ga":
                res = genetic_algorithm(ev, rng_a, population=8, elitism=2,
                                        tournament=3,
                                        max_generations=evals // 8)
            else:
                res = simulated_annealing(ev, rng_a, t0_temp=40.0,
                                          block_len=250, chains=2,
                                          max_iters=evals // 2)
            legacy.append(res)

    assert len(recs) == len(legacy) == reps * 3
    for got, want in zip(recs, legacy):
        assert got.result.best_cost == want.best_cost
        assert got.result.n_evaluated == want.n_evaluated
        assert _costs(got.result.history) == _costs(want.history)


def test_legacy_experiment_shim_is_gone():
    # The deprecated repro.core.runner.Experiment wrapper was removed
    # (ROADMAP item); the module must not silently reappear.
    with pytest.raises(ModuleNotFoundError):
        importlib.import_module("repro.core.runner")


def test_algo_seed_is_processes_stable():
    # frozen values: any change here breaks cross-process reproducibility
    assert algo_seed(0, 0, "br") == zlib.crc32(b"br") % 997
    assert algo_seed(3, 2, "sa") == 3 + 2000 + zlib.crc32(b"sa") % 997


# ---------------------------------------------------------------------------
# Backends.
# ---------------------------------------------------------------------------

def test_named_backends_agree():
    ref = run_experiment(fast_cfg(backend="fw-ref"))
    pal = run_experiment(fast_cfg(backend="fw-pallas"))
    assert ref[0].result.best_cost == pytest.approx(
        pal[0].result.best_cost, rel=1e-5)


# ---------------------------------------------------------------------------
# Sweeps: one jitted scorer across configs.
# ---------------------------------------------------------------------------

def test_sweep_reuses_single_jitted_scorer():
    clear_scorer_cache()
    cfgs = [fast_cfg(seed=s, budget=Budget(evals=6)) for s in (0, 1, 2)]
    res = run_sweep(cfgs)
    stats = scorer_cache_stats()
    # one compilation for three configs; the rest are cache hits
    assert res.stats.scorers_built == 1
    assert stats["misses"] == 1 and stats["hits"] >= 2
    assert len(res.runs) == 3 and res.stats.n_evaluated > 0
    # per-config results match standalone runs (repetitions == 1)
    for cfg, run in zip(cfgs, res.runs):
        solo = run_experiment(cfg)
        assert [r.result.best_cost for r in run.records] \
            == [r.result.best_cost for r in solo]


def test_sweep_folds_sa_repetitions_into_chains():
    cfg = fast_cfg(algorithms=("sa",), repetitions=3,
                   budget=Budget(evals=6), params={"sa": {"chains": 2}})
    res = run_sweep([cfg])
    (rec,) = res.records
    assert rec.repetition == -1           # folded batch record
    # 3 reps x 2 chains -> 6 chains, same per-chain iteration count:
    # initial batch (6) + (6*3 evals // 6 chains) iterations * 6 chains
    assert rec.result.n_evaluated == 6 + (6 * 3 // 6) * 6
    unfolded = run_sweep([cfg], fold_repetitions=False)
    assert len(unfolded.records) == 3
    assert {r.repetition for r in unfolded.records} == {0, 1, 2}
    # shared evaluator, but n_generated is a per-run delta, not cumulative
    for r in unfolded.records:
        assert 0 < r.result.n_generated < unfolded.records[0].result.n_generated * 3


def test_sweep_never_folds_wall_clock_budgets():
    cfg = fast_cfg(algorithms=("sa",), repetitions=2,
                   budget=Budget(evals=4, seconds=60.0))
    res = run_sweep([cfg])
    # a seconds budget covers one sequential run; folding would shrink it
    assert {r.repetition for r in res.records} == {0, 1}


def test_sweep_stacks_scoring_across_configs_bit_for_bit():
    """BR/GA runs sharing a jitted scorer execute in lockstep with stacked
    scoring calls; results are bit-for-bit those of unstacked execution."""
    cfgs = [fast_cfg(seed=s, algorithms=("br", "ga"), budget=Budget(evals=16),
                     params={"ga": {"population": 8, "elitism": 2,
                                    "tournament": 3}})
            for s in (0, 1)]
    stacked = run_sweep(cfgs)
    unstacked = run_sweep(cfgs, stack_scoring=False)
    assert stacked.stats.stacked_groups == 1
    assert stacked.stats.score_calls < unstacked.stats.score_calls
    for a, b in zip(stacked.records, unstacked.records):
        assert (a.algorithm, a.repetition) == (b.algorithm, b.repetition)
        assert a.result.best_cost == b.result.best_cost
        assert a.result.n_evaluated == b.result.n_evaluated
        assert a.result.n_generated == b.result.n_generated
        assert [(n, c) for _, n, c in a.result.history] \
            == [(n, c) for _, n, c in b.result.history]


def test_sweep_never_stacks_wall_clock_budgets():
    # interleaving would consume each run's time budget with the whole
    # group's work, like repetition folding would shrink it
    cfgs = [fast_cfg(seed=s, budget=Budget(evals=4, seconds=60.0))
            for s in (0, 1)]
    res = run_sweep(cfgs)
    assert res.stats.stacked_groups == 0


def test_batched_optimizers_registered_with_paper_defaults():
    assert set(OPTIMIZERS.names()) >= {"br-batched", "ga-batched",
                                       "sa-batched"}
    cfg = ExperimentConfig(arch="homog32")
    # "-batched" variants inherit their host-loop counterpart's Table
    # III/IV hyper-parameters.
    assert cfg.resolved_params("ga-batched") == cfg.resolved_params("ga")
    assert cfg.resolved_params("sa-batched") == cfg.resolved_params("sa")
