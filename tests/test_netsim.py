"""Regression + calibration tests for the layered netsim package.

Host oracle (``repro.netsim.sim``, still importable as ``core.netsim``):

* ``synthetic_packets`` — per-traffic-class rate accounting: sources and
  destinations drawn from the right chiplet kinds, no self-pairs,
  Bernoulli injection count tracking ``rate * n_cycles`` per source,
  rate clipping at 1 packet/cycle, seeded determinism.
* ``latency_throughput_curve`` — zero-load latency matching the routed
  hop latency, saturation monotonicity (average latency does not
  collapse as the injection rate grows, and diverges well past the
  bottleneck-link saturation point), per-rate independent seeding.
* ``NetSim.run`` never mutates its input packets; per-packet times are
  reported out of band in ``SimResult.times``.

Device rate model (``repro.netsim.model``):

* zero-load ``trace_lat`` equals the host's routed-hop formula,
* latency saturates monotonically with the injection rate,
* rank correlation against the host oracle across random placements is
  >= 0.9 per traffic class on all four paper archs (calibration).
"""
import dataclasses
from collections import Counter

import numpy as np
import pytest

from repro.core.api import make_rep
from repro.core.baseline import MeshBaseline
from repro.core.chiplets import COMPUTE, IO, MEMORY, paper_arch
from repro.core.netsim import (ROUTER_PIPELINE, ChipletNet, NetSim, Packet,
                               latency_throughput_curve, synthetic_packets)
from repro.core.topology import infer_links_mst, stack_graphs
from repro.netsim import Workload, demand_dim, make_trace_model

KIND_OF = {"c": COMPUTE, "m": MEMORY, "i": IO}


@pytest.fixture(scope="module")
def net():
    arch = paper_arch("homog32", "baseline")
    _, geo, links = MeshBaseline(arch).build()
    return arch, ChipletNet.from_links(arch, geo, links)


# ---------------------------------------------------------------------------
# synthetic_packets: per-class rate accounting.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("traffic", ["c2c", "c2m", "c2i", "m2i"])
def test_synthetic_packets_class_accounting(net, traffic):
    arch, cn = net
    ks, kd = KIND_OF[traffic[0]], KIND_OF[traffic[2]]
    n_src = int((cn.kinds == ks).sum())
    rate, n_cycles = 0.05, 4000
    pkts = synthetic_packets(cn, traffic, rate, n_cycles,
                             np.random.default_rng(7))
    assert pkts, "no packets generated"
    for p in pkts:
        assert cn.kinds[p.src] == ks
        assert cn.kinds[p.dst] == kd
        assert p.src != p.dst
        assert 0 <= p.cycle < n_cycles
        assert p.flits == 9                      # default data packet
    # Bernoulli(n_cycles, rate) per source: mean n_src*rate*n_cycles, and
    # a 5-sigma band on the total (self-pair drops only shave c2c a bit).
    mean = n_src * rate * n_cycles
    sigma = np.sqrt(n_src * n_cycles * rate * (1 - rate))
    slack = mean / max((cn.kinds == kd).sum(), 1)   # dropped self pairs
    assert mean - 5 * sigma - slack <= len(pkts) <= mean + 5 * sigma


def test_synthetic_packets_rate_clips_at_one(net):
    _, cn = net
    n_cycles = 50
    pkts = synthetic_packets(cn, "m2i", 3.0, n_cycles,
                             np.random.default_rng(0))
    n_src = int((cn.kinds == MEMORY).sum())
    # rate is clipped to 1 packet/cycle/source
    assert len(pkts) <= n_src * n_cycles


def test_synthetic_packets_deterministic_under_seed(net):
    _, cn = net
    a = synthetic_packets(cn, "c2m", 0.1, 500, np.random.default_rng(3))
    b = synthetic_packets(cn, "c2m", 0.1, 500, np.random.default_rng(3))
    assert [(p.src, p.dst, p.cycle) for p in a] \
        == [(p.src, p.dst, p.cycle) for p in b]


# ---------------------------------------------------------------------------
# latency_throughput_curve: zero-load latency + saturation monotonicity.
# ---------------------------------------------------------------------------

def test_zero_load_latency_matches_routed_hops(net):
    arch, cn = net
    sim = NetSim(cn, arch)
    # a single packet: latency = hops * (d2d + pipeline) + relays * L_R
    # + serialization (flits - 1), with no contention
    srcs = np.nonzero(cn.kinds == COMPUTE)[0]
    dsts = np.nonzero(cn.kinds == MEMORY)[0]
    s, d = int(srcs[0]), int(dsts[-1])
    res = sim.run([Packet(0, s, d, 9, 0)])
    path = cn.path(s, d)
    hops = len(path) - 1
    want = hops * (arch.latency.d2d_cost() + ROUTER_PIPELINE) \
        + (hops - 1) * arch.latency.l_relay + 9 - 1
    assert res.n_done == 1
    assert res.avg_latency == pytest.approx(want)


def test_latency_throughput_curve_saturates_monotonically(net):
    arch, cn = net
    rates = [0.005, 0.02, 0.1, 0.4]
    curve = latency_throughput_curve(cn, arch, "c2m", rates,
                                     n_cycles=1500, seed=1)
    assert [r for r, _ in curve] == rates
    lats = np.array([lat for _, lat in curve])
    assert np.isfinite(lats).all()
    # low-load latency sits near the zero-load point; saturation blows up
    assert lats[0] > 0
    # monotone non-decreasing within a small tolerance for queue noise
    assert (np.diff(lats) > -0.05 * lats[:-1]).all()
    # far past saturation the average latency must clearly diverge
    assert lats[-1] > 2.0 * lats[0]


def test_curve_per_class_rates_are_independent(net):
    """Each traffic class saturates against its own bottleneck: the curve
    for a sparse class (m2i, 4 sources) stays much flatter at the same
    per-source rate than the dense c2m class (32 sources)."""
    arch, cn = net
    r = [0.25]
    (_, lat_c2m), = latency_throughput_curve(cn, arch, "c2m", r,
                                             n_cycles=1200, seed=2)
    (_, lat_m2i), = latency_throughput_curve(cn, arch, "m2i", r,
                                             n_cycles=1200, seed=2)
    assert np.isfinite(lat_c2m) and np.isfinite(lat_m2i)
    assert lat_c2m > lat_m2i


# ---------------------------------------------------------------------------
# NetSim.run side-effect freedom + per-rate curve seeding.
# ---------------------------------------------------------------------------

def test_run_does_not_mutate_packets(net):
    arch, cn = net
    pkts = synthetic_packets(cn, "c2m", 0.05, 800, np.random.default_rng(11))
    sim = NetSim(cn, arch)
    before = [dataclasses.astuple(p) for p in pkts]
    r1 = sim.run(pkts)
    r2 = sim.run(pkts)
    # Packets are frozen pure inputs: no sim state leaks onto them, so a
    # second run over the same list reproduces the first exactly.
    assert [dataclasses.astuple(p) for p in pkts] == before
    assert not hasattr(pkts[0], "inject_t")
    assert not hasattr(pkts[0], "finish_t")
    assert r1.n_done == r2.n_done == len(pkts)
    assert r1.avg_latency == r2.avg_latency
    assert np.array_equal(r1.latencies, r2.latencies)
    # Per-packet times live in the result, keyed by pid.
    assert r1.times is not None and len(r1.times) == r1.n_done
    for p in pkts:
        inj, fin = r1.times[p.pid]
        assert inj >= p.cycle and fin > inj


def test_packet_is_frozen(net):
    p = Packet(0, 1, 2, 9, 0)
    with pytest.raises(dataclasses.FrozenInstanceError):
        p.flits = 3


def test_run_empty_trace(net):
    arch, cn = net
    res = NetSim(cn, arch).run([])
    assert res.n_done == 0
    assert np.isnan(res.avg_latency)
    assert res.times == {}


def test_curve_per_rate_seeds_deterministic_and_distinct(net):
    arch, cn = net
    rates = [0.03, 0.03]
    a = latency_throughput_curve(cn, arch, "c2m", rates, n_cycles=800, seed=5)
    b = latency_throughput_curve(cn, arch, "c2m", rates, n_cycles=800, seed=5)
    # Reproducible from `seed` alone...
    assert a == b
    # ...but each rate point draws from its own (seed, index) stream, so
    # a repeated rate gets an independent sample, not a copy.
    assert a[0][1] != a[1][1]


# ---------------------------------------------------------------------------
# Device rate model: zero-load identity + saturation on the mesh baseline.
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def device(net):
    arch, cn = net
    rep = make_rep(arch, "homog32", None)
    graph, _, _ = MeshBaseline(arch).build()
    batch = stack_graphs([graph])
    model = make_trace_model(rep.layout)
    return arch, cn, batch, model


def test_device_zero_load_matches_routed_hops(device):
    arch, cn, batch, model = device
    srcs = np.nonzero(cn.kinds == COMPUTE)[0]
    dsts = np.nonzero(cn.kinds == MEMORY)[0]
    s, d = int(srcs[0]), int(dsts[-1])
    # One packet in a million cycles: queueing is negligible, so the
    # device trace_lat must equal the host's routed-hop formula.
    wl = Workload.from_trace([Packet(0, s, d, 9, 0)], cn.kinds, 10 ** 6)
    assert wl.vec().shape == (demand_dim(cn.n),)
    out = model(batch, wl.vec())
    hops = len(cn.path(s, d)) - 1
    want = hops * (arch.latency.d2d_cost() + ROUTER_PIPELINE) \
        + (hops - 1) * arch.latency.l_relay + 9 - 1
    assert float(out["trace_lat_c2m"][0]) == pytest.approx(want, abs=0.05)
    assert float(out["trace_lat_c2c"][0]) == 0.0    # no demand in class


def test_device_latency_saturates_monotonically(device):
    _, cn, batch, model = device
    lats, loads = [], []
    for r in [1e-4, 1e-3, 1e-2, 0.1, 0.4]:
        wl = Workload.synthetic(cn.kinds, "c2m", r)
        out = model(batch, wl.vec())
        lats.append(float(out["trace_lat_c2m"][0]))
        loads.append(float(out["trace_max_load"][0]))
    lats, loads = np.array(lats), np.array(loads)
    assert (np.diff(lats) > 0).all()
    assert (np.diff(loads) > 0).all()
    # far past saturation the predicted latency must clearly diverge
    assert lats[-1] > 2.0 * lats[0]


def test_workload_serde_digest_and_scaling(net):
    _, cn = net
    wl = Workload.synthetic(cn.kinds, "c2m", 0.01)
    back = Workload.from_dict(wl.to_dict())
    assert back == wl and hash(back) == hash(wl)
    assert back.digest() == wl.digest()
    assert wl.scaled(2.0).rate.sum() == pytest.approx(2 * wl.rate.sum())
    assert wl.scaled(2.0) != wl
    with pytest.raises(ValueError):
        Workload.from_dict({**wl.to_dict(), "bogus": 1})


# ---------------------------------------------------------------------------
# Calibration: device rate model vs host oracle, rank correlation across
# random placements, per traffic class, on all four paper archs.
# ---------------------------------------------------------------------------

def _spearman(a, b):
    ra = np.argsort(np.argsort(a)).astype(float)
    rb = np.argsort(np.argsort(b)).astype(float)
    ra -= ra.mean()
    rb -= rb.mean()
    return float((ra * rb).sum()
                 / np.sqrt((ra * ra).sum() * (rb * rb).sum()))


def _shared_phys(links):
    cnt = Counter()
    for p, q in links:
        cnt[p] += 1
        cnt[q] += 1
    return {p for p, c in cnt.items() if c > 1}


def _calibration_placements(arch_name, strictness, n_pl, seed=5):
    """Random connected placements plus their host nets and score graphs.

    ``strictness`` filters hetero placements where §VI-A link inference
    double-books a PHY: the PHY-level score graph then admits pass-through
    routing at the shared PHY (free of the relay surcharge, and on
    non-relay chiplets not physically possible at all), which the
    chiplet-level oracle correctly rejects — a known laxity of the proxy
    graph (see ``core.topology``), not of the rate model under test.
    ``"any"`` rejects every double-booked PHY; ``"nonrelay"`` only
    double-booked PHYs on non-relay chiplets (dense 64-chiplet corner
    placements almost always share some relay PHY, and the missed
    10-cycle relay surcharge is immaterial at that scale).
    """
    arch = paper_arch(arch_name, "baseline")
    rep = make_rep(arch, arch_name, None)
    rng = np.random.default_rng(seed)
    graphs, nets = [], []
    while len(nets) < n_pl:
        sol = rep.random(rng)
        g = rep.score_graph(sol)
        if not g.connected:
            continue
        geo = rep.geometry(sol)
        if hasattr(rep, "links_of"):
            links, _ = rep.links_of(sol)
        else:
            links, _ = infer_links_mst(arch, geo)
            shared = _shared_phys(links)
            if strictness == "any" and shared:
                continue
            if strictness == "nonrelay" and any(
                    not geo.relay[geo.owner[p]] for p in shared):
                continue
        graphs.append(g)
        nets.append(ChipletNet.from_links(arch, geo, links))
    return arch, rep, stack_graphs(graphs), nets


@pytest.mark.parametrize("arch_name,strictness", [
    ("homog32", None),
    ("hetero32", "any"),
    ("homog64", None),
    pytest.param("hetero64", "nonrelay", marks=pytest.mark.slow),
])
def test_device_model_ranks_like_host_oracle(arch_name, strictness):
    """Per traffic class, the device rate model orders random placements
    like the event-driven host simulator (Spearman rho >= 0.9).

    Calibration is at low load with the *same* trace driving both sides
    per seed: the host runs the packet list, the device scores the
    empirical ``Workload.from_trace`` compilation of it, and both are
    averaged over seeds.  Pairs the host cannot route (placements that
    strand traffic behind non-relay chiplets) are dropped from the trace
    before both measurements.
    """
    rate, n_cycles, n_pl, n_seeds = 1e-4, 12000, 7, 3
    arch, rep, batch, nets = _calibration_placements(
        arch_name, strictness, n_pl)
    kinds = np.asarray(arch.kinds())
    model = make_trace_model(rep.layout)
    rhos = {}
    for t in ("c2c", "c2m", "c2i", "m2i"):
        dev, host = [], []
        for i, cn in enumerate(nets):
            one = {k: v[i:i + 1] for k, v in batch.items()}
            hs, ds = [], []
            for sd in range(n_seeds):
                pk = synthetic_packets(cn, t, rate, n_cycles,
                                       np.random.default_rng((9, i, sd)))
                pk = [p for p in pk if cn.next_hop[p.src, p.dst] >= 0]
                hs.append(NetSim(cn, arch).run(pk).avg_latency)
                wl = Workload.from_trace(pk, kinds, n_cycles)
                ds.append(float(np.asarray(
                    model(one, wl.vec())[f"trace_lat_{t}"])[0]))
            host.append(float(np.mean(hs)))
            dev.append(float(np.mean(ds)))
        rhos[t] = _spearman(np.array(dev), np.array(host))
    assert all(r >= 0.9 for r in rhos.values()), rhos
