"""Topology inference (§VI-A MST + augmentation) and latency/throughput
proxies (§IV-A) against brute-force oracles."""
import heapq

import numpy as np
import jax.numpy as jnp
import pytest
from _hyp import given, settings, st

from repro.core.chiplets import (COMPUTE, MEMORY, ArchSpec, Chiplet,
                                 LatencyParams, paper_arch)
from repro.core.placement_hetero import HeteroRep
from repro.core.proxies import fw_counts_ref, layout_for, make_scorer
from repro.core.topology import PlacedPhys, infer_links_mst


def dijkstra(W, src):
    V = W.shape[0]
    dist = np.full(V, np.inf)
    dist[src] = 0.0
    pq = [(0.0, src)]
    while pq:
        d, u = heapq.heappop(pq)
        if d > dist[u]:
            continue
        for v in range(V):
            nd = d + W[u, v]
            if nd < dist[v] - 1e-12:
                dist[v] = nd
                heapq.heappush(pq, (nd, v))
    return dist


def count_paths(W, D, src):
    """Count shortest paths by DP over distance order."""
    V = W.shape[0]
    order = np.argsort(D[src])
    cnt = np.zeros(V)
    cnt[src] = 1
    for v in order:
        if v == src or not np.isfinite(D[src, v]):
            continue
        for u in range(V):
            if np.isfinite(W[u, v]) and u != v \
                    and abs(D[src, u] + W[u, v] - D[src, v]) < 1e-9:
                cnt[v] += cnt[u]
    return cnt


@given(st.integers(0, 500))
@settings(max_examples=15, deadline=None)
def test_fw_counts_vs_dijkstra(seed):
    rng = np.random.default_rng(seed)
    V = 14
    W = np.full((V, V), 1e9, np.float32)
    np.fill_diagonal(W, 0)
    for _ in range(26):
        i, j = rng.integers(V, size=2)
        if i != j:
            w = float(rng.integers(1, 6))
            W[i, j] = min(W[i, j], w)
            W[j, i] = min(W[j, i], w)
    D, N = fw_counts_ref(jnp.array(W))
    D, N = np.array(D), np.array(N)
    Winf = np.where(W >= 1e8, np.inf, W)
    for s in range(V):
        ds = dijkstra(Winf, s)
        got = np.where(D[s] >= 1e8, np.inf, D[s])
        np.testing.assert_allclose(got, ds, rtol=1e-5)
        cs = count_paths(Winf, np.where(D >= 1e8, np.inf, D), s)
        reach = np.isfinite(ds)
        np.testing.assert_allclose(N[s][reach], cs[reach], rtol=1e-5)


def test_mst_topology_properties(rng):
    arch = paper_arch("hetero32", "baseline")
    rep = HeteroRep(arch)
    for seed in range(5):
        r = np.random.default_rng(seed)
        sol = rep.random(r)
        geo = rep.geometry(sol)
        links, connected = infer_links_mst(arch, geo)
        # no link exceeds max length; no self-links
        for p, q in links:
            assert geo.owner[p] != geo.owner[q]
            d = np.linalg.norm(geo.pos[p] - geo.pos[q])
            assert d <= arch.max_link_mm + 1e-6
        # augmentation never assigns >1 extra link to a used PHY:
        # count PHY usage; MST may touch a PHY multiple times but
        # augmented edges only join unused PHYs (checked structurally
        # inside infer_links_mst; here: usage is finite + sane)
        use = np.zeros(geo.pos.shape[0], int)
        for p, q in links:
            use[p] += 1
            use[q] += 1
        assert use.max() <= max(ch.n_phys() for ch in arch.chiplets) * 4


def test_connectivity_common_component_not_largest():
    """Constructed counterexample for the connectivity check: with
    multi-PHY non-relay chiplets, the component with the most PHYs (4:
    the right-hand chain of B1/B2 spare PHYs) touches only B1 and B2,
    while a *smaller* component (3: A's PHY chained to one PHY of each B)
    touches every chiplet.  The placement is therefore connected; judging
    against the most-PHY component misclassified it as disconnected.
    """
    a = Chiplet("a", COMPUTE, 1.0, 1.0, ((0.5, 0.5),), relay=False)
    b = Chiplet("b", MEMORY, 1.0, 1.0,
                ((0.0, 0.0), (0.0, 0.5), (0.0, 1.0)), relay=False)
    arch = ArchSpec("counterexample", (a, b, b), LatencyParams(),
                    max_link_mm=3.0)
    pos = np.array([[0, 0],                       # a0
                    [0, 2], [100, 0], [100, 4],   # B1: x1, y1, z1
                    [0, 4], [100, 2], [100, 6]],  # B2: x2, y2, z2
                   dtype=np.float32)
    owner = np.array([0, 1, 1, 1, 2, 2, 2], dtype=np.int32)
    geo = PlacedPhys(pos=pos, owner=owner,
                     relay=np.array([False, False, False]),
                     kinds=np.array([0, 1, 1], dtype=np.int8), area=1.0)
    links, connected = infer_links_mst(arch, geo)
    # MST yields exactly the two chains: {a0, x1, x2} and {y1, y2, z1, z2}.
    assert links == [(0, 1), (1, 4), (2, 5), (3, 5), (3, 6)]
    assert connected


def test_scorer_baseline_sanity(rng):
    from repro.core.baseline import MeshBaseline

    arch = paper_arch("homog32", "baseline")
    mb = MeshBaseline(arch)
    g, geo, links = mb.build()
    scorer = make_scorer(mb.layout, chunk=1)
    out = {k: np.asarray(v) for k, v in scorer(
        dict(W=g.W[None], edges=g.edges[None], edge_mask=g.edge_mask[None],
             area=np.array([g.area], np.float32))).items()}
    # C2C latency on a mesh of 32 computes: avg hops > 1 -> > one-hop cost
    one_hop = arch.latency.d2d_cost()
    assert out["lat_c2c"][0] > one_hop
    # all throughputs in (0, 1]
    for t in ("c2c", "c2m", "c2i", "m2i"):
        assert 0 < out[f"thr_{t}"][0] <= 1.0
    # C2M latency smaller than C2I (memory is closer to compute than IO
    # by construction of traffic endpoints? not guaranteed) — just finite:
    assert np.isfinite(out["lat_c2m"][0])


def test_pallas_fw_impl_in_scorer(rng):
    """The Pallas FW kernel slots into the scorer and matches the ref."""
    from repro.kernels.ops import fw_impl_pallas

    arch = paper_arch("homog32", "baseline")
    rep_h = HeteroRep(paper_arch("hetero32", "baseline"))
    sol = rep_h.random(np.random.default_rng(0))
    g = rep_h.score_graph(sol)
    batch = dict(W=g.W[None], edges=g.edges[None],
                 edge_mask=g.edge_mask[None],
                 area=np.array([g.area], np.float32))
    s_ref = make_scorer(rep_h.layout, chunk=1)
    s_pal = make_scorer(rep_h.layout, fw_impl=fw_impl_pallas, chunk=1)
    o1 = {k: np.asarray(v) for k, v in s_ref(batch).items()}
    o2 = {k: np.asarray(v) for k, v in s_pal(batch).items()}
    for k in o1:
        np.testing.assert_allclose(o1[k], o2[k], rtol=1e-4, atol=1e-4)
